//! Straggler-process playground: correlated failures end to end.
//!
//! 1. Materializes a Gilbert–Elliott persistent-slow-state scenario into
//!    an explicit JSON trace (the failure-process analogue of the churn
//!    subsystem's `topology_updates.json`), saves + reloads it, and
//!    replays it to show traces are faithful, portable artifacts.
//! 2. Runs DSGD-AAU against synchronous DSGD and fixed-k under the
//!    i.i.d. Bernoulli coin and under correlated processes with the same
//!    slowdown, showing that adaptive waiting matters most when slowness
//!    is *persistent* — the regime the coin cannot express.
//!
//! ```text
//! cargo run --release --example straggler_demo
//! ```

use dsgd_aau::algorithms::AlgorithmKind;
use dsgd_aau::config::{BackendKind, ExperimentConfig};
use dsgd_aau::coordinator::run_experiment;
use dsgd_aau::engine::Engine;
use dsgd_aau::sim::{materialize_trace, StragglerKind, StragglerModel};
use dsgd_aau::topology::TopologyKind;

fn main() -> anyhow::Result<()> {
    let n = 16;

    // --- 1. traces are explicit, saveable artifacts --------------------
    // (time constants at the workload scale: slow windows of ~0.1 s span
    // ~10 gradient steps at mean_compute = 0.01 s)
    let ge = StragglerModel {
        kind: StragglerKind::GilbertElliott { mean_fast: 0.4, mean_slow: 0.1 },
        seed: Some(42),
        ..StragglerModel::default()
    };
    let timeline = materialize_trace(&ge, n, 0, 150.0)?;
    println!(
        "materialized {} state flips over 150 virtual seconds ({} workers)",
        timeline.num_events(),
        n
    );
    for e in timeline.entries.iter().take(4) {
        let ev = e.events[0];
        println!(
            "  t={:<6.2} worker {} -> {}",
            e.time,
            ev.worker,
            if ev.slow { "slow" } else { "fast" }
        );
    }

    let path = std::env::temp_dir().join("straggler_demo_trace.json");
    timeline.save(&path)?;
    let reloaded = dsgd_aau::sim::StragglerTimeline::load(&path)?;
    anyhow::ensure!(reloaded == timeline, "trace must round-trip through JSON");
    println!("trace round-trips through JSON\n");

    // --- 2. training under correlated stragglers -----------------------
    let processes: Vec<(&str, StragglerModel)> = vec![
        ("bernoulli", StragglerModel::default()),
        ("gilbert_elliott", ge.clone()),
        (
            "weibull bursts",
            StragglerModel {
                kind: StragglerKind::WeibullBursts { shape: 0.7, scale: 0.4, mean_burst: 0.1 },
                seed: Some(42),
                ..StragglerModel::default()
            },
        ),
        (
            "trace replay",
            StragglerModel {
                kind: StragglerKind::Trace { path: path.display().to_string() },
                ..StragglerModel::default()
            },
        ),
    ];

    println!(
        "{:<16} {:>10} {:>8} {:>10} {:>9} {:>9}",
        "process", "algo", "iters", "vtime(s)", "s/iter", "loss"
    );
    for (label, straggler) in &processes {
        for alg in [
            AlgorithmKind::DsgdAau,
            AlgorithmKind::DsgdSync,
            AlgorithmKind::FixedK { k: n },
        ] {
            let mut cfg = ExperimentConfig::default();
            cfg.name = format!("straggler_demo_{label}");
            cfg.num_workers = n;
            cfg.topology = TopologyKind::Random { p: 0.25, seed: 3 };
            cfg.algorithm = alg;
            cfg.backend = BackendKind::Quadratic;
            cfg.straggler = straggler.clone();
            cfg.max_iterations = 400;
            cfg.eval_every = 100;
            cfg.mean_compute = 0.01;
            let s = run_experiment(&cfg)?;
            println!(
                "{:<16} {:>10} {:>8} {:>10.2} {:>9.4} {:>9.4}",
                label,
                s.algorithm,
                s.iterations,
                s.virtual_time,
                s.virtual_time / s.iterations.max(1) as f64,
                s.final_loss(),
            );
        }
    }
    std::fs::remove_file(&path).ok();

    // --- 3. the engine exposes which process drove a run ----------------
    let mut cfg = ExperimentConfig::default();
    cfg.num_workers = 8;
    cfg.backend = BackendKind::Quadratic;
    cfg.straggler = ge;
    cfg.max_iterations = 50;
    cfg.mean_compute = 0.01;
    let eng = Engine::from_config(&cfg, dsgd_aau::coordinator::build_backend(&cfg)?);
    println!("\nactive straggler process: {}", eng.core().straggler_process());

    println!(
        "\nReading: under bernoulli the per-iteration coin spreads slowness \
         evenly, so the barrier baselines limp along; under gilbert_elliott \
         or weibull the *same* slowdown concentrates into persistent windows \
         and the full-barrier baselines' time per iteration blows up while \
         DSGD-AAU routes gossip around the currently-slow workers."
    );
    Ok(())
}
