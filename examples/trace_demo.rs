//! Real-cluster trace replay: ingest a Google Borg machine-event excerpt
//! and an Alibaba utilization excerpt, inspect what the pipeline lowers
//! them into, then train DSGD-AAU and synchronous DSGD through each.
//!
//! Run from the repository root (the bundled excerpts resolve relative
//! to it):
//!
//! ```text
//! cargo run --release --example trace_demo
//! ```

use dsgd_aau::algorithms::AlgorithmKind;
use dsgd_aau::config::{BackendKind, ExperimentConfig};
use dsgd_aau::coordinator::run_experiment;
use dsgd_aau::topology::TopologyKind;
use dsgd_aau::trace::{TraceConfig, TraceIngest, TraceKind};

fn main() -> anyhow::Result<()> {
    let n = 10;
    let horizon = 8.0;
    let sources = [
        (TraceKind::Borg, "rust/testdata/traces/borg_machine_events.csv"),
        (TraceKind::Alibaba, "rust/testdata/traces/alibaba_machine_usage.csv"),
    ];

    for (kind, path) in sources {
        let tc = TraceConfig {
            kind,
            path: path.to_string(),
            horizon,
            ..TraceConfig::default()
        };

        // --- 1. what does ingestion see? -------------------------------
        let ing = TraceIngest::load(&tc)?;
        let graph = TopologyKind::Random { p: 0.3, seed: 11 }.build(n);
        let lowered = ing.lower(n, &graph)?;
        let (t0, t1) = lowered.window;
        println!(
            "\n=== {} ===\n{} events on {} machines over [{t0:.0}s, {t1:.0}s], \
             mapped onto {n} workers ({} dropped)\n\
             lowered: {} straggler flips, {} topology mutations over {horizon}s virtual",
            path,
            ing.num_events(),
            ing.machines().len(),
            lowered.machines_dropped,
            lowered.straggler.num_events(),
            lowered.topology.num_mutations(),
        );

        // --- 2. train through the replay -------------------------------
        println!(
            "{:<10} {:>8} {:>9} {:>8} {:>9} {:>8}",
            "algorithm", "iters", "loss", "strag%", "changes", "applied"
        );
        for alg in [AlgorithmKind::DsgdAau, AlgorithmKind::DsgdSync] {
            let mut cfg = ExperimentConfig::default();
            cfg.name = format!("trace_demo_{}_{}", kind.token(), alg.token());
            cfg.num_workers = n;
            cfg.topology = TopologyKind::Random { p: 0.3, seed: 11 };
            cfg.algorithm = alg;
            cfg.backend = BackendKind::Quadratic;
            cfg.trace = Some(tc.clone());
            cfg.max_iterations = u64::MAX / 2;
            cfg.time_budget = Some(horizon);
            cfg.eval_every = 200;
            cfg.mean_compute = 0.01;
            let s = run_experiment(&cfg)?;
            println!(
                "{:<10} {:>8} {:>9.4} {:>8.1} {:>9} {:>8}",
                s.algorithm,
                s.iterations,
                s.final_loss(),
                100.0 * s.straggler_fraction,
                s.recorder.topology_changes,
                s.recorder.mutations_applied,
            );
        }
    }

    println!(
        "\nReading: the Borg excerpt carries machine churn (REMOVE/ADD \
         lower to isolate/attach mutations; connectivity repair keeps a \
         lifeline), the Alibaba excerpt carries utilization-driven slow \
         windows (thresholded at 80% CPU with hysteresis) — the same \
         adaptive-waiting advantage DSGD-AAU shows on synthetic \
         processes carries over to real cluster history."
    );
    Ok(())
}
