//! Straggler-resilience scenario: how each algorithm's time-to-loss
//! degrades as the fleet gets slower and flakier — the paper's core
//! motivation (§1, §3) in one runnable.
//!
//! Sweeps straggler probability while keeping the workload fixed, and
//! prints the virtual time each algorithm needs to reach a loss target.
//!
//! ```text
//! cargo run --release --example straggler_sweep
//! ```

use dsgd_aau::algorithms::AlgorithmKind;
use dsgd_aau::config::{BackendKind, ExperimentConfig};
use dsgd_aau::coordinator::run_sweep;

fn main() -> anyhow::Result<()> {
    let probs = [0.0, 0.1, 0.3];
    let target_loss = 1.8f32;
    println!(
        "time (virtual s) to reach training loss <= {target_loss} — 16 workers, mlp_small, non-IID\n"
    );
    println!(
        "{:<18} {:>10} {:>10} {:>10}",
        "algorithm", "p=0%", "p=10%", "p=30%"
    );
    for alg in AlgorithmKind::all() {
        let cfgs: Vec<ExperimentConfig> = probs
            .iter()
            .map(|&p| {
                let mut cfg = ExperimentConfig::default();
                cfg.name = format!("sweep_{}_{p}", alg.token());
                cfg.num_workers = 16;
                cfg.algorithm = alg;
                cfg.backend = BackendKind::NativeMlp;
                cfg.model = "mlp_small".into();
                cfg.max_iterations = u64::MAX / 2;
                cfg.time_budget = Some(120.0);
                cfg.eval_every = 20;
                cfg.straggler.probability = p;
                cfg.seed = 11;
                cfg
            })
            .collect();
        let mut cells = Vec::new();
        for (_, res) in run_sweep(cfgs) {
            let s = res?;
            cells.push(match s.recorder.time_to_loss(target_loss) {
                Some(t) => format!("{t:.1}s"),
                None => format!("> {:.0}s", s.virtual_time),
            });
        }
        println!(
            "{:<18} {:>10} {:>10} {:>10}",
            alg.label(),
            cells[0],
            cells[1],
            cells[2]
        );
    }
    println!(
        "\nReading: synchronous DSGD blows up with straggler probability; \
         DSGD-AAU degrades gracefully (the paper's Figure 4/9 story)."
    );
    Ok(())
}
