//! Declare-your-own sweep: the `sweep` API that powers every `bench`
//! suite, used directly for a custom grid — topology density x
//! algorithm on the quadratic workload, with the standard sinks
//! (aligned table, CSV, machine-readable `BENCH_demo.json`).
//!
//! ```text
//! cargo run --release --example sweep_demo
//! ```
//!
//! Re-running with `args.resume = true` (or `--resume` on any `bench`
//! suite) skips every cell already recorded in the JSON and rewrites
//! byte-identical artifacts.

use dsgd_aau::algorithms::AlgorithmKind;
use dsgd_aau::config::ExperimentConfig;
use dsgd_aau::sweep::cli::BenchArgs;
use dsgd_aau::sweep::{run_suite, Axis, AxisValue, Column, Fmt, SweepSpec, TableSpec};
use dsgd_aau::topology::TopologyKind;

fn main() -> anyhow::Result<()> {
    let mut args = BenchArgs::default();
    args.out_dir = std::path::PathBuf::from("results/sweep_demo");

    let spec = SweepSpec::new("demo", "Custom sweep — consensus by topology density", |cfg| {
        cfg.num_workers = 8;
        cfg.max_iterations = 300;
        cfg.eval_every = 50;
        cfg.mean_compute = 0.01;
    })
    .axis(Axis::from_numbers("p", &[0.3], &[0.3, 0.6], &[0.3, 0.6, 0.9], |cfg, p| {
        cfg.topology = TopologyKind::Random { p, seed: 11 }
    }))
    .axis(Axis::list(
        "algorithm",
        AlgorithmKind::all()
            .iter()
            .map(|&a| {
                AxisValue::new(a.label(), move |cfg: &mut ExperimentConfig| cfg.algorithm = a)
            })
            .collect(),
    ))
    .table(TableSpec::long(
        "",
        vec![
            Column::new("iters", "iterations", Fmt::Int),
            Column::new("loss", "final_loss", Fmt::F4),
            Column::new("gap", "consensus_gap", Fmt::Sci2),
        ],
    ));

    let run = run_suite(&spec, &args)?;
    println!("\nran {} cell(s), {} resumed; summary at {}", run.ran, run.skipped,
        run.json_path.display());
    Ok(())
}
