//! Topology playground: how the communication graph shapes consensus.
//!
//! Builds each topology, reports its structure (degree/diameter/Metropolis
//! β), then runs a short DSGD-AAU training on each and shows how topology
//! affects pathsearch epoch length and convergence — the paper's
//! Assumption 2 (bounded connectivity time B) made tangible.
//!
//! ```text
//! cargo run --release --example topology_explorer
//! ```

use dsgd_aau::algorithms::AlgorithmKind;
use dsgd_aau::config::{BackendKind, ExperimentConfig};
use dsgd_aau::consensus::GroupWeights;
use dsgd_aau::coordinator::run_experiment;
use dsgd_aau::topology::TopologyKind;

fn main() -> anyhow::Result<()> {
    let n = 16;
    let kinds = [
        ("ring", TopologyKind::Ring),
        ("torus", TopologyKind::Torus),
        ("random(p=.2)", TopologyKind::Random { p: 0.2, seed: 3 }),
        ("star", TopologyKind::Star),
        ("complete", TopologyKind::Complete),
        ("bipartite", TopologyKind::Bipartite { seed: 3 }),
    ];

    println!(
        "{:<14} {:>6} {:>8} {:>9} {:>8} {:>10} {:>9} {:>8}",
        "topology", "edges", "diam", "beta", "iters", "epochs", "loss", "gap"
    );
    for (name, kind) in kinds {
        let g = kind.build(n);
        let all: Vec<usize> = (0..n).collect();
        let gw = GroupWeights::metropolis(&g, &all);
        anyhow::ensure!(gw.stochasticity_error() < 1e-5, "doubly stochastic");

        let mut cfg = ExperimentConfig::default();
        cfg.name = format!("topo_{name}");
        cfg.num_workers = n;
        cfg.topology = kind;
        cfg.algorithm = AlgorithmKind::DsgdAau;
        cfg.backend = BackendKind::Quadratic;
        cfg.max_iterations = 400;
        cfg.eval_every = 100;
        cfg.mean_compute = 0.01;
        let s = run_experiment(&cfg)?;

        println!(
            "{:<14} {:>6} {:>8} {:>9.4} {:>8} {:>10} {:>9.4} {:>8.2e}",
            name,
            g.num_edges(),
            g.diameter(),
            gw.min_positive(),
            s.iterations,
            s.epochs_completed,
            s.final_loss(),
            s.consensus_gap,
        );
    }
    println!(
        "\nReading: denser graphs complete pathsearch epochs in fewer \
         iterations (smaller B in Assumption 2) and close the consensus \
         gap faster; the star's hub bottleneck shows up as slow epochs."
    );
    Ok(())
}
