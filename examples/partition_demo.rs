//! Partition playground: what happens when the network *really* splits.
//!
//! 1. Cuts a ring fleet in half on an explicit schedule (no connectivity
//!    repair), shows the `PartitionMonitor` tracking ground-truth
//!    components and the lagged observed view workers act on.
//! 2. Runs DSGD-AAU through the same partition/heal cycle in three
//!    modes — repair (PR 1), partition-blind (PR 2 baseline) and
//!    partition-aware — and prints the adaptivity ledger: stalls,
//!    component epochs, heal restarts, time spent partitioned.
//!
//! ```text
//! cargo run --release --example partition_demo
//! ```

use dsgd_aau::adapt::{component_labels, AdaptConfig, PartitionMonitor};
use dsgd_aau::algorithms::AlgorithmKind;
use dsgd_aau::churn::{
    apply_mutations_unrepaired, ChurnConfig, ChurnKind, TopologyMutation, TopologyTimeline,
};
use dsgd_aau::config::{BackendKind, ExperimentConfig};
use dsgd_aau::coordinator::run_experiment;
use dsgd_aau::topology::TopologyKind;

fn main() -> anyhow::Result<()> {
    let n = 12;

    // --- 1. component tracking on a real cut ---------------------------
    let mut g = TopologyKind::Ring.build(n);
    let mut monitor = PartitionMonitor::new(&g, 0.5); // 500 ms detection
    let cut = vec![
        TopologyMutation::RemoveEdge(5, 6),
        TopologyMutation::RemoveEdge(0, 11),
    ];
    apply_mutations_unrepaired(&mut g, &cut);
    monitor.apply_mutations(&g, &cut);
    monitor.queue_observation(1.0); // views catch up at 1.0 + 0.5
    println!(
        "t=1.0  cut applied: ground truth {} components, workers still see {}",
        monitor.num_components(),
        monitor.num_observed_components()
    );
    monitor.promote_due(1.5);
    println!(
        "t=1.5  detection: workers now see {} components, labels {:?}",
        monitor.num_observed_components(),
        component_labels(&g)
    );

    // --- 2. training through a partition/heal cycle --------------------
    let mut tl = TopologyTimeline::new();
    tl.push(1.0, cut.clone());
    tl.push(
        9.0,
        vec![TopologyMutation::AddEdge(5, 6), TopologyMutation::AddEdge(0, 11)],
    );
    let path = std::env::temp_dir().join("partition_demo_schedule.json");
    tl.save(&path)?;

    let modes: Vec<(&str, AdaptConfig)> = vec![
        ("repair (PR 1)", AdaptConfig::default()),
        (
            "blind (PR 2)",
            AdaptConfig { allow_partitions: true, ..AdaptConfig::default() },
        ),
        (
            "aware",
            AdaptConfig {
                allow_partitions: true,
                partition_aware: true,
                detection_latency: 0.1.into(),
                heal_restart: true,
            },
        ),
    ];

    println!(
        "\n{:<14} {:>8} {:>9} {:>8} {:>8} {:>11} {:>9}",
        "mode", "iters", "loss", "stalls", "splits", "comp_epochs", "restarts"
    );
    for (label, adapt) in &modes {
        let mut cfg = ExperimentConfig::default();
        cfg.name = format!("partition_demo_{label}");
        cfg.num_workers = n;
        cfg.topology = TopologyKind::Ring;
        cfg.algorithm = AlgorithmKind::DsgdAau;
        cfg.backend = BackendKind::Quadratic;
        cfg.churn = ChurnConfig {
            kind: ChurnKind::Schedule { path: path.display().to_string() },
            seed: None,
        };
        cfg.adapt = adapt.clone();
        cfg.max_iterations = u64::MAX / 2;
        cfg.time_budget = Some(12.0);
        cfg.eval_every = 500;
        cfg.mean_compute = 0.01;
        let s = run_experiment(&cfg)?;
        println!(
            "{:<14} {:>8} {:>9.4} {:>8} {:>8} {:>11} {:>9}",
            label,
            s.iterations,
            s.final_loss(),
            s.recorder.stall_fallbacks,
            s.recorder.partition_splits,
            s.recorder.component_epochs,
            s.recorder.epoch_restarts,
        );
    }
    std::fs::remove_file(&path).ok();
    println!(
        "\nReading: with repair the cut never lands (one bridge survives); \
         blind mode lets it land and DSGD-AAU's epoch can no longer span \
         the graph — only the stall fallback keeps it alive; aware mode \
         retargets the epoch to each component, so stalls vanish, component \
         epochs fire throughout the cut, and the heal restarts accumulation."
    );
    Ok(())
}
