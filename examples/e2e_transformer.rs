//! End-to-end driver (DESIGN.md §5 / the EXPERIMENTS.md headline run):
//! decentralized training of the char-level transformer LM on the bundled
//! Shakespeare corpus, across 8 heterogeneous workers with stragglers,
//! with the full three-layer stack engaged:
//!
//!   L1  Pallas fused-linear kernels (inside the lowered HLO)
//!   L2  JAX transformer fwd/bwd, AOT-lowered to `artifacts/*.hlo.txt`
//!   L3  this rust engine: DSGD-AAU pathsearch + Metropolis gossip
//!
//! Requires `make artifacts`.  Logs the loss curve and compares DSGD-AAU
//! against synchronous DSGD under the same straggler model.
//!
//! ```text
//! cargo run --release --example e2e_transformer [-- --steps 300]
//! ```

use dsgd_aau::algorithms::AlgorithmKind;
use dsgd_aau::config::{BackendKind, ExperimentConfig};
use dsgd_aau::coordinator::run_experiment;

fn main() -> anyhow::Result<()> {
    let mut steps: u64 = 300;
    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        if a == "--steps" {
            steps = args.next().unwrap_or_default().parse()?;
        }
    }
    anyhow::ensure!(
        std::path::Path::new("artifacts/manifest.json").exists(),
        "run `make artifacts` first — this example exercises the PJRT path"
    );

    let mut base = ExperimentConfig::default();
    base.num_workers = 8;
    base.backend = BackendKind::Pjrt;
    base.model = "transformer_char".into();
    base.max_iterations = steps;
    base.eval_every = (steps / 20).max(1);
    base.mean_compute = 0.08; // virtual seconds per local fwd/bwd
    base.lr.eta0 = 0.25;      // char-LM needs a hotter start than CIFAR
    base.lr.decay_every = steps / 10;
    base.seed = 7;

    println!(
        "[e2e] char-transformer ({} params padded) | {} workers | {} gossip steps | stragglers {}% x{}",
        "298k",
        base.num_workers,
        steps,
        (base.straggler.probability * 100.0) as u32,
        base.straggler.slowdown as u32,
    );

    let mut results = Vec::new();
    for alg in [AlgorithmKind::DsgdAau, AlgorithmKind::DsgdSync] {
        let mut cfg = base.clone();
        cfg.algorithm = alg;
        cfg.name = format!("e2e_{}", alg.token());
        let t0 = std::time::Instant::now();
        let summary = run_experiment(&cfg)?;
        let wall = t0.elapsed().as_secs_f64();
        println!("\n=== {} ===", alg.label());
        println!("  iter    vtime(s)    loss     next-char acc");
        for p in &summary.recorder.curve {
            println!(
                "  {:>5}  {:>9.2}  {:>7.4}  {:>6.2}%",
                p.iteration,
                p.time,
                p.loss,
                100.0 * p.accuracy
            );
        }
        println!(
            "  -> virtual {:.1}s | host wall {:.1}s | {:.1} MB | epochs {}",
            summary.virtual_time,
            wall,
            summary.recorder.total_bytes() as f64 / 1e6,
            summary.epochs_completed
        );
        let csv = format!("results/e2e_transformer_{}.csv", alg.token());
        summary.recorder.write_csv(std::path::Path::new(&csv))?;
        println!("  wrote {csv}");
        results.push((alg, summary));
    }

    let aau = &results[0].1;
    let sync = &results[1].1;
    let first = aau.recorder.curve.first().map(|p| p.loss).unwrap_or(f32::NAN);
    println!(
        "\n[e2e] DSGD-AAU loss {:.3} -> {:.3} in {:.1}s virtual; \
         sync DSGD reached {:.3} in {:.1}s virtual ({}x slower per iteration)",
        first,
        aau.final_loss(),
        aau.virtual_time,
        sync.final_loss(),
        sync.virtual_time,
        format!(
            "{:.1}",
            (sync.virtual_time / sync.iterations.max(1) as f64)
                / (aau.virtual_time / aau.iterations.max(1) as f64)
        ),
    );
    anyhow::ensure!(aau.final_loss() < first, "e2e training must reduce loss");
    println!("[e2e] OK");
    Ok(())
}
