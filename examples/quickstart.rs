//! Quickstart: train a small MLP decentralized on a ring of 8 workers with
//! DSGD-AAU through the **real three-layer path** — the AOT-compiled
//! JAX/Pallas artifacts executed via PJRT from the rust event loop.
//!
//! ```text
//! make artifacts && cargo run --release --example quickstart
//! ```
//!
//! Falls back to the native backend with a warning if artifacts are
//! missing, so the example is always runnable.

use dsgd_aau::algorithms::AlgorithmKind;
use dsgd_aau::config::{BackendKind, ExperimentConfig};
use dsgd_aau::coordinator::run_experiment;
use dsgd_aau::topology::TopologyKind;

fn main() -> anyhow::Result<()> {
    let mut cfg = ExperimentConfig::default();
    cfg.name = "quickstart".into();
    cfg.num_workers = 8;
    cfg.topology = TopologyKind::Ring;
    cfg.algorithm = AlgorithmKind::DsgdAau;
    cfg.model = "mlp_tiny".into();
    cfg.max_iterations = 150;
    cfg.eval_every = 10;
    cfg.dataset_samples = 2048;
    cfg.pjrt_gossip = true; // consensus through the Pallas gossip kernel

    cfg.backend = if std::path::Path::new("artifacts/manifest.json").exists() {
        BackendKind::Pjrt
    } else {
        eprintln!("[quickstart] artifacts/ missing — run `make artifacts` for the PJRT path");
        cfg.pjrt_gossip = false;
        BackendKind::NativeMlp
    };

    println!(
        "[quickstart] DSGD-AAU on a ring of {} workers, backend={}, model={}",
        cfg.num_workers,
        cfg.backend.token(),
        cfg.model
    );
    let summary = run_experiment(&cfg)?;

    println!("\n  iter    vtime(s)    loss     acc");
    for p in &summary.recorder.curve {
        println!(
            "  {:>5}  {:>9.2}  {:>7.4}  {:>6.2}%",
            p.iteration,
            p.time,
            p.loss,
            100.0 * p.accuracy
        );
    }
    println!(
        "\n[quickstart] {} gossip iterations, {} pathsearch epochs, \
         {:.1} MB exchanged, consensus gap {:.3e}",
        summary.iterations,
        summary.epochs_completed,
        summary.recorder.total_bytes() as f64 / 1e6,
        summary.consensus_gap,
    );
    let first = summary.recorder.curve.first().map(|p| p.loss).unwrap_or(f32::NAN);
    anyhow::ensure!(
        summary.final_loss() < first,
        "loss did not decrease ({first} -> {})",
        summary.final_loss()
    );
    println!("[quickstart] OK — loss {first:.3} -> {:.3}", summary.final_loss());
    Ok(())
}
