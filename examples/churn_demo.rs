//! Churn playground: time-varying communication graphs end to end.
//!
//! 1. Materializes a flaky-link scenario into an explicit JSON schedule
//!    (the `topology_updates.json` idea), saves + reloads it, and replays
//!    it to show the schedule is a faithful, portable artifact.
//! 2. Runs DSGD-AAU against synchronous DSGD on a static graph and under
//!    three churn scenarios, showing that adaptive asynchronous updates
//!    keep converging while the graph shifts underneath them.
//!
//! ```text
//! cargo run --release --example churn_demo
//! ```

use dsgd_aau::algorithms::AlgorithmKind;
use dsgd_aau::churn::{apply_mutations, materialize, ChurnConfig, ChurnKind, TopologyTimeline};
use dsgd_aau::config::{BackendKind, ExperimentConfig};
use dsgd_aau::coordinator::run_experiment;
use dsgd_aau::topology::TopologyKind;

fn main() -> anyhow::Result<()> {
    let n = 16;
    let topology = TopologyKind::Random { p: 0.25, seed: 3 };

    // --- 1. schedules are explicit, saveable artifacts -----------------
    let flaky = ChurnConfig {
        kind: ChurnKind::FlakyLinks { rate: 2.0, mean_downtime: 1.0 },
        seed: Some(42),
    };
    let g0 = topology.build(n);
    let timeline = materialize(&flaky, n, 0, &g0, 20.0)?;
    println!(
        "materialized {} change batches / {} mutations over 20 virtual seconds",
        timeline.len(),
        timeline.num_mutations()
    );
    for e in timeline.entries.iter().take(4) {
        println!("  t={:<6.2} {:?}", e.time, e.mutations);
    }

    let path = std::env::temp_dir().join("churn_demo_schedule.json");
    timeline.save(&path)?;
    let reloaded = TopologyTimeline::load(&path)?;
    anyhow::ensure!(reloaded == timeline, "schedule must round-trip through JSON");

    let mut g = g0.clone();
    for e in &reloaded.entries {
        apply_mutations(&mut g, &e.mutations);
        anyhow::ensure!(g.is_connected(), "repair keeps the graph connected");
    }
    println!(
        "replayed schedule: {} -> {} edges, still connected\n",
        g0.num_edges(),
        g.num_edges()
    );
    std::fs::remove_file(&path).ok();

    // --- 2. training under churn ---------------------------------------
    let scenarios: Vec<(&str, ChurnConfig)> = vec![
        ("static", ChurnConfig::default()),
        ("flaky links", flaky.clone()),
        (
            "mobile workers",
            ChurnConfig {
                kind: ChurnKind::Mobile { movers: 4, interval: 0.5, degree: 3 },
                seed: None,
            },
        ),
        (
            "partition/heal",
            ChurnConfig {
                kind: ChurnKind::PartitionHeal { period: 4.0, downtime: 1.5 },
                seed: None,
            },
        ),
    ];

    println!(
        "{:<16} {:>10} {:>8} {:>9} {:>9} {:>9} {:>9}",
        "scenario", "algo", "iters", "loss", "gap", "changes", "deferred"
    );
    for (label, churn) in &scenarios {
        for alg in [AlgorithmKind::DsgdAau, AlgorithmKind::DsgdSync] {
            let mut cfg = ExperimentConfig::default();
            cfg.name = format!("churn_demo_{label}");
            cfg.num_workers = n;
            cfg.topology = topology;
            cfg.algorithm = alg;
            cfg.backend = BackendKind::Quadratic;
            cfg.churn = churn.clone();
            cfg.max_iterations = 600;
            cfg.eval_every = 150;
            cfg.mean_compute = 0.01;
            let s = run_experiment(&cfg)?;
            println!(
                "{:<16} {:>10} {:>8} {:>9.4} {:>9.2e} {:>9} {:>9}",
                label,
                s.algorithm,
                s.iterations,
                s.final_loss(),
                s.consensus_gap,
                s.recorder.topology_changes,
                s.recorder.mutations_deferred,
            );
        }
    }
    println!(
        "\nReading: DSGD-AAU's Pathsearch re-discovers novel edges as the \
         graph shifts, so churn costs it little; synchronous DSGD pays the \
         same barrier either way but its cached Metropolis weights now \
         refresh on every change."
    );
    Ok(())
}
