"""Layer-1 Pallas kernels for DSGD-AAU.

All kernels run in ``interpret=True`` mode so they lower to plain HLO ops
executable on the CPU PJRT client (real-TPU Mosaic lowering is a
compile-only target here; see DESIGN.md SS4).

Public surface:
    matmul            tiled matmul (f32 accumulate), optional bias + ReLU
    linear_relu       custom-vjp fused linear+ReLU (fwd & bwd via Pallas)
    linear_id         custom-vjp linear (no activation)
    gossip_average    Metropolis-weighted neighbor parameter average
"""

from .matmul import matmul, linear_relu, linear_id  # noqa: F401
from .gossip import gossip_average  # noqa: F401
