"""Tiled Pallas matmul with fused bias + ReLU, plus custom-vjp linears.

The paper's local-SGD hot spot is the dense forward/backward of the worker
model (2-NN / transformer FFN).  On TPU this kernel tiles HBM->VMEM with
BlockSpecs and accumulates on the MXU in f32; here it runs interpret=True
so the identical schedule lowers to portable HLO (DESIGN.md SS4).

Grid layout: (M/bm, N/bn, K/bk).  The K axis is the innermost sequential
grid dimension: each (i, j) output tile is initialised at k == 0,
accumulated over k, and bias/activation are applied at the final k step so
the whole linear layer is a single fused kernel.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Tile candidates in MXU-friendly descending order. 128 matches the MXU
# systolic array edge; smaller powers of two keep small models on a 1x1 grid.
_TILE_CANDIDATES = (512, 256, 128, 64, 32, 16, 8, 4, 2, 1)


def _pick_tile(dim: int, cap: int = 128) -> int:
    """Largest candidate tile <= cap that divides ``dim`` exactly."""
    for t in _TILE_CANDIDATES:
        if t <= cap and dim % t == 0:
            return t
    return 1


def _mm_kernel(x_ref, w_ref, b_ref, o_ref, *, nk: int, activation: str):
    """One (bm, bn) output tile; sequential accumulation over the K grid."""

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(pl.program_id(2) == nk - 1)
    def _finish():
        acc = o_ref[...] + b_ref[...]
        if activation == "relu":
            acc = jnp.maximum(acc, 0.0)
        o_ref[...] = acc


def matmul(
    x: jax.Array,
    w: jax.Array,
    b: jax.Array | None = None,
    *,
    activation: str = "none",
    bm: int | None = None,
    bn: int | None = None,
    bk: int | None = None,
) -> jax.Array:
    """``act(x @ w + b)`` as a single tiled Pallas kernel.

    Args:
        x: ``[M, K]`` float input.
        w: ``[K, N]`` float weights.
        b: optional ``[N]`` bias (zeros if omitted).
        activation: ``"none"`` or ``"relu"``, fused at the last K step.
        bm/bn/bk: tile overrides; defaults pick the largest divisor <= 128.

    Returns:
        ``[M, N]`` float32 result.
    """
    if x.ndim != 2 or w.ndim != 2:
        raise ValueError(f"matmul expects 2-D operands, got {x.shape}/{w.shape}")
    m, k = x.shape
    k2, n = w.shape
    if k != k2:
        raise ValueError(f"contraction mismatch: {x.shape} @ {w.shape}")
    if activation not in ("none", "relu"):
        raise ValueError(f"unknown activation {activation!r}")
    if b is None:
        b = jnp.zeros((n,), jnp.float32)
    # Tile policy (see DESIGN.md §Perf): M/N tiles at the 128 MXU edge,
    # K tile up to 512 — deeper K slabs cut grid-iteration overhead ~4x at
    # a VMEM cost of bm*bk + bk*bn + bm*bn floats (<= ~0.7 MiB for the
    # models here, far inside the ~16 MiB budget).
    bm = bm or _pick_tile(m)
    bn = bn or _pick_tile(n, cap=256)
    bk = bk or _pick_tile(k, cap=512)
    if m % bm or n % bn or k % bk:
        raise ValueError(f"tiles ({bm},{bn},{bk}) must divide dims ({m},{n},{k})")
    grid = (m // bm, n // bn, k // bk)
    kernel = functools.partial(_mm_kernel, nk=grid[2], activation=activation)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x.astype(jnp.float32), w.astype(jnp.float32), b.reshape(1, n).astype(jnp.float32))


# --------------------------------------------------------------------------
# custom-vjp linear layers: forward AND backward matmuls go through Pallas,
# so the entire 2-NN fwd/bwd lowers through the L1 kernel.
# --------------------------------------------------------------------------


@jax.custom_vjp
def linear_relu(x, w, b):
    """Fused ``relu(x @ w + b)`` with a Pallas forward and backward."""
    return matmul(x, w, b, activation="relu")


def _linear_relu_fwd(x, w, b):
    y = matmul(x, w, b, activation="relu")
    return y, (x, w, y)


def _linear_relu_bwd(res, dy):
    x, w, y = res
    dy = jnp.where(y > 0.0, dy, 0.0)
    dx = matmul(dy, w.T)
    dw = matmul(x.T, dy)
    db = jnp.sum(dy, axis=0)
    return dx, dw, db


linear_relu.defvjp(_linear_relu_fwd, _linear_relu_bwd)


@jax.custom_vjp
def linear_id(x, w, b):
    """``x @ w + b`` with a Pallas forward and backward."""
    return matmul(x, w, b, activation="none")


def _linear_id_fwd(x, w, b):
    return matmul(x, w, b, activation="none"), (x, w)


def _linear_id_bwd(res, dy):
    x, w = res
    dx = matmul(dy, w.T)
    dw = matmul(x.T, dy)
    db = jnp.sum(dy, axis=0)
    return dx, dw, db


linear_id.defvjp(_linear_id_fwd, _linear_id_bwd)
