"""Pure-jnp oracles for the Pallas kernels (the correctness ground truth)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def matmul_ref(x, w, b=None, activation: str = "none") -> jax.Array:
    """Reference for kernels.matmul: ``act(x @ w + b)`` in plain jnp."""
    y = jnp.dot(x.astype(jnp.float32), w.astype(jnp.float32))
    if b is not None:
        y = y + b.astype(jnp.float32)
    if activation == "relu":
        y = jnp.maximum(y, 0.0)
    elif activation != "none":
        raise ValueError(activation)
    return y


def linear_relu_ref(x, w, b) -> jax.Array:
    return matmul_ref(x, w, b, activation="relu")


def linear_id_ref(x, w, b) -> jax.Array:
    return matmul_ref(x, w, b, activation="none")


def gossip_average_ref(stack, weights) -> jax.Array:
    """Reference for kernels.gossip_average."""
    return jnp.einsum(
        "kd,k->d", stack.astype(jnp.float32), weights.astype(jnp.float32)
    )
