"""Pallas gossip-average kernel: the consensus update of eq. (4).

``out = sum_k weights[k] * stack[k, :]`` over a stack of neighbor parameter
vectors.  The Metropolis weights (Assumption 1) are computed by the rust
coordinator; zero-weight rows make the fixed-fanout artifact usable for any
active-neighbor count <= K_MAX.

Tiling: 1-D grid over the (padded) parameter dimension; each program loads
a ``(K, bd)`` VMEM block of the stack plus the full weight vector and emits
one ``(bd,)`` slice of the consensus result.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_TILE_CANDIDATES = (512, 256, 128, 64, 32, 16, 8, 4, 2, 1)


def _pick_tile(dim: int, cap: int = 512) -> int:
    for t in _TILE_CANDIDATES:
        if t <= cap and dim % t == 0:
            return t
    return 1


def _gossip_kernel(stack_ref, w_ref, o_ref):
    # (K, bd) * (K, 1) -> (bd,)
    o_ref[...] = jnp.sum(stack_ref[...] * w_ref[...].reshape(-1, 1), axis=0)


def gossip_average(stack: jax.Array, weights: jax.Array, *, bd: int | None = None) -> jax.Array:
    """Weighted average of stacked parameter vectors.

    Args:
        stack: ``[K, D]`` neighbor parameter vectors (row 0 is usually self).
        weights: ``[K]`` consensus weights; inactive rows carry weight 0.
        bd: tile width override (default: largest divisor of D <= 512).

    Returns:
        ``[D]`` float32 consensus vector.
    """
    if stack.ndim != 2 or weights.ndim != 1:
        raise ValueError(f"bad shapes: stack {stack.shape}, weights {weights.shape}")
    k, d = stack.shape
    if weights.shape[0] != k:
        raise ValueError(f"weights {weights.shape} != stack rows {k}")
    bd = bd or _pick_tile(d)
    if d % bd:
        raise ValueError(f"tile {bd} must divide D={d}")
    return pl.pallas_call(
        _gossip_kernel,
        grid=(d // bd,),
        in_specs=[
            pl.BlockSpec((k, bd), lambda i: (0, i)),
            pl.BlockSpec((k,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bd,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((d,), jnp.float32),
        interpret=True,
    )(stack.astype(jnp.float32), weights.astype(jnp.float32))
