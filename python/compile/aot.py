"""AOT lowering: JAX model graphs -> HLO text artifacts for the rust runtime.

This is the only place Python runs; afterwards the rust binary is
self-contained.  Interchange is **HLO text**, not serialized HloModuleProto:
jax >= 0.5 emits protos with 64-bit instruction ids which xla_extension
0.5.1 (the version behind the published ``xla`` 0.1.6 crate) rejects; the
text parser reassigns ids and round-trips cleanly (see
/opt/xla-example/README.md).

Usage:
    python -m compile.aot --out-dir ../artifacts \
        [--variants mlp_tiny,mlp_small,mlp2nn,transformer_char]

Outputs per variant:
    <name>.train.hlo.txt   (flat, x, y) -> (loss, grads_flat, correct)
    <name>.eval.hlo.txt    (flat, x, y) -> (loss, correct)
shared:
    gossip_d<Dp>_k<K>.hlo.txt  (stack[K, Dp], weights[K]) -> [Dp]
    manifest.json              shapes/dtypes/layout index for rust
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M
from .kernels import gossip_average

GOSSIP_FANOUT = 8  # max simultaneous gossip partners per consensus call
DEFAULT_VARIANTS = ("mlp_tiny", "mlp_small", "mlp2nn", "transformer_char")


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _dtype(tag: str):
    return jnp.float32 if tag == "f32" else jnp.int32


def lower_variant(spec: M.ModelSpec) -> dict:
    """Lower train/eval for one model variant; returns HLO text by role."""
    flat = jax.ShapeDtypeStruct((spec.padded_dim,), jnp.float32)
    (xs, xd) = spec.input_spec()
    (ys, yd) = spec.label_spec()
    x = jax.ShapeDtypeStruct(xs, _dtype(xd))
    y = jax.ShapeDtypeStruct(ys, _dtype(yd))

    def train(flat, x, y):
        return M.make_train_step(spec)(flat, x, y)

    def evals(flat, x, y):
        return M.make_eval_step(spec)(flat, x, y)

    return {
        "train": to_hlo_text(jax.jit(train).lower(flat, x, y)),
        "eval": to_hlo_text(jax.jit(evals).lower(flat, x, y)),
    }


def lower_gossip(padded_dim: int, fanout: int = GOSSIP_FANOUT) -> str:
    stack = jax.ShapeDtypeStruct((fanout, padded_dim), jnp.float32)
    weights = jax.ShapeDtypeStruct((fanout,), jnp.float32)

    def g(stack, weights):
        return (gossip_average(stack, weights),)

    return to_hlo_text(jax.jit(g).lower(stack, weights))


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--out", default=None, help="(legacy) single-file sentinel")
    ap.add_argument(
        "--variants", default=",".join(DEFAULT_VARIANTS),
        help="comma-separated model variant names",
    )
    args = ap.parse_args()
    out_dir = args.out_dir
    if args.out is not None:
        out_dir = os.path.dirname(args.out) or "."
    os.makedirs(out_dir, exist_ok=True)

    variants = [v for v in args.variants.split(",") if v]
    manifest = {
        "format": "hlo-text/v1",
        "gossip_fanout": GOSSIP_FANOUT,
        "variants": {},
        "gossip": {},
    }

    gossip_dims = set()
    for name in variants:
        spec = M.MODELS[name]
        print(f"[aot] lowering {name}: dim={spec.dim} padded={spec.padded_dim}")
        hlo = lower_variant(spec)
        files = {}
        for role, text in hlo.items():
            fname = f"{name}.{role}.hlo.txt"
            with open(os.path.join(out_dir, fname), "w") as f:
                f.write(text)
            files[role] = fname
        gossip_dims.add(spec.padded_dim)
        manifest["variants"][name] = {
            "kind": spec.kind,
            "dim": spec.dim,
            "padded_dim": spec.padded_dim,
            "batch": spec.batch,
            "num_classes": spec.num_classes,
            "input_shape": list(spec.input_spec()[0]),
            "input_dtype": spec.input_spec()[1],
            "label_shape": list(spec.label_spec()[0]),
            "input_dim": spec.input_dim,
            "seq_len": spec.seq_len,
            "vocab": spec.vocab,
            "files": files,
            "gossip_file": f"gossip_d{spec.padded_dim}_k{GOSSIP_FANOUT}.hlo.txt",
            "layout": [[n, list(s)] for n, s in spec.param_shapes()],
        }

    for dp in sorted(gossip_dims):
        fname = f"gossip_d{dp}_k{GOSSIP_FANOUT}.hlo.txt"
        print(f"[aot] lowering gossip D={dp} K={GOSSIP_FANOUT}")
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(lower_gossip(dp))
        manifest["gossip"][str(dp)] = fname

    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    if args.out is not None:
        # legacy Makefile sentinel: touch the requested path
        with open(args.out, "w") as f:
            f.write("see manifest.json\n")
    print(f"[aot] wrote {len(variants)} variants + manifest to {out_dir}")


if __name__ == "__main__":
    main()
