"""Layer-2 JAX models for DSGD-AAU workers.

Every worker in the rust engine runs the same compute graph, AOT-lowered
once by ``aot.py``; this module defines that graph.  All parameters live in
a single flat f32 vector (padded to a multiple of 256 so the gossip kernel
tiles cleanly), which is also the unit the rust coordinator gossips.

Models (paper SS6 / Appendix D, adapted per DESIGN.md SS3):
    mlp_tiny          32-32-16-10    fast unit-test model
    mlp_small         128-64-32-10   bench workhorse (synthetic CIFAR-like)
    mlp2nn            3072-256-256-10  the paper's 2-NN (Table 3) verbatim
    transformer_char  2-layer char LM (Shakespeare-task analogue)
    transformer_med   4-layer char LM for the e2e example

Entry points lowered to HLO:
    train_step(flat, x, y) -> (loss, grads_flat, correct)
    eval_step(flat, x, y)  -> (loss, correct)
plus the shared gossip_average(stack, weights) artifact from kernels/.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp

from .kernels import linear_id, linear_relu
from .kernels import ref as kref

PAD_MULTIPLE = 256  # gossip kernel tile granularity


@dataclasses.dataclass(frozen=True)
class ModelSpec:
    """Static description of one model variant (shapes are compile-time)."""

    name: str
    kind: str  # "mlp" | "transformer"
    batch: int
    num_classes: int
    # mlp fields
    input_dim: int = 0
    hidden: Tuple[int, ...] = ()
    # transformer fields
    vocab: int = 0
    seq_len: int = 0
    d_model: int = 0
    n_layers: int = 0
    n_heads: int = 0
    d_ff: int = 0

    def param_shapes(self) -> List[Tuple[str, Tuple[int, ...]]]:
        """Ordered (name, shape) list defining the flat layout."""
        shapes: List[Tuple[str, Tuple[int, ...]]] = []
        if self.kind == "mlp":
            dims = (self.input_dim, *self.hidden, self.num_classes)
            for i in range(len(dims) - 1):
                shapes.append((f"w{i}", (dims[i], dims[i + 1])))
                shapes.append((f"b{i}", (dims[i + 1],)))
        elif self.kind == "transformer":
            d, f = self.d_model, self.d_ff
            shapes.append(("embed", (self.vocab, d)))
            shapes.append(("pos", (self.seq_len, d)))
            for l in range(self.n_layers):
                shapes.append((f"l{l}.ln1_g", (d,)))
                shapes.append((f"l{l}.ln1_b", (d,)))
                shapes.append((f"l{l}.wqkv", (d, 3 * d)))
                shapes.append((f"l{l}.bqkv", (3 * d,)))
                shapes.append((f"l{l}.wo", (d, d)))
                shapes.append((f"l{l}.bo", (d,)))
                shapes.append((f"l{l}.ln2_g", (d,)))
                shapes.append((f"l{l}.ln2_b", (d,)))
                shapes.append((f"l{l}.w1", (d, f)))
                shapes.append((f"l{l}.b1", (f,)))
                shapes.append((f"l{l}.w2", (f, d)))
                shapes.append((f"l{l}.b2", (d,)))
            shapes.append(("lnf_g", (d,)))
            shapes.append(("lnf_b", (d,)))
            shapes.append(("head_w", (d, self.vocab)))
            shapes.append(("head_b", (self.vocab,)))
        else:
            raise ValueError(f"unknown kind {self.kind!r}")
        return shapes

    @property
    def dim(self) -> int:
        """True parameter count."""
        return sum(
            functools.reduce(lambda a, b: a * b, shape, 1)
            for _, shape in self.param_shapes()
        )

    @property
    def padded_dim(self) -> int:
        """Flat-vector length padded for the gossip kernel."""
        d = self.dim
        return ((d + PAD_MULTIPLE - 1) // PAD_MULTIPLE) * PAD_MULTIPLE

    def input_spec(self) -> Tuple[Tuple[int, ...], str]:
        """Per-batch input (shape, dtype) as seen by the rust runtime."""
        if self.kind == "mlp":
            return (self.batch, self.input_dim), "f32"
        return (self.batch, self.seq_len), "i32"

    def label_spec(self) -> Tuple[Tuple[int, ...], str]:
        if self.kind == "mlp":
            return (self.batch,), "i32"
        return (self.batch, self.seq_len), "i32"


MODELS: Dict[str, ModelSpec] = {
    "mlp_tiny": ModelSpec(
        name="mlp_tiny", kind="mlp", batch=16, num_classes=10,
        input_dim=32, hidden=(32, 16),
    ),
    "mlp_small": ModelSpec(
        name="mlp_small", kind="mlp", batch=32, num_classes=10,
        input_dim=128, hidden=(64, 32),
    ),
    "mlp2nn": ModelSpec(
        # The paper's 2-NN, Table 3: 3072 -> 256 -> 256 -> 10.
        name="mlp2nn", kind="mlp", batch=32, num_classes=10,
        input_dim=3072, hidden=(256, 256),
    ),
    "transformer_char": ModelSpec(
        name="transformer_char", kind="transformer", batch=16, num_classes=96,
        vocab=96, seq_len=64, d_model=128, n_layers=2, n_heads=4, d_ff=256,
    ),
    "transformer_med": ModelSpec(
        name="transformer_med", kind="transformer", batch=8, num_classes=96,
        vocab=96, seq_len=128, d_model=256, n_layers=4, n_heads=8, d_ff=1024,
    ),
}


# --------------------------------------------------------------------------
# flat <-> tree
# --------------------------------------------------------------------------


def unflatten(spec: ModelSpec, flat: jax.Array) -> Dict[str, jax.Array]:
    """Slice the (padded) flat vector into named parameter arrays."""
    params: Dict[str, jax.Array] = {}
    off = 0
    for name, shape in spec.param_shapes():
        size = functools.reduce(lambda a, b: a * b, shape, 1)
        params[name] = jax.lax.dynamic_slice_in_dim(flat, off, size).reshape(shape)
        off += size
    return params


def flatten(spec: ModelSpec, params: Dict[str, jax.Array]) -> jax.Array:
    """Inverse of :func:`unflatten`; pads with zeros to ``padded_dim``."""
    parts = [params[name].reshape(-1) for name, _ in spec.param_shapes()]
    flat = jnp.concatenate(parts).astype(jnp.float32)
    pad = spec.padded_dim - flat.shape[0]
    return jnp.pad(flat, (0, pad))


def init_params(spec: ModelSpec, key: jax.Array) -> jax.Array:
    """He-style init (zeros for biases, ones for LN gains), padded flat."""
    params: Dict[str, jax.Array] = {}
    for name, shape in spec.param_shapes():
        key, sub = jax.random.split(key)
        leaf = name.split(".")[-1]
        if leaf.endswith("_g"):
            params[name] = jnp.ones(shape, jnp.float32)
        elif leaf.startswith("b") or leaf.endswith("_b") or leaf == "pos":
            params[name] = jnp.zeros(shape, jnp.float32)
        else:
            fan_in = shape[0]
            params[name] = jnp.sqrt(2.0 / fan_in) * jax.random.normal(
                sub, shape, jnp.float32
            )
    return flatten(spec, params)


# --------------------------------------------------------------------------
# forward passes
# --------------------------------------------------------------------------


def _linear(x, w, b, act: str, use_pallas: bool):
    if use_pallas:
        return linear_relu(x, w, b) if act == "relu" else linear_id(x, w, b)
    return kref.matmul_ref(x, w, b, activation=act)


def _mlp_logits(spec: ModelSpec, p: Dict[str, jax.Array], x, use_pallas: bool):
    h = x.astype(jnp.float32)
    n_layers = len(spec.hidden) + 1
    for i in range(n_layers):
        act = "relu" if i < n_layers - 1 else "none"
        h = _linear(h, p[f"w{i}"], p[f"b{i}"], act, use_pallas)
    return h  # [B, C]


def _layer_norm(x, g, b, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * g + b


def _attention(spec: ModelSpec, qkv, B, T):
    d, h = spec.d_model, spec.n_heads
    dh = d // h
    q, k, v = jnp.split(qkv, 3, axis=-1)

    def heads(t):
        return t.reshape(B, T, h, dh).transpose(0, 2, 1, 3)

    q, k, v = heads(q), heads(k), heads(v)
    scores = jnp.einsum("bhqd,bhkd->bhqk", q, k) / jnp.sqrt(float(dh))
    mask = jnp.tril(jnp.ones((T, T), bool))
    scores = jnp.where(mask, scores, -1e30)
    attn = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", attn, v)
    return out.transpose(0, 2, 1, 3).reshape(B, T, d)


def _transformer_logits(spec: ModelSpec, p, tokens, use_pallas: bool):
    B, T = tokens.shape
    d = spec.d_model
    h = p["embed"][tokens] + p["pos"][None, :T, :]
    for l in range(spec.n_layers):
        x = _layer_norm(h, p[f"l{l}.ln1_g"], p[f"l{l}.ln1_b"])
        qkv = _linear(
            x.reshape(B * T, d), p[f"l{l}.wqkv"], p[f"l{l}.bqkv"], "none", use_pallas
        ).reshape(B, T, 3 * d)
        attn = _attention(spec, qkv, B, T)
        attn = _linear(
            attn.reshape(B * T, d), p[f"l{l}.wo"], p[f"l{l}.bo"], "none", use_pallas
        ).reshape(B, T, d)
        h = h + attn
        x = _layer_norm(h, p[f"l{l}.ln2_g"], p[f"l{l}.ln2_b"])
        ff = _linear(
            x.reshape(B * T, d), p[f"l{l}.w1"], p[f"l{l}.b1"], "relu", use_pallas
        )
        ff = _linear(ff, p[f"l{l}.w2"], p[f"l{l}.b2"], "none", use_pallas)
        h = h + ff.reshape(B, T, d)
    h = _layer_norm(h, p["lnf_g"], p["lnf_b"])
    logits = _linear(
        h.reshape(B * T, d), p["head_w"], p["head_b"], "none", use_pallas
    )
    return logits.reshape(B, T, spec.vocab)


def forward(spec: ModelSpec, flat: jax.Array, x: jax.Array, *, use_pallas: bool = True):
    """Logits for a batch: ``[B, C]`` (mlp) or ``[B, T, V]`` (transformer)."""
    p = unflatten(spec, flat)
    if spec.kind == "mlp":
        return _mlp_logits(spec, p, x, use_pallas)
    return _transformer_logits(spec, p, x, use_pallas)


# --------------------------------------------------------------------------
# loss / train / eval
# --------------------------------------------------------------------------


def loss_and_correct(spec: ModelSpec, flat, x, y, *, use_pallas: bool = True):
    """Mean cross-entropy + count of correct argmax predictions."""
    logits = forward(spec, flat, x, use_pallas=use_pallas)
    logits2 = logits.reshape(-1, logits.shape[-1])
    labels = y.reshape(-1).astype(jnp.int32)
    logp = jax.nn.log_softmax(logits2, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[:, None], axis=-1).squeeze(-1)
    loss = jnp.mean(nll)
    correct = jnp.sum((jnp.argmax(logits2, axis=-1) == labels).astype(jnp.int32))
    return loss, correct


def make_train_step(spec: ModelSpec, *, use_pallas: bool = True):
    """(flat, x, y) -> (loss, grads_flat_padded, correct)."""

    def step(flat, x, y):
        def loss_fn(f):
            return loss_and_correct(spec, f, x, y, use_pallas=use_pallas)

        (loss, correct), g = jax.value_and_grad(loss_fn, has_aux=True)(flat)
        return loss, g, correct

    return step


def make_eval_step(spec: ModelSpec, *, use_pallas: bool = True):
    """(flat, x, y) -> (loss, correct)."""

    def step(flat, x, y):
        return loss_and_correct(spec, flat, x, y, use_pallas=use_pallas)

    return step
