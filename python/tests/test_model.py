"""L2 model correctness: pallas path vs pure-jnp path, layout round-trips."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M


def _batch(spec, seed=0):
    (xs, xd) = spec.input_spec()
    (ys, _) = spec.label_spec()
    kx, ky = jax.random.split(jax.random.PRNGKey(seed))
    if xd == "f32":
        x = jax.random.normal(kx, xs, jnp.float32)
    else:
        x = jax.random.randint(kx, xs, 0, spec.vocab)
    y = jax.random.randint(ky, ys, 0, spec.num_classes)
    return x, y


@pytest.fixture(scope="module", params=["mlp_tiny", "transformer_char"])
def spec(request):
    return M.MODELS[request.param]


class TestLayout:
    def test_padded_dim_multiple(self):
        for s in M.MODELS.values():
            assert s.padded_dim % M.PAD_MULTIPLE == 0
            assert s.padded_dim >= s.dim

    def test_dim_matches_shapes(self):
        for s in M.MODELS.values():
            total = sum(int(np.prod(sh)) for _, sh in s.param_shapes())
            assert total == s.dim

    def test_mlp2nn_matches_paper_table3(self):
        # 3072x256 + 256 + 256x256 + 256 + 256x10 + 10 = 855,050
        s = M.MODELS["mlp2nn"]
        assert s.dim == 3072 * 256 + 256 + 256 * 256 + 256 + 256 * 10 + 10

    def test_flatten_unflatten_roundtrip(self, spec):
        flat = M.init_params(spec, jax.random.PRNGKey(3))
        tree = M.unflatten(spec, flat)
        flat2 = M.flatten(spec, tree)
        np.testing.assert_allclose(flat, flat2)

    def test_padding_is_zero(self, spec):
        flat = M.init_params(spec, jax.random.PRNGKey(4))
        if spec.padded_dim > spec.dim:
            np.testing.assert_allclose(flat[spec.dim:], 0.0)

    def test_unique_param_names(self):
        for s in M.MODELS.values():
            names = [n for n, _ in s.param_shapes()]
            assert len(names) == len(set(names))


class TestForward:
    def test_logits_shape(self, spec):
        flat = M.init_params(spec, jax.random.PRNGKey(0))
        x, _ = _batch(spec)
        logits = M.forward(spec, flat, x, use_pallas=False)
        if spec.kind == "mlp":
            assert logits.shape == (spec.batch, spec.num_classes)
        else:
            assert logits.shape == (spec.batch, spec.seq_len, spec.vocab)

    def test_pallas_matches_ref_forward(self, spec):
        flat = M.init_params(spec, jax.random.PRNGKey(1))
        x, _ = _batch(spec, 1)
        lp = M.forward(spec, flat, x, use_pallas=True)
        lr = M.forward(spec, flat, x, use_pallas=False)
        np.testing.assert_allclose(lp, lr, rtol=1e-4, atol=1e-4)

    def test_causality(self):
        # transformer: flipping a future token must not change past logits
        spec = M.MODELS["transformer_char"]
        flat = M.init_params(spec, jax.random.PRNGKey(2))
        x, _ = _batch(spec, 2)
        x2 = x.at[:, -1].set((x[:, -1] + 1) % spec.vocab)
        l1 = M.forward(spec, flat, x, use_pallas=False)
        l2 = M.forward(spec, flat, x2, use_pallas=False)
        np.testing.assert_allclose(l1[:, :-1], l2[:, :-1], rtol=1e-5, atol=1e-5)


class TestTrainStep:
    def test_pallas_grads_match_ref(self, spec):
        flat = M.init_params(spec, jax.random.PRNGKey(5))
        x, y = _batch(spec, 5)
        lp, gp, cp = jax.jit(M.make_train_step(spec, use_pallas=True))(flat, x, y)
        lr, gr, cr = jax.jit(M.make_train_step(spec, use_pallas=False))(flat, x, y)
        np.testing.assert_allclose(lp, lr, rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(gp, gr, rtol=3e-3, atol=3e-4)
        assert int(cp) == int(cr)

    def test_grad_padding_zero(self, spec):
        flat = M.init_params(spec, jax.random.PRNGKey(6))
        x, y = _batch(spec, 6)
        _, g, _ = jax.jit(M.make_train_step(spec))(flat, x, y)
        assert g.shape == (spec.padded_dim,)
        if spec.padded_dim > spec.dim:
            np.testing.assert_allclose(g[spec.dim:], 0.0)

    def test_loss_decreases_under_sgd(self):
        spec = M.MODELS["mlp_tiny"]
        flat = M.init_params(spec, jax.random.PRNGKey(7))
        x, y = _batch(spec, 7)
        step = jax.jit(M.make_train_step(spec))
        l0, g, _ = step(flat, x, y)
        for _ in range(20):
            l, g, _ = step(flat, x, y)
            flat = flat - 0.1 * g
        l1, _, _ = step(flat, x, y)
        assert float(l1) < float(l0) * 0.8

    def test_eval_matches_train_metrics(self, spec):
        flat = M.init_params(spec, jax.random.PRNGKey(8))
        x, y = _batch(spec, 8)
        lt, _, ct = jax.jit(M.make_train_step(spec, use_pallas=False))(flat, x, y)
        le, ce = jax.jit(M.make_eval_step(spec, use_pallas=False))(flat, x, y)
        np.testing.assert_allclose(lt, le, rtol=1e-6)
        assert int(ct) == int(ce)

    def test_correct_bounded_by_batch(self, spec):
        flat = M.init_params(spec, jax.random.PRNGKey(9))
        x, y = _batch(spec, 9)
        _, c = jax.jit(M.make_eval_step(spec, use_pallas=False))(flat, x, y)
        n = int(np.prod(spec.label_spec()[0]))
        assert 0 <= int(c) <= n
