"""L1 kernel correctness: Pallas vs pure-jnp oracle (the core signal)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import gossip_average, linear_id, linear_relu, matmul
from compile.kernels import ref
from compile.kernels.matmul import _pick_tile

DIMS = st.sampled_from([1, 2, 3, 4, 8, 10, 16, 24, 32, 48, 96, 128])


def _rand(key, shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)


class TestPickTile:
    def test_divides(self):
        for d in (1, 2, 10, 96, 128, 3072, 855296):
            t = _pick_tile(d)
            assert d % t == 0 and t <= 128

    def test_prefers_128(self):
        assert _pick_tile(3072) == 128
        assert _pick_tile(256) == 128

    def test_prime_falls_to_one(self):
        assert _pick_tile(7) == 1


class TestMatmul:
    @settings(max_examples=25, deadline=None)
    @given(m=DIMS, k=DIMS, n=DIMS, act=st.sampled_from(["none", "relu"]), seed=st.integers(0, 2**16))
    def test_matches_ref(self, m, k, n, act, seed):
        x = _rand(seed, (m, k))
        w = _rand(seed + 1, (k, n))
        b = _rand(seed + 2, (n,))
        got = matmul(x, w, b, activation=act)
        want = ref.matmul_ref(x, w, b, activation=act)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    def test_no_bias(self):
        x, w = _rand(0, (8, 16)), _rand(1, (16, 8))
        np.testing.assert_allclose(matmul(x, w), ref.matmul_ref(x, w), rtol=1e-5, atol=1e-5)

    def test_multi_tile_grid(self):
        # force a 2x2x2 grid with explicit tiles
        x, w = _rand(2, (64, 64)), _rand(3, (64, 64))
        got = matmul(x, w, bm=32, bn=32, bk=32)
        np.testing.assert_allclose(got, ref.matmul_ref(x, w), rtol=1e-4, atol=1e-4)

    def test_bad_contraction_raises(self):
        with pytest.raises(ValueError):
            matmul(_rand(0, (4, 5)), _rand(1, (6, 4)))

    def test_bad_tile_raises(self):
        with pytest.raises(ValueError):
            matmul(_rand(0, (4, 4)), _rand(1, (4, 4)), bm=3)

    def test_bad_activation_raises(self):
        with pytest.raises(ValueError):
            matmul(_rand(0, (4, 4)), _rand(1, (4, 4)), activation="gelu")

    def test_f32_accumulation_from_bf16_inputs(self):
        x = _rand(4, (16, 32)).astype(jnp.bfloat16)
        w = _rand(5, (32, 16)).astype(jnp.bfloat16)
        got = matmul(x, w)
        assert got.dtype == jnp.float32
        want = ref.matmul_ref(x, w)
        np.testing.assert_allclose(got, want, rtol=1e-2, atol=1e-2)


class TestLinearVjp:
    @settings(max_examples=10, deadline=None)
    @given(m=st.sampled_from([4, 8, 16]), k=st.sampled_from([8, 16, 32]),
           n=st.sampled_from([4, 8, 16]), seed=st.integers(0, 2**16))
    def test_relu_grads_match_ref(self, m, k, n, seed):
        x, w, b = _rand(seed, (m, k)), _rand(seed + 1, (k, n)), _rand(seed + 2, (n,))

        def f_p(x, w, b):
            return jnp.sum(jnp.sin(linear_relu(x, w, b)))

        def f_r(x, w, b):
            return jnp.sum(jnp.sin(ref.linear_relu_ref(x, w, b)))

        gp = jax.grad(f_p, argnums=(0, 1, 2))(x, w, b)
        gr = jax.grad(f_r, argnums=(0, 1, 2))(x, w, b)
        for a, c in zip(gp, gr):
            np.testing.assert_allclose(a, c, rtol=1e-4, atol=1e-4)

    def test_id_grads_match_ref(self):
        x, w, b = _rand(0, (8, 16)), _rand(1, (16, 4)), _rand(2, (4,))

        def f_p(*a):
            return jnp.sum(linear_id(*a) ** 2)

        def f_r(*a):
            return jnp.sum(ref.linear_id_ref(*a) ** 2)

        gp = jax.grad(f_p, argnums=(0, 1, 2))(x, w, b)
        gr = jax.grad(f_r, argnums=(0, 1, 2))(x, w, b)
        for a, c in zip(gp, gr):
            np.testing.assert_allclose(a, c, rtol=1e-4, atol=1e-4)

    def test_relu_dead_zone_zero_grad(self):
        # all pre-activations negative -> all grads w.r.t. x are zero
        x = jnp.ones((4, 4), jnp.float32)
        w = -jnp.eye(4, dtype=jnp.float32)
        b = jnp.zeros((4,), jnp.float32)
        g = jax.grad(lambda x: jnp.sum(linear_relu(x, w, b)))(x)
        np.testing.assert_allclose(g, jnp.zeros_like(g))


class TestGossip:
    @settings(max_examples=25, deadline=None)
    @given(k=st.integers(1, 12), d=st.sampled_from([1, 2, 8, 100, 256, 1000, 1792]),
           seed=st.integers(0, 2**16))
    def test_matches_ref(self, k, d, seed):
        stack = _rand(seed, (k, d))
        weights = jax.random.uniform(jax.random.PRNGKey(seed + 1), (k,))
        got = gossip_average(stack, weights)
        want = ref.gossip_average_ref(stack, weights)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    def test_zero_weight_rows_ignored(self):
        stack = _rand(0, (4, 64))
        w = jnp.array([0.5, 0.5, 0.0, 0.0])
        got = gossip_average(stack, w)
        want = 0.5 * stack[0] + 0.5 * stack[1]
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)

    def test_identity_weight(self):
        stack = _rand(1, (8, 128))
        w = jnp.zeros((8,)).at[3].set(1.0)
        np.testing.assert_allclose(gossip_average(stack, w), stack[3], rtol=1e-6, atol=1e-7)

    def test_doubly_stochastic_preserves_mean(self):
        # consensus with uniform weights keeps the average parameter vector
        stack = _rand(2, (8, 256))
        w = jnp.full((8,), 1.0 / 8.0)
        got = gossip_average(stack, w)
        np.testing.assert_allclose(got, jnp.mean(stack, axis=0), rtol=1e-5, atol=1e-6)

    def test_shape_mismatch_raises(self):
        with pytest.raises(ValueError):
            gossip_average(_rand(0, (4, 8)), jnp.ones((5,)))

    def test_bad_rank_raises(self):
        with pytest.raises(ValueError):
            gossip_average(_rand(0, (4, 8, 2)), jnp.ones((4,)))
