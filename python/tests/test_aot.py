"""AOT lowering: HLO text artifacts are well-formed and manifest-consistent."""

import json
import os

import jax
import jax.numpy as jnp
import pytest

from compile import aot
from compile import model as M

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


class TestLowering:
    def test_train_hlo_text(self):
        spec = M.MODELS["mlp_tiny"]
        hlo = aot.lower_variant(spec)
        for role in ("train", "eval"):
            text = hlo[role]
            assert "ENTRY" in text and "HloModule" in text
            # train entry takes (flat, x, y)
            assert f"f32[{spec.padded_dim}]" in text

    def test_gossip_hlo_text(self):
        text = aot.lower_gossip(512, fanout=4)
        assert "ENTRY" in text
        assert "f32[4,512]" in text

    def test_lowering_is_deterministic(self):
        a = aot.lower_gossip(256)
        b = aot.lower_gossip(256)
        assert a == b


@pytest.mark.skipif(not os.path.exists(os.path.join(ART, "manifest.json")),
                    reason="run `make artifacts` first")
class TestManifest:
    @pytest.fixture(scope="class")
    def manifest(self):
        with open(os.path.join(ART, "manifest.json")) as f:
            return json.load(f)

    def test_format(self, manifest):
        assert manifest["format"] == "hlo-text/v1"
        assert manifest["gossip_fanout"] == aot.GOSSIP_FANOUT

    def test_all_files_exist(self, manifest):
        for v in manifest["variants"].values():
            for fname in v["files"].values():
                assert os.path.exists(os.path.join(ART, fname)), fname
            assert os.path.exists(os.path.join(ART, v["gossip_file"]))

    def test_dims_match_specs(self, manifest):
        for name, v in manifest["variants"].items():
            spec = M.MODELS[name]
            assert v["dim"] == spec.dim
            assert v["padded_dim"] == spec.padded_dim
            assert v["batch"] == spec.batch
            assert v["layout"] == [[n, list(s)] for n, s in spec.param_shapes()]

    def test_gossip_dim_covered(self, manifest):
        for v in manifest["variants"].values():
            assert str(v["padded_dim"]) in manifest["gossip"]
