//! L3 micro-benchmarks (criterion is not in the offline dependency set,
//! so this is a hand-rolled `harness = false` bench with median-of-runs
//! reporting).  Covers the engine hot paths that the perf pass (§Perf in
//! EXPERIMENTS.md) optimizes:
//!
//!   * event-queue throughput
//!   * native gossip average (the consensus inner loop)
//!   * Metropolis weight construction
//!   * pathsearch novel-pair scanning
//!   * end-to-end engine events/sec on the quadratic backend
//!
//! Run: `cargo bench` (add `-- --quick` for fewer repetitions).

use dsgd_aau::algorithms::AlgorithmKind;
use dsgd_aau::config::{BackendKind, ExperimentConfig};
use dsgd_aau::consensus::GroupWeights;
use dsgd_aau::coordinator::run_experiment;
use dsgd_aau::engine::native_weighted_average;
use dsgd_aau::pathsearch::PathSearch;
use dsgd_aau::sim::{EventKind, EventQueue};
use dsgd_aau::topology::generators::random_connected;
use dsgd_aau::util::Rng64;
use std::time::Instant;

/// Time `f` over `iters` inner iterations, repeated `reps` times; returns
/// (median seconds per iteration, throughput/s).
fn bench<F: FnMut()>(name: &str, reps: usize, iters: usize, mut f: F) {
    let mut samples = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t0 = Instant::now();
        for _ in 0..iters {
            f();
        }
        samples.push(t0.elapsed().as_secs_f64() / iters as f64);
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let median = samples[samples.len() / 2];
    println!(
        "{name:<44} {:>12.3} ns/iter {:>14.0} iters/s",
        median * 1e9,
        1.0 / median
    );
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let reps = if quick { 3 } else { 7 };
    println!("== dsgd-aau micro benches (median of {reps}) ==\n");

    // 1. event queue push+pop
    {
        let mut q = EventQueue::new();
        let mut t = 0.0f64;
        bench("event_queue push+pop", reps, 100_000, || {
            t += 0.001;
            q.schedule(t, EventKind::ComputeDone(1));
            q.pop();
        });
    }

    // 2. native gossip average, 8 x 10k f32 (mlp_small scale)
    {
        let d = 10_752;
        let mut rng = Rng64::seed_from_u64(1);
        let rows_data: Vec<Vec<f32>> =
            (0..8).map(|_| (0..d).map(|_| rng.normal_f32()).collect()).collect();
        let rows: Vec<&[f32]> = rows_data.iter().map(|r| r.as_slice()).collect();
        let weights = [0.125f32; 8];
        bench("native_gossip_average 8x10752", reps, 2_000, || {
            let out = native_weighted_average(&rows, &weights);
            std::hint::black_box(out);
        });
    }

    // 2b. full gossip round: every member's weighted average, rows
    //     gathered once per round (mix_into_scratch's access pattern —
    //     the per-member re-gather made this O(m²) in allocations)
    {
        let d = 10_752;
        let m = 16;
        let mut rng = Rng64::seed_from_u64(2);
        let rows_data: Vec<Vec<f32>> =
            (0..m).map(|_| (0..d).map(|_| rng.normal_f32()).collect()).collect();
        let g = random_connected(m, 0.4, 5);
        let members: Vec<usize> = (0..m).collect();
        let gw = GroupWeights::metropolis(&g, &members);
        let mut scratch: Vec<Vec<f32>> = vec![vec![0f32; d]; m];
        bench("gossip_round gather-once 16x10752", reps, 200, || {
            let rows: Vec<&[f32]> = rows_data.iter().map(|r| r.as_slice()).collect();
            for (a, out) in scratch.iter_mut().enumerate() {
                dsgd_aau::engine::native_weighted_average_into(&rows, &gw.weights[a], out);
            }
            std::hint::black_box(&scratch);
        });
    }

    // 3. Metropolis weights for a 32-worker group on a random graph
    {
        let g = random_connected(64, 0.15, 7);
        let members: Vec<usize> = (0..64).step_by(2).collect();
        bench("metropolis_weights group=32 (N=64)", reps, 5_000, || {
            let gw = GroupWeights::metropolis(&g, &members);
            std::hint::black_box(gw);
        });
    }

    // 4. pathsearch novel-pair scan over a 32-worker ready set
    {
        let g = random_connected(128, 0.1, 9);
        let mut ps = PathSearch::new();
        ps.absorb_group(&g, &(0..64).collect::<Vec<_>>());
        let ready: Vec<usize> = (32..64).collect();
        bench("pathsearch find_novel_pair ready=32", reps, 20_000, || {
            std::hint::black_box(ps.find_novel_pair(&g, &ready));
        });
    }

    // 5. end-to-end engine throughput, quadratic backend
    for alg in [AlgorithmKind::DsgdAau, AlgorithmKind::AdPsgd, AlgorithmKind::DsgdSync] {
        let mut cfg = ExperimentConfig::default();
        cfg.num_workers = 32;
        cfg.algorithm = alg;
        cfg.backend = BackendKind::Quadratic;
        cfg.max_iterations = 2_000;
        cfg.eval_every = 1_000;
        cfg.mean_compute = 0.01;
        let t0 = Instant::now();
        let s = run_experiment(&cfg).expect("engine run");
        let wall = t0.elapsed().as_secs_f64();
        println!(
            "engine e2e {:<10} N=32 quad             {:>12.1} iters/s (host) {:>8} iters",
            alg.label(),
            s.iterations as f64 / wall,
            s.iterations
        );
    }

    println!("\n(engine e2e includes real gradient math; see EXPERIMENTS.md §Perf)");
}
