//! Fixture: a from_json without unknown-key rejection must be flagged.
pub struct Section {
    pub rate: f64,
}

impl Section {
    pub fn from_json(j: &Json) -> Result<Self> {
        Ok(Section { rate: j.get("rate").and_then(Json::as_f64).unwrap_or(0.0) })
    }
}
