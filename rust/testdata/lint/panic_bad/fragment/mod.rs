//! Fixture: panicking calls in the fragment wire (event-path) must be
//! flagged.
pub fn shard_of(plan: Option<usize>) -> usize {
    plan.unwrap()
}
