//! Fixture: panicking calls in membership dispatch (event-path) must be
//! flagged.
pub fn slot_of(slot: Result<usize, String>) -> usize {
    slot.expect("slot must be filled")
}
