//! Fixture: panicking calls in engine dispatch paths must be flagged.
pub fn dispatch(stash: Option<f64>, params: Result<f64, String>) -> f64 {
    if stash.is_none() {
        panic!("empty stash");
    }
    stash.unwrap() + params.expect("params missing")
}
