//! Fixture: a reasoned pragma suppresses the finding on the next line.
pub fn from_config(cfg: Option<f64>) -> f64 {
    // pallas-lint: allow(no-panic-in-engine) — documented panicking constructor, not dispatch
    cfg.expect("config invalid")
}
