//! Fixture: unknown-key rejection (directly or via apply_kv) passes.
pub struct Section {
    pub rate: f64,
}

impl Section {
    pub fn from_json(j: &Json) -> Result<Self> {
        let mut out = Section { rate: 0.0 };
        for (key, v) in j.as_obj().unwrap_or(&Default::default()) {
            match key.as_str() {
                "rate" => out.rate = v.as_f64().unwrap_or(0.0),
                other => bail!("unknown section key {other:?}"),
            }
        }
        Ok(out)
    }
}

pub struct Delegating;

impl Delegating {
    pub fn from_json(j: &Json) -> Result<Self> {
        let cfg = Delegating;
        for (key, v) in j.as_obj().iter().flat_map(|m| m.iter()) {
            cfg.apply_kv(key, v)?;
        }
        Ok(cfg)
    }
}
