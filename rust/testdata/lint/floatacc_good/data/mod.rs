//! Fixture: float hash reductions outside the ordered scopes are fine
//! (batch assembly does not feed the event stream).
use std::collections::HashMap;

pub fn checksum(m: &HashMap<usize, f32>) -> f32 {
    m.values().sum::<f32>()
}
