//! Fixture: annotation-typed float sums over ordered containers are
//! fine, as are annotation-typed integer sums over hash containers.
use std::collections::BTreeMap;

pub fn mean_lag(lags: &BTreeMap<usize, f32>) -> f32 {
    let total: f32 = lags.values().sum();
    total / lags.len() as f32
}

// pallas-lint: allow(no-unordered-iteration) — fixture: integer counts are order-independent
pub fn token_count(tokens: &std::collections::HashMap<usize, u64>) -> u64 {
    let total: u64 = tokens.values().sum();
    total
}
