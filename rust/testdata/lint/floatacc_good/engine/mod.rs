//! Fixture: ordered-container, integer and test-only reductions are all
//! fine in an event-ordered module.
use std::collections::BTreeMap;

pub fn mean_loss(losses: &BTreeMap<usize, f32>) -> f32 {
    losses.values().sum::<f32>() / losses.len() as f32
}

// pallas-lint: allow(no-unordered-iteration) — fixture: integer counts are order-independent
pub fn event_count(counts: &std::collections::HashMap<usize, u64>) -> u64 {
    counts.values().sum::<u64>()
}

#[cfg(test)]
mod tests {
    use std::collections::HashMap;

    #[test]
    fn scratch_float_sums_are_allowed_in_tests() {
        let m: HashMap<usize, f32> = HashMap::new();
        assert_eq!(m.values().sum::<f32>(), 0.0);
    }
}
