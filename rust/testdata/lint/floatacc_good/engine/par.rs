//! Fixture: parallel iterators are fine when the float reduction itself
//! stays sequential — par map, collect, then an ordered fold — and
//! integer parallel sums are order-independent to begin with.

pub fn event_total(counts: &[u64]) -> u64 {
    counts.par_iter().sum::<u64>()
}

pub fn total_loss(losses: &[f32]) -> f32 {
    let scaled: Vec<f32> = losses.par_iter().map(|l| l * 2.0).collect();
    scaled.iter().sum::<f32>()
}
