//! Fixture: a pragma that suppresses nothing is a stale baseline.
pub fn quiet(cfg: Option<f64>) -> f64 {
    // pallas-lint: allow(no-wall-clock) — leftover from a removed timing probe
    cfg.unwrap_or(0.0)
}
