//! Fixture: CLI binaries may read the host clock.
fn main() {
    let _t0 = std::time::Instant::now();
}
