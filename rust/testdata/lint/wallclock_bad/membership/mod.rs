//! Fixture: wall-clock reads outside sweep/bin must be flagged.
use std::time::{Instant, SystemTime};

pub fn sample_now() -> f64 {
    let t0 = Instant::now();
    let _wall = SystemTime::now();
    t0.elapsed().as_secs_f64()
}
