//! Fixture: unordered collections inside the engine must be flagged.
use std::collections::HashMap;

pub fn dispatch(stash: &HashMap<usize, f64>) -> f64 {
    stash.values().sum()
}
