//! Fixture: a reasonless pragma is itself a finding and suppresses nothing.
pub fn from_config(cfg: Option<f64>) -> f64 {
    // pallas-lint: allow(no-panic-in-engine)
    cfg.expect("config invalid")
}
