//! Fixture: seeded per-worker streams are the sanctioned randomness.
pub struct Rng64(u64);

impl Rng64 {
    pub fn seed_from_u64(seed: u64) -> Self {
        Rng64(seed)
    }

    // mentions of thread_rng in comments or "rand::random" in strings
    // must not trip the scan
    pub fn describe() -> &'static str {
        "not thread_rng"
    }
}
