//! Fixture: ordered collections are fine; test-only hash maps are fine.
use std::collections::BTreeMap;

pub fn dispatch(stash: &BTreeMap<usize, f64>) -> f64 {
    stash.values().sum()
}

// "HashSet" in a string and a comment must not trip the token scan.
pub fn describe() -> &'static str {
    "not a real HashSet usage"
}

#[cfg(test)]
mod tests {
    use std::collections::HashMap;

    #[test]
    fn scratch_maps_are_allowed_in_tests() {
        let m: HashMap<usize, usize> = HashMap::new();
        assert!(m.is_empty());
    }
}
