//! Fixture: `data` is not an event-ordered module; hash maps are fine.
use std::collections::HashMap;

pub fn index(names: &[String]) -> HashMap<String, usize> {
    names.iter().cloned().zip(0..).collect()
}
