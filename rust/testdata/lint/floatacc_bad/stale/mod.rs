//! Fixture: annotation-typed float sums over hash containers must be
//! flagged too (`let s: f32 = …sum()` — no turbofish to match).

// pallas-lint: allow(no-unordered-iteration) — fixture: the hash map itself is under test
use std::collections::HashMap;

// pallas-lint: allow(no-unordered-iteration) — fixture: the hash map itself is under test
pub fn mean_lag(lags: &HashMap<usize, f32>) -> f32 {
    let total: f32 = lags.values().sum();
    total / lags.len() as f32
}
