//! Fixture: float turbofish reductions over hash containers must be
//! flagged even when the container itself carries a reasoned pragma.

// pallas-lint: allow(no-unordered-iteration) — fixture: the hash map itself is under test
use std::collections::HashMap;

// pallas-lint: allow(no-unordered-iteration) — fixture: the hash map itself is under test
pub fn mean_loss(losses: &HashMap<usize, f32>) -> f32 {
    losses.values().sum::<f32>() / losses.len() as f32
}

// pallas-lint: allow(no-unordered-iteration) — fixture: the hash map itself is under test
pub fn total_weight(weights: &HashMap<usize, f64>) -> f64 {
    weights.values().copied().sum::<f64>()
}
