//! Fixture: float reductions chained off a parallel iterator must be
//! flagged in ordered scopes — thread scheduling decides the addition
//! order, so the result drifts bitwise across runs even without a hash
//! container anywhere in sight.

pub fn total_loss(losses: &[f32]) -> f32 {
    losses.par_iter().copied().sum::<f32>()
}

pub fn total_gap(gaps: &[f64]) -> f64 {
    let total: f64 = gaps.par_iter().sum();
    total
}
