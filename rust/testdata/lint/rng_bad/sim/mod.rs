//! Fixture: ambient RNG must be flagged anywhere in the tree.
pub fn jitter() -> f64 {
    let mut rng = thread_rng();
    let x: f64 = rand::random();
    let _seeded = StdRng::from_entropy();
    x + rng.gen_range(0.0..1.0)
}
