//! Fixture: non-panicking option handling in the adapt monitor path is
//! fine.
pub fn latency_of(lat: Option<f64>) -> f64 {
    lat.unwrap_or(0.1)
}
