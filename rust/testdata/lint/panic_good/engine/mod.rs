//! Fixture: non-panicking option/result handling in the engine is fine.
pub fn dispatch(stash: Option<f64>) -> f64 {
    let a = stash.unwrap_or(0.0);
    let b = stash.unwrap_or_else(|| 1.0);
    let c = stash.unwrap_or_default();
    a + b + c
}
