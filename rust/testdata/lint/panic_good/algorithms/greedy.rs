//! Fixture: the panic rule is scoped to the engine module only.
pub fn pick(xs: &[f64]) -> f64 {
    *xs.first().unwrap()
}
