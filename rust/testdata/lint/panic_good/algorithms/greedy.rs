//! Fixture: the panic rule covers the event-path modules (engine,
//! adapt, fragment, membership, stale); algorithms is outside the scope.
pub fn pick(xs: &[f64]) -> f64 {
    *xs.first().unwrap()
}
