//! Shared experiment-harness utilities for the table/figure binaries
//! (`rust/src/bin/bench_*.rs`): flag parsing, table formatting and CSV
//! output under `results/`.

use crate::config::ExperimentConfig;
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::io::Write as _;
use std::path::{Path, PathBuf};

/// Common bench flags.
#[derive(Debug, Clone)]
pub struct BenchArgs {
    /// Paper-scale run (`--full`) vs CI-scale (default).
    pub full: bool,
    /// Smoke-grid run (`--quick`): the smallest sweep that still covers
    /// every axis — what CI runs to keep the perf trajectory populated.
    pub quick: bool,
    /// Seeds per table cell.
    pub seeds: u64,
    /// Output directory for CSVs.
    pub out_dir: PathBuf,
    /// Backend override (`native_mlp` default; `pjrt` exercises artifacts).
    pub backend: Option<String>,
    /// Extra `key=value` overrides.
    pub extra: BTreeMap<String, String>,
}

impl Default for BenchArgs {
    fn default() -> Self {
        BenchArgs {
            full: false,
            quick: false,
            seeds: 3,
            out_dir: PathBuf::from("results"),
            backend: None,
            extra: BTreeMap::new(),
        }
    }
}

impl BenchArgs {
    /// Parse `std::env::args().skip(1)`.
    pub fn parse() -> Result<Self> {
        let mut out = BenchArgs::default();
        let mut it = std::env::args().skip(1);
        while let Some(a) = it.next() {
            match a.as_str() {
                "--full" => out.full = true,
                "--quick" => out.quick = true,
                "--seeds" => {
                    out.seeds = it.next().context("--seeds value")?.parse()?;
                }
                "--out" => out.out_dir = it.next().context("--out value")?.into(),
                "--backend" => out.backend = Some(it.next().context("--backend value")?),
                other => {
                    if let Some((k, v)) = other.strip_prefix("--").and_then(|s| s.split_once('=')) {
                        out.extra.insert(k.to_string(), v.to_string());
                    } else {
                        bail!(
                            "unknown flag {other} (--full --quick --seeds K --out DIR --backend B --k=v)"
                        );
                    }
                }
            }
        }
        Ok(out)
    }

    /// Apply the backend override to a config.
    pub fn apply(&self, cfg: &mut ExperimentConfig) -> Result<()> {
        if let Some(b) = &self.backend {
            cfg.backend = crate::config::BackendKind::parse(b)?;
        }
        Ok(())
    }
}

/// A printable results table (paper-style rows).
#[derive(Debug, Default)]
pub struct Table {
    /// Column headers.
    pub headers: Vec<String>,
    /// Row cells.
    pub rows: Vec<Vec<String>>,
}

impl Table {
    /// New table with headers.
    pub fn new(headers: &[&str]) -> Self {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    /// Append a row.
    pub fn row(&mut self, cells: Vec<String>) {
        self.rows.push(cells);
    }

    /// Render with aligned columns.
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                if i < widths.len() {
                    widths[i] = widths[i].max(c.len());
                } else {
                    widths.push(c.len());
                }
            }
        }
        let line = |cells: &[String]| {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:<w$}", c, w = widths.get(i).copied().unwrap_or(8) + 2))
                .collect::<String>()
        };
        let mut out = line(&self.headers);
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().map(|w| w + 2).sum::<usize>().min(120)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&line(row));
            out.push('\n');
        }
        out
    }

    /// Write as CSV into `dir/name.csv`.
    pub fn write_csv(&self, dir: &Path, name: &str) -> Result<PathBuf> {
        std::fs::create_dir_all(dir)?;
        let path = dir.join(format!("{name}.csv"));
        let mut f = std::fs::File::create(&path)?;
        writeln!(f, "{}", self.headers.join(","))?;
        for row in &self.rows {
            writeln!(f, "{}", row.join(","))?;
        }
        Ok(path)
    }
}

/// `mean ± std` cell formatting matching the paper's tables.
pub fn pm(mean: f64, std: f64) -> String {
    format!("{:.2} ± {:.2}", mean, std)
}

/// Percent formatting.
pub fn pct(v: f64) -> String {
    format!("{:.2}%", 100.0 * v)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["model", "AGP", "DSGD-AAU"]);
        t.row(vec!["2-NN".into(), "43.87".into(), "45.43".into()]);
        let s = t.render();
        assert!(s.contains("model"));
        assert!(s.lines().count() == 3);
    }

    #[test]
    fn csv_written() {
        let mut t = Table::new(&["a", "b"]);
        t.row(vec!["1".into(), "2".into()]);
        let dir = std::env::temp_dir().join("dsgd_harness_test");
        let p = t.write_csv(&dir, "t").unwrap();
        assert!(std::fs::read_to_string(p).unwrap().contains("a,b"));
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn pm_and_pct() {
        assert_eq!(pm(45.432, 0.158), "45.43 ± 0.16");
        assert_eq!(pct(0.4543), "45.43%");
    }
}
