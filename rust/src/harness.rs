//! Legacy shim: the experiment harness moved into the declarative sweep
//! layer — flag parsing lives in [`crate::sweep::cli`] and table/CSV
//! rendering in [`crate::sweep::table`].  These re-exports keep old
//! imports compiling for one release; new code should declare a
//! [`crate::sweep::SweepSpec`] and let the executor drive the sweep.

pub use crate::sweep::cli::BenchArgs;
pub use crate::sweep::table::{pct, pm, Table};
