//! Pathsearch — the decentralized strongly-connected-graph accumulation
//! procedure that realizes DSGD-AAU's adaptive neighbor selection
//! (paper Alg. 3 + Appendix B).
//!
//! Each epoch, workers collectively accumulate a set of visited edges `P`
//! and vertices `V`.  A gossip iteration ends when a *new* edge `(i, j)`
//! is established between two finished workers with `(i,j) ∈ E`,
//! `(i,j) ∉ P`, and `i ∉ V or j ∉ V`.  When `G' = (V, P)` spans all of
//! `N` and is connected, the epoch ends and `P, V` reset — every worker's
//! information has diffused to every other worker at least once.
//!
//! The paper implements consensus on `P, V` by ID broadcast; its overhead
//! is O(2NB) integer IDs per worker (Remark 4) and is negligible next to
//! parameter exchange, so the simulator tracks the consensus sets
//! centrally while *charging* the broadcast bytes to the communication
//! model.

use crate::topology::{norm_edge, Graph};
use crate::WorkerId;
use std::collections::BTreeSet;

/// Shared (consensus) Pathsearch state `P`, `V` plus epoch accounting.
#[derive(Debug, Clone, Default)]
pub struct PathSearch {
    /// Visited edges `P` (normalized).
    edges: BTreeSet<(usize, usize)>,
    /// Visited vertices `V`.
    vertices: BTreeSet<WorkerId>,
    /// Completed epochs (strongly-connected graphs established).
    pub epochs_completed: u64,
    /// Edges added over the lifetime (across epochs).
    pub total_edges_added: u64,
}

impl PathSearch {
    /// Fresh state with empty `P`, `V`.
    pub fn new() -> Self {
        Self::default()
    }

    /// Current visited-edge set size |P|.
    pub fn num_edges(&self) -> usize {
        self.edges.len()
    }

    /// Current visited-vertex set size |V|.
    pub fn num_vertices(&self) -> usize {
        self.vertices.len()
    }

    /// Whether `(i, j)` would be a *new* edge per Alg. 3 line 6:
    /// `(i,j) ∈ E ∧ (i,j) ∉ P ∧ (i ∉ V ∨ j ∉ V)`.
    pub fn is_novel_edge(&self, g: &Graph, i: WorkerId, j: WorkerId) -> bool {
        // edge-existence first: on sparse graphs one set probe rejects the
        // vast majority of pairs (measured faster than vertex-first;
        // EXPERIMENTS.md §Perf)
        g.has_edge(i, j)
            && !self.edges.contains(&norm_edge(i, j))
            && (!self.vertices.contains(&i) || !self.vertices.contains(&j))
    }

    /// A weaker novelty used once both endpoints are already in `V`:
    /// the edge itself is unvisited.  DSGD-AAU's epoch can only complete
    /// if the accumulated subgraph connects V = N, which may require
    /// edges between already-visited vertices; Appendix B admits these
    /// ("the current iteration continues until one such edge is
    /// established") via the connectivity test below.
    pub fn is_unvisited_edge(&self, g: &Graph, i: WorkerId, j: WorkerId) -> bool {
        g.has_edge(i, j) && !self.edges.contains(&norm_edge(i, j))
    }

    /// Find a pair of distinct workers in `ready` forming a novel edge.
    /// Prefers strictly-novel edges (new vertex) and falls back to
    /// unvisited edges when `V` already spans every ready worker but `G'`
    /// is not yet connected.
    pub fn find_novel_pair(&self, g: &Graph, ready: &[WorkerId]) -> Option<(WorkerId, WorkerId)> {
        for (ai, &a) in ready.iter().enumerate() {
            for &b in &ready[ai + 1..] {
                if self.is_novel_edge(g, a, b) {
                    return Some((a, b));
                }
            }
        }
        // fallback: vertices known, but more edges needed for connectivity
        if self.vertices.len() == g.num_vertices() && !self.is_complete(g) {
            for (ai, &a) in ready.iter().enumerate() {
                for &b in &ready[ai + 1..] {
                    if self.is_unvisited_edge(g, a, b) {
                        return Some((a, b));
                    }
                }
            }
        }
        None
    }

    /// Record every `E`-edge among `group` into `P` and all members into
    /// `V` (paper Fig. 2: the k=3 exchange adds (1,2) *and* (2,4)).
    /// Returns the number of newly visited edges.
    pub fn absorb_group(&mut self, g: &Graph, group: &[WorkerId]) -> usize {
        let mut added = 0;
        for (ai, &a) in group.iter().enumerate() {
            for &b in &group[ai + 1..] {
                if g.has_edge(a, b) && self.edges.insert(norm_edge(a, b)) {
                    added += 1;
                }
            }
            self.vertices.insert(a);
        }
        self.total_edges_added += added as u64;
        added
    }

    /// Epoch-completion test: `V = N` and `G' = (V, P)` connected.
    pub fn is_complete(&self, g: &Graph) -> bool {
        self.vertices.len() == g.num_vertices()
            && Graph::subgraph_connected(g.num_vertices(), &self.vertices, &self.edges)
    }

    /// Reset `P, V` for the next epoch (Alg. 2 line 10); call after
    /// `is_complete` returns true.
    pub fn reset_epoch(&mut self) {
        self.edges.clear();
        self.vertices.clear();
        self.epochs_completed += 1;
    }

    /// Component-scoped epoch-completion test: every worker in `members`
    /// is in `V` and the visited edges *among* `members` connect them.
    /// With `members` = all of `N` this coincides with [`Self::is_complete`].
    pub fn is_complete_within(&self, g: &Graph, members: &[WorkerId]) -> bool {
        if members.is_empty() {
            return false;
        }
        if !members.iter().all(|m| self.vertices.contains(m)) {
            return false;
        }
        let vset: BTreeSet<usize> = members.iter().copied().collect();
        // Edges with an endpoint outside the component cannot help it
        // span (and may exist transiently while observed views lag).
        let edges: BTreeSet<(usize, usize)> = self
            .edges
            .iter()
            .copied()
            .filter(|&(i, j)| vset.contains(&i) && vset.contains(&j))
            .collect();
        Graph::subgraph_connected(g.num_vertices(), &vset, &edges)
    }

    /// Component-scoped variant of [`Self::find_novel_pair`]: the epoch
    /// target is `universe` (the worker's live component) instead of the
    /// whole vertex set, so the unvisited-edge fallback unlocks as soon
    /// as `V` covers the component.
    pub fn find_novel_pair_within(
        &self,
        g: &Graph,
        ready: &[WorkerId],
        universe: &[WorkerId],
    ) -> Option<(WorkerId, WorkerId)> {
        for (ai, &a) in ready.iter().enumerate() {
            for &b in &ready[ai + 1..] {
                if self.is_novel_edge(g, a, b) {
                    return Some((a, b));
                }
            }
        }
        if universe.iter().all(|v| self.vertices.contains(v))
            && !self.is_complete_within(g, universe)
        {
            for (ai, &a) in ready.iter().enumerate() {
                for &b in &ready[ai + 1..] {
                    if self.is_unvisited_edge(g, a, b) {
                        return Some((a, b));
                    }
                }
            }
        }
        None
    }

    /// Retire a completed *component* epoch: remove `members` from `V`
    /// and every visited edge touching them, leaving other components'
    /// accumulation untouched.  The caller counts component epochs.
    pub fn reset_component(&mut self, members: &[WorkerId]) {
        let vset: BTreeSet<usize> = members.iter().copied().collect();
        self.edges.retain(|&(i, j)| !vset.contains(&i) && !vset.contains(&j));
        for m in members {
            self.vertices.remove(m);
        }
    }

    /// Iterator over the visited edges `P` (invariant tests).
    pub fn edges(&self) -> impl Iterator<Item = (usize, usize)> + '_ {
        self.edges.iter().copied()
    }

    /// Whether worker `w` is in the visited-vertex set `V`.
    pub fn contains_vertex(&self, w: WorkerId) -> bool {
        self.vertices.contains(&w)
    }

    /// Dynamic-topology hook: drop visited edges that no longer exist in
    /// `g`, restoring the invariant `P ⊆ E` after a churn mutation.
    /// Visited vertices stay — their information already diffused — so an
    /// epoch completes once the *surviving* accumulated subgraph spans and
    /// connects `N` again.  Returns the number of pruned edges.
    pub fn prune_missing(&mut self, g: &Graph) -> usize {
        let before = self.edges.len();
        self.edges.retain(|&(i, j)| g.has_edge(i, j));
        before - self.edges.len()
    }

    /// ID-broadcast cost of an update per Remark 4: each newly established
    /// edge floods two IDs through the network, bounded by `O(2N)` per
    /// worker; we charge `2 * N * 8` bytes per new edge.
    pub fn broadcast_bytes(num_workers: usize, new_edges: usize) -> u64 {
        (2 * num_workers * 8 * new_edges) as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::generators::{complete, random_connected, ring};

    #[test]
    fn novel_edge_rules() {
        let g = ring(4);
        let mut ps = PathSearch::new();
        assert!(ps.is_novel_edge(&g, 0, 1));
        assert!(!ps.is_novel_edge(&g, 0, 2)); // not an E edge
        ps.absorb_group(&g, &[0, 1]);
        assert!(!ps.is_novel_edge(&g, 0, 1)); // already in P
        assert!(ps.is_novel_edge(&g, 1, 2)); // 2 not in V
    }

    #[test]
    fn fig2_walkthrough() {
        // Paper Fig. 2: complete graph over 4 workers.
        let g = complete(4);
        let mut ps = PathSearch::new();
        // k=1: workers {4,1} (ids 3,0) exchange
        assert!(ps.find_novel_pair(&g, &[3, 0]).is_some());
        ps.absorb_group(&g, &[3, 0]);
        assert_eq!(ps.num_vertices(), 2);
        // k=2: workers {2,3} (ids 1,2)
        ps.absorb_group(&g, &[1, 2]);
        assert!(!ps.is_complete(&g)); // two components
        // k=3: workers {1,2,4} (ids 0,1,3) exchange; edges (0,1),(1,3),(0,3)
        ps.absorb_group(&g, &[0, 1, 3]);
        assert!(ps.is_complete(&g));
        ps.reset_epoch();
        assert_eq!(ps.epochs_completed, 1);
        assert_eq!(ps.num_edges(), 0);
    }

    #[test]
    fn ready_pair_respects_vertex_novelty() {
        let g = complete(3);
        let mut ps = PathSearch::new();
        ps.absorb_group(&g, &[0, 1]);
        // both 0,1 in V and (0,1) in P: no novel pair among {0,1}
        assert_eq!(ps.find_novel_pair(&g, &[0, 1]), None);
        // but {0,2} is novel
        assert_eq!(ps.find_novel_pair(&g, &[0, 2]), Some((0, 2)));
    }

    #[test]
    fn fallback_unvisited_edges_complete_epoch() {
        // Ring of 4: after visiting a spanning path 0-1, 1-2, 2-3 the graph
        // G'=(V,P) is already connected, so the epoch completes without the
        // fallback.  Star-of-paths case: path 0-1,2-3 then (1,2) closes it.
        let g = ring(4);
        let mut ps = PathSearch::new();
        ps.absorb_group(&g, &[0, 1]);
        ps.absorb_group(&g, &[2, 3]);
        assert!(ps.num_vertices() == 4 && !ps.is_complete(&g));
        let pair = ps.find_novel_pair(&g, &[1, 2]).expect("fallback must fire");
        ps.absorb_group(&g, &[pair.0, pair.1]);
        assert!(ps.is_complete(&g));
    }

    #[test]
    fn epoch_terminates_within_edge_budget_random_graphs() {
        // property: repeatedly absorbing novel pairs among random ready
        // sets completes an epoch in at most |E| absorptions.
        use crate::util::Rng64;
        for seed in 0..10u64 {
            let g = random_connected(16, 0.2, seed);
            let mut ps = PathSearch::new();
            let mut rng = Rng64::seed_from_u64(seed);
            let mut absorbs = 0usize;
            while !ps.is_complete(&g) {
                let mut ready: Vec<usize> = (0..16).collect();
                rng.shuffle(&mut ready);
                let ready = &ready[..8];
                if let Some((a, b)) = ps.find_novel_pair(&g, ready) {
                    ps.absorb_group(&g, &[a, b]);
                    absorbs += 1;
                }
                assert!(absorbs <= g.num_edges() + 16, "seed {seed}: runaway epoch");
            }
            ps.reset_epoch();
            assert_eq!(ps.epochs_completed, 1);
        }
    }

    #[test]
    fn prune_missing_restores_subset_invariant() {
        let mut g = complete(4);
        let mut ps = PathSearch::new();
        ps.absorb_group(&g, &[0, 1, 2]); // edges (0,1),(0,2),(1,2)
        assert_eq!(ps.num_edges(), 3);
        g.remove_edge(0, 1);
        g.remove_edge(1, 2);
        assert_eq!(ps.prune_missing(&g), 2);
        assert_eq!(ps.num_edges(), 1);
        assert_eq!(ps.num_vertices(), 3, "visited vertices survive pruning");
        // the pruned edge is novel again
        assert!(ps.is_unvisited_edge(&g, 0, 2) == false);
        g.add_edge(0, 1);
        assert!(ps.is_unvisited_edge(&g, 0, 1));
    }

    #[test]
    fn epoch_completes_after_pruning() {
        let g_full = complete(4);
        let mut ps = PathSearch::new();
        ps.absorb_group(&g_full, &[0, 1, 2, 3]);
        assert!(ps.is_complete(&g_full));
        // drop an edge the accumulated subgraph relied on; epoch resumes
        let mut g = g_full.clone();
        g.remove_vertex(3);
        g.add_edge(2, 3); // lifeline
        ps.prune_missing(&g);
        assert!(ps.is_complete(&g), "surviving subgraph still spans via (2,3)");
    }

    #[test]
    fn component_scoped_epoch_completes_and_resets_locally() {
        // two components: path 0-1-2 and edge 3-4
        let g = Graph::from_edges(5, &[(0, 1), (1, 2), (3, 4)]);
        let mut ps = PathSearch::new();
        ps.absorb_group(&g, &[0, 1]);
        ps.absorb_group(&g, &[3, 4]);
        assert!(!ps.is_complete_within(&g, &[0, 1, 2]), "2 not visited yet");
        assert!(ps.is_complete_within(&g, &[3, 4]));
        // component {3,4} retires without touching {0,1,2}'s progress
        ps.reset_component(&[3, 4]);
        assert!(!ps.contains_vertex(3) && !ps.contains_vertex(4));
        assert!(ps.contains_vertex(0) && ps.contains_vertex(1));
        ps.absorb_group(&g, &[1, 2]);
        assert!(ps.is_complete_within(&g, &[0, 1, 2]));
        // the global epoch is NOT complete (3,4 were retired from V)
        assert!(!ps.is_complete(&g));
    }

    #[test]
    fn component_scoped_fallback_unlocks_on_component_coverage() {
        // component {0,1,2,3} is a 4-ring; component {4,5} an edge.
        let g = Graph::from_edges(6, &[(0, 1), (1, 2), (2, 3), (3, 0), (4, 5)]);
        let comp: Vec<usize> = vec![0, 1, 2, 3];
        let mut ps = PathSearch::new();
        ps.absorb_group(&g, &[0, 1]);
        ps.absorb_group(&g, &[2, 3]);
        // V covers the component but G'=(V_c,P) is split: the global
        // fallback would stay locked (V != N), the component one fires.
        assert_eq!(ps.find_novel_pair(&g, &[1, 2]), None);
        let pair = ps.find_novel_pair_within(&g, &[1, 2], &comp).expect("fallback");
        ps.absorb_group(&g, &[pair.0, pair.1]);
        assert!(ps.is_complete_within(&g, &comp));
    }

    #[test]
    fn reset_component_of_everything_clears_without_counting() {
        // the heal-restart path resets the merged members; resetting the
        // whole fleet must clear P, V without bumping epochs_completed
        let g = complete(3);
        let mut ps = PathSearch::new();
        ps.absorb_group(&g, &[0, 1, 2]);
        assert!(ps.is_complete(&g));
        ps.reset_component(&[0, 1, 2]);
        assert_eq!(ps.epochs_completed, 0);
        assert_eq!(ps.num_edges(), 0);
        assert_eq!(ps.num_vertices(), 0);
    }

    #[test]
    fn broadcast_bytes_scaling() {
        assert_eq!(PathSearch::broadcast_bytes(128, 1), 2 * 128 * 8);
        assert_eq!(PathSearch::broadcast_bytes(128, 3), 3 * 2 * 128 * 8);
    }
}
