//! Sharded gossip: fragment the flat [`crate::model::ParamVec`] into `k`
//! contiguous shards and transfer one scheduled shard per gossip round.
//!
//! The paper's DSGD-AAU adapts *who* each worker waits for, but every
//! exchange still moves the full parameter vector — round bytes and
//! staleness scale with model size.  Model-fragmentation gossip
//! (arxiv 2410.12918) transfers fragments with per-shard versioning
//! instead: each round the scheduler picks which contiguous range of the
//! vector the group exchanges, the engine applies the consensus weights
//! to that range only, and bytes are charged for the shard actually
//! moved.  A second bytes knob simulates `f16` wire encoding
//! (quantize/dequantize on transfer, accounted at 2 bytes/param).
//!
//! Everything here is deterministic: the `seeded_random` schedule draws
//! from a dedicated [`Rng64`] stream (`seed_for("fragments")`), the
//! `stalest_first` schedule breaks ties toward the lowest shard index,
//! and the per-worker per-shard version counters advance only through
//! [`FragmentState::next_plan`] / [`FragmentState::reset_worker`] calls
//! made by the engine in event order.
//!
//! The default configuration (`count = 1`, `f32` wire) is *passthrough*:
//! the engine routes gossip through the exact legacy full-vector path,
//! bit-identical to a build without this module.

use crate::util::json::Json;
use crate::util::rng::Rng64;
use crate::WorkerId;
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;

/// Which shard a gossip round transfers.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShardSchedule {
    /// Cycle through shards `0, 1, …, k-1, 0, …` (a global cursor, not
    /// per-group — interleaved groups still cover all shards).
    RoundRobin,
    /// Pick the shard with the lowest summed version over the group's
    /// members (ties break toward the lowest shard index).
    StalestFirst,
    /// Uniform draw from a dedicated seeded stream.
    SeededRandom,
}

impl ShardSchedule {
    /// Parse from the snake_case config token.
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "round_robin" => ShardSchedule::RoundRobin,
            "stalest_first" => ShardSchedule::StalestFirst,
            "seeded_random" => ShardSchedule::SeededRandom,
            other => bail!(
                "unknown fragments schedule {other:?} (round_robin|stalest_first|seeded_random)"
            ),
        })
    }

    /// Inverse of [`Self::parse`].
    pub fn token(&self) -> &'static str {
        match self {
            ShardSchedule::RoundRobin => "round_robin",
            ShardSchedule::StalestFirst => "stalest_first",
            ShardSchedule::SeededRandom => "seeded_random",
        }
    }
}

/// How shard payloads are encoded on the (simulated) wire.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WireEncoding {
    /// Full-precision transfer, 4 bytes/param.
    F32,
    /// Half-precision transfer, 2 bytes/param: values round-trip through
    /// IEEE 754 binary16 (round-to-nearest-even) on every exchange.
    F16,
}

impl WireEncoding {
    /// Parse from the config token.
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "f32" => WireEncoding::F32,
            "f16" => WireEncoding::F16,
            other => bail!("unknown fragments encoding {other:?} (f32|f16)"),
        })
    }

    /// Inverse of [`Self::parse`].
    pub fn token(&self) -> &'static str {
        match self {
            WireEncoding::F32 => "f32",
            WireEncoding::F16 => "f16",
        }
    }

    /// Accounted wire cost per parameter.
    pub fn bytes_per_param(&self) -> u64 {
        match self {
            WireEncoding::F32 => 4,
            WireEncoding::F16 => 2,
        }
    }
}

/// The strict-parsed `"fragments"` config section.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FragmentConfig {
    /// Number of contiguous shards the parameter vector splits into
    /// (`1` = legacy full-vector exchange, bit-identical to a config
    /// without this section).
    pub count: usize,
    /// Which shard each gossip round transfers.
    pub schedule: ShardSchedule,
    /// Simulated wire encoding of shard payloads.
    pub encoding: WireEncoding,
    /// Seed override for the `seeded_random` schedule (`None` derives
    /// from the experiment seed via `seed_for("fragments")`).
    pub seed: Option<u64>,
}

impl Default for FragmentConfig {
    fn default() -> Self {
        FragmentConfig {
            count: 1,
            schedule: ShardSchedule::RoundRobin,
            encoding: WireEncoding::F32,
            seed: None,
        }
    }
}

impl FragmentConfig {
    /// Whether this configuration is the legacy full-vector exchange.
    /// Passthrough configs route through the engine's original gossip
    /// path and must stay bit-identical to builds without fragmentation.
    pub fn is_passthrough(&self) -> bool {
        self.count <= 1 && self.encoding == WireEncoding::F32
    }

    /// Parse the section; unknown keys are rejected.
    pub fn from_json(j: &Json) -> Result<Self> {
        let obj = j.as_obj().context("fragments must be an object")?;
        let mut cfg = FragmentConfig::default();
        for (key, v) in obj {
            match key.as_str() {
                "count" => {
                    cfg.count =
                        v.as_usize().context("fragments count must be a non-negative integer")?
                }
                "schedule" => {
                    cfg.schedule = ShardSchedule::parse(
                        v.as_str().context("fragments schedule must be a string")?,
                    )?
                }
                "encoding" => {
                    cfg.encoding = WireEncoding::parse(
                        v.as_str().context("fragments encoding must be a string")?,
                    )?
                }
                "seed" => {
                    cfg.seed = if matches!(v, Json::Null) {
                        None
                    } else {
                        Some(v.as_u64().context("fragments seed must be a non-negative integer")?)
                    }
                }
                other => bail!("unknown fragments key {other:?}"),
            }
        }
        cfg.validate()?;
        Ok(cfg)
    }

    /// Inverse of [`Self::from_json`].
    pub fn to_json(&self) -> Json {
        let mut m: BTreeMap<String, Json> = BTreeMap::new();
        m.insert("count".into(), Json::from(self.count));
        m.insert("schedule".into(), Json::from(self.schedule.token()));
        m.insert("encoding".into(), Json::from(self.encoding.token()));
        if let Some(s) = self.seed {
            m.insert("seed".into(), Json::from(s as usize));
        }
        Json::Obj(m)
    }

    /// Parameter sanity checks (called from `ExperimentConfig::validate`).
    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(self.count >= 1, "fragments count must be >= 1");
        Ok(())
    }
}

/// The shard a gossip round moves: the parameter range, its accounted
/// wire size for one point-to-point transfer, and the staleness the
/// schedule retired by picking it.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ShardPlan {
    /// Scheduled shard index.
    pub shard: usize,
    /// Start of the parameter range (inclusive).
    pub lo: usize,
    /// End of the parameter range (exclusive).
    pub hi: usize,
    /// Bytes one point-to-point transfer of this shard costs.
    pub wire_bytes: u64,
    /// Summed rounds-since-last-refresh of this shard over the group's
    /// members at scheduling time.
    pub staleness: u64,
}

/// Runtime shard bookkeeping: shard bounds, per-worker per-shard version
/// counters, and the scheduler state.
#[derive(Debug, Clone)]
pub struct FragmentState {
    bounds: Vec<usize>,
    /// `last_round[w][s]`: the gossip round in which worker `w` last
    /// exchanged shard `s` (0 = never; joiners reset to the current round).
    last_round: Vec<Vec<u64>>,
    rounds: u64,
    rr_cursor: usize,
    rng: Rng64,
    schedule: ShardSchedule,
    encoding: WireEncoding,
    passthrough: bool,
}

impl FragmentState {
    /// Build the runtime state for a `dim`-parameter model over `n`
    /// worker slots.  `seed` feeds the `seeded_random` stream unless the
    /// config overrides it; the shard count clamps to `dim` so every
    /// shard is non-empty.
    pub fn new(cfg: &FragmentConfig, dim: usize, n: usize, seed: u64) -> Self {
        let count = cfg.count.max(1).min(dim.max(1));
        // Contiguous, non-overlapping ranges covering [0, dim): the first
        // `dim % count` shards take the extra element.
        let mut bounds = Vec::with_capacity(count + 1);
        let (base, extra) = (dim / count, dim % count);
        let mut at = 0usize;
        bounds.push(at);
        for s in 0..count {
            at += base + usize::from(s < extra);
            bounds.push(at);
        }
        FragmentState {
            bounds,
            last_round: vec![vec![0u64; count]; n],
            rounds: 0,
            rr_cursor: 0,
            rng: Rng64::seed_from_u64(cfg.seed.unwrap_or(seed)),
            schedule: cfg.schedule,
            encoding: cfg.encoding,
            passthrough: cfg.is_passthrough(),
        }
    }

    /// Number of shards (clamped to the parameter dimension).
    pub fn shard_count(&self) -> usize {
        self.bounds.len() - 1
    }

    /// Whether the configured exchange is the legacy full-vector path.
    pub fn is_passthrough(&self) -> bool {
        self.passthrough
    }

    /// Whether shard payloads round-trip through binary16 on transfer.
    pub fn quantize_wire(&self) -> bool {
        self.encoding == WireEncoding::F16
    }

    /// The parameter range of shard `s`.
    pub fn shard_bounds(&self, s: usize) -> (usize, usize) {
        (self.bounds[s], self.bounds[s + 1])
    }

    /// Schedule the shard the next gossip round transfers among
    /// `members`, advancing the round counter and the members' version
    /// counters for the chosen shard.
    pub fn next_plan(&mut self, members: &[WorkerId]) -> ShardPlan {
        self.rounds += 1;
        let k = self.shard_count();
        let shard = match self.schedule {
            ShardSchedule::RoundRobin => {
                let s = self.rr_cursor % k;
                self.rr_cursor = (self.rr_cursor + 1) % k;
                s
            }
            ShardSchedule::StalestFirst => {
                // Lowest summed last-exchange round = stalest; ties break
                // toward the lowest shard index (the `<` comparison).
                let mut best = 0usize;
                let mut best_sum = u64::MAX;
                for s in 0..k {
                    let sum: u64 =
                        members.iter().map(|&m| self.last_round[m][s]).sum();
                    if sum < best_sum {
                        best_sum = sum;
                        best = s;
                    }
                }
                best
            }
            ShardSchedule::SeededRandom => self.rng.gen_range(k),
        };
        let (lo, hi) = self.shard_bounds(shard);
        let mut staleness = 0u64;
        for &m in members {
            staleness += (self.rounds - 1).saturating_sub(self.last_round[m][shard]);
            self.last_round[m][shard] = self.rounds;
        }
        ShardPlan {
            shard,
            lo,
            hi,
            wire_bytes: (hi - lo) as u64 * self.encoding.bytes_per_param(),
            staleness,
        }
    }

    /// A joiner warm-started with a fresh full vector is current on every
    /// shard: reset its counters to the present round so `stalest_first`
    /// does not chase phantom staleness.
    pub fn reset_worker(&mut self, w: WorkerId) {
        for v in &mut self.last_round[w] {
            *v = self.rounds;
        }
    }
}

/// Convert an `f32` to IEEE 754 binary16 bits with round-to-nearest-even
/// (ties to even), the hardware rounding mode; overflow saturates to
/// infinity, NaN payloads keep a quiet bit.
pub fn f32_to_f16_bits(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xff) as i32;
    let man = bits & 0x007f_ffff;
    if exp == 0xff {
        // Infinity or NaN; force a quiet NaN so a payload living entirely
        // in the dropped bits cannot collapse to infinity.
        let nan = if man != 0 { 0x0200 } else { 0 };
        return sign | 0x7c00 | nan | (man >> 13) as u16;
    }
    let unbiased = exp - 127;
    if unbiased > 15 {
        return sign | 0x7c00; // overflow -> ±inf
    }
    if unbiased >= -14 {
        // Normal half: drop 13 mantissa bits with RNE.
        let mut half_exp = (unbiased + 15) as u32;
        let mut half_man = man >> 13;
        let rem = man & 0x1fff;
        if rem > 0x1000 || (rem == 0x1000 && half_man & 1 == 1) {
            half_man += 1;
            if half_man == 0x400 {
                half_man = 0;
                half_exp += 1;
                if half_exp == 31 {
                    return sign | 0x7c00;
                }
            }
        }
        return sign | ((half_exp as u16) << 10) | half_man as u16;
    }
    if unbiased < -25 {
        return sign; // underflow to ±0
    }
    // Subnormal half: shift the (explicit-leading-one) mantissa down.
    let man = man | 0x0080_0000;
    let shift = (-1 - unbiased) as u32; // 14..=24 dropped bits
    let mut half_man = man >> shift;
    let rem = man & ((1u32 << shift) - 1);
    let halfway = 1u32 << (shift - 1);
    if rem > halfway || (rem == halfway && half_man & 1 == 1) {
        half_man += 1; // may carry into the exponent: smallest normal, still correct
    }
    sign | half_man as u16
}

/// Convert IEEE 754 binary16 bits back to `f32` (exact).
pub fn f16_bits_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1f) as u32;
    let man = (h & 0x3ff) as u32;
    let bits = if exp == 0x1f {
        sign | 0x7f80_0000 | (man << 13)
    } else if exp == 0 {
        if man == 0 {
            sign
        } else {
            // Subnormal half: renormalize into the f32 exponent range.
            let mut exp32 = 113u32; // 127 - 14
            let mut man = man;
            while man & 0x400 == 0 {
                man <<= 1;
                exp32 -= 1;
            }
            sign | (exp32 << 23) | ((man & 0x3ff) << 13)
        }
    } else {
        sign | ((exp + 112) << 23) | (man << 13)
    };
    f32::from_bits(bits)
}

/// Simulated wire round-trip of one value: what the receiver sees after
/// an `f16`-encoded transfer.
pub fn quantize_f16(x: f32) -> f32 {
    f16_bits_to_f32(f32_to_f16_bits(x))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_section_is_passthrough_and_roundtrips() {
        let cfg = FragmentConfig::default();
        assert!(cfg.is_passthrough());
        let back = FragmentConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(back, cfg);
    }

    #[test]
    fn section_parses_strictly() {
        let j = Json::parse(
            r#"{"count": 4, "schedule": "stalest_first", "encoding": "f16", "seed": 9}"#,
        )
        .unwrap();
        let cfg = FragmentConfig::from_json(&j).unwrap();
        assert_eq!(cfg.count, 4);
        assert_eq!(cfg.schedule, ShardSchedule::StalestFirst);
        assert_eq!(cfg.encoding, WireEncoding::F16);
        assert_eq!(cfg.seed, Some(9));
        assert!(!cfg.is_passthrough());
        let back = FragmentConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(back, cfg);
        // unknown keys, bad tokens and a zero count are rejected
        assert!(FragmentConfig::from_json(&Json::parse(r#"{"shards": 4}"#).unwrap()).is_err());
        assert!(FragmentConfig::from_json(
            &Json::parse(r#"{"schedule": "round-robin"}"#).unwrap()
        )
        .is_err());
        assert!(
            FragmentConfig::from_json(&Json::parse(r#"{"encoding": "bf16"}"#).unwrap()).is_err()
        );
        assert!(FragmentConfig::from_json(&Json::parse(r#"{"count": 0}"#).unwrap()).is_err());
    }

    #[test]
    fn count_one_with_f16_is_not_passthrough() {
        let cfg = FragmentConfig { encoding: WireEncoding::F16, ..FragmentConfig::default() };
        assert!(!cfg.is_passthrough(), "f16 wire must take the fragmented path");
    }

    #[test]
    fn bounds_partition_the_dimension() {
        let cfg = FragmentConfig { count: 4, ..FragmentConfig::default() };
        let st = FragmentState::new(&cfg, 10, 3, 7);
        assert_eq!(st.shard_count(), 4);
        let ranges: Vec<(usize, usize)> = (0..4).map(|s| st.shard_bounds(s)).collect();
        assert_eq!(ranges, [(0, 3), (3, 6), (6, 8), (8, 10)]);
        // shard count clamps to the dimension
        let tiny = FragmentState::new(&FragmentConfig { count: 64, ..cfg }, 5, 3, 7);
        assert_eq!(tiny.shard_count(), 5);
    }

    #[test]
    fn round_robin_cycles_and_random_is_seeded() {
        let cfg = FragmentConfig { count: 3, ..FragmentConfig::default() };
        let mut st = FragmentState::new(&cfg, 9, 2, 1);
        let picks: Vec<usize> = (0..6).map(|_| st.next_plan(&[0, 1]).shard).collect();
        assert_eq!(picks, [0, 1, 2, 0, 1, 2]);

        let rnd = FragmentConfig { schedule: ShardSchedule::SeededRandom, ..cfg };
        let mut a = FragmentState::new(&rnd, 9, 2, 5);
        let mut b = FragmentState::new(&rnd, 9, 2, 5);
        for _ in 0..20 {
            assert_eq!(a.next_plan(&[0]).shard, b.next_plan(&[0]).shard);
        }
        // the config seed overrides the derived one
        let pinned = FragmentConfig { seed: Some(5), ..rnd };
        let mut c = FragmentState::new(&pinned, 9, 2, 999);
        let mut d = FragmentState::new(&rnd, 9, 2, 5);
        for _ in 0..20 {
            assert_eq!(c.next_plan(&[0]).shard, d.next_plan(&[0]).shard);
        }
    }

    #[test]
    fn stalest_first_chases_the_oldest_shard() {
        let cfg = FragmentConfig {
            count: 3,
            schedule: ShardSchedule::StalestFirst,
            ..FragmentConfig::default()
        };
        let mut st = FragmentState::new(&cfg, 9, 2, 1);
        // all counters equal: ties break toward shard 0, then 1, then 2
        assert_eq!(st.next_plan(&[0, 1]).shard, 0);
        assert_eq!(st.next_plan(&[0, 1]).shard, 1);
        assert_eq!(st.next_plan(&[0, 1]).shard, 2);
        // worker 1 alone refreshes its stalest shard (0); over {0, 1}
        // shard 1 now has the lowest summed version
        // (s0 = 1+4, s1 = 2+2, s2 = 3+3)
        assert_eq!(st.next_plan(&[1]).shard, 0);
        assert_eq!(st.next_plan(&[0, 1]).shard, 1);
    }

    #[test]
    fn staleness_accumulates_and_reset_clears_it() {
        let cfg = FragmentConfig {
            count: 2,
            schedule: ShardSchedule::RoundRobin,
            ..FragmentConfig::default()
        };
        let mut st = FragmentState::new(&cfg, 8, 2, 1);
        assert_eq!(st.next_plan(&[0, 1]).staleness, 0, "round 1: nothing is stale yet");
        assert_eq!(st.next_plan(&[0, 1]).staleness, 2, "shard 1 missed round 1 on both");
        // worker 1 sits out rounds 3-4, then rejoins on shard 0 in round 5:
        // worker 0 refreshed it in round 3 (staleness 1), worker 1 in round 1
        // (staleness 3)
        assert_eq!(st.next_plan(&[0]).shard, 0);
        assert_eq!(st.next_plan(&[0]).shard, 1);
        let plan = st.next_plan(&[0, 1]);
        assert_eq!((plan.shard, plan.staleness), (0, 1 + 3));
        // a reset marks the worker current on every shard
        st.reset_worker(1);
        assert_eq!(st.next_plan(&[1]).staleness, 0);
    }

    #[test]
    fn wire_bytes_follow_the_encoding() {
        let f32cfg = FragmentConfig { count: 2, ..FragmentConfig::default() };
        let mut st = FragmentState::new(&f32cfg, 10, 1, 1);
        assert_eq!(st.next_plan(&[0]).wire_bytes, 5 * 4);
        let f16cfg = FragmentConfig { encoding: WireEncoding::F16, ..f32cfg };
        let mut st = FragmentState::new(&f16cfg, 10, 1, 1);
        assert!(st.quantize_wire());
        assert_eq!(st.next_plan(&[0]).wire_bytes, 5 * 2);
    }

    #[test]
    fn f16_roundtrip_is_exact_for_representable_values() {
        for x in [0.0f32, -0.0, 1.0, -1.0, 0.5, 2.0, 65504.0, -65504.0, 6.1035156e-5] {
            assert_eq!(quantize_f16(x), x, "{x} must survive the round-trip");
        }
        // subnormal halves round-trip too
        let tiny = f16_bits_to_f32(0x0001);
        assert_eq!(quantize_f16(tiny), tiny);
    }

    #[test]
    fn f16_rounds_to_nearest_even_and_saturates() {
        // 1 + 2^-11 sits exactly between 1.0 and the next half (1 + 2^-10):
        // ties go to the even mantissa, i.e. 1.0
        assert_eq!(quantize_f16(1.0 + f32::powi(2.0, -11)), 1.0);
        // 1 + 3·2^-12 is past the midpoint and rounds up
        assert_eq!(
            quantize_f16(1.0 + 3.0 * f32::powi(2.0, -12)),
            1.0 + f32::powi(2.0, -10)
        );
        // beyond the f16 range saturates to infinity; NaN stays NaN
        assert_eq!(quantize_f16(1e6), f32::INFINITY);
        assert_eq!(quantize_f16(-1e6), f32::NEG_INFINITY);
        assert!(quantize_f16(f32::NAN).is_nan());
        // below the smallest subnormal underflows to signed zero
        assert_eq!(quantize_f16(1e-10), 0.0);
        assert_eq!(quantize_f16(-1e-10).to_bits(), (-0.0f32).to_bits());
    }

    #[test]
    fn f16_is_idempotent() {
        let mut rng = Rng64::seed_from_u64(3);
        for _ in 0..1000 {
            let x = rng.normal_f32() * 10.0;
            let once = quantize_f16(x);
            assert_eq!(quantize_f16(once), once, "quantization must be idempotent at {x}");
        }
    }
}
