//! IID and non-IID dataset partitioners (paper Appendix D).
//!
//! Non-IID follows McMahan et al. [48] as used by the paper: sort by
//! label, split each class into `shards_per_class = N * classes_per_worker
//! / num_classes` shards, and deal each worker `classes_per_worker` shards
//! from distinct random classes (paper: 5 classes per worker with
//! `N/2 = 64` shards per class at N = 128).

use crate::util::Rng64;

/// Per-worker index assignment.
#[derive(Debug, Clone)]
pub struct Partition {
    /// `assignment[w]` = global sample indices owned by worker w.
    pub assignment: Vec<Vec<usize>>,
}

impl Partition {
    /// Number of workers.
    pub fn num_workers(&self) -> usize {
        self.assignment.len()
    }

    /// Total assigned samples.
    pub fn total(&self) -> usize {
        self.assignment.iter().map(Vec::len).sum()
    }

    /// Label-distribution skew measure: mean number of distinct labels per
    /// worker (low = very non-IID).
    pub fn mean_distinct_labels(&self, labels: &[i32]) -> f64 {
        let mut sum = 0usize;
        for shard in &self.assignment {
            let distinct: std::collections::HashSet<i32> =
                shard.iter().map(|&i| labels[i]).collect();
            sum += distinct.len();
        }
        sum as f64 / self.assignment.len().max(1) as f64
    }
}

/// Uniform random split of all indices among `n_workers`.
pub fn partition_iid(n_samples: usize, n_workers: usize, seed: u64) -> Partition {
    let mut rng = Rng64::seed_from_u64(seed);
    let mut idx: Vec<usize> = (0..n_samples).collect();
    rng.shuffle(&mut idx);
    let mut assignment = vec![Vec::new(); n_workers];
    for (pos, i) in idx.into_iter().enumerate() {
        assignment[pos % n_workers].push(i);
    }
    Partition { assignment }
}

/// McMahan-style label-shard non-IID split.
///
/// Each worker receives `classes_per_worker` shards, each shard drawn from
/// a single class; classes are chosen per-worker without replacement.
pub fn partition_noniid_shards(
    labels: &[i32],
    n_workers: usize,
    num_classes: usize,
    classes_per_worker: usize,
    seed: u64,
) -> Partition {
    let classes_per_worker = classes_per_worker.min(num_classes).max(1);
    let mut rng = Rng64::seed_from_u64(seed);

    // bucket indices per class, shuffled
    let mut per_class: Vec<Vec<usize>> = vec![Vec::new(); num_classes];
    for (i, &l) in labels.iter().enumerate() {
        per_class[l as usize].push(i);
    }
    for bucket in per_class.iter_mut() {
        rng.shuffle(bucket);
    }

    // shards per class so that total shards = n_workers * classes_per_worker
    let shards_per_class =
        ((n_workers * classes_per_worker) as f64 / num_classes as f64).ceil() as usize;
    let mut shards: Vec<(usize, Vec<usize>)> = Vec::new(); // (class, indices)
    for (c, bucket) in per_class.iter().enumerate() {
        if bucket.is_empty() {
            continue;
        }
        let size = (bucket.len() / shards_per_class).max(1);
        // keep EVERY chunk (the tail remainder too) so no sample is dropped;
        // surplus shards beyond the nominal count are dealt as leftovers
        for chunk in bucket.chunks(size) {
            shards.push((c, chunk.to_vec()));
        }
    }
    rng.shuffle(&mut shards);

    // deal each worker classes_per_worker shards of distinct classes
    let mut assignment = vec![Vec::new(); n_workers];
    let mut taken = vec![false; shards.len()];
    for (w, a) in assignment.iter_mut().enumerate() {
        let mut have: std::collections::HashSet<usize> = std::collections::HashSet::new();
        for _ in 0..classes_per_worker {
            // first pass: prefer an untaken shard of a class we don't have
            let pick = shards
                .iter()
                .enumerate()
                .position(|(si, (c, _))| !taken[si] && !have.contains(c))
                .or_else(|| shards.iter().enumerate().position(|(si, _)| !taken[si]));
            if let Some(si) = pick {
                taken[si] = true;
                have.insert(shards[si].0);
                a.extend_from_slice(&shards[si].1);
            }
        }
        let _ = w;
    }
    // leftovers (rounding) go round-robin so no sample is dropped
    let mut w = 0;
    for (si, shard) in shards.iter().enumerate() {
        if !taken[si] {
            assignment[w % n_workers].extend_from_slice(&shard.1);
            w += 1;
        }
    }
    Partition { assignment }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn labels(n: usize, classes: usize, seed: u64) -> Vec<i32> {
        let mut rng = Rng64::seed_from_u64(seed);
        (0..n).map(|_| rng.gen_range(classes) as i32).collect()
    }

    #[test]
    fn iid_covers_everything_evenly() {
        let p = partition_iid(1000, 8, 1);
        assert_eq!(p.total(), 1000);
        for a in &p.assignment {
            assert!((a.len() as i64 - 125).abs() <= 1);
        }
        let mut all: Vec<usize> = p.assignment.iter().flatten().copied().collect();
        all.sort_unstable();
        assert_eq!(all, (0..1000).collect::<Vec<_>>());
    }

    #[test]
    fn noniid_covers_everything_no_duplicates() {
        let l = labels(2000, 10, 2);
        let p = partition_noniid_shards(&l, 16, 10, 5, 3);
        let mut all: Vec<usize> = p.assignment.iter().flatten().copied().collect();
        all.sort_unstable();
        all.dedup();
        assert_eq!(all.len(), 2000, "every sample assigned exactly once");
    }

    #[test]
    fn noniid_is_skewed_vs_iid() {
        let l = labels(4000, 10, 4);
        let noniid = partition_noniid_shards(&l, 32, 10, 3, 5);
        let iid = partition_iid(4000, 32, 5);
        let skew_non = noniid.mean_distinct_labels(&l);
        let skew_iid = iid.mean_distinct_labels(&l);
        assert!(
            skew_non < skew_iid - 2.0,
            "non-IID {skew_non} should see far fewer labels than IID {skew_iid}"
        );
        assert!(skew_non <= 5.0, "≤ classes_per_worker + leftovers, got {skew_non}");
    }

    #[test]
    fn noniid_every_worker_nonempty() {
        let l = labels(1000, 10, 6);
        let p = partition_noniid_shards(&l, 64, 10, 5, 7);
        assert!(p.assignment.iter().all(|a| !a.is_empty()));
    }

    #[test]
    fn classes_per_worker_clamped() {
        let l = labels(500, 4, 8);
        // asking for 10 classes with only 4 available must not panic
        let p = partition_noniid_shards(&l, 8, 4, 10, 9);
        assert_eq!(p.total(), 500);
    }
}
