//! Synthetic class-clustered classification data (the CIFAR/MNIST stand-in).
//!
//! Each class `c` gets a random unit centroid `µ_c`; samples are
//! `x = µ_c · sep + ε`, `ε ~ N(0, σ²I)`.  With `sep/σ` around 1–2 the task
//! is learnable but not trivial, mirroring the relative difficulty ordering
//! of the paper's datasets.

use crate::util::Rng64;

/// In-memory synthetic classification dataset.
#[derive(Debug, Clone)]
pub struct SyntheticClassification {
    /// Feature matrix, row-major `[n_samples * dim]`.
    features: Vec<f32>,
    /// Labels in `0..num_classes`.
    labels: Vec<i32>,
    /// Feature dimension.
    pub dim: usize,
    /// Number of classes.
    pub num_classes: usize,
}

impl SyntheticClassification {
    /// Generate `n_samples` over `num_classes` clusters in `dim` dims.
    ///
    /// `separation` scales centroid norms relative to unit noise.
    pub fn generate(
        n_samples: usize,
        dim: usize,
        num_classes: usize,
        separation: f32,
        seed: u64,
    ) -> Self {
        let mut rng = Rng64::seed_from_u64(seed);
        // random unit centroids
        let mut centroids = vec![0f32; num_classes * dim];
        for c in 0..num_classes {
            let mut norm = 0f32;
            for d in 0..dim {
                let v: f32 = rng.normal_f32();
                centroids[c * dim + d] = v;
                norm += v * v;
            }
            let norm = norm.sqrt().max(1e-6);
            for d in 0..dim {
                centroids[c * dim + d] *= separation / norm;
            }
        }
        let mut features = vec![0f32; n_samples * dim];
        let mut labels = vec![0i32; n_samples];
        for i in 0..n_samples {
            let c = rng.gen_range(num_classes);
            labels[i] = c as i32;
            for d in 0..dim {
                let noise: f32 = rng.normal_f32();
                features[i * dim + d] = centroids[c * dim + d] + noise;
            }
        }
        SyntheticClassification { features, labels, dim, num_classes }
    }

    /// Number of samples.
    pub fn len(&self) -> usize {
        self.labels.len()
    }

    /// Whether the dataset is empty.
    pub fn is_empty(&self) -> bool {
        self.labels.is_empty()
    }

    /// Feature row of sample `i`.
    pub fn feature(&self, i: usize) -> &[f32] {
        &self.features[i * self.dim..(i + 1) * self.dim]
    }

    /// Label of sample `i`.
    pub fn label(&self, i: usize) -> i32 {
        self.labels[i]
    }

    /// All labels (for the partitioner).
    pub fn labels(&self) -> &[i32] {
        &self.labels
    }

    /// Gather a batch `[batch * dim]` of features and labels.
    pub fn gather(&self, indices: &[usize]) -> (Vec<f32>, Vec<i32>) {
        let mut x = Vec::with_capacity(indices.len() * self.dim);
        let mut y = Vec::with_capacity(indices.len());
        for &i in indices {
            x.extend_from_slice(self.feature(i));
            y.push(self.labels[i]);
        }
        (x, y)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_label_range() {
        let ds = SyntheticClassification::generate(200, 16, 10, 2.0, 1);
        assert_eq!(ds.len(), 200);
        assert_eq!(ds.feature(0).len(), 16);
        assert!(ds.labels().iter().all(|&l| (0..10).contains(&l)));
    }

    #[test]
    fn deterministic_per_seed() {
        let a = SyntheticClassification::generate(50, 8, 4, 2.0, 9);
        let b = SyntheticClassification::generate(50, 8, 4, 2.0, 9);
        assert_eq!(a.labels(), b.labels());
        assert_eq!(a.feature(7), b.feature(7));
    }

    #[test]
    fn classes_are_separable() {
        // nearest-centroid classifier must beat chance by a wide margin
        let ds = SyntheticClassification::generate(500, 32, 5, 3.0, 3);
        // estimate centroids from data
        let mut centroids = vec![vec![0f32; 32]; 5];
        let mut counts = vec![0usize; 5];
        for i in 0..ds.len() {
            let c = ds.label(i) as usize;
            counts[c] += 1;
            for (d, v) in ds.feature(i).iter().enumerate() {
                centroids[c][d] += v;
            }
        }
        for c in 0..5 {
            for d in 0..32 {
                centroids[c][d] /= counts[c].max(1) as f32;
            }
        }
        let mut correct = 0;
        for i in 0..ds.len() {
            let f = ds.feature(i);
            let best = (0..5)
                .min_by(|&a, &b| {
                    let da: f32 = f.iter().zip(&centroids[a]).map(|(x, c)| (x - c).powi(2)).sum();
                    let db: f32 = f.iter().zip(&centroids[b]).map(|(x, c)| (x - c).powi(2)).sum();
                    da.partial_cmp(&db).unwrap()
                })
                .unwrap();
            if best as i32 == ds.label(i) {
                correct += 1;
            }
        }
        let acc = correct as f64 / ds.len() as f64;
        assert!(acc > 0.8, "nearest-centroid accuracy {acc}");
    }

    #[test]
    fn gather_matches_rows() {
        let ds = SyntheticClassification::generate(20, 4, 3, 2.0, 5);
        let (x, y) = ds.gather(&[3, 7]);
        assert_eq!(&x[0..4], ds.feature(3));
        assert_eq!(&x[4..8], ds.feature(7));
        assert_eq!(y, vec![ds.label(3), ds.label(7)]);
    }
}
