//! Character corpus for the next-character-prediction task (the paper's
//! Shakespeare/LSTM workload, DESIGN.md §3).
//!
//! A public-domain excerpt of *The Complete Works of William Shakespeare*
//! is embedded so the LM task runs with zero external downloads.  Bytes
//! are mapped to a 96-symbol vocabulary (printable ASCII; everything else
//! folds to space), matching the `transformer_char` model's vocab.

use crate::data::WorkerShard;

/// Public-domain Shakespeare excerpt (sonnets 1–8 + Hamlet soliloquy).
pub const SHAKESPEARE_EXCERPT: &str = r#"
From fairest creatures we desire increase,
That thereby beauty's rose might never die,
But as the riper should by time decease,
His tender heir might bear his memory:
But thou, contracted to thine own bright eyes,
Feed'st thy light's flame with self-substantial fuel,
Making a famine where abundance lies,
Thyself thy foe, to thy sweet self too cruel.
Thou that art now the world's fresh ornament
And only herald to the gaudy spring,
Within thine own bud buriest thy content
And, tender churl, mak'st waste in niggarding.
Pity the world, or else this glutton be,
To eat the world's due, by the grave and thee.

When forty winters shall besiege thy brow,
And dig deep trenches in thy beauty's field,
Thy youth's proud livery, so gazed on now,
Will be a tattered weed of small worth held:
Then being asked where all thy beauty lies,
Where all the treasure of thy lusty days;
To say within thine own deep-sunken eyes,
Were an all-eating shame, and thriftless praise.
How much more praise deserved thy beauty's use,
If thou couldst answer 'This fair child of mine
Shall sum my count, and make my old excuse,'
Proving his beauty by succession thine.
This were to be new made when thou art old,
And see thy blood warm when thou feel'st it cold.

Look in thy glass and tell the face thou viewest,
Now is the time that face should form another,
Whose fresh repair if now thou not renewest,
Thou dost beguile the world, unbless some mother.
For where is she so fair whose uneared womb
Disdains the tillage of thy husbandry?
Or who is he so fond will be the tomb
Of his self-love, to stop posterity?
Thou art thy mother's glass, and she in thee
Calls back the lovely April of her prime;
So thou through windows of thine age shalt see,
Despite of wrinkles, this thy golden time.
But if thou live remembered not to be,
Die single and thine image dies with thee.

Unthrifty loveliness, why dost thou spend
Upon thyself thy beauty's legacy?
Nature's bequest gives nothing, but doth lend,
And being frank she lends to those are free:
Then, beauteous niggard, why dost thou abuse
The bounteous largess given thee to give?
Profitless usurer, why dost thou use
So great a sum of sums, yet canst not live?
For having traffic with thyself alone,
Thou of thyself thy sweet self dost deceive:
Then how when nature calls thee to be gone,
What acceptable audit canst thou leave?
Thy unused beauty must be tombed with thee,
Which, used, lives th' executor to be.

To be, or not to be, that is the question:
Whether 'tis nobler in the mind to suffer
The slings and arrows of outrageous fortune,
Or to take arms against a sea of troubles
And by opposing end them. To die: to sleep;
No more; and by a sleep to say we end
The heart-ache and the thousand natural shocks
That flesh is heir to, 'tis a consummation
Devoutly to be wish'd. To die, to sleep;
To sleep: perchance to dream: ay, there's the rub;
For in that sleep of death what dreams may come
When we have shuffled off this mortal coil,
Must give us pause: there's the respect
That makes calamity of so long life;
For who would bear the whips and scorns of time,
The oppressor's wrong, the proud man's contumely,
The pangs of despised love, the law's delay,
The insolence of office and the spurns
That patient merit of the unworthy takes,
When he himself might his quietus make
With a bare bodkin? who would fardels bear,
To grunt and sweat under a weary life,
But that the dread of something after death,
The undiscover'd country from whose bourn
No traveller returns, puzzles the will
And makes us rather bear those ills we have
Than fly to others that we know not of?
Thus conscience does make cowards of us all;
And thus the native hue of resolution
Is sicklied o'er with the pale cast of thought,
And enterprises of great pith and moment
With this regard their currents turn awry,
And lose the name of action.
"#;

/// Vocabulary size: printable ASCII 32..=126 plus newline -> 96 symbols.
pub const CHAR_VOCAB: usize = 96;

/// Map a byte to a token id in `0..CHAR_VOCAB`.
#[inline]
pub fn byte_to_token(b: u8) -> i32 {
    match b {
        b'\n' => 95,
        32..=126 => (b - 32) as i32,
        _ => 0, // fold to space
    }
}

/// Tokenized character corpus with next-char batch extraction.
#[derive(Debug, Clone)]
pub struct CharCorpus {
    tokens: Vec<i32>,
    /// Sequence length per sample.
    pub seq_len: usize,
}

impl CharCorpus {
    /// Tokenize `text` (use [`SHAKESPEARE_EXCERPT`] for the default task).
    pub fn new(text: &str, seq_len: usize) -> Self {
        let tokens: Vec<i32> = text.bytes().map(byte_to_token).collect();
        assert!(
            tokens.len() > seq_len + 1,
            "corpus ({}) shorter than seq_len {}",
            tokens.len(),
            seq_len
        );
        CharCorpus { tokens, seq_len }
    }

    /// Number of distinct sample positions (windows).
    pub fn len(&self) -> usize {
        self.tokens.len() - self.seq_len - 1
    }

    /// Whether no window fits.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Input/target windows for sample position `i`:
    /// `x = tokens[i .. i+T]`, `y = tokens[i+1 .. i+T+1]`.
    pub fn window(&self, i: usize) -> (&[i32], &[i32]) {
        (
            &self.tokens[i..i + self.seq_len],
            &self.tokens[i + 1..i + 1 + self.seq_len],
        )
    }

    /// Gather a batch from window positions: `([B*T] x, [B*T] y)`.
    pub fn gather(&self, positions: &[usize]) -> (Vec<i32>, Vec<i32>) {
        let mut x = Vec::with_capacity(positions.len() * self.seq_len);
        let mut y = Vec::with_capacity(positions.len() * self.seq_len);
        for &p in positions {
            let (xi, yi) = self.window(p);
            x.extend_from_slice(xi);
            y.extend_from_slice(yi);
        }
        (x, y)
    }

    /// Contiguous-range shards: worker `w` of `n` owns an equal slice of
    /// window positions — naturally non-IID (different text regions).
    pub fn shards(&self, n_workers: usize, seed: u64) -> Vec<WorkerShard> {
        let total = self.len();
        let per = (total / n_workers).max(1);
        (0..n_workers)
            .map(|w| {
                let lo = (w * per).min(total - 1);
                let hi = ((w + 1) * per).min(total);
                WorkerShard::new((lo..hi.max(lo + 1)).collect(), seed ^ w as u64)
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tokens_in_vocab() {
        let c = CharCorpus::new(SHAKESPEARE_EXCERPT, 64);
        for i in 0..c.len().min(500) {
            let (x, y) = c.window(i);
            assert!(x.iter().all(|&t| (0..CHAR_VOCAB as i32).contains(&t)));
            assert!(y.iter().all(|&t| (0..CHAR_VOCAB as i32).contains(&t)));
        }
    }

    #[test]
    fn target_is_shifted_input() {
        let c = CharCorpus::new("hello world, hello again", 8);
        let (x, y) = c.window(3);
        assert_eq!(&x[1..], &y[..7]);
    }

    #[test]
    fn gather_shapes() {
        let c = CharCorpus::new(SHAKESPEARE_EXCERPT, 32);
        let (x, y) = c.gather(&[0, 10, 20]);
        assert_eq!(x.len(), 3 * 32);
        assert_eq!(y.len(), 3 * 32);
    }

    #[test]
    fn shards_cover_disjoint_regions() {
        let c = CharCorpus::new(SHAKESPEARE_EXCERPT, 16);
        let shards = c.shards(4, 0);
        assert_eq!(shards.len(), 4);
        assert!(shards.iter().all(|s| !s.is_empty()));
    }

    #[test]
    #[should_panic(expected = "corpus")]
    fn short_corpus_panics() {
        CharCorpus::new("ab", 64);
    }
}
