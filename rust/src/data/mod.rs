//! Dataset substrate: synthetic classification corpora with IID / non-IID
//! label-shard partitioning (paper §6 + Appendix D) and a bundled
//! public-domain character corpus for the next-character-prediction task.
//!
//! The paper partitions CIFAR-10 non-IID by sorting samples by label,
//! splitting each class into `N/2` shards, and giving each worker shards
//! from 5 random classes (McMahan-style).  We reproduce that partitioner
//! exactly over a synthetic class-clustered dataset of the same
//! dimensionality, which preserves the heterogeneity (ς² > 0) that drives
//! the paper's non-IID results.

mod corpus;
mod partition;
mod synthetic;

pub use corpus::{byte_to_token, CharCorpus, CHAR_VOCAB, SHAKESPEARE_EXCERPT};
pub use partition::{partition_iid, partition_noniid_shards, Partition};
pub use synthetic::SyntheticClassification;

use crate::util::Rng64;

/// One worker's view of a dataset: indices into the global store plus a
/// cycling batch cursor (workers sample without global coordination).
#[derive(Debug, Clone)]
pub struct WorkerShard {
    indices: Vec<usize>,
    cursor: usize,
    rng: Rng64,
}

impl WorkerShard {
    /// New shard over the given global indices.
    pub fn new(mut indices: Vec<usize>, seed: u64) -> Self {
        let mut rng = Rng64::seed_from_u64(seed);
        // initial shuffle so batches are not label-sorted within the shard
        for i in (1..indices.len()).rev() {
            let j = rng.gen_range(i + 1);
            indices.swap(i, j);
        }
        WorkerShard { indices, cursor: 0, rng }
    }

    /// Number of local samples.
    pub fn len(&self) -> usize {
        self.indices.len()
    }

    /// Whether the shard is empty.
    pub fn is_empty(&self) -> bool {
        self.indices.is_empty()
    }

    /// Next mini-batch of `batch` global indices (cycles + reshuffles).
    pub fn next_batch(&mut self, batch: usize) -> Vec<usize> {
        assert!(!self.indices.is_empty(), "empty shard");
        let mut out = Vec::with_capacity(batch);
        for _ in 0..batch {
            if self.cursor >= self.indices.len() {
                self.cursor = 0;
                for i in (1..self.indices.len()).rev() {
                    let j = self.rng.gen_range(i + 1);
                    self.indices.swap(i, j);
                }
            }
            out.push(self.indices[self.cursor]);
            self.cursor += 1;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batch_cycles_through_shard() {
        let mut s = WorkerShard::new((0..10).collect(), 1);
        let mut seen = std::collections::HashSet::new();
        for _ in 0..5 {
            for i in s.next_batch(2) {
                seen.insert(i);
            }
        }
        assert_eq!(seen.len(), 10); // one full epoch covers everything
    }

    #[test]
    fn batch_larger_than_shard_wraps() {
        let mut s = WorkerShard::new(vec![3, 4, 5], 2);
        let b = s.next_batch(7);
        assert_eq!(b.len(), 7);
        assert!(b.iter().all(|i| (3..=5).contains(i)));
    }
}
