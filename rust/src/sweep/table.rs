//! Aligned results tables and paper-style cell formatting (moved here
//! from the old `harness` module, which now re-exports these names).

use anyhow::Result;
use std::io::Write as _;
use std::path::{Path, PathBuf};

/// A printable results table (paper-style rows).
#[derive(Debug, Default)]
pub struct Table {
    /// Column headers.
    pub headers: Vec<String>,
    /// Row cells.
    pub rows: Vec<Vec<String>>,
}

impl Table {
    /// New table with headers.
    pub fn new(headers: &[&str]) -> Self {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    /// New table from owned headers (sink rendering convenience).
    pub fn from_headers(headers: Vec<String>) -> Self {
        Table { headers, rows: Vec::new() }
    }

    /// Append a row.
    pub fn row(&mut self, cells: Vec<String>) {
        self.rows.push(cells);
    }

    /// Render with aligned columns.
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                if i < widths.len() {
                    widths[i] = widths[i].max(c.len());
                } else {
                    widths.push(c.len());
                }
            }
        }
        let line = |cells: &[String]| {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:<w$}", c, w = widths.get(i).copied().unwrap_or(8) + 2))
                .collect::<String>()
        };
        let mut out = line(&self.headers);
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().map(|w| w + 2).sum::<usize>().min(120)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&line(row));
            out.push('\n');
        }
        out
    }

    /// Write as CSV into `dir/name.csv` (RFC-4180 quoting: cells
    /// containing commas, quotes or newlines are quoted — scenario
    /// labels like `partition(p=4,d=2)` stay one column).
    pub fn write_csv(&self, dir: &Path, name: &str) -> Result<PathBuf> {
        std::fs::create_dir_all(dir)?;
        let path = dir.join(format!("{name}.csv"));
        let mut f = std::fs::File::create(&path)?;
        let line = |cells: &[String]| {
            cells.iter().map(|c| csv_escape(c)).collect::<Vec<_>>().join(",")
        };
        writeln!(f, "{}", line(&self.headers))?;
        for row in &self.rows {
            writeln!(f, "{}", line(row))?;
        }
        Ok(path)
    }
}

fn csv_escape(cell: &str) -> String {
    if cell.chars().any(|c| matches!(c, ',' | '"' | '\n' | '\r')) {
        format!("\"{}\"", cell.replace('"', "\"\""))
    } else {
        cell.to_string()
    }
}

/// `mean ± std` cell formatting matching the paper's tables.
pub fn pm(mean: f64, std: f64) -> String {
    format!("{:.2} ± {:.2}", mean, std)
}

/// Percent formatting.
pub fn pct(v: f64) -> String {
    format!("{:.2}%", 100.0 * v)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["model", "AGP", "DSGD-AAU"]);
        t.row(vec!["2-NN".into(), "43.87".into(), "45.43".into()]);
        let s = t.render();
        assert!(s.contains("model"));
        assert!(s.lines().count() == 3);
    }

    #[test]
    fn csv_written() {
        let mut t = Table::new(&["a", "b"]);
        t.row(vec!["1".into(), "2".into()]);
        let dir = std::env::temp_dir().join("dsgd_harness_test");
        let p = t.write_csv(&dir, "t").unwrap();
        assert!(std::fs::read_to_string(p).unwrap().contains("a,b"));
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn csv_quotes_comma_labels() {
        let mut t = Table::new(&["scenario", "loss"]);
        t.row(vec!["partition(p=4,d=2)".into(), "0.5".into()]);
        let dir = std::env::temp_dir().join("dsgd_csv_quote_test");
        let p = t.write_csv(&dir, "q").unwrap();
        let text = std::fs::read_to_string(p).unwrap();
        assert!(text.contains("\"partition(p=4,d=2)\",0.5"), "{text}");
        std::fs::remove_dir_all(dir).ok();
        assert_eq!(csv_escape("plain"), "plain");
        assert_eq!(csv_escape("a\"b"), "\"a\"\"b\"");
    }

    #[test]
    fn pm_and_pct() {
        assert_eq!(pm(45.432, 0.158), "45.43 ± 0.16");
        assert_eq!(pct(0.4543), "45.43%");
    }
}
