//! Declarative sweep specifications: named [`Axis`] values over
//! [`ExperimentConfig`] patches, cross-product and zip combinators, and
//! built-in `--quick`/`--full` tier scaling.
//!
//! A [`SweepSpec`] is the declaration the executor
//! ([`crate::sweep::run_suite`]) lowers onto the panic-contained
//! parallel sweep: every combination of axis values (plus an optional
//! seed axis) becomes one [`Cell`] — a fully patched config, its ordered
//! axis labels and a stable config hash used for `--resume`.

use crate::config::ExperimentConfig;
use crate::sweep::cli::BenchArgs;
use crate::util::json::Json;
use anyhow::{ensure, Result};
use std::rc::Rc;

/// A config mutation attached to one axis value (or the spec base).
pub type Patch = Rc<dyn Fn(&mut ExperimentConfig)>;

/// Grid tier selected by `--quick`/`--full` (default: neither).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Tier {
    /// Smallest grid that still covers every axis (the CI smoke tier).
    Quick,
    /// The development-scale grid (no flag).
    Default,
    /// Paper-scale grid (`--full`).
    Full,
}

impl Tier {
    /// Stable token used in the `BENCH_<suite>.json` header.
    pub fn token(self) -> &'static str {
        match self {
            Tier::Quick => "quick",
            Tier::Default => "default",
            Tier::Full => "full",
        }
    }

    /// Pick a per-tier scalar (budget, fleet size, iteration count...).
    pub fn pick<T>(self, quick: T, default_: T, full: T) -> T {
        match self {
            Tier::Quick => quick,
            Tier::Default => default_,
            Tier::Full => full,
        }
    }
}

fn tier_index(t: Tier) -> usize {
    match t {
        Tier::Quick => 0,
        Tier::Default => 1,
        Tier::Full => 2,
    }
}

/// One labelled value on an axis.
#[derive(Clone)]
pub struct AxisValue {
    /// Display label (table cell / JSON `labels` entry).
    pub label: String,
    patch: Patch,
}

impl AxisValue {
    /// New value: `label` plus the config mutation it stands for.
    pub fn new(label: impl Into<String>, f: impl Fn(&mut ExperimentConfig) + 'static) -> Self {
        AxisValue { label: label.into(), patch: Rc::new(f) }
    }

    /// Apply the value's config patch.
    pub fn apply(&self, cfg: &mut ExperimentConfig) {
        (self.patch.as_ref())(cfg)
    }
}

/// A named sweep axis with per-tier value lists.
#[derive(Clone)]
pub struct Axis {
    /// Axis name (table label column / pivot selector).
    pub name: String,
    /// Value lists indexed by tier (quick, default, full).
    lists: [Vec<AxisValue>; 3],
}

impl Axis {
    /// Axis with the same values at every tier.
    pub fn list(name: &str, values: Vec<AxisValue>) -> Axis {
        Axis { name: name.to_string(), lists: [values.clone(), values.clone(), values] }
    }

    /// Axis with explicitly declared per-tier value lists.
    pub fn tiered(
        name: &str,
        quick: Vec<AxisValue>,
        default_: Vec<AxisValue>,
        full: Vec<AxisValue>,
    ) -> Axis {
        Axis { name: name.to_string(), lists: [quick, default_, full] }
    }

    /// Numeric axis: per-tier value slices sharing one `f(cfg, v)` patch.
    pub fn from_numbers<T, F>(name: &str, quick: &[T], default_: &[T], full: &[T], f: F) -> Axis
    where
        T: Copy + std::fmt::Display + 'static,
        F: Fn(&mut ExperimentConfig, T) + Clone + 'static,
    {
        let mk = |vals: &[T]| -> Vec<AxisValue> {
            vals.iter()
                .map(|&v| {
                    let g = f.clone();
                    AxisValue::new(v.to_string(), move |cfg: &mut ExperimentConfig| g(cfg, v))
                })
                .collect()
        };
        Axis { name: name.to_string(), lists: [mk(quick), mk(default_), mk(full)] }
    }

    /// The axis values at `tier`.
    pub fn values(&self, tier: Tier) -> &[AxisValue] {
        &self.lists[tier_index(tier)]
    }

    /// Zip combinator: advance two axes in lockstep (labels joined with
    /// `|`, both patches applied).  Errors when any tier's lists differ
    /// in length.
    pub fn zip(self, other: Axis) -> Result<Axis> {
        let mut lists = [Vec::new(), Vec::new(), Vec::new()];
        for (i, out) in lists.iter_mut().enumerate() {
            ensure!(
                self.lists[i].len() == other.lists[i].len(),
                "zip: axes {} ({}) and {} ({}) differ in length",
                self.name,
                self.lists[i].len(),
                other.name,
                other.lists[i].len()
            );
            for (a, b) in self.lists[i].iter().zip(&other.lists[i]) {
                let pa = a.patch.clone();
                let pb = b.patch.clone();
                out.push(AxisValue {
                    label: format!("{}|{}", a.label, b.label),
                    patch: Rc::new(move |cfg: &mut ExperimentConfig| {
                        (pa.as_ref())(cfg);
                        (pb.as_ref())(cfg);
                    }),
                });
            }
        }
        Ok(Axis { name: format!("{}+{}", self.name, other.name), lists })
    }
}

/// Derived-metric targets shared by the whole suite (computed once by the
/// executor instead of per-binary).
#[derive(Debug, Clone, Copy, Default)]
pub struct Targets {
    /// Accuracy threshold for `time_to_target` / `mb_to_target`.
    pub accuracy: Option<f32>,
    /// Loss threshold for `time_to_loss_target`.
    pub loss: Option<f32>,
}

/// Numeric cell formatting for rendered tables.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Fmt {
    /// Integer.
    Int,
    /// One decimal.
    F1,
    /// Two decimals.
    F2,
    /// Four decimals.
    F4,
    /// Scientific, two decimals.
    Sci2,
    /// Percent of a [0, 1] fraction (`45.43%`).
    Pct,
    /// Speedup factor (`1.23x`).
    Speedup,
}

impl Fmt {
    /// Render one value.
    pub fn format(self, v: f64) -> String {
        match self {
            Fmt::Int => format!("{}", v as i64),
            Fmt::F1 => format!("{:.1}", v),
            Fmt::F2 => format!("{:.2}", v),
            Fmt::F4 => format!("{:.4}", v),
            Fmt::Sci2 => format!("{:.2e}", v),
            Fmt::Pct => crate::sweep::table::pct(v),
            Fmt::Speedup => format!("{:.2}x", v),
        }
    }
}

/// One metric column of a long-form table.
#[derive(Debug, Clone)]
pub struct Column {
    /// Column header.
    pub header: String,
    /// [`crate::sweep::RunRecord`] metric key.
    pub metric: String,
    /// Cell formatting.
    pub fmt: Fmt,
}

impl Column {
    /// New column.
    pub fn new(header: &str, metric: &str, fmt: Fmt) -> Self {
        Column { header: header.to_string(), metric: metric.to_string(), fmt }
    }
}

/// Shape of a rendered results table.
#[derive(Clone)]
pub enum TableShape {
    /// One row per cell: axis label columns plus metric columns.
    Long(Vec<Column>),
    /// Paper-style pivot: `row_axis` values down, `col_axis` values
    /// across, one metric per cell.  Buckets holding several records
    /// (e.g. a seed axis) render as `mean ± std` (scaled); singleton
    /// buckets use `fmt`.
    Pivot {
        /// Axis providing the row labels.
        row_axis: String,
        /// Axis providing the column headers.
        col_axis: String,
        /// Metric key aggregated into each cell.
        metric: String,
        /// Singleton-bucket formatting.
        fmt: Fmt,
        /// Multiplier applied before formatting (e.g. 100 for percent).
        scale: f64,
    },
}

/// A named table of a suite (the CSV is `<suite>_<name>.csv`, or
/// `<suite>.csv` when the name is empty).
#[derive(Clone)]
pub struct TableSpec {
    /// Table name (suffix of the CSV file).
    pub name: String,
    /// Rendered shape.
    pub shape: TableShape,
}

impl TableSpec {
    /// Long-form table.
    pub fn long(name: &str, columns: Vec<Column>) -> Self {
        TableSpec { name: name.to_string(), shape: TableShape::Long(columns) }
    }

    /// Pivot table.
    pub fn pivot(
        name: &str,
        row_axis: &str,
        col_axis: &str,
        metric: &str,
        fmt: Fmt,
        scale: f64,
    ) -> Self {
        TableSpec {
            name: name.to_string(),
            shape: TableShape::Pivot {
                row_axis: row_axis.to_string(),
                col_axis: col_axis.to_string(),
                metric: metric.to_string(),
                fmt,
                scale,
            },
        }
    }
}

/// One lowered grid cell: ordered axis labels, the patched config and a
/// stable hash of its JSON form (the `--resume` key).
#[derive(Clone)]
pub struct Cell {
    /// `(axis name, value label)` in axis-declaration order.
    pub labels: Vec<(String, String)>,
    /// Fully patched experiment config.
    pub cfg: ExperimentConfig,
    /// FNV-1a hash of `cfg.to_json()` (16 hex digits).
    pub hash: String,
}

/// Declarative sweep: base config, axes, targets and result tables.
///
/// ```
/// use dsgd_aau::sweep::cli::BenchArgs;
/// use dsgd_aau::sweep::{Axis, SweepSpec};
///
/// let spec = SweepSpec::new("doc", "demo sweep", |cfg| cfg.max_iterations = 10)
///     .axis(Axis::from_numbers("N", &[4usize], &[4, 8], &[8, 16], |cfg, n| {
///         cfg.num_workers = n
///     }));
/// let cells = spec.lower(&BenchArgs::default()).unwrap();
/// assert_eq!(cells.len(), 2); // default tier: N in {4, 8}
/// assert_eq!(cells[1].cfg.num_workers, 8);
/// assert_eq!(cells[1].labels, vec![("N".to_string(), "8".to_string())]);
/// ```
pub struct SweepSpec {
    /// Suite name (`bench <suite>`, `BENCH_<suite>.json`).
    pub suite: String,
    /// Heading printed above the tables.
    pub title: String,
    base: Patch,
    axes: Vec<Axis>,
    seed_base: Option<u64>,
    /// Derived-metric targets.
    pub targets: Targets,
    /// Compute a `speedup` metric vs the cell with this `(axis, label)`
    /// in each group of otherwise-identical labels.
    pub speedup_baseline: Option<(String, String)>,
    /// Tables rendered (and CSV'd) from the records.
    pub tables: Vec<TableSpec>,
    /// Free-form reading notes printed after the tables.
    pub notes: Option<String>,
    /// Write each fresh cell's loss curve as `<suite>_curve_<labels>.csv`.
    pub curve_csvs: bool,
    #[allow(clippy::type_complexity)]
    setup: Option<Box<dyn Fn(&BenchArgs) -> Result<()>>>,
    consumed: Vec<String>,
}

impl SweepSpec {
    /// New spec with a base config patch applied before any axis value.
    pub fn new(suite: &str, title: &str, base: impl Fn(&mut ExperimentConfig) + 'static) -> Self {
        SweepSpec {
            suite: suite.to_string(),
            title: title.to_string(),
            base: Rc::new(base),
            axes: Vec::new(),
            seed_base: None,
            targets: Targets::default(),
            speedup_baseline: None,
            tables: Vec::new(),
            notes: None,
            curve_csvs: false,
            setup: None,
            consumed: Vec::new(),
        }
    }

    /// Append an axis (first axis varies slowest).
    pub fn axis(mut self, a: Axis) -> Self {
        self.axes.push(a);
        self
    }

    /// Append an innermost `seed` axis: `--seeds K` cells with
    /// `cfg.seed = base + s`.
    pub fn with_seeds(mut self, base: u64) -> Self {
        self.seed_base = Some(base);
        self
    }

    /// Accuracy target for the shared derived metrics.
    pub fn target_accuracy(mut self, t: f32) -> Self {
        self.targets.accuracy = Some(t);
        self
    }

    /// Loss target for the shared derived metrics.
    pub fn target_loss(mut self, t: f32) -> Self {
        self.targets.loss = Some(t);
        self
    }

    /// Derive `speedup` against the `(axis, label)` baseline cell.
    pub fn speedup_vs(mut self, axis: &str, label: &str) -> Self {
        self.speedup_baseline = Some((axis.to_string(), label.to_string()));
        self
    }

    /// Append a result table.
    pub fn table(mut self, t: TableSpec) -> Self {
        self.tables.push(t);
        self
    }

    /// Reading notes printed after the tables.
    pub fn notes(mut self, s: &str) -> Self {
        self.notes = Some(s.to_string());
        self
    }

    /// Write per-cell loss-curve CSVs.
    pub fn curves(mut self) -> Self {
        self.curve_csvs = true;
        self
    }

    /// One-time setup hook run before the sweep (e.g. materializing a
    /// straggler trace into the output directory).
    pub fn setup(mut self, f: impl Fn(&BenchArgs) -> Result<()> + 'static) -> Self {
        self.setup = Some(Box::new(f));
        self
    }

    /// Declare `--key=value` extras the suite interprets itself; any
    /// other extra must name an [`ExperimentConfig`] key.
    pub fn consumes(mut self, keys: &[&str]) -> Self {
        self.consumed.extend(keys.iter().map(|k| k.to_string()));
        self
    }

    /// Run the setup hook, if any.
    pub fn run_setup(&self, args: &BenchArgs) -> Result<()> {
        if let Some(setup) = &self.setup {
            (setup.as_ref())(args)?;
        }
        Ok(())
    }

    /// Lower the spec into its ordered cell grid for `args`' tier:
    /// row-major cross product over the axes (first axis outermost, the
    /// seed axis innermost), deterministic and order-stable.
    pub fn lower(&self, args: &BenchArgs) -> Result<Vec<Cell>> {
        let tier = args.tier()?;
        let mut axes: Vec<Axis> = self.axes.clone();
        if let Some(base) = self.seed_base {
            ensure!(args.seeds >= 1, "--seeds must be at least 1");
            let vals: Vec<AxisValue> = (0..args.seeds)
                .map(|s| {
                    AxisValue::new(s.to_string(), move |cfg: &mut ExperimentConfig| {
                        cfg.seed = base + s
                    })
                })
                .collect();
            axes.push(Axis::list("seed", vals));
        }
        ensure!(!axes.is_empty(), "spec {} declares no axes", self.suite);
        {
            let mut names = std::collections::BTreeSet::new();
            for ax in &axes {
                ensure!(names.insert(ax.name.clone()), "duplicate axis name {}", ax.name);
                ensure!(
                    !ax.values(tier).is_empty(),
                    "axis {} has no values at tier {}",
                    ax.name,
                    tier.token()
                );
            }
        }

        let k = axes.len();
        let mut idx = vec![0usize; k];
        let mut cells = Vec::new();
        'grid: loop {
            let mut cfg = ExperimentConfig::default();
            (self.base.as_ref())(&mut cfg);
            let mut labels = Vec::with_capacity(k);
            for (a, ax) in axes.iter().enumerate() {
                let v = &ax.values(tier)[idx[a]];
                v.apply(&mut cfg);
                labels.push((ax.name.clone(), v.label.clone()));
            }
            for (key, raw) in &args.extra {
                if self.consumed.iter().any(|c| c == key) {
                    continue;
                }
                let v = Json::parse(raw).unwrap_or_else(|_| Json::Str(raw.clone()));
                cfg.apply_kv(key, &v)
                    .map_err(|e| anyhow::anyhow!("override --{key}={raw}: {e}"))?;
            }
            args.apply(&mut cfg)?;
            cfg.name = cell_name(&self.suite, &labels);
            let hash = config_hash(&cfg);
            cells.push(Cell { labels, cfg, hash });

            // odometer: last axis increments fastest
            let mut a = k;
            loop {
                if a == 0 {
                    break 'grid;
                }
                a -= 1;
                idx[a] += 1;
                if idx[a] < axes[a].values(tier).len() {
                    break;
                }
                idx[a] = 0;
            }
        }

        // Two cells with identical configs (ignoring the label-bearing
        // name) mean an axis collapsed — usually a `--key=value` override
        // clobbering an axis-set field, which would silently render a
        // fake table of N identical experiments.
        let mut seen = std::collections::BTreeMap::new();
        for c in &cells {
            let mut anon = c.cfg.clone();
            anon.name.clear();
            if let Some(first) = seen.insert(config_hash(&anon), c.cfg.name.clone()) {
                anyhow::bail!(
                    "cells {:?} and {:?} lower to identical experiments — \
                     an override (--key=value) probably collapsed an axis",
                    first,
                    c.cfg.name
                );
            }
        }
        Ok(cells)
    }
}

fn cell_name(suite: &str, labels: &[(String, String)]) -> String {
    let parts: Vec<String> = labels.iter().map(|(n, v)| format!("{n}={v}")).collect();
    format!("{suite}:{}", parts.join(","))
}

/// Stable config hash: FNV-1a over the compact JSON form.
pub fn config_hash(cfg: &ExperimentConfig) -> String {
    let text = cfg.to_json().to_string_compact();
    format!("{:016x}", crate::util::fnv1a(text.as_bytes()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tier_pick_and_tokens() {
        assert_eq!(Tier::Quick.pick(1, 2, 3), 1);
        assert_eq!(Tier::Default.pick(1, 2, 3), 2);
        assert_eq!(Tier::Full.pick(1, 2, 3), 3);
        assert_eq!(Tier::Quick.token(), "quick");
        assert_eq!(Tier::Full.token(), "full");
    }

    #[test]
    fn numeric_axis_tiers() {
        let ax = Axis::from_numbers("N", &[4usize], &[4, 8], &[8, 16, 32], |cfg, n| {
            cfg.num_workers = n
        });
        assert_eq!(ax.values(Tier::Quick).len(), 1);
        assert_eq!(ax.values(Tier::Default).len(), 2);
        assert_eq!(ax.values(Tier::Full).len(), 3);
        let mut cfg = ExperimentConfig::default();
        ax.values(Tier::Full)[2].apply(&mut cfg);
        assert_eq!(cfg.num_workers, 32);
        assert_eq!(ax.values(Tier::Full)[2].label, "32");
    }

    #[test]
    fn zip_combines_labels_and_patches() {
        let a = Axis::from_numbers("N", &[4usize, 8], &[4, 8], &[4, 8], |cfg, n| {
            cfg.num_workers = n
        });
        let b = Axis::from_numbers("eval", &[5u64, 10], &[5, 10], &[5, 10], |cfg, e| {
            cfg.eval_every = e
        });
        let z = a.zip(b).unwrap();
        assert_eq!(z.name, "N+eval");
        assert_eq!(z.values(Tier::Default).len(), 2);
        assert_eq!(z.values(Tier::Default)[1].label, "8|10");
        let mut cfg = ExperimentConfig::default();
        z.values(Tier::Default)[1].apply(&mut cfg);
        assert_eq!((cfg.num_workers, cfg.eval_every), (8, 10));
        // mismatched lengths are rejected
        let a = Axis::from_numbers("N", &[4usize], &[4], &[4], |cfg, n| cfg.num_workers = n);
        let b = Axis::from_numbers("eval", &[5u64, 10], &[5, 10], &[5, 10], |cfg, e| {
            cfg.eval_every = e
        });
        assert!(a.zip(b).is_err());
    }

    #[test]
    fn config_hash_stable_and_name_sensitive() {
        let cfg = ExperimentConfig::default();
        assert_eq!(config_hash(&cfg), config_hash(&cfg));
        let mut other = ExperimentConfig::default();
        other.name = "different".into();
        assert_ne!(config_hash(&cfg), config_hash(&other));
    }
}
