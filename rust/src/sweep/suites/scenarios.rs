//! Scenario suites beyond the paper's figures: the ROADMAP's churn,
//! straggler-process and partition grids as [`SweepSpec`] declarations.

use super::alg_axis;
use crate::adapt::AdaptConfig;
use crate::algorithms::AlgorithmKind;
use crate::churn::{ChurnConfig, ChurnKind};
use crate::config::{BackendKind, ExperimentConfig};
use crate::sim::{materialize_trace, StragglerKind, StragglerModel};
use crate::sweep::cli::BenchArgs;
use crate::sweep::spec::{Axis, AxisValue, Column, Fmt, SweepSpec, TableSpec};
use crate::topology::TopologyKind;
use anyhow::Result;

const STRAGGLER_SEED: u64 = 5;

fn quadratic_base(cfg: &mut ExperimentConfig, n: usize, seed: u64) {
    cfg.num_workers = n;
    cfg.backend = BackendKind::Quadratic;
    cfg.topology = TopologyKind::Random { p: 0.3, seed: 11 };
    cfg.mean_compute = 0.01;
    cfg.seed = seed;
}

fn flaky_value(rate: f64) -> AxisValue {
    AxisValue::new(format!("flaky(r={rate})"), move |cfg: &mut ExperimentConfig| {
        cfg.churn =
            ChurnConfig { kind: ChurnKind::FlakyLinks { rate, mean_downtime: 1.0 }, seed: None }
    })
}

fn churn_scenarios(rates: &[f64], extended: bool) -> Vec<AxisValue> {
    let mut out = vec![AxisValue::new("static", |_cfg: &mut ExperimentConfig| {})];
    out.extend(rates.iter().map(|&r| flaky_value(r)));
    if extended {
        out.push(AxisValue::new("mobile", |cfg: &mut ExperimentConfig| {
            cfg.churn = ChurnConfig {
                kind: ChurnKind::Mobile { movers: 3, interval: 0.5, degree: 3 },
                seed: None,
            }
        }));
        out.push(AxisValue::new("partition/heal", |cfg: &mut ExperimentConfig| {
            cfg.churn = ChurnConfig {
                kind: ChurnKind::PartitionHeal { period: 4.0, downtime: 1.5 },
                seed: None,
            }
        }));
    }
    out
}

/// Churn sweep: how DSGD-AAU and the four baselines cope with
/// time-varying communication graphs (static baseline, flaky links at
/// increasing rates, mobile workers, partition/heal cycles).
pub fn churn(args: &BenchArgs) -> Result<SweepSpec> {
    let tier = args.tier()?;
    let n = tier.pick(8usize, 12, 32);
    let iters = tier.pick(200u64, 800, 3000);
    Ok(SweepSpec::new(
        "churn",
        &format!("Churn sweep — {n} workers, quadratic workload, {iters} iterations"),
        move |cfg| {
            quadratic_base(cfg, n, 7000);
            cfg.max_iterations = iters;
            cfg.eval_every = (iters / 10).max(1);
        },
    )
    .axis(Axis::tiered(
        "scenario",
        churn_scenarios(&[0.5], true),
        churn_scenarios(&[0.5, 2.0], true),
        churn_scenarios(&[0.5, 2.0, 8.0], true),
    ))
    .axis(alg_axis(&AlgorithmKind::all()))
    .table(TableSpec::long(
        "",
        vec![
            Column::new("iters", "iterations", Fmt::Int),
            Column::new("vtime(s)", "virtual_time", Fmt::F2),
            Column::new("loss", "final_loss", Fmt::F4),
            Column::new("gap", "consensus_gap", Fmt::Sci2),
            Column::new("changes", "topology_changes", Fmt::Int),
            Column::new("applied", "mutations_applied", Fmt::Int),
            Column::new("deferred", "mutations_deferred", Fmt::Int),
        ],
    ))
    .notes(
        "Reading: the static rows reproduce the fixed-graph setting; under \
         churn every algorithm keeps converging because connectivity repair \
         preserves the paper's assumption, while `deferred` counts how often \
         a removal had to be held back to do so.",
    ))
}

fn ge_model() -> StragglerModel {
    StragglerModel {
        kind: StragglerKind::GilbertElliott { mean_fast: 0.4, mean_slow: 0.1 },
        seed: Some(STRAGGLER_SEED),
        ..StragglerModel::default()
    }
}

fn process_values(trace_path: String) -> Vec<AxisValue> {
    vec![
        AxisValue::new("bernoulli", |cfg: &mut ExperimentConfig| {
            cfg.straggler = StragglerModel::default()
        }),
        AxisValue::new("gilbert_elliott", |cfg: &mut ExperimentConfig| {
            cfg.straggler = ge_model()
        }),
        AxisValue::new("weibull", |cfg: &mut ExperimentConfig| {
            cfg.straggler = StragglerModel {
                kind: StragglerKind::WeibullBursts { shape: 0.7, scale: 0.4, mean_burst: 0.1 },
                seed: Some(STRAGGLER_SEED),
                ..StragglerModel::default()
            }
        }),
        AxisValue::new("trace(ge)", move |cfg: &mut ExperimentConfig| {
            cfg.straggler = StragglerModel {
                kind: StragglerKind::Trace { path: trace_path.clone() },
                ..StragglerModel::default()
            }
        }),
    ]
}

/// Straggler-process x churn x algorithm sweep (the ROADMAP's joint
/// grid).  The `trace(ge)` rows replay a materialized trace of the
/// `gilbert_elliott` rows and must match them — a standing round-trip
/// check of the trace subsystem.
pub fn straggler(args: &BenchArgs) -> Result<SweepSpec> {
    let tier = args.tier()?;
    let n = tier.pick(8usize, 12, 32);
    let iters = tier.pick(200u64, 600, 3000);
    let trace_path = args.out_dir.join("straggler_trace_ge.json");
    Ok(SweepSpec::new(
        "straggler",
        &format!("Straggler-process sweep — {n} workers, quadratic workload, {iters} iterations"),
        move |cfg| {
            quadratic_base(cfg, n, 9000);
            cfg.max_iterations = iters;
            cfg.eval_every = (iters / 10).max(1);
        },
    )
    .setup(move |_args: &BenchArgs| {
        // Materialize the Gilbert-Elliott evolution once (deterministic
        // artifact in the output directory) so the trace rows replay it
        // bit for bit; the horizon sits far past any run's virtual time.
        let tl = materialize_trace(&ge_model(), n, 0, 600.0)?;
        tl.save(&trace_path)?;
        Ok(())
    })
    .axis(Axis::list(
        "process",
        process_values(args.out_dir.join("straggler_trace_ge.json").display().to_string()),
    ))
    .axis(Axis::tiered(
        "churn",
        churn_scenarios(&[0.5], false),
        churn_scenarios(&[0.5, 2.0], false),
        churn_scenarios(&[0.5, 2.0, 8.0], false),
    ))
    .axis(alg_axis(&AlgorithmKind::all()))
    .table(TableSpec::long(
        "",
        vec![
            Column::new("iters", "iterations", Fmt::Int),
            Column::new("vtime(s)", "virtual_time", Fmt::F2),
            Column::new("loss", "final_loss", Fmt::F4),
            Column::new("strag%", "straggler_pct", Fmt::F1),
            Column::new("stalls", "stall_fallbacks", Fmt::Int),
        ],
    ))
    .notes(
        "Reading: under the correlated processes the same average straggler \
         budget hits the barrier algorithms much harder than the i.i.d. coin \
         (persistent slow workers sit in every round), which is exactly the \
         regime DSGD-AAU's adaptive waiting targets.  The trace(ge) rows \
         replay the gilbert_elliott rows' slow/fast evolution from JSON and \
         must match them; `stalls` counts DSGD-AAU's full-fleet liveness \
         fallbacks under churn.",
    ))
}

fn partition_scenarios(grids: &[(f64, f64)]) -> Vec<AxisValue> {
    grids
        .iter()
        .map(|&(period, downtime)| {
            AxisValue::new(
                format!("partition(p={period},d={downtime})"),
                move |cfg: &mut ExperimentConfig| {
                    cfg.churn = ChurnConfig {
                        kind: ChurnKind::PartitionHeal { period, downtime },
                        seed: Some(13),
                    }
                },
            )
        })
        .collect()
}

fn mode_values() -> Vec<AxisValue> {
    vec![
        AxisValue::new("repair", |cfg: &mut ExperimentConfig| cfg.adapt = AdaptConfig::default()),
        AxisValue::new("blind", |cfg: &mut ExperimentConfig| {
            cfg.adapt = AdaptConfig { allow_partitions: true, ..AdaptConfig::default() }
        }),
        AxisValue::new("aware", |cfg: &mut ExperimentConfig| {
            cfg.adapt = AdaptConfig {
                allow_partitions: true,
                partition_aware: true,
                detection_latency: 0.1.into(),
                heal_restart: true,
            }
        }),
    ]
}

/// Partition sweep: what real partitions cost each update rule, and what
/// partition-aware adaptivity buys back (`repair`/`blind`/`aware`).
pub fn partition(args: &BenchArgs) -> Result<SweepSpec> {
    let tier = args.tier()?;
    let n = tier.pick(12usize, 12, 32);
    let budget = tier.pick(4.0, 15.0, 40.0);
    Ok(SweepSpec::new(
        "partition",
        &format!("Partition sweep — {n} workers, quadratic workload, {budget}s budget"),
        move |cfg| {
            quadratic_base(cfg, n, 8000);
            cfg.max_iterations = u64::MAX / 2;
            cfg.time_budget = Some(budget);
            cfg.eval_every = 200;
        },
    )
    .axis(Axis::tiered(
        "scenario",
        partition_scenarios(&[(3.0, 1.5)]),
        partition_scenarios(&[(4.0, 2.0), (2.0, 1.0)]),
        partition_scenarios(&[(8.0, 3.0), (4.0, 2.0), (2.0, 1.0)]),
    ))
    .axis(Axis::list("mode", mode_values()))
    .axis(alg_axis(&AlgorithmKind::all()))
    .table(TableSpec::long(
        "",
        vec![
            Column::new("iters", "iterations", Fmt::Int),
            Column::new("loss", "final_loss", Fmt::F4),
            Column::new("stalls", "stall_fallbacks", Fmt::Int),
            Column::new("splits", "partition_splits", Fmt::Int),
            Column::new("merges", "partition_merges", Fmt::Int),
            Column::new("comp_epochs", "component_epochs", Fmt::Int),
            Column::new("restarts", "epoch_restarts", Fmt::Int),
        ],
    ))
    .notes(
        "Reading: `repair` keeps the paper's connectivity assumption by \
         deferring the last bridge; `blind` lets the cut happen and the \
         partition-blind rules crawl (DSGD-AAU only via stall fallbacks); \
         `aware` retargets every rule to the live component — stalls drop \
         to zero and iterations recover.",
    ))
}
