//! The registered bench suites: each paper table/figure (plus the
//! ROADMAP's churn/straggler/partition grids and the real-cluster trace
//! grid) as a ~30-line [`SweepSpec`] declaration.  The registry lives in
//! [`crate::sweep::cli`].

mod fragment;
mod membership;
mod paper;
mod scenarios;
mod showdown;
mod trace;

pub use fragment::fragment;
pub use membership::membership;
pub use paper::{ablation, accuracy, fixedk, loss_curves, speedup, timebudget};
pub use scenarios::{churn, partition, straggler};
pub use showdown::showdown;
pub use trace::trace;

use crate::algorithms::AlgorithmKind;
use crate::config::ExperimentConfig;
use crate::sweep::cli::BenchArgs;
use crate::sweep::spec::{Axis, AxisValue};

/// Algorithm axis labelled with the paper's column names.
pub(crate) fn alg_axis(algs: &[AlgorithmKind]) -> Axis {
    Axis::list(
        "algorithm",
        algs.iter()
            .map(|&a| {
                AxisValue::new(a.label(), move |cfg: &mut ExperimentConfig| cfg.algorithm = a)
            })
            .collect(),
    )
}

/// `--key=1` boolean extras (e.g. `--iid=1`).
pub(crate) fn flag(args: &BenchArgs, key: &str) -> bool {
    args.extra.get(key).map(|v| v == "1").unwrap_or(false)
}
