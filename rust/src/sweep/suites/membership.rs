//! Open-world membership suite: million-user populations sampled into a
//! bounded slot fleet (Poisson arrival/departure, per-round participation
//! sampling, optional two-tier hierarchy) — the sampled-participation
//! layer's standing grid.

use super::alg_axis;
use crate::adapt::AdaptConfig;
use crate::algorithms::AlgorithmKind;
use crate::config::{BackendKind, ExperimentConfig};
use crate::membership::{MembershipConfig, SamplingKind};
use crate::sweep::cli::BenchArgs;
use crate::sweep::spec::{Axis, AxisValue, Column, Fmt, SweepSpec, TableSpec};
use crate::topology::TopologyKind;
use anyhow::Result;

fn with_membership(cfg: &mut ExperimentConfig, f: impl FnOnce(&mut MembershipConfig)) {
    f(cfg.membership.as_mut().expect("membership base set"))
}

fn population_values(pops: &[usize]) -> Vec<AxisValue> {
    pops.iter()
        .map(|&p| {
            let label = if p >= 1_000_000 {
                format!("{}M", p / 1_000_000)
            } else {
                format!("{}k", p / 1_000)
            };
            AxisValue::new(label, move |cfg: &mut ExperimentConfig| {
                with_membership(cfg, |mc| mc.population = p)
            })
        })
        .collect()
}

fn churn_values(extended: bool) -> Vec<AxisValue> {
    let mut out = vec![
        AxisValue::new("stable", |cfg: &mut ExperimentConfig| {
            with_membership(cfg, |mc| {
                mc.arrival_rate = 0.0;
                mc.departure_rate = 0.0;
            })
        }),
        AxisValue::new("churn(λ=2,μ=0.2)", |cfg: &mut ExperimentConfig| {
            with_membership(cfg, |mc| {
                mc.arrival_rate = 2.0;
                mc.departure_rate = 0.2;
            })
        }),
        AxisValue::new("two-tier(a=4)", |cfg: &mut ExperimentConfig| {
            with_membership(cfg, |mc| {
                mc.arrival_rate = 2.0;
                mc.departure_rate = 0.2;
                mc.aggregators = 4;
            })
        }),
    ];
    if extended {
        out.push(AxisValue::new("heavy(λ=8,μ=1)", |cfg: &mut ExperimentConfig| {
            with_membership(cfg, |mc| {
                mc.arrival_rate = 8.0;
                mc.departure_rate = 1.0;
            })
        }));
    }
    out
}

fn sampling_values(extended: bool) -> Vec<AxisValue> {
    let mut out = vec![
        AxisValue::new("uniform(p=0.5)", |cfg: &mut ExperimentConfig| {
            with_membership(cfg, |mc| {
                mc.participation = 0.5;
                mc.sampling = SamplingKind::Uniform;
            })
        }),
        AxisValue::new("sticky(p=0.5,s=0.8)", |cfg: &mut ExperimentConfig| {
            with_membership(cfg, |mc| {
                mc.participation = 0.5;
                mc.sampling = SamplingKind::Sticky;
                mc.stickiness = 0.8;
            })
        }),
    ];
    if extended {
        out.push(AxisValue::new("sticky(p=0.25,s=0.9)", |cfg: &mut ExperimentConfig| {
            with_membership(cfg, |mc| {
                mc.participation = 0.25;
                mc.sampling = SamplingKind::Sticky;
                mc.stickiness = 0.9;
            })
        }));
    }
    out
}

/// Membership sweep: open-world populations (1e5–1e6 logical users)
/// sampled into a 16-slot fleet under uniform/sticky participation, user
/// arrival/departure, and the optional aggregator tier.
pub fn membership(args: &BenchArgs) -> Result<SweepSpec> {
    let tier = args.tier()?;
    let n = 16usize;
    let budget = tier.pick(4.0, 15.0, 40.0);
    // The quick/default tiers sweep the quadratic workload (fast smoke of
    // the membership machinery); the paper-scale tier trains the native
    // MLP so the 1e6-user axis carries an accuracy story too.
    let backend = tier.pick(BackendKind::Quadratic, BackendKind::Quadratic, BackendKind::NativeMlp);
    let workload = tier.pick("quadratic", "quadratic", "mlp_small");
    Ok(SweepSpec::new(
        "membership",
        &format!(
            "Open-world membership sweep — {n} slots, {workload} workload, {budget}s budget"
        ),
        move |cfg| {
            cfg.num_workers = n;
            cfg.backend = backend;
            if backend == BackendKind::NativeMlp {
                cfg.model = "mlp_small".into();
            }
            cfg.topology = TopologyKind::Random { p: 0.3, seed: 11 };
            cfg.mean_compute = 0.01;
            cfg.seed = 11000;
            cfg.max_iterations = u64::MAX / 2;
            cfg.time_budget = Some(budget);
            cfg.eval_every = 200;
            // vacant slots are isolated vertices — membership requires the
            // partition-aware mode end to end
            cfg.adapt = AdaptConfig {
                allow_partitions: true,
                partition_aware: true,
                detection_latency: 0.1.into(),
                heal_restart: true,
            };
            cfg.membership = Some(MembershipConfig {
                round_interval: 2.0,
                ..MembershipConfig::default()
            });
        },
    )
    .axis(Axis::tiered(
        "population",
        population_values(&[100_000]),
        population_values(&[100_000, 300_000]),
        population_values(&[100_000, 1_000_000]),
    ))
    .axis(Axis::tiered(
        "fleet",
        churn_values(false),
        churn_values(true),
        churn_values(true),
    ))
    .axis(Axis::tiered(
        "sampling",
        sampling_values(false),
        sampling_values(false),
        sampling_values(true),
    ))
    .axis(alg_axis(&[AlgorithmKind::DsgdAau, AlgorithmKind::Prague]))
    .table(TableSpec::long(
        "",
        vec![
            Column::new("iters", "iterations", Fmt::Int),
            Column::new("loss", "final_loss", Fmt::F4),
            Column::new("acc", "best_accuracy", Fmt::Pct),
            Column::new("bytes", "total_bytes", Fmt::Sci2),
            Column::new("rounds", "rounds_sampled", Fmt::Int),
            Column::new("joined", "workers_joined", Fmt::Int),
            Column::new("left", "workers_left", Fmt::Int),
            Column::new("comps", "max_components", Fmt::Int),
            Column::new("regroups", "prague_regroups", Fmt::Int),
        ],
    ))
    .notes(
        "Reading: population scales the logical user pool, not the engine — \
         memory and per-event cost stay O(active slots), so the 100k and 1M \
         rows run at the same speed.  `rounds` counts participation \
         resamples, `joined`/`left` the slot fills and retirements they \
         (plus the Poisson departure clock) caused; under sticky sampling \
         fewer swaps happen per round, trading freshness for warm-start \
         traffic.  `regroups` is Prague's proactive group reassignment \
         when members depart mid-epoch.  At --full the fleet trains the \
         native MLP (the `acc` column is meaningful there; the quadratic \
         tiers report its placeholder).",
    ))
}
