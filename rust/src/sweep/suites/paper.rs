//! Paper-evaluation suites (§6): accuracy/timebudget tables, loss
//! curves, the speedup figure and the ablation grids — each one a
//! declarative [`SweepSpec`] over the shared executor.

use super::{alg_axis, flag};
use crate::algorithms::AlgorithmKind;
use crate::config::{BackendKind, ExperimentConfig};
use crate::sweep::cli::BenchArgs;
use crate::sweep::spec::{Axis, AxisValue, Column, Fmt, SweepSpec, TableSpec};
use anyhow::Result;

fn model_values(names: &[&str]) -> Vec<AxisValue> {
    names
        .iter()
        .map(|&name| {
            let name = name.to_string();
            let set = name.clone();
            AxisValue::new(name, move |cfg: &mut ExperimentConfig| cfg.model = set.clone())
        })
        .collect()
}

/// Tables 1/8 (non-IID) and 10 (`--iid=1`): final accuracy of every
/// algorithm across the model ladder at a fixed worker count.
pub fn accuracy(args: &BenchArgs) -> Result<SweepSpec> {
    let tier = args.tier()?;
    let iid = flag(args, "iid");
    let n = tier.pick(8usize, 32, 128);
    let budget = tier.pick(15.0, 120.0, 300.0);
    let samples = tier.pick(2048usize, 4096, 16384);
    Ok(SweepSpec::new(
        "accuracy",
        &format!(
            "Table 1/8/10 analogue — best accuracy (%), N={n}, {} data",
            if iid { "IID" } else { "non-IID" }
        ),
        move |cfg| {
            cfg.num_workers = n;
            cfg.backend = BackendKind::NativeMlp;
            cfg.iid = iid;
            cfg.max_iterations = u64::MAX / 2;
            cfg.time_budget = Some(budget);
            cfg.eval_every = 50;
            cfg.dataset_samples = samples;
        },
    )
    .axis(Axis::tiered(
        "model",
        model_values(&["mlp_tiny"]),
        model_values(&["mlp_tiny", "mlp_small"]),
        model_values(&["mlp_tiny", "mlp_small", "mlp2nn"]),
    ))
    .axis(alg_axis(&AlgorithmKind::paper_table()))
    .with_seeds(1000)
    .consumes(&["iid"])
    .table(TableSpec::pivot("", "model", "algorithm", "best_accuracy", Fmt::F2, 100.0)))
}

/// Tables 2/9 (non-IID) and 11 (`--iid=1`): accuracy after a fixed
/// virtual wall-clock budget across worker counts.
pub fn timebudget(args: &BenchArgs) -> Result<SweepSpec> {
    let tier = args.tier()?;
    let iid = flag(args, "iid");
    let budget = tier.pick(8.0, 25.0, 60.0);
    Ok(SweepSpec::new(
        "timebudget",
        &format!(
            "Table 2/9/11 analogue — accuracy (%) after {budget:.0}s virtual budget, {} data",
            if iid { "IID" } else { "non-IID" }
        ),
        move |cfg| {
            cfg.backend = BackendKind::NativeMlp;
            cfg.model = "mlp_small".into();
            cfg.iid = iid;
            cfg.max_iterations = u64::MAX / 2;
            cfg.time_budget = Some(budget);
            cfg.eval_every = 25;
        },
    )
    .axis(Axis::from_numbers(
        "N",
        &[8usize, 16],
        &[8, 16, 32, 64],
        &[32, 64, 128, 256],
        |cfg, n| cfg.num_workers = n,
    ))
    .axis(alg_axis(&AlgorithmKind::paper_table()))
    .with_seeds(2000)
    .consumes(&["iid"])
    .table(TableSpec::pivot("", "N", "algorithm", "final_accuracy", Fmt::F2, 100.0)))
}

/// Figures 3–4: loss checkpoints per algorithm, plus per-cell curve CSVs
/// (loss vs iteration and vs virtual wall-clock).
pub fn loss_curves(args: &BenchArgs) -> Result<SweepSpec> {
    let tier = args.tier()?;
    let n = tier.pick(8usize, 32, 128);
    let iters = tier.pick(200u64, 1500, 6000);
    Ok(SweepSpec::new(
        "loss_curves",
        &format!("Figure 3/4 analogue — loss checkpoints (N={n}, non-IID)"),
        move |cfg| {
            cfg.num_workers = n;
            cfg.backend = BackendKind::NativeMlp;
            cfg.model = "mlp_small".into();
            cfg.max_iterations = iters;
            cfg.eval_every = (iters / 60).max(1);
            cfg.seed = 3000;
        },
    )
    .axis(alg_axis(&AlgorithmKind::all()))
    .curves()
    .table(TableSpec::long(
        "",
        vec![
            Column::new("loss@25%", "loss_q25", Fmt::F4),
            Column::new("loss@50%", "loss_q50", Fmt::F4),
            Column::new("loss@100%", "loss_q100", Fmt::F4),
            Column::new("vtime(s)", "virtual_time", Fmt::F1),
            Column::new("iters/s(virt)", "iters_per_vsec", Fmt::F1),
        ],
    )))
}

/// Figure 5(a)+(b): speedup over synchronous DSGD to a target accuracy,
/// and the communication spent reaching it, vs the number of workers.
pub fn speedup(args: &BenchArgs) -> Result<SweepSpec> {
    let tier = args.tier()?;
    let target: f32 = args.extra.get("target").and_then(|v| v.parse().ok()).unwrap_or(0.45);
    let budget = tier.pick(40.0, 200.0, 400.0);
    Ok(SweepSpec::new(
        "speedup",
        &format!(
            "Figure 5 analogue — speedup to {:.0}% accuracy (rel. sync DSGD) and MB to target",
            100.0 * target
        ),
        move |cfg| {
            cfg.backend = BackendKind::NativeMlp;
            cfg.model = "mlp_small".into();
            cfg.max_iterations = u64::MAX / 2;
            cfg.time_budget = Some(budget);
            cfg.eval_every = 20;
            cfg.seed = 4000;
        },
    )
    .axis(Axis::from_numbers("N", &[8usize], &[8, 16, 32], &[32, 64, 128, 256], |cfg, n| {
        cfg.num_workers = n
    }))
    .axis(alg_axis(&AlgorithmKind::all()))
    .consumes(&["target"])
    .target_accuracy(target)
    .speedup_vs("algorithm", AlgorithmKind::DsgdSync.label())
    .table(TableSpec::pivot("speedup", "N", "algorithm", "speedup", Fmt::Speedup, 1.0))
    .table(TableSpec::pivot("communication", "N", "algorithm", "mb_to_target", Fmt::F1, 1.0)))
}

fn ablation_params(probs: &[f64], slows: &[f64], batches: &[usize]) -> Vec<AxisValue> {
    let mut out = Vec::new();
    for &p in probs {
        out.push(AxisValue::new(format!("straggler_prob={p}"), move |cfg: &mut ExperimentConfig| {
            cfg.straggler.probability = p
        }));
    }
    for &s in slows {
        out.push(AxisValue::new(format!("slowdown={s}"), move |cfg: &mut ExperimentConfig| {
            cfg.straggler.slowdown = s
        }));
    }
    for &b in batches {
        out.push(AxisValue::new(format!("batch={b}"), move |cfg: &mut ExperimentConfig| {
            cfg.model = format!("mlp_small@b{b}")
        }));
    }
    out
}

/// Figures 9–12: straggler probability, straggler slowdown and batch
/// size ablations (IID via `--iid=1`, fixed time budget via
/// `--budget=1`; batch rides on the `mlp_small@b<K>` model variants).
pub fn ablation(args: &BenchArgs) -> Result<SweepSpec> {
    let tier = args.tier()?;
    let iid = flag(args, "iid");
    let budget_mode = flag(args, "budget");
    let metric = if budget_mode { "final_accuracy" } else { "best_accuracy" };
    let iters = tier.pick(300u64, 800, 3000);
    let n = tier.pick(8usize, 32, 128);
    let figure = match (iid, budget_mode) {
        (false, false) => "Figure 9",
        (false, true) => "Figure 10",
        (true, false) => "Figure 11",
        (true, true) => "Figure 12",
    };
    Ok(SweepSpec::new(
        "ablation",
        &format!("{figure} analogue — accuracy (%) vs straggler probability / slowdown / batch"),
        move |cfg| {
            cfg.num_workers = n;
            cfg.backend = BackendKind::NativeMlp;
            cfg.model = "mlp_small".into();
            cfg.iid = iid;
            if budget_mode {
                cfg.max_iterations = u64::MAX / 2;
                cfg.time_budget = Some(25.0);
            } else {
                cfg.max_iterations = iters;
            }
            cfg.eval_every = 25;
            cfg.seed = 5000;
        },
    )
    .axis(Axis::tiered(
        "param",
        ablation_params(&[0.2], &[20.0], &[32]),
        ablation_params(&[0.05, 0.2, 0.4], &[5.0, 20.0, 40.0], &[16, 32, 64]),
        ablation_params(&[0.05, 0.1, 0.2, 0.4], &[5.0, 10.0, 20.0, 40.0], &[32, 64, 128, 256]),
    ))
    .axis(alg_axis(&AlgorithmKind::paper_table()))
    // `fixedk` is the legacy routing flag of the retired bench_ablation binary
    .consumes(&["iid", "budget", "fixedk"])
    .table(TableSpec::pivot("", "param", "algorithm", metric, Fmt::Pct, 1.0)))
}

fn fixedk_values(ks: &[usize]) -> Vec<AxisValue> {
    let mut out: Vec<AxisValue> = ks
        .iter()
        .map(|&k| {
            AxisValue::new(format!("Fixed-k={k}"), move |cfg: &mut ExperimentConfig| {
                cfg.algorithm = AlgorithmKind::FixedK { k }
            })
        })
        .collect();
    out.push(AxisValue::new("DSGD-AAU (adaptive)", |cfg: &mut ExperimentConfig| {
        cfg.algorithm = AlgorithmKind::DsgdAau
    }));
    out
}

/// Design-choice ablation (DESIGN.md §5): DSGD-AAU's adaptive group
/// sizing vs the manually-tuned fixed-fastest-k prior art, under a fixed
/// virtual-time budget with stragglers.
pub fn fixedk(args: &BenchArgs) -> Result<SweepSpec> {
    let tier = args.tier()?;
    let n = tier.pick(8usize, 32, 64);
    let budget = tier.pick(6.0, 25.0, 25.0);
    Ok(SweepSpec::new(
        "fixedk",
        &format!(
            "Adaptivity ablation — fixed-k vs DSGD-AAU \
             ({budget:.0}s budget, 10% stragglers, N={n})"
        ),
        move |cfg| {
            cfg.num_workers = n;
            cfg.backend = BackendKind::NativeMlp;
            cfg.model = "mlp_small".into();
            cfg.max_iterations = u64::MAX / 2;
            cfg.time_budget = Some(budget);
            cfg.eval_every = 25;
            cfg.seed = 5000;
        },
    )
    .axis(Axis::tiered(
        "rule",
        fixedk_values(&[2, 4]),
        fixedk_values(&[2, 4, 8, 16]),
        fixedk_values(&[2, 4, 8, 16, 32]),
    ))
    // `fixedk` is the legacy routing flag of the retired bench_ablation binary
    .consumes(&["fixedk"])
    .table(TableSpec::long(
        "",
        vec![
            Column::new("acc@budget", "final_accuracy", Fmt::Pct),
            Column::new("iters", "iterations", Fmt::Int),
            Column::new("mean_group", "mean_group_size", Fmt::F1),
        ],
    )))
}
