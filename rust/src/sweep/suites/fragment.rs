//! Sharded-gossip suite: communication to a target accuracy with the
//! full-vector exchange versus fragmented exchanges under stragglers
//! and link churn, as a [`SweepSpec`] declaration.

use super::alg_axis;
use crate::algorithms::AlgorithmKind;
use crate::churn::{ChurnConfig, ChurnKind};
use crate::config::{BackendKind, ExperimentConfig};
use crate::fragment::{FragmentConfig, ShardSchedule, WireEncoding};
use crate::sim::{StragglerKind, StragglerModel};
use crate::sweep::cli::BenchArgs;
use crate::sweep::spec::{Axis, AxisValue, Column, Fmt, SweepSpec, TableSpec};
use crate::topology::TopologyKind;
use anyhow::Result;

fn fragmented(count: usize, schedule: ShardSchedule, encoding: WireEncoding) -> FragmentConfig {
    FragmentConfig { count, schedule, encoding, seed: None }
}

/// The exchange axis: the passthrough full-vector baseline against
/// fragmented wires at increasing aggressiveness.
fn exchange_values() -> Vec<AxisValue> {
    vec![
        AxisValue::new("full", |_cfg: &mut ExperimentConfig| {}),
        AxisValue::new("k4/stalest", |cfg: &mut ExperimentConfig| {
            cfg.fragments = fragmented(4, ShardSchedule::StalestFirst, WireEncoding::F32)
        }),
        AxisValue::new("k4/stalest+f16", |cfg: &mut ExperimentConfig| {
            cfg.fragments = fragmented(4, ShardSchedule::StalestFirst, WireEncoding::F16)
        }),
        AxisValue::new("k8/rr+f16", |cfg: &mut ExperimentConfig| {
            cfg.fragments = fragmented(8, ShardSchedule::RoundRobin, WireEncoding::F16)
        }),
    ]
}

/// Sharded gossip: MB to a target accuracy for the full-vector exchange
/// vs fragmented exchanges, under a bursty straggler process plus flaky
/// links (`--target=A` overrides the accuracy threshold).
pub fn fragment(args: &BenchArgs) -> Result<SweepSpec> {
    let tier = args.tier()?;
    let target: f32 = args.extra.get("target").and_then(|v| v.parse().ok()).unwrap_or(0.4);
    let budget = tier.pick(30.0, 150.0, 400.0);
    let n = tier.pick(8usize, 16, 32);
    Ok(SweepSpec::new(
        "fragment",
        &format!(
            "Sharded gossip — MB to {:.0}% accuracy, full vs fragmented exchange \
             ({n} workers, stragglers + flaky links)",
            100.0 * target
        ),
        move |cfg| {
            cfg.backend = BackendKind::NativeMlp;
            cfg.model = "mlp_small".into();
            cfg.num_workers = n;
            cfg.topology = TopologyKind::Random { p: 0.3, seed: 11 };
            cfg.max_iterations = u64::MAX / 2;
            cfg.time_budget = Some(budget);
            cfg.eval_every = 20;
            cfg.seed = 8200;
            cfg.straggler = StragglerModel {
                kind: StragglerKind::GilbertElliott { mean_fast: 0.4, mean_slow: 0.1 },
                seed: Some(5),
                ..StragglerModel::default()
            };
            cfg.churn = ChurnConfig {
                kind: ChurnKind::FlakyLinks { rate: 0.5, mean_downtime: 1.0 },
                seed: None,
            };
        },
    )
    .axis(Axis::list("exchange", exchange_values()))
    .axis(alg_axis(&[AlgorithmKind::DsgdAau, AlgorithmKind::AdPsgd, AlgorithmKind::Agp]))
    .consumes(&["target"])
    .target_accuracy(target)
    .table(TableSpec::long(
        "",
        vec![
            Column::new("MB@target", "mb_to_target", Fmt::F1),
            Column::new("acc", "best_accuracy", Fmt::Pct),
            Column::new("MB total", "total_bytes", Fmt::Sci2),
            Column::new("saved", "shard_bytes_saved", Fmt::Sci2),
            Column::new("staleness", "shard_staleness", Fmt::Int),
            Column::new("vtime(s)", "virtual_time", Fmt::F2),
        ],
    ))
    .table(TableSpec::pivot("communication", "exchange", "algorithm", "mb_to_target", Fmt::F1, 1.0))
    .notes(
        "Reading: `full` is the passthrough wire (bit-identical to the \
         pre-fragmentation engine); the fragmented rows move one shard per \
         gossip so each round costs 1/k of the full exchange (half that \
         again under f16), trading staleness for bytes. `MB@target` falls \
         back to total traffic when the target was never reached, so compare \
         it alongside `acc`; `saved` counts parameter bytes withheld versus \
         a full exchange with the same message count.",
    ))
}
