//! The `showdown` suite: DSGD-AAU's adaptive waiting against its two
//! strongest asynchronous adversaries — Hop-style bounded-staleness
//! scheduling ([`crate::stale`], `hop_bss`) and AD-PSGD — under every
//! straggler process the simulator offers (i.i.d. Bernoulli,
//! Gilbert–Elliott persistent slow states, Weibull bursts, and a Google
//! Borg machine-event replay) crossed with static / flaky-link /
//! partition-heal topologies.  The pivots report time and communication
//! to a fixed accuracy target, the head-to-head the ROADMAP asks for.

use super::alg_axis;
use crate::algorithms::AlgorithmKind;
use crate::churn::{ChurnConfig, ChurnKind, TopologyMutation};
use crate::config::{BackendKind, ExperimentConfig};
use crate::sim::straggler::StragglerEvent;
use crate::sim::{StragglerKind, StragglerModel, StragglerTimeline};
use crate::sweep::cli::BenchArgs;
use crate::sweep::spec::{Axis, AxisValue, Column, Fmt, SweepSpec, TableSpec};
use crate::topology::TopologyKind;
use crate::trace::{MapPolicy, TraceConfig, TraceIngest, TraceKind};
use anyhow::Result;

const STRAGGLER_SEED: u64 = 5;
const BORG_EXCERPT: &str = "rust/testdata/traces/borg_machine_events.csv";

/// Straggler-process axis: every synthetic process plus the Borg replay
/// (materialized to `borg_path` by the setup hook).
fn process_values(borg_path: String) -> Vec<AxisValue> {
    vec![
        AxisValue::new("bernoulli", |cfg: &mut ExperimentConfig| {
            cfg.straggler = StragglerModel::default()
        }),
        AxisValue::new("gilbert_elliott", |cfg: &mut ExperimentConfig| {
            cfg.straggler = StragglerModel {
                kind: StragglerKind::GilbertElliott { mean_fast: 0.4, mean_slow: 0.1 },
                seed: Some(STRAGGLER_SEED),
                ..StragglerModel::default()
            }
        }),
        AxisValue::new("weibull", |cfg: &mut ExperimentConfig| {
            cfg.straggler = StragglerModel {
                kind: StragglerKind::WeibullBursts { shape: 0.7, scale: 0.4, mean_burst: 0.1 },
                seed: Some(STRAGGLER_SEED),
                ..StragglerModel::default()
            }
        }),
        AxisValue::new("borg", move |cfg: &mut ExperimentConfig| {
            cfg.straggler = StragglerModel {
                kind: StragglerKind::Trace { path: borg_path.clone() },
                ..StragglerModel::default()
            }
        }),
    ]
}

fn scenario_values(flaky: bool, partition: bool) -> Vec<AxisValue> {
    let mut out = vec![AxisValue::new("static", |_cfg: &mut ExperimentConfig| {})];
    if flaky {
        out.push(AxisValue::new("flaky", |cfg: &mut ExperimentConfig| {
            cfg.churn = ChurnConfig {
                kind: ChurnKind::FlakyLinks { rate: 0.5, mean_downtime: 1.0 },
                seed: None,
            }
        }));
    }
    if partition {
        out.push(AxisValue::new("partition/heal", |cfg: &mut ExperimentConfig| {
            cfg.churn = ChurnConfig {
                kind: ChurnKind::PartitionHeal { period: 4.0, downtime: 1.5 },
                seed: None,
            }
        }));
    }
    out
}

/// Lower the bundled Borg machine-event excerpt into a straggler trace.
/// Borg machine events carry only ADD/REMOVE, so a machine's downtime is
/// reinterpreted as an extreme-straggler window: `Isolate` enters the
/// slow state, `Attach` recovers (on top of any utilization-driven flips
/// the lowering already produced).
fn materialize_borg_stragglers(n: usize, horizon: f64, out: &std::path::Path) -> Result<()> {
    let ingest = TraceIngest::load(&TraceConfig {
        kind: TraceKind::Borg,
        path: BORG_EXCERPT.into(),
        map: MapPolicy::RoundRobin,
        horizon,
        ..TraceConfig::default()
    })?;
    let initial = TopologyKind::Random { p: 0.3, seed: 11 }.build(n);
    let lowered = ingest.lower(n, &initial)?;
    let mut flips: Vec<(f64, StragglerEvent)> = Vec::new();
    for entry in &lowered.straggler.entries {
        for ev in &entry.events {
            flips.push((entry.time, *ev));
        }
    }
    for entry in &lowered.topology.entries {
        for m in &entry.mutations {
            match m {
                TopologyMutation::Isolate(w) => {
                    flips.push((entry.time, StragglerEvent { worker: *w, slow: true }))
                }
                TopologyMutation::Attach(w, _) => {
                    flips.push((entry.time, StragglerEvent { worker: *w, slow: false }))
                }
                _ => {}
            }
        }
    }
    flips.sort_by(|a, b| {
        a.0.partial_cmp(&b.0)
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.1.worker.cmp(&b.1.worker))
    });
    let mut tl = StragglerTimeline::new();
    for (t, ev) in flips {
        tl.push(t, vec![ev]);
    }
    tl.save(out)?;
    Ok(())
}

/// Head-to-head: DSGD-AAU vs Hop-BSS vs AD-PSGD across straggler
/// processes and topology scenarios, pivoted on time and MB to a target
/// accuracy (`--target=A` overrides the threshold).
pub fn showdown(args: &BenchArgs) -> Result<SweepSpec> {
    let tier = args.tier()?;
    let target: f32 = args.extra.get("target").and_then(|v| v.parse().ok()).unwrap_or(0.4);
    let n = tier.pick(8usize, 16, 32);
    let budget = tier.pick(30.0, 150.0, 400.0);
    let borg_path = args.out_dir.join("showdown_borg_straggler.json");
    let borg_setup = borg_path.clone();
    Ok(SweepSpec::new(
        "showdown",
        &format!(
            "Straggler showdown — DSGD-AAU vs Hop-BSS vs AD-PSGD, time/MB to \
             {:.0}% accuracy ({n} workers, every straggler process)",
            100.0 * target
        ),
        move |cfg| {
            cfg.backend = BackendKind::NativeMlp;
            cfg.model = "mlp_small".into();
            cfg.num_workers = n;
            cfg.topology = TopologyKind::Random { p: 0.3, seed: 11 };
            cfg.max_iterations = u64::MAX / 2;
            cfg.time_budget = Some(budget);
            cfg.eval_every = 20;
            cfg.seed = 13000;
        },
    )
    .setup(move |_args: &BenchArgs| materialize_borg_stragglers(n, budget, &borg_setup))
    .axis(Axis::list("process", process_values(borg_path.display().to_string())))
    .axis(Axis::tiered(
        "scenario",
        scenario_values(false, false),
        scenario_values(true, false),
        scenario_values(true, true),
    ))
    .axis(alg_axis(&[AlgorithmKind::DsgdAau, AlgorithmKind::HopBss, AlgorithmKind::AdPsgd]))
    .consumes(&["target"])
    .target_accuracy(target)
    .table(TableSpec::long(
        "",
        vec![
            Column::new("t@target", "time_to_target", Fmt::F2),
            Column::new("MB@target", "mb_to_target", Fmt::F1),
            Column::new("acc", "best_accuracy", Fmt::Pct),
            Column::new("skips", "stale_skips", Fmt::Int),
            Column::new("backups", "backup_activations", Fmt::Int),
            Column::new("block(s)", "queue_block_time", Fmt::F2),
            Column::new("maxstale", "max_observed_staleness", Fmt::Int),
            Column::new("vtime(s)", "virtual_time", Fmt::F2),
        ],
    ))
    .table(TableSpec::pivot(
        "time to target",
        "process",
        "algorithm",
        "time_to_target",
        Fmt::F2,
        1.0,
    ))
    .table(TableSpec::pivot("MB to target", "process", "algorithm", "mb_to_target", Fmt::F1, 1.0))
    .notes(
        "Reading: the paper's claim is that adaptive waiting (DSGD-AAU) \
         beats both full asynchrony (AD-PSGD) and bounded-staleness \
         scheduling (Hop-BSS) under correlated stragglers.  The pivots \
         aggregate mean±std over the scenario axis; `t@target` is null \
         when a cell never reached the accuracy target.  The borg rows \
         replay the bundled machine-event excerpt with machine downtime \
         reinterpreted as extreme-straggler windows (ADD/REMOVE are the \
         only Borg machine events); the Hop-BSS columns also report its \
         policy counters — skipped iterations, backup activations, and \
         virtual seconds parked on full token queues.  Run from the \
         repository root so the bundled excerpt resolves.",
    ))
}
