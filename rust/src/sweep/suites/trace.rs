//! The `trace` suite: bundled real-cluster excerpts (Google Borg machine
//! events, Alibaba machine usage, the generic fallback CSV) ingested by
//! `crate::trace` and replayed against every algorithm — real correlated
//! stragglers × real machine churn, with and without partition-aware
//! adaptivity.

use super::alg_axis;
use crate::adapt::AdaptConfig;
use crate::algorithms::AlgorithmKind;
use crate::config::{BackendKind, ExperimentConfig};
use crate::sweep::cli::BenchArgs;
use crate::sweep::spec::{Axis, AxisValue, Column, Fmt, SweepSpec, TableSpec};
use crate::topology::TopologyKind;
use crate::trace::{MapPolicy, TraceConfig, TraceKind};
use anyhow::Result;

/// Bundled excerpt paths, relative to the repository root (where CI and
/// `cargo run` execute).
const BORG_EXCERPT: &str = "rust/testdata/traces/borg_machine_events.csv";
const ALIBABA_EXCERPT: &str = "rust/testdata/traces/alibaba_machine_usage.csv";
const GENERIC_EXCERPT: &str = "rust/testdata/traces/generic_cluster.csv";

fn source_value(label: &str, kind: TraceKind, path: &str, horizon: f64) -> AxisValue {
    let path = path.to_string();
    AxisValue::new(label, move |cfg: &mut ExperimentConfig| {
        cfg.trace = Some(TraceConfig {
            kind,
            path: path.clone(),
            map: MapPolicy::RoundRobin,
            horizon,
            ..TraceConfig::default()
        });
    })
}

fn mode_value(label: &str, adapt: AdaptConfig) -> AxisValue {
    AxisValue::new(label, move |cfg: &mut ExperimentConfig| cfg.adapt = adapt.clone())
}

/// Real-cluster trace grid: each bundled excerpt ingested through the
/// `trace` pipeline and replayed against every algorithm.
pub fn trace(args: &BenchArgs) -> Result<SweepSpec> {
    let tier = args.tier()?;
    let n = tier.pick(8usize, 12, 16);
    let horizon = tier.pick(4.0, 12.0, 30.0);
    let borg = || source_value("borg", TraceKind::Borg, BORG_EXCERPT, horizon);
    let alibaba = || source_value("alibaba", TraceKind::Alibaba, ALIBABA_EXCERPT, horizon);
    let generic = || source_value("generic", TraceKind::Generic, GENERIC_EXCERPT, horizon);
    let repair = || mode_value("repair", AdaptConfig::default());
    let blind = || {
        mode_value("blind", AdaptConfig { allow_partitions: true, ..AdaptConfig::default() })
    };
    let aware = || {
        mode_value(
            "aware",
            AdaptConfig {
                allow_partitions: true,
                partition_aware: true,
                detection_latency: 0.1.into(),
                heal_restart: true,
            },
        )
    };
    Ok(SweepSpec::new(
        "trace",
        &format!(
            "Real-cluster trace replay — {n} workers, quadratic workload, \
             {horizon}s virtual horizon per excerpt"
        ),
        move |cfg| {
            cfg.num_workers = n;
            cfg.backend = BackendKind::Quadratic;
            cfg.topology = TopologyKind::Random { p: 0.3, seed: 11 };
            cfg.mean_compute = 0.01;
            cfg.seed = 11000;
            cfg.max_iterations = u64::MAX / 2;
            cfg.time_budget = Some(horizon);
            cfg.eval_every = 200;
        },
    )
    .axis(Axis::tiered(
        "source",
        vec![borg(), alibaba()],
        vec![borg(), alibaba(), generic()],
        vec![borg(), alibaba(), generic()],
    ))
    .axis(Axis::tiered(
        "mode",
        vec![repair()],
        vec![repair(), aware()],
        vec![repair(), blind(), aware()],
    ))
    .axis(alg_axis(&AlgorithmKind::all()))
    .table(TableSpec::long(
        "",
        vec![
            Column::new("iters", "iterations", Fmt::Int),
            Column::new("vtime(s)", "virtual_time", Fmt::F2),
            Column::new("loss", "final_loss", Fmt::F4),
            Column::new("strag%", "straggler_pct", Fmt::F1),
            Column::new("changes", "topology_changes", Fmt::Int),
            Column::new("applied", "mutations_applied", Fmt::Int),
            Column::new("splits", "partition_splits", Fmt::Int),
            Column::new("stalls", "stall_fallbacks", Fmt::Int),
        ],
    ))
    .notes(
        "Reading: every row replays a real machine-event log — Borg rows \
         exercise machine churn (REMOVE/ADD -> isolate/attach), Alibaba \
         rows exercise utilization-driven slow states, the generic rows \
         mix both.  In `repair` mode the connectivity assumption is \
         preserved (the last bridge defers); `aware` lets the machine \
         losses genuinely partition the fleet and retargets every rule to \
         its component.  Run from the repository root so the bundled \
         rust/testdata/traces/ excerpts resolve.",
    ))
}
