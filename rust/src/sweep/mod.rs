//! Declarative sweep & results API — the single experiment-driving layer
//! behind every `bench` suite.
//!
//! A [`SweepSpec`] declares named [`Axis`] values (algorithm, workers,
//! straggler process, churn scenario, adapt mode, seeds, or arbitrary
//! [`crate::config::ExperimentConfig`] patches) with cross-product and
//! [`Axis::zip`] combinators and built-in `--quick`/`--full` tier
//! scaling.  The executor ([`run_suite`]) lowers the spec onto the
//! panic-contained parallel sweep
//! ([`crate::coordinator::run_sweep_with_threads`]), streams each
//! finished cell to pluggable [`ResultSink`]s (aligned tables, CSV, and
//! a canonical machine-readable `BENCH_<suite>.json` per suite),
//! computes the shared derived metrics once (`time_to_target`,
//! `mb_to_target`, `speedup` vs a baseline cell), and supports
//! deterministic `--resume` by skipping cells whose config hash already
//! exists in the output JSON.  A failed cell becomes an `err` record and
//! renders as `err`/`n/a` — it never aborts the sweep.
//!
//! ## Suite reference
//!
//! Every paper table/figure is one registered suite of the `bench`
//! multiplexer binary (`bench list` prints the same mapping):
//!
//! ```text
//! paper artifact            invocation            notes
//! ------------------------  --------------------  --------------------------------
//! Tables 1/8 (Table 10)     bench accuracy        --iid=1 for Table 10
//! Tables 2/9 (Table 11)     bench timebudget      --iid=1 for Table 11
//! Figures 3-4               bench loss_curves     also writes per-cell curve CSVs
//! Figure 5(a)+(b)           bench speedup         --target=0.45 sets the accuracy
//! Figures 9-12              bench ablation        --iid=1 / --budget=1 pick the fig
//! DESIGN.md §5 ablation     bench fixedk          fixed-k vs adaptive group sizing
//! churn grid (ROADMAP)      bench churn           scenario x algorithm
//! joint grid (ROADMAP)      bench straggler       process x churn x algorithm
//! partition grid (ROADMAP)  bench partition       repair/blind/aware x algorithm
//! trace grid (ROADMAP)      bench trace           real-cluster excerpt x algorithm
//! open-world (ROADMAP)      bench membership      population x fleet x sampling
//! ```
//!
//! `bench engine` is not a sweep: it micro-benches the event loop
//! (events/sec, peak RSS vs fleet size) into `BENCH_engine.json` and
//! `--check` gates the numbers against the committed baseline.
//!
//! `bench all --quick` runs every suite's smoke grid (the CI perf
//! trajectory); `--resume` re-runs only the missing cells and produces
//! byte-identical artifacts to a cold run.

pub mod bench_engine;
pub mod cli;
mod exec;
mod record;
mod sink;
mod spec;
pub mod suites;
pub mod table;

pub use exec::{default_sinks, json_path, run_suite, run_suite_with_sinks, SuiteRun};
pub use record::{attach_speedup, RunRecord};
pub use sink::{JsonSink, ProgressSink, ResultSink, SinkCtx, TableSink, SCHEMA};
pub use spec::{
    config_hash, Axis, AxisValue, Cell, Column, Fmt, Patch, SweepSpec, TableShape, TableSpec,
    Targets, Tier,
};
