//! The sweep executor: lowers a [`SweepSpec`] onto the panic-contained
//! parallel sweep, streams finished cells to the sinks, computes shared
//! derived metrics once, and resumes deterministically by skipping cells
//! whose config hash already exists in `BENCH_<suite>.json`.

use crate::config::ExperimentConfig;
use crate::coordinator::{self, lock_ok};
use crate::sweep::cli::BenchArgs;
use crate::sweep::record::{attach_speedup, RunRecord};
use crate::sweep::sink::{JsonSink, ProgressSink, ResultSink, SinkCtx, TableSink};
use crate::sweep::spec::SweepSpec;
use crate::util::json::Json;
use anyhow::{Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

/// Outcome of one suite execution.
pub struct SuiteRun {
    /// Every cell's record in deterministic cell order.
    pub records: Vec<RunRecord>,
    /// Cells actually executed this invocation.
    pub ran: usize,
    /// Cells skipped via `--resume`.
    pub skipped: usize,
    /// Path of the machine-readable summary.
    pub json_path: PathBuf,
}

/// Canonical summary path: `<out_dir>/BENCH_<suite>.json`.
pub fn json_path(out_dir: &Path, suite: &str) -> PathBuf {
    out_dir.join(format!("BENCH_{suite}.json"))
}

/// The standard sink stack: progress lines, aligned tables + CSVs, and
/// the `BENCH_<suite>.json` summary.
pub fn default_sinks(spec: &SweepSpec, args: &BenchArgs) -> Vec<Box<dyn ResultSink>> {
    vec![
        Box::new(ProgressSink::for_suite(&spec.suite)),
        Box::new(TableSink),
        Box::new(JsonSink::at(json_path(&args.out_dir, &spec.suite))),
    ]
}

/// Run a suite with the standard sinks.
pub fn run_suite(spec: &SweepSpec, args: &BenchArgs) -> Result<SuiteRun> {
    run_suite_with_sinks(spec, args, default_sinks(spec, args))
}

/// Run a suite with a custom sink stack.
pub fn run_suite_with_sinks(
    spec: &SweepSpec,
    args: &BenchArgs,
    sinks: Vec<Box<dyn ResultSink>>,
) -> Result<SuiteRun> {
    spec.run_setup(args)?;
    let tier = args.tier()?;
    let cells = spec.lower(args)?;
    let path = json_path(&args.out_dir, &spec.suite);

    let prior = if args.resume && path.exists() {
        load_prior(&path).with_context(|| format!("resume from {}", path.display()))?
    } else {
        BTreeMap::new()
    };
    let mut slots: Vec<Option<RunRecord>> = Vec::with_capacity(cells.len());
    let mut to_run: Vec<usize> = Vec::new();
    for (i, cell) in cells.iter().enumerate() {
        match prior.get(&cell.hash) {
            Some(row) => slots.push(Some(RunRecord::from_json(cell, row)?)),
            None => {
                slots.push(None);
                to_run.push(i);
            }
        }
    }
    let skipped = cells.len() - to_run.len();
    if skipped > 0 {
        println!("[bench {}] resume: skipping {skipped} completed cell(s)", spec.suite);
    }

    let configs: Vec<ExperimentConfig> = to_run.iter().map(|&i| cells[i].cfg.clone()).collect();
    let threads = args
        .threads
        .unwrap_or_else(|| std::thread::available_parallelism().map(|p| p.get()).unwrap_or(4));
    let targets = spec.targets;
    let curve_csvs = spec.curve_csvs;
    let suite = spec.suite.clone();
    let out_dir = args.out_dir.clone();
    let slots_m = Mutex::new(slots);
    let sinks_m = Mutex::new(sinks);
    coordinator::run_sweep_streaming(configs, threads, |j, _cfg, res| {
        let i = to_run[j];
        let cell = &cells[i];
        let rec = match res {
            Ok(s) => {
                if curve_csvs {
                    let p = out_dir.join(curve_csv_name(&suite, &cell.labels));
                    if let Err(e) = s.recorder.write_csv(&p) {
                        eprintln!("[bench {suite}] curve csv {}: {e}", p.display());
                    }
                }
                RunRecord::from_summary(cell, targets, s)
            }
            Err(e) => RunRecord::from_error(cell, &format!("{e}")),
        };
        {
            let mut sinks = lock_ok(&sinks_m);
            for s in sinks.iter_mut() {
                if let Err(e) = s.on_record(&rec) {
                    eprintln!("[bench {suite}] sink error: {e}");
                }
            }
        }
        lock_ok(&slots_m)[i] = Some(rec);
    });

    let slots = into_inner_ok(slots_m);
    let mut sinks = into_inner_ok(sinks_m);
    let mut records: Vec<RunRecord> = Vec::with_capacity(cells.len());
    for (i, slot) in slots.into_iter().enumerate() {
        records.push(slot.ok_or_else(|| anyhow::anyhow!("cell {i} produced no record"))?);
    }
    if let Some((axis, baseline)) = &spec.speedup_baseline {
        attach_speedup(&mut records, axis, baseline);
    }
    let ctx = SinkCtx { spec, tier, out_dir: &args.out_dir };
    for s in sinks.iter_mut() {
        s.finish(&ctx, &records)?;
    }
    Ok(SuiteRun { records, ran: to_run.len(), skipped, json_path: path })
}

fn into_inner_ok<T>(m: Mutex<T>) -> T {
    m.into_inner().unwrap_or_else(|e| e.into_inner())
}

fn curve_csv_name(suite: &str, labels: &[(String, String)]) -> String {
    fn sanitize(s: &str) -> String {
        s.chars().map(|c| if c.is_ascii_alphanumeric() { c } else { '_' }).collect()
    }
    let parts: Vec<String> = labels.iter().map(|(_, v)| sanitize(v)).collect();
    format!("{suite}_curve_{}.csv", parts.join("_"))
}

/// Index a prior `BENCH_<suite>.json` by config hash for `--resume`.
/// Only `status: "ok"` rows count as completed — a cell that previously
/// failed (panic, transient error) is re-run rather than pinned to `err`
/// forever.  Deterministic failures re-fail identically, so resumed
/// output stays byte-identical to a cold run either way.
fn load_prior(path: &Path) -> Result<BTreeMap<String, Json>> {
    let text = std::fs::read_to_string(path)?;
    let j = Json::parse(&text)?;
    let rows = j.req("rows")?.as_arr().context("rows must be an array")?;
    let mut out = BTreeMap::new();
    for row in rows {
        if row.get("status").and_then(Json::as_str) != Some("ok") {
            continue;
        }
        let h = row.req("config_hash")?.as_str().context("config_hash must be a string")?;
        out.insert(h.to_string(), row.clone());
    }
    Ok(out)
}
