//! Pluggable result sinks: each finished cell streams to every sink, and
//! `finish` renders the suite artifacts — aligned tables, CSVs and the
//! canonical machine-readable `BENCH_<suite>.json`.

use crate::sweep::record::RunRecord;
use crate::sweep::spec::{Column, Fmt, SweepSpec, TableShape, TableSpec, Tier};
use crate::sweep::table::{pm, Table};
use crate::util::json::Json;
use anyhow::Result;
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// Schema tag of `BENCH_<suite>.json` documents.
pub const SCHEMA: &str = "dsgd-aau/bench/v1";

/// Context handed to [`ResultSink::finish`].
pub struct SinkCtx<'a> {
    /// The suite's spec (tables, notes, titles).
    pub spec: &'a SweepSpec,
    /// Grid tier the sweep ran at.
    pub tier: Tier,
    /// Output directory for CSV/JSON artifacts.
    pub out_dir: &'a Path,
}

/// A consumer of sweep results.
pub trait ResultSink: Send {
    /// Called once per freshly finished cell, from the worker thread
    /// that ran it (resumed cells are not re-streamed).
    fn on_record(&mut self, record: &RunRecord) -> Result<()> {
        let _ = record;
        Ok(())
    }

    /// Called once after the whole sweep with every record (resumed and
    /// fresh) in deterministic cell order, derived metrics attached.
    fn finish(&mut self, ctx: &SinkCtx<'_>, records: &[RunRecord]) -> Result<()>;
}

/// Streams one progress line per finished cell.
pub struct ProgressSink {
    prefix: String,
}

impl ProgressSink {
    /// Progress lines tagged `[bench <suite>]`.
    pub fn for_suite(suite: &str) -> Self {
        ProgressSink { prefix: format!("[bench {suite}]") }
    }
}

impl ResultSink for ProgressSink {
    fn on_record(&mut self, record: &RunRecord) -> Result<()> {
        let labels: Vec<String> =
            record.labels.iter().map(|(n, v)| format!("{n}={v}")).collect();
        match &record.error {
            None => println!("{} done {}", self.prefix, labels.join(" ")),
            Some(e) => println!("{} FAILED {} ({e})", self.prefix, labels.join(" ")),
        }
        Ok(())
    }

    fn finish(&mut self, _ctx: &SinkCtx<'_>, _records: &[RunRecord]) -> Result<()> {
        Ok(())
    }
}

/// Renders the spec's tables to stdout and writes their CSVs.
pub struct TableSink;

impl ResultSink for TableSink {
    fn finish(&mut self, ctx: &SinkCtx<'_>, records: &[RunRecord]) -> Result<()> {
        println!("\n{}\n", ctx.spec.title);
        for ts in &ctx.spec.tables {
            let table = render_table(ts, records);
            print!("{}", table.render());
            let csv_name = if ts.name.is_empty() {
                ctx.spec.suite.clone()
            } else {
                format!("{}_{}", ctx.spec.suite, ts.name)
            };
            let path = table.write_csv(ctx.out_dir, &csv_name)?;
            println!("wrote {}\n", path.display());
        }
        if let Some(notes) = &ctx.spec.notes {
            println!("{notes}");
        }
        Ok(())
    }
}

/// Writes the canonical machine-readable `BENCH_<suite>.json`.
pub struct JsonSink {
    path: PathBuf,
}

impl JsonSink {
    /// Sink writing to `path`.
    pub fn at(path: PathBuf) -> Self {
        JsonSink { path }
    }
}

impl ResultSink for JsonSink {
    fn finish(&mut self, ctx: &SinkCtx<'_>, records: &[RunRecord]) -> Result<()> {
        let mut root: BTreeMap<String, Json> = BTreeMap::new();
        root.insert("schema".into(), Json::from(SCHEMA));
        root.insert("bench".into(), Json::from(ctx.spec.suite.as_str()));
        root.insert("tier".into(), Json::from(ctx.tier.token()));
        root.insert("rows".into(), Json::Arr(records.iter().map(|r| r.to_json()).collect()));
        if let Some(dir) = self.path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)?;
            }
        }
        std::fs::write(&self.path, Json::Obj(root).to_string_compact())?;
        println!("wrote {}", self.path.display());
        Ok(())
    }
}

/// Render one table spec over the records.
pub fn render_table(ts: &TableSpec, records: &[RunRecord]) -> Table {
    match &ts.shape {
        TableShape::Long(columns) => render_long(columns, records),
        TableShape::Pivot { row_axis, col_axis, metric, fmt, scale } => {
            render_pivot(row_axis, col_axis, metric, *fmt, *scale, records)
        }
    }
}

fn fmt_opt(fmt: Fmt, v: Option<f64>, scale: f64) -> String {
    match v {
        Some(v) if v.is_finite() => fmt.format(v * scale),
        _ => "n/a".into(),
    }
}

fn render_long(columns: &[Column], records: &[RunRecord]) -> Table {
    let mut headers: Vec<String> = records
        .first()
        .map(|r| r.labels.iter().map(|(n, _)| n.clone()).collect())
        .unwrap_or_default();
    headers.extend(columns.iter().map(|c| c.header.clone()));
    let mut t = Table::from_headers(headers);
    for r in records {
        let mut row: Vec<String> = r.labels.iter().map(|(_, v)| v.clone()).collect();
        for c in columns {
            if r.is_ok() {
                row.push(fmt_opt(c.fmt, r.metric_f64(&c.metric), 1.0));
            } else {
                row.push("err".into());
            }
        }
        t.row(row);
    }
    t
}

fn render_pivot(
    row_axis: &str,
    col_axis: &str,
    metric: &str,
    fmt: Fmt,
    scale: f64,
    records: &[RunRecord],
) -> Table {
    let mut row_labels: Vec<String> = Vec::new();
    let mut col_labels: Vec<String> = Vec::new();
    let mut buckets: BTreeMap<(String, String), Vec<&RunRecord>> = BTreeMap::new();
    for r in records {
        let (Some(rl), Some(cl)) = (r.label(row_axis), r.label(col_axis)) else { continue };
        if !row_labels.iter().any(|l| l == rl) {
            row_labels.push(rl.to_string());
        }
        if !col_labels.iter().any(|l| l == cl) {
            col_labels.push(cl.to_string());
        }
        buckets.entry((rl.to_string(), cl.to_string())).or_default().push(r);
    }
    let mut headers = vec![row_axis.to_string()];
    headers.extend(col_labels.iter().cloned());
    let mut t = Table::from_headers(headers);
    for rl in &row_labels {
        let mut row = vec![rl.clone()];
        for cl in &col_labels {
            let cell = match buckets.get(&(rl.clone(), cl.clone())) {
                None => String::new(),
                Some(recs) => pivot_cell(recs, metric, fmt, scale),
            };
            row.push(cell);
        }
        t.row(row);
    }
    t
}

fn pivot_cell(recs: &[&RunRecord], metric: &str, fmt: Fmt, scale: f64) -> String {
    if recs.iter().any(|r| !r.is_ok()) {
        return "err".into();
    }
    let mut vals = Vec::with_capacity(recs.len());
    for r in recs {
        match r.metric_f64(metric) {
            Some(v) if v.is_finite() => vals.push(v),
            _ => return "n/a".into(),
        }
    }
    match vals.len() {
        0 => "n/a".into(),
        1 => fmt.format(vals[0] * scale),
        _ => {
            let (m, s) = crate::coordinator::mean_std(&vals);
            pm(m * scale, s * scale)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sweep::spec::TableSpec;

    fn rec(scn: &str, alg: &str, loss: f64, acc: Option<f64>) -> RunRecord {
        let mut metrics = BTreeMap::new();
        metrics.insert("final_loss".into(), Json::Num(loss));
        metrics.insert(
            "best_accuracy".into(),
            acc.map(Json::Num).unwrap_or(Json::Null),
        );
        RunRecord {
            labels: vec![("scenario".into(), scn.into()), ("algorithm".into(), alg.into())],
            config_hash: format!("{scn}/{alg}"),
            error: None,
            metrics,
        }
    }

    #[test]
    fn long_table_renders_labels_metrics_and_err_cells() {
        let mut records = vec![rec("a", "AGP", 0.5, Some(0.4)), rec("b", "AGP", 0.25, None)];
        records.push(RunRecord {
            labels: vec![("scenario".into(), "c".into()), ("algorithm".into(), "AGP".into())],
            config_hash: "c/AGP".into(),
            error: Some("boom".into()),
            metrics: BTreeMap::new(),
        });
        let ts = TableSpec::long(
            "",
            vec![
                Column::new("loss", "final_loss", Fmt::F4),
                Column::new("acc", "best_accuracy", Fmt::Pct),
            ],
        );
        let t = render_table(&ts, &records);
        assert_eq!(t.headers, vec!["scenario", "algorithm", "loss", "acc"]);
        assert_eq!(t.rows[0], vec!["a", "AGP", "0.5000", "40.00%"]);
        assert_eq!(t.rows[1][3], "n/a", "null metric renders n/a");
        assert_eq!(t.rows[2][2], "err", "failed cell renders err, sweep continues");
    }

    #[test]
    fn pivot_aggregates_mean_std_over_extra_axes() {
        let mut records = Vec::new();
        for (seed, loss) in [("0", 1.0), ("1", 3.0)] {
            let mut r = rec("a", "AGP", loss, None);
            r.labels.push(("seed".into(), seed.into()));
            r.config_hash = format!("a/AGP/{seed}");
            records.push(r);
        }
        let mut single = rec("a", "Prague", 0.125, None);
        single.labels.push(("seed".into(), "0".into()));
        records.push(single);
        let ts = TableSpec::pivot("", "scenario", "algorithm", "final_loss", Fmt::F4, 1.0);
        let t = render_table(&ts, &records);
        assert_eq!(t.headers, vec!["scenario", "AGP", "Prague"]);
        assert_eq!(t.rows[0][1], "2.00 ± 1.00", "multi-record bucket uses mean ± std");
        assert_eq!(t.rows[0][2], "0.1250", "singleton bucket uses the column format");
    }
}
