//! Bench CLI: shared flag parsing (the old `harness::BenchArgs`, grown
//! `--resume`/`--threads`), the suite registry mapping every paper
//! table/figure to its [`SweepSpec`], and the entry point behind the
//! `bench` multiplexer binary.

use crate::config::ExperimentConfig;
use crate::sweep::exec::{run_suite, SuiteRun};
use crate::sweep::spec::{SweepSpec, Tier};
use crate::sweep::suites;
use anyhow::{bail, ensure, Context, Result};
use std::collections::BTreeMap;
use std::path::PathBuf;

const USAGE: &str = "\
bench — declarative sweep driver for the paper's tables and figures

USAGE:
  bench <suite> [OPTIONS]   run one suite from its SweepSpec declaration
  bench all [OPTIONS]       run every suite (CI runs `bench all --quick`)
  bench list                list the suites and their paper mapping
  bench engine [OPTIONS]    engine micro-bench (events/sec, peak RSS) ->
                            BENCH_engine.json; --check gates against the
                            committed baseline with --tolerance (default 0.6)

OPTIONS:
  --quick          smallest grid still covering every axis (CI smoke tier)
  --full           paper-scale grid
  --seeds K        seeds per cell where the suite declares a seed axis
  --out DIR        output directory (default results/)
  --backend B      backend override (pjrt|native_mlp|quadratic)
  --resume         skip cells already recorded in BENCH_<suite>.json
  --threads T      sweep worker threads (default: available parallelism)
  --key=value      extra overrides: suite-specific (e.g. --iid=1) or any
                   ExperimentConfig key (e.g. --num_workers=64)
";

/// Common bench flags.
#[derive(Debug, Clone)]
pub struct BenchArgs {
    /// Paper-scale run (`--full`).
    pub full: bool,
    /// Smoke-grid run (`--quick`): the smallest sweep that still covers
    /// every axis — what CI runs to keep the perf trajectory populated.
    pub quick: bool,
    /// Seeds per table cell (suites opting into a seed axis).
    pub seeds: u64,
    /// Output directory for CSV/JSON artifacts.
    pub out_dir: PathBuf,
    /// Backend override (`native_mlp` default; `pjrt` exercises artifacts).
    pub backend: Option<String>,
    /// Skip cells whose config hash already exists in the suite JSON.
    pub resume: bool,
    /// Explicit sweep thread count (default: available parallelism).
    pub threads: Option<usize>,
    /// Extra `key=value` overrides.
    pub extra: BTreeMap<String, String>,
}

impl Default for BenchArgs {
    fn default() -> Self {
        BenchArgs {
            full: false,
            quick: false,
            seeds: 3,
            out_dir: PathBuf::from("results"),
            backend: None,
            resume: false,
            threads: None,
            extra: BTreeMap::new(),
        }
    }
}

impl BenchArgs {
    /// Parse `std::env::args().skip(1)`.
    pub fn parse() -> Result<Self> {
        Self::parse_from(std::env::args().skip(1).collect())
    }

    /// Parse an explicit argument list (exercised directly by tests).
    pub fn parse_from(args: Vec<String>) -> Result<Self> {
        let mut out = BenchArgs::default();
        let mut it = args.into_iter();
        while let Some(a) = it.next() {
            match a.as_str() {
                "--full" => out.full = true,
                "--quick" => out.quick = true,
                "--resume" => out.resume = true,
                "--seeds" => {
                    out.seeds = it.next().context("--seeds value")?.parse()?;
                }
                "--threads" => {
                    out.threads = Some(it.next().context("--threads value")?.parse()?);
                }
                "--out" => out.out_dir = it.next().context("--out value")?.into(),
                "--backend" => out.backend = Some(it.next().context("--backend value")?),
                other => {
                    if let Some((k, v)) = other.strip_prefix("--").and_then(|s| s.split_once('='))
                    {
                        out.extra.insert(k.to_string(), v.to_string());
                    } else {
                        bail!(
                            "unknown flag {other} (--full --quick --resume --seeds K \
                             --out DIR --backend B --threads T --k=v)"
                        );
                    }
                }
            }
        }
        Ok(out)
    }

    /// Grid tier selected by the flags.
    pub fn tier(&self) -> Result<Tier> {
        ensure!(!(self.quick && self.full), "--quick and --full are mutually exclusive");
        Ok(if self.quick {
            Tier::Quick
        } else if self.full {
            Tier::Full
        } else {
            Tier::Default
        })
    }

    /// Apply the backend override to a config.
    pub fn apply(&self, cfg: &mut ExperimentConfig) -> Result<()> {
        if let Some(b) = &self.backend {
            cfg.backend = crate::config::BackendKind::parse(b)?;
        }
        Ok(())
    }
}

/// A registered bench suite.
pub struct Suite {
    /// `bench <name>`.
    pub name: &'static str,
    /// Which paper table/figure the suite regenerates.
    pub paper: &'static str,
    /// One-line description.
    pub summary: &'static str,
    /// Build the spec for the given flags.
    pub build: fn(&BenchArgs) -> Result<SweepSpec>,
}

/// The thirteen suites, in paper order.
pub fn registry() -> Vec<Suite> {
    vec![
        Suite {
            name: "accuracy",
            paper: "Tables 1/8/10",
            summary: "final accuracy per model variant (non-IID; --iid=1)",
            build: suites::accuracy,
        },
        Suite {
            name: "timebudget",
            paper: "Tables 2/9/11",
            summary: "accuracy after a fixed virtual-time budget, per N",
            build: suites::timebudget,
        },
        Suite {
            name: "loss_curves",
            paper: "Figures 3-4",
            summary: "loss vs iteration and vs wall-clock, per algorithm",
            build: suites::loss_curves,
        },
        Suite {
            name: "speedup",
            paper: "Figure 5",
            summary: "speedup over sync DSGD and communication to target",
            build: suites::speedup,
        },
        Suite {
            name: "ablation",
            paper: "Figures 9-12",
            summary: "straggler probability/slowdown/batch ablations",
            build: suites::ablation,
        },
        Suite {
            name: "fixedk",
            paper: "DESIGN.md ablation",
            summary: "adaptive group sizing vs fixed-fastest-k",
            build: suites::fixedk,
        },
        Suite {
            name: "churn",
            paper: "ROADMAP churn grid",
            summary: "dynamic-topology scenarios vs every algorithm",
            build: suites::churn,
        },
        Suite {
            name: "straggler",
            paper: "ROADMAP joint grid",
            summary: "straggler process x churn x algorithm",
            build: suites::straggler,
        },
        Suite {
            name: "partition",
            paper: "ROADMAP partition grid",
            summary: "repair/blind/aware partition handling per algorithm",
            build: suites::partition,
        },
        Suite {
            name: "trace",
            paper: "ROADMAP trace import",
            summary: "real-cluster excerpts (Borg/Alibaba/generic) x algorithm",
            build: suites::trace,
        },
        Suite {
            name: "membership",
            paper: "ROADMAP open-world grid",
            summary: "sampled participation over 1e5-1e6 logical users",
            build: suites::membership,
        },
        Suite {
            name: "fragment",
            paper: "ROADMAP sharded gossip",
            summary: "MB to target accuracy: full vs fragmented exchange",
            build: suites::fragment,
        },
        Suite {
            name: "showdown",
            paper: "ROADMAP Hop head-to-head",
            summary: "DSGD-AAU vs Hop-BSS vs AD-PSGD x straggler process",
            build: suites::showdown,
        },
    ]
}

/// Look up a suite by name.
pub fn find_suite(name: &str) -> Option<Suite> {
    registry().into_iter().find(|s| s.name == name)
}

/// Build and run one registered suite.
pub fn run_named(name: &str, args: &BenchArgs) -> Result<SuiteRun> {
    let suite = find_suite(name).ok_or_else(|| {
        let names: Vec<&str> = registry().iter().map(|s| s.name).collect();
        anyhow::anyhow!("unknown suite {name:?} (try: {})", names.join(", "))
    })?;
    let spec = (suite.build)(args)?;
    run_suite(&spec, args)
}

/// Entry point of the `bench` multiplexer binary.
pub fn bench_main() -> Result<()> {
    let mut argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() {
        print!("{USAGE}");
        return Ok(());
    }
    let cmd = argv.remove(0);
    match cmd.as_str() {
        "-h" | "--help" | "help" => {
            print!("{USAGE}");
            Ok(())
        }
        "list" => {
            let mut t = crate::sweep::table::Table::new(&["suite", "paper", "summary"]);
            for s in registry() {
                t.row(vec![s.name.to_string(), s.paper.to_string(), s.summary.to_string()]);
            }
            print!("{}", t.render());
            Ok(())
        }
        "all" => {
            let args = BenchArgs::parse_from(argv)?;
            let mut failed: Vec<&'static str> = Vec::new();
            for s in registry() {
                println!("\n=== bench {} ===", s.name);
                if let Err(e) = run_named(s.name, &args) {
                    eprintln!("[bench {}] FAILED: {e:#}", s.name);
                    failed.push(s.name);
                }
            }
            ensure!(failed.is_empty(), "suites failed: {}", failed.join(", "));
            Ok(())
        }
        "engine" => {
            let args = BenchArgs::parse_from(argv)?;
            crate::sweep::bench_engine::run(&args)
        }
        name => {
            let args = BenchArgs::parse_from(argv)?;
            run_named(name, &args).map(|_| ())
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_has_thirteen_unique_suites() {
        let names: Vec<&str> = registry().iter().map(|s| s.name).collect();
        assert_eq!(names.len(), 13);
        let set: std::collections::BTreeSet<&str> = names.iter().copied().collect();
        assert_eq!(set.len(), names.len(), "suite names must be unique");
        assert!(find_suite("partition").is_some());
        assert!(find_suite("trace").is_some());
        assert!(find_suite("membership").is_some());
        assert!(find_suite("fragment").is_some());
        assert!(find_suite("showdown").is_some());
        assert!(find_suite("nope").is_none());
    }

    #[test]
    fn every_suite_builds_and_lowers_at_every_tier() {
        for quick in [true, false] {
            let mut args = BenchArgs::default();
            args.quick = quick;
            args.seeds = 2;
            for s in registry() {
                let spec = (s.build)(&args).unwrap_or_else(|e| panic!("{}: {e}", s.name));
                assert_eq!(spec.suite, s.name);
                let cells = spec.lower(&args).unwrap_or_else(|e| panic!("{}: {e}", s.name));
                assert!(!cells.is_empty(), "{} lowers to an empty grid", s.name);
                for c in &cells {
                    c.cfg.validate().unwrap_or_else(|e| panic!("{}: {}: {e}", s.name, c.cfg.name));
                }
            }
        }
    }
}
