//! `bench engine` — the canonical engine micro-bench behind
//! `BENCH_engine.json`: event-queue throughput (schedule/pop ops per
//! wall-clock second), end-to-end engine runs (events/sec, peak RSS)
//! across fleet sizes, and the compute micro-bench (params/sec for
//! `NativeMlpBackend::fwd_bwd` across `MlpShape` variants, blocked vs
//! scalar-reference).  `--check` gates the measured numbers against the
//! committed baseline (`rust/testdata/perf/BENCH_engine.json`) with a
//! multiplicative `--tolerance` (default 0.6: a run may be up to 40 %
//! slower / proportionally larger than the baseline before CI fails —
//! wide on purpose, shared runners are noisy).  The compute rows also
//! carry a `min_speedup` gate on the *in-run* blocked-vs-scalar ratio,
//! which is machine-independent and therefore ungoverned by the
//! tolerance.
//!
//! `--full` adds the large-cell profile rows (n ∈ {1e3, 1e4}, native
//! MLP backend, `compute_threads = 0`) that exercise the parallel
//! intra-cell stepping path end to end.

use crate::backend::{Backend, MlpShape, NativeMlpBackend};
use crate::config::{BackendKind, ExperimentConfig};
use crate::coordinator::run_experiment;
use crate::sim::{EventKind, EventQueue};
use crate::sweep::cli::BenchArgs;
use crate::util::json::Json;
use anyhow::{Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::time::Instant;

/// Default committed baseline location (repo-relative).
pub const BASELINE_PATH: &str = "rust/testdata/perf/BENCH_engine.json";

/// One end-to-end measurement row.
#[derive(Debug, Clone, Copy)]
struct E2eRow {
    n: usize,
    events_per_sec: f64,
    peak_rss_kb: Option<u64>,
}

/// Peak resident set (VmHWM) in kB — Linux only, `None` elsewhere.
fn peak_rss_kb() -> Option<u64> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    let line = status.lines().find(|l| l.starts_with("VmHWM:"))?;
    line.split_whitespace().nth(1)?.parse().ok()
}

/// Raw queue throughput: schedule+pop `ops` interleaved events through a
/// warm heap, returning operations (schedule or pop) per second.
fn bench_queue(ops: usize) -> f64 {
    let mut q = EventQueue::new();
    // keep a standing population so pops exercise a non-trivial heap
    for w in 0..1024usize {
        q.schedule(w as f64 * 0.001, EventKind::ComputeDone(w));
    }
    let start = Instant::now();
    let mut done = 0usize;
    while done < ops {
        let ev = q.pop().expect("standing population never drains");
        if let EventKind::ComputeDone(w) = ev.kind {
            q.schedule_in(1.0 + (w % 7) as f64 * 0.1, EventKind::ComputeDone(w));
        }
        done += 2; // one pop + one schedule
    }
    done as f64 / start.elapsed().as_secs_f64().max(1e-9)
}

/// End-to-end engine throughput at fleet size `n` (DSGD-AAU, quadratic
/// backend): processed events per wall-clock second, approximated as two
/// events (start + done) per local gradient step.
fn bench_e2e(n: usize, iters: u64) -> Result<E2eRow> {
    let mut cfg = ExperimentConfig::default();
    cfg.name = format!("bench_engine_n{n}");
    cfg.num_workers = n;
    cfg.backend = BackendKind::Quadratic;
    cfg.topology = crate::topology::TopologyKind::Random { p: 0.3, seed: 11 };
    cfg.mean_compute = 0.01;
    cfg.max_iterations = iters;
    cfg.eval_every = iters.max(1);
    cfg.seed = 12000;
    let start = Instant::now();
    let s = run_experiment(&cfg)?;
    let elapsed = start.elapsed().as_secs_f64().max(1e-9);
    Ok(E2eRow {
        n,
        events_per_sec: 2.0 * s.recorder.local_steps as f64 / elapsed,
        peak_rss_kb: peak_rss_kb(),
    })
}

/// End-to-end engine throughput at large fleet size `n` (`--full` only):
/// DSGD-AAU over a ring with the native MLP backend and auto intra-cell
/// threading, so the parallel stepping path is what's being profiled.
/// Ungated — no committed floors yet at these sizes.
fn bench_e2e_large(n: usize, iters: u64) -> Result<E2eRow> {
    let mut cfg = ExperimentConfig::default();
    cfg.name = format!("bench_engine_large_n{n}");
    cfg.num_workers = n;
    cfg.backend = BackendKind::NativeMlp;
    cfg.model = "mlp_tiny".into();
    cfg.dataset_samples = (2 * n).max(4096);
    cfg.compute_threads = 0; // auto: size to the machine
    cfg.topology = crate::topology::TopologyKind::Ring;
    cfg.mean_compute = 0.01;
    cfg.max_iterations = iters;
    cfg.eval_every = iters.max(1);
    cfg.seed = 12000;
    let start = Instant::now();
    let s = run_experiment(&cfg)?;
    let elapsed = start.elapsed().as_secs_f64().max(1e-9);
    Ok(E2eRow {
        n,
        events_per_sec: 2.0 * s.recorder.local_steps as f64 / elapsed,
        peak_rss_kb: peak_rss_kb(),
    })
}

/// One compute micro-bench row: fwd_bwd throughput in parameters/second
/// (flat model dim × calls / elapsed) on the blocked kernel path and the
/// retained scalar reference, plus their ratio.
#[derive(Debug, Clone)]
struct ComputeRow {
    shape: String,
    params_per_sec: f64,
    scalar_params_per_sec: f64,
    speedup: f64,
}

/// Time repeated calls of `step` for ~`budget` wall-clock seconds and
/// return parameters/second (`dim` per call).
fn fwd_bwd_throughput(dim: usize, budget: f64, mut step: impl FnMut() -> f32) -> f64 {
    let mut sink = step(); // warm-up call, also keeps the work observable
    let start = Instant::now();
    let mut calls = 0u64;
    while start.elapsed().as_secs_f64() < budget {
        sink += step();
        calls += 1;
    }
    let elapsed = start.elapsed().as_secs_f64().max(1e-9);
    assert!(sink.is_finite(), "fwd_bwd produced a non-finite loss");
    calls as f64 * dim as f64 / elapsed
}

/// Measure one `MlpShape` variant: same backend, same params, same fixed
/// batch (gathered via the dataset accessor, shard RNGs untouched) driven
/// through `fwd_bwd` and `fwd_bwd_reference`.
fn bench_compute(shape_name: &str, budget: f64) -> Result<ComputeRow> {
    let shape =
        MlpShape::by_name(shape_name).with_context(|| format!("unknown shape {shape_name}"))?;
    let dim = shape.dim();
    let batch = shape.batch;
    let backend = NativeMlpBackend::new(shape, 1, 1024.max(4 * batch), 3.0, true, 5, 9);
    let params = backend.init_params(9);
    let idx: Vec<usize> = (0..batch).collect();
    let (x, y) = backend.dataset().gather(&idx);
    let blocked = fwd_bwd_throughput(dim, budget, || backend.fwd_bwd(&params, &x, &y).0);
    let scalar =
        fwd_bwd_throughput(dim, budget, || backend.fwd_bwd_reference(&params, &x, &y).0);
    Ok(ComputeRow {
        shape: shape_name.to_string(),
        params_per_sec: blocked,
        scalar_params_per_sec: scalar,
        speedup: blocked / scalar.max(1e-9),
    })
}

fn row_json(r: &E2eRow) -> Json {
    let mut m: BTreeMap<String, Json> = BTreeMap::new();
    m.insert("n".into(), Json::from(r.n));
    m.insert("events_per_sec".into(), Json::Num(r.events_per_sec));
    match r.peak_rss_kb {
        Some(kb) => m.insert("peak_rss_kb".into(), Json::from(kb as usize)),
        None => m.insert("peak_rss_kb".into(), Json::Null),
    };
    Json::Obj(m)
}

fn compute_row_json(r: &ComputeRow) -> Json {
    let mut m: BTreeMap<String, Json> = BTreeMap::new();
    m.insert("shape".into(), Json::from(r.shape.as_str()));
    m.insert("params_per_sec".into(), Json::Num(r.params_per_sec));
    m.insert("scalar_params_per_sec".into(), Json::Num(r.scalar_params_per_sec));
    m.insert("speedup".into(), Json::Num(r.speedup));
    Json::Obj(m)
}

/// Gate one measured value against a baseline floor: `measured >=
/// tolerance * baseline` for throughput, `measured <= baseline /
/// tolerance` for sizes.
fn gate(
    failures: &mut Vec<String>,
    what: &str,
    measured: f64,
    baseline: f64,
    tolerance: f64,
    larger_is_better: bool,
) {
    let ok = if larger_is_better {
        measured >= tolerance * baseline
    } else {
        measured <= baseline / tolerance
    };
    if !ok {
        failures.push(format!(
            "{what}: measured {measured:.0} vs baseline {baseline:.0} (tolerance {tolerance})"
        ));
    }
}

fn check_against_baseline(
    baseline_path: &Path,
    queue_ops: f64,
    rows: &[E2eRow],
    compute_rows: &[ComputeRow],
    tolerance: f64,
) -> Result<()> {
    let text = std::fs::read_to_string(baseline_path)
        .with_context(|| format!("read baseline {}", baseline_path.display()))?;
    let base = Json::parse(&text)?;
    let failures = baseline_failures(&base, queue_ops, rows, compute_rows, tolerance)?;
    anyhow::ensure!(
        failures.is_empty(),
        "engine bench regressed past the baseline gate:\n  {}",
        failures.join("\n  ")
    );
    println!("[bench engine] baseline gate passed (tolerance {tolerance})");
    Ok(())
}

/// The gate proper, over a parsed baseline (separated so tests can feed
/// synthetic measurements through it without touching the filesystem).
fn baseline_failures(
    base: &Json,
    queue_ops: f64,
    rows: &[E2eRow],
    compute_rows: &[ComputeRow],
    tolerance: f64,
) -> Result<Vec<String>> {
    let mut failures = Vec::new();
    if let Some(b) = base.req("queue")?.req("ops_per_sec")?.as_f64() {
        gate(&mut failures, "queue ops/sec", queue_ops, b, tolerance, true);
    }
    // compute rows: a throughput floor under the usual tolerance, plus a
    // tolerance-free minimum on the in-run blocked-vs-scalar speedup
    // (same machine, same build — the ratio is what the blocked-kernel
    // rewrite promises, so it gets no noise allowance)
    let base_compute: &[Json] =
        base.get("compute").and_then(Json::as_arr).unwrap_or(&[]);
    for r in compute_rows {
        let Some(b) = base_compute
            .iter()
            .find(|bc| bc.get("shape").and_then(Json::as_str) == Some(r.shape.as_str()))
        else {
            continue; // shape not in the committed baseline — ungated
        };
        if let Some(floor) = b.get("params_per_sec").and_then(Json::as_f64) {
            gate(
                &mut failures,
                &format!("compute {} params/sec", r.shape),
                r.params_per_sec,
                floor,
                tolerance,
                true,
            );
        }
        if let Some(min) = b.get("min_speedup").and_then(Json::as_f64) {
            if r.speedup < min {
                failures.push(format!(
                    "compute {}: blocked/scalar speedup {:.2}x below required {min}x",
                    r.shape, r.speedup
                ));
            }
        }
    }
    let base_rows: &[Json] = base.req("e2e")?.as_arr().unwrap_or(&[]);
    for r in rows {
        let Some(b) = base_rows.iter().find(|br| {
            br.get("n").and_then(Json::as_usize) == Some(r.n)
        }) else {
            continue; // fleet size not in the committed baseline — ungated
        };
        if let Some(eps) = b.get("events_per_sec").and_then(Json::as_f64) {
            gate(
                &mut failures,
                &format!("e2e n={} events/sec", r.n),
                r.events_per_sec,
                eps,
                tolerance,
                true,
            );
        }
        if let (Some(kb), Some(bkb)) =
            (r.peak_rss_kb, b.get("peak_rss_kb").and_then(Json::as_f64))
        {
            gate(
                &mut failures,
                &format!("e2e n={} peak RSS kB", r.n),
                kb as f64,
                bkb,
                tolerance,
                false,
            );
        }
    }
    Ok(failures)
}

/// Entry point of `bench engine`.
pub fn run(args: &BenchArgs) -> Result<()> {
    let quick = args.quick;
    let ns: &[usize] = if quick { &[8, 32] } else { &[8, 32, 128] };
    let queue_ops = bench_queue(if quick { 400_000 } else { 2_000_000 });
    println!("[bench engine] queue: {queue_ops:.0} ops/sec");
    let iters = if quick { 400 } else { 2000 };
    let mut rows = Vec::new();
    for &n in ns {
        let row = bench_e2e(n, iters)?;
        println!(
            "[bench engine] e2e n={}: {:.0} events/sec, peak RSS {} kB",
            n,
            row.events_per_sec,
            row.peak_rss_kb.map_or("n/a".into(), |kb| kb.to_string()),
        );
        rows.push(row);
    }
    if !quick {
        // large-cell profile: the parallel intra-cell stepping path
        for &(n, iters) in &[(1_000usize, 100u64), (10_000, 20)] {
            let row = bench_e2e_large(n, iters)?;
            println!(
                "[bench engine] e2e-large n={}: {:.0} events/sec, peak RSS {} kB",
                n,
                row.events_per_sec,
                row.peak_rss_kb.map_or("n/a".into(), |kb| kb.to_string()),
            );
            rows.push(row);
        }
    }
    let shapes: &[&str] = if quick {
        &["mlp_tiny", "mlp_small"]
    } else {
        &["mlp_tiny", "mlp_small", "mlp2nn", "mlp_small@b1"]
    };
    let budget = if quick { 0.2 } else { 0.5 };
    let mut compute_rows = Vec::new();
    for shape in shapes {
        let row = bench_compute(shape, budget)?;
        println!(
            "[bench engine] compute {}: {:.3e} params/sec blocked, {:.3e} scalar ({:.2}x)",
            row.shape, row.params_per_sec, row.scalar_params_per_sec, row.speedup,
        );
        compute_rows.push(row);
    }

    let mut m: BTreeMap<String, Json> = BTreeMap::new();
    m.insert("schema".into(), Json::from("bench-engine-v2"));
    let mut qm: BTreeMap<String, Json> = BTreeMap::new();
    qm.insert("ops_per_sec".into(), Json::Num(queue_ops));
    m.insert("queue".into(), Json::Obj(qm));
    m.insert("e2e".into(), Json::Arr(rows.iter().map(row_json).collect()));
    m.insert("compute".into(), Json::Arr(compute_rows.iter().map(compute_row_json).collect()));
    let out = Json::Obj(m);
    std::fs::create_dir_all(&args.out_dir)?;
    let out_path = crate::sweep::json_path(&args.out_dir, "engine");
    std::fs::write(&out_path, out.to_string_compact())
        .with_context(|| format!("write {}", out_path.display()))?;
    println!("[bench engine] wrote {}", out_path.display());

    if args.extra.get("check").map(|v| v == "1").unwrap_or(false) {
        let tolerance: f64 = match args.extra.get("tolerance") {
            Some(t) => t.parse().context("--tolerance must be a number")?,
            None => 0.6,
        };
        let baseline = args
            .extra
            .get("baseline")
            .map(PathBuf::from)
            .unwrap_or_else(|| PathBuf::from(BASELINE_PATH));
        check_against_baseline(&baseline, queue_ops, &rows, &compute_rows, tolerance)?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn queue_bench_measures_something() {
        assert!(bench_queue(10_000) > 0.0);
    }

    #[test]
    fn gate_directions() {
        let mut f = Vec::new();
        gate(&mut f, "thr", 100.0, 100.0, 0.6, true);
        gate(&mut f, "rss", 100.0, 100.0, 0.6, false);
        assert!(f.is_empty());
        gate(&mut f, "thr", 50.0, 100.0, 0.6, true);
        assert_eq!(f.len(), 1, "40% floor breached");
        gate(&mut f, "rss", 200.0, 100.0, 0.6, false);
        assert_eq!(f.len(), 2, "size ceiling breached");
    }

    #[test]
    fn baseline_file_parses_and_gates_loosely() {
        // the committed baseline must stay parseable and conservative
        // enough that a quick in-test measurement passes it (compute rows
        // are left out here: speedup ratios are meaningless in unoptimized
        // test builds — the speedup gate is exercised synthetically below
        // and for real by the release-built CI bench run)
        let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join(BASELINE_PATH);
        let text = std::fs::read_to_string(&path).expect("committed baseline exists");
        let base = Json::parse(&text).expect("baseline parses");
        assert_eq!(base.req("schema").unwrap().as_str(), Some("bench-engine-v2"));
        assert!(
            base.req("compute").unwrap().as_arr().is_some_and(|rows| rows
                .iter()
                .any(|r| r.get("shape").and_then(Json::as_str) == Some("mlp_small")
                    && r.get("min_speedup").and_then(Json::as_f64).is_some_and(|s| s >= 2.0))),
            "baseline must require >= 2x blocked-vs-scalar speedup on mlp_small"
        );
        let queue_ops = bench_queue(20_000);
        let row = bench_e2e(8, 100).unwrap();
        check_against_baseline(&path, queue_ops, &[row], &[], 0.01)
            .expect("ultra-loose tolerance passes the committed floors");
    }

    #[test]
    fn compute_gate_enforces_floor_and_speedup() {
        let base = Json::parse(
            r#"{
                "schema": "bench-engine-v2",
                "queue": {"ops_per_sec": 100.0},
                "e2e": [],
                "compute": [
                    {"shape": "mlp_small", "params_per_sec": 1000.0, "min_speedup": 2.0}
                ]
            }"#,
        )
        .unwrap();
        let row = |pps: f64, speedup: f64| ComputeRow {
            shape: "mlp_small".into(),
            params_per_sec: pps,
            scalar_params_per_sec: pps / speedup,
            speedup,
        };
        // healthy: above floor, above required speedup
        let f = baseline_failures(&base, 100.0, &[], &[row(2000.0, 3.0)], 0.6).unwrap();
        assert!(f.is_empty(), "{f:?}");
        // throughput floor breached (tolerance applies)
        let f = baseline_failures(&base, 100.0, &[], &[row(100.0, 3.0)], 0.6).unwrap();
        assert_eq!(f.len(), 1, "{f:?}");
        assert!(f[0].contains("params/sec"), "{f:?}");
        // speedup gate breached (no tolerance on the ratio)
        let f = baseline_failures(&base, 100.0, &[], &[row(2000.0, 1.5)], 0.6).unwrap();
        assert_eq!(f.len(), 1, "{f:?}");
        assert!(f[0].contains("speedup"), "{f:?}");
        // unknown shapes are ungated
        let mut other = row(1.0, 0.5);
        other.shape = "mlp_tiny".into();
        let f = baseline_failures(&base, 100.0, &[], &[other], 0.6).unwrap();
        assert!(f.is_empty(), "{f:?}");
    }

    #[test]
    fn compute_bench_measures_both_paths() {
        let row = bench_compute("mlp_tiny", 0.02).unwrap();
        assert!(row.params_per_sec > 0.0);
        assert!(row.scalar_params_per_sec > 0.0);
        assert!(row.speedup > 0.0);
    }
}
