//! Canonical per-cell result records: the serializable unit every
//! [`crate::sweep::ResultSink`] consumes and `BENCH_<suite>.json` stores.
//!
//! A [`RunRecord`] is either a metrics map distilled from a
//! [`RunSummary`] (status `ok`) or a contained failure (status `err`) —
//! one failed cell renders as `err`/`n/a` and never aborts the sweep.
//! Records round-trip through JSON byte-identically, which is what makes
//! `--resume` produce output indistinguishable from a cold run.

use crate::engine::RunSummary;
use crate::sweep::spec::{Cell, Targets};
use crate::util::json::Json;
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;

/// One sweep cell's outcome.
#[derive(Debug, Clone)]
pub struct RunRecord {
    /// `(axis name, value label)` in axis order.
    pub labels: Vec<(String, String)>,
    /// The cell's stable config hash (the resume key).
    pub config_hash: String,
    /// `None` for a completed run; the error text otherwise.
    pub error: Option<String>,
    /// Named metrics (`Json::Null` for unreached targets).
    pub metrics: BTreeMap<String, Json>,
}

/// Finite numbers serialize as numbers; NaN/inf (empty curves) as null.
fn num(v: f64) -> Json {
    if v.is_finite() {
        Json::Num(v)
    } else {
        Json::Null
    }
}

fn opt(v: Option<f64>) -> Json {
    match v {
        Some(v) => num(v),
        None => Json::Null,
    }
}

impl RunRecord {
    /// Did the cell complete?
    pub fn is_ok(&self) -> bool {
        self.error.is_none()
    }

    /// Label of a named axis.
    pub fn label(&self, axis: &str) -> Option<&str> {
        self.labels.iter().find(|(n, _)| n == axis).map(|(_, v)| v.as_str())
    }

    /// Numeric metric lookup (`None` for missing/null/err).
    pub fn metric_f64(&self, key: &str) -> Option<f64> {
        self.metrics.get(key).and_then(Json::as_f64)
    }

    /// Distill a completed run into the shared metric set, computing the
    /// derived target metrics once for every suite.
    pub fn from_summary(cell: &Cell, targets: Targets, s: &RunSummary) -> Self {
        let r = &s.recorder;
        let mut m: BTreeMap<String, Json> = BTreeMap::new();
        m.insert("iterations".into(), num(s.iterations as f64));
        m.insert("virtual_time".into(), num(s.virtual_time));
        m.insert("final_loss".into(), num(s.final_loss() as f64));
        m.insert("final_accuracy".into(), num(s.final_accuracy() as f64));
        m.insert("best_accuracy".into(), num(r.best_accuracy() as f64));
        m.insert("consensus_gap".into(), num(s.consensus_gap as f64));
        m.insert("total_bytes".into(), num(r.total_bytes() as f64));
        m.insert("mean_group_size".into(), num(r.mean_group_size()));
        m.insert("straggler_pct".into(), num(100.0 * s.straggler_fraction));
        m.insert("stall_fallbacks".into(), num(r.stall_fallbacks as f64));
        m.insert("epochs_completed".into(), num(s.epochs_completed as f64));
        m.insert("topology_changes".into(), num(r.topology_changes as f64));
        m.insert("mutations_applied".into(), num(r.mutations_applied as f64));
        m.insert("mutations_deferred".into(), num(r.mutations_deferred as f64));
        m.insert("partition_splits".into(), num(r.partition_splits as f64));
        m.insert("partition_merges".into(), num(r.partition_merges as f64));
        m.insert("max_components".into(), num(r.max_components as f64));
        m.insert("component_epochs".into(), num(r.component_epochs as f64));
        m.insert("epoch_restarts".into(), num(r.epoch_restarts as f64));
        m.insert("partitioned_gossips".into(), num(r.partitioned_gossips as f64));
        m.insert("workers_joined".into(), num(r.workers_joined as f64));
        m.insert("workers_left".into(), num(r.workers_left as f64));
        m.insert("rounds_sampled".into(), num(r.rounds_sampled as f64));
        m.insert("prague_regroups".into(), num(r.prague_regroups as f64));
        m.insert("shard_bytes_saved".into(), num(r.shard_bytes_saved as f64));
        m.insert("shard_staleness".into(), num(r.shard_staleness as f64));
        m.insert("stale_skips".into(), num(r.stale_skips as f64));
        m.insert("backup_activations".into(), num(r.backup_activations as f64));
        m.insert("queue_block_time".into(), num(r.queue_block_time));
        m.insert(
            "max_observed_staleness".into(),
            num(r.max_observed_staleness as f64),
        );
        m.insert(
            "mean_observed_staleness".into(),
            num(r.mean_observed_staleness()),
        );
        m.insert("loss_q25".into(), num(r.loss_at_fraction(0.25) as f64));
        m.insert("loss_q50".into(), num(r.loss_at_fraction(0.5) as f64));
        m.insert("loss_q100".into(), num(r.loss_at_fraction(1.0) as f64));
        m.insert(
            "iters_per_vsec".into(),
            num(s.iterations as f64 / s.virtual_time.max(1e-9)),
        );
        if let Some(target) = targets.accuracy {
            m.insert("time_to_target".into(), opt(r.time_to_accuracy(target)));
            // Fig 5b framing: communication *to reach the target*, falling
            // back to total traffic when the target was never hit.
            let bytes = r.bytes_to_accuracy(target).unwrap_or_else(|| r.total_bytes());
            m.insert("mb_to_target".into(), num(bytes as f64 / 1e6));
        }
        if let Some(target) = targets.loss {
            m.insert("time_to_loss_target".into(), opt(r.time_to_loss(target)));
        }
        RunRecord {
            labels: cell.labels.clone(),
            config_hash: cell.hash.clone(),
            error: None,
            metrics: m,
        }
    }

    /// Record a contained per-cell failure.
    pub fn from_error(cell: &Cell, msg: &str) -> Self {
        RunRecord {
            labels: cell.labels.clone(),
            config_hash: cell.hash.clone(),
            error: Some(msg.to_string()),
            metrics: BTreeMap::new(),
        }
    }

    /// Serialize as one `rows[]` entry of `BENCH_<suite>.json`.
    pub fn to_json(&self) -> Json {
        let mut m: BTreeMap<String, Json> = BTreeMap::new();
        m.insert("config_hash".into(), Json::from(self.config_hash.as_str()));
        let mut lm: BTreeMap<String, Json> = BTreeMap::new();
        for (k, v) in &self.labels {
            lm.insert(k.clone(), Json::from(v.as_str()));
        }
        m.insert("labels".into(), Json::Obj(lm));
        match &self.error {
            None => {
                m.insert("status".into(), Json::from("ok"));
            }
            Some(e) => {
                m.insert("status".into(), Json::from("err"));
                m.insert("error".into(), Json::from(e.as_str()));
            }
        }
        m.insert("metrics".into(), Json::Obj(self.metrics.clone()));
        Json::Obj(m)
    }

    /// Rebuild a record from a stored row for the matching `cell`
    /// (labels and hash come from the cell — the hash match is what
    /// paired them up).
    pub fn from_json(cell: &Cell, row: &Json) -> Result<Self> {
        let status = row.req("status")?.as_str().context("status must be a string")?;
        let error = match status {
            "ok" => None,
            "err" => Some(
                row.get("error").and_then(Json::as_str).unwrap_or("unknown error").to_string(),
            ),
            other => bail!("unknown record status {other:?}"),
        };
        let metrics = row
            .req("metrics")?
            .as_obj()
            .context("metrics must be an object")?
            .clone();
        Ok(RunRecord {
            labels: cell.labels.clone(),
            config_hash: cell.hash.clone(),
            error,
            metrics,
        })
    }
}

/// Attach the `speedup` derived metric: for every record, the baseline
/// is the record sharing all labels except `axis`, where it reads
/// `baseline`; `speedup = t_baseline / t_cell` on `time_to_target`.
/// Cells (or baselines) that never reached the target get `null`.
pub fn attach_speedup(records: &mut [RunRecord], axis: &str, baseline: &str) {
    fn group_key(labels: &[(String, String)], axis: &str) -> Vec<(String, String)> {
        labels.iter().filter(|(n, _)| n != axis).cloned().collect()
    }
    let baselines: Vec<(Vec<(String, String)>, Option<f64>)> = records
        .iter()
        .filter(|r| r.label(axis) == Some(baseline))
        .map(|r| (group_key(&r.labels, axis), r.metric_f64("time_to_target")))
        .collect();
    for r in records.iter_mut() {
        let key = group_key(&r.labels, axis);
        let t_base = baselines.iter().find(|(k, _)| *k == key).and_then(|(_, t)| *t);
        let t_cell = r.metric_f64("time_to_target");
        let v = match (t_base, t_cell) {
            (Some(tb), Some(tc)) if tc > 0.0 => Json::Num(tb / tc),
            _ => Json::Null,
        };
        r.metrics.insert("speedup".into(), v);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(alg: &str, n: &str, t: Option<f64>) -> RunRecord {
        let mut metrics = BTreeMap::new();
        metrics.insert("time_to_target".into(), opt(t));
        RunRecord {
            labels: vec![("N".into(), n.into()), ("algorithm".into(), alg.into())],
            config_hash: format!("{alg}-{n}"),
            error: None,
            metrics,
        }
    }

    #[test]
    fn speedup_vs_baseline_per_group() {
        let mut records = vec![
            rec("DSGD", "8", Some(10.0)),
            rec("DSGD-AAU", "8", Some(2.0)),
            rec("DSGD", "16", Some(8.0)),
            rec("DSGD-AAU", "16", None),
        ];
        attach_speedup(&mut records, "algorithm", "DSGD");
        assert_eq!(records[0].metric_f64("speedup"), Some(1.0));
        assert_eq!(records[1].metric_f64("speedup"), Some(5.0));
        assert_eq!(records[2].metric_f64("speedup"), Some(1.0));
        assert_eq!(records[3].metric_f64("speedup"), None, "unreached target stays null");
    }

    #[test]
    fn non_finite_metrics_serialize_as_null() {
        assert_eq!(num(f64::NAN), Json::Null);
        assert_eq!(num(f64::INFINITY), Json::Null);
        assert_eq!(num(1.5), Json::Num(1.5));
    }
}
