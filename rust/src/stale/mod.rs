//! Bounded-staleness scheduling: per-link token queues, skip budgets,
//! and backup-worker bookkeeping (Hop, arxiv 1902.01064).
//!
//! DSGD-AAU waits *adaptively* (the group forms around whoever is ready);
//! Hop never waits on a set at all.  Each worker keeps a local iteration
//! clock, and every **directed** link `u -> v` carries a [`TokenQueue`] of
//! the updates `u` produced that `v` has not yet consumed.  Three policies
//! bound how far clocks may drift apart:
//!
//! * **Staleness bound `s`** — a worker may consume a neighbor's update
//!   only while their iteration lag is at most `s` (in either direction).
//!   Every parameter exchange the [`crate::algorithms::HopBss`] rule
//!   performs is gated on this check, which is the invariant the
//!   randomized suite in `rust/tests/stale.rs` asserts.
//! * **Skip iteration** — a worker whose neighbors have all fallen more
//!   than `s` behind may *skip* the consume step and advance its clock
//!   alone, but only while at least one of its producer queues still has
//!   room (`depth` tokens per link).  Once every outgoing queue is full
//!   the producer **blocks**: its gossip is deferred in virtual time (the
//!   worker parks until the laggard's clock advances), and the stall is
//!   charged to `Recorder::queue_block_time`.
//! * **Backup workers** — the highest-indexed `backups` slots double as
//!   designated backups.  When a straggler's *observed* slow state (no
//!   clock advance for `backup_after` virtual seconds — the same lagged
//!   observed-state idea as [`crate::adapt::PartitionMonitor`], where
//!   ground truth is only visible through delayed local evidence)
//!   persists, a backup clones the straggler's role: the blocked peer
//!   exchanges with the backup instead and the straggler is reseeded from
//!   the backup's parameters, its clock jumping to the donor's.
//!
//! The module owns the strict-parsed `"stale"` config section
//! ([`StaleConfig`]), the per-link queues, the parked-worker table, and
//! the clock arithmetic.  It is engine-agnostic: the `hop_bss` update
//! rule drives it with worker ids and virtual timestamps and performs the
//! actual parameter movement through [`crate::engine::EngineCore`].
//! State lives in `BTreeMap`s and `Vec`s only, keeping iteration order —
//! and therefore the event schedule — deterministic.

use crate::util::json::Json;
use crate::util::Rng64;
use crate::WorkerId;
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;

/// Strict-parsed `"stale"` config section: the bounded-staleness knobs
/// consumed by the `hop_bss` update rule.
///
/// The section is always present (like `"fragments"`); rules other than
/// `hop_bss` ignore it, so the default is inert for every other
/// algorithm.
#[derive(Debug, Clone, PartialEq)]
pub struct StaleConfig {
    /// Per-link staleness bound `s`: an update may be consumed only while
    /// the producer/consumer iteration lag is at most `bound`.
    pub bound: u64,
    /// Token-queue depth per directed link: how many unconsumed updates a
    /// producer may accumulate on one link before it must block.  This is
    /// also the skip budget — a worker may skip ahead only while some
    /// outgoing queue still has room.
    pub depth: u64,
    /// Allow skip-iteration (advance past an out-of-bound neighborhood
    /// while queue room remains).  With `skip = false` the worker blocks
    /// as soon as its neighborhood falls out of bound.
    pub skip: bool,
    /// Allow backup-worker activation.
    pub backup: bool,
    /// Number of designated backup slots (the highest-indexed workers).
    pub backups: usize,
    /// Observed-slow persistence threshold (virtual seconds without a
    /// clock advance) before a backup may clone a straggler's role.
    pub backup_after: f64,
    /// Scheduling-RNG seed override; defaults to `seed_for("stale")`.
    pub seed: Option<u64>,
}

impl Default for StaleConfig {
    fn default() -> Self {
        // Hop's evaluation runs small bounds; s = 4 with a 2-deep queue
        // keeps clocks tight while letting fast workers ride out one
        // Gilbert-Elliott slow period without blocking.
        StaleConfig {
            bound: 4,
            depth: 2,
            skip: true,
            backup: true,
            backups: 1,
            backup_after: 0.25,
            seed: None,
        }
    }
}

impl StaleConfig {
    /// Parse the `"stale"` config section.  Strict: unknown keys are
    /// errors, like every other section.
    pub fn from_json(j: &Json) -> Result<Self> {
        let mut cfg = StaleConfig::default();
        let obj = match j.as_obj() {
            Some(o) => o,
            None => bail!("stale section must be an object"),
        };
        for (k, v) in obj {
            match k.as_str() {
                "bound" => cfg.bound = v.as_u64().context("stale.bound must be an integer")?,
                "depth" => cfg.depth = v.as_u64().context("stale.depth must be an integer")?,
                "skip" => cfg.skip = v.as_bool().context("stale.skip must be a boolean")?,
                "backup" => cfg.backup = v.as_bool().context("stale.backup must be a boolean")?,
                "backups" => {
                    cfg.backups = v.as_usize().context("stale.backups must be an integer")?
                }
                "backup_after" => {
                    cfg.backup_after =
                        v.as_f64().context("stale.backup_after must be a number")?
                }
                "seed" => cfg.seed = Some(v.as_u64().context("stale.seed must be an integer")?),
                other => bail!(
                    "unknown stale key {other:?} (want bound, depth, skip, backup, \
                     backups, backup_after, seed)"
                ),
            }
        }
        cfg.validate()?;
        Ok(cfg)
    }

    /// Serialize back to the config form (inverse of [`Self::from_json`]).
    pub fn to_json(&self) -> Json {
        let mut m: BTreeMap<String, Json> = BTreeMap::new();
        m.insert("bound".into(), Json::from(self.bound as usize));
        m.insert("depth".into(), Json::from(self.depth as usize));
        m.insert("skip".into(), Json::from(self.skip));
        m.insert("backup".into(), Json::from(self.backup));
        m.insert("backups".into(), Json::from(self.backups));
        m.insert("backup_after".into(), Json::from(self.backup_after));
        if let Some(s) = self.seed {
            m.insert("seed".into(), Json::from(s as usize));
        }
        Json::Obj(m)
    }

    /// Range checks shared by strict parsing and config validation.
    pub fn validate(&self) -> Result<()> {
        if self.bound == 0 {
            bail!("stale.bound must be >= 1 (a zero bound forbids every exchange)");
        }
        if self.depth == 0 {
            bail!("stale.depth must be >= 1 (a zero-depth queue blocks immediately)");
        }
        if !(self.backup_after.is_finite() && self.backup_after > 0.0) {
            bail!("stale.backup_after must be a positive number of virtual seconds");
        }
        if self.backup && self.backups == 0 {
            bail!("stale.backups must be >= 1 when backup activation is enabled");
        }
        Ok(())
    }
}

/// One directed link's token queue: updates the producer has published
/// that the consumer has not yet drained.  Occupancy beyond `depth`
/// means the producer ran ahead of this consumer and must stop skipping;
/// a pairwise exchange drains the queue in both directions (the latest
/// state supersedes everything queued behind it).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TokenQueue {
    depth: u64,
    produced: u64,
    consumed: u64,
}

impl TokenQueue {
    /// Empty queue with room for `depth` unconsumed updates.
    pub fn new(depth: u64) -> Self {
        TokenQueue { depth: depth.max(1), produced: 0, consumed: 0 }
    }

    /// The producer published one more update.  Returns `false` when the
    /// queue was already full — the token is still recorded (the clock
    /// did advance), but the producer has exhausted this link's budget.
    pub fn produce(&mut self) -> bool {
        let had_room = !self.is_full();
        self.produced += 1;
        had_room
    }

    /// The consumer caught up to the producer's latest state (a pairwise
    /// exchange delivers the current vector, superseding every queued
    /// update).  Returns how many tokens were retired.
    pub fn drain(&mut self) -> u64 {
        let n = self.occupancy();
        self.consumed = self.produced;
        n
    }

    /// Unconsumed updates currently queued on this link.
    pub fn occupancy(&self) -> u64 {
        self.produced - self.consumed
    }

    /// Whether the producer has used up this link's token budget.
    pub fn is_full(&self) -> bool {
        self.occupancy() >= self.depth
    }
}

/// A worker parked by a full queue: who it waits on and since when.
#[derive(Debug, Clone, Copy, PartialEq)]
struct Parked {
    target: WorkerId,
    since: f64,
}

/// Runtime bounded-staleness state: per-worker iteration clocks, the
/// per-directed-link [`TokenQueue`]s, the parked-worker table, and the
/// observed-slow bookkeeping the backup policy reads.  Owned by
/// [`crate::engine::EngineCore`] and driven by the `hop_bss` rule.
#[derive(Debug, Clone)]
pub struct StaleState {
    cfg: StaleConfig,
    rng: Rng64,
    /// Local iteration clock per slot.
    clock: Vec<u64>,
    /// Virtual time of each slot's last clock advance (observed-slow
    /// evidence for the backup policy).
    last_advance: Vec<f64>,
    /// Token queues per directed link, created on first production.
    queues: BTreeMap<(WorkerId, WorkerId), TokenQueue>,
    /// Waiters per target, in arrival order (deterministic release).
    waiting_on: BTreeMap<WorkerId, Vec<WorkerId>>,
    /// Reverse map: parked worker -> (target, park time).
    parked: BTreeMap<WorkerId, Parked>,
}

impl StaleState {
    /// Fresh state for `n` slots.  `derived_seed` (`seed_for("stale")`)
    /// feeds the scheduling RNG unless the section pins its own seed.
    pub fn new(cfg: &StaleConfig, n: usize, derived_seed: u64) -> Self {
        StaleState {
            cfg: cfg.clone(),
            rng: Rng64::seed_from_u64(cfg.seed.unwrap_or(derived_seed)),
            clock: vec![0; n],
            last_advance: vec![0.0; n],
            queues: BTreeMap::new(),
            waiting_on: BTreeMap::new(),
            parked: BTreeMap::new(),
        }
    }

    /// The configured section (bound, depth, policy switches).
    pub fn config(&self) -> &StaleConfig {
        &self.cfg
    }

    /// Worker `w`'s local iteration clock.
    pub fn clock(&self, w: WorkerId) -> u64 {
        self.clock[w]
    }

    /// Signed iteration lag of `b` behind `a` (positive: `a` is ahead).
    pub fn lag(&self, a: WorkerId, b: WorkerId) -> i64 {
        self.clock[a] as i64 - self.clock[b] as i64
    }

    /// Deterministic partner pick among `k` candidates.
    pub fn pick(&mut self, k: usize) -> usize {
        self.rng.gen_range(k)
    }

    /// Worker `w` completed one local step at `now`: advance its clock
    /// and publish one token into each outgoing queue.
    pub fn advance(&mut self, w: WorkerId, now: f64, neighbors: &[WorkerId]) {
        self.clock[w] += 1;
        self.last_advance[w] = now;
        let depth = self.cfg.depth;
        for &r in neighbors {
            self.queues.entry((w, r)).or_insert_with(|| TokenQueue::new(depth)).produce();
        }
    }

    /// Neighbors whose iteration lag from `w` is within the bound, i.e.
    /// the set `w` may exchange with right now.
    pub fn in_bound(&self, w: WorkerId, neighbors: &[WorkerId]) -> Vec<WorkerId> {
        let s = self.cfg.bound as i64;
        neighbors.iter().copied().filter(|&r| self.lag(w, r).abs() <= s).collect()
    }

    /// Whether every outgoing queue of `w` is full: the skip budget is
    /// exhausted and the producer must block.
    pub fn producers_saturated(&self, w: WorkerId, neighbors: &[WorkerId]) -> bool {
        !neighbors.is_empty()
            && neighbors
                .iter()
                .all(|&r| self.queues.get(&(w, r)).is_some_and(TokenQueue::is_full))
    }

    /// Occupancy of the directed queue `from -> to` (0 if never used).
    pub fn occupancy(&self, from: WorkerId, to: WorkerId) -> u64 {
        self.queues.get(&(from, to)).map_or(0, TokenQueue::occupancy)
    }

    /// Record a pairwise exchange between `a` and `b`: both directed
    /// queues drain (each side consumed the other's latest state) and the
    /// consumed staleness — the absolute iteration lag — is returned for
    /// the recorder.  Callers gate on [`Self::in_bound`] (or check the
    /// lag themselves), so the returned value never exceeds the bound.
    pub fn consume_exchange(&mut self, a: WorkerId, b: WorkerId) -> u64 {
        if let Some(q) = self.queues.get_mut(&(a, b)) {
            q.drain();
        }
        if let Some(q) = self.queues.get_mut(&(b, a)) {
            q.drain();
        }
        self.lag(a, b).unsigned_abs()
    }

    /// Whether `r`'s observed slow state has persisted long enough for a
    /// backup to step in: no clock advance for `backup_after` seconds and
    /// not merely parked on a full queue.
    pub fn observed_slow(&self, r: WorkerId, now: f64) -> bool {
        !self.is_parked(r) && now - self.last_advance[r] >= self.cfg.backup_after
    }

    /// The designated backup slots: the `backups` highest indices
    /// (clamped so at least one regular worker remains).
    pub fn backup_slots(&self) -> Vec<WorkerId> {
        let n = self.clock.len();
        let k = self.cfg.backups.min(n.saturating_sub(1));
        (n - k..n).collect()
    }

    /// Reseed `w` from donor `d`: its clock jumps to the donor's and
    /// every queue touching `w` drains (its outstanding obligations are
    /// considered fulfilled by the reseed).  Used both when a straggler
    /// is cloned by a backup and when a laggard pulls the frontier's
    /// parameters to resynchronize.
    pub fn resync(&mut self, w: WorkerId, d: WorkerId, now: f64) {
        self.clock[w] = self.clock[d];
        self.last_advance[w] = now;
        for (&(a, b), q) in self.queues.iter_mut() {
            if a == w || b == w {
                q.drain();
            }
        }
    }

    /// Park `w` until `target`'s clock advances (the producer's queues
    /// are full).  The stall is accounted when the waiter is released.
    pub fn park(&mut self, w: WorkerId, target: WorkerId, now: f64) {
        self.parked.insert(w, Parked { target, since: now });
        self.waiting_on.entry(target).or_default().push(w);
    }

    /// Whether `w` is currently parked on a full queue.
    pub fn is_parked(&self, w: WorkerId) -> bool {
        self.parked.contains_key(&w)
    }

    /// Release every waiter parked on `target`, returning `(waiter,
    /// seconds waited)` in arrival order.  Callers re-park waiters whose
    /// lag is still out of bound; the accrued wait is returned each time
    /// so block time accumulates without double counting.
    pub fn release(&mut self, target: WorkerId, now: f64) -> Vec<(WorkerId, f64)> {
        let waiters = self.waiting_on.remove(&target).unwrap_or_default();
        let mut out = Vec::with_capacity(waiters.len());
        for w in waiters {
            if let Some(p) = self.parked.remove(&w) {
                out.push((w, now - p.since));
            }
        }
        out
    }

    /// Unpark every waiter everywhere (topology changed: targets may no
    /// longer be reachable).  Returns `(waiter, seconds waited)` in
    /// worker order.
    pub fn release_all(&mut self, now: f64) -> Vec<(WorkerId, f64)> {
        self.waiting_on.clear();
        let parked = std::mem::take(&mut self.parked);
        parked.into_iter().map(|(w, p)| (w, now - p.since)).collect()
    }

    /// Slot `w` left the fleet: forget its parked state and drain its
    /// queues.  Waiters parked **on** `w` are released separately via
    /// [`Self::release`] so their block time is accounted.
    pub fn on_leave(&mut self, w: WorkerId) {
        if let Some(p) = self.parked.remove(&w) {
            if let Some(ws) = self.waiting_on.get_mut(&p.target) {
                ws.retain(|&x| x != w);
            }
        }
        for (&(a, b), q) in self.queues.iter_mut() {
            if a == w || b == w {
                q.drain();
            }
        }
    }

    /// Slot `w` (re)joined at `now`: its clock starts at the fastest
    /// observed neighbor's (the engine warm-starts its parameters from
    /// the same neighborhood, so clock and state stay consistent).
    pub fn on_join(&mut self, w: WorkerId, now: f64, neighbor_clocks: &[u64]) {
        self.clock[w] = neighbor_clocks.iter().copied().max().unwrap_or(0);
        self.last_advance[w] = now;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::Json;

    #[test]
    fn default_section_roundtrips() {
        let cfg = StaleConfig::default();
        cfg.validate().unwrap();
        let back = StaleConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(cfg, back);
    }

    #[test]
    fn section_parses_strictly() {
        let ok = Json::parse(r#"{"bound": 6, "depth": 3, "skip": false, "seed": 9}"#).unwrap();
        let cfg = StaleConfig::from_json(&ok).unwrap();
        assert_eq!(cfg.bound, 6);
        assert_eq!(cfg.depth, 3);
        assert!(!cfg.skip);
        assert_eq!(cfg.seed, Some(9));

        let unknown = Json::parse(r#"{"bond": 6}"#).unwrap();
        assert!(StaleConfig::from_json(&unknown).is_err());
        let zero_bound = Json::parse(r#"{"bound": 0}"#).unwrap();
        assert!(StaleConfig::from_json(&zero_bound).is_err());
        let zero_depth = Json::parse(r#"{"depth": 0}"#).unwrap();
        assert!(StaleConfig::from_json(&zero_depth).is_err());
        let no_backups = Json::parse(r#"{"backup": true, "backups": 0}"#).unwrap();
        assert!(StaleConfig::from_json(&no_backups).is_err());
    }

    #[test]
    fn token_queue_fills_and_drains() {
        let mut q = TokenQueue::new(2);
        assert!(!q.is_full());
        assert!(q.produce());
        assert!(q.produce());
        assert!(q.is_full());
        assert!(!q.produce(), "production past depth reports a full queue");
        assert_eq!(q.occupancy(), 3);
        assert_eq!(q.drain(), 3);
        assert_eq!(q.occupancy(), 0);
        assert!(!q.is_full());
    }

    #[test]
    fn clocks_and_bounds() {
        let cfg = StaleConfig { bound: 2, depth: 1, ..StaleConfig::default() };
        let mut st = StaleState::new(&cfg, 3, 7);
        let nbrs = [1usize, 2];
        for _ in 0..3 {
            st.advance(0, 0.1, &nbrs);
        }
        assert_eq!(st.clock(0), 3);
        assert_eq!(st.lag(0, 1), 3);
        // Neighbor 1 is 3 > bound behind; neighbor 2 likewise.
        assert!(st.in_bound(0, &nbrs).is_empty());
        st.advance(1, 0.2, &[0]);
        assert_eq!(st.in_bound(0, &nbrs), vec![1]);
        // Both outgoing queues of 0 are full at depth 1.
        assert!(st.producers_saturated(0, &nbrs));
        let staleness = st.consume_exchange(0, 1);
        assert_eq!(staleness, 2);
        assert_eq!(st.occupancy(0, 1), 0);
        assert!(!st.producers_saturated(0, &nbrs), "queue 0->2 is still full, 0->1 drained");
    }

    #[test]
    fn park_release_accounts_wait() {
        let mut st = StaleState::new(&StaleConfig::default(), 4, 1);
        st.park(2, 0, 1.0);
        st.park(3, 0, 1.5);
        assert!(st.is_parked(2));
        let released = st.release(0, 2.0);
        assert_eq!(released, vec![(2, 1.0), (3, 0.5)]);
        assert!(!st.is_parked(2));
        assert!(st.release(0, 3.0).is_empty());
    }

    #[test]
    fn resync_jumps_clock_and_drains() {
        let cfg = StaleConfig { bound: 1, depth: 1, ..StaleConfig::default() };
        let mut st = StaleState::new(&cfg, 2, 1);
        for _ in 0..5 {
            st.advance(0, 0.1, &[1]);
        }
        assert_eq!(st.occupancy(0, 1), 5);
        st.resync(1, 0, 0.2);
        assert_eq!(st.clock(1), 5);
        assert_eq!(st.occupancy(0, 1), 0);
    }

    #[test]
    fn backup_slots_are_highest_indices() {
        let cfg = StaleConfig { backups: 2, ..StaleConfig::default() };
        let st = StaleState::new(&cfg, 6, 1);
        assert_eq!(st.backup_slots(), vec![4, 5]);
        // Clamped: never swallow the whole fleet.
        let st1 = StaleState::new(&cfg, 1, 1);
        assert!(st1.backup_slots().is_empty());
    }

    #[test]
    fn observed_slow_needs_persistence() {
        let cfg = StaleConfig { backup_after: 0.5, ..StaleConfig::default() };
        let mut st = StaleState::new(&cfg, 2, 1);
        st.advance(1, 1.0, &[0]);
        assert!(!st.observed_slow(1, 1.2));
        assert!(st.observed_slow(1, 1.6));
        // A parked worker is stalled, not slow.
        st.park(1, 0, 1.6);
        assert!(!st.observed_slow(1, 2.0));
    }

    #[test]
    fn seeding_is_deterministic() {
        let cfg = StaleConfig::default();
        let mut a = StaleState::new(&cfg, 4, 42);
        let mut b = StaleState::new(&cfg, 4, 42);
        let pa: Vec<usize> = (0..16).map(|_| a.pick(5)).collect();
        let pb: Vec<usize> = (0..16).map(|_| b.pick(5)).collect();
        assert_eq!(pa, pb);
        let pinned = StaleConfig { seed: Some(7), ..StaleConfig::default() };
        let mut c = StaleState::new(&pinned, 4, 42);
        let mut d = StaleState::new(&pinned, 4, 99);
        let pc: Vec<usize> = (0..16).map(|_| c.pick(5)).collect();
        let pd: Vec<usize> = (0..16).map(|_| d.pick(5)).collect();
        assert_eq!(pc, pd, "a pinned section seed overrides the derived seed");
    }
}
