//! High-level experiment coordinator: config → backend → engine → summary,
//! plus parallel sweep helpers used by the table/figure harnesses.

use crate::backend::{Backend, MlpShape, NativeMlpBackend, PjrtBackend, QuadraticBackend};
use crate::config::{BackendKind, ExperimentConfig};
use crate::engine::{Engine, RunSummary};
use anyhow::Result;
use std::path::Path;

/// Build the configured gradient backend.
pub fn build_backend(cfg: &ExperimentConfig) -> Result<Box<dyn Backend>> {
    let seed = cfg.seed_for("data");
    Ok(match cfg.backend {
        BackendKind::Quadratic => Box::new(QuadraticBackend::new(
            cfg.num_workers,
            64,
            32,
            if cfg.iid { 0.0 } else { 1.0 },
            seed,
        )),
        BackendKind::NativeMlp => {
            let shape = MlpShape::by_name(&cfg.model)
                .ok_or_else(|| anyhow::anyhow!("no native MLP shape for variant {}", cfg.model))?;
            Box::new(NativeMlpBackend::new(
                shape,
                cfg.num_workers,
                cfg.dataset_samples,
                cfg.separation,
                cfg.iid,
                cfg.classes_per_worker,
                seed,
            ))
        }
        BackendKind::Pjrt => Box::new(PjrtBackend::new(
            Path::new(&cfg.artifacts_dir),
            &cfg.model,
            cfg.num_workers,
            cfg.dataset_samples,
            cfg.separation,
            cfg.iid,
            cfg.classes_per_worker,
            seed,
        )?),
    })
}

/// Run one experiment end to end.
pub fn run_experiment(cfg: &ExperimentConfig) -> Result<RunSummary> {
    cfg.validate()?;
    let backend = build_backend(cfg)?;
    let mut engine = Engine::try_from_config(cfg, backend)?;
    Ok(engine.run())
}

/// Run many configs in parallel on OS threads (each engine is
/// single-threaded and CPU-bound; scale-out is per-experiment).
pub fn run_sweep(configs: Vec<ExperimentConfig>) -> Vec<(ExperimentConfig, Result<RunSummary>)> {
    let threads = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(4);
    run_sweep_with_threads(configs, threads)
}

/// [`run_sweep`] with an explicit worker-thread count.  Results are in
/// input order and independent of `threads` (the determinism suite
/// asserts byte-identical metrics across thread counts).
///
/// A panicking experiment is contained: it surfaces as an `Err` in that
/// experiment's slot instead of poisoning the shared queues and aborting
/// the whole sweep.
pub fn run_sweep_with_threads(
    configs: Vec<ExperimentConfig>,
    threads: usize,
) -> Vec<(ExperimentConfig, Result<RunSummary>)> {
    sweep_jobs(configs, threads, run_experiment)
}

/// [`run_sweep_with_threads`] that additionally streams every finished
/// experiment to `on_done` — called once per config (index within
/// `configs`, the config, its contained result) from the worker thread
/// that ran it, as soon as it finishes.  The `sweep` executor uses this
/// to feed `ResultSink`s without waiting for the whole grid.
pub fn run_sweep_streaming<F>(
    configs: Vec<ExperimentConfig>,
    threads: usize,
    on_done: F,
) -> Vec<(ExperimentConfig, Result<RunSummary>)>
where
    F: Fn(usize, &ExperimentConfig, &Result<RunSummary>) + Sync,
{
    sweep_jobs_observed(configs, threads, run_experiment, on_done)
}

/// Generic panic-contained work-stealing sweep: run `f` over `jobs` on
/// `threads` OS threads, returning `(job, result)` in input order.
fn sweep_jobs<T, R, F>(jobs: Vec<T>, threads: usize, f: F) -> Vec<(T, Result<R>)>
where
    T: Send,
    R: Send,
    F: Fn(&T) -> Result<R> + Send + Sync,
{
    sweep_jobs_observed(jobs, threads, f, |_, _, _| ())
}

/// [`sweep_jobs`] with a per-job observer invoked right after each job
/// finishes (even when it panicked — the observer sees the `Err`).
fn sweep_jobs_observed<T, R, F, O>(
    jobs: Vec<T>,
    threads: usize,
    f: F,
    obs: O,
) -> Vec<(T, Result<R>)>
where
    T: Send,
    R: Send,
    F: Fn(&T) -> Result<R> + Send + Sync,
    O: Fn(usize, &T, &Result<R>) + Sync,
{
    let threads = threads.max(1);
    let queue = std::sync::Mutex::new(jobs.into_iter().enumerate().rev().collect::<Vec<_>>());
    let results = std::sync::Mutex::new(Vec::new());
    let f = &f;
    let obs = &obs;
    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                // A panic below never happens while a lock is held, but
                // recover from poisoning anyway: the data is a job queue /
                // result list, both valid at every lock release.
                let next = lock_ok(&queue).pop();
                let Some((idx, job)) = next else { break };
                // Contain per-experiment panics: one poisoned config must
                // not sink the other results (the old `h.join().expect`
                // aborted the entire sweep).
                let out = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(&job)))
                    .unwrap_or_else(|payload| {
                        Err(anyhow::anyhow!("experiment panicked: {}", panic_message(&payload)))
                    });
                obs(idx, &job, &out);
                lock_ok(&results).push((idx, job, out));
            });
        }
    });
    let mut out = results.into_inner().unwrap_or_else(|e| e.into_inner());
    out.sort_by_key(|(idx, _, _)| *idx);
    out.into_iter().map(|(_, job, res)| (job, res)).collect()
}

/// Recover the guard even from a poisoned mutex (see `sweep_jobs`; also
/// reused by the sweep executor's record/sink mutexes).
pub(crate) fn lock_ok<T>(m: &std::sync::Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

/// Best-effort text of a panic payload (`&str` / `String` or a marker).
fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// Mean ± population-std helper for table cells over repeated seeds.
pub fn mean_std(values: &[f64]) -> (f64, f64) {
    if values.is_empty() {
        return (f64::NAN, f64::NAN);
    }
    let mean = values.iter().sum::<f64>() / values.len() as f64;
    let var = values.iter().map(|v| (v - mean) * (v - mean)).sum::<f64>() / values.len() as f64;
    (mean, var.sqrt())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::AlgorithmKind;

    fn quick_cfg(alg: AlgorithmKind) -> ExperimentConfig {
        let mut cfg = ExperimentConfig::default();
        cfg.num_workers = 8;
        cfg.algorithm = alg;
        cfg.backend = BackendKind::Quadratic;
        cfg.max_iterations = 300;
        cfg.eval_every = 50;
        cfg.mean_compute = 0.01;
        cfg
    }

    #[test]
    fn every_algorithm_runs_and_learns_quadratic() {
        for alg in AlgorithmKind::all() {
            let cfg = quick_cfg(alg);
            let out = run_experiment(&cfg).unwrap();
            let first = out.recorder.curve.first().unwrap().loss;
            let last = out.final_loss();
            assert!(
                last < first,
                "{}: loss {first} -> {last} should decrease",
                alg.label()
            );
            assert!(out.iterations > 0);
            assert!(out.virtual_time > 0.0);
        }
    }

    #[test]
    fn dsgd_aau_completes_epochs() {
        let out = run_experiment(&quick_cfg(AlgorithmKind::DsgdAau)).unwrap();
        assert!(out.epochs_completed >= 1, "pathsearch should complete epochs");
    }

    #[test]
    fn sync_dsgd_slowest_per_iteration_time() {
        // With identical iteration counts, synchronous DSGD must burn more
        // virtual time per iteration than DSGD-AAU under stragglers.
        let mut sync_cfg = quick_cfg(AlgorithmKind::DsgdSync);
        sync_cfg.max_iterations = 30;
        let mut aau_cfg = quick_cfg(AlgorithmKind::DsgdAau);
        aau_cfg.max_iterations = 30;
        let sync = run_experiment(&sync_cfg).unwrap();
        let aau = run_experiment(&aau_cfg).unwrap();
        let t_sync = sync.virtual_time / sync.iterations.max(1) as f64;
        let t_aau = aau.virtual_time / aau.iterations.max(1) as f64;
        assert!(
            t_sync > t_aau,
            "sync {t_sync:.4}s/iter should exceed AAU {t_aau:.4}s/iter"
        );
    }

    #[test]
    fn sweep_runs_in_parallel_and_preserves_order() {
        let cfgs: Vec<_> = AlgorithmKind::all()
            .into_iter()
            .map(|a| {
                let mut c = quick_cfg(a);
                c.max_iterations = 50;
                c
            })
            .collect();
        let results = run_sweep(cfgs.clone());
        assert_eq!(results.len(), cfgs.len());
        for ((cfg, res), expect) in results.iter().zip(&cfgs) {
            assert_eq!(cfg.algorithm, expect.algorithm);
            assert!(res.is_ok());
        }
    }

    #[test]
    fn panicking_job_does_not_sink_the_sweep() {
        // one poisoned job among four: its slot surfaces the panic as an
        // Err, every other slot completes, order is preserved — across
        // thread counts, including the single-thread worker that runs the
        // poisoned job and must survive to drain the queue.
        for threads in [1usize, 4] {
            let jobs: Vec<usize> = vec![0, 1, 2, 3];
            let results = sweep_jobs(jobs, threads, |&j| -> Result<usize> {
                if j == 2 {
                    panic!("poisoned config {j}");
                }
                Ok(j * 10)
            });
            assert_eq!(results.len(), 4, "threads={threads}");
            for (j, res) in &results {
                match *j {
                    2 => {
                        let msg = res.as_ref().unwrap_err().to_string();
                        assert!(msg.contains("panicked"), "threads={threads}: {msg}");
                        assert!(msg.contains("poisoned config 2"), "{msg}");
                    }
                    _ => assert_eq!(*res.as_ref().unwrap(), j * 10, "threads={threads}"),
                }
            }
        }
    }

    #[test]
    fn erroring_config_does_not_sink_the_sweep() {
        let good = quick_cfg(AlgorithmKind::DsgdAau);
        let mut bad = quick_cfg(AlgorithmKind::DsgdAau);
        bad.churn = crate::churn::ChurnConfig {
            kind: crate::churn::ChurnKind::FlakyLinks { rate: 0.0, mean_downtime: 1.0 },
            seed: None,
        };
        let results = run_sweep_with_threads(vec![good, bad.clone(), bad], 2);
        assert_eq!(results.len(), 3);
        assert!(results[0].1.is_ok(), "good config must survive its bad neighbors");
        assert!(results[1].1.is_err() && results[2].1.is_err());
    }

    #[test]
    fn streaming_observer_sees_every_job_once() {
        let jobs: Vec<usize> = vec![0, 1, 2, 3];
        let seen = std::sync::Mutex::new(Vec::new());
        let results = sweep_jobs_observed(
            jobs,
            2,
            |&j| -> Result<usize> {
                if j == 1 {
                    panic!("boom");
                }
                Ok(j)
            },
            |idx, job, res| {
                seen.lock().unwrap().push((idx, *job, res.is_ok()));
            },
        );
        assert_eq!(results.len(), 4);
        let mut seen = seen.into_inner().unwrap();
        seen.sort();
        assert_eq!(
            seen,
            vec![(0, 0, true), (1, 1, false), (2, 2, true), (3, 3, true)],
            "observer fires exactly once per job, panics included"
        );
    }

    #[test]
    fn mean_std_basics() {
        let (m, s) = mean_std(&[1.0, 2.0, 3.0]);
        assert!((m - 2.0).abs() < 1e-12);
        assert!((s - (2.0f64 / 3.0).sqrt()).abs() < 1e-12);
    }
}
