//! Dynamic-topology & churn subsystem: time-varying communication graphs.
//!
//! The paper analyzes a *fixed* connected graph `G`, but real decentralized
//! deployments face flaky links, worker churn and mobility.  This module
//! models those as timestamped **topology mutations** applied to the live
//! [`Graph`] at virtual time:
//!
//! * [`TopologyMutation`] — link add/remove, worker isolate (crash/leave)
//!   and attach (join/recover/move);
//! * [`TopologyTimeline`] — an explicit schedule of mutation batches with
//!   JSON load/save (in the spirit of nebulastream's
//!   `topology_updates.json`), so scenarios are reproducible artifacts;
//! * [`apply_mutations`] — the single mutation entry point, with
//!   **connectivity repair**: any removal that would disconnect `G` is
//!   deferred (left in place), so the paper's standing connectivity
//!   assumption holds after every applied mutation;
//! * [`generators`] — seeded scenario generators (random flaky links,
//!   mobile workers rewiring their neighborhood, planned partition/heal
//!   cycles) plus schedule replay, all driven through [`ChurnModel`].
//!
//! The engine consumes this via `EventKind::TopologyChange` events: at
//! each change point the model emits mutations, the engine applies them
//! with repair, prunes Pathsearch's visited-edge set, and invalidates its
//! cached full-graph Metropolis weights.

pub mod generators;

pub use generators::{materialize, ChurnConfig, ChurnKind, ChurnModel};

use crate::topology::Graph;
use crate::util::json::Json;
use crate::WorkerId;
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::path::Path;

/// One atomic change to the communication graph.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum TopologyMutation {
    /// Insert the undirected link `(i, j)`.
    AddEdge(usize, usize),
    /// Drop the undirected link `(i, j)` (deferred if it is a bridge).
    RemoveEdge(usize, usize),
    /// Worker crash/leave: drop every incident link.  Connectivity repair
    /// always retains a last "lifeline" link, modeling the degraded but
    /// reachable state the connectivity assumption requires.
    Isolate(WorkerId),
    /// Worker join/recover/move: connect to the listed neighbors.
    Attach(WorkerId, Vec<WorkerId>),
}

impl TopologyMutation {
    /// Serialize to the schedule-file form.
    pub fn to_json(&self) -> Json {
        let mut m: BTreeMap<String, Json> = BTreeMap::new();
        match self {
            TopologyMutation::AddEdge(i, j) => {
                m.insert("action".into(), Json::from("add"));
                m.insert("i".into(), Json::from(*i));
                m.insert("j".into(), Json::from(*j));
            }
            TopologyMutation::RemoveEdge(i, j) => {
                m.insert("action".into(), Json::from("remove"));
                m.insert("i".into(), Json::from(*i));
                m.insert("j".into(), Json::from(*j));
            }
            TopologyMutation::Isolate(w) => {
                m.insert("action".into(), Json::from("isolate"));
                m.insert("worker".into(), Json::from(*w));
            }
            TopologyMutation::Attach(w, ns) => {
                m.insert("action".into(), Json::from("attach"));
                m.insert("worker".into(), Json::from(*w));
                m.insert(
                    "neighbors".into(),
                    Json::Arr(ns.iter().map(|&n| Json::from(n)).collect()),
                );
            }
        }
        Json::Obj(m)
    }

    /// Inverse of [`Self::to_json`].
    pub fn from_json(j: &Json) -> Result<Self> {
        let action = j.req("action")?.as_str().context("action must be a string")?;
        let endpoint = |key: &str| -> Result<usize> {
            j.req(key)?.as_usize().with_context(|| format!("{key} must be a worker id"))
        };
        Ok(match action {
            "add" => TopologyMutation::AddEdge(endpoint("i")?, endpoint("j")?),
            "remove" => TopologyMutation::RemoveEdge(endpoint("i")?, endpoint("j")?),
            "isolate" => TopologyMutation::Isolate(endpoint("worker")?),
            "attach" => {
                let ns = j
                    .req("neighbors")?
                    .as_arr()
                    .context("neighbors must be an array")?
                    .iter()
                    .map(|v| v.as_usize().context("neighbor ids must be integers"))
                    .collect::<Result<Vec<_>>>()?;
                TopologyMutation::Attach(endpoint("worker")?, ns)
            }
            other => bail!("unknown mutation action {other:?} (add|remove|isolate|attach)"),
        })
    }
}

/// A batch of mutations at one virtual timestamp.
#[derive(Debug, Clone, PartialEq)]
pub struct TimelineEntry {
    /// Virtual time (seconds) the batch fires at.
    pub time: f64,
    /// Mutations applied in order.
    pub mutations: Vec<TopologyMutation>,
}

/// Timestamped mutation schedule (sorted by time).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct TopologyTimeline {
    /// Schedule entries in non-decreasing time order.
    pub entries: Vec<TimelineEntry>,
}

impl TopologyTimeline {
    /// Empty schedule.
    pub fn new() -> Self {
        Self::default()
    }

    /// Append a batch (times must be appended in non-decreasing order;
    /// [`Self::from_json`] sorts, so hand-built schedules can use it).
    pub fn push(&mut self, time: f64, mutations: Vec<TopologyMutation>) {
        debug_assert!(
            self.entries.last().map_or(true, |e| e.time <= time),
            "timeline must be pushed in time order"
        );
        self.entries.push(TimelineEntry { time, mutations });
    }

    /// Number of scheduled batches.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// Whether the schedule is empty.
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Total mutation count across all batches.
    pub fn num_mutations(&self) -> usize {
        self.entries.iter().map(|e| e.mutations.len()).sum()
    }

    /// Serialize as `{"updates": [{"time": t, "events": [...]}]}`.
    pub fn to_json(&self) -> Json {
        let updates: Vec<Json> = self
            .entries
            .iter()
            .map(|e| {
                let mut m: BTreeMap<String, Json> = BTreeMap::new();
                m.insert("time".into(), Json::Num(e.time));
                m.insert(
                    "events".into(),
                    Json::Arr(e.mutations.iter().map(|mu| mu.to_json()).collect()),
                );
                Json::Obj(m)
            })
            .collect();
        let mut m: BTreeMap<String, Json> = BTreeMap::new();
        m.insert("updates".into(), Json::Arr(updates));
        Json::Obj(m)
    }

    /// Inverse of [`Self::to_json`]; entries are sorted by time.  Strict
    /// parse: unknown keys in the document or an update entry are errors
    /// (a typo like `"event"` must not silently drop a schedule).
    pub fn from_json(j: &Json) -> Result<Self> {
        if let Some(obj) = j.as_obj() {
            for key in obj.keys() {
                anyhow::ensure!(key == "updates", "unknown timeline key {key:?} (want updates)");
            }
        }
        let mut entries = Vec::new();
        for e in j.req("updates")?.as_arr().context("updates must be an array")? {
            if let Some(obj) = e.as_obj() {
                for key in obj.keys() {
                    anyhow::ensure!(
                        key == "time" || key == "events",
                        "unknown update key {key:?} (want time, events)"
                    );
                }
            }
            let time = e.req("time")?.as_f64().context("time must be a number")?;
            anyhow::ensure!(time >= 0.0 && time.is_finite(), "bad update time {time}");
            let mutations = e
                .req("events")?
                .as_arr()
                .context("events must be an array")?
                .iter()
                .map(TopologyMutation::from_json)
                .collect::<Result<Vec<_>>>()?;
            entries.push(TimelineEntry { time, mutations });
        }
        entries.sort_by(|a, b| a.time.partial_cmp(&b.time).expect("finite times"));
        Ok(TopologyTimeline { entries })
    }

    /// Write the schedule to a JSON file.
    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)?;
            }
        }
        std::fs::write(path, self.to_json().to_string_compact())
            .with_context(|| format!("write schedule {}", path.display()))
    }

    /// Load a schedule from a JSON file.
    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("read schedule {}", path.display()))?;
        Self::from_json(&Json::parse(&text)?)
    }
}

/// What happened when a mutation batch was applied.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ApplyOutcome {
    /// Mutated links (adds + removals) actually applied.
    pub applied: usize,
    /// Removals deferred by connectivity repair (the link stays up).
    pub deferred: usize,
}

impl ApplyOutcome {
    /// Accumulate another outcome.
    pub fn absorb(&mut self, other: ApplyOutcome) {
        self.applied += other.applied;
        self.deferred += other.deferred;
    }
}

/// Apply a mutation batch in order with connectivity repair: a removal
/// that would disconnect the graph is deferred (the link stays up), so a
/// connected graph stays connected after *every* mutation.  Out-of-range
/// ids, self-loops and redundant adds/removes are skipped.
pub fn apply_mutations(g: &mut Graph, mutations: &[TopologyMutation]) -> ApplyOutcome {
    apply_mutations_impl(g, mutations, true)
}

/// Apply a mutation batch *without* connectivity repair: removals apply
/// even when they disconnect the graph, so partitions are real.  Used by
/// the engine when the `adapt` config allows partitions; the
/// [`crate::adapt::PartitionMonitor`] then tracks the resulting
/// component structure.  `deferred` is always 0 in the outcome.
pub fn apply_mutations_unrepaired(g: &mut Graph, mutations: &[TopologyMutation]) -> ApplyOutcome {
    apply_mutations_impl(g, mutations, false)
}

fn apply_mutations_impl(
    g: &mut Graph,
    mutations: &[TopologyMutation],
    repair: bool,
) -> ApplyOutcome {
    let n = g.num_vertices();
    let mut out = ApplyOutcome::default();
    for m in mutations {
        match m {
            TopologyMutation::AddEdge(i, j) => {
                if *i < n && *j < n && i != j && !g.has_edge(*i, *j) {
                    g.add_edge(*i, *j);
                    out.applied += 1;
                }
            }
            TopologyMutation::RemoveEdge(i, j) => {
                if *i < n && *j < n {
                    try_remove(g, *i, *j, repair, &mut out);
                }
            }
            TopologyMutation::Isolate(w) => {
                if *w < n {
                    for nb in g.neighbors(*w).to_vec() {
                        try_remove(g, *w, nb, repair, &mut out);
                    }
                }
            }
            TopologyMutation::Attach(w, ns) => {
                for &nb in ns {
                    if *w < n && nb < n && nb != *w && !g.has_edge(*w, nb) {
                        g.add_edge(*w, nb);
                        out.applied += 1;
                    }
                }
            }
        }
    }
    out
}

/// Remove `(i, j)` unless absent; with `repair`, bridges are deferred.
fn try_remove(g: &mut Graph, i: usize, j: usize, repair: bool, out: &mut ApplyOutcome) {
    if !g.has_edge(i, j) {
        return;
    }
    if repair && g.would_disconnect(i, j) {
        out.deferred += 1;
    } else {
        g.remove_edge(i, j);
        out.applied += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::generators::{ring, star};

    #[test]
    fn apply_add_remove_roundtrip() {
        let mut g = ring(6);
        let out = apply_mutations(
            &mut g,
            &[
                TopologyMutation::AddEdge(0, 3),
                TopologyMutation::RemoveEdge(0, 1),
                TopologyMutation::RemoveEdge(0, 1), // redundant: skipped
                TopologyMutation::AddEdge(0, 3),    // redundant: skipped
            ],
        );
        assert_eq!(out, ApplyOutcome { applied: 2, deferred: 0 });
        assert!(g.has_edge(0, 3) && !g.has_edge(0, 1));
        assert!(g.is_connected());
    }

    #[test]
    fn bridge_removal_deferred() {
        // ring edges are all non-bridges until the first removal; after
        // removing (0,1) every remaining ring edge is a bridge.
        let mut g = ring(4);
        let out = apply_mutations(
            &mut g,
            &[TopologyMutation::RemoveEdge(0, 1), TopologyMutation::RemoveEdge(2, 3)],
        );
        assert_eq!(out, ApplyOutcome { applied: 1, deferred: 1 });
        assert!(g.has_edge(2, 3), "bridge must stay up");
        assert!(g.is_connected());
    }

    #[test]
    fn isolate_keeps_a_lifeline() {
        let mut g = star(5); // hub 0
        let out = apply_mutations(&mut g, &[TopologyMutation::Isolate(3)]);
        // worker 3's only link is a bridge: the crash leaves the lifeline
        assert_eq!(out, ApplyOutcome { applied: 0, deferred: 1 });
        assert!(g.is_connected());

        // with redundancy the isolate strips all but one link
        let mut g = ring(5);
        g.add_edge(0, 2);
        g.add_edge(0, 3);
        let out = apply_mutations(&mut g, &[TopologyMutation::Isolate(0)]);
        assert!(out.applied >= 1 && out.deferred >= 1, "{out:?}");
        assert_eq!(g.degree(0), 1, "exactly the lifeline remains");
        assert!(g.is_connected());
    }

    #[test]
    fn attach_then_isolate_rewires() {
        let mut g = ring(6);
        let out = apply_mutations(
            &mut g,
            &[
                TopologyMutation::Attach(0, vec![2, 3]),
                TopologyMutation::RemoveEdge(0, 1),
                TopologyMutation::RemoveEdge(0, 5),
            ],
        );
        assert_eq!(out.deferred, 0, "{out:?}");
        assert!(g.has_edge(0, 2) && g.has_edge(0, 3));
        assert!(!g.has_edge(0, 1) && !g.has_edge(0, 5));
        assert!(g.is_connected());
    }

    #[test]
    fn unrepaired_apply_allows_real_partitions() {
        let mut g = ring(4);
        let out = apply_mutations_unrepaired(
            &mut g,
            &[TopologyMutation::RemoveEdge(0, 1), TopologyMutation::RemoveEdge(2, 3)],
        );
        assert_eq!(out, ApplyOutcome { applied: 2, deferred: 0 });
        assert!(!g.is_connected(), "without repair the cut is real");

        // isolate strips every incident link, no lifeline
        let mut g = star(5);
        let out = apply_mutations_unrepaired(&mut g, &[TopologyMutation::Isolate(3)]);
        assert_eq!(out, ApplyOutcome { applied: 1, deferred: 0 });
        assert_eq!(g.degree(3), 0);
        assert!(!g.is_connected());
    }

    #[test]
    fn out_of_range_and_self_loops_skipped() {
        let mut g = ring(4);
        let before = g.clone();
        let out = apply_mutations(
            &mut g,
            &[
                TopologyMutation::AddEdge(0, 9),
                TopologyMutation::RemoveEdge(9, 1),
                TopologyMutation::AddEdge(2, 2),
                TopologyMutation::Isolate(17),
                TopologyMutation::Attach(1, vec![1, 40]),
            ],
        );
        assert_eq!(out, ApplyOutcome::default());
        assert_eq!(g, before);
    }

    #[test]
    fn mutation_json_roundtrip() {
        for m in [
            TopologyMutation::AddEdge(1, 2),
            TopologyMutation::RemoveEdge(3, 0),
            TopologyMutation::Isolate(7),
            TopologyMutation::Attach(4, vec![0, 2, 5]),
        ] {
            assert_eq!(TopologyMutation::from_json(&m.to_json()).unwrap(), m);
        }
        assert!(TopologyMutation::from_json(&Json::parse(r#"{"action":"warp"}"#).unwrap())
            .is_err());
    }

    #[test]
    fn timeline_json_and_file_roundtrip() {
        let mut tl = TopologyTimeline::new();
        tl.push(0.5, vec![TopologyMutation::AddEdge(0, 2)]);
        tl.push(
            1.25,
            vec![TopologyMutation::RemoveEdge(1, 2), TopologyMutation::Isolate(3)],
        );
        let back = TopologyTimeline::from_json(&tl.to_json()).unwrap();
        assert_eq!(back, tl);
        assert_eq!(back.num_mutations(), 3);

        let path = std::env::temp_dir()
            .join(format!("dsgd_churn_schedule_{}.json", std::process::id()));
        tl.save(&path).unwrap();
        assert_eq!(TopologyTimeline::load(&path).unwrap(), tl);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn timeline_from_json_sorts_by_time() {
        let text = r#"{"updates": [
            {"time": 2.0, "events": [{"action": "add", "i": 0, "j": 1}]},
            {"time": 1.0, "events": [{"action": "remove", "i": 2, "j": 3}]}
        ]}"#;
        let tl = TopologyTimeline::from_json(&Json::parse(text).unwrap()).unwrap();
        assert_eq!(tl.entries[0].time, 1.0);
        assert_eq!(tl.entries[1].time, 2.0);
    }
}
