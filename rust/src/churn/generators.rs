//! Churn scenario generators: seeded processes that emit topology
//! mutations over virtual time, plus schedule replay.
//!
//! Three synthetic scenario families (the axes the nebulastream
//! topology-change generator sweeps — rate of change, number of mobile
//! nodes, planned link schedules) and a replay mode:
//!
//! * **flaky links** — at a configurable rate a random non-bridge link
//!   fails, coming back after ~`mean_downtime` seconds;
//! * **mobile workers** — a fixed cohort of workers re-wires its
//!   neighborhood on an interval (old links dropped, fresh ones attached);
//! * **partition/heal** — every `period` a random bisection cuts all
//!   cross links (connectivity repair retains one bridge, modeling the
//!   last degraded route) and heals `downtime` seconds later;
//! * **schedule** — replay a [`TopologyTimeline`] JSON file.
//!
//! All randomness flows through [`Rng64`] streams seeded from
//! `ExperimentConfig::seed_for("churn")` (overridable per config), so
//! runs are exactly reproducible and [`materialize`] emits the same
//! evolution the engine will execute.

use super::{apply_mutations, TopologyMutation, TopologyTimeline};
use crate::topology::Graph;
use crate::util::json::Json;
use crate::util::Rng64;
use crate::WorkerId;
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::collections::BTreeSet;
use std::path::Path;

/// Which churn scenario to run (config-selectable).
#[derive(Debug, Clone, PartialEq, Default)]
pub enum ChurnKind {
    /// Static graph (the paper's setting).
    #[default]
    None,
    /// Random link failures at `rate` events/second; each failed link
    /// restores after roughly `mean_downtime` seconds.
    FlakyLinks {
        /// Link-failure events per virtual second.
        rate: f64,
        /// Mean seconds a failed link stays down.
        mean_downtime: f64,
    },
    /// `movers` mobile workers; every `interval` seconds the next one
    /// re-wires to `degree` fresh random neighbors.
    Mobile {
        /// Size of the mobile cohort.
        movers: usize,
        /// Seconds between re-wiring events.
        interval: f64,
        /// Links each mobile worker maintains after a move.
        degree: usize,
    },
    /// Every `period` seconds a random bisection cuts the cross links
    /// (one repaired bridge survives); the cut heals `downtime` seconds
    /// later.
    PartitionHeal {
        /// Seconds between partition events.
        period: f64,
        /// Seconds the partition lasts before healing.
        downtime: f64,
    },
    /// Replay a saved [`TopologyTimeline`] JSON schedule.
    Schedule {
        /// Path to the schedule file.
        path: String,
    },
}

/// Churn section of the experiment config.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ChurnConfig {
    /// Scenario kind and parameters.
    pub kind: ChurnKind,
    /// Generator seed override; defaults to `seed_for("churn")`.
    pub seed: Option<u64>,
}

impl ChurnConfig {
    /// Parse the config form: a bare kind string (all parameters default)
    /// or an object like `{"kind": "flaky_links", "rate": 2.0,
    /// "mean_downtime": 1.0}`.  Like `ExperimentConfig::from_json`,
    /// unknown keys and wrongly-typed values are rejected rather than
    /// silently defaulted.
    pub fn from_json(j: &Json) -> Result<Self> {
        let kind_token = j
            .as_str()
            .or_else(|| j.get("kind").and_then(Json::as_str))
            .context("churn must be a kind string or an object with a \"kind\" field")?
            .to_string();
        let f = |key: &str, default: f64| -> Result<f64> {
            match j.get(key) {
                None => Ok(default),
                Some(v) => v
                    .as_f64()
                    .with_context(|| format!("churn {key} must be a number")),
            }
        };
        let u = |key: &str, default: usize| -> Result<usize> {
            match j.get(key) {
                None => Ok(default),
                Some(v) => v
                    .as_usize()
                    .with_context(|| format!("churn {key} must be a non-negative integer")),
            }
        };
        let (kind, allowed): (ChurnKind, &[&str]) = match kind_token.as_str() {
            "none" => (ChurnKind::None, &[]),
            "flaky_links" => (
                ChurnKind::FlakyLinks {
                    rate: f("rate", 1.0)?,
                    mean_downtime: f("mean_downtime", 1.0)?,
                },
                &["rate", "mean_downtime"],
            ),
            "mobile" => (
                ChurnKind::Mobile {
                    movers: u("movers", 2)?,
                    interval: f("interval", 1.0)?,
                    degree: u("degree", 2)?,
                },
                &["movers", "interval", "degree"],
            ),
            "partition_heal" => (
                ChurnKind::PartitionHeal {
                    period: f("period", 10.0)?,
                    downtime: f("downtime", 3.0)?,
                },
                &["period", "downtime"],
            ),
            "schedule" => (
                ChurnKind::Schedule {
                    path: j
                        .get("path")
                        .and_then(Json::as_str)
                        .context("schedule churn needs a \"path\" string")?
                        .to_string(),
                },
                &["path"],
            ),
            other => bail!(
                "unknown churn kind {other:?} (none|flaky_links|mobile|partition_heal|schedule)"
            ),
        };
        let seed = match j.get("seed") {
            None => None,
            Some(v) => {
                Some(v.as_u64().context("churn seed must be a non-negative integer")?)
            }
        };
        if let Some(obj) = j.as_obj() {
            for key in obj.keys() {
                if key != "kind" && key != "seed" && !allowed.contains(&key.as_str()) {
                    bail!("unknown churn key {key:?} for kind {kind_token:?}");
                }
            }
        }
        Ok(ChurnConfig { kind, seed })
    }

    /// Inverse of [`Self::from_json`].
    pub fn to_json(&self) -> Json {
        let mut m: BTreeMap<String, Json> = BTreeMap::new();
        match &self.kind {
            ChurnKind::None => {
                m.insert("kind".into(), Json::from("none"));
            }
            ChurnKind::FlakyLinks { rate, mean_downtime } => {
                m.insert("kind".into(), Json::from("flaky_links"));
                m.insert("rate".into(), Json::Num(*rate));
                m.insert("mean_downtime".into(), Json::Num(*mean_downtime));
            }
            ChurnKind::Mobile { movers, interval, degree } => {
                m.insert("kind".into(), Json::from("mobile"));
                m.insert("movers".into(), Json::from(*movers));
                m.insert("interval".into(), Json::Num(*interval));
                m.insert("degree".into(), Json::from(*degree));
            }
            ChurnKind::PartitionHeal { period, downtime } => {
                m.insert("kind".into(), Json::from("partition_heal"));
                m.insert("period".into(), Json::Num(*period));
                m.insert("downtime".into(), Json::Num(*downtime));
            }
            ChurnKind::Schedule { path } => {
                m.insert("kind".into(), Json::from("schedule"));
                m.insert("path".into(), Json::from(path.as_str()));
            }
        }
        if let Some(s) = self.seed {
            m.insert("seed".into(), Json::from(s as usize));
        }
        Json::Obj(m)
    }

    /// Parameter sanity checks (called from `ExperimentConfig::validate`).
    pub fn validate(&self) -> Result<()> {
        match &self.kind {
            ChurnKind::None => {}
            ChurnKind::FlakyLinks { rate, mean_downtime } => {
                anyhow::ensure!(*rate > 0.0, "flaky_links rate must be positive");
                anyhow::ensure!(*mean_downtime > 0.0, "mean_downtime must be positive");
            }
            ChurnKind::Mobile { movers, interval, degree } => {
                anyhow::ensure!(*movers >= 1, "mobile movers must be >= 1");
                anyhow::ensure!(*interval > 0.0, "mobile interval must be positive");
                anyhow::ensure!(*degree >= 1, "mobile degree must be >= 1");
            }
            ChurnKind::PartitionHeal { period, downtime } => {
                anyhow::ensure!(*period > 0.0, "partition period must be positive");
                anyhow::ensure!(
                    *downtime > 0.0 && *downtime < *period,
                    "partition downtime must lie in (0, period)"
                );
            }
            ChurnKind::Schedule { path } => {
                anyhow::ensure!(!path.is_empty(), "schedule churn needs a path");
            }
        }
        Ok(())
    }

    /// Whether the config describes an active (non-static) scenario.
    pub fn is_active(&self) -> bool {
        self.kind != ChurnKind::None
    }
}

/// Runtime churn process: the engine asks it *when* the next change is
/// due and *what* mutations fire at that time.
#[derive(Debug)]
pub struct ChurnModel {
    inner: Inner,
    next: Option<f64>,
}

#[derive(Debug)]
enum Inner {
    Inactive,
    Flaky {
        dt: f64,
        mean_downtime: f64,
        rng: Rng64,
        /// Failed links and their restore times.
        down: Vec<((usize, usize), f64)>,
        /// Next failure tick (failures stay on the `dt` grid; restores
        /// fire at their own sampled times).
        next_fail: f64,
    },
    Mobile {
        movers: Vec<WorkerId>,
        interval: f64,
        degree: usize,
        rng: Rng64,
        cursor: usize,
    },
    Partition {
        period: f64,
        downtime: f64,
        rng: Rng64,
        /// Cross links cut by the active partition (restored on heal).
        cut: Vec<(usize, usize)>,
        healing: bool,
    },
    Replay {
        timeline: TopologyTimeline,
        cursor: usize,
    },
}

impl ChurnModel {
    /// A model that never fires (static topology).
    pub fn inactive() -> Self {
        ChurnModel { inner: Inner::Inactive, next: None }
    }

    /// Build from the config section.  `derived_seed` should come from
    /// `ExperimentConfig::seed_for("churn")`; an explicit `seed` in the
    /// config overrides it.
    pub fn from_config(cfg: &ChurnConfig, num_workers: usize, derived_seed: u64) -> Result<Self> {
        cfg.validate()?;
        let seed = cfg.seed.unwrap_or(derived_seed);
        Ok(match &cfg.kind {
            ChurnKind::None => ChurnModel::inactive(),
            ChurnKind::FlakyLinks { rate, mean_downtime } => {
                let dt = 1.0 / *rate;
                ChurnModel {
                    inner: Inner::Flaky {
                        dt,
                        mean_downtime: *mean_downtime,
                        rng: Rng64::seed_from_u64(seed),
                        down: Vec::new(),
                        next_fail: dt,
                    },
                    next: Some(dt),
                }
            }
            ChurnKind::Mobile { movers, interval, degree } => {
                anyhow::ensure!(
                    *movers <= num_workers,
                    "mobile movers ({movers}) exceeds the fleet size ({num_workers})"
                );
                anyhow::ensure!(
                    *degree < num_workers,
                    "mobile degree ({degree}) needs at least degree+1 workers ({num_workers})"
                );
                let mut rng = Rng64::seed_from_u64(seed);
                let pool: Vec<WorkerId> = (0..num_workers).collect();
                let movers = rng.sample(&pool, *movers);
                ChurnModel {
                    inner: Inner::Mobile {
                        movers,
                        interval: *interval,
                        degree: *degree,
                        rng,
                        cursor: 0,
                    },
                    next: Some(*interval),
                }
            }
            ChurnKind::PartitionHeal { period, downtime } => ChurnModel {
                inner: Inner::Partition {
                    period: *period,
                    downtime: *downtime,
                    rng: Rng64::seed_from_u64(seed),
                    cut: Vec::new(),
                    healing: false,
                },
                next: Some(*period),
            },
            ChurnKind::Schedule { path } => {
                Self::replay(TopologyTimeline::load(Path::new(path))?)
            }
        })
    }

    /// Replay an in-memory schedule (used by tests and demos).
    pub fn replay(timeline: TopologyTimeline) -> Self {
        let next = timeline.entries.first().map(|e| e.time);
        ChurnModel { inner: Inner::Replay { timeline, cursor: 0 }, next }
    }

    /// Whether any future change is pending.
    pub fn is_active(&self) -> bool {
        self.next.is_some()
    }

    /// Virtual time of the next change, if any.
    pub fn next_change(&self) -> Option<f64> {
        self.next
    }

    /// Emit the mutations due at `now` (the time previously returned by
    /// [`Self::next_change`]) against the current graph `g`, advancing the
    /// process.  The caller applies them via
    /// [`apply_mutations`](super::apply_mutations).
    pub fn step(&mut self, now: f64, g: &Graph) -> Vec<TopologyMutation> {
        match &mut self.inner {
            Inner::Inactive => {
                self.next = None;
                Vec::new()
            }
            Inner::Flaky { dt, mean_downtime, rng, down, next_fail } => {
                let mut muts = Vec::new();
                // restore links whose downtime expired
                down.retain(|&((i, j), until)| {
                    if until <= now + 1e-9 {
                        muts.push(TopologyMutation::AddEdge(i, j));
                        false
                    } else {
                        true
                    }
                });
                // failure ticks stay on the 1/rate grid; this step may be
                // a pure restore event between ticks
                if now + 1e-9 >= *next_fail {
                    // fail one random non-bridge link (`Graph::edges`
                    // iterates the BTreeSet in sorted order, so the
                    // indexed draw below is deterministic)
                    let mut edges: Vec<(usize, usize)> = g.edges().collect();
                    for _ in 0..8 {
                        if edges.is_empty() {
                            break;
                        }
                        let idx = rng.gen_range(edges.len());
                        let (i, j) = edges[idx];
                        if g.would_disconnect(i, j) {
                            edges.swap_remove(idx);
                            continue;
                        }
                        muts.push(TopologyMutation::RemoveEdge(i, j));
                        let downtime = *mean_downtime * (0.5 + rng.gen_f64());
                        down.push(((i, j), now + downtime));
                        break;
                    }
                    *next_fail = now + *dt;
                }
                // wake at whichever comes first: the next failure tick or
                // the earliest pending restore (so downtime is honored
                // even when 1/rate exceeds it)
                let earliest_restore =
                    down.iter().map(|&(_, until)| until).fold(f64::INFINITY, f64::min);
                self.next = Some(next_fail.min(earliest_restore));
                muts
            }
            Inner::Mobile { movers, interval, degree, rng, cursor } => {
                let w = movers[*cursor % movers.len()];
                *cursor += 1;
                let pool: Vec<WorkerId> =
                    (0..g.num_vertices()).filter(|&x| x != w).collect();
                let fresh = rng.sample(&pool, *degree);
                // attach first, then drop the stale links: the new
                // neighborhood is in place before the old one goes away
                let mut muts = vec![TopologyMutation::Attach(w, fresh.clone())];
                for &old in g.neighbors(w) {
                    if !fresh.contains(&old) {
                        muts.push(TopologyMutation::RemoveEdge(w, old));
                    }
                }
                self.next = Some(now + *interval);
                muts
            }
            Inner::Partition { period, downtime, rng, cut, healing } => {
                if *healing {
                    *healing = false;
                    self.next = Some(now - *downtime + *period);
                    cut.drain(..).map(|(i, j)| TopologyMutation::AddEdge(i, j)).collect()
                } else {
                    let n = g.num_vertices();
                    let mut ids: Vec<usize> = (0..n).collect();
                    rng.shuffle(&mut ids);
                    let side_a: BTreeSet<usize> = ids[..n / 2].iter().copied().collect();
                    let mut muts = Vec::new();
                    for (i, j) in g.edges() {
                        if side_a.contains(&i) != side_a.contains(&j) {
                            muts.push(TopologyMutation::RemoveEdge(i, j));
                            cut.push((i, j));
                        }
                    }
                    *healing = true;
                    self.next = Some(now + *downtime);
                    muts
                }
            }
            Inner::Replay { timeline, cursor } => {
                let mut muts = Vec::new();
                while let Some(e) = timeline.entries.get(*cursor) {
                    if e.time <= now + 1e-9 {
                        muts.extend(e.mutations.iter().cloned());
                        *cursor += 1;
                    } else {
                        break;
                    }
                }
                self.next = timeline.entries.get(*cursor).map(|e| e.time);
                muts
            }
        }
    }
}

/// Materialize the evolution `cfg` would produce on `initial` up to
/// `horizon` virtual seconds, as a saveable [`TopologyTimeline`].
/// Replaying the result through [`apply_mutations`] reproduces the exact
/// same graph evolution the engine executes with the generator.
pub fn materialize(
    cfg: &ChurnConfig,
    num_workers: usize,
    derived_seed: u64,
    initial: &Graph,
    horizon: f64,
) -> Result<TopologyTimeline> {
    let mut model = ChurnModel::from_config(cfg, num_workers, derived_seed)?;
    let mut g = initial.clone();
    let mut timeline = TopologyTimeline::new();
    while let Some(t) = model.next_change() {
        if t > horizon {
            break;
        }
        let muts = model.step(t, &g);
        assert!(
            model.next_change().map_or(true, |nt| nt > t),
            "churn process must advance time"
        );
        if !muts.is_empty() {
            apply_mutations(&mut g, &muts);
            timeline.push(t, muts);
        }
    }
    Ok(timeline)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::generators::{random_connected, ring};

    fn flaky() -> ChurnConfig {
        ChurnConfig {
            kind: ChurnKind::FlakyLinks { rate: 2.0, mean_downtime: 1.0 },
            seed: Some(7),
        }
    }

    #[test]
    fn config_json_roundtrip() {
        for cfg in [
            ChurnConfig::default(),
            flaky(),
            ChurnConfig {
                kind: ChurnKind::Mobile { movers: 3, interval: 0.5, degree: 2 },
                seed: None,
            },
            ChurnConfig {
                kind: ChurnKind::PartitionHeal { period: 8.0, downtime: 2.0 },
                seed: Some(1),
            },
            ChurnConfig {
                kind: ChurnKind::Schedule { path: "sched.json".into() },
                seed: None,
            },
        ] {
            let back = ChurnConfig::from_json(&cfg.to_json()).unwrap();
            assert_eq!(back, cfg);
        }
        // bare-string form
        assert_eq!(
            ChurnConfig::from_json(&Json::from("none")).unwrap(),
            ChurnConfig::default()
        );
        assert!(ChurnConfig::from_json(&Json::from("earthquake")).is_err());
    }

    #[test]
    fn from_json_rejects_typos_and_wrong_types() {
        // misspelled parameter key: rejected, not silently defaulted
        let j = Json::parse(r#"{"kind": "flaky_links", "rte": 8.0}"#).unwrap();
        assert!(ChurnConfig::from_json(&j).is_err());
        // parameter of another kind: also unknown here
        let j = Json::parse(r#"{"kind": "mobile", "rate": 2.0}"#).unwrap();
        assert!(ChurnConfig::from_json(&j).is_err());
        // wrongly-typed value
        let j = Json::parse(r#"{"kind": "flaky_links", "rate": "8.0"}"#).unwrap();
        assert!(ChurnConfig::from_json(&j).is_err());
        let j = Json::parse(r#"{"kind": "mobile", "movers": 2.5}"#).unwrap();
        assert!(ChurnConfig::from_json(&j).is_err());
        // schedule without a path
        let j = Json::parse(r#"{"kind": "schedule"}"#).unwrap();
        assert!(ChurnConfig::from_json(&j).is_err());
        // missing kind entirely
        let j = Json::parse(r#"{"rate": 2.0}"#).unwrap();
        assert!(ChurnConfig::from_json(&j).is_err());
        // correct spellings still parse
        let j = Json::parse(r#"{"kind": "flaky_links", "rate": 8.0, "seed": 3}"#).unwrap();
        let cfg = ChurnConfig::from_json(&j).unwrap();
        assert_eq!(
            cfg.kind,
            ChurnKind::FlakyLinks { rate: 8.0, mean_downtime: 1.0 }
        );
        assert_eq!(cfg.seed, Some(3));
    }

    #[test]
    fn validate_rejects_bad_parameters() {
        let bad = ChurnConfig {
            kind: ChurnKind::FlakyLinks { rate: 0.0, mean_downtime: 1.0 },
            seed: None,
        };
        assert!(bad.validate().is_err());
        let bad = ChurnConfig {
            kind: ChurnKind::PartitionHeal { period: 5.0, downtime: 5.0 },
            seed: None,
        };
        assert!(bad.validate().is_err());
        assert!(flaky().validate().is_ok());
    }

    #[test]
    fn flaky_keeps_graph_connected_and_link_count_stable() {
        let g0 = random_connected(16, 0.2, 3);
        let tl = materialize(&flaky(), 16, 99, &g0, 50.0).unwrap();
        assert!(!tl.is_empty(), "flaky scenario must generate events");
        let mut g = g0.clone();
        for e in &tl.entries {
            apply_mutations(&mut g, &e.mutations);
            assert!(g.is_connected(), "disconnected at t={}", e.time);
        }
        // failed links come back: the long-run edge count stays in a band
        assert!(g.num_edges() + 4 >= g0.num_edges(), "{} vs {}", g.num_edges(), g0.num_edges());
    }

    #[test]
    fn mobile_rewires_the_cohort() {
        let cfg = ChurnConfig {
            kind: ChurnKind::Mobile { movers: 2, interval: 1.0, degree: 2 },
            seed: Some(11),
        };
        let g0 = ring(10);
        let tl = materialize(&cfg, 10, 0, &g0, 10.0).unwrap();
        assert_eq!(tl.len(), 10, "one move per interval");
        let mut g = g0.clone();
        for e in &tl.entries {
            assert!(matches!(e.mutations[0], TopologyMutation::Attach(_, _)));
            apply_mutations(&mut g, &e.mutations);
            assert!(g.is_connected());
        }
        assert_ne!(g, g0, "moves must change the graph");
    }

    #[test]
    fn partition_cuts_then_heals() {
        let cfg = ChurnConfig {
            kind: ChurnKind::PartitionHeal { period: 10.0, downtime: 4.0 },
            seed: Some(5),
        };
        let g0 = random_connected(12, 0.4, 9);
        let mut model = ChurnModel::from_config(&cfg, 12, 0).unwrap();
        assert_eq!(model.next_change(), Some(10.0));
        let mut g = g0.clone();

        let cut = model.step(10.0, &g);
        assert!(cut.iter().all(|m| matches!(m, TopologyMutation::RemoveEdge(_, _))));
        let out = apply_mutations(&mut g, &cut);
        assert!(g.is_connected(), "repair must leave a bridge");
        assert!(out.deferred >= 1, "the last cross link is deferred");
        assert!(g.num_edges() < g0.num_edges());

        assert_eq!(model.next_change(), Some(14.0));
        let heal = model.step(14.0, &g);
        assert!(heal.iter().all(|m| matches!(m, TopologyMutation::AddEdge(_, _))));
        apply_mutations(&mut g, &heal);
        assert_eq!(g.num_edges(), g0.num_edges(), "heal restores every cut link");
        assert_eq!(model.next_change(), Some(20.0), "next partition one period later");
    }

    #[test]
    fn generator_is_deterministic_per_seed() {
        let g0 = random_connected(14, 0.25, 1);
        let a = materialize(&flaky(), 14, 42, &g0, 25.0).unwrap();
        let b = materialize(&flaky(), 14, 42, &g0, 25.0).unwrap();
        assert_eq!(a, b);
        let mut other = flaky();
        other.seed = Some(8);
        let c = materialize(&other, 14, 42, &g0, 25.0).unwrap();
        assert_ne!(a, c, "different seeds must differ");
    }

    #[test]
    fn replay_matches_materialized_evolution() {
        let cfg = ChurnConfig {
            kind: ChurnKind::Mobile { movers: 3, interval: 0.5, degree: 2 },
            seed: Some(21),
        };
        let g0 = random_connected(12, 0.2, 4);
        let tl = materialize(&cfg, 12, 0, &g0, 12.0).unwrap();

        // drive the materialized schedule through a replay model
        let mut model = ChurnModel::replay(tl.clone());
        let mut g = g0.clone();
        while let Some(t) = model.next_change() {
            let muts = model.step(t, &g);
            apply_mutations(&mut g, &muts);
        }

        // and directly through apply_mutations
        let mut g2 = g0.clone();
        for e in &tl.entries {
            apply_mutations(&mut g2, &e.mutations);
        }
        assert_eq!(g, g2);
    }
}
