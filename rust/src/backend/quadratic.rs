//! Exact-gradient least-squares backend.
//!
//! Worker j owns `F_j(w) = ‖A_j w − b_j‖² / (2 m)`; the global objective
//! `F = (1/N) Σ F_j` is strongly convex with a closed-form optimum, so
//! convergence tests can assert against ground truth.  Non-IID data
//! heterogeneity (the paper's ς²) is controlled by drawing each worker's
//! target `b_j` from a per-worker shifted solution.

use super::{Backend, EvalOutput, GradOutput};
use crate::model::ParamVec;
use crate::WorkerId;
use crate::util::Rng64;

/// Per-worker quadratic problems.
pub struct QuadraticBackend {
    dim: usize,
    rows: usize,
    /// `a[w]`: row-major `rows × dim` design matrix.
    a: Vec<Vec<f32>>,
    /// `b[w]`: rows targets.
    b: Vec<Vec<f32>>,
    /// Global least-squares solution (for tests).
    w_star: Vec<f32>,
}

impl QuadraticBackend {
    /// Build `n` worker problems of `dim` unknowns and `rows` equations
    /// each.  `heterogeneity` scales per-worker solution shifts (0 = every
    /// worker shares the same optimum = IID).
    pub fn new(n: usize, dim: usize, rows: usize, heterogeneity: f32, seed: u64) -> Self {
        let mut rng = Rng64::seed_from_u64(seed);
        let normal = |rng: &mut Rng64| -> f32 { rng.normal_f32() };
        // common solution + per-worker shift
        let w0: Vec<f32> = (0..dim).map(|_| normal(&mut rng)).collect();
        let mut a = Vec::with_capacity(n);
        let mut b = Vec::with_capacity(n);
        for _ in 0..n {
            let shift: Vec<f32> =
                (0..dim).map(|_| heterogeneity * normal(&mut rng)).collect();
            let wj: Vec<f32> = w0.iter().zip(&shift).map(|(x, s)| x + s).collect();
            let mut aj = vec![0f32; rows * dim];
            for v in aj.iter_mut() {
                *v = normal(&mut rng) / (dim as f32).sqrt();
            }
            let mut bj = vec![0f32; rows];
            for r in 0..rows {
                let dot: f32 =
                    (0..dim).map(|d| aj[r * dim + d] * wj[d]).sum();
                bj[r] = dot + 0.05 * normal(&mut rng); // observation noise
            }
            a.push(aj);
            b.push(bj);
        }
        // estimate the global optimum by gradient descent on the average
        // objective (cheap: dims are small in tests)
        let mut w_star = vec![0f32; dim];
        for _ in 0..2000 {
            let mut g = vec![0f32; dim];
            for j in 0..n {
                grad_into(&a[j], &b[j], rows, dim, &w_star, &mut g);
            }
            for d in 0..dim {
                w_star[d] -= 0.5 * g[d] / n as f32;
            }
        }
        QuadraticBackend { dim, rows, a, b, w_star }
    }

    /// Ground-truth global optimum (tests).
    pub fn w_star(&self) -> &[f32] {
        &self.w_star
    }

    /// Global objective value at `w`.
    pub fn global_loss(&self, w: &[f32]) -> f32 {
        let n = self.a.len();
        (0..n).map(|j| self.local_loss(j, w)).sum::<f32>() / n as f32
    }

    fn local_loss(&self, j: usize, w: &[f32]) -> f32 {
        let (a, b) = (&self.a[j], &self.b[j]);
        let mut acc = 0f32;
        for r in 0..self.rows {
            let pred: f32 = (0..self.dim).map(|d| a[r * self.dim + d] * w[d]).sum();
            acc += (pred - b[r]) * (pred - b[r]);
        }
        acc / (2.0 * self.rows as f32)
    }
}

/// `g += ∇ ‖A w − b‖²/(2 rows)` accumulated in place.
fn grad_into(a: &[f32], b: &[f32], rows: usize, dim: usize, w: &[f32], g: &mut [f32]) {
    for r in 0..rows {
        let pred: f32 = (0..dim).map(|d| a[r * dim + d] * w[d]).sum();
        let resid = (pred - b[r]) / rows as f32;
        for d in 0..dim {
            g[d] += resid * a[r * dim + d];
        }
    }
}

impl Backend for QuadraticBackend {
    fn dim(&self) -> usize {
        self.dim
    }

    fn init_params(&self, seed: u64) -> ParamVec {
        let mut rng = Rng64::seed_from_u64(seed);
        (0..self.dim).map(|_| rng.normal_f32()).collect()
    }

    fn grad(&mut self, w: WorkerId, params: &[f32]) -> GradOutput {
        let mut g = vec![0f32; self.dim];
        grad_into(&self.a[w], &self.b[w], self.rows, self.dim, params, &mut g);
        GradOutput {
            loss: self.local_loss(w, params),
            grad: g,
            correct: 0,
            examples: self.rows as u32,
        }
    }

    fn eval(&mut self, params: &[f32]) -> EvalOutput {
        let loss = self.global_loss(params);
        // pseudo-accuracy: monotone transform so the curve/table machinery
        // (time-to-accuracy etc.) also works on quadratic workloads
        EvalOutput { loss, accuracy: 1.0 / (1.0 + loss) }
    }

    fn name(&self) -> &'static str {
        "quadratic"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gradient_descent_reaches_w_star() {
        let mut b = QuadraticBackend::new(4, 16, 32, 0.0, 3);
        let mut w = b.init_params(1);
        for _ in 0..500 {
            // full-batch averaged gradient across workers
            let mut g = vec![0f32; 16];
            for j in 0..4 {
                let gj = b.grad(j, &w).grad;
                for d in 0..16 {
                    g[d] += gj[d] / 4.0;
                }
            }
            for d in 0..16 {
                w[d] -= 0.5 * g[d];
            }
        }
        let dist: f32 = w
            .iter()
            .zip(b.w_star())
            .map(|(x, y)| (x - y) * (x - y))
            .sum::<f32>()
            .sqrt();
        assert!(dist < 0.05, "dist to optimum {dist}");
    }

    #[test]
    fn heterogeneity_increases_local_disagreement() {
        let mut iid = QuadraticBackend::new(8, 8, 16, 0.0, 5);
        let mut het = QuadraticBackend::new(8, 8, 16, 2.0, 5);
        let w = vec![0f32; 8];
        let spread = |b: &mut QuadraticBackend| -> f32 {
            let grads: Vec<Vec<f32>> = (0..8).map(|j| b.grad(j, &w).grad).collect();
            let mean: Vec<f32> = (0..8)
                .map(|d| grads.iter().map(|g| g[d]).sum::<f32>() / 8.0)
                .collect();
            grads
                .iter()
                .map(|g| {
                    g.iter()
                        .zip(&mean)
                        .map(|(a, b)| (a - b) * (a - b))
                        .sum::<f32>()
                        .sqrt()
                })
                .sum::<f32>()
                / 8.0
        };
        assert!(spread(&mut het) > 2.0 * spread(&mut iid));
    }

    #[test]
    fn eval_monotone_in_loss() {
        let mut b = QuadraticBackend::new(2, 4, 8, 0.0, 7);
        let good = b.eval(&b.w_star().to_vec());
        let bad = b.eval(&vec![10.0; 4]);
        assert!(good.loss < bad.loss);
        assert!(good.accuracy > bad.accuracy);
    }
}
