//! PJRT gradient backend: the production path.
//!
//! Wraps [`crate::runtime::ModelRuntime`] with per-worker data shards so
//! the engine's `grad`/`eval` calls execute the AOT JAX/Pallas artifacts.
//! MLP variants train on synthetic classification data; transformer
//! variants train on the embedded character corpus.

use super::{Backend, EvalOutput, GradOutput};
use crate::data::{
    partition_iid, partition_noniid_shards, CharCorpus, SyntheticClassification,
    WorkerShard, SHAKESPEARE_EXCERPT,
};
use crate::model::{init_params, LayoutEntry, ParamVec};
use crate::runtime::{BatchInput, ModelRuntime};
use crate::WorkerId;
use anyhow::Result;
use std::path::Path;

enum TaskData {
    Classification { data: SyntheticClassification, eval_indices: Vec<usize> },
    Chars { corpus: CharCorpus, eval_positions: Vec<usize> },
}

/// PJRT-executing backend.
pub struct PjrtBackend {
    runtime: ModelRuntime,
    task: TaskData,
    shards: Vec<WorkerShard>,
    layout: Vec<LayoutEntry>,
    /// Cumulative seconds spent inside PJRT execute calls (perf metric).
    pub execute_seconds: f64,
    /// Number of train-step executions.
    pub train_calls: u64,
}

impl PjrtBackend {
    /// Load artifacts for `variant` and shard the matching task data.
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        artifacts_dir: &Path,
        variant: &str,
        n_workers: usize,
        n_samples: usize,
        separation: f32,
        iid: bool,
        classes_per_worker: usize,
        seed: u64,
    ) -> Result<Self> {
        let runtime = ModelRuntime::load(artifacts_dir, variant)?;
        let meta = &runtime.meta;
        let layout: Vec<LayoutEntry> = meta
            .layout
            .iter()
            .map(|(name, shape)| LayoutEntry { name: name.clone(), shape: shape.clone() })
            .collect();
        let (task, shards) = if meta.kind == "mlp" {
            let eval_n = 256.min(n_samples / 4).max(64);
            let data = SyntheticClassification::generate(
                n_samples + eval_n,
                meta.input_dim,
                meta.num_classes,
                separation,
                seed,
            );
            let train_labels: Vec<i32> = data.labels()[..n_samples].to_vec();
            let part = if iid {
                partition_iid(n_samples, n_workers, seed ^ 1)
            } else {
                partition_noniid_shards(
                    &train_labels,
                    n_workers,
                    meta.num_classes,
                    classes_per_worker,
                    seed ^ 1,
                )
            };
            let shards: Vec<WorkerShard> = part
                .assignment
                .into_iter()
                .enumerate()
                .map(|(w, idx)| WorkerShard::new(idx, seed ^ ((w as u64) << 8)))
                .collect();
            let eval_indices = (n_samples..n_samples + eval_n).collect();
            (TaskData::Classification { data, eval_indices }, shards)
        } else {
            let corpus = CharCorpus::new(SHAKESPEARE_EXCERPT, meta.seq_len);
            let shards = corpus.shards(n_workers, seed ^ 2);
            // spread eval windows across the whole corpus
            let total = corpus.len();
            let eval_positions: Vec<usize> =
                (0..meta.batch).map(|i| i * total / meta.batch).collect();
            (TaskData::Chars { corpus, eval_positions }, shards)
        };
        Ok(PjrtBackend {
            runtime,
            task,
            shards,
            layout,
            execute_seconds: 0.0,
            train_calls: 0,
        })
    }

    /// PJRT platform (diagnostics).
    pub fn platform(&self) -> String {
        self.runtime.platform()
    }

    fn eval_batch(&self) -> (BatchOwned, Vec<i32>) {
        match &self.task {
            TaskData::Classification { data, eval_indices } => {
                // eval artifact batch is fixed: take the first `batch`
                let b = self.runtime.meta.batch;
                let idx = &eval_indices[..b.min(eval_indices.len())];
                let (x, y) = data.gather(idx);
                (BatchOwned::Features(x), y)
            }
            TaskData::Chars { corpus, eval_positions } => {
                let (x, y) = corpus.gather(eval_positions);
                (BatchOwned::Tokens(x), y)
            }
        }
    }
}

/// Owned batch storage matching [`BatchInput`].
enum BatchOwned {
    Features(Vec<f32>),
    Tokens(Vec<i32>),
}

impl BatchOwned {
    fn as_input(&self) -> BatchInput<'_> {
        match self {
            BatchOwned::Features(f) => BatchInput::Features(f),
            BatchOwned::Tokens(t) => BatchInput::Tokens(t),
        }
    }
}

impl Backend for PjrtBackend {
    fn dim(&self) -> usize {
        self.runtime.meta.padded_dim
    }

    fn init_params(&self, seed: u64) -> ParamVec {
        init_params(&self.layout, self.runtime.meta.padded_dim, seed)
    }

    fn grad(&mut self, w: WorkerId, params: &[f32]) -> GradOutput {
        let b = self.runtime.meta.batch;
        let (batch, y) = match &mut self.task {
            TaskData::Classification { data, .. } => {
                let idx = self.shards[w].next_batch(b);
                let (x, y) = data.gather(&idx);
                (BatchOwned::Features(x), y)
            }
            TaskData::Chars { corpus, .. } => {
                let pos = self.shards[w].next_batch(b);
                let (x, y) = corpus.gather(&pos);
                (BatchOwned::Tokens(x), y)
            }
        };
        // pallas-lint: allow(no-wall-clock) — host-side kernel-time diagnostic; never enters virtual time
        let t0 = std::time::Instant::now();
        let out = self
            .runtime
            .train_step(params, &batch.as_input(), &y)
            .expect("PJRT train step failed");
        self.execute_seconds += t0.elapsed().as_secs_f64();
        self.train_calls += 1;
        let examples = y.len() as u32;
        GradOutput {
            loss: out.loss,
            grad: out.grad,
            correct: out.correct.max(0) as u32,
            examples,
        }
    }

    fn eval(&mut self, params: &[f32]) -> EvalOutput {
        let (batch, y) = self.eval_batch();
        // pallas-lint: allow(no-wall-clock) — host-side kernel-time diagnostic; never enters virtual time
        let t0 = std::time::Instant::now();
        let (loss, correct) = self
            .runtime
            .eval_step(params, &batch.as_input(), &y)
            .expect("PJRT eval step failed");
        self.execute_seconds += t0.elapsed().as_secs_f64();
        EvalOutput { loss, accuracy: correct.max(0) as f32 / y.len() as f32 }
    }

    fn gossip_average(&mut self, rows: &[&[f32]], weights: &[f32]) -> Option<Vec<f32>> {
        if rows.len() > self.runtime.gossip_fanout {
            return None;
        }
        // pallas-lint: allow(no-wall-clock) — host-side kernel-time diagnostic; never enters virtual time
        let t0 = std::time::Instant::now();
        let out = self.runtime.gossip_average(rows, weights).ok();
        self.execute_seconds += t0.elapsed().as_secs_f64();
        out
    }

    fn name(&self) -> &'static str {
        "pjrt"
    }
}
