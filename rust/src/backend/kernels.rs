//! Cache-blocked, autovectorizable matmul kernels for the native MLP
//! forward–backward pass.
//!
//! Every kernel here is **bitwise equal** to the scalar reference path
//! ([`crate::backend::NativeMlpBackend::fwd_bwd_reference`]) by
//! construction: for each output element, the floating-point accumulation
//! order — and the exact set of skipped zero terms — is identical to the
//! scalar loops, so blocking reorders *memory traffic*, never *math*.
//! The invariants each kernel preserves:
//!
//! * **Forward** ([`matmul_bias_act`]): `out[r][o]` starts at `bias[o]`
//!   and accumulates `x[r][a] · w[a][o]` over `a` ascending, skipping
//!   terms where `x[r][a] == 0.0` (exactly the scalar skip — `-0.0`
//!   counts as zero there too).  The fused ReLU applies `v < 0.0 → 0.0`
//!   at store, the same predicate as the scalar post-pass (so `-0.0`
//!   survives unchanged in both).
//! * **dW** ([`matmul_at_delta`]): `gw[a][o]` accumulates
//!   `act[r][a] · delta[r][o]` over `r` ascending, skipping rows where
//!   `act[r][a] == 0.0`.  A register accumulator starting at `+0.0` and
//!   stored once is bitwise the same as the scalar's in-place `+=` into a
//!   zeroed buffer (an accumulation from `+0.0` can never produce `-0.0`
//!   that in-place addition would avoid, and untouched elements store the
//!   untouched `+0.0`).
//! * **dprev** ([`matmul_delta_wt`]): `dprev[r][a]` accumulates
//!   `wt[k][a] · delta[r][k]` over `k` ascending with *no* skip — the
//!   scalar dot product adds every `w[a][k] · delta[r][k]` term, zeros
//!   included (a skipped `±0.0` product can flip the sign of a zero
//!   accumulator, so the blocked kernel must add them too).  The caller
//!   passes `w` pre-transposed so the inner loop is a contiguous
//!   elementwise FMA over `a` (vectorizable) instead of a serial dot
//!   reduction (not).  The ReLU mask (`act[r][a] > 0.0`) applies after,
//!   forcing masked entries to the scalar's untouched `+0.0`.
//!
//! Block sizes: [`MR`] batch rows × [`NR`] output columns per register
//! tile.  Full tiles take a constant-bound microkernel the compiler
//! unrolls and vectorizes; edge tiles (batch not a multiple of `MR`,
//! output dim not a multiple of `NR` — e.g. the 10-class logit layer)
//! take the same code shape with runtime bounds.  The reference-parity
//! suite (`rust/tests/backend_parity.rs`) fuzzes both paths against the
//! scalar reference across every `MlpShape` variant and asserts exact
//! bit equality.

/// Batch rows per register tile.
pub const MR: usize = 4;
/// Output columns per register tile.
pub const NR: usize = 16;

/// Blocked `out[b, dn] = x[b, di] @ w[di, dn] + bias`, with a fused ReLU
/// at store when `relu` is set.  Bitwise equal to the scalar
/// `matmul_add_bias` + ReLU post-pass (see module docs).
pub fn matmul_bias_act(
    x: &[f32],
    w: &[f32],
    bias: &[f32],
    b: usize,
    di: usize,
    dn: usize,
    relu: bool,
    out: &mut [f32],
) {
    debug_assert_eq!(x.len(), b * di);
    debug_assert_eq!(w.len(), di * dn);
    debug_assert_eq!(bias.len(), dn);
    debug_assert_eq!(out.len(), b * dn);
    let mut r0 = 0;
    while r0 < b {
        let mr = MR.min(b - r0);
        let mut o0 = 0;
        while o0 < dn {
            let nr = NR.min(dn - o0);
            if mr == MR && nr == NR {
                fwd_tile_full(x, w, bias, di, dn, r0, o0, relu, out);
            } else {
                fwd_tile_edge(x, w, bias, di, dn, r0, o0, mr, nr, relu, out);
            }
            o0 += NR;
        }
        r0 += MR;
    }
}

/// Full `MR × NR` forward tile: accumulators live in registers, each
/// loaded `w` row feeds all `MR` batch rows.  Constant loop bounds let
/// the compiler unroll and vectorize the inner FMA.
#[inline]
fn fwd_tile_full(
    x: &[f32],
    w: &[f32],
    bias: &[f32],
    di: usize,
    dn: usize,
    r0: usize,
    o0: usize,
    relu: bool,
    out: &mut [f32],
) {
    let mut acc = [[0f32; NR]; MR];
    for row in acc.iter_mut() {
        row.copy_from_slice(&bias[o0..o0 + NR]);
    }
    for a in 0..di {
        let wrow = &w[a * dn + o0..a * dn + o0 + NR];
        for (r, accr) in acc.iter_mut().enumerate() {
            let xv = x[(r0 + r) * di + a];
            if xv == 0.0 {
                continue; // identical to the scalar zero-skip
            }
            for (c, &wv) in accr.iter_mut().zip(wrow) {
                *c += xv * wv;
            }
        }
    }
    for (r, accr) in acc.iter().enumerate() {
        let orow = &mut out[(r0 + r) * dn + o0..(r0 + r) * dn + o0 + NR];
        for (o, &v) in orow.iter_mut().zip(accr) {
            *o = if relu && v < 0.0 { 0.0 } else { v };
        }
    }
}

/// Edge forward tile (`mr ≤ MR`, `nr ≤ NR` with at least one strict):
/// same accumulation order as the full tile, runtime bounds.
fn fwd_tile_edge(
    x: &[f32],
    w: &[f32],
    bias: &[f32],
    di: usize,
    dn: usize,
    r0: usize,
    o0: usize,
    mr: usize,
    nr: usize,
    relu: bool,
    out: &mut [f32],
) {
    let mut acc = [[0f32; NR]; MR];
    for row in acc.iter_mut().take(mr) {
        row[..nr].copy_from_slice(&bias[o0..o0 + nr]);
    }
    for a in 0..di {
        let wrow = &w[a * dn + o0..a * dn + o0 + nr];
        for (r, accr) in acc.iter_mut().enumerate().take(mr) {
            let xv = x[(r0 + r) * di + a];
            if xv == 0.0 {
                continue;
            }
            for (c, &wv) in accr[..nr].iter_mut().zip(wrow) {
                *c += xv * wv;
            }
        }
    }
    for (r, accr) in acc.iter().enumerate().take(mr) {
        let orow = &mut out[(r0 + r) * dn + o0..(r0 + r) * dn + o0 + nr];
        for (o, &v) in orow.iter_mut().zip(&accr[..nr]) {
            *o = if relu && v < 0.0 { 0.0 } else { v };
        }
    }
}

/// Blocked `gw[di, dn] = act[b, di]ᵀ @ delta[b, dn]` (the weight
/// gradient).  `gw` is *assigned* (not accumulated into); callers pass
/// the weight block of the flat gradient buffer.  Bitwise equal to the
/// scalar `r`-outer accumulation with its `act == 0.0` row skip.
pub fn matmul_at_delta(
    act: &[f32],
    delta: &[f32],
    b: usize,
    di: usize,
    dn: usize,
    gw: &mut [f32],
) {
    debug_assert_eq!(act.len(), b * di);
    debug_assert_eq!(delta.len(), b * dn);
    debug_assert_eq!(gw.len(), di * dn);
    let mut a0 = 0;
    while a0 < di {
        let ma = MR.min(di - a0);
        let mut o0 = 0;
        while o0 < dn {
            let nr = NR.min(dn - o0);
            let mut acc = [[0f32; NR]; MR];
            for r in 0..b {
                let drow = &delta[r * dn + o0..r * dn + o0 + nr];
                for (ai, accr) in acc.iter_mut().enumerate().take(ma) {
                    let av = act[r * di + a0 + ai];
                    if av == 0.0 {
                        continue; // identical to the scalar zero-skip
                    }
                    for (c, &dv) in accr[..nr].iter_mut().zip(drow) {
                        *c += av * dv;
                    }
                }
            }
            for (ai, accr) in acc.iter().enumerate().take(ma) {
                gw[(a0 + ai) * dn + o0..(a0 + ai) * dn + o0 + nr]
                    .copy_from_slice(&accr[..nr]);
            }
            o0 += NR;
        }
        a0 += MR;
    }
}

/// Transpose `w[di, dn]` into `wt[dn, di]` (`wt[k][a] = w[a][k]`) —
/// the one-off per-layer cost that turns the backward `delta @ Wᵀ`
/// dot-product reduction into a contiguous vectorizable FMA.
pub fn transpose_into(w: &[f32], di: usize, dn: usize, wt: &mut [f32]) {
    debug_assert_eq!(w.len(), di * dn);
    debug_assert_eq!(wt.len(), di * dn);
    for a in 0..di {
        for k in 0..dn {
            wt[k * di + a] = w[a * dn + k];
        }
    }
}

/// `dprev[b, di] = (delta[b, dn] @ wt[dn, di]ᵀ-of-transpose) ⊙ relu'(act)`:
/// the input-gradient matmul over the *pre-transposed* weights, masked by
/// the forward activations (`act[r][a] > 0.0` keeps the value, anything
/// else forces `+0.0` — exactly the scalar's skip-leaves-zero).  The
/// per-element accumulation runs over `k` ascending with no zero-skip,
/// matching the scalar dot product term for term.
pub fn matmul_delta_wt(
    delta: &[f32],
    wt: &[f32],
    act: &[f32],
    b: usize,
    di: usize,
    dn: usize,
    dprev: &mut [f32],
) {
    debug_assert_eq!(delta.len(), b * dn);
    debug_assert_eq!(wt.len(), di * dn);
    debug_assert_eq!(act.len(), b * di);
    debug_assert_eq!(dprev.len(), b * di);
    for r in 0..b {
        let prow = &mut dprev[r * di..(r + 1) * di];
        prow.fill(0.0);
        let drow = &delta[r * dn..(r + 1) * dn];
        for (k, &dv) in drow.iter().enumerate() {
            let wtrow = &wt[k * di..(k + 1) * di];
            // One k per pass keeps the per-element order identical to
            // the scalar dot product (pairing two k's would reassociate
            // the sum and break bit parity).
            for (p, &wv) in prow.iter_mut().zip(wtrow) {
                *p += wv * dv;
            }
        }
        let arow = &act[r * di..(r + 1) * di];
        for (p, &av) in prow.iter_mut().zip(arow) {
            if !(av > 0.0) {
                *p = 0.0;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng64;

    fn fill(rng: &mut Rng64, n: usize, zero_every: usize) -> Vec<f32> {
        (0..n)
            .map(|i| {
                if zero_every > 0 && i % zero_every == 0 {
                    0.0
                } else {
                    rng.normal_f32()
                }
            })
            .collect()
    }

    /// The scalar forward the blocked kernel must match bit for bit.
    fn fwd_reference(
        x: &[f32],
        w: &[f32],
        bias: &[f32],
        b: usize,
        di: usize,
        dn: usize,
        relu: bool,
    ) -> Vec<f32> {
        let mut out = vec![0f32; b * dn];
        for r in 0..b {
            let orow = &mut out[r * dn..(r + 1) * dn];
            orow.copy_from_slice(bias);
            let xrow = &x[r * di..(r + 1) * di];
            for (a, &xv) in xrow.iter().enumerate() {
                if xv == 0.0 {
                    continue;
                }
                let wrow = &w[a * dn..(a + 1) * dn];
                for (o, wv) in orow.iter_mut().zip(wrow) {
                    *o += xv * wv;
                }
            }
        }
        if relu {
            for v in out.iter_mut() {
                if *v < 0.0 {
                    *v = 0.0;
                }
            }
        }
        out
    }

    fn dw_reference(act: &[f32], delta: &[f32], b: usize, di: usize, dn: usize) -> Vec<f32> {
        let mut gw = vec![0f32; di * dn];
        for r in 0..b {
            let arow = &act[r * di..(r + 1) * di];
            let drow = &delta[r * dn..(r + 1) * dn];
            for a in 0..di {
                let av = arow[a];
                if av == 0.0 {
                    continue;
                }
                for (g, d) in gw[a * dn..(a + 1) * dn].iter_mut().zip(drow) {
                    *g += av * d;
                }
            }
        }
        gw
    }

    fn dprev_reference(
        delta: &[f32],
        w: &[f32],
        act: &[f32],
        b: usize,
        di: usize,
        dn: usize,
    ) -> Vec<f32> {
        let mut dprev = vec![0f32; b * di];
        for r in 0..b {
            let drow = &delta[r * dn..(r + 1) * dn];
            let arow = &act[r * di..(r + 1) * di];
            let prow = &mut dprev[r * di..(r + 1) * di];
            for a in 0..di {
                if arow[a] > 0.0 {
                    let wrow = &w[a * dn..(a + 1) * dn];
                    let mut acc = 0f32;
                    for (wv, dv) in wrow.iter().zip(drow) {
                        acc += wv * dv;
                    }
                    prow[a] = acc;
                }
            }
        }
        dprev
    }

    fn assert_bits_eq(a: &[f32], b: &[f32], what: &str) {
        assert_eq!(a.len(), b.len(), "{what}: length");
        for (i, (x, y)) in a.iter().zip(b).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "{what}: element {i}: {x} vs {y}");
        }
    }

    #[test]
    fn forward_matches_scalar_bitwise_over_edge_shapes() {
        let mut rng = Rng64::seed_from_u64(7);
        // (b, di, dn) covering full tiles, tail rows, tail cols, and both
        for &(b, di, dn) in
            &[(4, 16, 16), (1, 3, 10), (5, 32, 16), (7, 13, 10), (32, 128, 64), (3, 1, 1)]
        {
            for relu in [false, true] {
                let x = fill(&mut rng, b * di, 3);
                let w = fill(&mut rng, di * dn, 0);
                let bias = fill(&mut rng, dn, 0);
                let mut out = vec![f32::NAN; b * dn]; // prove every slot is written
                matmul_bias_act(&x, &w, &bias, b, di, dn, relu, &mut out);
                let reference = fwd_reference(&x, &w, &bias, b, di, dn, relu);
                assert_bits_eq(&out, &reference, &format!("fwd b={b} di={di} dn={dn}"));
            }
        }
    }

    #[test]
    fn dw_matches_scalar_bitwise_over_edge_shapes() {
        let mut rng = Rng64::seed_from_u64(8);
        for &(b, di, dn) in &[(4, 16, 16), (1, 3, 10), (5, 32, 16), (7, 13, 10), (16, 30, 10)] {
            // act has zeros (post-ReLU shape) to exercise the skip
            let act = fill(&mut rng, b * di, 2);
            let delta = fill(&mut rng, b * dn, 0);
            let mut gw = vec![f32::NAN; di * dn];
            matmul_at_delta(&act, &delta, b, di, dn, &mut gw);
            let reference = dw_reference(&act, &delta, b, di, dn);
            assert_bits_eq(&gw, &reference, &format!("dW b={b} di={di} dn={dn}"));
        }
    }

    #[test]
    fn dprev_matches_scalar_bitwise_over_edge_shapes() {
        let mut rng = Rng64::seed_from_u64(9);
        for &(b, di, dn) in &[(4, 16, 16), (1, 3, 10), (5, 32, 16), (7, 13, 10), (16, 30, 10)] {
            let delta = fill(&mut rng, b * dn, 5);
            let w = fill(&mut rng, di * dn, 0);
            // negative and zero activations exercise the ReLU mask
            let act = fill(&mut rng, b * di, 2);
            let mut wt = vec![0f32; di * dn];
            transpose_into(&w, di, dn, &mut wt);
            let mut dprev = vec![f32::NAN; b * di];
            matmul_delta_wt(&delta, &wt, &act, b, di, dn, &mut dprev);
            let reference = dprev_reference(&delta, &w, &act, b, di, dn);
            assert_bits_eq(&dprev, &reference, &format!("dprev b={b} di={di} dn={dn}"));
        }
    }

    #[test]
    fn transpose_roundtrips() {
        let mut rng = Rng64::seed_from_u64(10);
        let (di, dn) = (7, 5);
        let w = fill(&mut rng, di * dn, 0);
        let mut wt = vec![0f32; di * dn];
        transpose_into(&w, di, dn, &mut wt);
        let mut back = vec![0f32; di * dn];
        transpose_into(&wt, dn, di, &mut back);
        assert_eq!(w, back);
    }

    #[test]
    fn negative_zero_inputs_are_skipped_like_positive_zero() {
        // -0.0 == 0.0, so the scalar skip treats both as zero; the
        // blocked kernel must too, or a -0.0·w term could flip the sign
        // of a zero accumulator.
        let x = vec![-0.0f32, 2.0];
        let w = vec![-3.0f32, 1.0, 4.0, -1.0]; // 2×2
        let bias = vec![0.0f32, -0.0];
        let mut out = vec![f32::NAN; 2];
        matmul_bias_act(&x, &w, &bias, 1, 2, 2, false, &mut out);
        let reference = fwd_reference(&x, &w, &bias, 1, 2, 2, false);
        assert_bits_eq(&out, &reference, "fwd -0.0");
    }
}
