//! Gradient backends: who computes the local SGD step.
//!
//! * [`PjrtBackend`] — the real path: AOT JAX/Pallas artifacts via PJRT.
//! * [`NativeMlpBackend`] — a rust reimplementation of the `mlp_*`
//!   variants (exact same math, no PJRT), used as the fast comparator in
//!   the table/figure harnesses and the perf baseline.
//! * [`QuadraticBackend`] — per-worker least-squares problems with exact
//!   gradients; used by the convergence-property tests (the theory says
//!   all doubly-stochastic gossip rules drive `‖∇F(w̄)‖ → small`).

pub mod kernels;
mod native_mlp;
mod pjrt;
mod quadratic;

pub use native_mlp::{MlpShape, NativeMlpBackend};
pub use pjrt::PjrtBackend;
pub use quadratic::QuadraticBackend;

use crate::model::ParamVec;
use crate::WorkerId;

/// Result of a local gradient computation.
#[derive(Debug, Clone)]
pub struct GradOutput {
    /// Local mini-batch loss.
    pub loss: f32,
    /// Flat gradient (padded_dim length).
    pub grad: Vec<f32>,
    /// Correct predictions in the mini-batch.
    pub correct: u32,
    /// Mini-batch size (denominator for accuracy).
    pub examples: u32,
}

/// Result of a global evaluation.
#[derive(Debug, Clone, Copy)]
pub struct EvalOutput {
    /// Mean loss over the eval batches.
    pub loss: f32,
    /// Accuracy in [0, 1].
    pub accuracy: f32,
}

/// A gradient/eval provider for the engine.
///
/// Backends are constructed and consumed within a single engine thread
/// (`run_sweep` parallelizes across experiments, not inside one), so no
/// `Send` bound is required — which lets the PJRT client's `Rc` internals
/// live here directly.
pub trait Backend {
    /// Flat (padded) parameter dimension.
    fn dim(&self) -> usize;

    /// Initial parameters for worker `w` (workers may start identical or
    /// not; the paper starts from a common init, seeded here per run).
    fn init_params(&self, seed: u64) -> ParamVec;

    /// Compute worker `w`'s local mini-batch gradient at `params`.
    fn grad(&mut self, w: WorkerId, params: &[f32]) -> GradOutput;

    /// Compute a batch of per-worker gradients, one per `(ws[i],
    /// params[i])` pair, returned in input order.
    ///
    /// Contract: the result must be byte-identical to calling [`grad`]
    /// sequentially for each pair, for every `threads` value — backends
    /// may parallelize internally (up to `threads` OS threads) only if
    /// they can keep that promise (pure per-worker compute, any shared
    /// RNG advanced serially in input order).  The default implementation
    /// is the sequential loop itself.
    ///
    /// [`grad`]: Backend::grad
    fn grad_batch(&mut self, ws: &[WorkerId], params: &[&[f32]], _threads: usize) -> Vec<GradOutput> {
        ws.iter().zip(params).map(|(&w, p)| self.grad(w, p)).collect()
    }

    /// Evaluate `params` globally (held-out or full-data depending on
    /// backend).
    fn eval(&mut self, params: &[f32]) -> EvalOutput;

    /// Parameter payload size in bytes (for communication accounting).
    fn param_bytes(&self) -> u64 {
        4 * self.dim() as u64
    }

    /// Optional accelerated gossip average (PJRT Pallas kernel); `None`
    /// means the engine averages natively.
    fn gossip_average(&mut self, _rows: &[&[f32]], _weights: &[f32]) -> Option<Vec<f32>> {
        None
    }

    /// Backend label for logs.
    fn name(&self) -> &'static str;
}
