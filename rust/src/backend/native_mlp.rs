//! Native rust MLP backend — the same math as the `mlp_*` JAX variants
//! (He init, ReLU hidden layers, softmax cross-entropy) with hand-written
//! backprop.  Used as the PJRT-free comparator in the big table sweeps
//! (N up to 256 workers × thousands of gossip iterations) and as the perf
//! baseline for the runtime benches.

use super::{kernels, Backend, EvalOutput, GradOutput};
use crate::data::{
    partition_iid, partition_noniid_shards, SyntheticClassification, WorkerShard,
};
use crate::model::{init_params, LayoutEntry, ParamVec};
use crate::WorkerId;

/// Configuration mirroring a `model.MODELS` MLP entry.
#[derive(Debug, Clone)]
pub struct MlpShape {
    /// Layer dims, e.g. `[128, 64, 32, 10]`.
    pub dims: Vec<usize>,
    /// Mini-batch size.
    pub batch: usize,
}

impl MlpShape {
    /// The `mlp_small` variant (bench workhorse).
    pub fn small() -> Self {
        MlpShape { dims: vec![128, 64, 32, 10], batch: 32 }
    }

    /// The `mlp_tiny` variant (tests).
    pub fn tiny() -> Self {
        MlpShape { dims: vec![32, 32, 16, 10], batch: 16 }
    }

    /// The paper's 2-NN (Table 3).
    pub fn mlp2nn() -> Self {
        MlpShape { dims: vec![3072, 256, 256, 10], batch: 32 }
    }

    /// Look up by variant name.  An optional `@b<K>` suffix overrides
    /// the mini-batch size (e.g. `mlp_small@b64` — how the ablation
    /// suite sweeps batch without leaving the config surface).
    pub fn by_name(name: &str) -> Option<Self> {
        let (base, batch) = match name.split_once("@b") {
            Some((base, b)) => (base, Some(b.parse::<usize>().ok().filter(|&b| b > 0)?)),
            None => (name, None),
        };
        let mut shape = match base {
            "mlp_tiny" => Self::tiny(),
            "mlp_small" => Self::small(),
            "mlp2nn" => Self::mlp2nn(),
            _ => return None,
        };
        if let Some(b) = batch {
            shape.batch = b;
        }
        Some(shape)
    }

    /// Flat parameter count.
    pub fn dim(&self) -> usize {
        self.dims.windows(2).map(|w| w[0] * w[1] + w[1]).sum()
    }

    /// Padded to the gossip tile multiple (matches python PAD_MULTIPLE).
    pub fn padded_dim(&self) -> usize {
        (self.dim() + 255) / 256 * 256
    }

    /// Layout matching `ModelSpec::param_shapes`.
    pub fn layout(&self) -> Vec<LayoutEntry> {
        let mut out = Vec::new();
        for (i, w) in self.dims.windows(2).enumerate() {
            out.push(LayoutEntry { name: format!("w{i}"), shape: vec![w[0], w[1]] });
            out.push(LayoutEntry { name: format!("b{i}"), shape: vec![w[1]] });
        }
        out
    }
}

/// Native MLP backend over synthetic classification data.
pub struct NativeMlpBackend {
    shape: MlpShape,
    data: SyntheticClassification,
    shards: Vec<WorkerShard>,
    eval_indices: Vec<usize>,
    padded: usize,
}

impl NativeMlpBackend {
    /// Build over a fresh synthetic dataset.
    ///
    /// `iid` selects the partitioner; `classes_per_worker` applies to the
    /// non-IID label-shard split (paper: 5).
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        shape: MlpShape,
        n_workers: usize,
        n_samples: usize,
        separation: f32,
        iid: bool,
        classes_per_worker: usize,
        seed: u64,
    ) -> Self {
        let num_classes = *shape.dims.last().unwrap();
        let input_dim = shape.dims[0];
        // train + held-out eval pool
        let eval_n = 512.min(n_samples / 4).max(64);
        let data = SyntheticClassification::generate(
            n_samples + eval_n,
            input_dim,
            num_classes,
            separation,
            seed,
        );
        let train_labels: Vec<i32> = data.labels()[..n_samples].to_vec();
        let part = if iid {
            partition_iid(n_samples, n_workers, seed ^ 1)
        } else {
            partition_noniid_shards(
                &train_labels,
                n_workers,
                num_classes,
                classes_per_worker,
                seed ^ 1,
            )
        };
        let shards = part
            .assignment
            .into_iter()
            .enumerate()
            .map(|(w, idx)| WorkerShard::new(idx, seed ^ (w as u64) << 8))
            .collect();
        let eval_indices = (n_samples..n_samples + eval_n).collect();
        let padded = shape.padded_dim();
        NativeMlpBackend { shape, data, shards, eval_indices, padded }
    }

    /// Read-only view of the synthetic dataset.  The parity and bench
    /// harnesses use this to gather fixed batches without advancing the
    /// per-worker shard RNGs.
    pub fn dataset(&self) -> &SyntheticClassification {
        &self.data
    }

    /// The model shape this backend was built with.
    pub fn shape(&self) -> &MlpShape {
        &self.shape
    }

    /// Forward + backward over one gathered batch, on the cache-blocked
    /// kernel path ([`super::kernels`]).  Returns
    /// `(loss, grad_flat, correct)`.
    ///
    /// Bitwise equal to [`Self::fwd_bwd_reference`]: the blocked kernels
    /// preserve the scalar path's per-output-element accumulation order
    /// and zero-skip set exactly (see the kernels module docs for the
    /// per-kernel argument), so blocking changes memory traffic, never
    /// math.  `rust/tests/backend_parity.rs` fuzzes the equivalence
    /// across every `MlpShape` variant and batch size.
    pub fn fwd_bwd(&self, params: &[f32], x: &[f32], y: &[i32]) -> (f32, Vec<f32>, u32) {
        let dims = &self.shape.dims;
        let b = y.len();
        let l = dims.len() - 1;
        // slice params
        let mut weights: Vec<&[f32]> = Vec::with_capacity(l);
        let mut biases: Vec<&[f32]> = Vec::with_capacity(l);
        let mut off = 0usize;
        for win in dims.windows(2) {
            let (di, dn) = (win[0], win[1]);
            weights.push(&params[off..off + di * dn]);
            off += di * dn;
            biases.push(&params[off..off + dn]);
            off += dn;
        }
        // forward, keeping activations; ReLU fused into the tile store
        let mut acts: Vec<Vec<f32>> = vec![x.to_vec()];
        for (i, win) in dims.windows(2).enumerate() {
            let (di, dn) = (win[0], win[1]);
            let input = &acts[i];
            let mut out = vec![0f32; b * dn];
            kernels::matmul_bias_act(input, weights[i], biases[i], b, di, dn, i < l - 1, &mut out);
            acts.push(out);
        }
        // softmax CE + dlogits (same elementwise pass as the reference)
        let c = dims[l];
        let logits = &acts[l];
        let mut loss = 0f32;
        let mut correct = 0u32;
        let mut delta = vec![0f32; b * c];
        for r in 0..b {
            let row = &logits[r * c..(r + 1) * c];
            let max = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let mut denom = 0f32;
            for &v in row {
                denom += (v - max).exp();
            }
            let label = y[r] as usize;
            loss += -(row[label] - max - denom.ln());
            let argmax = row
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap()
                .0;
            if argmax == label {
                correct += 1;
            }
            for k in 0..c {
                let p = (row[k] - max).exp() / denom;
                delta[r * c + k] = (p - if k == label { 1.0 } else { 0.0 }) / b as f32;
            }
        }
        loss /= b as f32;
        // backward, blocked: dW by register tile, dprev over a per-layer
        // transposed weight scratch so the inner loop is contiguous
        let mut grad = vec![0f32; self.padded];
        let mut doff = off; // == dim
        debug_assert_eq!(doff, self.shape.dim());
        let mut delta_cur = delta;
        let mut wt_scratch: Vec<f32> = Vec::new();
        for i in (0..l).rev() {
            let (di, dn) = (dims[i], dims[i + 1]);
            doff -= dn; // bias block: db[k] = Σ_r delta[r][k], r ascending
            for r in 0..b {
                let drow = &delta_cur[r * dn..(r + 1) * dn];
                for (g, d) in grad[doff..doff + dn].iter_mut().zip(drow) {
                    *g += *d;
                }
            }
            doff -= di * dn; // weight block: dW = act^T delta
            let act = &acts[i];
            kernels::matmul_at_delta(act, &delta_cur, b, di, dn, &mut grad[doff..doff + di * dn]);
            if i > 0 {
                // delta_prev = (delta @ W^T) * relu'(act_i)
                wt_scratch.resize(di * dn, 0.0);
                kernels::transpose_into(weights[i], di, dn, &mut wt_scratch);
                let mut dprev = vec![0f32; b * di];
                kernels::matmul_delta_wt(&delta_cur, &wt_scratch, act, b, di, dn, &mut dprev);
                delta_cur = dprev;
            }
        }
        (loss, grad, correct)
    }

    /// The original scalar forward + backward, retained verbatim as the
    /// reference the blocked path is proven against (bit for bit) by
    /// `rust/tests/backend_parity.rs` and the in-tree kernel unit tests.
    /// Also the slow side of the `bench engine` compute micro-bench, so
    /// the committed speedup baseline is measured against real code, not
    /// a remembered number.
    pub fn fwd_bwd_reference(&self, params: &[f32], x: &[f32], y: &[i32]) -> (f32, Vec<f32>, u32) {
        let dims = &self.shape.dims;
        let b = y.len();
        let l = dims.len() - 1;
        // slice params
        let mut weights: Vec<&[f32]> = Vec::with_capacity(l);
        let mut biases: Vec<&[f32]> = Vec::with_capacity(l);
        let mut off = 0usize;
        for win in dims.windows(2) {
            let (di, dn) = (win[0], win[1]);
            weights.push(&params[off..off + di * dn]);
            off += di * dn;
            biases.push(&params[off..off + dn]);
            off += dn;
        }
        // forward, keeping activations
        let mut acts: Vec<Vec<f32>> = vec![x.to_vec()];
        for (i, win) in dims.windows(2).enumerate() {
            let (di, dn) = (win[0], win[1]);
            let input = &acts[i];
            let mut out = vec![0f32; b * dn];
            matmul_add_bias(input, weights[i], biases[i], b, di, dn, &mut out);
            if i < l - 1 {
                for v in out.iter_mut() {
                    if *v < 0.0 {
                        *v = 0.0;
                    }
                }
            }
            acts.push(out);
        }
        // softmax CE + dlogits
        let c = dims[l];
        let logits = &acts[l];
        let mut loss = 0f32;
        let mut correct = 0u32;
        let mut delta = vec![0f32; b * c];
        for r in 0..b {
            let row = &logits[r * c..(r + 1) * c];
            let max = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let mut denom = 0f32;
            for &v in row {
                denom += (v - max).exp();
            }
            let label = y[r] as usize;
            loss += -(row[label] - max - denom.ln());
            let argmax = row
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap()
                .0;
            if argmax == label {
                correct += 1;
            }
            for k in 0..c {
                let p = (row[k] - max).exp() / denom;
                delta[r * c + k] = (p - if k == label { 1.0 } else { 0.0 }) / b as f32;
            }
        }
        loss /= b as f32;
        // backward
        let mut grad = vec![0f32; self.padded];
        let mut doff = off; // == dim
        debug_assert_eq!(doff, self.shape.dim());
        let mut delta_cur = delta;
        for i in (0..l).rev() {
            let (di, dn) = (dims[i], dims[i + 1]);
            doff -= dn; // bias block
            for r in 0..b {
                for k in 0..dn {
                    grad[doff + k] += delta_cur[r * dn + k];
                }
            }
            doff -= di * dn; // weight block: dW = act^T delta
            let act = &acts[i];
            for r in 0..b {
                let arow = &act[r * di..(r + 1) * di];
                let drow = &delta_cur[r * dn..(r + 1) * dn];
                for a in 0..di {
                    let av = arow[a];
                    if av == 0.0 {
                        continue;
                    }
                    let gw = &mut grad[doff + a * dn..doff + (a + 1) * dn];
                    for (g, d) in gw.iter_mut().zip(drow) {
                        *g += av * d;
                    }
                }
            }
            if i > 0 {
                // delta_prev = (delta @ W^T) * relu'(act_i)
                let w = weights[i];
                let mut dprev = vec![0f32; b * di];
                for r in 0..b {
                    let drow = &delta_cur[r * dn..(r + 1) * dn];
                    let arow = &acts[i][r * di..(r + 1) * di];
                    let prow = &mut dprev[r * di..(r + 1) * di];
                    for a in 0..di {
                        if arow[a] > 0.0 {
                            let wrow = &w[a * dn..(a + 1) * dn];
                            let mut acc = 0f32;
                            for (wv, dv) in wrow.iter().zip(drow) {
                                acc += wv * dv;
                            }
                            prow[a] = acc;
                        }
                    }
                }
                delta_cur = dprev;
            }
        }
        (loss, grad, correct)
    }
}

/// `out[b, dO] = x[b, dI] @ w[dI, dO] + bias` — the scalar reference
/// kernel, used only by [`NativeMlpBackend::fwd_bwd_reference`].  The
/// fast path lives in [`super::kernels`].
fn matmul_add_bias(
    x: &[f32],
    w: &[f32],
    bias: &[f32],
    b: usize,
    di: usize,
    dn: usize,
    out: &mut [f32],
) {
    for r in 0..b {
        let orow = &mut out[r * dn..(r + 1) * dn];
        orow.copy_from_slice(bias);
        let xrow = &x[r * di..(r + 1) * di];
        for (a, &xv) in xrow.iter().enumerate() {
            if xv == 0.0 {
                continue;
            }
            let wrow = &w[a * dn..(a + 1) * dn];
            for (o, wv) in orow.iter_mut().zip(wrow) {
                *o += xv * wv;
            }
        }
    }
}

impl Backend for NativeMlpBackend {
    fn dim(&self) -> usize {
        self.padded
    }

    fn init_params(&self, seed: u64) -> ParamVec {
        init_params(&self.shape.layout(), self.padded, seed)
    }

    fn grad(&mut self, w: WorkerId, params: &[f32]) -> GradOutput {
        let idx = self.shards[w].next_batch(self.shape.batch);
        let (x, y) = self.data.gather(&idx);
        let (loss, grad, correct) = self.fwd_bwd(params, &x, &y);
        GradOutput { loss, grad, correct, examples: y.len() as u32 }
    }

    fn grad_batch(&mut self, ws: &[WorkerId], params: &[&[f32]], threads: usize) -> Vec<GradOutput> {
        debug_assert_eq!(ws.len(), params.len());
        // Draw every mini-batch serially, in input order: the per-worker
        // shard RNGs advance exactly as N sequential `grad` calls would,
        // independent of the thread count below.
        let jobs: Vec<(Vec<f32>, Vec<i32>)> = ws
            .iter()
            .map(|&w| {
                let idx = self.shards[w].next_batch(self.shape.batch);
                self.data.gather(&idx)
            })
            .collect();
        let threads = threads.max(1).min(jobs.len());
        let this: &NativeMlpBackend = self;
        if threads <= 1 {
            return jobs
                .iter()
                .zip(params)
                .map(|((x, y), p)| {
                    let (loss, grad, correct) = this.fwd_bwd(p, x, y);
                    GradOutput { loss, grad, correct, examples: y.len() as u32 }
                })
                .collect();
        }
        // fwd_bwd is pure (&self, no RNG), so jobs can run on any thread;
        // results land in position-indexed slots, so the output order —
        // and therefore everything downstream — is thread-count-invariant.
        let mut outs: Vec<Option<GradOutput>> = Vec::new();
        outs.resize_with(jobs.len(), || None);
        let chunk = jobs.len().div_ceil(threads);
        std::thread::scope(|s| {
            let mut out_rest: &mut [Option<GradOutput>] = &mut outs;
            let mut job_rest: &[(Vec<f32>, Vec<i32>)] = &jobs;
            let mut par_rest: &[&[f32]] = params;
            while !job_rest.is_empty() {
                let take = chunk.min(job_rest.len());
                let (out_chunk, r) = out_rest.split_at_mut(take);
                out_rest = r;
                let (job_chunk, r) = job_rest.split_at(take);
                job_rest = r;
                let (par_chunk, r) = par_rest.split_at(take);
                par_rest = r;
                s.spawn(move || {
                    for ((slot, (x, y)), p) in
                        out_chunk.iter_mut().zip(job_chunk).zip(par_chunk)
                    {
                        let (loss, grad, correct) = this.fwd_bwd(p, x, y);
                        *slot =
                            Some(GradOutput { loss, grad, correct, examples: y.len() as u32 });
                    }
                });
            }
        });
        outs.into_iter().map(|o| o.expect("every batch slot is filled")).collect()
    }

    fn eval(&mut self, params: &[f32]) -> EvalOutput {
        let (x, y) = self.data.gather(&self.eval_indices);
        let (loss, _, correct) = self.fwd_bwd(params, &x, &y);
        EvalOutput { loss, accuracy: correct as f32 / y.len() as f32 }
    }

    fn name(&self) -> &'static str {
        "native_mlp"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn backend() -> NativeMlpBackend {
        NativeMlpBackend::new(MlpShape::tiny(), 4, 512, 3.0, true, 5, 1)
    }

    #[test]
    fn shapes_match_python_side() {
        // mlp_tiny: 32*32+32 + 32*16+16 + 16*10+10 = 1754, padded 1792
        let s = MlpShape::tiny();
        assert_eq!(s.dim(), 1754);
        assert_eq!(s.padded_dim(), 1792);
        let s = MlpShape::mlp2nn();
        assert_eq!(s.dim(), 855_050);
        assert_eq!(s.padded_dim(), 855_296);
    }

    #[test]
    fn by_name_batch_suffix() {
        let s = MlpShape::by_name("mlp_small@b64").unwrap();
        assert_eq!(s.dims, MlpShape::small().dims);
        assert_eq!(s.batch, 64);
        assert_eq!(MlpShape::by_name("mlp_small").unwrap().batch, MlpShape::small().batch);
        assert!(MlpShape::by_name("mlp_small@b0").is_none());
        assert!(MlpShape::by_name("mlp_small@bx").is_none());
        assert!(MlpShape::by_name("nope@b32").is_none());
    }

    #[test]
    fn numeric_gradient_check() {
        let b = backend();
        let params = b.init_params(3);
        let idx: Vec<usize> = (0..8).collect();
        let (x, y) = b.data.gather(&idx);
        let (_, grad, _) = b.fwd_bwd(&params, &x, &y);
        // check a scattering of coordinates with central differences
        let eps = 1e-2f32;
        for &d in &[0usize, 17, 600, 1200, 1700] {
            let mut p1 = params.clone();
            p1[d] += eps;
            let (l1, _, _) = b.fwd_bwd(&p1, &x, &y);
            let mut p2 = params.clone();
            p2[d] -= eps;
            let (l2, _, _) = b.fwd_bwd(&p2, &x, &y);
            let num = (l1 - l2) / (2.0 * eps);
            assert!(
                (num - grad[d]).abs() < 2e-2 + 0.05 * num.abs(),
                "coord {d}: numeric {num} vs analytic {}",
                grad[d]
            );
        }
    }

    #[test]
    fn sgd_learns() {
        let mut b = backend();
        let mut params = b.init_params(5);
        let before = b.eval(&params);
        for _ in 0..150 {
            let g = b.grad(0, &params);
            crate::model::axpy(&mut params, -0.1, &g.grad);
        }
        let after = b.eval(&params);
        assert!(
            after.loss < before.loss,
            "loss should drop: {} -> {}",
            before.loss,
            after.loss
        );
        assert!(after.accuracy > before.accuracy);
    }

    #[test]
    fn grad_padding_zero_for_every_variant() {
        // every shape variant — including batch sizes that leave tail
        // blocks in the MR×NR tiling — must keep the padding slots at
        // literal +0.0 after a full grad step
        for name in ["mlp_tiny", "mlp_small", "mlp2nn", "mlp_tiny@b1", "mlp_small@b5"] {
            let shape = MlpShape::by_name(name).unwrap();
            let dim = shape.dim();
            let padded = shape.padded_dim();
            let mut b = NativeMlpBackend::new(shape, 4, 512, 3.0, true, 5, 1);
            let params = b.init_params(7);
            let g = b.grad(1, &params);
            assert_eq!(g.grad.len(), padded, "{name}");
            assert!(
                g.grad[dim..].iter().all(|&v| v.to_bits() == 0),
                "{name}: padding tail must be literal +0.0"
            );
        }
    }

    #[test]
    fn blocked_path_matches_reference_smoke() {
        // one quick case here; the full fuzz lives in
        // rust/tests/backend_parity.rs
        let b = backend();
        let params = b.init_params(11);
        let idx: Vec<usize> = (3..3 + 16).collect();
        let (x, y) = b.data.gather(&idx);
        let (loss_f, grad_f, correct_f) = b.fwd_bwd(&params, &x, &y);
        let (loss_r, grad_r, correct_r) = b.fwd_bwd_reference(&params, &x, &y);
        assert_eq!(loss_f.to_bits(), loss_r.to_bits());
        assert_eq!(correct_f, correct_r);
        assert_eq!(grad_f.len(), grad_r.len());
        for (i, (a, r)) in grad_f.iter().zip(&grad_r).enumerate() {
            assert_eq!(a.to_bits(), r.to_bits(), "grad[{i}]: {a} vs {r}");
        }
    }

    #[test]
    fn grad_batch_matches_sequential_grads_any_thread_count() {
        // the batched entry point must be byte-identical to N sequential
        // grad() calls, for every thread count
        for threads in [1usize, 2, 8] {
            let mut seq = backend();
            let mut bat = backend();
            let params: Vec<ParamVec> =
                (0..4).map(|s| seq.init_params(20 + s as u64)).collect();
            let expected: Vec<GradOutput> =
                (0..4).map(|w| seq.grad(w, &params[w])).collect();
            let ws: Vec<WorkerId> = (0..4).collect();
            let views: Vec<&[f32]> = params.iter().map(|p| p.as_slice()).collect();
            let got = bat.grad_batch(&ws, &views, threads);
            assert_eq!(got.len(), expected.len());
            for (w, (g, e)) in got.iter().zip(&expected).enumerate() {
                assert_eq!(g.loss.to_bits(), e.loss.to_bits(), "t={threads} w={w}");
                assert_eq!(g.correct, e.correct);
                assert_eq!(g.examples, e.examples);
                assert!(g
                    .grad
                    .iter()
                    .zip(&e.grad)
                    .all(|(a, b)| a.to_bits() == b.to_bits()));
            }
        }
    }
}
