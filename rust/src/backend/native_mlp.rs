//! Native rust MLP backend — the same math as the `mlp_*` JAX variants
//! (He init, ReLU hidden layers, softmax cross-entropy) with hand-written
//! backprop.  Used as the PJRT-free comparator in the big table sweeps
//! (N up to 256 workers × thousands of gossip iterations) and as the perf
//! baseline for the runtime benches.

use super::{Backend, EvalOutput, GradOutput};
use crate::data::{
    partition_iid, partition_noniid_shards, SyntheticClassification, WorkerShard,
};
use crate::model::{init_params, LayoutEntry, ParamVec};
use crate::WorkerId;

/// Configuration mirroring a `model.MODELS` MLP entry.
#[derive(Debug, Clone)]
pub struct MlpShape {
    /// Layer dims, e.g. `[128, 64, 32, 10]`.
    pub dims: Vec<usize>,
    /// Mini-batch size.
    pub batch: usize,
}

impl MlpShape {
    /// The `mlp_small` variant (bench workhorse).
    pub fn small() -> Self {
        MlpShape { dims: vec![128, 64, 32, 10], batch: 32 }
    }

    /// The `mlp_tiny` variant (tests).
    pub fn tiny() -> Self {
        MlpShape { dims: vec![32, 32, 16, 10], batch: 16 }
    }

    /// The paper's 2-NN (Table 3).
    pub fn mlp2nn() -> Self {
        MlpShape { dims: vec![3072, 256, 256, 10], batch: 32 }
    }

    /// Look up by variant name.  An optional `@b<K>` suffix overrides
    /// the mini-batch size (e.g. `mlp_small@b64` — how the ablation
    /// suite sweeps batch without leaving the config surface).
    pub fn by_name(name: &str) -> Option<Self> {
        let (base, batch) = match name.split_once("@b") {
            Some((base, b)) => (base, Some(b.parse::<usize>().ok().filter(|&b| b > 0)?)),
            None => (name, None),
        };
        let mut shape = match base {
            "mlp_tiny" => Self::tiny(),
            "mlp_small" => Self::small(),
            "mlp2nn" => Self::mlp2nn(),
            _ => return None,
        };
        if let Some(b) = batch {
            shape.batch = b;
        }
        Some(shape)
    }

    /// Flat parameter count.
    pub fn dim(&self) -> usize {
        self.dims.windows(2).map(|w| w[0] * w[1] + w[1]).sum()
    }

    /// Padded to the gossip tile multiple (matches python PAD_MULTIPLE).
    pub fn padded_dim(&self) -> usize {
        (self.dim() + 255) / 256 * 256
    }

    /// Layout matching `ModelSpec::param_shapes`.
    pub fn layout(&self) -> Vec<LayoutEntry> {
        let mut out = Vec::new();
        for (i, w) in self.dims.windows(2).enumerate() {
            out.push(LayoutEntry { name: format!("w{i}"), shape: vec![w[0], w[1]] });
            out.push(LayoutEntry { name: format!("b{i}"), shape: vec![w[1]] });
        }
        out
    }
}

/// Native MLP backend over synthetic classification data.
pub struct NativeMlpBackend {
    shape: MlpShape,
    data: SyntheticClassification,
    shards: Vec<WorkerShard>,
    eval_indices: Vec<usize>,
    padded: usize,
}

impl NativeMlpBackend {
    /// Build over a fresh synthetic dataset.
    ///
    /// `iid` selects the partitioner; `classes_per_worker` applies to the
    /// non-IID label-shard split (paper: 5).
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        shape: MlpShape,
        n_workers: usize,
        n_samples: usize,
        separation: f32,
        iid: bool,
        classes_per_worker: usize,
        seed: u64,
    ) -> Self {
        let num_classes = *shape.dims.last().unwrap();
        let input_dim = shape.dims[0];
        // train + held-out eval pool
        let eval_n = 512.min(n_samples / 4).max(64);
        let data = SyntheticClassification::generate(
            n_samples + eval_n,
            input_dim,
            num_classes,
            separation,
            seed,
        );
        let train_labels: Vec<i32> = data.labels()[..n_samples].to_vec();
        let part = if iid {
            partition_iid(n_samples, n_workers, seed ^ 1)
        } else {
            partition_noniid_shards(
                &train_labels,
                n_workers,
                num_classes,
                classes_per_worker,
                seed ^ 1,
            )
        };
        let shards = part
            .assignment
            .into_iter()
            .enumerate()
            .map(|(w, idx)| WorkerShard::new(idx, seed ^ (w as u64) << 8))
            .collect();
        let eval_indices = (n_samples..n_samples + eval_n).collect();
        let padded = shape.padded_dim();
        NativeMlpBackend { shape, data, shards, eval_indices, padded }
    }

    /// Forward + backward over one gathered batch.  Returns
    /// `(loss, grad_flat, correct)`.
    fn fwd_bwd(&self, params: &[f32], x: &[f32], y: &[i32]) -> (f32, Vec<f32>, u32) {
        let dims = &self.shape.dims;
        let b = y.len();
        let l = dims.len() - 1;
        // slice params
        let mut weights: Vec<&[f32]> = Vec::with_capacity(l);
        let mut biases: Vec<&[f32]> = Vec::with_capacity(l);
        let mut off = 0usize;
        for win in dims.windows(2) {
            let (di, dn) = (win[0], win[1]);
            weights.push(&params[off..off + di * dn]);
            off += di * dn;
            biases.push(&params[off..off + dn]);
            off += dn;
        }
        // forward, keeping activations
        let mut acts: Vec<Vec<f32>> = vec![x.to_vec()];
        for (i, win) in dims.windows(2).enumerate() {
            let (di, dn) = (win[0], win[1]);
            let input = &acts[i];
            let mut out = vec![0f32; b * dn];
            matmul_add_bias(input, weights[i], biases[i], b, di, dn, &mut out);
            if i < l - 1 {
                for v in out.iter_mut() {
                    if *v < 0.0 {
                        *v = 0.0;
                    }
                }
            }
            acts.push(out);
        }
        // softmax CE + dlogits
        let c = dims[l];
        let logits = &acts[l];
        let mut loss = 0f32;
        let mut correct = 0u32;
        let mut delta = vec![0f32; b * c];
        for r in 0..b {
            let row = &logits[r * c..(r + 1) * c];
            let max = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let mut denom = 0f32;
            for &v in row {
                denom += (v - max).exp();
            }
            let label = y[r] as usize;
            loss += -(row[label] - max - denom.ln());
            let argmax = row
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap()
                .0;
            if argmax == label {
                correct += 1;
            }
            for k in 0..c {
                let p = (row[k] - max).exp() / denom;
                delta[r * c + k] = (p - if k == label { 1.0 } else { 0.0 }) / b as f32;
            }
        }
        loss /= b as f32;
        // backward
        let mut grad = vec![0f32; self.padded];
        let mut doff = off; // == dim
        debug_assert_eq!(doff, self.shape.dim());
        let mut delta_cur = delta;
        for i in (0..l).rev() {
            let (di, dn) = (dims[i], dims[i + 1]);
            doff -= dn; // bias block
            for r in 0..b {
                for k in 0..dn {
                    grad[doff + k] += delta_cur[r * dn + k];
                }
            }
            doff -= di * dn; // weight block: dW = act^T delta
            let act = &acts[i];
            for r in 0..b {
                let arow = &act[r * di..(r + 1) * di];
                let drow = &delta_cur[r * dn..(r + 1) * dn];
                for a in 0..di {
                    let av = arow[a];
                    if av == 0.0 {
                        continue;
                    }
                    let gw = &mut grad[doff + a * dn..doff + (a + 1) * dn];
                    for (g, d) in gw.iter_mut().zip(drow) {
                        *g += av * d;
                    }
                }
            }
            if i > 0 {
                // delta_prev = (delta @ W^T) * relu'(act_i)
                let w = weights[i];
                let mut dprev = vec![0f32; b * di];
                for r in 0..b {
                    let drow = &delta_cur[r * dn..(r + 1) * dn];
                    let arow = &acts[i][r * di..(r + 1) * di];
                    let prow = &mut dprev[r * di..(r + 1) * di];
                    for a in 0..di {
                        if arow[a] > 0.0 {
                            let wrow = &w[a * dn..(a + 1) * dn];
                            let mut acc = 0f32;
                            for (wv, dv) in wrow.iter().zip(drow) {
                                acc += wv * dv;
                            }
                            prow[a] = acc;
                        }
                    }
                }
                delta_cur = dprev;
            }
        }
        (loss, grad, correct)
    }
}

/// `out[b, dO] = x[b, dI] @ w[dI, dO] + bias`.
fn matmul_add_bias(
    x: &[f32],
    w: &[f32],
    bias: &[f32],
    b: usize,
    di: usize,
    dn: usize,
    out: &mut [f32],
) {
    for r in 0..b {
        let orow = &mut out[r * dn..(r + 1) * dn];
        orow.copy_from_slice(bias);
        let xrow = &x[r * di..(r + 1) * di];
        for (a, &xv) in xrow.iter().enumerate() {
            if xv == 0.0 {
                continue;
            }
            let wrow = &w[a * dn..(a + 1) * dn];
            for (o, wv) in orow.iter_mut().zip(wrow) {
                *o += xv * wv;
            }
        }
    }
}

impl Backend for NativeMlpBackend {
    fn dim(&self) -> usize {
        self.padded
    }

    fn init_params(&self, seed: u64) -> ParamVec {
        init_params(&self.shape.layout(), self.padded, seed)
    }

    fn grad(&mut self, w: WorkerId, params: &[f32]) -> GradOutput {
        let idx = self.shards[w].next_batch(self.shape.batch);
        let (x, y) = self.data.gather(&idx);
        let (loss, grad, correct) = self.fwd_bwd(params, &x, &y);
        GradOutput { loss, grad, correct, examples: y.len() as u32 }
    }

    fn eval(&mut self, params: &[f32]) -> EvalOutput {
        let (x, y) = self.data.gather(&self.eval_indices);
        let (loss, _, correct) = self.fwd_bwd(params, &x, &y);
        EvalOutput { loss, accuracy: correct as f32 / y.len() as f32 }
    }

    fn name(&self) -> &'static str {
        "native_mlp"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn backend() -> NativeMlpBackend {
        NativeMlpBackend::new(MlpShape::tiny(), 4, 512, 3.0, true, 5, 1)
    }

    #[test]
    fn shapes_match_python_side() {
        // mlp_tiny: 32*32+32 + 32*16+16 + 16*10+10 = 1754, padded 1792
        let s = MlpShape::tiny();
        assert_eq!(s.dim(), 1754);
        assert_eq!(s.padded_dim(), 1792);
        let s = MlpShape::mlp2nn();
        assert_eq!(s.dim(), 855_050);
        assert_eq!(s.padded_dim(), 855_296);
    }

    #[test]
    fn by_name_batch_suffix() {
        let s = MlpShape::by_name("mlp_small@b64").unwrap();
        assert_eq!(s.dims, MlpShape::small().dims);
        assert_eq!(s.batch, 64);
        assert_eq!(MlpShape::by_name("mlp_small").unwrap().batch, MlpShape::small().batch);
        assert!(MlpShape::by_name("mlp_small@b0").is_none());
        assert!(MlpShape::by_name("mlp_small@bx").is_none());
        assert!(MlpShape::by_name("nope@b32").is_none());
    }

    #[test]
    fn numeric_gradient_check() {
        let b = backend();
        let params = b.init_params(3);
        let idx: Vec<usize> = (0..8).collect();
        let (x, y) = b.data.gather(&idx);
        let (_, grad, _) = b.fwd_bwd(&params, &x, &y);
        // check a scattering of coordinates with central differences
        let eps = 1e-2f32;
        for &d in &[0usize, 17, 600, 1200, 1700] {
            let mut p1 = params.clone();
            p1[d] += eps;
            let (l1, _, _) = b.fwd_bwd(&p1, &x, &y);
            let mut p2 = params.clone();
            p2[d] -= eps;
            let (l2, _, _) = b.fwd_bwd(&p2, &x, &y);
            let num = (l1 - l2) / (2.0 * eps);
            assert!(
                (num - grad[d]).abs() < 2e-2 + 0.05 * num.abs(),
                "coord {d}: numeric {num} vs analytic {}",
                grad[d]
            );
        }
    }

    #[test]
    fn sgd_learns() {
        let mut b = backend();
        let mut params = b.init_params(5);
        let before = b.eval(&params);
        for _ in 0..150 {
            let g = b.grad(0, &params);
            crate::model::axpy(&mut params, -0.1, &g.grad);
        }
        let after = b.eval(&params);
        assert!(
            after.loss < before.loss,
            "loss should drop: {} -> {}",
            before.loss,
            after.loss
        );
        assert!(after.accuracy > before.accuracy);
    }

    #[test]
    fn grad_padding_zero() {
        let mut b = backend();
        let params = b.init_params(7);
        let g = b.grad(1, &params);
        assert_eq!(g.grad.len(), 1792);
        assert!(g.grad[1754..].iter().all(|&v| v == 0.0));
    }
}
