//! Flat parameter vectors and their initialization.
//!
//! Workers gossip whole flat f32 vectors (padded to the gossip-kernel tile
//! multiple — see `python/compile/model.py`).  Initialization mirrors the
//! JAX side: He-scaled normals for weight matrices, zeros for biases,
//! ones for LayerNorm gains, driven by the manifest's layout table.

use crate::util::Rng64;

/// Named tensor layout entry from the manifest.
#[derive(Debug, Clone)]
pub struct LayoutEntry {
    /// Parameter name, e.g. `"w0"` or `"l1.wqkv"`.
    pub name: String,
    /// Tensor shape.
    pub shape: Vec<usize>,
}

impl LayoutEntry {
    /// Element count.
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

/// A worker's flat parameter (or gradient) vector.
pub type ParamVec = Vec<f32>;

/// Whether a leaf tensor name denotes a bias: exactly `b<digits>` (the
/// manifest's per-layer `b0`, `b1`, …) or a `_b` suffix.  A bare
/// `starts_with('b')` test is wrong — it classifies weight tensors like
/// `beta` or `base` as biases and silently zero-initializes them.
fn is_bias_leaf(leaf: &str) -> bool {
    if leaf.ends_with("_b") {
        return true;
    }
    match leaf.strip_prefix('b') {
        Some(rest) => !rest.is_empty() && rest.bytes().all(|c| c.is_ascii_digit()),
        None => false,
    }
}

/// He-style init over a layout, padded with zeros to `padded_dim`.
///
/// Weight tensors (rank ≥ 2 or names not matching bias/gain patterns) get
/// `N(0, 2/fan_in)`; biases (`b<digits>` / `*_b`) and positional tables
/// get zeros; LayerNorm gains (`*_g`) get ones — mirroring
/// `model.init_params` on the JAX side.
pub fn init_params(layout: &[LayoutEntry], padded_dim: usize, seed: u64) -> ParamVec {
    let mut rng = Rng64::seed_from_u64(seed);
    let mut out = Vec::with_capacity(padded_dim);
    for entry in layout {
        let leaf = entry.name.rsplit('.').next().unwrap_or(&entry.name);
        let n = entry.numel();
        if leaf.ends_with("_g") {
            out.extend(std::iter::repeat(1.0f32).take(n));
        } else if is_bias_leaf(leaf) || leaf == "pos" {
            out.extend(std::iter::repeat(0.0f32).take(n));
        } else {
            let fan_in = entry.shape[0].max(1);
            let scale = (2.0 / fan_in as f32).sqrt();
            for _ in 0..n {
                out.push(rng.normal_f32() * scale);
            }
        }
    }
    assert!(out.len() <= padded_dim, "layout exceeds padded_dim");
    out.resize(padded_dim, 0.0);
    out
}

/// `y += alpha * x` over equal-length slices (the SGD apply).
#[inline]
pub fn axpy(y: &mut [f32], alpha: f32, x: &[f32]) {
    debug_assert_eq!(y.len(), x.len());
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi += alpha * xi;
    }
}

/// Euclidean norm.
pub fn l2_norm(x: &[f32]) -> f32 {
    x.iter().map(|v| v * v).sum::<f32>().sqrt()
}

/// Mean of several equal-length vectors (consensus diagnostics).
pub fn mean_of(vectors: &[&[f32]]) -> ParamVec {
    assert!(!vectors.is_empty());
    let d = vectors[0].len();
    let mut out = vec![0f32; d];
    for v in vectors {
        debug_assert_eq!(v.len(), d);
        for (o, x) in out.iter_mut().zip(*v) {
            *o += x;
        }
    }
    let inv = 1.0 / vectors.len() as f32;
    for o in out.iter_mut() {
        *o *= inv;
    }
    out
}

/// Max pairwise L2 distance from the mean — the consensus gap
/// `max_j ||w_j − w̄||` that Theorem 1's proof bounds.
pub fn consensus_gap(vectors: &[&[f32]]) -> f32 {
    let mean = mean_of(vectors);
    vectors
        .iter()
        .map(|v| {
            v.iter()
                .zip(&mean)
                .map(|(a, b)| (a - b) * (a - b))
                .sum::<f32>()
                .sqrt()
        })
        .fold(0.0, f32::max)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn layout() -> Vec<LayoutEntry> {
        vec![
            LayoutEntry { name: "w0".into(), shape: vec![8, 4] },
            LayoutEntry { name: "b0".into(), shape: vec![4] },
            LayoutEntry { name: "l0.ln1_g".into(), shape: vec![4] },
            LayoutEntry { name: "pos".into(), shape: vec![2, 4] },
        ]
    }

    #[test]
    fn init_respects_layout_roles() {
        let p = init_params(&layout(), 64, 1);
        assert_eq!(p.len(), 64);
        // bias zeros
        assert!(p[32..36].iter().all(|&v| v == 0.0));
        // gains ones
        assert!(p[36..40].iter().all(|&v| v == 1.0));
        // pos zeros
        assert!(p[40..48].iter().all(|&v| v == 0.0));
        // padding zeros
        assert!(p[48..].iter().all(|&v| v == 0.0));
        // weights non-degenerate
        assert!(l2_norm(&p[..32]) > 0.1);
    }

    #[test]
    fn b_prefixed_weights_are_not_biases() {
        // regression: a weight tensor named `beta` (or `l0.base`) used to
        // match the bias pattern and silently train from zeros
        let layout = vec![
            LayoutEntry { name: "beta".into(), shape: vec![8, 4] },
            LayoutEntry { name: "l0.base".into(), shape: vec![4, 4] },
            LayoutEntry { name: "b1".into(), shape: vec![4] },
            LayoutEntry { name: "l0.attn_b".into(), shape: vec![4] },
        ];
        let p = init_params(&layout, 64, 1);
        assert!(l2_norm(&p[..32]) > 0.1, "`beta` must get He init, not zeros");
        assert!(l2_norm(&p[32..48]) > 0.1, "`base` must get He init, not zeros");
        assert!(p[48..52].iter().all(|&v| v == 0.0), "`b1` stays a zero-init bias");
        assert!(p[52..56].iter().all(|&v| v == 0.0), "`_b` suffix stays a zero-init bias");
        // the classifier itself: digits-only after `b`, or a `_b` suffix
        assert!(is_bias_leaf("b0") && is_bias_leaf("b12") && is_bias_leaf("attn_b"));
        assert!(!is_bias_leaf("beta") && !is_bias_leaf("base") && !is_bias_leaf("b"));
        assert!(!is_bias_leaf("b2x") && !is_bias_leaf("w0"));
    }

    #[test]
    fn init_deterministic() {
        assert_eq!(init_params(&layout(), 64, 5), init_params(&layout(), 64, 5));
        assert_ne!(init_params(&layout(), 64, 5), init_params(&layout(), 64, 6));
    }

    #[test]
    fn axpy_works() {
        let mut y = vec![1.0, 2.0];
        axpy(&mut y, -0.5, &[2.0, 4.0]);
        assert_eq!(y, vec![0.0, 0.0]);
    }

    #[test]
    fn consensus_gap_zero_when_equal() {
        let a = vec![1.0f32, 2.0, 3.0];
        let refs: Vec<&[f32]> = vec![&a, &a, &a];
        assert_eq!(consensus_gap(&refs), 0.0);
    }

    #[test]
    fn mean_of_two() {
        let a = vec![0.0f32, 2.0];
        let b = vec![2.0f32, 0.0];
        assert_eq!(mean_of(&[&a, &b]), vec![1.0, 1.0]);
    }
}
