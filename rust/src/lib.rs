//! # dsgd-aau — Straggler-Resilient Decentralized Learning
//!
//! A production-quality reproduction of *"Straggler-Resilient Decentralized
//! Learning via Adaptive Asynchronous Updates"* (DSGD-AAU, cs.LG 2023) as a
//! three-layer Rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the decentralized training runtime: communication
//!   topologies, Metropolis consensus, the Pathsearch procedure (paper
//!   Alg. 3), the DSGD-AAU update rule plus five adversaries (synchronous
//!   DSGD, AD-PSGD, Prague, AGP, and the Hop-style bounded-staleness
//!   rule backed by the [`stale`] token-queue subsystem), a
//!   discrete-event cluster simulator with
//!   pluggable straggler injection ([`sim::straggler`]: the paper's
//!   i.i.d. Bernoulli coin, Gilbert–Elliott persistent slow states,
//!   Weibull-renewal bursts, JSON trace replay), a dynamic-topology
//!   [`churn`] subsystem (time-varying graphs: flaky links, mobile
//!   workers, partition/heal cycles, JSON schedules — applied live with
//!   connectivity repair, or without it when the [`adapt`] section allows
//!   real partitions), partition-aware adaptivity ([`adapt`]: incremental
//!   connected-component tracking with configurable detection latency;
//!   every update rule retargets to the live component), and the
//!   declarative [`sweep`] layer: every table/figure of the paper's
//!   evaluation plus the churn/straggler/partition grids is a
//!   [`sweep::SweepSpec`] declaration registered in the single `bench`
//!   multiplexer binary (`bench list` maps suites to paper artifacts).
//! * **L2 (python/compile/model.py)** — the worker model fwd/bwd in JAX,
//!   AOT-lowered once to HLO text.
//! * **L1 (python/compile/kernels/)** — Pallas kernels (fused linear
//!   fwd/bwd, gossip average) called from L2.
//!
//! Python never runs on the training path: the [`runtime`] module loads the
//! AOT artifacts via PJRT and executes them from the rust event loop.
//!
//! Real cluster history plugs into the same machinery: the [`trace`]
//! module ingests Google Borg / Alibaba machine-event logs (plus a
//! documented generic CSV) and lowers them onto the replayable
//! straggler/topology timelines via the `trace` config section.
//!
//! ## Guides
//!
//! Four long-form guides live in `docs/` at the repository root:
//!
//! * `docs/architecture.md` — layering (engine → sim/churn/adapt/trace →
//!   sweep) and an event-loop walkthrough;
//! * `docs/config.md` — the full `ExperimentConfig` reference, one
//!   validated JSON example per strict-parsed section;
//! * `docs/scenarios.md` — the scenario cookbook: writing, generating
//!   and ingesting timelines, the three trace-file formats, and how to
//!   add a sweep suite;
//! * `docs/lint.md` — the [`analysis`] module's `pallas-lint` pass:
//!   the determinism rule catalogue, the suppression pragma, and how to
//!   add a rule (`cargo run --bin lint`).
//!
//! ## Quick start
//!
//! One experiment:
//!
//! ```no_run
//! use dsgd_aau::config::ExperimentConfig;
//! use dsgd_aau::coordinator;
//!
//! let mut cfg = ExperimentConfig::default();
//! cfg.num_workers = 16;
//! cfg.algorithm = dsgd_aau::algorithms::AlgorithmKind::DsgdAau;
//! let result = coordinator::run_experiment(&cfg).unwrap();
//! println!("final loss {:.4}", result.final_loss());
//! ```
//!
//! A declarative sweep (exactly how every `bench <suite>` is defined —
//! axes cross-multiply, cells run in parallel with per-cell panic
//! containment, results stream to table/CSV/JSON sinks, and `--resume`
//! skips cells already present in `BENCH_<suite>.json`):
//!
//! ```no_run
//! use dsgd_aau::sweep::cli::BenchArgs;
//! use dsgd_aau::sweep::{run_suite, Axis, Column, Fmt, SweepSpec, TableSpec};
//!
//! let spec = SweepSpec::new("demo", "final loss by fleet size", |cfg| {
//!     cfg.max_iterations = 200;
//!     cfg.mean_compute = 0.01;
//! })
//! .axis(Axis::from_numbers("N", &[4usize], &[4, 8], &[8, 16], |cfg, n| {
//!     cfg.num_workers = n
//! }))
//! .table(TableSpec::long("", vec![Column::new("loss", "final_loss", Fmt::F4)]));
//! let run = run_suite(&spec, &BenchArgs::default()).unwrap();
//! println!("{} cells ({} resumed)", run.records.len(), run.skipped);
//! ```

// `missing_docs` is denied module-by-module as coverage lands; the goal
// is a crate-wide deny once the remaining seed modules are documented.
#[deny(missing_docs)]
pub mod adapt;
#[deny(missing_docs)]
pub mod algorithms;
#[deny(missing_docs)]
pub mod analysis;
pub mod backend;
pub mod churn;
pub mod config;
pub mod consensus;
pub mod coordinator;
pub mod data;
pub mod engine;
#[deny(missing_docs)]
pub mod fragment;
pub mod harness;
#[deny(missing_docs)]
pub mod membership;
pub mod metrics;
pub mod model;
pub mod pathsearch;
pub mod runtime;
pub mod sim;
#[deny(missing_docs)]
pub mod stale;
#[deny(missing_docs)]
pub mod sweep;
#[deny(missing_docs)]
pub mod topology;
#[deny(missing_docs)]
pub mod trace;
pub mod util;

/// Worker identifier: dense indices `0..N`.
pub type WorkerId = usize;
