//! # dsgd-aau — Straggler-Resilient Decentralized Learning
//!
//! A production-quality reproduction of *"Straggler-Resilient Decentralized
//! Learning via Adaptive Asynchronous Updates"* (DSGD-AAU, cs.LG 2023) as a
//! three-layer Rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the decentralized training runtime: communication
//!   topologies, Metropolis consensus, the Pathsearch procedure (paper
//!   Alg. 3), the DSGD-AAU update rule plus four baselines (synchronous
//!   DSGD, AD-PSGD, Prague, AGP), a discrete-event cluster simulator with
//!   pluggable straggler injection ([`sim::straggler`]: the paper's
//!   i.i.d. Bernoulli coin, Gilbert–Elliott persistent slow states,
//!   Weibull-renewal bursts, JSON trace replay), a dynamic-topology
//!   [`churn`] subsystem (time-varying graphs: flaky links, mobile
//!   workers, partition/heal cycles, JSON schedules — applied live with
//!   connectivity repair, or without it when the [`adapt`] section allows
//!   real partitions), partition-aware adaptivity ([`adapt`]: incremental
//!   connected-component tracking with configurable detection latency;
//!   every update rule retargets to the live component), and the
//!   experiment harness regenerating every table/figure of the paper's
//!   evaluation plus churn, straggler and partition sweeps
//!   (`bench_churn`, `bench_straggler`, `bench_partition`).
//! * **L2 (python/compile/model.py)** — the worker model fwd/bwd in JAX,
//!   AOT-lowered once to HLO text.
//! * **L1 (python/compile/kernels/)** — Pallas kernels (fused linear
//!   fwd/bwd, gossip average) called from L2.
//!
//! Python never runs on the training path: the [`runtime`] module loads the
//! AOT artifacts via PJRT and executes them from the rust event loop.
//!
//! ## Quick start
//!
//! ```no_run
//! use dsgd_aau::config::ExperimentConfig;
//! use dsgd_aau::coordinator;
//!
//! let mut cfg = ExperimentConfig::default();
//! cfg.num_workers = 16;
//! cfg.algorithm = dsgd_aau::algorithms::AlgorithmKind::DsgdAau;
//! let result = coordinator::run_experiment(&cfg).unwrap();
//! println!("final loss {:.4}", result.final_loss());
//! ```

pub mod adapt;
pub mod algorithms;
pub mod backend;
pub mod churn;
pub mod config;
pub mod consensus;
pub mod coordinator;
pub mod data;
pub mod engine;
pub mod harness;
pub mod metrics;
pub mod model;
pub mod pathsearch;
pub mod runtime;
pub mod sim;
pub mod topology;
pub mod util;

/// Worker identifier: dense indices `0..N`.
pub type WorkerId = usize;
