//! Google Borg / ClusterData `machine_events` parser.
//!
//! Row format (ClusterData v2 `machine_events` table):
//!
//! ```text
//! timestamp,machine_id,event_type[,platform_id,cpus,memory]
//! ```
//!
//! * `timestamp` — microseconds since trace start (converted to seconds);
//! * `event_type` — the ClusterData codes `0` = ADD, `1` = REMOVE,
//!   `2` = UPDATE; the words `ADD`/`REMOVE`/`UPDATE` are accepted too,
//!   case-insensitively.  UPDATE rows carry capacity changes we do not
//!   model and parse to nothing.
//!
//! Blank lines, `#` comments and a `timestamp,...` header row are
//! skipped; anything else malformed is a row-numbered error.  Fields must
//! not be quoted (the public trace files are plain CSV).

use super::{MachineEvent, TraceEvent};
use anyhow::{anyhow, bail, ensure, Result};

pub(super) fn parse(text: &str) -> Result<Vec<TraceEvent>> {
    let mut out = Vec::new();
    for (i, line) in text.lines().enumerate() {
        let row = i + 1;
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let cols: Vec<&str> = line.split(',').map(str::trim).collect();
        if cols[0].eq_ignore_ascii_case("timestamp") {
            continue; // header
        }
        ensure!(
            cols.len() >= 3,
            "row {row}: expected `timestamp,machine_id,event_type`, got {} column(s)",
            cols.len()
        );
        let us: f64 = cols[0]
            .parse()
            .map_err(|_| anyhow!("row {row}: bad timestamp {:?}", cols[0]))?;
        ensure!(
            us.is_finite() && us >= 0.0,
            "row {row}: timestamp must be a non-negative number of microseconds"
        );
        let machine = cols[1];
        ensure!(!machine.is_empty(), "row {row}: empty machine id");
        let event = match cols[2].to_ascii_lowercase().as_str() {
            "0" | "add" => Some(MachineEvent::Up),
            "1" | "remove" => Some(MachineEvent::Down),
            "2" | "update" => None,
            other => bail!(
                "row {row}: unknown Borg event type {other:?} (0/ADD, 1/REMOVE, 2/UPDATE)"
            ),
        };
        if let Some(event) = event {
            out.push(TraceEvent { time: us / 1e6, machine: machine.to_string(), event });
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_codes_words_headers_and_comments() {
        let text = "# excerpt\n\
                    timestamp,machine_id,event_type,platform_id,cpus,memory\n\
                    0,m1,0,p,0.5,0.25\n\
                    5000000,m2,ADD,p,0.5,0.25\n\
                    10000000,m1,1,,,\n\
                    15000000,m1,remove,,,\n\
                    20000000,m2,2,p,1.0,0.5\n\
                    25000000,m1,add,,,\n";
        let evs = parse(text).unwrap();
        // the UPDATE row parses to nothing
        assert_eq!(evs.len(), 5);
        assert_eq!(evs[0], TraceEvent { time: 0.0, machine: "m1".into(), event: MachineEvent::Up });
        assert_eq!(evs[1].time, 5.0, "microseconds convert to seconds");
        assert_eq!(evs[2].event, MachineEvent::Down);
        assert_eq!(
            evs[4],
            TraceEvent { time: 25.0, machine: "m1".into(), event: MachineEvent::Up }
        );
    }

    #[test]
    fn malformed_rows_are_row_numbered() {
        // row 3 (after the header) has a bogus event type
        let text = "timestamp,machine_id,event_type\n0,m1,0\n5,m1,explode\n";
        let err = parse(text).unwrap_err().to_string();
        assert!(err.contains("row 3"), "{err}");
        assert!(err.contains("explode"), "{err}");

        let err = parse("nonsense,m1,0\n").unwrap_err().to_string();
        assert!(err.contains("row 1") && err.contains("timestamp"), "{err}");

        let err = parse("-5,m1,0\n").unwrap_err().to_string();
        assert!(err.contains("row 1"), "{err}");

        let err = parse("0,m1\n").unwrap_err().to_string();
        assert!(err.contains("row 1") && err.contains("column"), "{err}");

        let err = parse("0,,0\n").unwrap_err().to_string();
        assert!(err.contains("row 1") && err.contains("machine"), "{err}");
    }
}
