//! Alibaba cluster-trace parser (`machine_usage` + `machine_meta`).
//!
//! Two row layouts of the cluster-trace-v2018 release are accepted and
//! may be mixed in one file:
//!
//! * **`machine_usage`** (9 columns):
//!   `machine_id,time_stamp,cpu_util_percent,mem_util_percent,mem_gps,mkpi,net_in,net_out,disk_io_percent`
//!   — each row yields a [`MachineEvent::Usage`] sample
//!   (`cpu_util_percent / 100`); the ingestion pipeline thresholds the
//!   samples into slow states with hysteresis.  Only the first three
//!   columns are read; trailing columns may be empty but must be present.
//! * **`machine_meta`** (exactly 7 columns, trailing non-numeric
//!   `status`):
//!   `machine_id,time_stamp,failure_domain_1,failure_domain_2,cpu_num,mem_size,status`
//!   — the `status` transition yields availability events: `USING` is up,
//!   any other status (`OFFLINE`, `OFF_LINE`, …) is down.  A 7-column row
//!   whose last field is empty or numeric is treated as a (truncated)
//!   usage row instead — statuses in the public trace are always words,
//!   so a hand-trimmed usage row cannot silently become a machine-down
//!   event.
//!
//! `time_stamp` is seconds since trace start.  Blank lines, `#` comments
//! and a `machine_id,...` header row are skipped; anything else malformed
//! is a row-numbered error.

use super::{MachineEvent, TraceEvent};
use anyhow::{anyhow, ensure, Result};

pub(super) fn parse(text: &str) -> Result<Vec<TraceEvent>> {
    let mut out = Vec::new();
    for (i, line) in text.lines().enumerate() {
        let row = i + 1;
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let cols: Vec<&str> = line.split(',').map(str::trim).collect();
        if cols[0].eq_ignore_ascii_case("machine_id") {
            continue; // header
        }
        let machine = cols[0];
        ensure!(!machine.is_empty(), "row {row}: empty machine id");
        ensure!(
            cols.len() >= 3,
            "row {row}: expected a machine_usage (9-column) or machine_meta (7-column) row, \
             got {} column(s)",
            cols.len()
        );
        let time: f64 = cols[1]
            .parse()
            .map_err(|_| anyhow!("row {row}: bad time_stamp {:?}", cols[1]))?;
        ensure!(
            time.is_finite() && time >= 0.0,
            "row {row}: time_stamp must be a non-negative number of seconds"
        );
        let is_meta =
            cols.len() == 7 && !cols[6].is_empty() && cols[6].parse::<f64>().is_err();
        let event = if is_meta {
            // machine_meta: trailing status column drives availability
            if cols[6].eq_ignore_ascii_case("using") {
                MachineEvent::Up
            } else {
                MachineEvent::Down
            }
        } else {
            // machine_usage: cpu_util_percent in [0, 100]
            let util: f64 = cols[2]
                .parse()
                .map_err(|_| anyhow!("row {row}: bad cpu_util_percent {:?}", cols[2]))?;
            ensure!(
                (0.0..=100.0).contains(&util),
                "row {row}: cpu_util_percent {util} outside [0, 100]"
            );
            MachineEvent::Usage(util / 100.0)
        };
        out.push(TraceEvent { time, machine: machine.to_string(), event });
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_usage_and_meta_rows() {
        let text = "machine_id,time_stamp,cpu_util_percent,mem_util_percent,mem_gps,mkpi,net_in,net_out,disk_io_percent\n\
                    m_1932,30,22,56,,,,,\n\
                    m_1932,60,91,60,,,,,\n\
                    m_0718,30,1,1,1,1,1,1,1\n\
                    m_0718,90,fd1,fd2,96,normalized,USING\n\
                    m_0718,120,fd1,fd2,96,normalized,OFFLINE\n";
        let evs = parse(text).unwrap();
        assert_eq!(evs.len(), 5);
        assert_eq!(evs[0].event, MachineEvent::Usage(0.22));
        assert_eq!(evs[1].event, MachineEvent::Usage(0.91));
        assert_eq!(evs[3].event, MachineEvent::Up, "7-column USING row is availability");
        assert_eq!(evs[4].event, MachineEvent::Down);
        assert_eq!(evs[4].time, 120.0);
    }

    #[test]
    fn truncated_usage_rows_do_not_masquerade_as_meta() {
        // 7 columns with a numeric tail: a hand-trimmed usage row — it
        // must stay a utilization sample, never a machine-down event
        let evs = parse("m_1,10,93,1,2,3,4\n").unwrap();
        assert_eq!(evs.len(), 1);
        assert_eq!(evs[0].event, MachineEvent::Usage(0.93));
    }

    #[test]
    fn malformed_rows_are_row_numbered() {
        let err = parse("m_1,abc,50,1,,,,,\n").unwrap_err().to_string();
        assert!(err.contains("row 1") && err.contains("time_stamp"), "{err}");

        let err = parse("m_1,10,140,1,,,,,\n").unwrap_err().to_string();
        assert!(err.contains("row 1") && err.contains("[0, 100]"), "{err}");

        let err = parse("m_1,10\n").unwrap_err().to_string();
        assert!(err.contains("row 1") && err.contains("column"), "{err}");

        // a 7-column row with an empty status is not silently meta: it
        // falls through to the usage path and fails on the bad utilization
        let err = parse("m_1,10,x,x,x,x,\n").unwrap_err().to_string();
        assert!(err.contains("row 1") && err.contains("cpu_util_percent"), "{err}");

        // the bad row is the third line (header counts)
        let text = "machine_id,time_stamp,cpu_util_percent\nm_1,10,50,1,,,,,\nm_1,20,oops,1,,,,,\n";
        let err = parse(text).unwrap_err().to_string();
        assert!(err.contains("row 3"), "{err}");
    }
}
