//! The documented generic fallback CSV (see `docs/scenarios.md`).
//!
//! Row format:
//!
//! ```text
//! time,node,event[,value]
//! ```
//!
//! * `time` — seconds since trace start (any non-negative float);
//! * `node` — opaque machine identifier;
//! * `event` — one of `up`, `down`, `slow`, `recover`, or `usage`
//!   (case-insensitive); `usage` requires a `value` in `[0, 1]`, which
//!   the pipeline thresholds into slow states with hysteresis.
//!
//! Blank lines, `#` comments and a `time,...` header row are skipped;
//! anything else malformed is a row-numbered error.

use super::{MachineEvent, TraceEvent};
use anyhow::{anyhow, bail, ensure, Result};

pub(super) fn parse(text: &str) -> Result<Vec<TraceEvent>> {
    let mut out = Vec::new();
    for (i, line) in text.lines().enumerate() {
        let row = i + 1;
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let cols: Vec<&str> = line.split(',').map(str::trim).collect();
        if cols[0].eq_ignore_ascii_case("time") {
            continue; // header
        }
        ensure!(
            cols.len() >= 3,
            "row {row}: expected `time,node,event[,value]`, got {} column(s)",
            cols.len()
        );
        let time: f64 =
            cols[0].parse().map_err(|_| anyhow!("row {row}: bad time {:?}", cols[0]))?;
        ensure!(
            time.is_finite() && time >= 0.0,
            "row {row}: time must be a non-negative number of seconds"
        );
        let machine = cols[1];
        ensure!(!machine.is_empty(), "row {row}: empty node id");
        let event = match cols[2].to_ascii_lowercase().as_str() {
            "up" => MachineEvent::Up,
            "down" => MachineEvent::Down,
            "slow" => MachineEvent::Slow(true),
            "recover" => MachineEvent::Slow(false),
            "usage" => {
                let raw = cols.get(3).copied().unwrap_or("");
                ensure!(!raw.is_empty(), "row {row}: usage needs a value column");
                let v: f64 = raw
                    .parse()
                    .map_err(|_| anyhow!("row {row}: bad usage value {raw:?}"))?;
                ensure!(
                    (0.0..=1.0).contains(&v),
                    "row {row}: usage value {v} outside [0, 1]"
                );
                MachineEvent::Usage(v)
            }
            other => bail!(
                "row {row}: unknown event {other:?} (up|down|slow|recover|usage)"
            ),
        };
        out.push(TraceEvent { time, machine: machine.to_string(), event });
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_every_event_kind() {
        let text = "time,node,event,value\n\
                    # warm-up\n\
                    0,a,up,\n\
                    1.5,a,slow,\n\
                    2,a,recover,\n\
                    3,b,down,\n\
                    4,b,up,\n\
                    5,c,usage,0.92\n";
        let evs = parse(text).unwrap();
        assert_eq!(evs.len(), 6);
        assert_eq!(
            evs[1],
            TraceEvent { time: 1.5, machine: "a".into(), event: MachineEvent::Slow(true) }
        );
        assert_eq!(evs[2].event, MachineEvent::Slow(false));
        assert_eq!(evs[5].event, MachineEvent::Usage(0.92));
    }

    #[test]
    fn malformed_rows_are_row_numbered() {
        let err = parse("x,a,up\n").unwrap_err().to_string();
        assert!(err.contains("row 1") && err.contains("time"), "{err}");

        let err = parse("1,a,explode\n").unwrap_err().to_string();
        assert!(err.contains("row 1") && err.contains("explode"), "{err}");

        let err = parse("1,a,usage\n").unwrap_err().to_string();
        assert!(err.contains("row 1") && err.contains("value"), "{err}");

        let err = parse("1,a,usage,7\n").unwrap_err().to_string();
        assert!(err.contains("row 1") && err.contains("[0, 1]"), "{err}");

        let err = parse("time,node,event\n1,a,up\n2,,down\n").unwrap_err().to_string();
        assert!(err.contains("row 3"), "{err}");
    }
}
