//! Real-cluster trace ingestion: machine-event logs → replayable timelines.
//!
//! PR 1's `TopologyTimeline` and PR 2's `StragglerTimeline` can replay any
//! correlated churn/straggler process bit for bit, but until now every
//! scenario was synthetically generated.  This module grounds both axes in
//! *real* cluster history: it parses machine-event logs from production
//! traces and lowers them onto the existing timeline formats, so a morning
//! of Borg machine churn or an Alibaba utilization storm becomes an
//! `ExperimentConfig` any of the five algorithms can train through.
//!
//! ## Pipeline
//!
//! 1. **Parse** ([`parse_events`]) one of three formats into a common
//!    stream of [`TraceEvent`]s (seconds + opaque machine id + what
//!    happened).  Malformed rows are rejected with row-numbered errors.
//!    * [`TraceKind::Borg`] — Google Borg / ClusterData `machine_events`
//!      CSV (`timestamp,machine_id,event_type`, µs timestamps, event
//!      types `0`/ADD, `1`/REMOVE, `2`/UPDATE);
//!    * [`TraceKind::Alibaba`] — Alibaba cluster-trace `machine_usage`
//!      rows (CPU-utilization samples) and `machine_meta` rows (`USING`
//!      /`OFFLINE` status transitions);
//!    * [`TraceKind::Generic`] — the documented fallback CSV
//!      (`time,node,event[,value]`; see `docs/scenarios.md`).
//! 2. **Map** machines onto the `m` simulated workers ([`MapPolicy`]:
//!    stable hash, first-appearance round-robin, or one-to-one onto the
//!    top-`m` busiest machines, dropping the rest).
//! 3. **Threshold** utilization samples into slow states with hysteresis:
//!    a machine enters the slow state when utilization reaches
//!    `threshold` and recovers only once it falls to
//!    `threshold - hysteresis`, so samples oscillating around the
//!    threshold do not flap.
//! 4. **Rescale** the selected wall-clock `window` (defaults to the whole
//!    trace span) linearly onto `horizon` virtual seconds, folding
//!    pre-window history into the state at virtual time zero.
//! 5. **Lower** ([`TraceIngest::lower`]) into a [`LoweredTrace`]:
//!    machine slow/recover flips become a [`StragglerTimeline`], machine
//!    REMOVE/ADD become `Isolate`/`Attach` mutations in a
//!    [`TopologyTimeline`] — both replayed through the exact churn and
//!    straggler paths the synthetic generators use.
//!
//! When several machines share one worker, the worker is **slow while any
//! of its machines is slow** and **down only while all of them are down**
//! (the worker models their pooled capacity).  Workers with no mapped
//! machine stay up and fast.
//!
//! ## Config reference (`trace` section)
//!
//! ```json
//! {
//!   "trace": {
//!     "kind": "borg",                // borg | alibaba | generic
//!     "path": "rust/testdata/traces/borg_machine_events.csv",
//!     "map": "round_robin",          // hash | round_robin | top_busiest
//!     "window": [0.0, 3600.0],       // optional trace-seconds slice
//!     "horizon": 30.0,               // virtual seconds the window maps onto
//!     "threshold": 0.8,              // utilization entering the slow state
//!     "hysteresis": 0.1              // recover at threshold - hysteresis
//!   }
//! }
//! ```
//!
//! Like every other section, unknown keys and wrongly-typed values are
//! rejected rather than silently defaulted.  A config with a `trace`
//! section must leave `churn` inactive and `straggler` on the default
//! Bernoulli kind: the trace *is* the churn schedule and the straggler
//! process (the straggler section's `slowdown` still applies while a
//! machine is slow).

mod alibaba;
mod borg;
mod generic;

use crate::churn::{TopologyMutation, TopologyTimeline};
use crate::sim::straggler::{StragglerEvent, StragglerTimeline};
use crate::topology::Graph;
use crate::util::json::Json;
use crate::WorkerId;
use anyhow::{bail, ensure, Context, Result};
use std::collections::BTreeMap;
use std::path::Path;

/// Which trace format [`parse_events`] expects.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TraceKind {
    /// Google Borg / ClusterData `machine_events` CSV.
    Borg,
    /// Alibaba cluster-trace `machine_usage` / `machine_meta` CSV.
    Alibaba,
    /// The documented generic fallback CSV (`time,node,event[,value]`).
    Generic,
}

impl TraceKind {
    /// Parse from the snake_case config token.
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "borg" => TraceKind::Borg,
            "alibaba" => TraceKind::Alibaba,
            "generic" => TraceKind::Generic,
            other => bail!("unknown trace kind {other:?} (borg|alibaba|generic)"),
        })
    }

    /// Inverse of [`Self::parse`].
    pub fn token(&self) -> &'static str {
        match self {
            TraceKind::Borg => "borg",
            TraceKind::Alibaba => "alibaba",
            TraceKind::Generic => "generic",
        }
    }
}

/// How trace machines are assigned to the `m` simulated workers.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MapPolicy {
    /// Stable FNV-1a hash of the machine id modulo `m` (machine counts
    /// far above `m` spread roughly evenly; mapping is independent of
    /// event order).
    Hash,
    /// Machines in order of first appearance get workers `0, 1, …,
    /// m-1, 0, …` (the default: deterministic and balanced).
    RoundRobin,
    /// The `m` machines with the most trace events map one-to-one onto
    /// workers `0..m` (ties broken by machine id); quieter machines are
    /// dropped from the scenario.
    TopBusiest,
}

impl MapPolicy {
    /// Parse from the snake_case config token.
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "hash" => MapPolicy::Hash,
            "round_robin" => MapPolicy::RoundRobin,
            "top_busiest" => MapPolicy::TopBusiest,
            other => bail!("unknown trace map policy {other:?} (hash|round_robin|top_busiest)"),
        })
    }

    /// Inverse of [`Self::parse`].
    pub fn token(&self) -> &'static str {
        match self {
            MapPolicy::Hash => "hash",
            MapPolicy::RoundRobin => "round_robin",
            MapPolicy::TopBusiest => "top_busiest",
        }
    }
}

/// What happened to a machine at one trace timestamp.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum MachineEvent {
    /// The machine (re)joined the cluster (Borg ADD, Alibaba `USING`,
    /// generic `up`).
    Up,
    /// The machine left the cluster (Borg REMOVE, Alibaba `OFFLINE`,
    /// generic `down`).
    Down,
    /// Explicit slow-state flip (generic `slow` / `recover`).
    Slow(bool),
    /// Utilization sample in `[0, 1]` (Alibaba `machine_usage`, generic
    /// `usage`); thresholded into slow states by the pipeline.
    Usage(f64),
}

/// One parsed machine event: the common currency of the three parsers.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceEvent {
    /// Seconds since the trace epoch (parsers normalize units).
    pub time: f64,
    /// Opaque source-machine identifier.
    pub machine: String,
    /// What happened.
    pub event: MachineEvent,
}

/// Parse raw trace text in the given format into machine events.
/// Returns row-numbered errors for malformed rows (1-based, counting
/// headers, comments and blank lines).
pub fn parse_events(kind: TraceKind, text: &str) -> Result<Vec<TraceEvent>> {
    match kind {
        TraceKind::Borg => borg::parse(text),
        TraceKind::Alibaba => alibaba::parse(text),
        TraceKind::Generic => generic::parse(text),
    }
}

/// The `trace` section of the experiment config.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceConfig {
    /// Trace format.
    pub kind: TraceKind,
    /// Path to the trace file.
    pub path: String,
    /// Machine → worker assignment policy.
    pub map: MapPolicy,
    /// Optional `[start, end]` slice of the trace in trace seconds;
    /// `None` uses the whole span (first to last event).
    pub window: Option<(f64, f64)>,
    /// Virtual seconds the selected window is rescaled onto.
    pub horizon: f64,
    /// Utilization at which a machine enters the slow state.
    pub threshold: f64,
    /// A slow machine recovers once utilization falls to
    /// `threshold - hysteresis` (flap damping).
    pub hysteresis: f64,
}

impl Default for TraceConfig {
    fn default() -> Self {
        TraceConfig {
            kind: TraceKind::Generic,
            path: String::new(),
            map: MapPolicy::RoundRobin,
            window: None,
            horizon: 60.0,
            threshold: 0.8,
            hysteresis: 0.1,
        }
    }
}

impl TraceConfig {
    /// Parse the config form, rejecting unknown keys and wrong types
    /// like the `churn`/`straggler`/`adapt` sections.  `kind` and `path`
    /// are required.
    pub fn from_json(j: &Json) -> Result<Self> {
        let obj = j.as_obj().context("trace section must be an object")?;
        let mut cfg = TraceConfig::default();
        let (mut saw_kind, mut saw_path) = (false, false);
        for (key, v) in obj {
            match key.as_str() {
                "kind" => {
                    cfg.kind =
                        TraceKind::parse(v.as_str().context("trace kind must be a string")?)?;
                    saw_kind = true;
                }
                "path" => {
                    cfg.path = v.as_str().context("trace path must be a string")?.to_string();
                    saw_path = true;
                }
                "map" => {
                    cfg.map =
                        MapPolicy::parse(v.as_str().context("trace map must be a string")?)?;
                }
                "window" => {
                    let a = v.as_arr().context("trace window must be [start, end]")?;
                    ensure!(a.len() == 2, "trace window must be [start, end]");
                    let t0 = a[0].as_f64().context("trace window start must be a number")?;
                    let t1 = a[1].as_f64().context("trace window end must be a number")?;
                    cfg.window = Some((t0, t1));
                }
                "horizon" => {
                    cfg.horizon = v.as_f64().context("trace horizon must be a number")?;
                }
                "threshold" => {
                    cfg.threshold = v.as_f64().context("trace threshold must be a number")?;
                }
                "hysteresis" => {
                    cfg.hysteresis = v.as_f64().context("trace hysteresis must be a number")?;
                }
                other => bail!(
                    "unknown trace key {other:?} \
                     (kind|path|map|window|horizon|threshold|hysteresis)"
                ),
            }
        }
        ensure!(saw_kind, "trace section needs a \"kind\" (borg|alibaba|generic)");
        ensure!(saw_path, "trace section needs a \"path\"");
        cfg.validate()?;
        Ok(cfg)
    }

    /// Inverse of [`Self::from_json`].
    pub fn to_json(&self) -> Json {
        let mut m: BTreeMap<String, Json> = BTreeMap::new();
        m.insert("kind".into(), Json::from(self.kind.token()));
        m.insert("path".into(), Json::from(self.path.as_str()));
        m.insert("map".into(), Json::from(self.map.token()));
        if let Some((t0, t1)) = self.window {
            m.insert("window".into(), Json::Arr(vec![Json::Num(t0), Json::Num(t1)]));
        }
        m.insert("horizon".into(), Json::Num(self.horizon));
        m.insert("threshold".into(), Json::Num(self.threshold));
        m.insert("hysteresis".into(), Json::Num(self.hysteresis));
        Json::Obj(m)
    }

    /// Parameter sanity checks (called from `ExperimentConfig::validate`).
    pub fn validate(&self) -> Result<()> {
        ensure!(!self.path.is_empty(), "trace needs a non-empty path");
        ensure!(
            self.horizon.is_finite() && self.horizon > 0.0,
            "trace horizon must be positive and finite"
        );
        ensure!(
            self.threshold > 0.0 && self.threshold <= 1.0,
            "trace threshold must be in (0, 1]"
        );
        ensure!(
            self.hysteresis >= 0.0 && self.hysteresis < self.threshold,
            "trace hysteresis must be in [0, threshold)"
        );
        if let Some((t0, t1)) = self.window {
            ensure!(
                t0.is_finite() && t1.is_finite() && t1 > t0,
                "trace window must satisfy start < end"
            );
        }
        Ok(())
    }
}

/// A parsed trace plus its ingestion settings, ready to lower onto the
/// simulator's replayable timelines.
///
/// ```
/// use dsgd_aau::topology::generators::ring;
/// use dsgd_aau::trace::{TraceConfig, TraceIngest, TraceKind};
///
/// let csv = "time,node,event,value\n\
///            0,a,up,\n\
///            5,a,slow,\n\
///            10,b,down,\n\
///            20,a,recover,\n\
///            40,b,up,\n";
/// let cfg = TraceConfig { kind: TraceKind::Generic, horizon: 8.0, ..TraceConfig::default() };
/// let lowered = TraceIngest::from_text(&cfg, csv).unwrap().lower(4, &ring(4)).unwrap();
/// assert_eq!(lowered.straggler.num_events(), 2); // slow + recover
/// assert_eq!(lowered.topology.num_mutations(), 2); // isolate + attach
/// assert!(lowered.straggler.entries.iter().all(|e| e.time <= 8.0));
/// ```
#[derive(Debug, Clone)]
pub struct TraceIngest {
    cfg: TraceConfig,
    /// Events sorted by time (stable, so same-time rows keep file order).
    events: Vec<TraceEvent>,
}

impl TraceIngest {
    /// Read and parse the file named by `cfg.path`.
    pub fn load(cfg: &TraceConfig) -> Result<Self> {
        cfg.validate()?;
        let text = std::fs::read_to_string(Path::new(&cfg.path))
            .with_context(|| format!("read trace {}", cfg.path))?;
        Self::from_text(cfg, &text).with_context(|| format!("parse trace {}", cfg.path))
    }

    /// Parse trace text directly (tests, doctests, embedded scenarios);
    /// `cfg.path` is ignored here and may be empty.
    pub fn from_text(cfg: &TraceConfig, text: &str) -> Result<Self> {
        let mut events = parse_events(cfg.kind, text)?;
        ensure!(!events.is_empty(), "trace holds no machine events");
        events.sort_by(|a, b| a.time.partial_cmp(&b.time).expect("finite event times"));
        Ok(TraceIngest { cfg: cfg.clone(), events })
    }

    /// Number of parsed machine events.
    pub fn num_events(&self) -> usize {
        self.events.len()
    }

    /// Distinct machine ids, ascending.
    pub fn machines(&self) -> Vec<&str> {
        let set: std::collections::BTreeSet<&str> =
            self.events.iter().map(|e| e.machine.as_str()).collect();
        set.into_iter().collect()
    }

    /// Time span `(first, last)` of the parsed events, in trace seconds.
    pub fn span(&self) -> (f64, f64) {
        (
            self.events.first().map_or(0.0, |e| e.time),
            self.events.last().map_or(0.0, |e| e.time),
        )
    }

    /// Lower the trace onto an `m`-worker fleet whose initial
    /// communication graph is `initial` (recovering machines re-attach a
    /// worker to its initial neighbors).  Pre-window history folds into
    /// flips at virtual time zero; in-window flips land at linearly
    /// rescaled times in `[0, horizon]`.
    pub fn lower(&self, workers: usize, initial: &Graph) -> Result<LoweredTrace> {
        ensure!(workers >= 1, "trace lowering needs at least one worker");
        ensure!(
            initial.num_vertices() == workers,
            "initial graph has {} vertices for {} workers",
            initial.num_vertices(),
            workers
        );
        let (t0, t1) = match self.cfg.window {
            Some(w) => w,
            None => {
                let (lo, hi) = self.span();
                ensure!(
                    hi > lo,
                    "trace spans zero time ({lo}); set an explicit \"window\""
                );
                (lo, hi)
            }
        };

        // --- machine -> worker mapping ---------------------------------
        let order = first_appearance_order(&self.events);
        let mapping = build_mapping(self.cfg.map, &order, &self.events, workers);
        ensure!(
            !mapping.is_empty(),
            "no machines mapped onto workers (policy {})",
            self.cfg.map.token()
        );
        let machines_dropped = order.len() - mapping.len();

        // --- per-machine state machines -> worker-level flips ----------
        // A machine is up & fast until the trace says otherwise; a worker
        // is slow while ANY mapped machine is slow, down only while ALL
        // its machines are down.
        #[derive(Clone, Copy, Default)]
        struct MState {
            down: bool,
            slow: bool,
        }
        let mut mstate: BTreeMap<String, MState> = BTreeMap::new();
        let mut machines_per_worker = vec![0usize; workers];
        for (name, &w) in &mapping {
            machines_per_worker[w] += 1;
            mstate.insert(name.clone(), MState::default());
        }
        let mut slow_count = vec![0usize; workers];
        let mut down_count = vec![0usize; workers];
        let mut w_slow = vec![false; workers];
        let mut w_down = vec![false; workers];

        // One worker-level state change at a trace timestamp.
        enum Flip {
            Slow(WorkerId, bool),
            Down(WorkerId, bool),
        }
        let mut flips: Vec<(f64, Flip)> = Vec::new();
        for ev in &self.events {
            if ev.time > t1 {
                break;
            }
            let Some(&w) = mapping.get(&ev.machine) else {
                continue; // dropped by top_busiest
            };
            let st = mstate.get_mut(&ev.machine).expect("mapped machine has state");
            let (mut new_down, mut new_slow) = (st.down, st.slow);
            match ev.event {
                MachineEvent::Up => new_down = false,
                MachineEvent::Down => new_down = true,
                MachineEvent::Slow(s) => new_slow = s,
                MachineEvent::Usage(u) => {
                    if !st.slow && u >= self.cfg.threshold {
                        new_slow = true;
                    } else if st.slow && u <= self.cfg.threshold - self.cfg.hysteresis {
                        new_slow = false;
                    }
                }
            }
            if new_slow != st.slow {
                st.slow = new_slow;
                slow_count[w] = if new_slow { slow_count[w] + 1 } else { slow_count[w] - 1 };
                let agg = slow_count[w] > 0;
                if agg != w_slow[w] {
                    w_slow[w] = agg;
                    flips.push((ev.time, Flip::Slow(w, agg)));
                }
            }
            if new_down != st.down {
                st.down = new_down;
                down_count[w] = if new_down { down_count[w] + 1 } else { down_count[w] - 1 };
                let agg = down_count[w] == machines_per_worker[w];
                if agg != w_down[w] {
                    w_down[w] = agg;
                    flips.push((ev.time, Flip::Down(w, agg)));
                }
            }
        }

        // --- window clipping + linear rescale --------------------------
        // Flips before t0 fold into the state at virtual time zero; the
        // rest land at (t - t0) / (t1 - t0) * horizon.
        let scale = self.cfg.horizon / (t1 - t0);
        let mut start_slow = vec![false; workers];
        let mut start_down = vec![false; workers];
        let mut scaled: Vec<(f64, Flip)> = Vec::new();
        for (t, flip) in flips {
            if t < t0 {
                match flip {
                    Flip::Slow(w, s) => start_slow[w] = s,
                    Flip::Down(w, d) => start_down[w] = d,
                }
            } else {
                scaled.push(((t - t0) * scale, flip));
            }
        }
        let mut initial_flips: Vec<Flip> = Vec::new();
        for w in 0..workers {
            if start_slow[w] {
                initial_flips.push(Flip::Slow(w, true));
            }
            if start_down[w] {
                initial_flips.push(Flip::Down(w, true));
            }
        }
        let all: Vec<(f64, Flip)> = initial_flips
            .into_iter()
            .map(|f| (0.0, f))
            .chain(scaled)
            .collect();

        // --- emit the two timelines, batching equal timestamps ---------
        let mut straggler = StragglerTimeline::new();
        let mut topology = TopologyTimeline::new();
        let mut s_batch: Vec<StragglerEvent> = Vec::new();
        let mut t_batch: Vec<TopologyMutation> = Vec::new();
        let mut at = 0.0f64;
        let flush =
            |time: f64,
             s_batch: &mut Vec<StragglerEvent>,
             t_batch: &mut Vec<TopologyMutation>,
             straggler: &mut StragglerTimeline,
             topology: &mut TopologyTimeline| {
                if !s_batch.is_empty() {
                    straggler.push(time, std::mem::take(s_batch));
                }
                if !t_batch.is_empty() {
                    topology.push(time, std::mem::take(t_batch));
                }
            };
        for (t, flip) in all {
            if t != at {
                flush(at, &mut s_batch, &mut t_batch, &mut straggler, &mut topology);
                at = t;
            }
            match flip {
                Flip::Slow(w, s) => s_batch.push(StragglerEvent { worker: w, slow: s }),
                Flip::Down(w, true) => t_batch.push(TopologyMutation::Isolate(w)),
                Flip::Down(w, false) => {
                    t_batch.push(TopologyMutation::Attach(w, initial.neighbors(w).to_vec()))
                }
            }
        }
        flush(at, &mut s_batch, &mut t_batch, &mut straggler, &mut topology);

        Ok(LoweredTrace {
            straggler,
            topology,
            mapping,
            machines_dropped,
            window: (t0, t1),
            horizon: self.cfg.horizon,
        })
    }
}

/// Result of [`TraceIngest::lower`]: the trace expressed in the
/// simulator's native replay formats, plus ingestion diagnostics.
#[derive(Debug, Clone)]
pub struct LoweredTrace {
    /// Worker slow/recover flips (drives the straggler process).
    pub straggler: StragglerTimeline,
    /// Worker isolate/attach mutations (drives the churn replay path).
    pub topology: TopologyTimeline,
    /// Machine id → worker assignment actually used.
    pub mapping: BTreeMap<String, WorkerId>,
    /// Machines dropped by the mapping policy (`top_busiest` overflow).
    pub machines_dropped: usize,
    /// The trace-seconds window that was lowered.
    pub window: (f64, f64),
    /// Virtual seconds the window was rescaled onto.
    pub horizon: f64,
}

/// Distinct machines in order of first appearance in the (time-sorted)
/// event stream.
fn first_appearance_order(events: &[TraceEvent]) -> Vec<String> {
    let mut seen: std::collections::BTreeSet<&str> = std::collections::BTreeSet::new();
    let mut order = Vec::new();
    for e in events {
        if seen.insert(e.machine.as_str()) {
            order.push(e.machine.clone());
        }
    }
    order
}

fn build_mapping(
    policy: MapPolicy,
    order: &[String],
    events: &[TraceEvent],
    workers: usize,
) -> BTreeMap<String, WorkerId> {
    let mut mapping = BTreeMap::new();
    match policy {
        MapPolicy::Hash => {
            for name in order {
                let h = crate::util::fnv1a(name.as_bytes());
                mapping.insert(name.clone(), (h % workers as u64) as WorkerId);
            }
        }
        MapPolicy::RoundRobin => {
            for (i, name) in order.iter().enumerate() {
                mapping.insert(name.clone(), i % workers);
            }
        }
        MapPolicy::TopBusiest => {
            let mut counts: BTreeMap<&str, usize> = BTreeMap::new();
            for e in events {
                *counts.entry(e.machine.as_str()).or_insert(0) += 1;
            }
            let mut ranked: Vec<(&str, usize)> = counts.into_iter().collect();
            // busiest first, ties by machine id ascending
            ranked.sort_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(b.0)));
            for (w, (name, _)) in ranked.into_iter().take(workers).enumerate() {
                mapping.insert(name.to_string(), w);
            }
        }
    }
    mapping
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::generators::ring;

    fn generic_cfg() -> TraceConfig {
        TraceConfig { kind: TraceKind::Generic, horizon: 10.0, ..TraceConfig::default() }
    }

    const GENERIC: &str = "time,node,event,value\n\
                           0,a,up,\n\
                           10,a,slow,\n\
                           20,b,down,\n\
                           30,a,recover,\n\
                           40,b,up,\n\
                           50,c,usage,0.95\n\
                           60,c,usage,0.75\n\
                           70,c,usage,0.60\n\
                           100,a,slow,\n";

    #[test]
    fn config_json_roundtrip_and_strict_keys() {
        let cfg = TraceConfig {
            kind: TraceKind::Borg,
            path: "traces/x.csv".into(),
            map: MapPolicy::TopBusiest,
            window: Some((10.0, 500.0)),
            horizon: 25.0,
            threshold: 0.9,
            hysteresis: 0.2,
        };
        let back = TraceConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(back, cfg);
        // no window key when None
        let cfg = TraceConfig { path: "t.csv".into(), ..TraceConfig::default() };
        let back = TraceConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(back, cfg);

        for bad in [
            r#"{"kind": "borg"}"#,                                 // no path
            r#"{"path": "x.csv"}"#,                                // no kind
            r#"{"kind": "slurm", "path": "x.csv"}"#,               // unknown kind
            r#"{"kind": "borg", "path": "x.csv", "pth": 1}"#,      // typo key
            r#"{"kind": "borg", "path": "x.csv", "window": [3]}"#, // bad window
            r#"{"kind": "borg", "path": "x.csv", "window": [5, 2]}"#,
            r#"{"kind": "borg", "path": "x.csv", "horizon": 0}"#,
            r#"{"kind": "borg", "path": "x.csv", "threshold": 1.5}"#,
            r#"{"kind": "borg", "path": "x.csv", "hysteresis": 0.9}"#,
            r#"{"kind": "borg", "path": "x.csv", "map": "best"}"#,
        ] {
            assert!(TraceConfig::from_json(&Json::parse(bad).unwrap()).is_err(), "{bad}");
        }
    }

    #[test]
    fn tokens_roundtrip() {
        for k in [TraceKind::Borg, TraceKind::Alibaba, TraceKind::Generic] {
            assert_eq!(TraceKind::parse(k.token()).unwrap(), k);
        }
        for p in [MapPolicy::Hash, MapPolicy::RoundRobin, MapPolicy::TopBusiest] {
            assert_eq!(MapPolicy::parse(p.token()).unwrap(), p);
        }
    }

    #[test]
    fn lowering_emits_both_timelines_scaled_into_the_horizon() {
        let ing = TraceIngest::from_text(&generic_cfg(), GENERIC).unwrap();
        assert_eq!(ing.machines(), vec!["a", "b", "c"]);
        let lt = ing.lower(6, &ring(6)).unwrap();
        // round-robin by first appearance: a->0, b->1, c->2
        assert_eq!(lt.mapping.get("a"), Some(&0));
        assert_eq!(lt.mapping.get("b"), Some(&1));
        assert_eq!(lt.mapping.get("c"), Some(&2));
        assert_eq!(lt.machines_dropped, 0);
        // span [0, 100] -> horizon 10: trace t=10 lands at 1.0 etc.
        assert_eq!(lt.window, (0.0, 100.0));
        let times: Vec<f64> = lt.straggler.entries.iter().map(|e| e.time).collect();
        // a slow@10->1.0, a recover@30->3.0, c usage-slow@50->5.0,
        // c recover@70 (0.60 <= 0.8-0.1)->7.0, a slow@100->10.0
        assert_eq!(times, vec![1.0, 3.0, 5.0, 7.0, 10.0]);
        // b down@20 -> isolate at 2.0, b up@40 -> attach at 4.0
        assert_eq!(lt.topology.len(), 2);
        assert_eq!(lt.topology.entries[0].time, 2.0);
        assert!(matches!(lt.topology.entries[0].mutations[0], TopologyMutation::Isolate(1)));
        assert_eq!(lt.topology.entries[1].time, 4.0);
        match &lt.topology.entries[1].mutations[0] {
            TopologyMutation::Attach(1, ns) => {
                assert_eq!(ns, &ring(6).neighbors(1).to_vec(), "reattach to initial neighbors")
            }
            other => panic!("expected attach, got {other:?}"),
        }
    }

    #[test]
    fn hysteresis_suppresses_flapping() {
        // 0.82 enters; 0.75 stays slow (> 0.7 exit level); 0.69 recovers
        let text = "time,node,event,value\n\
                    0,m,usage,0.82\n\
                    10,m,usage,0.75\n\
                    20,m,usage,0.81\n\
                    30,m,usage,0.69\n\
                    40,m,usage,0.10\n";
        let ing = TraceIngest::from_text(&generic_cfg(), text).unwrap();
        let lt = ing.lower(2, &ring(2)).unwrap();
        let flips: Vec<(f64, bool)> = lt
            .straggler
            .entries
            .iter()
            .flat_map(|e| e.events.iter().map(move |ev| (e.time, ev.slow)))
            .collect();
        assert_eq!(flips, vec![(0.0, true), (7.5, false)]);
    }

    #[test]
    fn window_folds_prior_history_into_time_zero() {
        let cfg = TraceConfig { window: Some((25.0, 75.0)), ..generic_cfg() };
        let ing = TraceIngest::from_text(&cfg, GENERIC).unwrap();
        let lt = ing.lower(6, &ring(6)).unwrap();
        // at t0=25: a is slow (slow@10, recover@30 is inside the window),
        // b is down (down@20, up@40 inside the window)
        let first = &lt.straggler.entries[0];
        assert_eq!(first.time, 0.0);
        assert_eq!(first.events, vec![StragglerEvent { worker: 0, slow: true }]);
        assert!(matches!(lt.topology.entries[0].mutations[0], TopologyMutation::Isolate(1)));
        assert_eq!(lt.topology.entries[0].time, 0.0);
        // recover@30 -> (30-25)/50*10 = 1.0; up@40 -> 3.0
        assert_eq!(lt.straggler.entries[1].time, 1.0);
        assert_eq!(lt.topology.entries[1].time, 3.0);
        // events past t1=75 (a slow@100) are clipped
        assert!(lt.straggler.entries.iter().all(|e| e.time <= 10.0));
        assert_eq!(lt.straggler.num_events(), 4, "slow@0, recover, c-slow, c-recover");
    }

    #[test]
    fn many_machines_aggregate_any_slow_all_down() {
        // four machines onto two workers round-robin: a,c -> 0; b,d -> 1
        let text = "time,node,event,value\n\
                    0,a,up,\n\
                    0,b,up,\n\
                    0,c,up,\n\
                    0,d,up,\n\
                    10,a,slow,\n\
                    20,c,slow,\n\
                    30,a,recover,\n\
                    40,c,recover,\n\
                    50,b,down,\n\
                    60,d,down,\n\
                    70,b,up,\n\
                    80,d,up,\n\
                    100,a,usage,0.1\n";
        let ing = TraceIngest::from_text(&generic_cfg(), text).unwrap();
        let lt = ing.lower(2, &ring(2)).unwrap();
        // worker 0: slow from 10 (any) until 40 (all fast again)
        let flips: Vec<(f64, usize, bool)> = lt
            .straggler
            .entries
            .iter()
            .flat_map(|e| e.events.iter().map(move |ev| (e.time, ev.worker, ev.slow)))
            .collect();
        assert_eq!(flips, vec![(1.0, 0, true), (4.0, 0, false)]);
        // worker 1: down only once BOTH b and d are down (60), back at 70
        assert_eq!(lt.topology.len(), 2);
        assert_eq!(lt.topology.entries[0].time, 6.0);
        assert!(matches!(lt.topology.entries[0].mutations[0], TopologyMutation::Isolate(1)));
        assert_eq!(lt.topology.entries[1].time, 7.0);
    }

    #[test]
    fn mapping_policies_are_deterministic() {
        // hash: stable across runs
        let cfg = TraceConfig { map: MapPolicy::Hash, ..generic_cfg() };
        let a = TraceIngest::from_text(&cfg, GENERIC).unwrap().lower(4, &ring(4)).unwrap();
        let b = TraceIngest::from_text(&cfg, GENERIC).unwrap().lower(4, &ring(4)).unwrap();
        assert_eq!(a.mapping, b.mapping);
        // top_busiest with 2 workers keeps the 2 machines with most
        // events (a: 4 events, c: 3, b: 2 -> keep a, c) and drops b
        let cfg = TraceConfig { map: MapPolicy::TopBusiest, ..generic_cfg() };
        let lt = TraceIngest::from_text(&cfg, GENERIC).unwrap().lower(2, &ring(2)).unwrap();
        assert_eq!(lt.mapping.len(), 2);
        assert_eq!(lt.machines_dropped, 1);
        assert_eq!(lt.mapping.get("a"), Some(&0));
        assert_eq!(lt.mapping.get("c"), Some(&1));
        assert!(lt.topology.is_empty(), "b's down/up events are dropped with it");
    }

    #[test]
    fn degenerate_traces_are_errors() {
        // no events at all
        assert!(TraceIngest::from_text(&generic_cfg(), "time,node,event,value\n").is_err());
        // zero time span without an explicit window
        let ing =
            TraceIngest::from_text(&generic_cfg(), "time,node,event,value\n5,a,slow,\n").unwrap();
        assert!(ing.lower(2, &ring(2)).is_err());
        // fleet-size mismatch
        let ing = TraceIngest::from_text(&generic_cfg(), GENERIC).unwrap();
        assert!(ing.lower(4, &ring(6)).is_err());
    }
}
