//! Training metrics: loss/accuracy curves over iteration and virtual time,
//! communication accounting, and CSV export for the figure harnesses.

use std::path::Path;

/// One point on the training curve.
#[derive(Debug, Clone, Copy)]
pub struct CurvePoint {
    /// Gossip-iteration counter k.
    pub iteration: u64,
    /// Virtual wall-clock seconds.
    pub time: f64,
    /// Global training loss (evaluated on the averaged parameters).
    pub loss: f32,
    /// Global accuracy in [0, 1].
    pub accuracy: f32,
    /// Cumulative bytes (parameters + control) exchanged so far.
    pub bytes: u64,
}

/// Accumulated run metrics.
#[derive(Debug, Clone, Default)]
pub struct Recorder {
    /// Eval snapshots over the run.
    pub curve: Vec<CurvePoint>,
    /// Total parameter bytes exchanged.
    pub param_bytes: u64,
    /// Total control-plane bytes (Pathsearch ID broadcasts etc.).
    pub control_bytes: u64,
    /// Number of gossip rounds performed.
    pub gossip_rounds: u64,
    /// Number of local gradient steps across all workers.
    pub local_steps: u64,
    /// Sum of gossip group sizes (for mean group size diagnostics).
    pub group_size_sum: u64,
    /// Wall-clock seconds of real compute spent in backend calls.
    pub backend_seconds: f64,
    /// Topology-change events processed (churn subsystem).
    pub topology_changes: u64,
    /// Graph mutations actually applied across all changes.
    pub mutations_applied: u64,
    /// Removals deferred by connectivity repair (the link stayed up).
    pub mutations_deferred: u64,
    /// Full-fleet stall fallbacks fired by DSGD-AAU (liveness guard:
    /// every worker was waiting with no novel edge available).
    pub stall_fallbacks: u64,
    /// Ground-truth component splits (partition events) over the run.
    pub partition_splits: u64,
    /// Ground-truth component merges (heal events) over the run.
    pub partition_merges: u64,
    /// Largest number of simultaneous components the graph reached.
    pub max_components: usize,
    /// Pathsearch epochs abandoned because an observed heal merged
    /// components (partition-aware DSGD-AAU's restart policy).
    pub epoch_restarts: u64,
    /// Pathsearch epochs completed scoped to a strict sub-component
    /// (counted separately from `PathSearch::epochs_completed`).
    pub component_epochs: u64,
    /// Gossip rounds executed while the graph was partitioned (> 1
    /// ground-truth component).
    pub partitioned_gossips: u64,
    /// Gossip rounds bucketed by the ground-truth component count at the
    /// time of the round — the per-component progress profile.
    pub gossips_by_components: std::collections::BTreeMap<usize, u64>,
    /// Open-world membership: pool users promoted into active slots
    /// (rotation refills, trace-routed attaches — initial fill excluded).
    pub workers_joined: u64,
    /// Open-world membership: active slots vacated (rotation leaves,
    /// departure-clock retirements, trace-routed isolates — the initial
    /// vacancy pass is excluded).
    pub workers_left: u64,
    /// Open-world membership: `RoundSample` participation rotations fired.
    pub rounds_sampled: u64,
    /// Prague proactive group rebuilds triggered by an adopted split
    /// or a member departure (stranded workers regroup without waiting
    /// for fire-time sub-group all-reduces).
    pub prague_regroups: u64,
    /// Sharded gossip: parameter bytes *not* sent versus a full-vector
    /// exchange with the same message count (zero in passthrough mode).
    pub shard_bytes_saved: u64,
    /// Sharded gossip: summed per-member shard staleness (rounds since
    /// each participant last refreshed the scheduled shard).
    pub shard_staleness: u64,
    /// Bounded-staleness scheduling (`hop_bss`): iterations skipped
    /// because the whole neighborhood was out of bound but queue room
    /// remained.
    pub stale_skips: u64,
    /// Bounded-staleness scheduling: backup-worker activations (a
    /// designated backup cloned a persistently observed-slow worker).
    pub backup_activations: u64,
    /// Bounded-staleness scheduling: total virtual seconds workers spent
    /// parked because every outgoing token queue was full.
    pub queue_block_time: f64,
    /// Largest iteration lag ever consumed by a bounded-staleness
    /// exchange (must stay ≤ the configured bound `s`).
    pub max_observed_staleness: u64,
    /// Sum of consumed iteration lags over all bounded-staleness
    /// exchanges (numerator of the mean observed staleness).
    pub observed_staleness_sum: u64,
    /// Count of bounded-staleness exchanges (denominator of the mean
    /// observed staleness).
    pub observed_staleness_count: u64,
}

impl Recorder {
    /// Fresh recorder.
    pub fn new() -> Self {
        Self::default()
    }

    /// Append an eval snapshot (bytes = cumulative traffic at this point).
    /// An exact repeat of the last point's `(iteration, time)` is dropped:
    /// nothing can have changed in zero virtual time at the same k, and
    /// trailing duplicates would skew CSV output and `bytes_to_accuracy`.
    pub fn record_eval(&mut self, iteration: u64, time: f64, loss: f32, accuracy: f32) {
        if let Some(last) = self.curve.last() {
            if last.iteration == iteration && last.time == time {
                return;
            }
        }
        let bytes = self.total_bytes();
        self.curve.push(CurvePoint { iteration, time, loss, accuracy, bytes });
    }

    /// Cumulative bytes at the first point reaching `target` accuracy.
    pub fn bytes_to_accuracy(&self, target: f32) -> Option<u64> {
        self.curve.iter().find(|p| p.accuracy >= target).map(|p| p.bytes)
    }

    /// Charge a gossip round among `group_size` workers of `bytes` payload.
    pub fn record_gossip(&mut self, group_size: usize, bytes: u64) {
        self.gossip_rounds += 1;
        self.group_size_sum += group_size as u64;
        self.param_bytes += bytes;
    }

    /// Note the ground-truth component count at a gossip round (the
    /// engine calls this right after [`Self::record_gossip`]).
    pub fn note_gossip_components(&mut self, components: usize) {
        *self.gossips_by_components.entry(components).or_insert(0) += 1;
        if components > 1 {
            self.partitioned_gossips += 1;
        }
    }

    /// Record one bounded-staleness consumption of iteration lag `s`
    /// (per exchange; updates the max and the mean's running sums).
    pub fn note_staleness(&mut self, s: u64) {
        self.max_observed_staleness = self.max_observed_staleness.max(s);
        self.observed_staleness_sum += s;
        self.observed_staleness_count += 1;
    }

    /// Mean iteration lag consumed per bounded-staleness exchange
    /// (0.0 when the rule never ran).
    pub fn mean_observed_staleness(&self) -> f64 {
        if self.observed_staleness_count == 0 {
            0.0
        } else {
            self.observed_staleness_sum as f64 / self.observed_staleness_count as f64
        }
    }

    /// Total bytes (parameters + control plane).
    pub fn total_bytes(&self) -> u64 {
        self.param_bytes + self.control_bytes
    }

    /// Mean gossip group size.
    pub fn mean_group_size(&self) -> f64 {
        if self.gossip_rounds == 0 {
            0.0
        } else {
            self.group_size_sum as f64 / self.gossip_rounds as f64
        }
    }

    /// Final recorded loss (NaN when no eval happened).
    pub fn final_loss(&self) -> f32 {
        self.curve.last().map(|p| p.loss).unwrap_or(f32::NAN)
    }

    /// Final recorded accuracy.
    pub fn final_accuracy(&self) -> f32 {
        self.curve.last().map(|p| p.accuracy).unwrap_or(f32::NAN)
    }

    /// Best (max) accuracy along the curve.
    pub fn best_accuracy(&self) -> f32 {
        self.curve.iter().map(|p| p.accuracy).fold(f32::NAN, f32::max)
    }

    /// Earliest virtual time at which `target` accuracy was reached.
    pub fn time_to_accuracy(&self, target: f32) -> Option<f64> {
        self.curve.iter().find(|p| p.accuracy >= target).map(|p| p.time)
    }

    /// Earliest virtual time at which loss dropped to `target` or below.
    pub fn time_to_loss(&self, target: f32) -> Option<f64> {
        self.curve.iter().find(|p| p.loss <= target).map(|p| p.time)
    }

    /// Earliest gossip iteration at which `target` accuracy was reached.
    pub fn iterations_to_accuracy(&self, target: f32) -> Option<u64> {
        self.curve.iter().find(|p| p.accuracy >= target).map(|p| p.iteration)
    }

    /// Loss at a fractional position along the recorded curve (0.0 =
    /// first eval, 1.0 = last; the loss-curve suite's checkpoint query).
    /// NaN when no eval happened.
    pub fn loss_at_fraction(&self, frac: f64) -> f32 {
        if self.curve.is_empty() {
            return f32::NAN;
        }
        let idx = ((self.curve.len() - 1) as f64 * frac.clamp(0.0, 1.0)) as usize;
        self.curve[idx].loss
    }

    /// The curve as CSV text (`iteration,time,loss,accuracy,bytes`).
    /// Byte-stable for identical runs — the golden-run determinism suite
    /// compares these strings directly.
    pub fn csv_string(&self) -> String {
        use std::fmt::Write as _;
        let mut out = String::from("iteration,time,loss,accuracy,bytes\n");
        for p in &self.curve {
            let _ = writeln!(
                out,
                "{},{:.6},{:.6},{:.6},{}",
                p.iteration, p.time, p.loss, p.accuracy, p.bytes
            );
        }
        out
    }

    /// Write the curve as CSV (`iteration,time,loss,accuracy,bytes`).
    pub fn write_csv(&self, path: &Path) -> std::io::Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, self.csv_string())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn recorder() -> Recorder {
        let mut r = Recorder::new();
        r.record_eval(0, 0.0, 2.3, 0.1);
        r.record_eval(10, 1.0, 1.5, 0.4);
        r.record_eval(20, 2.0, 0.9, 0.7);
        r
    }

    #[test]
    fn curve_queries() {
        let r = recorder();
        assert_eq!(r.final_loss(), 0.9);
        assert_eq!(r.final_accuracy(), 0.7);
        assert_eq!(r.best_accuracy(), 0.7);
        assert_eq!(r.time_to_accuracy(0.4), Some(1.0));
        assert_eq!(r.time_to_accuracy(0.9), None);
        assert_eq!(r.time_to_loss(1.5), Some(1.0));
        assert_eq!(r.iterations_to_accuracy(0.4), Some(10));
        assert_eq!(r.iterations_to_accuracy(0.9), None);
    }

    #[test]
    fn loss_at_fraction_checkpoints() {
        let r = recorder();
        assert_eq!(r.loss_at_fraction(0.0), 2.3);
        assert_eq!(r.loss_at_fraction(0.5), 1.5);
        assert_eq!(r.loss_at_fraction(1.0), 0.9);
        assert_eq!(r.loss_at_fraction(2.0), 0.9, "fraction clamps to the curve");
        assert!(Recorder::new().loss_at_fraction(0.5).is_nan());
    }

    #[test]
    fn duplicate_trailing_eval_point_dropped() {
        let mut r = recorder();
        assert_eq!(r.curve.len(), 3);
        // exact repeat of the last (iteration, time): dropped
        r.record_eval(20, 2.0, 0.9, 0.7);
        assert_eq!(r.curve.len(), 3, "duplicate trailing point must be deduped");
        // same iteration at a later time (an EvalTick): kept
        r.record_eval(20, 2.5, 0.85, 0.72);
        assert_eq!(r.curve.len(), 4);
        // same time at a later iteration (two fires at one instant): kept
        r.record_eval(21, 2.5, 0.84, 0.73);
        assert_eq!(r.curve.len(), 5);
        assert_eq!(r.final_accuracy(), 0.73);
    }

    #[test]
    fn gossip_accounting() {
        let mut r = Recorder::new();
        r.record_gossip(2, 100);
        r.record_gossip(4, 300);
        assert_eq!(r.param_bytes, 400);
        assert_eq!(r.mean_group_size(), 3.0);
        r.control_bytes += 50;
        assert_eq!(r.total_bytes(), 450);
    }

    #[test]
    fn csv_roundtrip() {
        let r = recorder();
        let dir = std::env::temp_dir().join("dsgd_aau_metrics_test");
        let path = dir.join("curve.csv");
        r.write_csv(&path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.starts_with("iteration,time,loss,accuracy,bytes"));
        assert_eq!(text.lines().count(), 4);
        assert_eq!(text, r.csv_string(), "file bytes = in-memory CSV");
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn staleness_accounting() {
        let mut r = Recorder::new();
        assert_eq!(r.mean_observed_staleness(), 0.0);
        r.note_staleness(2);
        r.note_staleness(0);
        r.note_staleness(4);
        assert_eq!(r.max_observed_staleness, 4);
        assert_eq!(r.observed_staleness_count, 3);
        assert_eq!(r.mean_observed_staleness(), 2.0);
    }

    #[test]
    fn partition_counters_and_component_buckets() {
        let mut r = Recorder::new();
        r.record_gossip(2, 10);
        r.note_gossip_components(1);
        r.record_gossip(3, 10);
        r.note_gossip_components(3);
        r.record_gossip(2, 10);
        r.note_gossip_components(3);
        assert_eq!(r.partitioned_gossips, 2);
        assert_eq!(r.gossips_by_components.get(&1), Some(&1));
        assert_eq!(r.gossips_by_components.get(&3), Some(&2));
        assert_eq!(r.gossips_by_components.get(&2), None);
    }
}
