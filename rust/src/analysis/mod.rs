//! `pallas-lint`: a dependency-free static-analysis pass over the
//! crate's own source, guarding the determinism and simulation
//! invariants every verification claim rests on (byte-identical golden
//! replays, bitwise incremental-Metropolis rebuilds, replay parity
//! across sweep thread counts).
//!
//! The pass lexes each file ([`lexer`]), scopes it onto the crate tree
//! by path, and runs the rule registry ([`rules::registry`]) over the
//! code tokens.  Intentional sites are baselined with an inline pragma:
//!
//! ```text
//! // pallas-lint: allow(no-wall-clock) — host-side diagnostic only
//! ```
//!
//! The reason is mandatory; a reasonless or malformed pragma is itself
//! a finding (`lint-pragma`), and a pragma that suppresses nothing is
//! flagged as `unused-pragma` so baselines cannot rot.  Run it with
//! `cargo run --bin lint`; see `docs/lint.md` for the rule catalogue.

pub mod lexer;
pub mod rules;

use anyhow::{Context, Result};
use lexer::{lex, Tok, TokKind};
pub use rules::{registry, RuleInfo, Severity};
use std::path::Path;

/// One lint diagnostic, bound to a file.
#[derive(Debug, Clone)]
pub struct Finding {
    /// Path relative to the lint root.
    pub file: String,
    /// 1-based line.
    pub line: u32,
    /// 1-based byte column.
    pub col: u32,
    /// Rule name (a core rule, `lint-pragma`, or `unused-pragma`).
    pub rule: String,
    /// Finding severity.
    pub severity: Severity,
    /// The offending lexeme.
    pub lexeme: String,
    /// Human explanation.
    pub message: String,
}

impl Finding {
    /// `file:line:col [rule] lexeme — message`, the human report line.
    pub fn render(&self) -> String {
        format!(
            "{}:{}:{}: {} [{}] `{}` — {}",
            self.file,
            self.line,
            self.col,
            self.severity.label(),
            self.rule,
            self.lexeme,
            self.message
        )
    }
}

/// The result of linting a tree (or a single source in tests).
#[derive(Debug, Default)]
pub struct LintReport {
    /// All findings, sorted by `(file, line, col, rule)`.
    pub findings: Vec<Finding>,
    /// Number of `.rs` files scanned.
    pub files_scanned: usize,
}

impl LintReport {
    /// Whether any finding is [`Severity::Error`] (non-zero exit).
    pub fn has_errors(&self) -> bool {
        self.findings.iter().any(|f| f.severity == Severity::Error)
    }

    /// Machine-readable report (for `--format=json` / the CI artifact).
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        use std::collections::BTreeMap;
        let findings: Vec<Json> = self
            .findings
            .iter()
            .map(|f| {
                let mut o = BTreeMap::new();
                o.insert("file".to_string(), Json::from(f.file.as_str()));
                o.insert("line".to_string(), Json::from(f.line as usize));
                o.insert("col".to_string(), Json::from(f.col as usize));
                o.insert("rule".to_string(), Json::from(f.rule.as_str()));
                o.insert("severity".to_string(), Json::from(f.severity.label()));
                o.insert("lexeme".to_string(), Json::from(f.lexeme.as_str()));
                o.insert("message".to_string(), Json::from(f.message.as_str()));
                Json::Obj(o)
            })
            .collect();
        let rules: Vec<Json> = registry()
            .iter()
            .map(|r| {
                let mut o = BTreeMap::new();
                o.insert("name".to_string(), Json::from(r.name));
                o.insert("severity".to_string(), Json::from(r.severity.label()));
                o.insert("description".to_string(), Json::from(r.description));
                Json::Obj(o)
            })
            .collect();
        let mut top = BTreeMap::new();
        top.insert("files_scanned".to_string(), Json::from(self.files_scanned));
        top.insert("findings".to_string(), Json::Arr(findings));
        top.insert("rules".to_string(), Json::Arr(rules));
        Json::Obj(top)
    }
}

/// A parsed suppression pragma.
struct Pragma {
    /// Line the pragma *ends* on (suppresses this line and the next).
    line: u32,
    col: u32,
    /// Allowed rule names (validated against the registry).
    allowed: Vec<String>,
    /// Per-rule "did it suppress anything" flags, parallel to `allowed`.
    used: Vec<bool>,
}

/// Marker every pragma comment carries.
const PRAGMA_TAG: &str = "pallas-lint:";

/// Parse the pragmas out of one file's comment tokens.  Malformed
/// pragmas (bad syntax, unknown rule, missing reason) become findings
/// immediately and do not suppress anything.
fn parse_pragmas(toks: &[Tok], findings: &mut Vec<Finding>) -> Vec<Pragma> {
    let mut pragmas = Vec::new();
    for t in toks {
        if t.kind != TokKind::Comment || !t.text.contains(PRAGMA_TAG) {
            continue;
        }
        // Doc comments are prose: a pragma quoted in rustdoc (like the
        // example in this module's docs) must not become a live one.
        let doc = ["///", "//!", "/**", "/*!"].iter().any(|p| t.text.starts_with(p));
        if doc {
            continue;
        }
        let end_line = t.line + t.text.matches('\n').count() as u32;
        let mut bad = |msg: String| {
            findings.push(Finding {
                file: String::new(),
                line: t.line,
                col: t.col,
                rule: "lint-pragma".to_string(),
                severity: Severity::Error,
                lexeme: PRAGMA_TAG.trim_end_matches(':').to_string(),
                message: msg,
            });
        };
        let text = t.text.trim_end_matches("*/");
        let after_tag = &text[text.find(PRAGMA_TAG).unwrap() + PRAGMA_TAG.len()..];
        let rest = after_tag.trim_start();
        let Some(args) = rest.strip_prefix("allow(") else {
            bad("pragma must be `pallas-lint: allow(<rule>) — <reason>`".to_string());
            continue;
        };
        let Some(close) = args.find(')') else {
            bad("unterminated allow(...) in pragma".to_string());
            continue;
        };
        let mut allowed = Vec::new();
        let mut ok = true;
        for name in args[..close].split(',') {
            let name = name.trim();
            if !rules::is_known_rule(name) {
                bad(format!("unknown rule {name:?} in pragma"));
                ok = false;
                break;
            }
            allowed.push(name.to_string());
        }
        if !ok {
            continue;
        }
        let reason = args[close + 1..]
            .trim_matches(|c: char| c.is_whitespace() || matches!(c, '-' | '—' | '–' | ':'));
        if reason.is_empty() {
            bad("pragma reason is mandatory: allow(<rule>) — <why this site is safe>"
                .to_string());
            continue;
        }
        let used = vec![false; allowed.len()];
        pragmas.push(Pragma { line: end_line, col: t.col, allowed, used });
    }
    pragmas
}

/// Lint one source text as if it lived at `rel` under the lint root.
/// Pragma suppression applies to findings on the pragma's own line or
/// the line directly below it.
pub fn lint_source(rel: &str, src: &str) -> Vec<Finding> {
    let toks = lex(src);
    let mut findings: Vec<Finding> = Vec::new();
    let mut pragmas = parse_pragmas(&toks, &mut findings);
    for raw in rules::run_rules(rel, &toks) {
        let mut suppressed = false;
        for p in &mut pragmas {
            if raw.line != p.line && raw.line != p.line + 1 {
                continue;
            }
            if let Some(k) = p.allowed.iter().position(|r| r == raw.rule) {
                p.used[k] = true;
                suppressed = true;
            }
        }
        if !suppressed {
            findings.push(Finding {
                file: String::new(),
                line: raw.line,
                col: raw.col,
                rule: raw.rule.to_string(),
                severity: raw.severity,
                lexeme: raw.lexeme,
                message: raw.message,
            });
        }
    }
    for p in &pragmas {
        for (k, used) in p.used.iter().enumerate() {
            if !used {
                findings.push(Finding {
                    file: String::new(),
                    line: p.line,
                    col: p.col,
                    rule: "unused-pragma".to_string(),
                    severity: Severity::Warning,
                    lexeme: p.allowed[k].clone(),
                    message: format!(
                        "pragma allows `{}` but nothing on this or the next line \
                         triggers it; remove the stale baseline",
                        p.allowed[k]
                    ),
                });
            }
        }
    }
    for f in &mut findings {
        f.file = rel.to_string();
    }
    findings.sort_by(|a, b| {
        (a.line, a.col, a.rule.as_str()).cmp(&(b.line, b.col, b.rule.as_str()))
    });
    findings
}

/// Collect every `.rs` file under `root`, depth-first in sorted order
/// (so reports are deterministic across platforms).
fn collect_rs_files(dir: &Path, out: &mut Vec<std::path::PathBuf>) -> Result<()> {
    let mut entries: Vec<_> = std::fs::read_dir(dir)
        .with_context(|| format!("reading {}", dir.display()))?
        .collect::<std::io::Result<_>>()?;
    entries.sort_by_key(|e| e.file_name());
    for e in entries {
        let path = e.path();
        if path.is_dir() {
            collect_rs_files(&path, out)?;
        } else if path.extension().map_or(false, |x| x == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

/// Lint every `.rs` file under `root` (normally `rust/src`).
pub fn lint_tree(root: &Path) -> Result<LintReport> {
    let mut files = Vec::new();
    collect_rs_files(root, &mut files)?;
    let mut report = LintReport { findings: Vec::new(), files_scanned: files.len() };
    for path in &files {
        let rel = path
            .strip_prefix(root)
            .unwrap_or(path)
            .to_string_lossy()
            .replace('\\', "/");
        let src =
            std::fs::read_to_string(path).with_context(|| format!("reading {}", path.display()))?;
        report.findings.extend(lint_source(&rel, &src));
    }
    report.findings.sort_by(|a, b| {
        (a.file.as_str(), a.line, a.col, a.rule.as_str())
            .cmp(&(b.file.as_str(), b.line, b.col, b.rule.as_str()))
    });
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pragma_with_reason_suppresses_same_and_next_line() {
        let same = "fn f() { let t = Instant::now(); } // pallas-lint: allow(no-wall-clock) \
                    — test fixture\n";
        assert!(lint_source("engine/mod.rs", same).is_empty());
        let above = "// pallas-lint: allow(no-wall-clock) — test fixture\n\
                     fn f() { let t = Instant::now(); }\n";
        assert!(lint_source("engine/mod.rs", above).is_empty());
    }

    #[test]
    fn reasonless_pragma_is_a_finding_and_does_not_suppress() {
        let src = "// pallas-lint: allow(no-wall-clock)\n\
                   fn f() { let t = Instant::now(); }\n";
        let f = lint_source("engine/mod.rs", src);
        assert_eq!(f.len(), 2);
        assert!(f.iter().any(|x| x.rule == "lint-pragma"));
        assert!(f.iter().any(|x| x.rule == "no-wall-clock"));
    }

    #[test]
    fn doc_comment_pragmas_are_inert() {
        let src = "/// pallas-lint: allow(no-wall-clock) — quoted example, not live\nfn f() {}\n";
        assert!(lint_source("engine/mod.rs", src).is_empty());
    }

    #[test]
    fn unknown_rule_in_pragma_is_flagged() {
        let src = "// pallas-lint: allow(no-such-rule) — because\nfn f() {}\n";
        let f = lint_source("engine/mod.rs", src);
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].rule, "lint-pragma");
    }

    #[test]
    fn unused_pragma_is_flagged() {
        let src = "// pallas-lint: allow(no-wall-clock) — stale\nfn f() {}\n";
        let f = lint_source("engine/mod.rs", src);
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].rule, "unused-pragma");
        assert_eq!(f[0].severity, Severity::Warning);
    }

    #[test]
    fn findings_render_position_rule_and_lexeme() {
        let f = lint_source("engine/mod.rs", "fn f() { x.unwrap(); }\n");
        assert_eq!(f.len(), 1);
        let line = f[0].render();
        assert!(line.contains("engine/mod.rs:1:12"), "{line}");
        assert!(line.contains("no-panic-in-engine"));
        assert!(line.contains("unwrap("));
    }

    #[test]
    fn json_report_shape() {
        let mut report = LintReport { findings: Vec::new(), files_scanned: 3 };
        report.findings = lint_source("engine/mod.rs", "fn f() { x.unwrap(); }\n");
        let j = report.to_json();
        assert_eq!(j.get("files_scanned").and_then(|v| v.as_usize()), Some(3));
        let arr = j.get("findings").and_then(|v| v.as_arr()).unwrap();
        assert_eq!(arr.len(), 1);
        assert_eq!(arr[0].get("rule").and_then(|v| v.as_str()), Some("no-panic-in-engine"));
        assert_eq!(j.get("rules").and_then(|v| v.as_arr()).map(|r| r.len()), Some(6));
    }
}
