//! The rule registry and the six determinism/invariant rules.
//!
//! Rules operate on the token stream from [`crate::analysis::lexer`]
//! plus the module scope derived from the file's path in the crate
//! tree.  Code inside `#[test]` functions and `#[cfg(test)]` items is
//! skipped: tests may freely use wall clocks, unwraps and hash maps.

use super::lexer::{Tok, TokKind};

/// How severe a finding is.  Errors fail the lint (non-zero exit);
/// warnings are reported but do not.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Severity {
    /// Reported, but does not fail the run.
    Warning,
    /// Fails the run.
    Error,
}

impl Severity {
    /// Lower-case label for reports.
    pub fn label(&self) -> &'static str {
        match self {
            Severity::Warning => "warning",
            Severity::Error => "error",
        }
    }
}

/// A rule's registry entry: name, severity, and what it guards.
#[derive(Debug, Clone, Copy)]
pub struct RuleInfo {
    /// Stable rule name, as used in suppression pragmas.
    pub name: &'static str,
    /// Default severity of its findings.
    pub severity: Severity,
    /// One-line description for `lint --rules` and docs.
    pub description: &'static str,
}

/// Modules whose event/weight paths must iterate in a defined order.
pub const ORDERED_SCOPES: [&str; 9] = [
    "engine",
    "algorithms",
    "membership",
    "consensus",
    "adapt",
    "churn",
    "topology",
    "fragment",
    "stale",
];

/// Event-path modules that must degrade deterministically instead of
/// panicking into the sweep's containment: the engine dispatch itself
/// plus the subsystems it calls from inside event handlers.
pub const PANIC_FREE_SCOPES: [&str; 5] =
    ["engine", "adapt", "fragment", "membership", "stale"];

/// Modules allowed to read the host clock (measurement harness + CLIs).
pub const WALL_CLOCK_EXEMPT: [&str; 2] = ["sweep", "bin"];

/// The six core (suppressible) rules, in catalogue order.
pub fn registry() -> Vec<RuleInfo> {
    vec![
        RuleInfo {
            name: "no-unordered-iteration",
            severity: Severity::Error,
            description: "HashMap/HashSet in event-ordered modules (iteration order leaks \
                          into event order; use BTreeMap/BTreeSet)",
        },
        RuleInfo {
            name: "no-wall-clock",
            severity: Severity::Error,
            description: "Instant::now/SystemTime::now outside sweep/bin (simulation runs \
                          on virtual time only)",
        },
        RuleInfo {
            name: "no-ambient-rng",
            severity: Severity::Error,
            description: "thread_rng/rand::random/from_entropy anywhere (all randomness \
                          must come from seeded per-worker streams)",
        },
        RuleInfo {
            name: "no-panic-in-engine",
            severity: Severity::Error,
            description: "unwrap()/expect(/panic! in the event path (engine, adapt, \
                          fragment, membership, stale — sweep panic containment is a \
                          backstop, not a code path)",
        },
        RuleInfo {
            name: "strict-config-parse",
            severity: Severity::Error,
            description: "from_json impls must reject unknown keys (the strict-parsed \
                          section convention)",
        },
        RuleInfo {
            name: "no-float-accumulation-order",
            severity: Severity::Error,
            description: "float sum/product (turbofish or annotation-typed) over a hash \
                          container or a parallel iterator in event-ordered modules (f32 \
                          addition is non-associative, so a randomized visit or reduction \
                          order changes the result bitwise; reduce over a BTree/sorted \
                          Vec, sequentially)",
        },
    ]
}

/// Whether `name` is one of the suppressible core rules.
pub fn is_known_rule(name: &str) -> bool {
    registry().iter().any(|r| r.name == name)
}

/// A raw finding before pragma suppression (file attached by the caller).
#[derive(Debug, Clone)]
pub struct RawFinding {
    /// Rule that fired.
    pub rule: &'static str,
    /// Finding severity.
    pub severity: Severity,
    /// 1-based line.
    pub line: u32,
    /// 1-based byte column.
    pub col: u32,
    /// The offending lexeme (e.g. `HashMap`, `Instant::now`).
    pub lexeme: String,
    /// Human explanation.
    pub message: String,
}

/// Map a path relative to the source root onto crate-module components:
/// `engine/mod.rs` → `["engine"]`, `algorithms/prague.rs` →
/// `["algorithms", "prague"]`, `main.rs` → `["bin"]`, `lib.rs` → `[]`.
pub fn module_path(rel: &str) -> Vec<String> {
    let rel = rel.replace('\\', "/");
    let mut parts: Vec<String> = rel.split('/').map(|s| s.to_string()).collect();
    let last = parts.pop().unwrap_or_default();
    match last.as_str() {
        "lib.rs" => {}
        "mod.rs" => {}
        "main.rs" => parts.push("bin".to_string()),
        other => parts.push(other.trim_end_matches(".rs").to_string()),
    }
    parts
}

/// Mark every token that sits inside a `#[test]` function or a
/// `#[cfg(test)]`-gated item (incl. `mod tests { … }` bodies).
pub fn test_spans(toks: &[Tok]) -> Vec<bool> {
    let mut in_test = vec![false; toks.len()];
    let mut i = 0;
    while i < toks.len() {
        if toks[i].is_punct('#') && toks.get(i + 1).map_or(false, |t| t.is_punct('[')) {
            if let Some(close) = matching_bracket(toks, i + 1) {
                let body = &toks[i + 2..close];
                let is_test = body.iter().any(|t| t.is_ident("test"))
                    && !body.iter().any(|t| t.is_ident("not"));
                if is_test {
                    let end = item_end(toks, close + 1);
                    for flag in in_test.iter_mut().take(end + 1).skip(i) {
                        *flag = true;
                    }
                    i = end + 1;
                    continue;
                }
                i = close + 1;
                continue;
            }
        }
        i += 1;
    }
    in_test
}

/// Index of the `]` matching the `[` at `open`, tolerating nesting.
fn matching_bracket(toks: &[Tok], open: usize) -> Option<usize> {
    let mut depth = 0usize;
    for (j, t) in toks.iter().enumerate().skip(open) {
        if t.is_punct('[') {
            depth += 1;
        } else if t.is_punct(']') {
            depth -= 1;
            if depth == 0 {
                return Some(j);
            }
        }
    }
    None
}

/// Index of the last token of the item starting at `start` (after an
/// attribute): skips further attributes, then ends at the `}` matching
/// the first `{`, or at a `;` if one comes first (no body).
fn item_end(toks: &[Tok], mut start: usize) -> usize {
    // skip stacked attributes (`#[test] #[ignore] fn …`)
    while start < toks.len()
        && toks[start].is_punct('#')
        && toks.get(start + 1).map_or(false, |t| t.is_punct('['))
    {
        match matching_bracket(toks, start + 1) {
            Some(close) => start = close + 1,
            None => return toks.len().saturating_sub(1),
        }
    }
    let mut j = start;
    while j < toks.len() {
        if toks[j].is_punct(';') {
            return j;
        }
        if toks[j].is_punct('{') {
            let mut depth = 0usize;
            while j < toks.len() {
                if toks[j].is_punct('{') {
                    depth += 1;
                } else if toks[j].is_punct('}') {
                    depth -= 1;
                    if depth == 0 {
                        return j;
                    }
                }
                j += 1;
            }
            return toks.len().saturating_sub(1);
        }
        j += 1;
    }
    toks.len().saturating_sub(1)
}

/// Run every rule over one file's tokens.  `rel` is the path relative
/// to the source root (used for module scoping).
pub fn run_rules(rel: &str, toks: &[Tok]) -> Vec<RawFinding> {
    let scope = module_path(rel);
    let top = scope.first().map(String::as_str).unwrap_or("").to_string();
    let in_test = test_spans(toks);
    // Pre-filter to code tokens (comments out, test regions out) while
    // remembering original positions for sequence checks.
    let code: Vec<&Tok> = toks
        .iter()
        .zip(&in_test)
        .filter(|(t, &skip)| !skip && t.kind != TokKind::Comment)
        .map(|(t, _)| t)
        .collect();

    let mut out = Vec::new();
    no_unordered_iteration(&top, &code, &mut out);
    no_wall_clock(&top, &code, &mut out);
    no_ambient_rng(&code, &mut out);
    no_panic_in_engine(&top, &code, &mut out);
    strict_config_parse(&code, &mut out);
    no_float_accumulation_order(&top, &code, &mut out);
    out
}

fn push(out: &mut Vec<RawFinding>, rule: &'static str, t: &Tok, lexeme: &str, msg: String) {
    let severity = registry()
        .iter()
        .find(|r| r.name == rule)
        .map(|r| r.severity)
        .unwrap_or(Severity::Error);
    out.push(RawFinding {
        rule,
        severity,
        line: t.line,
        col: t.col,
        lexeme: lexeme.to_string(),
        message: msg,
    });
}

fn no_unordered_iteration(top: &str, code: &[&Tok], out: &mut Vec<RawFinding>) {
    if !ORDERED_SCOPES.contains(&top) {
        return;
    }
    for t in code {
        if t.is_ident("HashMap") || t.is_ident("HashSet") {
            push(
                out,
                "no-unordered-iteration",
                t,
                &t.text,
                format!(
                    "{} in `{top}`: iteration order is randomized per process and leaks \
                     into event order; use BTreeMap/BTreeSet or a sorted Vec",
                    t.text
                ),
            );
        }
    }
}

fn no_wall_clock(top: &str, code: &[&Tok], out: &mut Vec<RawFinding>) {
    if WALL_CLOCK_EXEMPT.contains(&top) {
        return;
    }
    for w in code.windows(4) {
        let clock = w[0].kind == TokKind::Ident
            && (w[0].text == "Instant" || w[0].text == "SystemTime");
        if clock && w[1].is_punct(':') && w[2].is_punct(':') && w[3].is_ident("now") {
            let lexeme = format!("{}::now", w[0].text);
            push(
                out,
                "no-wall-clock",
                w[0],
                &lexeme,
                format!("{lexeme} outside sweep/bin: the simulation runs on virtual time"),
            );
        }
    }
}

fn no_ambient_rng(code: &[&Tok], out: &mut Vec<RawFinding>) {
    for t in code {
        if t.is_ident("thread_rng") || t.is_ident("from_entropy") {
            push(
                out,
                "no-ambient-rng",
                t,
                &t.text,
                format!("{}: all randomness must come from seeded per-worker streams", t.text),
            );
        }
    }
    for w in code.windows(4) {
        if w[0].is_ident("rand")
            && w[1].is_punct(':')
            && w[2].is_punct(':')
            && w[3].is_ident("random")
        {
            push(
                out,
                "no-ambient-rng",
                w[0],
                "rand::random",
                "rand::random: all randomness must come from seeded per-worker streams"
                    .to_string(),
            );
        }
    }
}

fn no_panic_in_engine(top: &str, code: &[&Tok], out: &mut Vec<RawFinding>) {
    if !PANIC_FREE_SCOPES.contains(&top) {
        return;
    }
    for w in code.windows(2) {
        let (t, next) = (w[0], w[1]);
        if (t.is_ident("unwrap") || t.is_ident("expect")) && next.is_punct('(') {
            push(
                out,
                "no-panic-in-engine",
                t,
                &format!("{}(", t.text),
                format!(
                    "{}() in `{top}`: event-path code must degrade deterministically, \
                     not panic into the sweep's containment",
                    t.text
                ),
            );
        } else if t.is_ident("panic") && next.is_punct('!') {
            push(
                out,
                "no-panic-in-engine",
                t,
                "panic!",
                format!(
                    "panic! in `{top}`: event-path code must degrade deterministically, \
                     not panic into the sweep's containment"
                ),
            );
        }
    }
}

/// A `from_json` body satisfies the strict-parse convention when it
/// either bails with an "unknown …" message itself or delegates to
/// `apply_kv` (which does).
fn strict_config_parse(code: &[&Tok], out: &mut Vec<RawFinding>) {
    let mut i = 0;
    while i + 1 < code.len() {
        if code[i].is_ident("fn") && code[i + 1].is_ident("from_json") {
            let name = code[i + 1];
            // find the body: first `{` after the signature
            let mut j = i + 2;
            while j < code.len() && !code[j].is_punct('{') {
                j += 1;
            }
            let mut depth = 0usize;
            let mut end = j;
            while end < code.len() {
                if code[end].is_punct('{') {
                    depth += 1;
                } else if code[end].is_punct('}') {
                    depth -= 1;
                    if depth == 0 {
                        break;
                    }
                }
                end += 1;
            }
            let body = &code[j..end.min(code.len())];
            let strict = body.iter().any(|t| {
                (t.kind == TokKind::Str && t.text.to_ascii_lowercase().contains("unknown"))
                    || t.is_ident("apply_kv")
            });
            if !strict {
                push(
                    out,
                    "strict-config-parse",
                    name,
                    "from_json",
                    "from_json without unknown-key rejection: strict-parsed sections must \
                     bail on keys they do not understand"
                        .to_string(),
                );
            }
            i = end;
        }
        i += 1;
    }
}

/// Flag float `sum()`/`product()` reductions whose visit order is not
/// deterministic: inside a function that also names a
/// `HashMap`/`HashSet` (the classic "iterate the hash container, fold
/// the floats" shape, order-randomized even when the container itself
/// carries a suppression pragma), or chained off a **parallel iterator**
/// in the same statement (`par_iter().sum::<f32>()` — rayon-style
/// reductions combine partial sums in thread-completion order).  Two
/// detection forms for each: the turbofish (`sum::<f32>()`) and the
/// annotation-typed let binding (`let s: f32 = it.sum()`).  Scoped to
/// the event-ordered modules; the enclosing-function / same-statement
/// windows are heuristics that keep the rule free of false positives on
/// ordered reductions.
fn no_float_accumulation_order(top: &str, code: &[&Tok], out: &mut Vec<RawFinding>) {
    if !ORDERED_SCOPES.contains(&top) {
        return;
    }
    // does the reduction's enclosing function also name a hash
    // container? (conservative: same-fn co-occurrence)
    let hashed_fn = |i: usize| {
        let fn_start = code[..i].iter().rposition(|t| t.is_ident("fn")).unwrap_or(0);
        code[fn_start..i].iter().any(|t| t.is_ident("HashMap") || t.is_ident("HashSet"))
    };
    let stmt_start_of = |i: usize| {
        code[..i]
            .iter()
            .rposition(|t| t.is_punct(';') || t.is_punct('{') || t.is_punct('}'))
            .map(|j| j + 1)
            .unwrap_or(0)
    };
    // is the reduction chained off a parallel iterator in this statement?
    let par_stmt = |i: usize| {
        code[stmt_start_of(i)..i].iter().any(|t| {
            t.is_ident("par_iter")
                || t.is_ident("into_par_iter")
                || t.is_ident("par_iter_mut")
                || t.is_ident("par_bridge")
                || t.is_ident("par_chunks")
        })
    };
    let flag = |out: &mut Vec<RawFinding>, t: &Tok, lexeme: &str, parallel: bool| {
        let why = if parallel {
            "over a parallel iterator"
        } else {
            "in a function using HashMap/HashSet"
        };
        let fix = if parallel {
            "collect and reduce sequentially in a deterministic order"
        } else {
            "reduce over a BTree container or a sorted Vec"
        };
        push(
            out,
            "no-float-accumulation-order",
            t,
            lexeme,
            format!(
                "{lexeme} {why} in `{top}`: float addition is non-associative, so a \
                 nondeterministic accumulation order changes the result bitwise; {fix}"
            ),
        );
    };
    for i in 0..code.len().saturating_sub(4) {
        let t = code[i];
        let turbofish = (t.is_ident("sum") || t.is_ident("product"))
            && code[i + 1].is_punct(':')
            && code[i + 2].is_punct(':')
            && code[i + 3].is_punct('<')
            && (code[i + 4].is_ident("f32") || code[i + 4].is_ident("f64"));
        if !turbofish {
            continue;
        }
        let parallel = par_stmt(i);
        if parallel || hashed_fn(i) {
            let lexeme = format!("{}::<{}>", t.text, code[i + 4].text);
            flag(out, t, &lexeme, parallel);
        }
    }
    // annotation-typed form: `let s: f32 = …sum()` — the element type is
    // named on the binding instead of the turbofish
    for i in 0..code.len().saturating_sub(1) {
        let t = code[i];
        let bare_call = (t.is_ident("sum") || t.is_ident("product")) && code[i + 1].is_punct('(');
        if !bare_call {
            continue;
        }
        let stmt = &code[stmt_start_of(i)..i];
        let is_let = stmt.first().map_or(false, |t| t.is_ident("let"));
        let float_typed = stmt
            .windows(2)
            .any(|w| w[0].is_punct(':') && (w[1].is_ident("f32") || w[1].is_ident("f64")));
        if !(is_let && float_typed) {
            continue;
        }
        let parallel = par_stmt(i);
        if parallel || hashed_fn(i) {
            let lexeme = format!("{}()", t.text);
            flag(out, t, &lexeme, parallel);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::lexer::lex;

    #[test]
    fn module_paths() {
        assert_eq!(module_path("engine/mod.rs"), vec!["engine"]);
        assert_eq!(module_path("algorithms/prague.rs"), vec!["algorithms", "prague"]);
        assert_eq!(module_path("config.rs"), vec!["config"]);
        assert_eq!(module_path("bin/lint.rs"), vec!["bin", "lint"]);
        assert_eq!(module_path("main.rs"), vec!["bin"]);
        assert!(module_path("lib.rs").is_empty());
    }

    #[test]
    fn test_regions_are_skipped() {
        let src = "fn live() { m.unwrap(); }\n\
                   #[cfg(test)]\nmod tests {\n fn t() { x.unwrap(); }\n}\n";
        let f = run_rules("engine/mod.rs", &lex(src));
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].line, 1);
    }

    #[test]
    fn cfg_not_test_is_not_a_test_region() {
        let src = "#[cfg(not(test))]\nfn live() { m.unwrap(); }\n";
        let f = run_rules("engine/mod.rs", &lex(src));
        assert_eq!(f.len(), 1);
    }

    #[test]
    fn scoping_gates_unordered_and_wall_clock() {
        let src = "use std::collections::HashMap;\nfn f() { let t = Instant::now(); }\n";
        assert_eq!(run_rules("engine/mod.rs", &lex(src)).len(), 2);
        assert_eq!(run_rules("data/mod.rs", &lex(src)).len(), 1); // clock only
        assert_eq!(run_rules("sweep/cli.rs", &lex(src)).len(), 0); // neither
    }

    #[test]
    fn panic_rule_ignores_unwrap_or_else() {
        let src = "fn f() { a.unwrap_or_else(|| 0); b.unwrap_or(1); c.unwrap_or_default(); }";
        assert!(run_rules("engine/mod.rs", &lex(src)).is_empty());
    }

    #[test]
    fn panic_rule_covers_event_path_scopes() {
        let src = "fn f(x: Option<u32>) -> u32 { x.unwrap() }";
        for m in [
            "engine/mod.rs",
            "adapt/monitor.rs",
            "fragment/mod.rs",
            "membership/mod.rs",
            "stale/mod.rs",
        ] {
            assert_eq!(run_rules(m, &lex(src)).len(), 1, "{m} must be panic-free");
        }
        // algorithms and the measurement layers stay out of scope
        assert!(run_rules("algorithms/greedy.rs", &lex(src)).is_empty());
        assert!(run_rules("sweep/cli.rs", &lex(src)).is_empty());
    }

    #[test]
    fn float_accumulation_catches_annotation_typed_sums() {
        // `let s: f32 = …sum()` over a hash container: flagged (also
        // exercises the new `stale` ordered scope)
        let bad = "fn f(m: &HashMap<u32, f32>) -> f32 { let s: f32 = m.values().sum(); s }";
        let fired: Vec<&str> =
            run_rules("stale/mod.rs", &lex(bad)).iter().map(|f| f.rule).collect();
        assert!(fired.contains(&"no-float-accumulation-order"), "{fired:?}");
        // same shape over an ordered container: clean
        let ordered =
            "fn f(m: &BTreeMap<u32, f32>) -> f32 { let s: f32 = m.values().sum(); s }";
        assert!(run_rules("stale/mod.rs", &lex(ordered)).is_empty());
        // annotation-typed *integer* sum over a hash container: only the
        // container rule fires
        let ints = "fn f(m: &HashMap<u32, u64>) -> u64 { let s: u64 = m.values().sum(); s }";
        let fired: Vec<&str> =
            run_rules("stale/mod.rs", &lex(ints)).iter().map(|f| f.rule).collect();
        assert!(!fired.contains(&"no-float-accumulation-order"), "{fired:?}");
        // hash usage and the annotated reduction in different fns: clean
        let split = "fn a(m: &HashMap<u32, f32>) {}\n\
                     fn b(v: &[f32]) -> f32 { let s: f32 = v.iter().sum(); s }";
        let fired: Vec<&str> =
            run_rules("fragment/mod.rs", &lex(split)).iter().map(|f| f.rule).collect();
        assert!(!fired.contains(&"no-float-accumulation-order"), "{fired:?}");
    }

    #[test]
    fn float_accumulation_needs_hash_and_turbofish() {
        // hash container + float turbofish reduction in one fn: flagged
        // (the HashMap ident itself also fires no-unordered-iteration)
        let bad = "fn f(m: &HashMap<u32, f32>) -> f32 { m.values().sum::<f32>() }";
        let fired: Vec<&str> =
            run_rules("engine/mod.rs", &lex(bad)).iter().map(|f| f.rule).collect();
        assert!(fired.contains(&"no-float-accumulation-order"));
        // ordered container: clean
        let ordered = "fn f(m: &BTreeMap<u32, f32>) -> f32 { m.values().sum::<f32>() }";
        assert!(run_rules("engine/mod.rs", &lex(ordered)).is_empty());
        // integer reduction over a hash container: only the container rule
        let ints = "fn f(m: &HashMap<u32, u64>) -> u64 { m.values().sum::<u64>() }";
        let fired: Vec<&str> =
            run_rules("engine/mod.rs", &lex(ints)).iter().map(|f| f.rule).collect();
        assert!(!fired.contains(&"no-float-accumulation-order"));
        // out-of-scope module: clean
        assert!(run_rules("data/mod.rs", &lex(bad)).is_empty());
        // the hash usage and the reduction in *different* fns: clean
        let split = "fn a(m: &HashMap<u32, f32>) {}\n\
                     fn b(v: &[f32]) -> f32 { v.iter().sum::<f32>() }";
        let fired: Vec<&str> =
            run_rules("fragment/mod.rs", &lex(split)).iter().map(|f| f.rule).collect();
        assert!(!fired.contains(&"no-float-accumulation-order"));
    }

    #[test]
    fn float_accumulation_catches_parallel_iterators() {
        // float turbofish reduction chained off par_iter: flagged even
        // with no hash container anywhere in the function
        let bad = "fn f(v: &[f32]) -> f32 { v.par_iter().copied().sum::<f32>() }";
        let f = run_rules("engine/mod.rs", &lex(bad));
        assert_eq!(f.len(), 1, "{f:?}");
        assert!(f[0].message.contains("parallel iterator"), "{}", f[0].message);
        // annotation-typed form over into_par_iter
        let bad2 = "fn f(v: Vec<f64>) -> f64 { let s: f64 = v.into_par_iter().sum(); s }";
        assert_eq!(run_rules("engine/mod.rs", &lex(bad2)).len(), 1);
        // integer parallel sum: order-independent, clean
        let ints = "fn f(v: &[u64]) -> u64 { v.par_iter().sum::<u64>() }";
        assert!(run_rules("engine/mod.rs", &lex(ints)).is_empty());
        // the parallel stage and the float fold in different statements:
        // the reduction itself is sequential and ordered, clean
        let staged = "fn f(v: &[f32]) -> f32 { \
                      let c: Vec<f32> = v.par_iter().copied().collect(); \
                      c.iter().sum::<f32>() }";
        assert!(run_rules("engine/mod.rs", &lex(staged)).is_empty());
        // out-of-scope module: clean
        assert!(run_rules("data/mod.rs", &lex(bad)).is_empty());
    }

    #[test]
    fn strict_parse_accepts_bail_and_apply_kv() {
        let ok1 = r#"fn from_json(v: &Json) { bail!("unknown key {k:?}"); }"#;
        let ok2 = "fn from_json(v: &Json) { cfg.apply_kv(key, v)?; }";
        let bad = "fn from_json(v: &Json) { let x = v.get(\"kind\"); }";
        assert!(run_rules("config.rs", &lex(ok1)).is_empty());
        assert!(run_rules("config.rs", &lex(ok2)).is_empty());
        assert_eq!(run_rules("config.rs", &lex(bad)).len(), 1);
    }
}
