//! A small Rust lexer for the lint pass.
//!
//! Tokenizes a source file into identifiers, punctuation, literals and
//! comments with `line:col` positions, handling exactly the constructs
//! that make naive grepping unsound: line and (nested) block comments,
//! string literals with escapes, raw strings with arbitrary `#` fences,
//! byte strings, char literals, and the char-vs-lifetime ambiguity.
//! It does **not** parse: rules operate on the token stream plus the
//! module scope, which is all the repo's invariants need.

/// What kind of lexeme a token is.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TokKind {
    /// Identifier or keyword (`fn`, `HashMap`, `unwrap`, …).
    Ident,
    /// Numeric literal (loosely lexed; never inspected by rules).
    Num,
    /// String literal of any flavor (`"…"`, `r#"…"#`, `b"…"`); `text`
    /// holds the *contents* without quotes or fences.
    Str,
    /// Char or byte-char literal (`'x'`, `b'\n'`).
    Char,
    /// Lifetime or loop label (`'a`, `'outer`).
    Lifetime,
    /// Single punctuation character (`{`, `:`, `!`, …).
    Punct,
    /// Line or block comment; `text` holds the full comment including
    /// its `//` / `/* */` markers (pragmas are parsed out of these).
    Comment,
}

/// One token with its 1-based source position.
#[derive(Debug, Clone)]
pub struct Tok {
    /// Lexeme class.
    pub kind: TokKind,
    /// Lexeme text (see [`TokKind`] for per-kind conventions).
    pub text: String,
    /// 1-based line of the first character.
    pub line: u32,
    /// 1-based byte column of the first character.
    pub col: u32,
}

impl Tok {
    /// Whether this token is the identifier `s`.
    pub fn is_ident(&self, s: &str) -> bool {
        self.kind == TokKind::Ident && self.text == s
    }

    /// Whether this token is the punctuation character `c`.
    pub fn is_punct(&self, c: char) -> bool {
        self.kind == TokKind::Punct && self.text.len() == c.len_utf8() && self.text.starts_with(c)
    }
}

/// Tokenize `src`.  Unterminated literals/comments are tolerated (the
/// remainder of the file becomes one token): the lint must keep scanning
/// a tree that may not even compile yet.
pub fn lex(src: &str) -> Vec<Tok> {
    Lexer { src: src.as_bytes(), pos: 0, line: 1, col: 1, toks: Vec::new() }.run()
}

struct Lexer<'a> {
    src: &'a [u8],
    pos: usize,
    line: u32,
    col: u32,
    toks: Vec<Tok>,
}

impl<'a> Lexer<'a> {
    fn run(mut self) -> Vec<Tok> {
        while let Some(b) = self.peek(0) {
            let (line, col, start) = (self.line, self.col, self.pos);
            match b {
                b' ' | b'\t' | b'\r' | b'\n' => {
                    self.bump();
                }
                b'/' if self.peek(1) == Some(b'/') => self.line_comment(line, col, start),
                b'/' if self.peek(1) == Some(b'*') => self.block_comment(line, col, start),
                b'"' => self.string(line, col),
                b'\'' => self.char_or_lifetime(line, col),
                b'r' | b'b' if self.raw_or_byte_prefix() => self.raw_or_byte(line, col),
                c if c.is_ascii_digit() => self.number(line, col, start),
                c if c == b'_' || c.is_ascii_alphabetic() => self.ident(line, col, start),
                _ => {
                    self.bump();
                    self.push(TokKind::Punct, start, line, col);
                }
            }
        }
        self.toks
    }

    fn peek(&self, ahead: usize) -> Option<u8> {
        self.src.get(self.pos + ahead).copied()
    }

    fn bump(&mut self) {
        if self.src[self.pos] == b'\n' {
            self.line += 1;
            self.col = 1;
        } else {
            self.col += 1;
        }
        self.pos += 1;
    }

    fn push(&mut self, kind: TokKind, start: usize, line: u32, col: u32) {
        let text = String::from_utf8_lossy(&self.src[start..self.pos]).into_owned();
        self.toks.push(Tok { kind, text, line, col });
    }

    fn push_text(&mut self, kind: TokKind, text: String, line: u32, col: u32) {
        self.toks.push(Tok { kind, text, line, col });
    }

    fn line_comment(&mut self, line: u32, col: u32, start: usize) {
        while let Some(b) = self.peek(0) {
            if b == b'\n' {
                break;
            }
            self.bump();
        }
        self.push(TokKind::Comment, start, line, col);
    }

    fn block_comment(&mut self, line: u32, col: u32, start: usize) {
        self.bump(); // '/'
        self.bump(); // '*'
        let mut depth = 1usize;
        while let Some(b) = self.peek(0) {
            if b == b'/' && self.peek(1) == Some(b'*') {
                depth += 1;
                self.bump();
                self.bump();
            } else if b == b'*' && self.peek(1) == Some(b'/') {
                depth -= 1;
                self.bump();
                self.bump();
                if depth == 0 {
                    break;
                }
            } else {
                self.bump();
            }
        }
        self.push(TokKind::Comment, start, line, col);
    }

    /// `"…"` with `\` escapes; the token text is the unquoted contents.
    fn string(&mut self, line: u32, col: u32) {
        self.bump(); // opening quote
        let start = self.pos;
        while let Some(b) = self.peek(0) {
            match b {
                b'\\' => {
                    self.bump();
                    if self.peek(0).is_some() {
                        self.bump();
                    }
                }
                b'"' => break,
                _ => self.bump(),
            }
        }
        let text = String::from_utf8_lossy(&self.src[start..self.pos]).into_owned();
        if self.peek(0) == Some(b'"') {
            self.bump();
        }
        self.push_text(TokKind::Str, text, line, col);
    }

    /// Lifetime (`'a`) vs char literal (`'a'`, `'\n'`, `'\u{1F600}'`).
    fn char_or_lifetime(&mut self, line: u32, col: u32) {
        self.bump(); // '\''
        let start = self.pos;
        let first = self.peek(0);
        let ident_start =
            first.map_or(false, |b| b == b'_' || b.is_ascii_alphabetic());
        if ident_start && self.peek(1) != Some(b'\'') {
            // lifetime or label: consume the identifier tail
            while let Some(b) = self.peek(0) {
                if b == b'_' || b.is_ascii_alphanumeric() {
                    self.bump();
                } else {
                    break;
                }
            }
            self.push(TokKind::Lifetime, start, line, col);
            return;
        }
        // char literal: one (possibly escaped) char then the closing quote
        while let Some(b) = self.peek(0) {
            match b {
                b'\\' => {
                    self.bump();
                    if self.peek(0).is_some() {
                        self.bump();
                    }
                }
                b'\'' => break,
                _ => self.bump(),
            }
        }
        let text = String::from_utf8_lossy(&self.src[start..self.pos]).into_owned();
        if self.peek(0) == Some(b'\'') {
            self.bump();
        }
        self.push_text(TokKind::Char, text, line, col);
    }

    /// Whether the current `r`/`b` starts a raw/byte literal rather than
    /// an identifier: `r"`, `r#`, `b"`, `b'`, `br`/`rb` + fence.
    fn raw_or_byte_prefix(&self) -> bool {
        let b0 = self.peek(0).unwrap();
        match (b0, self.peek(1)) {
            (b'r', Some(b'"')) | (b'r', Some(b'#')) => true,
            (b'b', Some(b'"')) | (b'b', Some(b'\'')) => true,
            (b'b', Some(b'r')) => matches!(self.peek(2), Some(b'"') | Some(b'#')),
            _ => false,
        }
    }

    fn raw_or_byte(&mut self, line: u32, col: u32) {
        let raw = match self.peek(0) {
            Some(b'r') => true,
            Some(b'b') if self.peek(1) == Some(b'r') => {
                self.bump(); // 'b'
                true
            }
            _ => false,
        };
        self.bump(); // 'r' or 'b'
        if !raw {
            // b"…" or b'…': reuse the escaped forms
            if self.peek(0) == Some(b'"') {
                self.string(line, col);
            } else {
                self.char_or_lifetime(line, col);
            }
            return;
        }
        let mut fence = 0usize;
        while self.peek(0) == Some(b'#') {
            fence += 1;
            self.bump();
        }
        if self.peek(0) != Some(b'"') {
            // `r#foo` raw identifier: lex the tail as a plain ident
            let start = self.pos;
            while let Some(b) = self.peek(0) {
                if b == b'_' || b.is_ascii_alphanumeric() {
                    self.bump();
                } else {
                    break;
                }
            }
            self.push(TokKind::Ident, start, line, col);
            return;
        }
        self.bump(); // opening quote
        let start = self.pos;
        let mut end = self.pos;
        'scan: while let Some(b) = self.peek(0) {
            if b == b'"' {
                // need `fence` hashes to close
                for k in 0..fence {
                    if self.peek(1 + k) != Some(b'#') {
                        self.bump();
                        continue 'scan;
                    }
                }
                end = self.pos;
                self.bump(); // closing quote
                for _ in 0..fence {
                    self.bump();
                }
                let text = String::from_utf8_lossy(&self.src[start..end]).into_owned();
                self.push_text(TokKind::Str, text, line, col);
                return;
            }
            self.bump();
            end = self.pos;
        }
        let text = String::from_utf8_lossy(&self.src[start..end]).into_owned();
        self.push_text(TokKind::Str, text, line, col);
    }

    fn number(&mut self, line: u32, col: u32, start: usize) {
        // integer part (incl. 0x/0b/0o digits and type-suffix letters)
        while let Some(b) = self.peek(0) {
            if b == b'_' || b.is_ascii_alphanumeric() {
                self.bump();
            } else {
                break;
            }
        }
        // fractional part only when `.` is followed by a digit, so `1.max`
        // and ranges like `0..n` stay separate tokens
        if self.peek(0) == Some(b'.') && self.peek(1).map_or(false, |b| b.is_ascii_digit()) {
            self.bump();
            while let Some(b) = self.peek(0) {
                if b == b'_' || b.is_ascii_alphanumeric() {
                    self.bump();
                } else {
                    break;
                }
            }
        }
        // exponent sign (`1e-3`): the `e` was consumed above
        if matches!(self.peek(0), Some(b'+') | Some(b'-'))
            && self.src[self.pos - 1].eq_ignore_ascii_case(&b'e')
            && self.src[start].is_ascii_digit()
        {
            self.bump();
            while let Some(b) = self.peek(0) {
                if b == b'_' || b.is_ascii_digit() {
                    self.bump();
                } else {
                    break;
                }
            }
        }
        self.push(TokKind::Num, start, line, col);
    }

    fn ident(&mut self, line: u32, col: u32, start: usize) {
        while let Some(b) = self.peek(0) {
            if b == b'_' || b.is_ascii_alphanumeric() {
                self.bump();
            } else {
                break;
            }
        }
        self.push(TokKind::Ident, start, line, col);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn kinds(src: &str) -> Vec<(TokKind, String)> {
        lex(src).into_iter().map(|t| (t.kind, t.text)).collect()
    }

    #[test]
    fn idents_and_puncts() {
        let t = kinds("fn main() {}");
        assert_eq!(t[0], (TokKind::Ident, "fn".into()));
        assert_eq!(t[1], (TokKind::Ident, "main".into()));
        assert!(t[2..].iter().all(|(k, _)| *k == TokKind::Punct));
    }

    #[test]
    fn comments_capture_text_and_positions() {
        let t = lex("let x = 1; // HashMap here\n/* Instant::now */ let y;");
        let comments: Vec<&Tok> = t.iter().filter(|t| t.kind == TokKind::Comment).collect();
        assert_eq!(comments.len(), 2);
        assert!(comments[0].text.contains("HashMap"));
        assert_eq!(comments[0].line, 1);
        assert_eq!(comments[1].line, 2);
        // no Ident token leaked out of either comment
        assert!(!t.iter().any(|t| t.kind == TokKind::Ident && t.text == "HashMap"));
    }

    #[test]
    fn nested_block_comments() {
        let t = kinds("/* a /* nested */ still comment */ fn");
        assert_eq!(t.len(), 2);
        assert_eq!(t[1], (TokKind::Ident, "fn".into()));
    }

    #[test]
    fn strings_hide_identifiers() {
        let t = kinds(r#"let s = "HashMap \" Instant::now";"#);
        assert!(t.iter().any(|(k, x)| *k == TokKind::Str && x.contains("HashMap")));
        assert!(!t.iter().any(|(k, x)| *k == TokKind::Ident && x == "HashMap"));
    }

    #[test]
    fn raw_strings_with_fences() {
        let t = kinds(r###"let s = r#"a "quoted" HashMap"# ;"###);
        assert!(t.iter().any(|(k, x)| *k == TokKind::Str && x.contains("HashMap")));
        assert!(!t.iter().any(|(k, x)| *k == TokKind::Ident && x == "HashMap"));
    }

    #[test]
    fn char_vs_lifetime() {
        let t = kinds("let c: char = 'a'; fn f<'a>(x: &'a str) {} let n = '\\n';");
        let chars: Vec<_> = t.iter().filter(|(k, _)| *k == TokKind::Char).collect();
        let lifetimes: Vec<_> = t.iter().filter(|(k, _)| *k == TokKind::Lifetime).collect();
        assert_eq!(chars.len(), 2);
        assert_eq!(lifetimes.len(), 2);
    }

    #[test]
    fn numbers_do_not_eat_methods_or_ranges() {
        let t = kinds("let a = 1.max(2); for i in 0..n {} let f = 1.5e-3;");
        assert!(t.iter().any(|(k, x)| *k == TokKind::Ident && x == "max"));
        assert!(t.iter().any(|(k, x)| *k == TokKind::Ident && x == "n"));
        assert!(t.iter().any(|(k, x)| *k == TokKind::Num && x == "1.5e-3"));
    }

    #[test]
    fn byte_literals() {
        let t = kinds(r#"let b = b"bytes"; let c = b'\n'; let r = br#x;"#);
        assert!(t.iter().any(|(k, x)| *k == TokKind::Str && x == "bytes"));
        assert!(t.iter().any(|(k, _)| *k == TokKind::Char));
    }

    #[test]
    fn positions_are_one_based() {
        let t = lex("a\n  b");
        assert_eq!((t[0].line, t[0].col), (1, 1));
        assert_eq!((t[1].line, t[1].col), (2, 3));
    }
}
