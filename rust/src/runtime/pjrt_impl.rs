//! Real PJRT runtime (feature `pjrt`): compiles the AOT HLO-text
//! artifacts on a CPU PJRT client via the vendored `xla` bindings.
//!
//! Interchange is HLO *text*: xla_extension 0.5.1 rejects jax ≥ 0.5
//! serialized protos (64-bit instruction ids); the text parser reassigns
//! ids (see /opt/xla-example/README.md).

use super::{BatchInput, TrainOutput, VariantMeta};
use crate::runtime::Manifest;
use anyhow::{Context, Result};
use std::path::{Path, PathBuf};

/// f32 slice -> raw bytes (little-endian host layout, what PJRT expects).
fn f32_bytes(x: &[f32]) -> &[u8] {
    unsafe { std::slice::from_raw_parts(x.as_ptr() as *const u8, x.len() * 4) }
}

fn i32_bytes(x: &[i32]) -> &[u8] {
    unsafe { std::slice::from_raw_parts(x.as_ptr() as *const u8, x.len() * 4) }
}

/// Build an f32 literal of the given dims from a host slice (zero-copy on
/// the rust side; PJRT copies into device-layout memory once).
pub fn literal_f32(dims: &[usize], data: &[f32]) -> xla::Literal {
    debug_assert_eq!(dims.iter().product::<usize>(), data.len());
    xla::Literal::create_from_shape_and_untyped_data(
        xla::ElementType::F32,
        dims,
        f32_bytes(data),
    )
    .expect("f32 literal")
}

/// Build an i32 literal of the given dims.
pub fn literal_i32(dims: &[usize], data: &[i32]) -> xla::Literal {
    debug_assert_eq!(dims.iter().product::<usize>(), data.len());
    xla::Literal::create_from_shape_and_untyped_data(
        xla::ElementType::S32,
        dims,
        i32_bytes(data),
    )
    .expect("i32 literal")
}

/// One compiled HLO module on the shared CPU client.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
}

impl Executable {
    /// Load HLO text from `path` and compile it on `client`.
    pub fn load(client: &xla::PjRtClient, path: &Path) -> Result<Self> {
        let proto = xla::HloModuleProto::from_text_file(path)
            .map_err(|e| anyhow::anyhow!("parse {}: {e}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compile {}: {e}", path.display()))?;
        Ok(Executable { exe })
    }

    /// Execute with literal inputs; returns the (single-device) output
    /// tuple decomposed into element literals.
    pub fn run(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let bufs = self.exe.execute::<xla::Literal>(inputs).map_err(|e| anyhow::anyhow!("{e}"))?;
        let lit = bufs[0][0].to_literal_sync().map_err(|e| anyhow::anyhow!("{e}"))?;
        lit.to_tuple().map_err(|e| anyhow::anyhow!("{e}"))
    }
}

/// All executables for one model variant.
pub struct ModelRuntime {
    /// Variant metadata from the manifest.
    pub meta: VariantMeta,
    /// Gossip stack fanout K of the gossip artifact.
    pub gossip_fanout: usize,
    client: xla::PjRtClient,
    train: Executable,
    evals: Executable,
    gossip: Executable,
}

impl ModelRuntime {
    /// Load the manifest in `dir` and compile the three executables for
    /// `variant`.
    pub fn load(dir: &Path, variant: &str) -> Result<Self> {
        let manifest = Manifest::load(&dir.join("manifest.json"))
            .context("loading manifest (run `make artifacts`)")?;
        let meta = manifest
            .variants
            .get(variant)
            .ok_or_else(|| anyhow::anyhow!("variant {variant} not in manifest"))?
            .clone();
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow::anyhow!("{e}"))?;
        let train = Executable::load(&client, &dir.join(&meta.files["train"]))?;
        let evals = Executable::load(&client, &dir.join(&meta.files["eval"]))?;
        let gossip = Executable::load(&client, &dir.join(&meta.gossip_file))?;
        Ok(ModelRuntime {
            meta,
            gossip_fanout: manifest.gossip_fanout,
            client,
            train,
            evals,
            gossip,
        })
    }

    /// Path helper: `ModelRuntime::load(Path::new("artifacts"), …)`.
    pub fn load_default(variant: &str) -> Result<Self> {
        Self::load(&PathBuf::from("artifacts"), variant)
    }

    fn input_literals(&self, flat: &[f32], x: &BatchInput, y: &[i32]) -> Result<Vec<xla::Literal>> {
        anyhow::ensure!(
            flat.len() == self.meta.padded_dim,
            "flat params {} != padded_dim {}",
            flat.len(),
            self.meta.padded_dim
        );
        let x_lit = match x {
            BatchInput::Features(f) => {
                anyhow::ensure!(self.meta.input_dtype == "f32", "variant expects tokens");
                literal_f32(&self.meta.input_shape, f)
            }
            BatchInput::Tokens(t) => {
                anyhow::ensure!(self.meta.input_dtype == "i32", "variant expects features");
                literal_i32(&self.meta.input_shape, t)
            }
        };
        let y_lit = literal_i32(&self.meta.label_shape, y);
        Ok(vec![literal_f32(&[self.meta.padded_dim], flat), x_lit, y_lit])
    }

    /// One local SGD gradient step: `(loss, grads, correct)`.
    pub fn train_step(&self, flat: &[f32], x: &BatchInput, y: &[i32]) -> Result<TrainOutput> {
        let inputs = self.input_literals(flat, x, y)?;
        let out = self.train.run(&inputs)?;
        anyhow::ensure!(out.len() == 3, "train output arity {}", out.len());
        let loss = out[0].get_first_element::<f32>().map_err(|e| anyhow::anyhow!("{e}"))?;
        let grad = out[1].to_vec::<f32>().map_err(|e| anyhow::anyhow!("{e}"))?;
        let correct = out[2].get_first_element::<i32>().map_err(|e| anyhow::anyhow!("{e}"))?;
        Ok(TrainOutput { loss, grad, correct })
    }

    /// Evaluate a parameter vector on one batch: `(loss, correct)`.
    pub fn eval_step(&self, flat: &[f32], x: &BatchInput, y: &[i32]) -> Result<(f32, i32)> {
        let inputs = self.input_literals(flat, x, y)?;
        let out = self.evals.run(&inputs)?;
        anyhow::ensure!(out.len() == 2, "eval output arity {}", out.len());
        let loss = out[0].get_first_element::<f32>().map_err(|e| anyhow::anyhow!("{e}"))?;
        let correct = out[1].get_first_element::<i32>().map_err(|e| anyhow::anyhow!("{e}"))?;
        Ok((loss, correct))
    }

    /// Metropolis-weighted average of up to `gossip_fanout` parameter
    /// vectors via the Pallas gossip kernel.  `rows` and `weights` shorter
    /// than the fanout are zero-padded (zero rows contribute nothing).
    pub fn gossip_average(&self, rows: &[&[f32]], weights: &[f32]) -> Result<Vec<f32>> {
        let k = self.gossip_fanout;
        let d = self.meta.padded_dim;
        anyhow::ensure!(rows.len() == weights.len(), "rows/weights mismatch");
        anyhow::ensure!(rows.len() <= k, "group {} exceeds fanout {k}", rows.len());
        let mut stack = vec![0f32; k * d];
        for (r, row) in rows.iter().enumerate() {
            anyhow::ensure!(row.len() == d, "row {} len {} != {d}", r, row.len());
            stack[r * d..(r + 1) * d].copy_from_slice(row);
        }
        let mut w = vec![0f32; k];
        w[..weights.len()].copy_from_slice(weights);
        let out = self
            .gossip
            .run(&[literal_f32(&[k, d], &stack), literal_f32(&[k], &w)])?;
        anyhow::ensure!(out.len() == 1, "gossip output arity {}", out.len());
        out[0].to_vec::<f32>().map_err(|e| anyhow::anyhow!("{e}"))
    }

    /// Underlying PJRT platform name (diagnostics).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }
}
