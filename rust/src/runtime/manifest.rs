//! `artifacts/manifest.json` schema (written by `python -m compile.aot`),
//! parsed with the in-tree JSON substrate.

use crate::util::json::Json;
use anyhow::{ensure, Context, Result};
use std::collections::HashMap;
use std::path::Path;

/// Per-variant artifact metadata.
#[derive(Debug, Clone)]
pub struct VariantMeta {
    /// `"mlp"` or `"transformer"`.
    pub kind: String,
    /// True parameter count.
    pub dim: usize,
    /// Flat-vector length (padded to the gossip tile multiple).
    pub padded_dim: usize,
    /// Batch size the artifact was lowered for.
    pub batch: usize,
    /// Classification classes (== vocab for LM variants).
    pub num_classes: usize,
    /// Batch input shape, e.g. `[32, 128]`.
    pub input_shape: Vec<usize>,
    /// `"f32"` (features) or `"i32"` (tokens).
    pub input_dtype: String,
    /// Label shape, e.g. `[32]` or `[16, 64]`.
    pub label_shape: Vec<usize>,
    /// MLP input feature dimension (0 for LM variants).
    pub input_dim: usize,
    /// LM sequence length (0 for MLP variants).
    pub seq_len: usize,
    /// LM vocabulary (0 for MLP variants).
    pub vocab: usize,
    /// Role -> HLO file name (`train`, `eval`).
    pub files: HashMap<String, String>,
    /// Gossip artifact file for this variant's padded_dim.
    pub gossip_file: String,
    /// Ordered (name, shape) parameter layout.
    pub layout: Vec<(String, Vec<usize>)>,
}

/// Top-level manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    /// Schema tag, `"hlo-text/v1"`.
    pub format: String,
    /// Max gossip stack rows K in the gossip artifacts.
    pub gossip_fanout: usize,
    /// Model variants by name.
    pub variants: HashMap<String, VariantMeta>,
    /// padded_dim (stringified) -> gossip artifact file.
    pub gossip: HashMap<String, String>,
}

fn shape_of(j: &Json, key: &str) -> Result<Vec<usize>> {
    j.req(key)?
        .as_arr()
        .with_context(|| format!("{key} must be an array"))?
        .iter()
        .map(|v| v.as_usize().with_context(|| format!("{key} entries must be integers")))
        .collect()
}

fn str_of(j: &Json, key: &str) -> Result<String> {
    Ok(j.req(key)?.as_str().with_context(|| format!("{key} must be a string"))?.to_string())
}

fn usize_of(j: &Json, key: &str) -> Result<usize> {
    j.req(key)?.as_usize().with_context(|| format!("{key} must be an integer"))
}

impl VariantMeta {
    // pallas-lint: allow(strict-config-parse) — artifact manifest from the Python AOT pipeline; newer pipelines may add forward-compatible keys
    fn from_json(j: &Json) -> Result<Self> {
        let files = j
            .req("files")?
            .as_obj()
            .context("files must be an object")?
            .iter()
            .map(|(k, v)| Ok((k.clone(), v.as_str().context("file names")?.to_string())))
            .collect::<Result<HashMap<_, _>>>()?;
        let layout = j
            .req("layout")?
            .as_arr()
            .context("layout must be an array")?
            .iter()
            .map(|entry| {
                let pair = entry.as_arr().context("layout entry")?;
                ensure!(pair.len() == 2, "layout entry must be [name, shape]");
                let name = pair[0].as_str().context("layout name")?.to_string();
                let shape = pair[1]
                    .as_arr()
                    .context("layout shape")?
                    .iter()
                    .map(|v| v.as_usize().context("layout dims"))
                    .collect::<Result<Vec<_>>>()?;
                Ok((name, shape))
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(VariantMeta {
            kind: str_of(j, "kind")?,
            dim: usize_of(j, "dim")?,
            padded_dim: usize_of(j, "padded_dim")?,
            batch: usize_of(j, "batch")?,
            num_classes: usize_of(j, "num_classes")?,
            input_shape: shape_of(j, "input_shape")?,
            input_dtype: str_of(j, "input_dtype")?,
            label_shape: shape_of(j, "label_shape")?,
            input_dim: usize_of(j, "input_dim")?,
            seq_len: usize_of(j, "seq_len")?,
            vocab: usize_of(j, "vocab")?,
            gossip_file: str_of(j, "gossip_file")?,
            files,
            layout,
        })
    }
}

impl Manifest {
    /// Load and validate from a JSON file.
    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("read {}: {e}", path.display()))?;
        let j = Json::parse(&text)?;
        let format = str_of(&j, "format")?;
        ensure!(format == "hlo-text/v1", "unknown manifest format {format}");
        let gossip_fanout = usize_of(&j, "gossip_fanout")?;
        let variants = j
            .req("variants")?
            .as_obj()
            .context("variants must be an object")?
            .iter()
            .map(|(name, v)| {
                Ok((
                    name.clone(),
                    VariantMeta::from_json(v).with_context(|| format!("variant {name}"))?,
                ))
            })
            .collect::<Result<HashMap<_, _>>>()?;
        let gossip = j
            .req("gossip")?
            .as_obj()
            .context("gossip must be an object")?
            .iter()
            .map(|(k, v)| Ok((k.clone(), v.as_str().context("gossip file")?.to_string())))
            .collect::<Result<HashMap<_, _>>>()?;
        Ok(Manifest { format, gossip_fanout, variants, gossip })
    }

    /// Layout converted to the model module's entry type.
    pub fn layout_of(&self, variant: &str) -> Option<Vec<crate::model::LayoutEntry>> {
        self.variants.get(variant).map(|v| {
            v.layout
                .iter()
                .map(|(name, shape)| crate::model::LayoutEntry {
                    name: name.clone(),
                    shape: shape.clone(),
                })
                .collect()
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
        "format": "hlo-text/v1",
        "gossip_fanout": 8,
        "variants": {
            "mlp_tiny": {
                "kind": "mlp", "dim": 1754, "padded_dim": 1792,
                "batch": 16, "num_classes": 10,
                "input_shape": [16, 32], "input_dtype": "f32",
                "label_shape": [16], "input_dim": 32, "seq_len": 0, "vocab": 0,
                "files": {"train": "t.hlo.txt", "eval": "e.hlo.txt"},
                "gossip_file": "g.hlo.txt",
                "layout": [["w0", [32, 32]], ["b0", [32]]]
            }
        },
        "gossip": {"1792": "g.hlo.txt"}
    }"#;

    fn write_tmp(text: &str) -> std::path::PathBuf {
        let p = std::env::temp_dir().join(format!("dsgd_manifest_{}.json", std::process::id()));
        std::fs::write(&p, text).unwrap();
        p
    }

    #[test]
    fn parses_sample() {
        let p = write_tmp(SAMPLE);
        let m = Manifest::load(&p).unwrap();
        assert_eq!(m.gossip_fanout, 8);
        let v = &m.variants["mlp_tiny"];
        assert_eq!(v.padded_dim, 1792);
        assert_eq!(v.layout[0].0, "w0");
        assert_eq!(v.layout[0].1, vec![32, 32]);
        assert_eq!(v.files["train"], "t.hlo.txt");
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn layout_conversion() {
        let p = write_tmp(SAMPLE);
        let m = Manifest::load(&p).unwrap();
        let layout = m.layout_of("mlp_tiny").unwrap();
        assert_eq!(layout[0].numel(), 1024);
        assert!(m.layout_of("nope").is_none());
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn rejects_wrong_format() {
        let p = write_tmp(r#"{"format": "v2", "gossip_fanout": 1, "variants": {}, "gossip": {}}"#);
        assert!(Manifest::load(&p).is_err());
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn real_manifest_if_present() {
        let p = Path::new("artifacts/manifest.json");
        if p.exists() {
            let m = Manifest::load(p).unwrap();
            assert!(m.variants.contains_key("mlp_tiny"));
            for v in m.variants.values() {
                assert!(v.padded_dim % 256 == 0);
                assert!(v.dim <= v.padded_dim);
            }
        }
    }
}
