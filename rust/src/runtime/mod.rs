//! PJRT runtime: load and execute the AOT-compiled JAX/Pallas artifacts.
//!
//! `make artifacts` produces `artifacts/manifest.json` plus one HLO-text
//! file per (model variant, role) and per gossip dimension.  With the
//! `pjrt` feature enabled, [`pjrt_impl`] compiles them once on a CPU PJRT
//! client via the vendored `xla` bindings and exposes typed entry points
//! used from the training hot loop — Python never runs at training time.
//! Without the feature (the offline default) a stub with the same surface
//! reports the runtime as unavailable; manifest parsing stays native.

mod manifest;

pub use manifest::{Manifest, VariantMeta};

#[cfg(feature = "pjrt")]
mod pjrt_impl;
#[cfg(feature = "pjrt")]
pub use pjrt_impl::{literal_f32, literal_i32, Executable, ModelRuntime};

#[cfg(not(feature = "pjrt"))]
mod stub;
#[cfg(not(feature = "pjrt"))]
pub use stub::ModelRuntime;

/// Output of one AOT train step.
#[derive(Debug, Clone)]
pub struct TrainOutput {
    /// Mean cross-entropy over the batch.
    pub loss: f32,
    /// Flat gradient, padded to `padded_dim`.
    pub grad: Vec<f32>,
    /// Correct argmax predictions in the batch.
    pub correct: i32,
}

/// Batch input: MLP variants take f32 features, LM variants i32 tokens.
#[derive(Debug, Clone)]
pub enum BatchInput<'a> {
    /// `[batch * input_dim]` row-major features.
    Features(&'a [f32]),
    /// `[batch * seq_len]` token ids.
    Tokens(&'a [i32]),
}
