//! PJRT runtime stub: compiled when the `pjrt` feature is off (the
//! default in the offline container, which lacks the vendored `xla`
//! bindings).  The API mirrors [`super::pjrt_impl`]'s `ModelRuntime` so
//! the PJRT backend type-checks; every entry point reports that the
//! runtime is unavailable.  The native_mlp and quadratic backends cover
//! the full test/bench surface without it.

use super::{BatchInput, TrainOutput, VariantMeta};
use anyhow::{bail, Result};
use std::path::{Path, PathBuf};

/// Stub of the PJRT model runtime; [`ModelRuntime::load`] always errors.
pub struct ModelRuntime {
    /// Variant metadata (never populated — load always fails).
    pub meta: VariantMeta,
    /// Gossip stack fanout (never populated).
    pub gossip_fanout: usize,
}

impl ModelRuntime {
    /// Always errors: built without the `pjrt` feature.
    pub fn load(_dir: &Path, _variant: &str) -> Result<Self> {
        bail!(
            "built without the `pjrt` feature: the xla/PJRT runtime is \
             unavailable (use backend = native_mlp or quadratic, or rebuild \
             with --features pjrt on the full toolchain image)"
        )
    }

    /// Path helper matching the real runtime.
    pub fn load_default(variant: &str) -> Result<Self> {
        Self::load(&PathBuf::from("artifacts"), variant)
    }

    /// Unreachable (no instance can be constructed).
    pub fn train_step(&self, _flat: &[f32], _x: &BatchInput, _y: &[i32]) -> Result<TrainOutput> {
        bail!("pjrt feature disabled")
    }

    /// Unreachable (no instance can be constructed).
    pub fn eval_step(&self, _flat: &[f32], _x: &BatchInput, _y: &[i32]) -> Result<(f32, i32)> {
        bail!("pjrt feature disabled")
    }

    /// Unreachable (no instance can be constructed).
    pub fn gossip_average(&self, _rows: &[&[f32]], _weights: &[f32]) -> Result<Vec<f32>> {
        bail!("pjrt feature disabled")
    }

    /// Platform label for logs.
    pub fn platform(&self) -> String {
        "pjrt-disabled".to_string()
    }
}
