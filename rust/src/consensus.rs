//! Metropolis consensus weights (paper Assumption 1, eq. 6).
//!
//! For a gossip group `S` at iteration k, the active communication graph is
//! the subgraph of `G` induced on `S`; the Metropolis rule assigns
//!
//! ```text
//! P_ij = 1 / (1 + max(p_i, p_j))    if (i,j) active,
//! P_ii = 1 - Σ_{j≠i} P_ij,
//! ```
//!
//! where `p_i` is the number of active neighbors worker i waits on.  The
//! resulting matrix is symmetric and doubly stochastic, which is what the
//! convergence proof (Lemma 1/2) requires of every `P(k)`.

use crate::topology::Graph;
use crate::WorkerId;

/// Consensus weights for one gossip group: for each member, the weight it
/// assigns to every member (including itself).  Row-indexed by position in
/// `members`.
#[derive(Debug, Clone)]
pub struct GroupWeights {
    /// Group members in ascending WorkerId order.
    pub members: Vec<WorkerId>,
    /// `weights[a][b]` = P_{members[b], members[a]}: contribution of member
    /// b's parameters to member a's update.  Symmetric.
    pub weights: Vec<Vec<f32>>,
}

impl GroupWeights {
    /// Metropolis weights on the subgraph of `g` induced on `members`.
    ///
    /// Members with no active neighbor inside the group get weight 1 on
    /// themselves (they keep their parameters — a degenerate but valid
    /// doubly-stochastic row).
    pub fn metropolis(g: &Graph, members: &[WorkerId]) -> Self {
        let mut members: Vec<WorkerId> = members.to_vec();
        members.sort_unstable();
        members.dedup();
        let m = members.len();

        // Probe each pair exactly once (hash lookups dominate this path —
        // see EXPERIMENTS.md §Perf) and keep the adjacency for both passes.
        let mut adj = vec![false; m * m];
        let mut active_deg = vec![0usize; m];
        for a in 0..m {
            for b in (a + 1)..m {
                if g.has_edge(members[a], members[b]) {
                    adj[a * m + b] = true;
                    active_deg[a] += 1;
                    active_deg[b] += 1;
                }
            }
        }

        let mut w = vec![vec![0f32; m]; m];
        for a in 0..m {
            for b in (a + 1)..m {
                if adj[a * m + b] {
                    let v = 1.0 / (1.0 + active_deg[a].max(active_deg[b]) as f32);
                    w[a][b] = v;
                    w[b][a] = v;
                }
            }
        }
        for a in 0..m {
            let off: f32 = w[a].iter().sum();
            w[a][a] = 1.0 - off;
        }
        GroupWeights { members, weights: w }
    }

    /// Incrementally recompute the rows of the listed member workers
    /// against the live graph (ids not in the group are ignored).
    ///
    /// Caller contract (membership join/leave maintenance): `touched`
    /// must contain every member whose induced degree changed — the
    /// mutated worker and its old/new neighbors — **plus their
    /// neighbors**, whose off-diagonal entries reference the changed
    /// degrees.  Under that contract the result is bitwise identical to
    /// a from-scratch [`Self::metropolis`] over the same members: the
    /// per-entry formula, f32 summation order, and diagonal fix-up are
    /// replicated exactly, and every entry outside the touched rows is
    /// provably unchanged (both endpoint degrees are unchanged).
    ///
    /// Cost is O(|touched| · m) entry updates plus one O(active edges)
    /// degree pass — not the O(m²) pair probe of a full rebuild.
    pub fn refresh_rows(&mut self, g: &Graph, touched: &[WorkerId]) {
        let m = self.members.len();
        // Current within-group degrees from the live graph (equals the
        // pair-probe degrees of `metropolis` by symmetry of `has_edge`).
        let mut active_deg = vec![0usize; m];
        for (a, &wa) in self.members.iter().enumerate() {
            active_deg[a] =
                g.neighbors(wa).iter().filter(|x| self.members.binary_search(x).is_ok()).count();
        }
        let mut rows: Vec<usize> =
            touched.iter().filter_map(|w| self.members.binary_search(w).ok()).collect();
        rows.sort_unstable();
        rows.dedup();
        for &a in &rows {
            let mut row = vec![0f32; m];
            for (b, &wb) in self.members.iter().enumerate() {
                if b != a && g.has_edge(self.members[a], wb) {
                    row[b] = 1.0 / (1.0 + active_deg[a].max(active_deg[b]) as f32);
                }
            }
            let off: f32 = row.iter().sum();
            row[a] = 1.0 - off;
            // mirror into untouched rows' columns; under the caller
            // contract any actually-changed entry has its owner row in
            // `rows` too, so this only rewrites identical values there
            for b in 0..m {
                self.weights[b][a] = row[b];
            }
            self.weights[a] = row;
        }
        debug_assert!(
            self.stochasticity_error() < 1e-4,
            "refresh_rows broke double stochasticity — touched set too small"
        );
    }

    /// Pairwise averaging (AD-PSGD style): both members weight 1/2.
    pub fn pairwise(i: WorkerId, j: WorkerId) -> Self {
        let members = if i < j { vec![i, j] } else { vec![j, i] };
        GroupWeights { members, weights: vec![vec![0.5, 0.5], vec![0.5, 0.5]] }
    }

    /// Uniform all-to-all averaging (Prague's partial all-reduce inside a
    /// group): every member weight 1/m.
    pub fn uniform(members: &[WorkerId]) -> Self {
        let mut members: Vec<WorkerId> = members.to_vec();
        members.sort_unstable();
        members.dedup();
        let m = members.len();
        let v = 1.0 / m as f32;
        GroupWeights { members, weights: vec![vec![v; m]; m] }
    }

    /// Group size.
    pub fn len(&self) -> usize {
        self.members.len()
    }

    /// True when the group has no members at all.  A *singleton* group is
    /// not empty — use [`Self::is_singleton`] to test for the
    /// one-worker case where gossip is a no-op.
    pub fn is_empty(&self) -> bool {
        self.members.is_empty()
    }

    /// True when the group is a single worker: gossip moves nothing, so
    /// the engine's gossip paths early-out without charging bytes.
    pub fn is_singleton(&self) -> bool {
        self.members.len() == 1
    }

    /// Max |row sum − 1| and |col sum − 1|: 0 for doubly stochastic.
    pub fn stochasticity_error(&self) -> f32 {
        let m = self.len();
        let mut err = 0f32;
        for a in 0..m {
            let row: f32 = self.weights[a].iter().sum();
            err = err.max((row - 1.0).abs());
            let col: f32 = (0..m).map(|b| self.weights[b][a]).sum();
            err = err.max((col - 1.0).abs());
        }
        err
    }

    /// Smallest strictly-positive entry (the paper's β, which lower-bounds
    /// the product-matrix entries via Lemma 2).
    pub fn min_positive(&self) -> f32 {
        self.weights
            .iter()
            .flatten()
            .copied()
            .filter(|&v| v > 0.0)
            .fold(f32::INFINITY, f32::min)
    }

    /// Number of active (positive-weight) undirected pairs — the edges
    /// parameter messages actually traverse.  Metropolis weights are zero
    /// between non-adjacent members, so this equals the induced-subgraph
    /// edge count; for uniform (all-reduce) groups it is m(m-1)/2.
    pub fn active_edges(&self) -> usize {
        let m = self.len();
        let mut count = 0;
        for a in 0..m {
            for b in (a + 1)..m {
                if self.weights[a][b] > 0.0 {
                    count += 1;
                }
            }
        }
        count
    }

    /// Whether every entry is non-negative (Assumption 1's "non-negative
    /// Metropolis weight rule"; can fail only for adversarial inputs).
    pub fn is_non_negative(&self) -> bool {
        self.weights.iter().flatten().all(|&v| v >= -1e-7)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::generators::{complete, random_connected, ring};

    #[test]
    fn metropolis_doubly_stochastic_ring() {
        let g = ring(6);
        let gw = GroupWeights::metropolis(&g, &[0, 1, 2, 3, 4, 5]);
        assert!(gw.stochasticity_error() < 1e-6);
        assert!(gw.is_non_negative());
    }

    #[test]
    fn metropolis_partial_group() {
        // group {0,1,3} on a ring of 6: only edge (0,1) is active
        let g = ring(6);
        let gw = GroupWeights::metropolis(&g, &[0, 1, 3]);
        assert!(gw.stochasticity_error() < 1e-6);
        // p_0 = p_1 = 1 -> P_01 = 1/2
        assert!((gw.weights[0][1] - 0.5).abs() < 1e-6);
        // worker 3 is isolated inside the group: keeps itself
        assert!((gw.weights[2][2] - 1.0).abs() < 1e-6);
    }

    #[test]
    fn metropolis_symmetric() {
        let g = random_connected(12, 0.3, 3);
        let gw = GroupWeights::metropolis(&g, &(0..12).collect::<Vec<_>>());
        for a in 0..12 {
            for b in 0..12 {
                assert!((gw.weights[a][b] - gw.weights[b][a]).abs() < 1e-7);
            }
        }
    }

    #[test]
    fn metropolis_complete_is_uniformish() {
        let g = complete(4);
        let gw = GroupWeights::metropolis(&g, &[0, 1, 2, 3]);
        // all degrees 3 -> off-diagonals 1/4, diagonal 1/4
        for a in 0..4 {
            for b in 0..4 {
                assert!((gw.weights[a][b] - 0.25).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn pairwise_is_half_half() {
        let gw = GroupWeights::pairwise(5, 2);
        assert_eq!(gw.members, vec![2, 5]);
        assert!(gw.stochasticity_error() < 1e-7);
        assert!((gw.weights[0][1] - 0.5).abs() < 1e-7);
    }

    #[test]
    fn uniform_rows() {
        let gw = GroupWeights::uniform(&[3, 1, 2]);
        assert_eq!(gw.members, vec![1, 2, 3]);
        assert!(gw.stochasticity_error() < 1e-6);
        assert!((gw.min_positive() - 1.0 / 3.0).abs() < 1e-6);
    }

    #[test]
    fn dedup_members() {
        let gw = GroupWeights::uniform(&[1, 1, 2]);
        assert_eq!(gw.members, vec![1, 2]);
    }

    #[test]
    fn refresh_rows_matches_from_scratch_bitwise() {
        // vacate vertex 4 of a random graph: touched = {4} ∪ N(4) ∪ N(N(4))
        let mut g = random_connected(10, 0.35, 9);
        let all: Vec<WorkerId> = (0..10).collect();
        let mut gw = GroupWeights::metropolis(&g, &all);
        let nbrs: Vec<usize> = g.neighbors(4).to_vec();
        g.remove_vertex(4);
        let mut touched: Vec<WorkerId> = vec![4];
        touched.extend(&nbrs);
        for &x in &nbrs {
            touched.extend(g.neighbors(x));
        }
        gw.refresh_rows(&g, &touched);
        let fresh = GroupWeights::metropolis(&g, &all);
        for a in 0..10 {
            for b in 0..10 {
                assert_eq!(
                    gw.weights[a][b].to_bits(),
                    fresh.weights[a][b].to_bits(),
                    "entry ({a},{b}) diverged from from-scratch metropolis"
                );
            }
        }
        assert!(gw.stochasticity_error() < 1e-6);
    }

    #[test]
    fn refresh_rows_after_rejoin_with_new_edges() {
        // re-attach vertex 4 with a different edge set than it had
        let mut g = ring(8);
        let all: Vec<WorkerId> = (0..8).collect();
        let mut gw = GroupWeights::metropolis(&g, &all);
        g.remove_vertex(4);
        gw.refresh_rows(&g, &[2, 3, 4, 5, 6]);
        g.add_edge(4, 0);
        g.add_edge(4, 1);
        // touched: 4 and new neighbors {0,1} and their neighbors
        let mut touched = vec![4, 0, 1];
        for &x in &[0usize, 1] {
            touched.extend(g.neighbors(x));
        }
        gw.refresh_rows(&g, &touched);
        let fresh = GroupWeights::metropolis(&g, &all);
        for a in 0..8 {
            for b in 0..8 {
                assert_eq!(gw.weights[a][b].to_bits(), fresh.weights[a][b].to_bits());
            }
        }
        // unknown ids are ignored, not a panic
        gw.refresh_rows(&g, &[99]);
    }

    #[test]
    fn singleton_group_identity() {
        let g = ring(4);
        let gw = GroupWeights::metropolis(&g, &[2]);
        assert_eq!(gw.len(), 1);
        assert!((gw.weights[0][0] - 1.0).abs() < 1e-7);
        // a singleton is not "empty": is_empty means zero members
        assert!(gw.is_singleton());
        assert!(!gw.is_empty());
        let none = GroupWeights::uniform(&[]);
        assert!(none.members.is_empty() && none.is_empty() && !none.is_singleton());
    }
}
