//! Open-world membership: a sampled-participation population layer.
//!
//! The paper models a *closed* fleet of `n` workers.  Production
//! decentralized training is open-world: a population of 1e5–1e6 logical
//! users arrives and departs over time, and only a sampled slice occupies
//! the `n` bounded *active slots* the engine actually simulates at any
//! instant.  This module holds that population without materializing it —
//! every data structure is O(slots), never O(population):
//!
//! * the inactive population is a mean-field *fluid pool* advanced in
//!   closed form (`dp/dt = λ − μ·p`), so arrivals cost O(1) regardless
//!   of population size;
//! * active occupants are tracked per slot as minted logical user ids
//!   (a `u64` counter arena — no dense per-user parameter state exists);
//! * departures of active users fire from a single exponential thinning
//!   clock over the edge slots (per-occupied-slot hazard `μ`, thinned
//!   from the upper bound `μ·E`);
//! * every `round_interval` virtual seconds a `RoundSample` rotation
//!   re-samples which pool users occupy the edge slots, either uniformly
//!   or stickiness-weighted (each sitting occupant survives the rotation
//!   with probability `stickiness`);
//! * an optional two-tier hierarchy reserves the first `aggregators`
//!   slots as always-on hubs on a ring, with every edge slot starred
//!   onto one hub — edge users then route through intermediate
//!   aggregation nodes exactly as in hierarchical FL deployments.
//!
//! The engine consumes this model through three events
//! ([`WorkerJoin`](crate::sim::EventKind::WorkerJoin) /
//! [`WorkerLeave`](crate::sim::EventKind::WorkerLeave) /
//! [`RoundSample`](crate::sim::EventKind::RoundSample)): joiners
//! warm-start from the neighbor average of the slot they inherit, a
//! departure-clock leave retires its user (and that slot's parameters)
//! permanently, while a rotation leave merely returns the user to the
//! pool.  Vacant slots appear to the partition machinery as isolated
//! singleton components, which is why the `membership` config section
//! requires `adapt.partition_aware = true`: every update rule then
//! automatically scopes its waiting/barrier logic to the live active
//! components and tolerates mid-epoch departures.
//!
//! Trace-driven arrivals reuse the existing `trace/` ingestion: a lowered
//! Borg/Alibaba timeline replayed by [`crate::churn::ChurnModel`] emits
//! `Isolate`/`Attach` mutations which the engine routes through the same
//! leave/join paths (see `docs/scenarios.md`), so real REMOVE/ADD machine
//! events drive the open-world fleet instead of the Poisson processes.

use crate::topology::Graph;
use crate::util::json::Json;
use crate::util::Rng64;
use anyhow::{bail, Context, Result};
use std::collections::BTreeSet;

/// How the per-round participation sampler picks edge-slot occupants.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SamplingKind {
    /// Every rotation resamples all edge slots uniformly from the pool;
    /// sitting occupants return to the pool first (high turnover, the
    /// classical uniform-participation regime).
    Uniform,
    /// Each sitting occupant survives the rotation with probability
    /// `stickiness`; only the remainder is resampled from the pool
    /// (models device availability correlation across rounds).
    Sticky,
}

impl SamplingKind {
    /// Parse the config token (`"uniform"` or `"sticky"`).
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "uniform" => Ok(SamplingKind::Uniform),
            "sticky" => Ok(SamplingKind::Sticky),
            other => bail!("unknown membership.sampling {other:?} (expected uniform|sticky)"),
        }
    }

    /// The config token for this kind.
    pub fn token(&self) -> &'static str {
        match self {
            SamplingKind::Uniform => "uniform",
            SamplingKind::Sticky => "sticky",
        }
    }
}

/// Strict-parsed `membership` config section (open-world population).
#[derive(Debug, Clone, PartialEq)]
pub struct MembershipConfig {
    /// Logical population size (1e5–1e6 scale); only sets the initial
    /// fluid pool, so memory stays O(active slots).
    pub population: usize,
    /// Poisson birth rate λ (users/virtual-second) flowing into the pool.
    pub arrival_rate: f64,
    /// Per-user death hazard μ (1/virtual-second); drains both the pool
    /// (in fluid form) and active edge slots (via the thinning clock).
    pub departure_rate: f64,
    /// Virtual seconds between `RoundSample` participation rotations.
    pub round_interval: f64,
    /// Fraction of edge slots kept occupied by each rotation, in (0, 1].
    pub participation: f64,
    /// Participation sampler.
    pub sampling: SamplingKind,
    /// Per-round survival probability of a sitting occupant, in [0, 1);
    /// only used by [`SamplingKind::Sticky`].
    pub stickiness: f64,
    /// Number of always-on two-tier aggregator slots (0 = flat topology).
    pub aggregators: usize,
    /// Membership RNG seed override; `None` derives from the experiment
    /// seed via `seed_for("membership")`.
    pub seed: Option<u64>,
}

impl Default for MembershipConfig {
    fn default() -> Self {
        MembershipConfig {
            population: 100_000,
            arrival_rate: 0.0,
            departure_rate: 0.0,
            round_interval: 1.0,
            participation: 1.0,
            sampling: SamplingKind::Uniform,
            stickiness: 0.5,
            aggregators: 0,
            seed: None,
        }
    }
}

fn need_usize(key: &str, v: &Json) -> Result<usize> {
    v.as_usize().with_context(|| format!("membership.{key} must be a non-negative integer"))
}

fn need_f64(key: &str, v: &Json) -> Result<f64> {
    v.as_f64().with_context(|| format!("membership.{key} must be a number"))
}

impl MembershipConfig {
    /// Strict parse: unknown keys are errors, values are type-checked.
    pub fn from_json(v: &Json) -> Result<Self> {
        let obj = v.as_obj().context("membership section must be an object")?;
        let mut cfg = MembershipConfig::default();
        for (k, v) in obj {
            match k.as_str() {
                "population" => cfg.population = need_usize(k, v)?,
                "arrival_rate" => cfg.arrival_rate = need_f64(k, v)?,
                "departure_rate" => cfg.departure_rate = need_f64(k, v)?,
                "round_interval" => cfg.round_interval = need_f64(k, v)?,
                "participation" => cfg.participation = need_f64(k, v)?,
                "sampling" => {
                    let s = v.as_str().context("membership.sampling must be a string")?;
                    cfg.sampling = SamplingKind::parse(s)?;
                }
                "stickiness" => cfg.stickiness = need_f64(k, v)?,
                "aggregators" => cfg.aggregators = need_usize(k, v)?,
                "seed" => {
                    cfg.seed = match v {
                        Json::Null => None,
                        other => Some(
                            other.as_u64().context("membership.seed must be an integer or null")?,
                        ),
                    }
                }
                other => bail!("unknown membership config key {other:?}"),
            }
        }
        cfg.validate()?;
        Ok(cfg)
    }

    /// Serialize back to the canonical JSON object.
    pub fn to_json(&self) -> Json {
        let mut m = std::collections::BTreeMap::new();
        m.insert("population".into(), Json::from(self.population as f64));
        m.insert("arrival_rate".into(), Json::from(self.arrival_rate));
        m.insert("departure_rate".into(), Json::from(self.departure_rate));
        m.insert("round_interval".into(), Json::from(self.round_interval));
        m.insert("participation".into(), Json::from(self.participation));
        m.insert("sampling".into(), Json::Str(self.sampling.token().to_string()));
        m.insert("stickiness".into(), Json::from(self.stickiness));
        m.insert("aggregators".into(), Json::from(self.aggregators as f64));
        if let Some(s) = self.seed {
            m.insert("seed".into(), Json::from(s as f64));
        }
        Json::Obj(m)
    }

    /// Range checks local to the section (cross-section rules live in
    /// [`crate::config::ExperimentConfig::validate`]).
    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(self.population >= 1, "membership.population must be >= 1");
        anyhow::ensure!(
            self.arrival_rate.is_finite() && self.arrival_rate >= 0.0,
            "membership.arrival_rate must be finite and >= 0"
        );
        anyhow::ensure!(
            self.departure_rate.is_finite() && self.departure_rate >= 0.0,
            "membership.departure_rate must be finite and >= 0"
        );
        anyhow::ensure!(
            self.round_interval.is_finite() && self.round_interval > 0.0,
            "membership.round_interval must be finite and > 0"
        );
        anyhow::ensure!(
            self.participation > 0.0 && self.participation <= 1.0,
            "membership.participation must be in (0, 1]"
        );
        anyhow::ensure!(
            (0.0..1.0).contains(&self.stickiness),
            "membership.stickiness must be in [0, 1)"
        );
        Ok(())
    }
}

/// Slot changes committed by one `RoundSample` rotation.  The model has
/// already updated its occupancy when this is returned; the engine turns
/// each entry into a `WorkerLeave`/`WorkerJoin` event at the same
/// timestamp (leaves first) so rule hooks observe an ordered stream.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct RoundOutcome {
    /// Edge slots vacated this rotation (occupants returned to the pool).
    pub leaves: Vec<usize>,
    /// Edge slots filled this rotation (pool users promoted to active).
    pub joins: Vec<usize>,
}

/// The open-world population model: fluid pool + slot occupancy arena +
/// departure thinning clock + rotation schedule.  All state is O(slots).
#[derive(Debug, Clone)]
pub struct MembershipModel {
    cfg: MembershipConfig,
    n: usize,
    rng: Rng64,
    /// Mean-field inactive population (fractional users are fine — only
    /// `floor(pool)` can be promoted at any instant).
    pool: f64,
    /// Last virtual time the pool ODE was advanced to.
    last_advance: f64,
    /// Next logical user id to mint (ids are never reused: a retired id
    /// is gone forever, a pooled user gets a fresh id on re-promotion —
    /// the pool is anonymous by mean-field construction).
    next_uid: u64,
    /// Per-slot occupant (logical user id), `None` when vacant.
    occupant: Vec<Option<u64>>,
    /// Users permanently retired by the departure clock.
    retired: u64,
    /// Pending departure-clock sample: (fire time, edge slot, occupant
    /// uid at draw time — `None` means the slot was vacant at draw and
    /// the event is a thinned no-op).
    next_departure: Option<(f64, usize, Option<u64>)>,
    /// Next `RoundSample` fire time.
    next_round: f64,
    /// Rotation leaves committed but not yet consumed by the engine.
    pending_leave: BTreeSet<usize>,
    /// Rotation joins committed but not yet consumed by the engine.
    pending_join: BTreeSet<usize>,
}

impl MembershipModel {
    /// Build the model for `num_workers` active slots and fill the
    /// initial occupancy: all aggregator slots plus
    /// `ceil(participation · E)` seeded-random edge slots.
    pub fn from_config(cfg: &MembershipConfig, num_workers: usize, seed: u64) -> Result<Self> {
        anyhow::ensure!(num_workers >= 1, "membership requires at least one worker slot");
        anyhow::ensure!(
            cfg.aggregators < num_workers,
            "membership.aggregators ({}) must be < num_workers ({num_workers})",
            cfg.aggregators
        );
        anyhow::ensure!(
            cfg.population >= num_workers,
            "membership.population ({}) must be >= num_workers ({num_workers})",
            cfg.population
        );
        let mut rng = Rng64::seed_from_u64(cfg.seed.unwrap_or(seed));
        let mut occupant = vec![None; num_workers];
        let mut minted = 0u64;
        for slot in occupant.iter_mut().take(cfg.aggregators) {
            *slot = Some(minted);
            minted += 1;
        }
        let edge_slots: Vec<usize> = (cfg.aggregators..num_workers).collect();
        let target = Self::target_for(cfg.participation, edge_slots.len());
        for s in rng.sample(&edge_slots, target) {
            occupant[s] = Some(minted);
            minted += 1;
        }
        let pool = cfg.population as f64 - minted as f64;
        Ok(MembershipModel {
            cfg: cfg.clone(),
            n: num_workers,
            rng,
            pool,
            last_advance: 0.0,
            next_uid: minted,
            occupant,
            retired: 0,
            next_departure: None,
            next_round: cfg.round_interval,
            pending_leave: BTreeSet::new(),
            pending_join: BTreeSet::new(),
        })
    }

    /// Rotation target: `ceil(participation · E)`, clamped to `[1, E]`.
    fn target_for(participation: f64, edge_count: usize) -> usize {
        ((participation * edge_count as f64).ceil() as usize).clamp(1, edge_count.max(1))
    }

    /// Number of edge (non-aggregator) slots.
    fn edge_count(&self) -> usize {
        self.n - self.cfg.aggregators
    }

    /// Whether `slot` currently holds a user.
    pub fn is_occupied(&self, slot: usize) -> bool {
        self.occupant[slot].is_some()
    }

    /// Occupied slot count (aggregators included).
    pub fn occupied_count(&self) -> usize {
        self.occupant.iter().filter(|o| o.is_some()).count()
    }

    /// Current fluid pool size (inactive population).
    pub fn pool(&self) -> f64 {
        self.pool
    }

    /// Users permanently retired by the departure clock so far.
    pub fn retired(&self) -> u64 {
        self.retired
    }

    /// Slots left vacant by the initial fill — the engine vacates these
    /// through its normal leave path before the run starts.
    pub fn initially_vacant(&self) -> Vec<usize> {
        (0..self.n).filter(|&s| self.occupant[s].is_none()).collect()
    }

    /// Two-tier hierarchical topology when `aggregators > 0`: a ring over
    /// the aggregator slots (a single pair becomes one edge) plus a star
    /// edge from every edge slot to `slot % aggregators`.  `None` for the
    /// flat case — the engine then uses the configured topology.
    pub fn build_graph(&self) -> Option<Graph> {
        let a = self.cfg.aggregators;
        if a == 0 {
            return None;
        }
        let mut g = Graph::empty(self.n);
        for i in 0..a {
            g.add_edge(i, (i + 1) % a); // self-loop/dup-safe for a <= 2
        }
        for w in a..self.n {
            g.add_edge(w, w % a);
        }
        Some(g)
    }

    /// Advance the fluid pool ODE `dp/dt = λ − μ·p` to `now` using the
    /// exact solution `p(t+dt) = λ/μ + (p − λ/μ)·e^(−μ·dt)` (or linear
    /// growth when μ = 0).  O(1) per call, deterministic.
    fn advance_pool(&mut self, now: f64) {
        let dt = now - self.last_advance;
        if dt <= 0.0 {
            return;
        }
        let (lam, mu) = (self.cfg.arrival_rate, self.cfg.departure_rate);
        self.pool = if mu > 0.0 {
            let eq = lam / mu;
            eq + (self.pool - eq) * (-mu * dt).exp()
        } else {
            self.pool + lam * dt
        };
        self.last_advance = now;
    }

    /// Draw the next departure-clock sample after `now` and return its
    /// fire time and slot for the engine to schedule, or `None` when
    /// μ = 0 (no clock).  Thinning: the clock runs at the upper bound
    /// `μ·E` and picks a uniform edge slot; if that slot's occupant
    /// changed (or was vacant) by fire time, the event is a no-op.
    pub fn schedule_departure(&mut self, now: f64) -> Option<(f64, usize)> {
        let mu = self.cfg.departure_rate;
        let e = self.edge_count();
        if mu <= 0.0 || e == 0 {
            self.next_departure = None;
            return None;
        }
        let t = now + self.rng.exponential(1.0 / (mu * e as f64));
        let slot = self.cfg.aggregators + self.rng.gen_range(e);
        self.next_departure = Some((t, slot, self.occupant[slot]));
        Some((t, slot))
    }

    /// Handle a `WorkerLeave(slot)` event at `now`.  Returns
    /// `(proceed, next_clock)`: `proceed` is whether the engine should
    /// actually vacate the slot, and `next_clock` is the redrawn
    /// departure sample to schedule (departure-clock events only).
    ///
    /// A rotation leave (pre-committed by [`Self::fire_round`]) always
    /// proceeds.  A departure-clock leave proceeds only if the recorded
    /// occupant still sits in the slot (thinning) and vacating it would
    /// not silence the whole engine (at least one active slot survives).
    pub fn on_leave_event(&mut self, slot: usize, now: f64) -> (bool, Option<(f64, usize)>) {
        if self.pending_leave.remove(&slot) {
            return (true, None);
        }
        let recorded = match self.next_departure {
            Some((t, s, uid)) if s == slot && t <= now => uid,
            _ => None, // stale or mismatched event: thinned no-op
        };
        self.advance_pool(now);
        let valid = recorded.is_some()
            && self.occupant[slot] == recorded
            && self.occupied_count() > 1;
        if valid {
            self.occupant[slot] = None;
            self.retired += 1;
        }
        (valid, self.schedule_departure(now))
    }

    /// Handle a `WorkerJoin(slot)` event: proceeds iff the join was
    /// pre-committed by [`Self::fire_round`].
    pub fn on_join_event(&mut self, slot: usize) -> bool {
        self.pending_join.remove(&slot)
    }

    /// Next `RoundSample` fire time (drift-free fixed grid).
    pub fn next_round_time(&self) -> f64 {
        self.next_round
    }

    /// Fire the participation rotation at `now`: commit occupancy
    /// atomically and return the slot deltas for the engine to replay as
    /// events.  Sitting edge occupants either survive (sticky) or return
    /// to the pool (uniform); vacancies up to the participation target
    /// are refilled from the pool while it has whole users left.
    pub fn fire_round(&mut self, now: f64) -> RoundOutcome {
        self.advance_pool(now);
        self.next_round += self.cfg.round_interval;
        let a = self.cfg.aggregators;
        let target = Self::target_for(self.cfg.participation, self.edge_count());

        let mut kept: Vec<usize> = Vec::new();
        let mut leaves: Vec<usize> = Vec::new();
        for s in a..self.n {
            if self.occupant[s].is_none() {
                continue;
            }
            let survive = match self.cfg.sampling {
                SamplingKind::Uniform => false,
                SamplingKind::Sticky => self.rng.gen_bool(self.cfg.stickiness),
            };
            if survive && kept.len() < target {
                kept.push(s);
            } else {
                leaves.push(s);
            }
        }
        // Rotation leaves return to the pool (only the departure clock
        // retires users permanently).
        for &s in &leaves {
            self.occupant[s] = None;
            self.pool += 1.0;
        }
        let vacant: Vec<usize> = (a..self.n).filter(|&s| self.occupant[s].is_none()).collect();
        let want = (target - kept.len()).min(self.pool.floor().max(0.0) as usize);
        let mut joins = self.rng.sample(&vacant, want);
        joins.sort_unstable();
        for &s in &joins {
            self.occupant[s] = Some(self.next_uid);
            self.next_uid += 1;
            self.pool -= 1.0;
        }
        // Rotation can never starve the engine: leaves replenish the pool
        // before the refill draws, so a non-empty occupancy always yields
        // at least one join.  Only the departure clock can shrink the
        // active set, and it refuses to retire the last occupant.
        self.pending_leave.extend(leaves.iter().copied());
        self.pending_join.extend(joins.iter().copied());
        RoundOutcome { leaves, joins }
    }

    /// Commit an externally-driven join (trace/churn `Attach` of a vacant
    /// or previously-unknown worker id routed by the engine).  Mints a
    /// fresh user, drawing from the pool when it has whole users left.
    /// Returns false if the slot is already occupied.
    pub fn extern_join(&mut self, slot: usize, now: f64) -> bool {
        if self.occupant[slot].is_some() {
            return false;
        }
        self.advance_pool(now);
        if self.pool >= 1.0 {
            self.pool -= 1.0;
        }
        self.occupant[slot] = Some(self.next_uid);
        self.next_uid += 1;
        true
    }

    /// Commit an externally-driven leave (trace/churn `Isolate` of an
    /// occupied slot routed by the engine); the user retires permanently,
    /// mirroring a machine REMOVE event.  Returns false when the slot is
    /// vacant or the last active one.
    pub fn extern_leave(&mut self, slot: usize, now: f64) -> bool {
        if self.occupant[slot].is_none() || self.occupied_count() <= 1 {
            return false;
        }
        self.advance_pool(now);
        self.occupant[slot] = None;
        self.retired += 1;
        true
    }

    /// Approximate resident bytes of the model — used by the membership
    /// bench/tests to assert O(slots) scaling: the footprint must not
    /// grow with `population`.
    pub fn mem_bytes(&self) -> usize {
        std::mem::size_of::<Self>()
            + self.occupant.capacity() * std::mem::size_of::<Option<u64>>()
            + (self.pending_leave.len() + self.pending_join.len())
                * std::mem::size_of::<usize>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(population: usize) -> MembershipConfig {
        MembershipConfig {
            population,
            arrival_rate: 5.0,
            departure_rate: 0.1,
            round_interval: 1.0,
            participation: 0.5,
            sampling: SamplingKind::Sticky,
            stickiness: 0.6,
            aggregators: 0,
            seed: Some(7),
        }
    }

    #[test]
    fn config_roundtrip_and_strict_keys() {
        let c = cfg(1000);
        let j = c.to_json();
        let back = MembershipConfig::from_json(&j).unwrap();
        assert_eq!(back, c);
        let bad = Json::parse(r#"{"poplation": 10}"#).unwrap();
        assert!(MembershipConfig::from_json(&bad).is_err());
        let bad2 = Json::parse(r#"{"participation": 0.0}"#).unwrap();
        assert!(MembershipConfig::from_json(&bad2).is_err());
        let bad3 = Json::parse(r#"{"sampling": "roulette"}"#).unwrap();
        assert!(MembershipConfig::from_json(&bad3).is_err());
    }

    #[test]
    fn initial_fill_meets_target_and_pool_balances() {
        let m = MembershipModel::from_config(&cfg(1000), 16, 1).unwrap();
        assert_eq!(m.occupied_count(), 8); // ceil(0.5 * 16)
        assert!((m.pool() - 992.0).abs() < 1e-9);
        assert_eq!(m.initially_vacant().len(), 8);
    }

    #[test]
    fn aggregator_slots_always_occupied_and_graph_connected() {
        let mut c = cfg(1000);
        c.aggregators = 3;
        let m = MembershipModel::from_config(&c, 16, 1).unwrap();
        for s in 0..3 {
            assert!(m.is_occupied(s), "aggregator slot {s} vacant");
        }
        let g = m.build_graph().unwrap();
        assert!(g.is_connected());
        assert_eq!(g.num_vertices(), 16);
        // every edge slot stars onto exactly one hub
        for w in 3..16 {
            assert_eq!(g.degree(w), 1);
            assert!(g.has_edge(w, w % 3));
        }
        // flat config has no membership topology
        assert!(MembershipModel::from_config(&cfg(1000), 16, 1).unwrap().build_graph().is_none());
    }

    #[test]
    fn pool_ode_matches_euler_integration() {
        let mut m = MembershipModel::from_config(&cfg(10_000), 8, 1).unwrap();
        let p0 = m.pool();
        m.advance_pool(3.0);
        // fine-step Euler reference
        let (lam, mu) = (5.0, 0.1);
        let mut p = p0;
        let steps = 300_000;
        let dt = 3.0 / steps as f64;
        for _ in 0..steps {
            p += (lam - mu * p) * dt;
        }
        assert!((m.pool() - p).abs() < 1e-2, "closed form {} vs euler {p}", m.pool());
    }

    #[test]
    fn rotation_is_deterministic_per_seed() {
        let mut a = MembershipModel::from_config(&cfg(1000), 16, 1).unwrap();
        let mut b = MembershipModel::from_config(&cfg(1000), 16, 1).unwrap();
        for r in 1..=20 {
            let now = r as f64;
            assert_eq!(a.fire_round(now), b.fire_round(now));
            let da = a.schedule_departure(now);
            assert_eq!(da, b.schedule_departure(now));
            if let Some((t, s)) = da {
                assert_eq!(a.on_leave_event(s, t).0, b.on_leave_event(s, t).0);
            }
        }
        assert_eq!(a.occupied_count(), b.occupied_count());
        assert_eq!(a.retired(), b.retired());
    }

    #[test]
    fn uniform_rotation_swaps_all_occupants() {
        let mut c = cfg(1000);
        c.sampling = SamplingKind::Uniform;
        let mut m = MembershipModel::from_config(&c, 16, 1).unwrap();
        let out = m.fire_round(1.0);
        assert_eq!(out.leaves.len(), 8); // everyone rotated out
        assert_eq!(out.joins.len(), 8); // target refilled from the pool
        assert_eq!(m.occupied_count(), 8);
    }

    #[test]
    fn departure_clock_thins_stale_samples() {
        let mut m = MembershipModel::from_config(&cfg(1000), 4, 1).unwrap();
        let (t, slot) = m.schedule_departure(0.0).unwrap();
        // rotate the occupant away before the clock fires
        m.occupant[slot] = None;
        let (fired, next) = m.on_leave_event(slot, t);
        assert!(!fired, "stale departure must be a no-op");
        assert!(next.is_some(), "clock must be redrawn either way");
    }

    #[test]
    fn last_active_slot_is_protected() {
        let mut c = cfg(10);
        c.participation = 0.01; // target clamps to 1 slot
        let mut m = MembershipModel::from_config(&c, 4, 1).unwrap();
        assert_eq!(m.occupied_count(), 1);
        // even with an empty pool, a rotation leave replenishes the pool
        // before the refill draws, so occupancy never collapses to zero
        m.pool = 0.0;
        let out = m.fire_round(0.0);
        assert_eq!(m.occupied_count(), 1, "engine would starve");
        assert_eq!(out.leaves.len(), out.joins.len());
        // departure clock refuses to retire the last occupant
        let slot = (0..4).find(|&s| m.is_occupied(s)).unwrap();
        m.pending_leave.clear();
        m.pending_join.clear();
        m.next_departure = Some((1.0, slot, m.occupant[slot]));
        let (fired, _) = m.on_leave_event(slot, 1.0);
        assert!(!fired);
    }

    #[test]
    fn memory_is_o_slots_not_o_population() {
        let small = MembershipModel::from_config(&cfg(100_000), 32, 1).unwrap();
        let big = MembershipModel::from_config(&cfg(1_000_000), 32, 1).unwrap();
        assert_eq!(small.mem_bytes(), big.mem_bytes());
        assert!(big.mem_bytes() < 64 * 1024, "footprint {} not O(slots)", big.mem_bytes());
    }

    #[test]
    fn extern_join_and_leave_round_trip() {
        let mut m = MembershipModel::from_config(&cfg(1000), 8, 1).unwrap();
        let vacant = m.initially_vacant()[0];
        let pool0 = m.pool();
        assert!(m.extern_join(vacant, 0.5));
        assert!(!m.extern_join(vacant, 0.6)); // already occupied
        assert!((m.pool() - (pool0 - 1.0)).abs() < 1e-9);
        assert!(m.extern_leave(vacant, 0.7));
        assert_eq!(m.retired(), 1); // trace REMOVE retires permanently
        assert!(!m.extern_leave(vacant, 0.8)); // already vacant
    }
}
