//! Partition-aware adaptivity: component tracking + the `adapt` config.
//!
//! The paper assumes the communication graph stays connected, and the
//! churn subsystem's *connectivity repair* enforces that by deferring any
//! removal that would disconnect the graph.  Real partitions do happen,
//! though — and DSGD-AAU's whole point is to adapt *how many neighbors a
//! worker waits for* to what the network can actually deliver.  This
//! module makes that adaptivity partition-aware:
//!
//! * [`PartitionMonitor`] maintains connected-component membership
//!   incrementally as topology mutations apply: an engine-level **ground
//!   truth** view plus lagged **observed** views modeling the detection
//!   latency with which workers learn about splits and heals
//!   (timeout/heartbeat time, not zero);
//! * [`AdaptConfig`] is the strict-parsed `adapt` config section that
//!   switches the behavior on.  With everything at its default the
//!   simulator is bit-for-bit the legacy (always-connected, repair-on)
//!   system.
//!
//! With `partition_aware` on, every update rule retargets to the live
//! component structure: DSGD-AAU's Pathsearch epoch completes when the
//! accumulated subgraph spans the worker's *component* (and restarts when
//! a heal merges components, instead of leaning on the stall-fallback
//! liveness guard), synchronous DSGD barriers per component, fixed-k
//! clamps its group to the component, and Prague/AD-PSGD/AGP stop
//! sampling peers their component cannot reach.
//!
//! ## Config reference (`adapt` section)
//!
//! ```json
//! {
//!   "adapt": {
//!     "allow_partitions": true,       // disable connectivity repair:
//!                                     // removals apply even when they
//!                                     // disconnect the graph
//!     "partition_aware": true,        // component-aware update rules
//!                                     // (implies allow_partitions)
//!     "detection_latency": 0.5,       // seconds until workers observe a
//!                                     // component change (0 = instant);
//!                                     // a per-worker array like
//!                                     // [0.1, 0.1, 2.0, 2.0] gives each
//!                                     // worker its own latency
//!     "heal_restart": true            // restart the Pathsearch epoch when
//!                                     // the observed view sees a merge
//!   }
//! }
//! ```
//!
//! Like the `churn` and `straggler` sections, unknown keys and
//! wrongly-typed values are rejected rather than silently defaulted, and
//! omitting the section (or any key) keeps the legacy behavior:
//! `allow_partitions = false`, `partition_aware = false`,
//! `detection_latency = 0`, `heal_restart = true`.  The scalar
//! `detection_latency` form is bit-compatible with the pre-array
//! behavior; the per-worker array models heterogeneous failure detectors
//! (fast heartbeats near the cut, slow timeouts elsewhere) and must have
//! exactly one entry per worker.

mod monitor;

pub use monitor::{component_labels, PartitionMonitor, ViewDelta};

use crate::util::json::Json;
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;

/// How long workers take to observe a ground-truth component change:
/// one shared latency (the legacy scalar config form) or one latency per
/// worker (heterogeneous failure detectors).
#[derive(Debug, Clone, PartialEq)]
pub enum DetectionLatency {
    /// Every worker shares one latency (scalar config form;
    /// bit-compatible with the pre-array behavior).
    Uniform(f64),
    /// Worker `w` observes changes `latencies[w]` seconds late; the
    /// vector must hold exactly one entry per worker (checked when the
    /// engine is assembled, where the fleet size is known).
    PerWorker(Vec<f64>),
}

impl Default for DetectionLatency {
    fn default() -> Self {
        DetectionLatency::Uniform(0.0)
    }
}

impl From<f64> for DetectionLatency {
    fn from(v: f64) -> Self {
        DetectionLatency::Uniform(v)
    }
}

/// Scalar comparisons keep legacy call sites readable:
/// `cfg.adapt.detection_latency == 0.5` matches only the uniform form.
impl PartialEq<f64> for DetectionLatency {
    fn eq(&self, other: &f64) -> bool {
        matches!(self, DetectionLatency::Uniform(v) if v == other)
    }
}

impl DetectionLatency {
    /// The largest configured latency (an upper bound on how stale any
    /// worker's view can be).
    pub fn max_latency(&self) -> f64 {
        match self {
            DetectionLatency::Uniform(v) => *v,
            DetectionLatency::PerWorker(v) => v.iter().copied().fold(0.0, f64::max),
        }
    }

    /// Expand to one latency per worker for an `n`-worker fleet;
    /// a per-worker array of any other length is an error.
    pub fn resolve(&self, n: usize) -> Result<Vec<f64>> {
        match self {
            DetectionLatency::Uniform(v) => Ok(vec![*v; n]),
            DetectionLatency::PerWorker(v) => {
                anyhow::ensure!(
                    v.len() == n,
                    "adapt detection_latency array has {} entries for {} workers",
                    v.len(),
                    n
                );
                Ok(v.clone())
            }
        }
    }

    /// Parse the config form: a number, or an array of per-worker numbers.
    // pallas-lint: allow(strict-config-parse) — scalar-or-array form: there are no object keys to reject
    pub fn from_json(j: &Json) -> Result<Self> {
        if let Some(v) = j.as_f64() {
            return Ok(DetectionLatency::Uniform(v));
        }
        if let Some(a) = j.as_arr() {
            let vals = a
                .iter()
                .map(|v| {
                    v.as_f64()
                        .context("adapt detection_latency array entries must be numbers")
                })
                .collect::<Result<Vec<f64>>>()?;
            return Ok(DetectionLatency::PerWorker(vals));
        }
        bail!("adapt detection_latency must be a number or an array of per-worker numbers")
    }

    /// Inverse of [`Self::from_json`].
    pub fn to_json(&self) -> Json {
        match self {
            DetectionLatency::Uniform(v) => Json::Num(*v),
            DetectionLatency::PerWorker(v) => {
                Json::Arr(v.iter().map(|&x| Json::Num(x)).collect())
            }
        }
    }

    /// Sanity checks: every latency finite and non-negative, per-worker
    /// arrays non-empty.
    pub fn validate(&self) -> Result<()> {
        match self {
            DetectionLatency::Uniform(v) => {
                anyhow::ensure!(
                    v.is_finite() && *v >= 0.0,
                    "adapt detection_latency must be finite and >= 0"
                );
            }
            DetectionLatency::PerWorker(vals) => {
                anyhow::ensure!(
                    !vals.is_empty(),
                    "adapt detection_latency array must not be empty"
                );
                for v in vals {
                    anyhow::ensure!(
                        v.is_finite() && *v >= 0.0,
                        "adapt detection_latency entries must be finite and >= 0"
                    );
                }
            }
        }
        Ok(())
    }
}

/// The `adapt` section of the experiment config.
#[derive(Debug, Clone, PartialEq)]
pub struct AdaptConfig {
    /// Disable connectivity repair so removals can genuinely partition
    /// the graph (legacy default: `false`, repair on).
    pub allow_partitions: bool,
    /// Component-aware update rules (implies [`Self::allow_partitions`]).
    pub partition_aware: bool,
    /// Seconds between a ground-truth component change and the moment
    /// workers' local views observe it — one shared scalar or a
    /// per-worker array ([`DetectionLatency`]).
    pub detection_latency: DetectionLatency,
    /// When the observed view reports a merge (heal), restart the
    /// Pathsearch epoch so `P, V` re-accumulate over the merged graph.
    pub heal_restart: bool,
}

impl Default for AdaptConfig {
    fn default() -> Self {
        AdaptConfig {
            allow_partitions: false,
            partition_aware: false,
            detection_latency: DetectionLatency::default(),
            heal_restart: true,
        }
    }
}

impl AdaptConfig {
    /// Whether the engine must apply mutations without connectivity
    /// repair (`partition_aware` forces it: component retargeting is
    /// meaningless while repair keeps the graph connected).
    pub fn partitions_allowed(&self) -> bool {
        self.allow_partitions || self.partition_aware
    }

    /// Parse the config form, rejecting unknown keys and wrong types
    /// (mirrors `ChurnConfig::from_json`).
    pub fn from_json(j: &Json) -> Result<Self> {
        let obj = j.as_obj().context("adapt must be an object")?;
        let mut cfg = AdaptConfig::default();
        for (key, v) in obj {
            match key.as_str() {
                "allow_partitions" => {
                    cfg.allow_partitions =
                        v.as_bool().context("adapt allow_partitions must be a bool")?
                }
                "partition_aware" => {
                    cfg.partition_aware =
                        v.as_bool().context("adapt partition_aware must be a bool")?
                }
                "detection_latency" => {
                    cfg.detection_latency = DetectionLatency::from_json(v)?;
                }
                "heal_restart" => {
                    cfg.heal_restart =
                        v.as_bool().context("adapt heal_restart must be a bool")?
                }
                other => bail!("unknown adapt key {other:?}"),
            }
        }
        cfg.validate()?;
        Ok(cfg)
    }

    /// Inverse of [`Self::from_json`].
    pub fn to_json(&self) -> Json {
        let mut m: BTreeMap<String, Json> = BTreeMap::new();
        m.insert("allow_partitions".into(), Json::Bool(self.allow_partitions));
        m.insert("partition_aware".into(), Json::Bool(self.partition_aware));
        m.insert("detection_latency".into(), self.detection_latency.to_json());
        m.insert("heal_restart".into(), Json::Bool(self.heal_restart));
        Json::Obj(m)
    }

    /// Parameter sanity checks (called from `ExperimentConfig::validate`).
    pub fn validate(&self) -> Result<()> {
        self.detection_latency.validate()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_legacy() {
        let cfg = AdaptConfig::default();
        assert!(!cfg.partitions_allowed());
        assert!(!cfg.partition_aware);
        assert_eq!(cfg.detection_latency, 0.0);
        assert!(cfg.heal_restart);
        cfg.validate().unwrap();
    }

    #[test]
    fn partition_aware_implies_allow() {
        let cfg = AdaptConfig { partition_aware: true, ..AdaptConfig::default() };
        assert!(cfg.partitions_allowed());
    }

    #[test]
    fn json_roundtrip() {
        let cfg = AdaptConfig {
            allow_partitions: true,
            partition_aware: true,
            detection_latency: 0.75.into(),
            heal_restart: false,
        };
        let back = AdaptConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(back, cfg);
        // per-worker array form round-trips too
        let cfg = AdaptConfig {
            partition_aware: true,
            detection_latency: DetectionLatency::PerWorker(vec![0.1, 0.1, 2.0]),
            ..AdaptConfig::default()
        };
        let back = AdaptConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(back, cfg);
    }

    #[test]
    fn detection_latency_scalar_and_array_forms() {
        let lat = DetectionLatency::from_json(&Json::Num(0.5)).unwrap();
        assert_eq!(lat, DetectionLatency::Uniform(0.5));
        assert_eq!(lat.max_latency(), 0.5);
        assert_eq!(lat.resolve(3).unwrap(), vec![0.5, 0.5, 0.5]);

        let j = Json::parse("[0.1, 0.2, 0.3]").unwrap();
        let lat = DetectionLatency::from_json(&j).unwrap();
        assert_eq!(lat, DetectionLatency::PerWorker(vec![0.1, 0.2, 0.3]));
        assert_eq!(lat.max_latency(), 0.3);
        assert_eq!(lat.resolve(3).unwrap(), vec![0.1, 0.2, 0.3]);
        assert!(lat.resolve(4).is_err(), "array length must match the fleet");

        for bad in ["\"fast\"", "[0.1, \"x\"]", "[]", "[-1.0]", "-2"] {
            let j = Json::parse(bad).unwrap();
            let parsed = DetectionLatency::from_json(&j);
            assert!(
                parsed.is_err() || parsed.unwrap().validate().is_err(),
                "{bad} should be rejected"
            );
        }
    }

    #[test]
    fn strict_parsing_rejects_typos_and_wrong_types() {
        let j = Json::parse(r#"{"partition_awre": true}"#).unwrap();
        assert!(AdaptConfig::from_json(&j).is_err());
        let j = Json::parse(r#"{"detection_latency": "fast"}"#).unwrap();
        assert!(AdaptConfig::from_json(&j).is_err());
        let j = Json::parse(r#"{"partition_aware": 1}"#).unwrap();
        assert!(AdaptConfig::from_json(&j).is_err());
        let j = Json::parse(r#"{"detection_latency": -1.0}"#).unwrap();
        assert!(AdaptConfig::from_json(&j).is_err());
        let j = Json::parse(r#"{"detection_latency": [0.5, -1.0]}"#).unwrap();
        assert!(AdaptConfig::from_json(&j).is_err());
        let j = Json::parse(r#"{"partition_aware": true, "detection_latency": 0.25}"#).unwrap();
        let cfg = AdaptConfig::from_json(&j).unwrap();
        assert!(cfg.partition_aware && cfg.detection_latency == 0.25);
        let j = Json::parse(r#"{"partition_aware": true, "detection_latency": [0.25, 1.0]}"#)
            .unwrap();
        let cfg = AdaptConfig::from_json(&j).unwrap();
        assert_eq!(cfg.detection_latency, DetectionLatency::PerWorker(vec![0.25, 1.0]));
    }
}
