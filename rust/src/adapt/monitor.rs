//! Connected-component tracking for partition-aware adaptivity.
//!
//! [`PartitionMonitor`] maintains two kinds of views of the live graph's
//! component structure:
//!
//! * **ground truth** — updated incrementally as every topology-mutation
//!   batch applies (the engine is the single writer), with canonical
//!   labels (each vertex is labeled by the smallest vertex id in its
//!   component) so labels are comparable against a from-scratch BFS;
//! * **observed** — what the *workers* believe, which lags ground truth
//!   by a per-worker detection latency.  Real deployments learn about a
//!   partition via timeouts/heartbeats, not instantaneously — and not at
//!   the same moment everywhere: each worker adopts a queued ground-truth
//!   snapshot only once its own latency has elapsed.  Update rules
//!   therefore consult the observed view only, always *from some
//!   worker's perspective* (`component_of`, `component_members`,
//!   `same_component_observed`).
//!
//! With one shared latency (the legacy scalar config) every worker adopts
//! each snapshot at the same instant and the behavior is bit-compatible
//! with the fleet-wide view this monitor used to keep.  With heterogeneous
//! latencies, fast detectors act on the new component structure while
//! slow ones still see the old one — exactly the disagreement window the
//! stall-fallback liveness guard exists for.
//!
//! The incremental ground-truth update recomputes labels only for
//! components touched by a mutation batch (plus any component an added
//! edge bridges into): on fleets where churn touches a few links at a
//! time this is O(size of the affected components), not O(N + E).

use crate::churn::TopologyMutation;
use crate::topology::Graph;
use crate::WorkerId;
use std::collections::{BTreeMap, BTreeSet, VecDeque};

/// Canonical component labels of `g`: `labels[v]` is the smallest vertex
/// id in `v`'s connected component.  The reference implementation the
/// incremental monitor is tested against.
pub fn component_labels(g: &Graph) -> Vec<usize> {
    let n = g.num_vertices();
    let mut labels = vec![usize::MAX; n];
    for s in 0..n {
        if labels[s] != usize::MAX {
            continue;
        }
        // `s` is the smallest unlabeled id, hence the smallest id in its
        // component: it is the canonical label.
        labels[s] = s;
        let mut stack = vec![s];
        while let Some(v) = stack.pop() {
            for &u in g.neighbors(v) {
                if labels[u] == usize::MAX {
                    labels[u] = s;
                    stack.push(u);
                }
            }
        }
    }
    labels
}

/// Number of distinct components in a canonical label vector.
fn count_components(labels: &[usize]) -> usize {
    labels.iter().enumerate().filter(|&(v, &l)| v == l).count()
}

/// Number of distinct labels in any label vector.  Equals
/// [`count_components`] on canonical vectors, but also correct for the
/// composite per-worker observed vector, where a component's canonical
/// representative may hold a newer view than its members.
fn distinct_labels(labels: &[usize]) -> usize {
    labels.iter().collect::<BTreeSet<_>>().len()
}

/// Split/merge events between two label vectors (old → new).
fn diff_labels(old: &[usize], new: &[usize]) -> ViewDelta {
    debug_assert_eq!(old.len(), new.len());
    // old label -> set of new labels its members ended up in (splits),
    // new label -> set of old labels its members came from (merges).
    let mut fwd: BTreeMap<usize, BTreeSet<usize>> = BTreeMap::new();
    let mut bwd: BTreeMap<usize, BTreeSet<usize>> = BTreeMap::new();
    for (&o, &nw) in old.iter().zip(new.iter()) {
        fwd.entry(o).or_default().insert(nw);
        bwd.entry(nw).or_default().insert(o);
    }
    ViewDelta {
        splits: fwd.values().map(|s| (s.len() - 1) as u64).sum(),
        merges: bwd.values().map(|s| (s.len() - 1) as u64).sum(),
    }
}

/// What changed between two component views.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ViewDelta {
    /// Components that broke apart (per extra piece).
    pub splits: u64,
    /// Components that fused (per absorbed piece).
    pub merges: u64,
}

impl ViewDelta {
    /// Whether any membership changed.  Canonical labels change iff some
    /// component gained or lost members, so this is exact.
    pub fn changed(&self) -> bool {
        self.splits + self.merges > 0
    }

    /// Accumulate another delta.
    pub fn absorb(&mut self, other: ViewDelta) {
        self.splits += other.splits;
        self.merges += other.merges;
    }
}

/// One queued ground-truth snapshot awaiting per-worker detection.
#[derive(Debug, Clone)]
struct Snapshot {
    /// Virtual time the snapshot was queued; worker `w` adopts it once
    /// `queued_at + latency[w]` has passed.
    queued_at: f64,
    labels: Vec<usize>,
}

/// Incremental connected-component monitor with lagged per-worker views.
///
/// Ground truth updates synchronously with every mutation batch; each
/// worker adopts queued snapshots only once its own detection latency
/// elapses.  The per-worker API (`component_of`, `component_members`,
/// `same_component_observed`) keeps update rules honest about which view
/// they are allowed to act on; `observed_labels` and
/// `num_observed_components` summarize the composite fleet view (each
/// worker's own belief about itself), while the split/merge counters
/// fold every ground-truth transition in exactly once, when its first
/// worker adopts it.
#[derive(Debug, Clone)]
pub struct PartitionMonitor {
    /// Per-worker detection latencies.
    latencies: Vec<f64>,
    /// Sorted distinct latency values (detect-event schedule).
    distinct: Vec<f64>,
    truth: Vec<usize>,
    truth_components: usize,
    /// Snapshot history; `hist[0]` has absolute index `base`.  Snapshots
    /// stay alive while any worker's adopted view points at them.
    hist: VecDeque<Snapshot>,
    base: usize,
    /// Absolute index (into the snapshot history) of each worker's
    /// adopted view; always `>= base`.
    view_idx: Vec<usize>,
    /// Composite observed labels: `observed[w]` is `w`'s label in `w`'s
    /// adopted view.
    observed: Vec<usize>,
    observed_components: usize,
    /// Absolute index of the newest snapshot whose arrival transition has
    /// been folded into the split/merge counters (each ground-truth
    /// transition counts exactly once, when its first worker adopts it).
    counted: usize,
    observed_merges: u64,
    observed_splits: u64,
    /// Members of components formed by observed merges, accumulated until
    /// a rule drains them (scopes DSGD-AAU's heal restart to the merged
    /// components instead of wiping unrelated accumulation).
    merge_members: BTreeSet<WorkerId>,
}

impl PartitionMonitor {
    /// Monitor for the initial graph with one shared detection latency;
    /// truth and observed views coincide at the start.
    pub fn new(g: &Graph, detection_latency: f64) -> Self {
        Self::with_latencies(g, vec![detection_latency; g.num_vertices()])
    }

    /// Monitor with an explicit per-worker latency vector (one entry per
    /// vertex of `g`).
    pub fn with_latencies(g: &Graph, latencies: Vec<f64>) -> Self {
        assert_eq!(
            latencies.len(),
            g.num_vertices(),
            "monitor needs one detection latency per worker"
        );
        let labels = component_labels(g);
        let components = count_components(&labels);
        let mut distinct = latencies.clone();
        distinct.sort_by(f64::total_cmp);
        distinct.dedup();
        let n = latencies.len();
        let mut hist = VecDeque::new();
        hist.push_back(Snapshot { queued_at: f64::NEG_INFINITY, labels: labels.clone() });
        PartitionMonitor {
            latencies,
            distinct,
            truth: labels.clone(),
            truth_components: components,
            hist,
            base: 0,
            view_idx: vec![0; n],
            observed: labels,
            observed_components: components,
            counted: 0,
            observed_merges: 0,
            observed_splits: 0,
            merge_members: BTreeSet::new(),
        }
    }

    /// Sorted distinct per-worker latencies: after a component change the
    /// engine schedules one `PartitionDetect` event per entry, so every
    /// worker's adoption instant gets a wake-up.
    pub fn distinct_latencies(&self) -> Vec<f64> {
        self.distinct.clone()
    }

    /// Update ground truth after `muts` were applied to `g` (the graph is
    /// the *post-application* state).  Only components containing a
    /// mutation endpoint — plus components an added edge bridges into —
    /// are relabeled.  Returns the ground-truth delta.
    pub fn apply_mutations(&mut self, g: &Graph, muts: &[TopologyMutation]) -> ViewDelta {
        let n = g.num_vertices();
        debug_assert_eq!(self.truth.len(), n, "monitor sized for a different fleet");
        let mut touched: BTreeSet<usize> = BTreeSet::new();
        for m in muts {
            match m {
                TopologyMutation::AddEdge(i, j) | TopologyMutation::RemoveEdge(i, j) => {
                    touched.insert(*i);
                    touched.insert(*j);
                }
                TopologyMutation::Isolate(w) => {
                    touched.insert(*w);
                }
                TopologyMutation::Attach(w, ns) => {
                    touched.insert(*w);
                    touched.extend(ns.iter().copied());
                }
            }
        }
        touched.retain(|&v| v < n);
        if touched.is_empty() {
            return ViewDelta::default();
        }
        // Affected = every member of a component containing a touched
        // vertex (an Isolate/RemoveEdge can strand parts of the old
        // component that contain no mutation endpoint).
        let affected_labels: BTreeSet<usize> =
            touched.iter().map(|&v| self.truth[v]).collect();
        let old = self.truth.clone();
        let mut fresh = vec![false; n];
        for v in 0..n {
            if !affected_labels.contains(&old[v]) || fresh[v] {
                continue;
            }
            // Ascending scan: `v` is the smallest not-yet-relabeled vertex
            // of its (new) component, so it is the canonical label.  The
            // flood may walk into previously unaffected components via
            // added edges; relabeling them keeps labels canonical.
            let mut stack = vec![v];
            self.truth[v] = v;
            fresh[v] = true;
            while let Some(x) = stack.pop() {
                for &u in g.neighbors(x) {
                    if !fresh[u] {
                        fresh[u] = true;
                        self.truth[u] = v;
                        stack.push(u);
                    }
                }
            }
        }
        self.truth_components = count_components(&self.truth);
        diff_labels(&old, &self.truth)
    }

    /// Stage the current ground truth to become observed: worker `w`
    /// adopts the snapshot once `now + latency[w]` has passed.
    pub fn queue_observation(&mut self, now: f64) {
        self.hist.push_back(Snapshot { queued_at: now, labels: self.truth.clone() });
    }

    /// Advance every worker whose detection latency has elapsed onto the
    /// queued snapshots.  Snapshots are adopted one step per round
    /// fleet-wide, and each snapshot's arrival is folded into the
    /// split/merge counters exactly once — when its *first* worker adopts
    /// it.  Consecutive ground-truth snapshots are coherent label
    /// vectors, so their diff is meaningful; diffing the composite view
    /// instead would make a split adopted at different times masquerade
    /// as a later merge (spuriously firing DSGD-AAU's heal restart).
    /// With a uniform latency every worker adopts together and the
    /// per-snapshot deltas match the legacy fleet-wide promotion exactly.
    /// Returns the combined counted delta (zero when nothing new was
    /// due, even if slower workers caught up to already-counted views).
    pub fn promote_due(&mut self, now: f64) -> ViewDelta {
        let mut total = ViewDelta::default();
        loop {
            let mut moved = false;
            for w in 0..self.view_idx.len() {
                let next = self.view_idx[w] + 1;
                if next - self.base < self.hist.len()
                    && self.hist[next - self.base].queued_at + self.latencies[w] <= now + 1e-9
                {
                    self.view_idx[w] = next;
                    moved = true;
                }
            }
            if !moved {
                break;
            }
            let newest = self.view_idx.iter().copied().max().unwrap_or(self.counted);
            while self.counted < newest {
                self.counted += 1;
                let prev = self.hist[self.counted - 1 - self.base].labels.clone();
                let next = self.hist[self.counted - self.base].labels.clone();
                total.absorb(self.count_transition(&prev, &next));
            }
            self.refresh_composite();
        }
        self.gc();
        total
    }

    /// Make every worker's observed view equal to ground truth
    /// immediately (used when all detection latencies are zero; the
    /// transition is counted against the current composite view, which
    /// under a uniform latency is the previously adopted snapshot).
    pub fn promote_now(&mut self) -> ViewDelta {
        let old = std::mem::take(&mut self.observed);
        let new = self.truth.clone();
        let delta = self.count_transition(&old, &new);
        self.hist.clear();
        self.hist.push_back(Snapshot { queued_at: f64::NEG_INFINITY, labels: new });
        self.base = 0;
        self.counted = 0;
        for idx in self.view_idx.iter_mut() {
            *idx = 0;
        }
        self.refresh_composite();
        delta
    }

    /// Fold one coherent label-vector transition (old → new) into the
    /// observed split/merge counters and the merge-member set.
    fn count_transition(&mut self, old: &[usize], new: &[usize]) -> ViewDelta {
        let delta = diff_labels(old, new);
        if delta.merges > 0 {
            // Record every member of a freshly merged component (a new
            // label fed by more than one old label) so rules can scope
            // their heal reaction to exactly these workers.
            let mut sources: BTreeMap<usize, BTreeSet<usize>> = BTreeMap::new();
            for (&o, &nw) in old.iter().zip(new.iter()) {
                sources.entry(nw).or_default().insert(o);
            }
            for (v, &l) in new.iter().enumerate() {
                if sources.get(&l).map_or(false, |s| s.len() > 1) {
                    self.merge_members.insert(v);
                }
            }
        }
        self.observed_merges += delta.merges;
        self.observed_splits += delta.splits;
        delta
    }

    /// Rebuild the composite observed vector (each worker's self-label
    /// in its adopted view) and its distinct-component count.
    fn refresh_composite(&mut self) {
        let n = self.view_idx.len();
        let mut labels = Vec::with_capacity(n);
        for w in 0..n {
            labels.push(self.hist[self.view_idx[w] - self.base].labels[w]);
        }
        self.observed = labels;
        self.observed_components = distinct_labels(&self.observed);
    }

    /// Drop history no worker's view points at any longer.
    fn gc(&mut self) {
        let min_idx = self.view_idx.iter().copied().min().unwrap_or(self.base);
        while self.base < min_idx {
            self.hist.pop_front();
            self.base += 1;
        }
    }

    /// Number of ground-truth components.
    pub fn num_components(&self) -> usize {
        self.truth_components
    }

    /// Number of distinct components in the composite observed view.
    pub fn num_observed_components(&self) -> usize {
        self.observed_components
    }

    /// Ground-truth canonical labels (engine diagnostics / tests).
    pub fn labels(&self) -> &[usize] {
        &self.truth
    }

    /// Composite observed labels: entry `w` is what worker `w` believes
    /// its own component label to be.
    pub fn observed_labels(&self) -> &[usize] {
        &self.observed
    }

    /// The full label vector of `w`'s adopted view.
    fn view_of(&self, w: WorkerId) -> &[usize] {
        &self.hist[self.view_idx[w] - self.base].labels
    }

    /// Observed component label of worker `w` (what `w` believes).
    pub fn component_of(&self, w: WorkerId) -> usize {
        self.observed[w]
    }

    /// Whether `a` believes `b` is in its component (evaluated in `a`'s
    /// adopted view; with heterogeneous latencies the relation need not
    /// be symmetric while views disagree).
    pub fn same_component_observed(&self, a: WorkerId, b: WorkerId) -> bool {
        let view = self.view_of(a);
        view[a] == view[b]
    }

    /// Every worker `w` believes shares its component, ascending
    /// (includes `w`; evaluated in `w`'s adopted view).
    pub fn component_members(&self, w: WorkerId) -> Vec<WorkerId> {
        let view = self.view_of(w);
        let label = view[w];
        (0..view.len()).filter(|&v| view[v] == label).collect()
    }

    /// Cumulative component-merge events the workers' views have
    /// observed — each ground-truth transition counted once, at first
    /// adoption (update rules use this to notice heals).
    pub fn observed_merges(&self) -> u64 {
        self.observed_merges
    }

    /// Drain the members of components formed by observed merges since
    /// the last call (ascending).  DSGD-AAU resets exactly these workers'
    /// Pathsearch accumulation on a heal, leaving uninvolved components'
    /// progress intact.
    pub fn take_merge_members(&mut self) -> Vec<WorkerId> {
        let out: Vec<WorkerId> = self.merge_members.iter().copied().collect();
        self.merge_members.clear();
        out
    }

    /// Cumulative component-split events the workers' views have
    /// observed — each ground-truth transition counted once, at first
    /// adoption.
    pub fn observed_splits(&self) -> u64 {
        self.observed_splits
    }

    /// Queued snapshots the slowest worker has not yet adopted.
    pub fn pending_views(&self) -> usize {
        let newest = self.base + self.hist.len() - 1;
        newest - self.view_idx.iter().copied().min().unwrap_or(newest)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::churn::apply_mutations_unrepaired;
    use crate::topology::generators::{complete, random_connected, ring};

    #[test]
    fn labels_are_canonical_bfs() {
        let g = ring(5);
        assert_eq!(component_labels(&g), vec![0, 0, 0, 0, 0]);
        let mut g = ring(6);
        g.remove_edge(0, 1);
        g.remove_edge(3, 4);
        // components {1,2,3} and {4,5,0}
        assert_eq!(component_labels(&g), vec![0, 1, 1, 1, 0, 0]);
    }

    #[test]
    fn split_and_heal_tracked_incrementally() {
        let mut g = ring(6);
        let mut mon = PartitionMonitor::new(&g, 0.0);
        assert_eq!(mon.num_components(), 1);

        let cut = [
            TopologyMutation::RemoveEdge(0, 1),
            TopologyMutation::RemoveEdge(3, 4),
        ];
        apply_mutations_unrepaired(&mut g, &cut);
        let delta = mon.apply_mutations(&g, &cut);
        assert_eq!(delta, ViewDelta { splits: 1, merges: 0 });
        assert_eq!(mon.num_components(), 2);
        assert_eq!(mon.labels(), component_labels(&g).as_slice());

        let heal = [TopologyMutation::AddEdge(0, 1)];
        apply_mutations_unrepaired(&mut g, &heal);
        let delta = mon.apply_mutations(&g, &heal);
        assert_eq!(delta, ViewDelta { splits: 0, merges: 1 });
        assert_eq!(mon.num_components(), 1);
        assert_eq!(mon.labels(), component_labels(&g).as_slice());
    }

    #[test]
    fn zero_latency_promotes_observed_immediately() {
        let mut g = complete(4);
        let mut mon = PartitionMonitor::new(&g, 0.0);
        let muts = [TopologyMutation::Isolate(3)];
        apply_mutations_unrepaired(&mut g, &muts);
        mon.apply_mutations(&g, &muts);
        mon.promote_now();
        assert_eq!(mon.num_observed_components(), 2);
        assert_eq!(mon.component_members(3), vec![3]);
        assert_eq!(mon.component_members(0), vec![0, 1, 2]);
        assert_eq!(mon.observed_splits(), 1);
    }

    #[test]
    fn detection_latency_delays_the_observed_view() {
        let mut g = ring(4);
        let mut mon = PartitionMonitor::new(&g, 1.5);
        let cut = [
            TopologyMutation::RemoveEdge(0, 1),
            TopologyMutation::RemoveEdge(2, 3),
        ];
        apply_mutations_unrepaired(&mut g, &cut);
        mon.apply_mutations(&g, &cut);
        mon.queue_observation(10.0); // due at 10.0 + latency 1.5
        // truth split, workers have not noticed yet
        assert_eq!(mon.num_components(), 2);
        assert_eq!(mon.num_observed_components(), 1);
        assert!(mon.same_component_observed(0, 1));
        assert_eq!(mon.promote_due(10.2), ViewDelta::default());
        assert_eq!(mon.num_observed_components(), 1);
        assert_eq!(mon.pending_views(), 1);
        let delta = mon.promote_due(11.5);
        assert_eq!(delta.splits, 1);
        assert_eq!(mon.num_observed_components(), 2);
        assert!(!mon.same_component_observed(0, 1));
        assert_eq!(mon.pending_views(), 0);
    }

    #[test]
    fn per_worker_latencies_stagger_adoption() {
        // ring(6) cut into {1,2,3} and {4,5,0}; workers 0-2 detect fast
        // (0.5 s), workers 3-5 slowly (2.0 s)
        let mut g = ring(6);
        let lat = vec![0.5, 0.5, 0.5, 2.0, 2.0, 2.0];
        let mut mon = PartitionMonitor::with_latencies(&g, lat);
        assert_eq!(mon.distinct_latencies(), vec![0.5, 2.0]);
        let cut = [
            TopologyMutation::RemoveEdge(0, 1),
            TopologyMutation::RemoveEdge(3, 4),
        ];
        apply_mutations_unrepaired(&mut g, &cut);
        mon.apply_mutations(&g, &cut);
        mon.queue_observation(10.0);

        // t = 10.6: only the fast detectors have adopted the split view
        let delta = mon.promote_due(10.6);
        assert!(delta.changed());
        assert_eq!(mon.pending_views(), 1, "slow workers still hold the old view");
        // fast worker 1 sees the cut: its component is {1,2,3}
        assert_eq!(mon.component_members(1), vec![1, 2, 3]);
        assert!(!mon.same_component_observed(1, 0));
        // slow worker 4 still believes the ring is whole
        assert_eq!(mon.component_members(4), (0..6).collect::<Vec<_>>());
        assert!(mon.same_component_observed(4, 1), "stale view: 4 still sees 1");

        // t = 12.0: everyone has adopted; views agree again
        let late = mon.promote_due(12.0);
        assert_eq!(late, ViewDelta::default(), "the transition was already counted");
        assert_eq!(mon.pending_views(), 0);
        assert_eq!(mon.component_members(4), vec![0, 4, 5]);
        assert_eq!(mon.num_observed_components(), 2);
        assert_eq!(mon.observed_labels(), component_labels(&g).as_slice());
        // one real split, and — crucially — no phantom merge from the
        // slow workers catching up, so DSGD-AAU's heal restart stays off
        assert_eq!(mon.observed_splits(), 1);
        assert_eq!(mon.observed_merges(), 0);
        assert!(mon.take_merge_members().is_empty());
    }

    #[test]
    fn merge_members_scoped_to_the_healed_components() {
        // comps {0,1} {2,3} {4,5}; a heal merges the first two — the
        // drained member list must exclude the untouched {4,5}
        let mut g = Graph::from_edges(6, &[(0, 1), (2, 3), (4, 5)]);
        let mut mon = PartitionMonitor::new(&g, 0.0);
        assert!(mon.take_merge_members().is_empty());
        let heal = [TopologyMutation::AddEdge(1, 2)];
        apply_mutations_unrepaired(&mut g, &heal);
        mon.apply_mutations(&g, &heal);
        mon.promote_now();
        assert_eq!(mon.take_merge_members(), vec![0, 1, 2, 3]);
        assert!(mon.take_merge_members().is_empty(), "drained after the take");
        assert_eq!(mon.observed_merges(), 1);
    }

    #[test]
    fn attach_merges_components() {
        let mut g = Graph::from_edges(5, &[(0, 1), (2, 3)]);
        let mut mon = PartitionMonitor::new(&g, 0.0);
        assert_eq!(mon.num_components(), 3); // {0,1} {2,3} {4}
        let muts = [TopologyMutation::Attach(4, vec![1, 2])];
        apply_mutations_unrepaired(&mut g, &muts);
        let delta = mon.apply_mutations(&g, &muts);
        assert_eq!(mon.num_components(), 1);
        assert_eq!(delta.merges, 2);
        assert_eq!(mon.labels(), component_labels(&g).as_slice());
    }

    #[test]
    fn seeded_random_mutations_match_scratch_labels() {
        use crate::util::Rng64;
        for seed in 0..20u64 {
            let mut g = random_connected(12, 0.2, seed);
            let mut mon = PartitionMonitor::new(&g, 0.0);
            let mut rng = Rng64::seed_from_u64(seed ^ 0x5eed);
            for _ in 0..8 {
                let muts = [
                    TopologyMutation::RemoveEdge(rng.gen_range(12), rng.gen_range(12)),
                    TopologyMutation::AddEdge(rng.gen_range(12), rng.gen_range(12)),
                    TopologyMutation::Isolate(rng.gen_range(12)),
                ];
                apply_mutations_unrepaired(&mut g, &muts);
                mon.apply_mutations(&g, &muts);
                assert_eq!(
                    mon.labels(),
                    component_labels(&g).as_slice(),
                    "seed {seed}: incremental labels diverged"
                );
                assert_eq!(mon.num_components(), count_components(mon.labels()));
            }
        }
    }

    #[test]
    fn history_is_garbage_collected() {
        let mut g = ring(4);
        let mut mon = PartitionMonitor::new(&g, 1.0);
        for i in 0..50 {
            let t = i as f64;
            let muts = if i % 2 == 0 {
                [TopologyMutation::RemoveEdge(0, 1)]
            } else {
                [TopologyMutation::AddEdge(0, 1)]
            };
            apply_mutations_unrepaired(&mut g, &muts);
            mon.apply_mutations(&g, &muts);
            mon.queue_observation(t);
            mon.promote_due(t); // adopts the snapshot queued at t - 1
        }
        assert!(
            mon.hist.len() <= 3,
            "adopted snapshots must be garbage-collected, kept {}",
            mon.hist.len()
        );
    }
}
