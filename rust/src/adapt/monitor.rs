//! Connected-component tracking for partition-aware adaptivity.
//!
//! [`PartitionMonitor`] maintains two views of the live graph's component
//! structure:
//!
//! * **ground truth** — updated incrementally as every topology-mutation
//!   batch applies (the engine is the single writer), with canonical
//!   labels (each vertex is labeled by the smallest vertex id in its
//!   component) so labels are comparable against a from-scratch BFS;
//! * **observed** — what the *workers* believe, which lags ground truth
//!   by a configurable detection latency.  Real deployments learn about
//!   a partition via timeouts/heartbeats, not instantaneously; update
//!   rules therefore consult the observed view only.
//!
//! The incremental update recomputes labels only for components touched
//! by a mutation batch (plus any component an added edge bridges into):
//! on fleets where churn touches a few links at a time this is O(size of
//! the affected components), not O(N + E).

use crate::churn::TopologyMutation;
use crate::topology::Graph;
use crate::WorkerId;
use std::collections::{BTreeMap, BTreeSet, VecDeque};

/// Canonical component labels of `g`: `labels[v]` is the smallest vertex
/// id in `v`'s connected component.  The reference implementation the
/// incremental monitor is tested against.
pub fn component_labels(g: &Graph) -> Vec<usize> {
    let n = g.num_vertices();
    let mut labels = vec![usize::MAX; n];
    for s in 0..n {
        if labels[s] != usize::MAX {
            continue;
        }
        // `s` is the smallest unlabeled id, hence the smallest id in its
        // component: it is the canonical label.
        labels[s] = s;
        let mut stack = vec![s];
        while let Some(v) = stack.pop() {
            for &u in g.neighbors(v) {
                if labels[u] == usize::MAX {
                    labels[u] = s;
                    stack.push(u);
                }
            }
        }
    }
    labels
}

/// Number of distinct components in a canonical label vector.
fn count_components(labels: &[usize]) -> usize {
    labels.iter().enumerate().filter(|&(v, &l)| v == l).count()
}

/// Split/merge events between two label vectors (old → new).
fn diff_labels(old: &[usize], new: &[usize]) -> ViewDelta {
    debug_assert_eq!(old.len(), new.len());
    // old label -> set of new labels its members ended up in (splits),
    // new label -> set of old labels its members came from (merges).
    let mut fwd: BTreeMap<usize, BTreeSet<usize>> = BTreeMap::new();
    let mut bwd: BTreeMap<usize, BTreeSet<usize>> = BTreeMap::new();
    for (&o, &nw) in old.iter().zip(new.iter()) {
        fwd.entry(o).or_default().insert(nw);
        bwd.entry(nw).or_default().insert(o);
    }
    ViewDelta {
        splits: fwd.values().map(|s| (s.len() - 1) as u64).sum(),
        merges: bwd.values().map(|s| (s.len() - 1) as u64).sum(),
    }
}

/// What changed between two component views.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ViewDelta {
    /// Components that broke apart (per extra piece).
    pub splits: u64,
    /// Components that fused (per absorbed piece).
    pub merges: u64,
}

impl ViewDelta {
    /// Whether any membership changed.  Canonical labels change iff some
    /// component gained or lost members, so this is exact.
    pub fn changed(&self) -> bool {
        self.splits + self.merges > 0
    }

    /// Accumulate another delta.
    pub fn absorb(&mut self, other: ViewDelta) {
        self.splits += other.splits;
        self.merges += other.merges;
    }
}

/// A pending observed-view update (ground truth snapshot awaiting its
/// detection latency).
#[derive(Debug, Clone)]
struct PendingView {
    due: f64,
    labels: Vec<usize>,
}

/// Incremental connected-component monitor with lagged per-worker views.
///
/// All workers share one detection latency, so the observed view is a
/// single label vector every worker queries for *its own* component —
/// the per-worker API (`component_of`, `component_members`) keeps update
/// rules honest about which view they are allowed to act on.
#[derive(Debug, Clone)]
pub struct PartitionMonitor {
    detection_latency: f64,
    truth: Vec<usize>,
    truth_components: usize,
    observed: Vec<usize>,
    observed_components: usize,
    observed_merges: u64,
    observed_splits: u64,
    pending: VecDeque<PendingView>,
    /// Members of components formed by observed merges, accumulated until
    /// a rule drains them (scopes DSGD-AAU's heal restart to the merged
    /// components instead of wiping unrelated accumulation).
    merge_members: BTreeSet<WorkerId>,
}

impl PartitionMonitor {
    /// Monitor for the initial graph; truth and observed views coincide.
    pub fn new(g: &Graph, detection_latency: f64) -> Self {
        let labels = component_labels(g);
        let components = count_components(&labels);
        PartitionMonitor {
            detection_latency,
            truth: labels.clone(),
            truth_components: components,
            observed: labels,
            observed_components: components,
            observed_merges: 0,
            observed_splits: 0,
            pending: VecDeque::new(),
            merge_members: BTreeSet::new(),
        }
    }

    /// Update ground truth after `muts` were applied to `g` (the graph is
    /// the *post-application* state).  Only components containing a
    /// mutation endpoint — plus components an added edge bridges into —
    /// are relabeled.  Returns the ground-truth delta.
    pub fn apply_mutations(&mut self, g: &Graph, muts: &[TopologyMutation]) -> ViewDelta {
        let n = g.num_vertices();
        debug_assert_eq!(self.truth.len(), n, "monitor sized for a different fleet");
        let mut touched: BTreeSet<usize> = BTreeSet::new();
        for m in muts {
            match m {
                TopologyMutation::AddEdge(i, j) | TopologyMutation::RemoveEdge(i, j) => {
                    touched.insert(*i);
                    touched.insert(*j);
                }
                TopologyMutation::Isolate(w) => {
                    touched.insert(*w);
                }
                TopologyMutation::Attach(w, ns) => {
                    touched.insert(*w);
                    touched.extend(ns.iter().copied());
                }
            }
        }
        touched.retain(|&v| v < n);
        if touched.is_empty() {
            return ViewDelta::default();
        }
        // Affected = every member of a component containing a touched
        // vertex (an Isolate/RemoveEdge can strand parts of the old
        // component that contain no mutation endpoint).
        let affected_labels: BTreeSet<usize> =
            touched.iter().map(|&v| self.truth[v]).collect();
        let old = self.truth.clone();
        let mut fresh = vec![false; n];
        for v in 0..n {
            if !affected_labels.contains(&old[v]) || fresh[v] {
                continue;
            }
            // Ascending scan: `v` is the smallest not-yet-relabeled vertex
            // of its (new) component, so it is the canonical label.  The
            // flood may walk into previously unaffected components via
            // added edges; relabeling them keeps labels canonical.
            let mut stack = vec![v];
            self.truth[v] = v;
            fresh[v] = true;
            while let Some(x) = stack.pop() {
                for &u in g.neighbors(x) {
                    if !fresh[u] {
                        fresh[u] = true;
                        self.truth[u] = v;
                        stack.push(u);
                    }
                }
            }
        }
        self.truth_components = count_components(&self.truth);
        diff_labels(&old, &self.truth)
    }

    /// Stage the current ground truth to become the observed view once
    /// the detection latency elapses: due at `now + detection_latency`.
    pub fn queue_observation(&mut self, now: f64) {
        self.pending.push_back(PendingView {
            due: now + self.detection_latency,
            labels: self.truth.clone(),
        });
    }

    /// Promote every pending view whose detection time has arrived,
    /// accumulating observed split/merge counters.  Returns the combined
    /// delta (zero when nothing was due).
    pub fn promote_due(&mut self, now: f64) -> ViewDelta {
        let mut total = ViewDelta::default();
        while let Some(front) = self.pending.front() {
            if front.due > now + 1e-9 {
                break;
            }
            let view = self.pending.pop_front().expect("front exists");
            total.absorb(self.set_observed(view.labels));
        }
        total
    }

    /// Make the observed view equal to ground truth immediately (used
    /// when `detection_latency == 0`).
    pub fn promote_now(&mut self) -> ViewDelta {
        self.pending.clear();
        let labels = self.truth.clone();
        self.set_observed(labels)
    }

    fn set_observed(&mut self, labels: Vec<usize>) -> ViewDelta {
        let delta = diff_labels(&self.observed, &labels);
        if delta.merges > 0 {
            // Record every member of a freshly merged component (a new
            // label fed by more than one old label) so rules can scope
            // their heal reaction to exactly these workers.
            let mut sources: BTreeMap<usize, BTreeSet<usize>> = BTreeMap::new();
            for (&o, &nw) in self.observed.iter().zip(labels.iter()) {
                sources.entry(nw).or_default().insert(o);
            }
            for (v, &l) in labels.iter().enumerate() {
                if sources.get(&l).map_or(false, |s| s.len() > 1) {
                    self.merge_members.insert(v);
                }
            }
        }
        self.observed = labels;
        self.observed_components = count_components(&self.observed);
        self.observed_merges += delta.merges;
        self.observed_splits += delta.splits;
        delta
    }

    /// Number of ground-truth components.
    pub fn num_components(&self) -> usize {
        self.truth_components
    }

    /// Number of components in the workers' observed view.
    pub fn num_observed_components(&self) -> usize {
        self.observed_components
    }

    /// Ground-truth canonical labels (engine diagnostics / tests).
    pub fn labels(&self) -> &[usize] {
        &self.truth
    }

    /// Observed canonical labels.
    pub fn observed_labels(&self) -> &[usize] {
        &self.observed
    }

    /// Observed component label of worker `w` (what `w` believes).
    pub fn component_of(&self, w: WorkerId) -> usize {
        self.observed[w]
    }

    /// Whether `a` and `b` are in the same component per the observed view.
    pub fn same_component_observed(&self, a: WorkerId, b: WorkerId) -> bool {
        self.observed[a] == self.observed[b]
    }

    /// Every worker in `w`'s observed component, ascending (includes `w`).
    pub fn component_members(&self, w: WorkerId) -> Vec<WorkerId> {
        let label = self.observed[w];
        (0..self.observed.len()).filter(|&v| self.observed[v] == label).collect()
    }

    /// Cumulative component-merge events the observed view has seen
    /// (update rules use this to notice heals).
    pub fn observed_merges(&self) -> u64 {
        self.observed_merges
    }

    /// Drain the members of components formed by observed merges since
    /// the last call (ascending).  DSGD-AAU resets exactly these workers'
    /// Pathsearch accumulation on a heal, leaving uninvolved components'
    /// progress intact.
    pub fn take_merge_members(&mut self) -> Vec<WorkerId> {
        let out: Vec<WorkerId> = self.merge_members.iter().copied().collect();
        self.merge_members.clear();
        out
    }

    /// Cumulative component-split events the observed view has seen.
    pub fn observed_splits(&self) -> u64 {
        self.observed_splits
    }

    /// Views whose detection latency has not yet elapsed.
    pub fn pending_views(&self) -> usize {
        self.pending.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::churn::apply_mutations_unrepaired;
    use crate::topology::generators::{complete, random_connected, ring};

    #[test]
    fn labels_are_canonical_bfs() {
        let g = ring(5);
        assert_eq!(component_labels(&g), vec![0, 0, 0, 0, 0]);
        let mut g = ring(6);
        g.remove_edge(0, 1);
        g.remove_edge(3, 4);
        // components {1,2,3} and {4,5,0}
        assert_eq!(component_labels(&g), vec![0, 1, 1, 1, 0, 0]);
    }

    #[test]
    fn split_and_heal_tracked_incrementally() {
        let mut g = ring(6);
        let mut mon = PartitionMonitor::new(&g, 0.0);
        assert_eq!(mon.num_components(), 1);

        let cut = [
            TopologyMutation::RemoveEdge(0, 1),
            TopologyMutation::RemoveEdge(3, 4),
        ];
        apply_mutations_unrepaired(&mut g, &cut);
        let delta = mon.apply_mutations(&g, &cut);
        assert_eq!(delta, ViewDelta { splits: 1, merges: 0 });
        assert_eq!(mon.num_components(), 2);
        assert_eq!(mon.labels(), component_labels(&g).as_slice());

        let heal = [TopologyMutation::AddEdge(0, 1)];
        apply_mutations_unrepaired(&mut g, &heal);
        let delta = mon.apply_mutations(&g, &heal);
        assert_eq!(delta, ViewDelta { splits: 0, merges: 1 });
        assert_eq!(mon.num_components(), 1);
        assert_eq!(mon.labels(), component_labels(&g).as_slice());
    }

    #[test]
    fn zero_latency_promotes_observed_immediately() {
        let mut g = complete(4);
        let mut mon = PartitionMonitor::new(&g, 0.0);
        let muts = [TopologyMutation::Isolate(3)];
        apply_mutations_unrepaired(&mut g, &muts);
        mon.apply_mutations(&g, &muts);
        mon.promote_now();
        assert_eq!(mon.num_observed_components(), 2);
        assert_eq!(mon.component_members(3), vec![3]);
        assert_eq!(mon.component_members(0), vec![0, 1, 2]);
        assert_eq!(mon.observed_splits(), 1);
    }

    #[test]
    fn detection_latency_delays_the_observed_view() {
        let mut g = ring(4);
        let mut mon = PartitionMonitor::new(&g, 1.5);
        let cut = [
            TopologyMutation::RemoveEdge(0, 1),
            TopologyMutation::RemoveEdge(2, 3),
        ];
        apply_mutations_unrepaired(&mut g, &cut);
        mon.apply_mutations(&g, &cut);
        mon.queue_observation(10.0); // due at 10.0 + latency 1.5
        // truth split, workers have not noticed yet
        assert_eq!(mon.num_components(), 2);
        assert_eq!(mon.num_observed_components(), 1);
        assert!(mon.same_component_observed(0, 1));
        assert_eq!(mon.promote_due(10.2), ViewDelta::default());
        assert_eq!(mon.num_observed_components(), 1);
        let delta = mon.promote_due(11.5);
        assert_eq!(delta.splits, 1);
        assert_eq!(mon.num_observed_components(), 2);
        assert!(!mon.same_component_observed(0, 1));
        assert_eq!(mon.pending_views(), 0);
    }

    #[test]
    fn merge_members_scoped_to_the_healed_components() {
        // comps {0,1} {2,3} {4,5}; a heal merges the first two — the
        // drained member list must exclude the untouched {4,5}
        let mut g = Graph::from_edges(6, &[(0, 1), (2, 3), (4, 5)]);
        let mut mon = PartitionMonitor::new(&g, 0.0);
        assert!(mon.take_merge_members().is_empty());
        let heal = [TopologyMutation::AddEdge(1, 2)];
        apply_mutations_unrepaired(&mut g, &heal);
        mon.apply_mutations(&g, &heal);
        mon.promote_now();
        assert_eq!(mon.take_merge_members(), vec![0, 1, 2, 3]);
        assert!(mon.take_merge_members().is_empty(), "drained after the take");
        assert_eq!(mon.observed_merges(), 1);
    }

    #[test]
    fn attach_merges_components() {
        let mut g = Graph::from_edges(5, &[(0, 1), (2, 3)]);
        let mut mon = PartitionMonitor::new(&g, 0.0);
        assert_eq!(mon.num_components(), 3); // {0,1} {2,3} {4}
        let muts = [TopologyMutation::Attach(4, vec![1, 2])];
        apply_mutations_unrepaired(&mut g, &muts);
        let delta = mon.apply_mutations(&g, &muts);
        assert_eq!(mon.num_components(), 1);
        assert_eq!(delta.merges, 2);
        assert_eq!(mon.labels(), component_labels(&g).as_slice());
    }

    #[test]
    fn seeded_random_mutations_match_scratch_labels() {
        use crate::util::Rng64;
        for seed in 0..20u64 {
            let mut g = random_connected(12, 0.2, seed);
            let mut mon = PartitionMonitor::new(&g, 0.0);
            let mut rng = Rng64::seed_from_u64(seed ^ 0x5eed);
            for _ in 0..8 {
                let muts = [
                    TopologyMutation::RemoveEdge(rng.gen_range(12), rng.gen_range(12)),
                    TopologyMutation::AddEdge(rng.gen_range(12), rng.gen_range(12)),
                    TopologyMutation::Isolate(rng.gen_range(12)),
                ];
                apply_mutations_unrepaired(&mut g, &muts);
                mon.apply_mutations(&g, &muts);
                assert_eq!(
                    mon.labels(),
                    component_labels(&g).as_slice(),
                    "seed {seed}: incremental labels diverged"
                );
                assert_eq!(mon.num_components(), count_components(mon.labels()));
            }
        }
    }
}
