//! The decentralized training engine: event loop + shared mechanics.
//!
//! [`EngineCore`] owns worker parameters, gradient stashes, the virtual
//! clock, the consensus/Pathsearch state and all accounting; an
//! [`UpdateRule`](crate::algorithms::UpdateRule) reacts to compute-done
//! events and drives gossip through the core's primitives.  Gradient
//! *values* are real (produced by the [`Backend`]); *durations* come from
//! the [`ComputeModel`] so straggler dynamics match the paper's testbed.

use crate::adapt::{AdaptConfig, PartitionMonitor};
use crate::algorithms::UpdateRule;
use crate::backend::{Backend, GradOutput};
use crate::churn::{self, ApplyOutcome, ChurnModel, TopologyMutation};
use crate::config::{ExperimentConfig, LrSchedule};
use crate::consensus::GroupWeights;
use crate::metrics::Recorder;
use crate::model::ParamVec;
use crate::pathsearch::PathSearch;
use crate::sim::{CommModel, ComputeModel, Event, EventKind, EventQueue};
use crate::topology::Graph;
use crate::WorkerId;

/// Shared engine state exposed to update rules.
pub struct EngineCore {
    /// Communication topology.  Under churn this is the *live* graph:
    /// `TopologyChange` events mutate it in place (with connectivity
    /// repair), so update rules always act on the current topology.
    pub graph: Graph,
    /// Virtual-time event queue.
    pub queue: EventQueue,
    /// Link model.
    pub comm: CommModel,
    /// Pathsearch consensus sets (used by DSGD-AAU).
    pub pathsearch: PathSearch,
    /// Connected-component tracking: engine-level ground truth plus the
    /// lagged observed view update rules consult under partition-aware
    /// adaptivity.  Kept current even in legacy mode (where repair keeps
    /// the graph connected and the monitor stays at one component).
    pub monitor: PartitionMonitor,
    /// Metrics.
    pub recorder: Recorder,
    /// Gossip-iteration counter k.
    pub k: u64,
    adapt: AdaptConfig,
    compute: ComputeModel,
    backend: Box<dyn Backend>,
    params: Vec<ParamVec>,
    stash: Vec<Option<GradOutput>>,
    lr: LrSchedule,
    lr_per_round: bool,
    eval_every: u64,
    pjrt_gossip: bool,
    param_bytes: u64,
    /// Sum/count of recent local losses (coarse progress signal).
    recent_loss: (f64, u64),
    /// Reusable gossip output buffers (swapped with worker params each
    /// round, so the steady-state hot loop performs zero allocation).
    scratch: Vec<ParamVec>,
    /// Cached full-fleet Metropolis weights (synchronous DSGD's per-round
    /// matrix); invalidated whenever the topology changes.
    full_weights: Option<GroupWeights>,
}

impl EngineCore {
    /// Number of workers.
    pub fn num_workers(&self) -> usize {
        self.params.len()
    }

    /// Current virtual time.
    pub fn now(&self) -> f64 {
        self.queue.now()
    }

    /// Immutable view of worker `w`'s parameters.
    pub fn params_of(&self, w: WorkerId) -> &[f32] {
        &self.params[w]
    }

    /// Whether worker `w` has a stashed (un-applied) gradient.
    pub fn has_stash(&self, w: WorkerId) -> bool {
        self.stash[w].is_some()
    }

    /// Whether update rules must retarget to the live component structure
    /// (the `adapt.partition_aware` switch).
    pub fn partition_aware(&self) -> bool {
        self.adapt.partition_aware
    }

    /// Whether topology mutations apply without connectivity repair.
    pub fn partitions_allowed(&self) -> bool {
        self.adapt.partitions_allowed()
    }

    /// Whether an observed component merge restarts the Pathsearch epoch.
    pub fn heal_restart(&self) -> bool {
        self.adapt.heal_restart
    }

    /// Neighbors of `w` that `w` believes reachable: the live-graph
    /// neighbor list, filtered by the observed component view when
    /// partition-aware adaptivity is on (identity filter otherwise).
    /// The sampling pool for AD-PSGD's averaging partner and AGP's
    /// push target.
    pub fn observed_neighbors(&self, w: WorkerId) -> Vec<WorkerId> {
        self.graph
            .neighbors(w)
            .iter()
            .copied()
            .filter(|&r| !self.partition_aware() || self.monitor.same_component_observed(w, r))
            .collect()
    }

    /// Begin a local computation for `w` *now*: the gradient is evaluated
    /// on the current parameters and its completion scheduled after a
    /// sampled compute duration.
    pub fn begin_compute(&mut self, w: WorkerId) {
        let out = self.backend.grad(w, &self.params[w]);
        self.recent_loss.0 += out.loss as f64;
        self.recent_loss.1 += 1;
        self.stash[w] = Some(out);
        let dur = self.compute.sample_duration(w, self.queue.now());
        self.queue.schedule_in(dur, EventKind::ComputeDone(w));
    }

    /// Schedule worker `w` to begin computing after `delay` (e.g. after a
    /// gossip round's communication completes).
    pub fn restart_after(&mut self, w: WorkerId, delay: f64) {
        self.queue.schedule_in(delay, EventKind::ComputeStart(w));
    }

    /// Apply worker `w`'s stashed gradient: `w̃ = w − η(k)·g` (eq. 4 line 1).
    /// No-op if no stash is pending (defensive).
    ///
    /// The schedule follows the paper verbatim by default: `η(k) = η0 δ^k`
    /// indexed by the algorithm's own gossip-iteration counter k.  Setting
    /// `lr_per_round` in the config indexes by normalized rounds
    /// (`local_steps / N`) instead, equalizing decay per unit of gradient
    /// work across iteration semantics (an ablation knob; see DESIGN.md §10).
    pub fn apply_gradient(&mut self, w: WorkerId) {
        if let Some(out) = self.stash[w].take() {
            let idx = if self.lr_per_round {
                self.recorder.local_steps / self.params.len() as u64
            } else {
                self.k
            };
            let lr = self.lr.at(idx);
            crate::model::axpy(&mut self.params[w], -lr, &out.grad);
            self.recorder.local_steps += 1;
        }
    }

    /// Drop worker `w`'s stashed gradient without applying it.
    pub fn discard_stash(&mut self, w: WorkerId) {
        self.stash[w] = None;
    }

    /// Simultaneous consensus update over a gossip group (eq. 4 line 2):
    /// every member's new vector is the weighted average of the group's
    /// current vectors.  Uses the PJRT Pallas gossip kernel when enabled
    /// and the group fits the artifact fanout; falls back to a native
    /// fused loop otherwise.  Charges two parameter messages per active
    /// (positive-weight) pair — the induced-subgraph edges.
    pub fn gossip(&mut self, gw: &GroupWeights) {
        let m = gw.len();
        if m <= 1 {
            return;
        }
        debug_assert!(gw.stochasticity_error() < 1e-4, "non-doubly-stochastic weights");
        self.mix_into_scratch(gw);
        for (a, &mb) in gw.members.iter().enumerate() {
            std::mem::swap(&mut self.params[mb], &mut self.scratch[a]);
        }
        // Parameter messages traverse only active (positive-weight) pairs,
        // bidirectionally — the induced-subgraph edges for Metropolis
        // groups.  Rules with a cheaper collective (Prague's ring
        // all-reduce) use `gossip_costed` instead.
        let bytes = 2 * gw.active_edges() as u64 * self.param_bytes;
        self.recorder.record_gossip(m, bytes);
        self.recorder.note_gossip_components(self.monitor.num_components());
    }

    /// Like [`Self::gossip`] but with an explicit byte charge (collectives
    /// whose traffic is not edge-shaped, e.g. ring all-reduce).
    pub fn gossip_costed(&mut self, gw: &GroupWeights, bytes: u64) {
        let m = gw.len();
        if m <= 1 {
            return;
        }
        debug_assert!(gw.stochasticity_error() < 1e-4, "non-doubly-stochastic weights");
        self.mix_into_scratch(gw);
        for (a, &mb) in gw.members.iter().enumerate() {
            std::mem::swap(&mut self.params[mb], &mut self.scratch[a]);
        }
        self.recorder.record_gossip(m, bytes);
        self.recorder.note_gossip_components(self.monitor.num_components());
    }

    /// Compute every member's weighted average into the scratch buffers
    /// (allocation-free once warm; the PJRT Pallas kernel is used when
    /// enabled and the group fits the artifact fanout).  The member rows
    /// are gathered once per round, not once per member — the per-member
    /// gather made this hot path O(m²) in allocations.
    fn mix_into_scratch(&mut self, gw: &GroupWeights) {
        let m = gw.len();
        let d = self.params[0].len();
        while self.scratch.len() < m {
            self.scratch.push(vec![0f32; d]);
        }
        let rows: Vec<&[f32]> =
            gw.members.iter().map(|&mb| self.params[mb].as_slice()).collect();
        for a in 0..m {
            let weights = &gw.weights[a];
            if self.pjrt_gossip {
                if let Some(out) = self.backend.gossip_average(&rows, weights) {
                    self.scratch[a] = out;
                    continue;
                }
            }
            self.scratch[a].resize(d, 0.0);
            native_weighted_average_into(&rows, weights, &mut self.scratch[a]);
        }
    }

    /// Full-fleet Metropolis consensus round on the *current* graph.  The
    /// weight matrix is cached between rounds and recomputed only after a
    /// topology change (synchronous DSGD previously rebuilt it every
    /// barrier).
    pub fn gossip_all(&mut self) {
        let gw = self.full_weights.take().unwrap_or_else(|| {
            let all: Vec<WorkerId> = (0..self.params.len()).collect();
            GroupWeights::metropolis(&self.graph, &all)
        });
        self.gossip(&gw);
        self.full_weights = Some(gw);
    }

    /// Bookkeeping after a topology mutation batch: invalidate the cached
    /// full-graph Metropolis weights, restore Pathsearch's `P ⊆ E`
    /// invariant, charge the membership broadcast to the control plane
    /// (each applied mutation floods two endpoint IDs, the same O(2N)
    /// accounting as Pathsearch's Remark 4), and update the partition
    /// monitor's ground truth incrementally.  Returns `true` when a
    /// component change must be detected later — the caller schedules one
    /// `PartitionDetect` event per distinct detection latency.
    pub fn on_topology_changed(
        &mut self,
        outcome: ApplyOutcome,
        muts: &[TopologyMutation],
    ) -> bool {
        self.full_weights = None;
        self.pathsearch.prune_missing(&self.graph);
        self.recorder.control_bytes +=
            PathSearch::broadcast_bytes(self.num_workers(), outcome.applied);
        self.recorder.topology_changes += 1;
        self.recorder.mutations_applied += outcome.applied as u64;
        self.recorder.mutations_deferred += outcome.deferred as u64;

        let delta = self.monitor.apply_mutations(&self.graph, muts);
        if !delta.changed() {
            return false;
        }
        self.recorder.partition_splits += delta.splits;
        self.recorder.partition_merges += delta.merges;
        self.recorder.max_components =
            self.recorder.max_components.max(self.monitor.num_components());
        // Even a zero detection latency routes through a PartitionDetect
        // event: promotion then happens at the same timestamp but after
        // the mutation batch, and the update rule's `on_view_changed`
        // hook runs from the event loop, never mid-mutation.
        self.monitor.queue_observation(self.now());
        true
    }

    /// Pairwise average with explicit byte accounting (AD-PSGD's atomic
    /// averaging exchanges exactly two parameter messages).
    pub fn gossip_pair(&mut self, i: WorkerId, j: WorkerId) {
        let gw = GroupWeights::pairwise(i, j);
        self.mix_into_scratch(&gw);
        for (a, &mb) in gw.members.iter().enumerate() {
            std::mem::swap(&mut self.params[mb], &mut self.scratch[a]);
        }
        self.recorder.record_gossip(2, 2 * self.param_bytes);
        self.recorder.note_gossip_components(self.monitor.num_components());
    }

    /// Overwrite worker `w`'s parameters (push-sum style rules).
    pub fn set_params(&mut self, w: WorkerId, v: ParamVec) {
        debug_assert_eq!(v.len(), self.params[w].len());
        self.params[w] = v;
    }

    /// Charge `bytes` of parameter traffic without a group update (AGP
    /// pushes, Pathsearch floods use `recorder.control_bytes`).
    pub fn charge_param_bytes(&mut self, bytes: u64) {
        self.recorder.param_bytes += bytes;
    }

    /// Parameter message size in bytes.
    pub fn param_bytes(&self) -> u64 {
        self.param_bytes
    }

    /// Communication time for a gossip round among `m` workers.
    pub fn gossip_delay(&self, m: usize) -> f64 {
        self.comm.gossip_time(m, self.param_bytes)
    }

    /// Advance the gossip-iteration counter, evaluating on schedule.
    pub fn advance_iteration(&mut self) {
        self.k += 1;
        if self.k % self.eval_every == 0 {
            self.eval_now();
        }
    }

    /// Evaluate the fleet-average parameter vector and record the point.
    /// A repeat call at the same `(k, now)` — e.g. the end-of-run eval
    /// landing on an iteration that already evaluated — is a no-op: the
    /// recorder dedupes, and the backend eval is skipped up front.
    pub fn eval_now(&mut self) {
        let (k, t) = (self.k, self.now());
        if self.recorder.curve.last().map_or(false, |p| p.iteration == k && p.time == t) {
            return;
        }
        let refs: Vec<&[f32]> = self.params.iter().map(|p| p.as_slice()).collect();
        let mean = crate::model::mean_of(&refs);
        let out = self.backend.eval(&mean);
        self.recorder.record_eval(k, t, out.loss, out.accuracy);
    }

    /// Consensus gap `max_j ‖w_j − w̄‖` (Theorem 1 diagnostics).
    pub fn consensus_gap(&self) -> f32 {
        let refs: Vec<&[f32]> = self.params.iter().map(|p| p.as_slice()).collect();
        crate::model::consensus_gap(&refs)
    }

    /// Mean of local losses since the last call (coarse progress signal).
    pub fn drain_recent_loss(&mut self) -> f32 {
        let (s, n) = self.recent_loss;
        self.recent_loss = (0.0, 0);
        if n == 0 {
            f32::NAN
        } else {
            (s / n as f64) as f32
        }
    }

    /// Observed straggler fraction from the compute model.
    pub fn straggler_fraction(&self) -> f64 {
        self.compute.straggler_fraction()
    }

    /// Label of the active straggler process.
    pub fn straggler_process(&self) -> &'static str {
        self.compute.process_name()
    }
}

/// `Σ_b weights[b] · rows[b]` with a flat fused loop (the native gossip).
pub fn native_weighted_average(rows: &[&[f32]], weights: &[f32]) -> ParamVec {
    let mut out = vec![0f32; rows[0].len()];
    native_weighted_average_into(rows, weights, &mut out);
    out
}

/// Allocation-free form of [`native_weighted_average`].  Active rows are
/// gathered first and the inner loop is unrolled two-rows-at-a-time so
/// each pass over `out` consumes two inputs (halves the `out` read/write
/// traffic versus row-by-row axpy; see EXPERIMENTS.md §Perf).
pub fn native_weighted_average_into(rows: &[&[f32]], weights: &[f32], out: &mut [f32]) {
    debug_assert_eq!(rows.len(), weights.len());
    let d = out.len();
    out.fill(0.0);
    let active: Vec<(usize, f32)> = weights
        .iter()
        .enumerate()
        .filter(|(_, &w)| w != 0.0)
        .map(|(i, &w)| (i, w))
        .collect();
    let mut it = active.chunks_exact(2);
    for pair in &mut it {
        let (i0, w0) = pair[0];
        let (i1, w1) = pair[1];
        let (r0, r1) = (rows[i0], rows[i1]);
        debug_assert!(r0.len() == d && r1.len() == d);
        for k in 0..d {
            out[k] += w0 * r0[k] + w1 * r1[k];
        }
    }
    for &(i, w) in it.remainder() {
        let r = rows[i];
        debug_assert_eq!(r.len(), d);
        for k in 0..d {
            out[k] += w * r[k];
        }
    }
}

/// Outcome of a full engine run.
#[derive(Debug)]
pub struct RunSummary {
    /// All recorded metrics.
    pub recorder: Recorder,
    /// Gossip iterations executed.
    pub iterations: u64,
    /// Final virtual time (seconds).
    pub virtual_time: f64,
    /// Observed straggler fraction.
    pub straggler_fraction: f64,
    /// Label of the straggler process that drove the run.
    pub straggler_process: &'static str,
    /// Pathsearch epochs completed (DSGD-AAU only; 0 otherwise).
    pub epochs_completed: u64,
    /// Final consensus gap `max_j ‖w_j − w̄‖`.
    pub consensus_gap: f32,
    /// Algorithm label.
    pub algorithm: &'static str,
}

impl RunSummary {
    /// Final global loss.
    pub fn final_loss(&self) -> f32 {
        self.recorder.final_loss()
    }

    /// Final global accuracy.
    pub fn final_accuracy(&self) -> f32 {
        self.recorder.final_accuracy()
    }
}

/// Event loop driver binding an [`EngineCore`] to an update rule.
pub struct Engine {
    core: EngineCore,
    rule: Box<dyn UpdateRule>,
    churn: ChurnModel,
    max_iterations: u64,
    time_budget: Option<f64>,
    /// Time-based evaluation period (drives `EventKind::EvalTick`).
    eval_every_seconds: Option<f64>,
}

impl Engine {
    /// Assemble an engine from a config and a backend; panics on invalid
    /// configs (tests/benches convenience — [`Self::try_from_config`] is
    /// the fallible form used by the coordinator).
    pub fn from_config(cfg: &ExperimentConfig, backend: Box<dyn Backend>) -> Self {
        Self::try_from_config(cfg, backend)
            .expect("engine config invalid (churn schedule missing or bad parameters)")
    }

    /// Assemble an engine from a config and a backend.
    pub fn try_from_config(
        cfg: &ExperimentConfig,
        backend: Box<dyn Backend>,
    ) -> anyhow::Result<Self> {
        let n = cfg.num_workers;
        let graph = cfg.topology.build(n);
        assert!(graph.is_connected(), "topology must be connected");
        // A trace section replaces both synthetic generators: the lowered
        // straggler timeline drives the compute model and the lowered
        // topology timeline replays through the churn path.
        let lowered = match &cfg.trace {
            Some(tc) => Some(crate::trace::TraceIngest::load(tc)?.lower(n, &graph)?),
            None => None,
        };
        let compute = match &lowered {
            Some(lt) => ComputeModel::with_process(
                n,
                cfg.mean_compute,
                cfg.hetero_sigma,
                cfg.straggler.slowdown,
                Box::new(crate::sim::TraceProcess::from_timeline(&lt.straggler, n)),
                cfg.seed_for("compute"),
            ),
            None => ComputeModel::new(
                n,
                cfg.mean_compute,
                cfg.hetero_sigma,
                &cfg.straggler,
                cfg.seed_for("compute"),
            )?,
        };
        let dim = backend.dim();
        let init = backend.init_params(cfg.seed_for("init"));
        assert_eq!(init.len(), dim);
        let param_bytes = backend.param_bytes();
        let monitor =
            PartitionMonitor::with_latencies(&graph, cfg.adapt.detection_latency.resolve(n)?);
        let mut recorder = Recorder::new();
        recorder.max_components = monitor.num_components();
        let core = EngineCore {
            graph,
            queue: EventQueue::new(),
            comm: cfg.comm,
            pathsearch: PathSearch::new(),
            monitor,
            recorder,
            k: 0,
            adapt: cfg.adapt.clone(),
            compute,
            backend,
            params: vec![init; n],
            stash: vec![None; n],
            lr: cfg.lr,
            lr_per_round: cfg.lr_per_round,
            eval_every: cfg.eval_every.max(1),
            pjrt_gossip: cfg.pjrt_gossip,
            param_bytes,
            recent_loss: (0.0, 0),
            scratch: Vec::new(),
            full_weights: None,
        };
        let rule = cfg.algorithm.build(cfg.prague_group, cfg.seed_for("algorithm"));
        let churn = match lowered {
            Some(lt) => ChurnModel::replay(lt.topology),
            None => ChurnModel::from_config(&cfg.churn, n, cfg.seed_for("churn"))?,
        };
        Ok(Engine {
            core,
            rule,
            churn,
            max_iterations: cfg.max_iterations,
            time_budget: cfg.time_budget,
            eval_every_seconds: cfg.eval_every_seconds,
        })
    }

    /// Read-only core access (tests/diagnostics).
    pub fn core(&self) -> &EngineCore {
        &self.core
    }

    /// Run to completion (iteration cap, time budget, or quiescence).
    pub fn run(&mut self) -> RunSummary {
        let n = self.core.num_workers();
        for w in 0..n {
            self.core.begin_compute(w);
        }
        self.rule.on_start(&mut self.core);
        self.core.eval_now(); // k = 0 baseline point
        if let Some(t) = self.churn.next_change() {
            self.core.queue.schedule(t, EventKind::TopologyChange);
        }
        if let Some(dt) = self.eval_every_seconds {
            self.core.queue.schedule(dt, EventKind::EvalTick);
        }
        while let Some(Event { kind, .. }) = self.core.queue.pop() {
            match kind {
                EventKind::ComputeStart(w) => self.core.begin_compute(w),
                EventKind::ComputeDone(w) => self.rule.on_ready(w, &mut self.core),
                EventKind::EvalTick => {
                    self.core.eval_now();
                    // re-arm only while other activity is pending so a
                    // quiescing run cannot be kept alive by its own ticks
                    if let Some(dt) = self.eval_every_seconds {
                        if !self.core.queue.is_empty() {
                            self.core.queue.schedule_in(dt, EventKind::EvalTick);
                        }
                    }
                }
                EventKind::TopologyChange => {
                    let now = self.core.queue.now();
                    let muts = self.churn.step(now, &self.core.graph);
                    if !muts.is_empty() {
                        let outcome = if self.core.partitions_allowed() {
                            churn::apply_mutations_unrepaired(&mut self.core.graph, &muts)
                        } else {
                            let outcome = churn::apply_mutations(&mut self.core.graph, &muts);
                            debug_assert!(
                                self.core.graph.is_connected(),
                                "connectivity repair failed at t={now}"
                            );
                            outcome
                        };
                        if self.core.on_topology_changed(outcome, &muts) {
                            // One detect wake-up per distinct latency, so
                            // each worker's adoption instant gets a
                            // `PartitionDetect` event even when detectors
                            // are heterogeneous.
                            for latency in self.core.monitor.distinct_latencies() {
                                self.core
                                    .queue
                                    .schedule_in(latency, EventKind::PartitionDetect);
                            }
                        }
                    }
                    if let Some(t) = self.churn.next_change() {
                        self.core.queue.schedule(t, EventKind::TopologyChange);
                    }
                }
                EventKind::PartitionDetect => {
                    let now = self.core.queue.now();
                    let delta = self.core.monitor.promote_due(now);
                    if delta.changed() {
                        // Waiting sets may already satisfy their new
                        // (smaller or merged) components — fire them now.
                        self.rule.on_view_changed(&mut self.core);
                    }
                }
            }
            if self.core.k >= self.max_iterations {
                break;
            }
            if let Some(budget) = self.time_budget {
                if self.core.now() >= budget {
                    break;
                }
            }
        }
        // Final curve point.  When the last event already evaluated at
        // this exact (k, t) the recorder drops the duplicate, so CSV
        // output and bytes_to_accuracy see each point once.
        self.core.eval_now();
        RunSummary {
            iterations: self.core.k,
            virtual_time: self.core.now(),
            straggler_fraction: self.core.straggler_fraction(),
            straggler_process: self.core.straggler_process(),
            epochs_completed: self.core.pathsearch.epochs_completed,
            consensus_gap: self.core.consensus_gap(),
            algorithm: self.rule.name(),
            recorder: std::mem::take(&mut self.core.recorder),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn native_weighted_average_basics() {
        let a = vec![1.0f32, 0.0];
        let b = vec![0.0f32, 1.0];
        let out = native_weighted_average(&[&a, &b], &[0.25, 0.75]);
        assert_eq!(out, vec![0.25, 0.75]);
    }

    #[test]
    fn zero_weight_skipped() {
        let a = vec![f32::NAN, f32::NAN];
        let b = vec![2.0f32, 4.0];
        // NaN row has zero weight and must not poison the result
        let out = native_weighted_average(&[&a, &b], &[0.0, 1.0]);
        assert_eq!(out, vec![2.0, 4.0]);
    }
}
