//! The decentralized training engine: event loop + shared mechanics.
//!
//! [`EngineCore`] owns worker parameters, gradient stashes, the virtual
//! clock, the consensus/Pathsearch state and all accounting; an
//! [`UpdateRule`](crate::algorithms::UpdateRule) reacts to compute-done
//! events and drives gossip through the core's primitives.  Gradient
//! *values* are real (produced by the [`Backend`]); *durations* come from
//! the [`ComputeModel`] so straggler dynamics match the paper's testbed.

use crate::adapt::{AdaptConfig, PartitionMonitor};
use crate::algorithms::UpdateRule;
use crate::backend::{Backend, GradOutput};
use crate::churn::{self, ApplyOutcome, ChurnModel, TopologyMutation};
use crate::config::{ExperimentConfig, LrSchedule};
use crate::consensus::GroupWeights;
use crate::fragment::{quantize_f16, FragmentState, ShardPlan};
use crate::membership::MembershipModel;
use crate::metrics::Recorder;
use crate::model::ParamVec;
use crate::pathsearch::PathSearch;
use crate::sim::{CommModel, ComputeModel, Event, EventKind, EventQueue};
use crate::stale::StaleState;
use crate::topology::Graph;
use crate::WorkerId;
use std::collections::BTreeMap;

/// Shared engine state exposed to update rules.
pub struct EngineCore {
    /// Communication topology.  Under churn this is the *live* graph:
    /// `TopologyChange` events mutate it in place (with connectivity
    /// repair), so update rules always act on the current topology.
    pub graph: Graph,
    /// Virtual-time event queue.
    pub queue: EventQueue,
    /// Link model.
    pub comm: CommModel,
    /// Pathsearch consensus sets (used by DSGD-AAU).
    pub pathsearch: PathSearch,
    /// Connected-component tracking: engine-level ground truth plus the
    /// lagged observed view update rules consult under partition-aware
    /// adaptivity.  Kept current even in legacy mode (where repair keeps
    /// the graph connected and the monitor stays at one component).
    pub monitor: PartitionMonitor,
    /// Metrics.
    pub recorder: Recorder,
    /// Gossip-iteration counter k.
    pub k: u64,
    /// Bounded-staleness scheduling state (`stale` config section):
    /// per-worker iteration clocks, per-directed-link token queues, and
    /// the parked-worker table.  Inert unless the update rule drives it
    /// (only `hop_bss` does today).
    pub stale: StaleState,
    adapt: AdaptConfig,
    compute: ComputeModel,
    /// OS threads for intra-cell gradient batches (`compute_threads`
    /// config knob, already resolved: `0 = auto` became the detected
    /// parallelism).  Purely a wall-clock lever — `begin_compute_batch`
    /// commits results in drain order whatever this is, so metrics are
    /// byte-identical across values (the determinism suite sweeps it).
    compute_threads: usize,
    backend: Box<dyn Backend>,
    params: Vec<ParamVec>,
    stash: Vec<Option<GradOutput>>,
    lr: LrSchedule,
    lr_per_round: bool,
    eval_every: u64,
    pjrt_gossip: bool,
    param_bytes: u64,
    /// Sum/count of recent local losses (coarse progress signal).
    recent_loss: (f64, u64),
    /// Reusable gossip output buffers (swapped with worker params each
    /// round, so the steady-state hot loop performs zero allocation).
    scratch: Vec<ParamVec>,
    /// Cached full-fleet Metropolis weights (synchronous DSGD's per-round
    /// matrix); invalidated whenever churn mutates the topology.  Under
    /// open-world membership the cache is instead maintained
    /// *incrementally*: join/leave recomputes only the touched rows
    /// (`GroupWeights::refresh_rows`), never the whole matrix.
    full_weights: Option<GroupWeights>,
    /// Per-slot occupancy under open-world membership (all `true` in the
    /// closed-world default, so every guard below is a no-op there).
    active: Vec<bool>,
    /// Exact scheduled completion time of each slot's in-flight compute;
    /// NaN when idle.  A popped `ComputeDone` is accepted only when its
    /// timestamp equals this bitwise — vacating a slot cancels the
    /// in-flight gradient by resetting the entry to NaN, so a stale
    /// completion from a previous occupant can never fire for a joiner.
    expected_done: Vec<f64>,
    /// Sharded-gossip bookkeeping (`fragments` config section): shard
    /// bounds, per-worker per-shard version counters and the scheduler.
    /// Passthrough state (the `count = 1`, `f32` default) routes every
    /// gossip through the exact legacy full-vector path.
    fragments: FragmentState,
    /// Wire bytes of one point-to-point message in the most recent
    /// gossip round (= `param_bytes` in passthrough; the scheduled
    /// shard's cost otherwise).  Update rules derive communication
    /// delays from this so a shard exchange is also *faster*, not just
    /// cheaper on the byte meter.
    last_wire_bytes: u64,
}

impl EngineCore {
    /// Number of workers.
    pub fn num_workers(&self) -> usize {
        self.params.len()
    }

    /// Current virtual time.
    pub fn now(&self) -> f64 {
        self.queue.now()
    }

    /// Immutable view of worker `w`'s parameters.
    pub fn params_of(&self, w: WorkerId) -> &[f32] {
        &self.params[w]
    }

    /// Whether worker `w` has a stashed (un-applied) gradient.
    pub fn has_stash(&self, w: WorkerId) -> bool {
        self.stash[w].is_some()
    }

    /// Whether slot `w` currently holds an active worker (always true in
    /// closed-world runs without a `membership` section).
    pub fn is_active(&self, w: WorkerId) -> bool {
        self.active[w]
    }

    /// Number of occupied slots.
    pub fn num_active(&self) -> usize {
        self.active.iter().filter(|a| **a).count()
    }

    /// Dispatch guard for `ComputeStart(w)`: a vacant slot must not start
    /// computing, and a slot with an in-flight gradient must not stack a
    /// second one (a pre-departure restart racing a later join).  Always
    /// true in closed-world runs — each worker's lifecycle is strictly
    /// start → done → restart there.
    pub fn can_start(&self, w: WorkerId) -> bool {
        self.active[w] && self.expected_done[w].is_nan()
    }

    /// Dispatch guard for `ComputeDone(w)`: accept only the completion
    /// whose timestamp matches the scheduled one bitwise, then mark the
    /// slot idle.  Cancelled computes (the slot was vacated mid-flight)
    /// and completions of a previous occupant fail the match and are
    /// dropped.  O(1) per event — membership dispatch never scans slots.
    pub fn accept_done(&mut self, w: WorkerId) -> bool {
        if self.expected_done[w].to_bits() == self.queue.now().to_bits() {
            self.expected_done[w] = f64::NAN;
            true
        } else {
            false
        }
    }

    /// Max row/column-sum deviation from 1 of the cached full-fleet
    /// Metropolis matrix (`None` when no matrix is cached).  Under
    /// open-world membership the matrix is maintained incrementally
    /// across join/leave, so this is the doubly-stochasticity invariant
    /// the membership tests gate on.
    pub fn full_weights_stochastic_error(&self) -> Option<f32> {
        self.full_weights.as_ref().map(GroupWeights::stochasticity_error)
    }

    /// Whether the incrementally maintained full-fleet Metropolis matrix
    /// is bitwise identical to a from-scratch rebuild over all slots on
    /// the live graph (`None` when no matrix is cached).
    pub fn full_weights_match_rebuild(&self) -> Option<bool> {
        self.full_weights.as_ref().map(|gw| {
            let all: Vec<WorkerId> = (0..self.graph.num_vertices()).collect();
            let fresh = GroupWeights::metropolis(&self.graph, &all);
            gw.members == fresh.members
                && gw.weights.len() == fresh.weights.len()
                && gw
                    .weights
                    .iter()
                    .zip(&fresh.weights)
                    .all(|(a, b)| {
                        a.len() == b.len()
                            && a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits())
                    })
        })
    }

    /// Whether update rules must retarget to the live component structure
    /// (the `adapt.partition_aware` switch).
    pub fn partition_aware(&self) -> bool {
        self.adapt.partition_aware
    }

    /// Whether topology mutations apply without connectivity repair.
    pub fn partitions_allowed(&self) -> bool {
        self.adapt.partitions_allowed()
    }

    /// Whether an observed component merge restarts the Pathsearch epoch.
    pub fn heal_restart(&self) -> bool {
        self.adapt.heal_restart
    }

    /// Neighbors of `w` that `w` believes reachable: the live-graph
    /// neighbor list, filtered by the observed component view when
    /// partition-aware adaptivity is on (identity filter otherwise).
    /// The sampling pool for AD-PSGD's averaging partner and AGP's
    /// push target.
    pub fn observed_neighbors(&self, w: WorkerId) -> Vec<WorkerId> {
        self.graph
            .neighbors(w)
            .iter()
            .copied()
            .filter(|&r| !self.partition_aware() || self.monitor.same_component_observed(w, r))
            .collect()
    }

    /// Begin a local computation for `w` *now*: the gradient is evaluated
    /// on the current parameters and its completion scheduled after a
    /// sampled compute duration.
    pub fn begin_compute(&mut self, w: WorkerId) {
        let out = self.backend.grad(w, &self.params[w]);
        self.commit_grad(w, out);
    }

    /// Begin local computations for every worker in `ws`, in order.
    ///
    /// Byte-identical to calling [`begin_compute`] for each worker in
    /// turn: the backend's `grad_batch` contract guarantees the outputs
    /// match sequential `grad` calls (any internal parallelism
    /// notwithstanding), and the commit loop below then applies them —
    /// and draws each compute duration from the shared straggler RNG —
    /// serially in the same input order.  The engine's parallel
    /// intra-cell stepping is therefore invisible to metrics: only
    /// wall-clock changes with `compute_threads`.
    ///
    /// [`begin_compute`]: EngineCore::begin_compute
    pub fn begin_compute_batch(&mut self, ws: &[WorkerId]) {
        if ws.len() <= 1 {
            if let Some(&w) = ws.first() {
                self.begin_compute(w);
            }
            return;
        }
        let outs = {
            let views: Vec<&[f32]> = ws.iter().map(|&w| self.params[w].as_slice()).collect();
            self.backend.grad_batch(ws, &views, self.compute_threads)
        };
        debug_assert_eq!(outs.len(), ws.len());
        for (&w, out) in ws.iter().zip(outs) {
            self.commit_grad(w, out);
        }
    }

    /// Serial tail of a compute start: record the loss, stash the
    /// gradient, and schedule the completion.  Draws from the shared
    /// straggler RNG, so callers must invoke it in worker input order.
    fn commit_grad(&mut self, w: WorkerId, out: GradOutput) {
        self.recent_loss.0 += out.loss as f64;
        self.recent_loss.1 += 1;
        self.stash[w] = Some(out);
        let dur = self.compute.sample_duration(w, self.queue.now());
        // identical float expression to EventQueue::schedule_in, so the
        // popped event time matches bitwise in accept_done
        self.expected_done[w] = self.queue.now() + dur;
        self.queue.schedule_in(dur, EventKind::ComputeDone(w));
    }

    /// Schedule worker `w` to begin computing after `delay` (e.g. after a
    /// gossip round's communication completes).
    pub fn restart_after(&mut self, w: WorkerId, delay: f64) {
        self.queue.schedule_in(delay, EventKind::ComputeStart(w));
    }

    /// Apply worker `w`'s stashed gradient: `w̃ = w − η(k)·g` (eq. 4 line 1).
    /// No-op if no stash is pending (defensive).
    ///
    /// The schedule follows the paper verbatim by default: `η(k) = η0 δ^k`
    /// indexed by the algorithm's own gossip-iteration counter k.  Setting
    /// `lr_per_round` in the config indexes by normalized rounds
    /// (`local_steps / N`) instead, equalizing decay per unit of gradient
    /// work across iteration semantics (an ablation knob; see DESIGN.md §10).
    pub fn apply_gradient(&mut self, w: WorkerId) {
        if let Some(out) = self.stash[w].take() {
            let idx = if self.lr_per_round {
                self.recorder.local_steps / self.params.len() as u64
            } else {
                self.k
            };
            let lr = self.lr.at(idx);
            crate::model::axpy(&mut self.params[w], -lr, &out.grad);
            self.recorder.local_steps += 1;
        }
    }

    /// Drop worker `w`'s stashed gradient without applying it.
    pub fn discard_stash(&mut self, w: WorkerId) {
        self.stash[w] = None;
    }

    /// Simultaneous consensus update over a gossip group (eq. 4 line 2):
    /// every member's new vector is the weighted average of the group's
    /// current vectors — of the scheduled shard range only when the
    /// `fragments` section configures sharded exchange.  Uses the PJRT
    /// Pallas gossip kernel when enabled, the group fits the artifact
    /// fanout and the exchange is full-vector; falls back to a native
    /// fused loop otherwise.  Charges two parameter messages per active
    /// (positive-weight) pair — the induced-subgraph edges.  Empty and
    /// singleton groups return without moving (or charging) anything.
    pub fn gossip(&mut self, gw: &GroupWeights) {
        if gw.is_empty() || gw.is_singleton() {
            return;
        }
        debug_assert!(gw.stochasticity_error() < 1e-4, "non-doubly-stochastic weights");
        // Parameter messages traverse only active (positive-weight) pairs,
        // bidirectionally — the induced-subgraph edges for Metropolis
        // groups.  Rules with a cheaper collective (Prague's ring
        // all-reduce) use `gossip_costed` instead.
        let messages = 2 * gw.active_edges() as u64;
        self.gossip_with_messages(gw, messages);
    }

    /// Like [`Self::gossip`] but with an explicit message count
    /// (collectives whose traffic is not edge-shaped, e.g. Prague's ring
    /// all-reduce at `2(m−1)` messages).  Each message is charged at the
    /// round's wire size: the full vector in passthrough, the scheduled
    /// shard under fragmentation.
    pub fn gossip_costed(&mut self, gw: &GroupWeights, messages: u64) {
        if gw.is_empty() || gw.is_singleton() {
            return;
        }
        debug_assert!(gw.stochasticity_error() < 1e-4, "non-doubly-stochastic weights");
        self.gossip_with_messages(gw, messages);
    }

    /// Shared gossip body: mix, write back, account `messages` transfers.
    ///
    /// Passthrough (the default `fragments` config) is the exact legacy
    /// full-vector path — scratch swap, PJRT kernel eligibility,
    /// `messages · param_bytes` on the byte meter — and stays
    /// bit-identical to builds without fragmentation.  Otherwise the
    /// scheduler picks one shard, the consensus weights apply to that
    /// contiguous range only (through a simulated `f16` wire round-trip
    /// when configured), and each message is charged at the shard's wire
    /// size, with the savings and retired staleness recorded.
    fn gossip_with_messages(&mut self, gw: &GroupWeights, messages: u64) {
        let m = gw.len();
        if self.fragments.is_passthrough() {
            self.mix_into_scratch(gw);
            for (a, &mb) in gw.members.iter().enumerate() {
                std::mem::swap(&mut self.params[mb], &mut self.scratch[a]);
            }
            self.last_wire_bytes = self.param_bytes;
            self.recorder.record_gossip(m, messages * self.param_bytes);
            self.recorder.note_gossip_components(self.monitor.num_components());
            return;
        }
        let plan = self.fragments.next_plan(&gw.members);
        self.mix_range_into_scratch(gw, plan.lo, plan.hi);
        let w = plan.hi - plan.lo;
        for (a, &mb) in gw.members.iter().enumerate() {
            self.params[mb][plan.lo..plan.hi].copy_from_slice(&self.scratch[a][..w]);
        }
        self.last_wire_bytes = plan.wire_bytes;
        self.recorder.record_gossip(m, messages * plan.wire_bytes);
        self.recorder.shard_bytes_saved +=
            messages * self.param_bytes.saturating_sub(plan.wire_bytes);
        self.recorder.shard_staleness += plan.staleness;
        self.recorder.note_gossip_components(self.monitor.num_components());
    }

    /// Weighted-average the members' `[lo, hi)` parameter ranges into the
    /// scratch buffer prefixes (the fragmented-gossip mix).  Under `f16`
    /// wire encoding every input row — including each member's own —
    /// round-trips through binary16 first: what a member mixes is what
    /// the wire delivered.  The PJRT gossip kernel is full-vector only,
    /// so shard mixes always take the native loop.
    fn mix_range_into_scratch(&mut self, gw: &GroupWeights, lo: usize, hi: usize) {
        let m = gw.len();
        let d = self.params[0].len();
        let w = hi - lo;
        while self.scratch.len() < m {
            self.scratch.push(vec![0f32; d]);
        }
        let quantized: Option<Vec<Vec<f32>>> = self.fragments.quantize_wire().then(|| {
            gw.members
                .iter()
                .map(|&mb| self.params[mb][lo..hi].iter().copied().map(quantize_f16).collect())
                .collect()
        });
        let rows: Vec<&[f32]> = match &quantized {
            Some(q) => q.iter().map(|r| r.as_slice()).collect(),
            None => gw.members.iter().map(|&mb| &self.params[mb][lo..hi]).collect(),
        };
        for a in 0..m {
            self.scratch[a].resize(d, 0.0);
            native_weighted_average_into(&rows, &gw.weights[a], &mut self.scratch[a][..w]);
        }
    }

    /// Compute every member's weighted average into the scratch buffers
    /// (allocation-free once warm; the PJRT Pallas kernel is used when
    /// enabled and the group fits the artifact fanout).  The member rows
    /// are gathered once per round, not once per member — the per-member
    /// gather made this hot path O(m²) in allocations.
    fn mix_into_scratch(&mut self, gw: &GroupWeights) {
        let m = gw.len();
        let d = self.params[0].len();
        while self.scratch.len() < m {
            self.scratch.push(vec![0f32; d]);
        }
        let rows: Vec<&[f32]> =
            gw.members.iter().map(|&mb| self.params[mb].as_slice()).collect();
        for a in 0..m {
            let weights = &gw.weights[a];
            if self.pjrt_gossip {
                if let Some(out) = self.backend.gossip_average(&rows, weights) {
                    self.scratch[a] = out;
                    continue;
                }
            }
            self.scratch[a].resize(d, 0.0);
            native_weighted_average_into(&rows, weights, &mut self.scratch[a]);
        }
    }

    /// Full-fleet Metropolis consensus round on the *current* graph.  The
    /// weight matrix is cached between rounds and recomputed only after a
    /// topology change (synchronous DSGD previously rebuilt it every
    /// barrier).
    pub fn gossip_all(&mut self) {
        let gw = self.full_weights.take().unwrap_or_else(|| {
            let all: Vec<WorkerId> = (0..self.params.len()).collect();
            GroupWeights::metropolis(&self.graph, &all)
        });
        self.gossip(&gw);
        self.full_weights = Some(gw);
    }

    /// Bookkeeping after a topology mutation batch: invalidate the cached
    /// full-graph Metropolis weights, restore Pathsearch's `P ⊆ E`
    /// invariant, charge the membership broadcast to the control plane
    /// (each applied mutation floods two endpoint IDs, the same O(2N)
    /// accounting as Pathsearch's Remark 4), and update the partition
    /// monitor's ground truth incrementally.  Returns `true` when a
    /// component change must be detected later — the caller schedules one
    /// `PartitionDetect` event per distinct detection latency.
    pub fn on_topology_changed(
        &mut self,
        outcome: ApplyOutcome,
        muts: &[TopologyMutation],
    ) -> bool {
        self.full_weights = None;
        self.pathsearch.prune_missing(&self.graph);
        self.recorder.control_bytes +=
            PathSearch::broadcast_bytes(self.num_workers(), outcome.applied);
        self.recorder.topology_changes += 1;
        self.recorder.mutations_applied += outcome.applied as u64;
        self.recorder.mutations_deferred += outcome.deferred as u64;

        let delta = self.monitor.apply_mutations(&self.graph, muts);
        if !delta.changed() {
            return false;
        }
        self.recorder.partition_splits += delta.splits;
        self.recorder.partition_merges += delta.merges;
        self.recorder.max_components =
            self.recorder.max_components.max(self.monitor.num_components());
        // Even a zero detection latency routes through a PartitionDetect
        // event: promotion then happens at the same timestamp but after
        // the mutation batch, and the update rule's `on_view_changed`
        // hook runs from the event loop, never mid-mutation.
        self.monitor.queue_observation(self.now());
        true
    }

    /// Pairwise average with explicit message accounting (AD-PSGD's
    /// atomic averaging exchanges exactly two parameter messages).
    pub fn gossip_pair(&mut self, i: WorkerId, j: WorkerId) {
        let gw = GroupWeights::pairwise(i, j);
        self.gossip_with_messages(&gw, 2);
    }

    /// Overwrite worker `w`'s parameters (push-sum style rules).
    pub fn set_params(&mut self, w: WorkerId, v: ParamVec) {
        debug_assert_eq!(v.len(), self.params[w].len());
        self.params[w] = v;
    }

    /// Charge `bytes` of parameter traffic without a group update (AGP
    /// pushes, Pathsearch floods use `recorder.control_bytes`).
    pub fn charge_param_bytes(&mut self, bytes: u64) {
        self.recorder.param_bytes += bytes;
    }

    /// Full-vector parameter message size in bytes.
    pub fn param_bytes(&self) -> u64 {
        self.param_bytes
    }

    /// Wire bytes of one message in the most recent gossip round: the
    /// full vector in passthrough, the scheduled shard's cost under
    /// fragmentation.  Update rules compute post-gossip communication
    /// delays from this.
    pub fn round_wire_bytes(&self) -> u64 {
        self.last_wire_bytes
    }

    /// Communication time for the most recent gossip round among `m`
    /// workers (sized by [`Self::round_wire_bytes`], so a shard exchange
    /// is proportionally faster than a full-vector one).
    pub fn gossip_delay(&self, m: usize) -> f64 {
        self.comm.gossip_time(m, self.last_wire_bytes)
    }

    /// Schedule the shard a point-to-point push among `members` moves
    /// (AGP's push path).  Passthrough returns a full-vector pseudo-plan
    /// without touching the scheduler state, so default configs stay
    /// bit-identical to the pre-fragmentation engine.
    pub fn fragment_plan(&mut self, members: &[WorkerId]) -> ShardPlan {
        if self.fragments.is_passthrough() {
            ShardPlan {
                shard: 0,
                lo: 0,
                hi: self.params[0].len(),
                wire_bytes: self.param_bytes,
                staleness: 0,
            }
        } else {
            self.fragments.next_plan(members)
        }
    }

    /// What `w` puts on the wire for `plan`'s range: the raw range in
    /// `f32` mode, the binary16 round-trip of it under `f16` encoding.
    pub fn wire_slice(&self, w: WorkerId, plan: &ShardPlan) -> ParamVec {
        let range = &self.params[w][plan.lo..plan.hi];
        if self.fragments.quantize_wire() {
            range.iter().copied().map(quantize_f16).collect()
        } else {
            range.to_vec()
        }
    }

    /// Charge one point-to-point transfer of `plan`'s shard (AGP pushes;
    /// group rounds account inside [`Self::gossip`]).  In passthrough
    /// this is exactly the legacy `charge_param_bytes(param_bytes())`.
    pub fn charge_shard_transfer(&mut self, plan: &ShardPlan) {
        self.recorder.param_bytes += plan.wire_bytes;
        self.recorder.shard_bytes_saved += self.param_bytes.saturating_sub(plan.wire_bytes);
        self.recorder.shard_staleness += plan.staleness;
        self.last_wire_bytes = plan.wire_bytes;
    }

    /// Advance the gossip-iteration counter, evaluating on schedule.
    pub fn advance_iteration(&mut self) {
        self.k += 1;
        if self.k % self.eval_every == 0 {
            self.eval_now();
        }
    }

    /// Evaluate the fleet-average parameter vector and record the point.
    /// A repeat call at the same `(k, now)` — e.g. the end-of-run eval
    /// landing on an iteration that already evaluated — is a no-op: the
    /// recorder dedupes, and the backend eval is skipped up front.
    pub fn eval_now(&mut self) {
        let (k, t) = (self.k, self.now());
        if self.recorder.curve.last().map_or(false, |p| p.iteration == k && p.time == t) {
            return;
        }
        // Evaluate the mean over *occupied* slots only: vacant slots hold
        // retired parameters that no live worker owns (identity filter in
        // closed-world runs).
        let refs: Vec<&[f32]> = self
            .params
            .iter()
            .zip(&self.active)
            .filter(|(_, &a)| a)
            .map(|(p, _)| p.as_slice())
            .collect();
        let mean = crate::model::mean_of(&refs);
        let out = self.backend.eval(&mean);
        self.recorder.record_eval(k, t, out.loss, out.accuracy);
    }

    /// Consensus gap `max_j ‖w_j − w̄‖` over occupied slots (Theorem 1
    /// diagnostics).
    pub fn consensus_gap(&self) -> f32 {
        let refs: Vec<&[f32]> = self
            .params
            .iter()
            .zip(&self.active)
            .filter(|(_, &a)| a)
            .map(|(p, _)| p.as_slice())
            .collect();
        crate::model::consensus_gap(&refs)
    }

    /// Stage the monitor ground truth after a membership slot mutation
    /// and schedule its delayed adoption — the same split/merge
    /// bookkeeping as [`Self::on_topology_changed`], minus the churn
    /// counters (membership changes are not churn events).
    fn note_membership_mutation(&mut self, muts: &[TopologyMutation]) {
        let delta = self.monitor.apply_mutations(&self.graph, muts);
        if !delta.changed() {
            return;
        }
        self.recorder.partition_splits += delta.splits;
        self.recorder.partition_merges += delta.merges;
        self.recorder.max_components =
            self.recorder.max_components.max(self.monitor.num_components());
        self.monitor.queue_observation(self.now());
        for latency in self.monitor.distinct_latencies() {
            self.queue.schedule_in(latency, EventKind::PartitionDetect);
        }
    }

    /// Vacate slot `s` (open-world membership): cancel its in-flight
    /// compute, drop its stashed gradient, retire its parameters with it
    /// (they stay in the buffer but leave the eval/consensus mean),
    /// isolate it in the graph, prune Pathsearch, refresh only the
    /// touched Metropolis rows, and stage the monitor observation.
    /// O(active-degree neighborhood), never O(n) beyond the slot vectors.
    fn vacate_slot(&mut self, s: WorkerId) {
        debug_assert!(self.active[s], "vacating already-vacant slot {s}");
        self.active[s] = false;
        self.expected_done[s] = f64::NAN;
        self.stash[s] = None;
        let old_nbrs: Vec<WorkerId> = self.graph.neighbors(s).to_vec();
        self.graph.remove_vertex(s);
        self.pathsearch.prune_missing(&self.graph);
        self.pathsearch.reset_component(&[s]);
        // leave announcement: one id pair flooded, same O(2N) accounting
        // as the churn/Pathsearch broadcasts
        self.recorder.control_bytes += PathSearch::broadcast_bytes(self.num_workers(), 1);
        if let Some(gw) = self.full_weights.as_mut() {
            // rows with a changed induced degree ({s} ∪ N(s)) plus their
            // neighbors, whose entries reference those degrees
            let mut touched = vec![s];
            touched.extend(&old_nbrs);
            for &x in &old_nbrs {
                touched.extend(self.graph.neighbors(x));
            }
            gw.refresh_rows(&self.graph, &touched);
        }
        self.note_membership_mutation(&[TopologyMutation::Isolate(s)]);
    }

    /// Fill vacant slot `s` (open-world membership): re-wire its template
    /// edges toward currently active peers, warm-start its parameters
    /// from the neighbor average of the inherited slot (the caller passes
    /// the fleet-init fallback for a joiner with no reachable neighbor),
    /// charge the warm-start pulls, refresh only the touched Metropolis
    /// rows, and stage the monitor observation.  Returns the attach
    /// targets.  The caller starts the joiner's compute afterwards.
    ///
    /// The warm-start average is scoped to one observed component: mid-
    /// heal, the template neighbors can straddle a partition, and a plain
    /// mean of both sides would seed the joiner with a vector no live
    /// component trained (dragging both components' consensus toward the
    /// blend).  The joiner averages — and pays for — only the largest
    /// coherent cohort of its targets (ties break toward the cohort
    /// holding the lowest worker id); the remaining targets still get
    /// their edges and converge through normal gossip.
    fn fill_slot(&mut self, s: WorkerId, template: &Graph, init: &ParamVec) -> Vec<WorkerId> {
        debug_assert!(!self.active[s], "filling occupied slot {s}");
        let targets: Vec<WorkerId> =
            template.neighbors(s).iter().copied().filter(|&x| self.active[x]).collect();
        for &t in &targets {
            self.graph.add_edge(s, t);
        }
        self.active[s] = true;
        // Cohorts keyed by observed component label (pre-attach view):
        // BTreeMap order makes "lowest worker id" the first max-length
        // entry, so the pick is deterministic.
        let mut cohorts: BTreeMap<usize, Vec<WorkerId>> = BTreeMap::new();
        for &t in &targets {
            cohorts.entry(self.monitor.component_of(t)).or_default().push(t);
        }
        let cohort: &[WorkerId] = cohorts
            .values()
            .max_by_key(|members| (members.len(), std::cmp::Reverse(members[0])))
            .map(Vec::as_slice)
            .unwrap_or(&[]);
        self.params[s] = if cohort.is_empty() {
            init.clone()
        } else {
            let rows: Vec<&[f32]> = cohort.iter().map(|&t| self.params[t].as_slice()).collect();
            crate::model::mean_of(&rows)
        };
        // a fresh full vector is current on every shard
        self.fragments.reset_worker(s);
        // warm start pulls one parameter message per averaged cohort
        // member, plus the join announcement on the control plane
        self.recorder.param_bytes += cohort.len() as u64 * self.param_bytes;
        self.recorder.control_bytes += PathSearch::broadcast_bytes(self.num_workers(), 1);
        if let Some(gw) = self.full_weights.as_mut() {
            let mut touched = vec![s];
            touched.extend(&targets);
            for &x in &targets {
                touched.extend(self.graph.neighbors(x));
            }
            gw.refresh_rows(&self.graph, &touched);
        }
        self.note_membership_mutation(&[TopologyMutation::Attach(s, targets.clone())]);
        targets
    }

    /// Mean of local losses since the last call (coarse progress signal).
    pub fn drain_recent_loss(&mut self) -> f32 {
        let (s, n) = self.recent_loss;
        self.recent_loss = (0.0, 0);
        if n == 0 {
            f32::NAN
        } else {
            (s / n as f64) as f32
        }
    }

    /// Observed straggler fraction from the compute model.
    pub fn straggler_fraction(&self) -> f64 {
        self.compute.straggler_fraction()
    }

    /// Label of the active straggler process.
    pub fn straggler_process(&self) -> &'static str {
        self.compute.process_name()
    }
}

/// `Σ_b weights[b] · rows[b]` with a flat fused loop (the native gossip).
pub fn native_weighted_average(rows: &[&[f32]], weights: &[f32]) -> ParamVec {
    let mut out = vec![0f32; rows[0].len()];
    native_weighted_average_into(rows, weights, &mut out);
    out
}

/// Allocation-free form of [`native_weighted_average`].  Active rows are
/// gathered first and the inner loop is unrolled two-rows-at-a-time so
/// each pass over `out` consumes two inputs (halves the `out` read/write
/// traffic versus row-by-row axpy; see EXPERIMENTS.md §Perf).
pub fn native_weighted_average_into(rows: &[&[f32]], weights: &[f32], out: &mut [f32]) {
    debug_assert_eq!(rows.len(), weights.len());
    let d = out.len();
    out.fill(0.0);
    let active: Vec<(usize, f32)> = weights
        .iter()
        .enumerate()
        .filter(|(_, &w)| w != 0.0)
        .map(|(i, &w)| (i, w))
        .collect();
    let mut it = active.chunks_exact(2);
    for pair in &mut it {
        let (i0, w0) = pair[0];
        let (i1, w1) = pair[1];
        let (r0, r1) = (rows[i0], rows[i1]);
        debug_assert!(r0.len() == d && r1.len() == d);
        for k in 0..d {
            out[k] += w0 * r0[k] + w1 * r1[k];
        }
    }
    for &(i, w) in it.remainder() {
        let r = rows[i];
        debug_assert_eq!(r.len(), d);
        for k in 0..d {
            out[k] += w * r[k];
        }
    }
}

/// Outcome of a full engine run.
#[derive(Debug)]
pub struct RunSummary {
    /// All recorded metrics.
    pub recorder: Recorder,
    /// Gossip iterations executed.
    pub iterations: u64,
    /// Final virtual time (seconds).
    pub virtual_time: f64,
    /// Observed straggler fraction.
    pub straggler_fraction: f64,
    /// Label of the straggler process that drove the run.
    pub straggler_process: &'static str,
    /// Pathsearch epochs completed (DSGD-AAU only; 0 otherwise).
    pub epochs_completed: u64,
    /// Final consensus gap `max_j ‖w_j − w̄‖`.
    pub consensus_gap: f32,
    /// Algorithm label.
    pub algorithm: &'static str,
}

impl RunSummary {
    /// Final global loss.
    pub fn final_loss(&self) -> f32 {
        self.recorder.final_loss()
    }

    /// Final global accuracy.
    pub fn final_accuracy(&self) -> f32 {
        self.recorder.final_accuracy()
    }
}

/// Event loop driver binding an [`EngineCore`] to an update rule.
pub struct Engine {
    core: EngineCore,
    rule: Box<dyn UpdateRule>,
    churn: ChurnModel,
    max_iterations: u64,
    time_budget: Option<f64>,
    /// Time-based evaluation period (drives `EventKind::EvalTick`).
    eval_every_seconds: Option<f64>,
    /// Open-world population layer (`membership` config section): owns the
    /// user pool, the round sampler and the departure clock, and feeds the
    /// event loop `WorkerJoin`/`WorkerLeave`/`RoundSample` events.
    membership: Option<MembershipModel>,
    /// Slot-graph template: a joiner re-wires the vacant slot's template
    /// edges toward whichever endpoints are currently active.
    initial_graph: Graph,
    /// Fleet init vector — warm-start fallback for a joiner whose slot has
    /// no active template neighbor.
    init_params: ParamVec,
    /// External worker id (trace machines ≥ n) → assigned slot (satellite:
    /// trace ADD/REMOVE of previously-unknown machine ids route through
    /// the join/leave path instead of being dropped).
    extern_map: BTreeMap<usize, WorkerId>,
}

impl Engine {
    /// Assemble an engine from a config and a backend; panics on invalid
    /// configs (tests/benches convenience — [`Self::try_from_config`] is
    /// the fallible form used by the coordinator).
    pub fn from_config(cfg: &ExperimentConfig, backend: Box<dyn Backend>) -> Self {
        Self::try_from_config(cfg, backend)
            // pallas-lint: allow(no-panic-in-engine) — documented panicking constructor, not dispatch
            .expect("engine config invalid (churn schedule missing or bad parameters)")
    }

    /// Assemble an engine from a config and a backend.
    pub fn try_from_config(
        cfg: &ExperimentConfig,
        backend: Box<dyn Backend>,
    ) -> anyhow::Result<Self> {
        let n = cfg.num_workers;
        let membership = cfg
            .membership
            .as_ref()
            .map(|mc| MembershipModel::from_config(mc, n, cfg.seed_for("membership")))
            .transpose()?;
        // Two-tier membership (aggregators > 0) replaces the configured
        // topology with the hierarchical slot graph; otherwise the slot
        // graph *is* the configured topology.  Initial vacancies are
        // applied as leaves in `run`, so the template built here is the
        // fully-occupied graph.
        let graph = membership
            .as_ref()
            .and_then(|m| m.build_graph())
            .unwrap_or_else(|| cfg.topology.build(n));
        assert!(graph.is_connected(), "topology must be connected");
        // A trace section replaces both synthetic generators: the lowered
        // straggler timeline drives the compute model and the lowered
        // topology timeline replays through the churn path.
        let lowered = match &cfg.trace {
            Some(tc) => Some(crate::trace::TraceIngest::load(tc)?.lower(n, &graph)?),
            None => None,
        };
        let compute = match &lowered {
            Some(lt) => ComputeModel::with_process(
                n,
                cfg.mean_compute,
                cfg.hetero_sigma,
                cfg.straggler.slowdown,
                Box::new(crate::sim::TraceProcess::from_timeline(&lt.straggler, n)),
                cfg.seed_for("compute"),
            ),
            None => ComputeModel::new(
                n,
                cfg.mean_compute,
                cfg.hetero_sigma,
                &cfg.straggler,
                cfg.seed_for("compute"),
            )?,
        };
        let dim = backend.dim();
        let init = backend.init_params(cfg.seed_for("init"));
        assert_eq!(init.len(), dim);
        let param_bytes = backend.param_bytes();
        let monitor =
            PartitionMonitor::with_latencies(&graph, cfg.adapt.detection_latency.resolve(n)?);
        let mut recorder = Recorder::new();
        recorder.max_components = monitor.num_components();
        // Closed-world runs lazily (re)build the full matrix on demand;
        // open-world runs prime it here and maintain it incrementally
        // across every join/leave (`GroupWeights::refresh_rows`).
        let full_weights = membership.is_some().then(|| {
            let all: Vec<WorkerId> = (0..n).collect();
            GroupWeights::metropolis(&graph, &all)
        });
        let initial_graph = graph.clone();
        let core = EngineCore {
            graph,
            queue: EventQueue::new(),
            comm: cfg.comm,
            pathsearch: PathSearch::new(),
            monitor,
            recorder,
            k: 0,
            adapt: cfg.adapt.clone(),
            compute,
            compute_threads: match cfg.compute_threads {
                // auto: size to the machine (a capability probe, not a
                // clock — results are identical whatever it returns)
                0 => std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1),
                t => t,
            },
            backend,
            params: vec![init.clone(); n],
            stash: vec![None; n],
            lr: cfg.lr,
            lr_per_round: cfg.lr_per_round,
            eval_every: cfg.eval_every.max(1),
            pjrt_gossip: cfg.pjrt_gossip,
            param_bytes,
            recent_loss: (0.0, 0),
            scratch: Vec::new(),
            full_weights,
            active: vec![true; n],
            expected_done: vec![f64::NAN; n],
            stale: StaleState::new(&cfg.stale, n, cfg.seed_for("stale")),
            fragments: FragmentState::new(&cfg.fragments, dim, n, cfg.seed_for("fragments")),
            last_wire_bytes: param_bytes,
        };
        let rule = cfg.algorithm.build(cfg.prague_group, cfg.seed_for("algorithm"));
        let churn = match lowered {
            Some(lt) => ChurnModel::replay(lt.topology),
            None => ChurnModel::from_config(&cfg.churn, n, cfg.seed_for("churn"))?,
        };
        Ok(Engine {
            core,
            rule,
            churn,
            max_iterations: cfg.max_iterations,
            time_budget: cfg.time_budget,
            eval_every_seconds: cfg.eval_every_seconds,
            membership,
            initial_graph,
            init_params: init,
            extern_map: BTreeMap::new(),
        })
    }

    /// Slot `s` leaves the fleet: core teardown, then the update rule's
    /// hook (so a group-based rule can shrink or fire the departed
    /// member's group before the monitor even promotes the vacancy).
    fn do_leave(&mut self, s: WorkerId) {
        self.core.vacate_slot(s);
        self.rule.on_worker_leave(s, &mut self.core);
    }

    /// A joiner occupies vacant slot `s`: core re-wiring + warm start,
    /// then the rule's hook, then the joiner starts computing.
    fn do_join(&mut self, s: WorkerId) {
        self.core.fill_slot(s, &self.initial_graph, &self.init_params);
        self.rule.on_worker_join(s, &mut self.core);
        self.core.begin_compute(s);
    }

    /// Route churn/trace mutations through the membership model
    /// (satellite fix: an `Isolate`/`Attach` naming a machine id the
    /// engine has never seen — trace REMOVE/ADD of an unknown worker — is
    /// a membership leave/join, not a topology edit).  Returns the
    /// mutations that still apply as plain topology churn.
    fn route_membership_mutations(
        &mut self,
        muts: Vec<TopologyMutation>,
        now: f64,
    ) -> Vec<TopologyMutation> {
        // temporarily detach the model: do_leave/do_join re-borrow self
        // pallas-lint: allow(no-panic-in-engine) — caller dispatches here only when membership is Some
        let mut model = self.membership.take().expect("membership routing without model");
        let n = self.core.num_workers();
        let mut rest = Vec::new();
        for m in muts {
            match m {
                TopologyMutation::Isolate(w) => {
                    let slot = if w < n {
                        Some(w)
                    } else {
                        self.extern_map.remove(&w)
                    };
                    let Some(slot) = slot else { continue };
                    if model.extern_leave(slot, now) {
                        self.core.recorder.workers_left += 1;
                        self.do_leave(slot);
                    }
                }
                TopologyMutation::Attach(w, targets) => {
                    if w < n {
                        if model.extern_join(w, now) {
                            self.core.recorder.workers_joined += 1;
                            self.do_join(w);
                        } else {
                            // occupied slot: a plain re-wire, not a join
                            rest.push(TopologyMutation::Attach(w, targets));
                        }
                    } else if let Some(slot) = (0..n).find(|&s| !self.core.active[s]) {
                        // previously-unknown machine id: admit it into the
                        // lowest vacant slot and remember the mapping so a
                        // later REMOVE of the same id routes back here
                        if model.extern_join(slot, now) {
                            self.extern_map.insert(w, slot);
                            self.core.recorder.workers_joined += 1;
                            self.do_join(slot);
                        }
                    }
                    // no vacant slot: the fleet is full, the arrival is
                    // turned away (dropped, as the pre-membership code did)
                }
                other => rest.push(other),
            }
        }
        self.membership = Some(model);
        rest
    }

    /// Read-only core access (tests/diagnostics).
    pub fn core(&self) -> &EngineCore {
        &self.core
    }

    /// Mutable core access (tests drive gossip primitives directly, e.g.
    /// the shard-equals-full-vector bitwise invariant suite).
    pub fn core_mut(&mut self) -> &mut EngineCore {
        &mut self.core
    }

    /// Run to completion (iteration cap, time budget, or quiescence).
    pub fn run(&mut self) -> RunSummary {
        let n = self.core.num_workers();
        self.rule.on_start(&mut self.core);
        // Open-world runs start with only the sampled slots occupied: the
        // template graph vacates down to the membership model's initial
        // occupancy before anyone computes (not counted as departures).
        let vacant =
            self.membership.as_ref().map(|m| m.initially_vacant()).unwrap_or_default();
        for s in vacant {
            self.do_leave(s);
        }
        let startup: Vec<WorkerId> = (0..n).filter(|&w| self.core.active[w]).collect();
        self.core.begin_compute_batch(&startup);
        self.core.eval_now(); // k = 0 baseline point
        if let Some(t) = self.churn.next_change() {
            self.core.queue.schedule(t, EventKind::TopologyChange);
        }
        if let Some(dt) = self.eval_every_seconds {
            self.core.queue.schedule(dt, EventKind::EvalTick);
        }
        if let Some(model) = self.membership.as_mut() {
            self.core.queue.schedule(model.next_round_time(), EventKind::RoundSample);
            if let Some((t, s)) = model.schedule_departure(0.0) {
                self.core.queue.schedule(t, EventKind::WorkerLeave(s));
            }
        }
        while let Some(Event { kind, .. }) = self.core.queue.pop() {
            match kind {
                EventKind::ComputeStart(w) => {
                    // Parallel intra-cell stepping: drain the run of
                    // *consecutive* same-timestamp ComputeStarts at the
                    // queue head and hand them to the backend as one
                    // batch.  Only consecutive heads are taken — a
                    // same-time TopologyChange (or any other event)
                    // between two starts ends the batch, so event
                    // interleaving is exactly the serial engine's.
                    // Results commit in drain (FIFO) order, which *is*
                    // the order the serial loop would have popped, so
                    // the trajectory is byte-identical for every
                    // `compute_threads` value.
                    let now = self.core.queue.now();
                    let mut batch: Vec<WorkerId> = Vec::new();
                    if self.core.can_start(w) {
                        batch.push(w);
                    }
                    // if this timestamp already exhausts the time budget
                    // the serial loop would break after this one event —
                    // don't drain peers it would never have started
                    let within_budget = self.time_budget.map_or(true, |b| now < b);
                    while let Some(head) = self.core.queue.peek() {
                        if !within_budget {
                            break;
                        }
                        match head.kind {
                            EventKind::ComputeStart(v)
                                if head.time.to_bits() == now.to_bits() =>
                            {
                                self.core.queue.pop();
                                // duplicate starts for one worker collapse
                                // exactly as serial dispatch would: the
                                // first commit arms expected_done, so
                                // can_start vetoes the second
                                if self.core.can_start(v) && !batch.contains(&v) {
                                    batch.push(v);
                                }
                            }
                            _ => break,
                        }
                    }
                    self.core.begin_compute_batch(&batch);
                }
                EventKind::ComputeDone(w) => {
                    if self.core.accept_done(w) {
                        self.rule.on_ready(w, &mut self.core);
                    }
                }
                EventKind::WorkerJoin(s) => {
                    let admit = self
                        .membership
                        .as_mut()
                        .map_or(false, |model| model.on_join_event(s));
                    if admit {
                        self.core.recorder.workers_joined += 1;
                        self.do_join(s);
                    }
                }
                EventKind::WorkerLeave(s) => {
                    let now = self.core.queue.now();
                    let (proceed, redraw) = match self.membership.as_mut() {
                        Some(model) => model.on_leave_event(s, now),
                        None => (false, None),
                    };
                    if proceed {
                        self.core.recorder.workers_left += 1;
                        self.do_leave(s);
                    }
                    if let Some((t, slot)) = redraw {
                        self.core.queue.schedule(t, EventKind::WorkerLeave(slot));
                    }
                }
                EventKind::RoundSample => {
                    let now = self.core.queue.now();
                    if let Some(model) = self.membership.as_mut() {
                        let outcome = model.fire_round(now);
                        self.core.recorder.rounds_sampled += 1;
                        // leaves replay before joins at the same timestamp
                        // (FIFO tie-break), so a rotated slot is vacated
                        // before its next occupant arrives
                        for &s in &outcome.leaves {
                            self.core.queue.schedule(now, EventKind::WorkerLeave(s));
                        }
                        for &s in &outcome.joins {
                            self.core.queue.schedule(now, EventKind::WorkerJoin(s));
                        }
                        self.core.queue.schedule(model.next_round_time(), EventKind::RoundSample);
                    }
                }
                EventKind::EvalTick => {
                    self.core.eval_now();
                    // re-arm only while other activity is pending so a
                    // quiescing run cannot be kept alive by its own ticks
                    if let Some(dt) = self.eval_every_seconds {
                        if !self.core.queue.is_empty() {
                            self.core.queue.schedule_in(dt, EventKind::EvalTick);
                        }
                    }
                }
                EventKind::TopologyChange => {
                    let now = self.core.queue.now();
                    let muts = self.churn.step(now, &self.core.graph);
                    // under membership, Isolate/Attach churn (including
                    // trace ADD/REMOVE of unknown machine ids) is a
                    // membership leave/join, not a topology edit
                    let muts = if self.membership.is_some() {
                        self.route_membership_mutations(muts, now)
                    } else {
                        muts
                    };
                    if !muts.is_empty() {
                        let outcome = if self.core.partitions_allowed() {
                            churn::apply_mutations_unrepaired(&mut self.core.graph, &muts)
                        } else {
                            let outcome = churn::apply_mutations(&mut self.core.graph, &muts);
                            debug_assert!(
                                self.core.graph.is_connected(),
                                "connectivity repair failed at t={now}"
                            );
                            outcome
                        };
                        if self.core.on_topology_changed(outcome, &muts) {
                            // One detect wake-up per distinct latency, so
                            // each worker's adoption instant gets a
                            // `PartitionDetect` event even when detectors
                            // are heterogeneous.
                            for latency in self.core.monitor.distinct_latencies() {
                                self.core
                                    .queue
                                    .schedule_in(latency, EventKind::PartitionDetect);
                            }
                        }
                        if self.membership.is_some() {
                            // on_topology_changed dropped the cached
                            // matrix; open-world maintenance is
                            // incremental, so rebuild the baseline the
                            // next join/leave will patch
                            let all: Vec<WorkerId> = (0..n).collect();
                            self.core.full_weights =
                                Some(GroupWeights::metropolis(&self.core.graph, &all));
                        }
                    }
                    if let Some(t) = self.churn.next_change() {
                        self.core.queue.schedule(t, EventKind::TopologyChange);
                    }
                }
                EventKind::PartitionDetect => {
                    let now = self.core.queue.now();
                    let delta = self.core.monitor.promote_due(now);
                    if delta.changed() {
                        // Waiting sets may already satisfy their new
                        // (smaller or merged) components — fire them now.
                        self.rule.on_view_changed(&mut self.core);
                    }
                }
            }
            if self.core.k >= self.max_iterations {
                break;
            }
            if let Some(budget) = self.time_budget {
                if self.core.now() >= budget {
                    break;
                }
            }
        }
        // Final curve point.  When the last event already evaluated at
        // this exact (k, t) the recorder drops the duplicate, so CSV
        // output and bytes_to_accuracy see each point once.
        self.core.eval_now();
        RunSummary {
            iterations: self.core.k,
            virtual_time: self.core.now(),
            straggler_fraction: self.core.straggler_fraction(),
            straggler_process: self.core.straggler_process(),
            epochs_completed: self.core.pathsearch.epochs_completed,
            consensus_gap: self.core.consensus_gap(),
            algorithm: self.rule.name(),
            recorder: std::mem::take(&mut self.core.recorder),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn native_weighted_average_basics() {
        let a = vec![1.0f32, 0.0];
        let b = vec![0.0f32, 1.0];
        let out = native_weighted_average(&[&a, &b], &[0.25, 0.75]);
        assert_eq!(out, vec![0.25, 0.75]);
    }

    #[test]
    fn zero_weight_skipped() {
        let a = vec![f32::NAN, f32::NAN];
        let b = vec![2.0f32, 4.0];
        // NaN row has zero weight and must not poison the result
        let out = native_weighted_average(&[&a, &b], &[0.0, 1.0]);
        assert_eq!(out, vec![2.0, 4.0]);
    }

    #[test]
    fn fill_slot_warm_start_scoped_to_observed_component() {
        // Membership under partition: slot 4's template neighbors straddle
        // a split ({0,1,2} vs {3}).  The joiner must warm-start from the
        // majority cohort's mean only — never a cross-partition blend —
        // and pay warm-start bytes for that cohort only, while the edges
        // toward the minority side are still wired up.
        let mut cfg = ExperimentConfig::default();
        cfg.num_workers = 5;
        cfg.backend = crate::config::BackendKind::Quadratic;
        cfg.topology = crate::topology::TopologyKind::Complete;
        cfg.adapt = AdaptConfig {
            allow_partitions: true,
            partition_aware: true,
            detection_latency: 0.0.into(),
            heal_restart: false,
        };
        let backend = crate::coordinator::build_backend(&cfg).unwrap();
        let mut eng = Engine::try_from_config(&cfg, backend).unwrap();
        let core = eng.core_mut();
        let template = core.graph.clone();
        let dim = core.params[0].len();
        let init = vec![-7.0f32; dim];

        core.vacate_slot(4);
        // cut the survivors into {0,1,2} and {3}, observed immediately
        core.graph.remove_edge(0, 3);
        core.graph.remove_edge(1, 3);
        core.graph.remove_edge(2, 3);
        core.monitor = PartitionMonitor::new(&core.graph, 0.0);
        for w in 0..3 {
            core.params[w] = vec![1.0 + w as f32; dim];
        }
        core.params[3] = vec![100.0; dim];

        let bytes_before = core.recorder.param_bytes;
        let targets = core.fill_slot(4, &template, &init);
        assert_eq!(targets, vec![0, 1, 2, 3]);
        // mean over {0,1,2} is exactly 2.0; a full-target blend would be
        // pulled far off by worker 3's vector
        assert_eq!(core.params[4], vec![2.0f32; dim]);
        assert_eq!(
            core.recorder.param_bytes - bytes_before,
            3 * core.param_bytes,
            "warm start must be charged for the averaged cohort only"
        );
        // the minority-side edge still exists — it converges via gossip
        assert!(core.graph.has_edge(4, 3));

        // a joiner with no reachable neighbor falls back to the fleet init
        core.vacate_slot(4);
        let lonely = Graph::empty(5);
        let targets = core.fill_slot(4, &lonely, &init);
        assert!(targets.is_empty());
        assert_eq!(core.params[4], init);
    }
}
