//! `dsgd-aau` CLI — the launcher for single runs and quick inspection.
//!
//! ```text
//! dsgd-aau train --config exp.json             # run one experiment
//! dsgd-aau train --algorithm dsgd_aau -n 32    # ... or ad-hoc flags
//! dsgd-aau compare -n 16                       # all algorithms, one table
//! dsgd-aau inspect                             # artifact manifest summary
//! dsgd-aau default-config                      # print config template
//! ```
//!
//! (Argument parsing is hand-rolled: the offline dependency set has no
//! clap; see `rust/src/util/`.)

use anyhow::{bail, Context, Result};
use dsgd_aau::algorithms::AlgorithmKind;
use dsgd_aau::config::{BackendKind, ExperimentConfig};
use dsgd_aau::coordinator;
use dsgd_aau::runtime::Manifest;
use std::path::{Path, PathBuf};

const USAGE: &str = "\
dsgd-aau — straggler-resilient decentralized learning (DSGD-AAU)

USAGE:
  dsgd-aau train   [OPTIONS]     run one experiment
  dsgd-aau compare [OPTIONS]     run all five algorithms on one workload
  dsgd-aau inspect [--dir D]     summarize the AOT artifact manifest
  dsgd-aau default-config        print the default config as JSON

Paper tables/figures are driven by the separate `bench` multiplexer
binary (`bench list` maps every suite to its paper artifact).

OPTIONS (train/compare):
  --config FILE          JSON config (flags below override it)
  --algorithm A          dsgd_aau | dsgd_sync | ad_psgd | prague | agp
  -n, --workers N        number of workers
  --backend B            pjrt | native_mlp | quadratic
  --model M              model variant (manifest key), e.g. mlp_small
  --iterations K         gossip iterations to run
  --time-budget SECS     virtual-time budget
  --iid                  IID partitioning (default non-IID)
  --straggler-prob P     Bernoulli straggler probability (forces the
                         bernoulli process, overriding a correlated
                         \"straggler\" section from --config)
  --slowdown S           straggler slowdown factor
  --seed S               RNG seed
  --out FILE             write the loss-curve CSV here
";

/// Parsed train/compare options.
#[derive(Default)]
struct TrainArgs {
    config: Option<PathBuf>,
    algorithm: Option<String>,
    workers: Option<usize>,
    backend: Option<String>,
    model: Option<String>,
    iterations: Option<u64>,
    time_budget: Option<f64>,
    iid: bool,
    straggler_prob: Option<f64>,
    slowdown: Option<f64>,
    seed: Option<u64>,
    out: Option<PathBuf>,
}

fn take_value(args: &mut std::vec::IntoIter<String>, flag: &str) -> Result<String> {
    args.next().with_context(|| format!("{flag} requires a value"))
}

impl TrainArgs {
    fn parse(args: Vec<String>) -> Result<Self> {
        let mut out = TrainArgs::default();
        let mut it = args.into_iter();
        while let Some(arg) = it.next() {
            match arg.as_str() {
                "--config" => out.config = Some(take_value(&mut it, "--config")?.into()),
                "--algorithm" => out.algorithm = Some(take_value(&mut it, "--algorithm")?),
                "-n" | "--workers" => {
                    out.workers = Some(take_value(&mut it, "--workers")?.parse()?)
                }
                "--backend" => out.backend = Some(take_value(&mut it, "--backend")?),
                "--model" => out.model = Some(take_value(&mut it, "--model")?),
                "--iterations" => {
                    out.iterations = Some(take_value(&mut it, "--iterations")?.parse()?)
                }
                "--time-budget" => {
                    out.time_budget = Some(take_value(&mut it, "--time-budget")?.parse()?)
                }
                "--iid" => out.iid = true,
                "--straggler-prob" => {
                    out.straggler_prob = Some(take_value(&mut it, "--straggler-prob")?.parse()?)
                }
                "--slowdown" => out.slowdown = Some(take_value(&mut it, "--slowdown")?.parse()?),
                "--seed" => out.seed = Some(take_value(&mut it, "--seed")?.parse()?),
                "--out" => out.out = Some(take_value(&mut it, "--out")?.into()),
                other => bail!("unknown option {other}\n\n{USAGE}"),
            }
        }
        Ok(out)
    }

    fn to_config(&self) -> Result<ExperimentConfig> {
        let mut cfg = match &self.config {
            Some(p) => ExperimentConfig::from_json_file(p)?,
            None => ExperimentConfig::default(),
        };
        if let Some(a) = &self.algorithm {
            cfg.algorithm = AlgorithmKind::parse(a)?;
        }
        if let Some(n) = self.workers {
            cfg.num_workers = n;
        }
        if let Some(b) = &self.backend {
            cfg.backend = BackendKind::parse(b)?;
        }
        if let Some(m) = &self.model {
            cfg.model = m.clone();
        }
        if let Some(i) = self.iterations {
            cfg.max_iterations = i;
        }
        if self.time_budget.is_some() {
            cfg.time_budget = self.time_budget;
        }
        if self.iid {
            cfg.iid = true;
        }
        if let Some(p) = self.straggler_prob {
            // the flag names the Bernoulli coin explicitly, so it also
            // overrides a correlated `straggler` section from --config
            // (otherwise it would be silently ignored)
            cfg.straggler.probability = p;
            cfg.straggler.kind = dsgd_aau::sim::StragglerKind::Bernoulli;
        }
        if let Some(s) = self.slowdown {
            cfg.straggler.slowdown = s;
        }
        if let Some(s) = self.seed {
            cfg.seed = s;
        }
        Ok(cfg)
    }
}

fn print_summary(cfg: &ExperimentConfig, s: &dsgd_aau::engine::RunSummary) {
    println!(
        "{:>9}  N={:<4} iters={:<6} vtime={:>9.2}s  loss={:<8.4} acc={:>6.2}%  \
         MB={:<9.1} gap={:.3e}",
        s.algorithm,
        cfg.num_workers,
        s.iterations,
        s.virtual_time,
        s.final_loss(),
        s.final_accuracy() * 100.0,
        s.recorder.total_bytes() as f64 / 1e6,
        s.consensus_gap,
    );
}

fn main() -> Result<()> {
    let mut argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() {
        print!("{USAGE}");
        return Ok(());
    }
    let cmd = argv.remove(0);
    match cmd.as_str() {
        "train" => {
            let args = TrainArgs::parse(argv)?;
            let cfg = args.to_config()?;
            eprintln!(
                "[dsgd-aau] {} / {} / N={}",
                cfg.algorithm.label(),
                cfg.backend.token(),
                cfg.num_workers
            );
            let summary = coordinator::run_experiment(&cfg)?;
            print_summary(&cfg, &summary);
            if let Some(out) = args.out {
                summary.recorder.write_csv(&out)?;
                eprintln!("[dsgd-aau] wrote {}", out.display());
            }
        }
        "compare" => {
            let args = TrainArgs::parse(argv)?;
            let base = args.to_config()?;
            let cfgs: Vec<ExperimentConfig> = AlgorithmKind::all()
                .into_iter()
                .map(|a| {
                    let mut c = base.clone();
                    c.algorithm = a;
                    c
                })
                .collect();
            println!(
                "{:>9}  {:<6} {:<8} {:<10} {:<9} {:<8} {:<10} {}",
                "algo", "N", "iters", "vtime(s)", "loss", "acc", "MB", "gap"
            );
            for (cfg, res) in coordinator::run_sweep(cfgs) {
                match res {
                    Ok(s) => print_summary(&cfg, &s),
                    Err(e) => println!("{:>9}  FAILED: {e}", cfg.algorithm.label()),
                }
            }
        }
        "inspect" => {
            let mut dir = PathBuf::from("artifacts");
            let mut it = argv.into_iter();
            while let Some(a) = it.next() {
                match a.as_str() {
                    "--dir" => dir = take_value(&mut it, "--dir")?.into(),
                    other => bail!("unknown option {other}"),
                }
            }
            let m = Manifest::load(&dir.join("manifest.json"))?;
            println!("format {} | gossip fanout {}", m.format, m.gossip_fanout);
            let mut names: Vec<_> = m.variants.keys().collect();
            names.sort();
            for name in names {
                let v = &m.variants[name];
                println!(
                    "  {:<18} kind={:<12} dim={:<8} padded={:<8} batch={:<4} in={:?}",
                    name, v.kind, v.dim, v.padded_dim, v.batch, v.input_shape
                );
            }
            let _ = Path::new("."); // keep Path import exercised on all paths
        }
        "default-config" => {
            println!("{}", ExperimentConfig::default().to_json().to_string_compact());
        }
        "-h" | "--help" | "help" => print!("{USAGE}"),
        other => bail!("unknown command {other}\n\n{USAGE}"),
    }
    Ok(())
}
