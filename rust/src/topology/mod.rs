//! Communication graphs for the decentralized system (paper §2).
//!
//! The decentralized system is an undirected graph `G = (N, E)`; an edge
//! `(i, j)` means workers i and j can exchange parameters.  The paper
//! assumes `G` is (strongly) connected; all generators here guarantee it.

pub mod generators;

pub use generators::TopologyKind;

use std::collections::HashSet;

/// Undirected communication graph with adjacency lists and an edge set.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Graph {
    n: usize,
    adj: Vec<Vec<usize>>,
    edges: HashSet<(usize, usize)>, // normalized (min, max)
}

/// Normalize an undirected edge to `(min, max)` form.
#[inline]
pub fn norm_edge(i: usize, j: usize) -> (usize, usize) {
    if i < j {
        (i, j)
    } else {
        (j, i)
    }
}

impl Graph {
    /// Empty graph over `n` vertices.
    pub fn empty(n: usize) -> Self {
        Graph { n, adj: vec![Vec::new(); n], edges: HashSet::new() }
    }

    /// Build from an explicit edge list (self-loops and duplicates ignored).
    pub fn from_edges(n: usize, edges: &[(usize, usize)]) -> Self {
        let mut g = Graph::empty(n);
        for &(i, j) in edges {
            g.add_edge(i, j);
        }
        g
    }

    /// Insert the undirected edge `(i, j)`; no-op for self-loops/duplicates.
    pub fn add_edge(&mut self, i: usize, j: usize) {
        assert!(i < self.n && j < self.n, "edge ({i},{j}) out of range n={}", self.n);
        if i == j {
            return;
        }
        if self.edges.insert(norm_edge(i, j)) {
            self.adj[i].push(j);
            self.adj[j].push(i);
        }
    }

    /// Number of vertices.
    pub fn num_vertices(&self) -> usize {
        self.n
    }

    /// Number of undirected edges.
    pub fn num_edges(&self) -> usize {
        self.edges.len()
    }

    /// Neighbors of `i` (excluding `i` itself).
    pub fn neighbors(&self, i: usize) -> &[usize] {
        &self.adj[i]
    }

    /// Degree of `i`.
    pub fn degree(&self, i: usize) -> usize {
        self.adj[i].len()
    }

    /// Whether the undirected edge `(i, j)` exists.
    pub fn has_edge(&self, i: usize, j: usize) -> bool {
        i != j && self.edges.contains(&norm_edge(i, j))
    }

    /// Iterator over normalized edges.
    pub fn edges(&self) -> impl Iterator<Item = (usize, usize)> + '_ {
        self.edges.iter().copied()
    }

    /// BFS connectivity over all `n` vertices.  For undirected graphs this
    /// is exactly the paper's strong-connectivity assumption.
    pub fn is_connected(&self) -> bool {
        if self.n == 0 {
            return true;
        }
        let mut seen = vec![false; self.n];
        let mut stack = vec![0usize];
        seen[0] = true;
        let mut count = 1;
        while let Some(v) = stack.pop() {
            for &u in &self.adj[v] {
                if !seen[u] {
                    seen[u] = true;
                    count += 1;
                    stack.push(u);
                }
            }
        }
        count == self.n
    }

    /// Connectivity of the subgraph induced by `vertices` using only
    /// `edge_set` edges.  Used by Pathsearch to decide epoch completion.
    pub fn subgraph_connected(
        n: usize,
        vertices: &HashSet<usize>,
        edge_set: &HashSet<(usize, usize)>,
    ) -> bool {
        if vertices.is_empty() {
            return false;
        }
        let mut adj = vec![Vec::new(); n];
        for &(i, j) in edge_set {
            adj[i].push(j);
            adj[j].push(i);
        }
        let start = *vertices.iter().next().unwrap();
        let mut seen = HashSet::new();
        seen.insert(start);
        let mut stack = vec![start];
        while let Some(v) = stack.pop() {
            for &u in &adj[v] {
                if vertices.contains(&u) && seen.insert(u) {
                    stack.push(u);
                }
            }
        }
        seen.len() == vertices.len()
    }

    /// Two-coloring check (bipartite graphs are what AD-PSGD formally
    /// requires to avoid deadlock; see paper §7).
    pub fn is_bipartite(&self) -> bool {
        let mut color = vec![-1i8; self.n];
        for s in 0..self.n {
            if color[s] != -1 {
                continue;
            }
            color[s] = 0;
            let mut stack = vec![s];
            while let Some(v) = stack.pop() {
                for &u in &self.adj[v] {
                    if color[u] == -1 {
                        color[u] = 1 - color[v];
                        stack.push(u);
                    } else if color[u] == color[v] {
                        return false;
                    }
                }
            }
        }
        true
    }

    /// Graph diameter via BFS from every vertex (test/diagnostic helper;
    /// O(V·E), fine for the sizes we simulate).
    pub fn diameter(&self) -> usize {
        let mut diam = 0;
        for s in 0..self.n {
            let mut dist = vec![usize::MAX; self.n];
            dist[s] = 0;
            let mut q = std::collections::VecDeque::from([s]);
            while let Some(v) = q.pop_front() {
                for &u in &self.adj[v] {
                    if dist[u] == usize::MAX {
                        dist[u] = dist[v] + 1;
                        q.push_back(u);
                    }
                }
            }
            diam = diam.max(dist.iter().copied().filter(|&d| d != usize::MAX).max().unwrap_or(0));
        }
        diam
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_graph_connected_iff_tiny() {
        assert!(Graph::empty(0).is_connected());
        assert!(Graph::empty(1).is_connected());
        assert!(!Graph::empty(2).is_connected());
    }

    #[test]
    fn add_edge_dedup_and_self_loop() {
        let mut g = Graph::empty(3);
        g.add_edge(0, 1);
        g.add_edge(1, 0);
        g.add_edge(1, 1);
        assert_eq!(g.num_edges(), 1);
        assert_eq!(g.degree(1), 1);
        assert!(!g.has_edge(1, 1));
    }

    #[test]
    fn path_graph_connectivity_and_diameter() {
        let g = Graph::from_edges(4, &[(0, 1), (1, 2), (2, 3)]);
        assert!(g.is_connected());
        assert_eq!(g.diameter(), 3);
        assert!(g.is_bipartite());
    }

    #[test]
    fn triangle_not_bipartite() {
        let g = Graph::from_edges(3, &[(0, 1), (1, 2), (2, 0)]);
        assert!(!g.is_bipartite());
        assert_eq!(g.diameter(), 1);
    }

    #[test]
    fn subgraph_connectivity() {
        let verts: HashSet<usize> = [0, 1, 2].into_iter().collect();
        let edges: HashSet<(usize, usize)> = [(0, 1), (1, 2)].into_iter().collect();
        assert!(Graph::subgraph_connected(5, &verts, &edges));
        let edges2: HashSet<(usize, usize)> = [(0, 1)].into_iter().collect();
        assert!(!Graph::subgraph_connected(5, &verts, &edges2));
    }

    #[test]
    fn disconnected_detected() {
        let g = Graph::from_edges(4, &[(0, 1), (2, 3)]);
        assert!(!g.is_connected());
    }
}
