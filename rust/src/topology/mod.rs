//! Communication graphs for the decentralized system (paper §2).
//!
//! The decentralized system is an undirected graph `G = (N, E)`; an edge
//! `(i, j)` means workers i and j can exchange parameters.  The paper
//! assumes `G` is (strongly) connected; all generators here guarantee it.

pub mod generators;

pub use generators::TopologyKind;

use std::collections::BTreeSet;

/// Undirected communication graph with adjacency lists and an edge set.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Graph {
    n: usize,
    adj: Vec<Vec<usize>>,
    edges: BTreeSet<(usize, usize)>, // normalized (min, max)
}

/// Normalize an undirected edge to `(min, max)` form.
#[inline]
pub fn norm_edge(i: usize, j: usize) -> (usize, usize) {
    if i < j {
        (i, j)
    } else {
        (j, i)
    }
}

impl Graph {
    /// Empty graph over `n` vertices.
    pub fn empty(n: usize) -> Self {
        Graph { n, adj: vec![Vec::new(); n], edges: BTreeSet::new() }
    }

    /// Build from an explicit edge list (self-loops and duplicates ignored).
    pub fn from_edges(n: usize, edges: &[(usize, usize)]) -> Self {
        let mut g = Graph::empty(n);
        for &(i, j) in edges {
            g.add_edge(i, j);
        }
        g
    }

    /// Insert the undirected edge `(i, j)`; no-op for self-loops/duplicates.
    pub fn add_edge(&mut self, i: usize, j: usize) {
        assert!(i < self.n && j < self.n, "edge ({i},{j}) out of range n={}", self.n);
        if i == j {
            return;
        }
        if self.edges.insert(norm_edge(i, j)) {
            self.adj[i].push(j);
            self.adj[j].push(i);
        }
    }

    /// Remove the undirected edge `(i, j)`, keeping the adjacency lists
    /// and edge set consistent.  Returns whether the edge existed.
    pub fn remove_edge(&mut self, i: usize, j: usize) -> bool {
        assert!(i < self.n && j < self.n, "edge ({i},{j}) out of range n={}", self.n);
        if i == j || !self.edges.remove(&norm_edge(i, j)) {
            return false;
        }
        if let Some(p) = self.adj[i].iter().position(|&x| x == j) {
            self.adj[i].swap_remove(p);
        }
        if let Some(p) = self.adj[j].iter().position(|&x| x == i) {
            self.adj[j].swap_remove(p);
        }
        true
    }

    /// Detach vertex `i` by removing every incident edge (worker ids are
    /// dense and fixed, so "removing" a vertex means isolating it).
    /// Returns the number of edges removed.
    pub fn remove_vertex(&mut self, i: usize) -> usize {
        let nbrs = std::mem::take(&mut self.adj[i]);
        for &j in &nbrs {
            self.edges.remove(&norm_edge(i, j));
            if let Some(p) = self.adj[j].iter().position(|&x| x == i) {
                self.adj[j].swap_remove(p);
            }
        }
        nbrs.len()
    }

    /// Whether removing the (existing) edge `(i, j)` would disconnect the
    /// graph — i.e. the edge is a bridge.  False when the edge is absent.
    pub fn would_disconnect(&self, i: usize, j: usize) -> bool {
        if !self.has_edge(i, j) {
            return false;
        }
        let skip = norm_edge(i, j);
        let mut seen = vec![false; self.n];
        let mut stack = vec![0usize];
        seen[0] = true;
        let mut count = 1;
        while let Some(v) = stack.pop() {
            for &u in &self.adj[v] {
                if norm_edge(v, u) == skip {
                    continue;
                }
                if !seen[u] {
                    seen[u] = true;
                    count += 1;
                    stack.push(u);
                }
            }
        }
        count != self.n
    }

    /// Number of vertices.
    pub fn num_vertices(&self) -> usize {
        self.n
    }

    /// Number of undirected edges.
    pub fn num_edges(&self) -> usize {
        self.edges.len()
    }

    /// Neighbors of `i` (excluding `i` itself).
    pub fn neighbors(&self, i: usize) -> &[usize] {
        &self.adj[i]
    }

    /// Degree of `i`.
    pub fn degree(&self, i: usize) -> usize {
        self.adj[i].len()
    }

    /// Whether the undirected edge `(i, j)` exists.
    pub fn has_edge(&self, i: usize, j: usize) -> bool {
        i != j && self.edges.contains(&norm_edge(i, j))
    }

    /// Iterator over normalized edges, in ascending `(min, max)` order
    /// (the edge set is a `BTreeSet`, so iteration is deterministic).
    pub fn edges(&self) -> impl Iterator<Item = (usize, usize)> + '_ {
        self.edges.iter().copied()
    }

    /// BFS connectivity over all `n` vertices.  For undirected graphs this
    /// is exactly the paper's strong-connectivity assumption.
    pub fn is_connected(&self) -> bool {
        if self.n == 0 {
            return true;
        }
        let mut seen = vec![false; self.n];
        let mut stack = vec![0usize];
        seen[0] = true;
        let mut count = 1;
        while let Some(v) = stack.pop() {
            for &u in &self.adj[v] {
                if !seen[u] {
                    seen[u] = true;
                    count += 1;
                    stack.push(u);
                }
            }
        }
        count == self.n
    }

    /// Connectivity of the subgraph induced by `vertices` using only
    /// `edge_set` edges.  Used by Pathsearch to decide epoch completion.
    pub fn subgraph_connected(
        n: usize,
        vertices: &BTreeSet<usize>,
        edge_set: &BTreeSet<(usize, usize)>,
    ) -> bool {
        if vertices.is_empty() {
            return false;
        }
        let mut adj = vec![Vec::new(); n];
        for &(i, j) in edge_set {
            adj[i].push(j);
            adj[j].push(i);
        }
        let start = *vertices.iter().next().unwrap();
        let mut seen = BTreeSet::new();
        seen.insert(start);
        let mut stack = vec![start];
        while let Some(v) = stack.pop() {
            for &u in &adj[v] {
                if vertices.contains(&u) && seen.insert(u) {
                    stack.push(u);
                }
            }
        }
        seen.len() == vertices.len()
    }

    /// Two-coloring check (bipartite graphs are what AD-PSGD formally
    /// requires to avoid deadlock; see paper §7).
    pub fn is_bipartite(&self) -> bool {
        let mut color = vec![-1i8; self.n];
        for s in 0..self.n {
            if color[s] != -1 {
                continue;
            }
            color[s] = 0;
            let mut stack = vec![s];
            while let Some(v) = stack.pop() {
                for &u in &self.adj[v] {
                    if color[u] == -1 {
                        color[u] = 1 - color[v];
                        stack.push(u);
                    } else if color[u] == color[v] {
                        return false;
                    }
                }
            }
        }
        true
    }

    /// Graph diameter via BFS from every vertex (test/diagnostic helper;
    /// O(V·E), fine for the sizes we simulate).
    pub fn diameter(&self) -> usize {
        let mut diam = 0;
        for s in 0..self.n {
            let mut dist = vec![usize::MAX; self.n];
            dist[s] = 0;
            let mut q = std::collections::VecDeque::from([s]);
            while let Some(v) = q.pop_front() {
                for &u in &self.adj[v] {
                    if dist[u] == usize::MAX {
                        dist[u] = dist[v] + 1;
                        q.push_back(u);
                    }
                }
            }
            diam = diam.max(dist.iter().copied().filter(|&d| d != usize::MAX).max().unwrap_or(0));
        }
        diam
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_graph_connected_iff_tiny() {
        assert!(Graph::empty(0).is_connected());
        assert!(Graph::empty(1).is_connected());
        assert!(!Graph::empty(2).is_connected());
    }

    #[test]
    fn add_edge_dedup_and_self_loop() {
        let mut g = Graph::empty(3);
        g.add_edge(0, 1);
        g.add_edge(1, 0);
        g.add_edge(1, 1);
        assert_eq!(g.num_edges(), 1);
        assert_eq!(g.degree(1), 1);
        assert!(!g.has_edge(1, 1));
    }

    #[test]
    fn path_graph_connectivity_and_diameter() {
        let g = Graph::from_edges(4, &[(0, 1), (1, 2), (2, 3)]);
        assert!(g.is_connected());
        assert_eq!(g.diameter(), 3);
        assert!(g.is_bipartite());
    }

    #[test]
    fn triangle_not_bipartite() {
        let g = Graph::from_edges(3, &[(0, 1), (1, 2), (2, 0)]);
        assert!(!g.is_bipartite());
        assert_eq!(g.diameter(), 1);
    }

    #[test]
    fn subgraph_connectivity() {
        let verts: BTreeSet<usize> = [0, 1, 2].into_iter().collect();
        let edges: BTreeSet<(usize, usize)> = [(0, 1), (1, 2)].into_iter().collect();
        assert!(Graph::subgraph_connected(5, &verts, &edges));
        let edges2: BTreeSet<(usize, usize)> = [(0, 1)].into_iter().collect();
        assert!(!Graph::subgraph_connected(5, &verts, &edges2));
    }

    #[test]
    fn disconnected_detected() {
        let g = Graph::from_edges(4, &[(0, 1), (2, 3)]);
        assert!(!g.is_connected());
    }

    #[test]
    fn remove_edge_keeps_adjacency_consistent() {
        let mut g = Graph::from_edges(4, &[(0, 1), (1, 2), (2, 3), (3, 0)]);
        assert!(g.remove_edge(1, 2));
        assert!(!g.has_edge(1, 2));
        assert_eq!(g.num_edges(), 3);
        assert_eq!(g.degree(1), 1);
        assert_eq!(g.degree(2), 1);
        assert!(!g.neighbors(1).contains(&2));
        assert!(!g.neighbors(2).contains(&1));
        // removing again (or a never-present edge, or a self-loop) is a no-op
        assert!(!g.remove_edge(1, 2));
        assert!(!g.remove_edge(0, 2));
        assert!(!g.remove_edge(3, 3));
        assert_eq!(g.num_edges(), 3);
        // re-adding restores both views
        g.add_edge(2, 1);
        assert!(g.has_edge(1, 2));
        assert_eq!(g.degree(1), 2);
    }

    #[test]
    fn remove_vertex_isolates() {
        let mut g = Graph::from_edges(5, &[(0, 1), (0, 2), (0, 3), (3, 4), (1, 2)]);
        assert_eq!(g.remove_vertex(0), 3);
        assert_eq!(g.degree(0), 0);
        assert_eq!(g.num_edges(), 2);
        assert!(g.has_edge(3, 4) && g.has_edge(1, 2));
        for v in 1..5 {
            assert!(!g.neighbors(v).contains(&0), "stale adjacency at {v}");
        }
        assert_eq!(g.remove_vertex(0), 0); // already isolated
    }

    #[test]
    fn would_disconnect_detects_bridges() {
        // path 0-1-2 plus triangle 2-3-4: every path edge is a bridge,
        // triangle edges are not.
        let mut g = Graph::from_edges(5, &[(0, 1), (1, 2), (2, 3), (3, 4), (4, 2)]);
        assert!(g.would_disconnect(0, 1));
        assert!(g.would_disconnect(1, 2));
        assert!(!g.would_disconnect(2, 3));
        assert!(!g.would_disconnect(3, 4));
        assert!(!g.would_disconnect(0, 3)); // absent edge: never a bridge
        // consistency with an actual removal
        g.remove_edge(2, 3);
        assert!(g.is_connected());
        assert!(g.would_disconnect(4, 2));
    }
}
