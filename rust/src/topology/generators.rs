//! Topology generators.  Every generator returns a *connected* graph; the
//! random family repairs connectivity by wiring components along a random
//! spanning chain, matching the paper's "randomly generate a connected
//! graph" setup (§6).

use super::Graph;
use crate::util::json::Json;
use crate::util::Rng64;
use anyhow::{bail, Result};
use std::collections::BTreeMap;

/// Which communication graph to build (config-selectable).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum TopologyKind {
    /// Cycle over all workers: degree 2, diameter N/2.
    Ring,
    /// Every pair connected (the paper's Figure 2 example setting).
    Complete,
    /// Erdős–Rényi `G(n, p)` with connectivity repair — the paper's
    /// "randomly generated connected graph".
    Random {
        /// Edge probability.
        p: f64,
        /// Generator seed.
        seed: u64,
    },
    /// 2-D torus grid (near-square factorization of N).
    Torus,
    /// Hub-and-spoke; worst case for decentralized gossip.
    Star,
    /// Random connected bipartite graph (what AD-PSGD formally needs).
    Bipartite {
        /// Generator seed.
        seed: u64,
    },
}

impl Default for TopologyKind {
    fn default() -> Self {
        TopologyKind::Random { p: 0.1, seed: 17 }
    }
}

impl TopologyKind {
    /// Build the graph over `n` workers.
    pub fn build(&self, n: usize) -> Graph {
        match *self {
            TopologyKind::Ring => ring(n),
            TopologyKind::Complete => complete(n),
            TopologyKind::Random { p, seed } => random_connected(n, p, seed),
            TopologyKind::Torus => torus(n),
            TopologyKind::Star => star(n),
            TopologyKind::Bipartite { seed } => bipartite(n, seed),
        }
    }

    /// Parse the config form: `{"kind": "random", "p": 0.1, "seed": 17}` or
    /// a bare string for parameterless kinds.  Strict parse: object keys
    /// the chosen kind does not take are errors (a misspelled or misplaced
    /// parameter must not be silently ignored).
    pub fn from_json(j: &Json) -> Result<Self> {
        let kind = j
            .as_str()
            .or_else(|| j.get("kind").and_then(Json::as_str))
            .unwrap_or_default()
            .to_string();
        if let Some(obj) = j.as_obj() {
            let allowed: &[&str] = match kind.as_str() {
                "random" => &["kind", "p", "seed"],
                "bipartite" => &["kind", "seed"],
                _ => &["kind"],
            };
            for key in obj.keys() {
                if !allowed.contains(&key.as_str()) {
                    bail!("unknown topology key {key:?} for kind {kind:?} (want {allowed:?})");
                }
            }
        }
        Ok(match kind.as_str() {
            "ring" => TopologyKind::Ring,
            "complete" => TopologyKind::Complete,
            "torus" => TopologyKind::Torus,
            "star" => TopologyKind::Star,
            "random" => TopologyKind::Random {
                p: j.get("p").and_then(Json::as_f64).unwrap_or(0.1),
                seed: j.get("seed").and_then(Json::as_u64).unwrap_or(17),
            },
            "bipartite" => TopologyKind::Bipartite {
                seed: j.get("seed").and_then(Json::as_u64).unwrap_or(17),
            },
            other => bail!("unknown topology kind {other:?}"),
        })
    }

    /// Inverse of [`Self::from_json`].
    pub fn to_json(&self) -> Json {
        let mut m: BTreeMap<String, Json> = BTreeMap::new();
        match *self {
            TopologyKind::Ring => m.insert("kind".into(), Json::from("ring")),
            TopologyKind::Complete => m.insert("kind".into(), Json::from("complete")),
            TopologyKind::Torus => m.insert("kind".into(), Json::from("torus")),
            TopologyKind::Star => m.insert("kind".into(), Json::from("star")),
            TopologyKind::Random { p, seed } => {
                m.insert("kind".into(), Json::from("random"));
                m.insert("p".into(), Json::Num(p));
                m.insert("seed".into(), Json::from(seed as usize))
            }
            TopologyKind::Bipartite { seed } => {
                m.insert("kind".into(), Json::from("bipartite"));
                m.insert("seed".into(), Json::from(seed as usize))
            }
        };
        Json::Obj(m)
    }
}

/// Cycle graph 0-1-2-…-(n-1)-0.
pub fn ring(n: usize) -> Graph {
    let mut g = Graph::empty(n);
    if n < 2 {
        return g;
    }
    for i in 0..n {
        g.add_edge(i, (i + 1) % n);
    }
    g
}

/// Complete graph K_n.
pub fn complete(n: usize) -> Graph {
    let mut g = Graph::empty(n);
    for i in 0..n {
        for j in (i + 1)..n {
            g.add_edge(i, j);
        }
    }
    g
}

/// Erdős–Rényi with connectivity repair: sample `G(n, p)`, then connect the
/// components along a shuffled spanning chain so the result is connected
/// while staying sparse.
pub fn random_connected(n: usize, p: f64, seed: u64) -> Graph {
    let mut rng = Rng64::seed_from_u64(seed);
    let mut g = Graph::empty(n);
    for i in 0..n {
        for j in (i + 1)..n {
            if rng.gen_f64() < p {
                g.add_edge(i, j);
            }
        }
    }
    // Connectivity repair: union-find over components, then chain them.
    let mut parent: Vec<usize> = (0..n).collect();
    fn find(parent: &mut Vec<usize>, x: usize) -> usize {
        if parent[x] != x {
            let r = find(parent, parent[x]);
            parent[x] = r;
        }
        parent[x]
    }
    for (i, j) in g.edges().collect::<Vec<_>>() {
        let (ri, rj) = (find(&mut parent, i), find(&mut parent, j));
        if ri != rj {
            parent[ri] = rj;
        }
    }
    let mut order: Vec<usize> = (0..n).collect();
    rng.shuffle(&mut order);
    for w in order.windows(2) {
        let (a, b) = (w[0], w[1]);
        let (ra, rb) = (find(&mut parent, a), find(&mut parent, b));
        if ra != rb {
            g.add_edge(a, b);
            parent[ra] = rb;
        }
    }
    g
}

/// 2-D torus on the most-square factorization of `n` (falls back to ring
/// when `n` is prime).
pub fn torus(n: usize) -> Graph {
    let mut rows = (n as f64).sqrt() as usize;
    while rows > 1 && n % rows != 0 {
        rows -= 1;
    }
    if rows <= 1 {
        return ring(n);
    }
    let cols = n / rows;
    let mut g = Graph::empty(n);
    let idx = |r: usize, c: usize| r * cols + c;
    for r in 0..rows {
        for c in 0..cols {
            g.add_edge(idx(r, c), idx(r, (c + 1) % cols));
            g.add_edge(idx(r, c), idx((r + 1) % rows, c));
        }
    }
    g
}

/// Star with hub 0.
pub fn star(n: usize) -> Graph {
    let mut g = Graph::empty(n);
    for i in 1..n {
        g.add_edge(0, i);
    }
    g
}

/// Random connected bipartite graph: split vertices in two halves, add
/// random cross edges, repair with a zig-zag chain.
pub fn bipartite(n: usize, seed: u64) -> Graph {
    let mut rng = Rng64::seed_from_u64(seed);
    let half = n / 2;
    let mut g = Graph::empty(n);
    for a in 0..half {
        for b in half..n {
            if rng.gen_f64() < 0.3 {
                g.add_edge(a, b);
            }
        }
    }
    // zig-zag spanning chain alternating sides keeps it bipartite + connected
    if half >= 1 && n > half {
        let right = n - half;
        for k in 0..n.saturating_sub(1) {
            let a = (k / 2) % half;
            let b = half + ((k + 1) / 2) % right;
            g.add_edge(a, b);
        }
    }
    g
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_properties() {
        let g = ring(8);
        assert!(g.is_connected());
        assert_eq!(g.num_edges(), 8);
        assert!((0..8).all(|i| g.degree(i) == 2));
    }

    #[test]
    fn complete_properties() {
        let g = complete(6);
        assert!(g.is_connected());
        assert_eq!(g.num_edges(), 15);
        assert_eq!(g.diameter(), 1);
    }

    #[test]
    fn random_always_connected_even_p_zero() {
        for seed in 0..20 {
            let g = random_connected(32, 0.0, seed);
            assert!(g.is_connected(), "seed {seed}");
        }
    }

    #[test]
    fn random_deterministic_per_seed() {
        let a = random_connected(16, 0.2, 5);
        let b = random_connected(16, 0.2, 5);
        assert_eq!(a, b);
    }

    #[test]
    fn torus_properties() {
        let g = torus(16); // 4x4
        assert!(g.is_connected());
        assert!((0..16).all(|i| g.degree(i) == 4));
    }

    #[test]
    fn torus_prime_falls_back_to_ring() {
        let g = torus(7);
        assert!(g.is_connected());
        assert_eq!(g.num_edges(), 7);
    }

    #[test]
    fn star_properties() {
        let g = star(10);
        assert!(g.is_connected());
        assert_eq!(g.degree(0), 9);
        assert_eq!(g.diameter(), 2);
    }

    #[test]
    fn bipartite_connected_and_two_colorable() {
        for seed in 0..10 {
            let g = bipartite(20, seed);
            assert!(g.is_connected(), "seed {seed}");
            assert!(g.is_bipartite(), "seed {seed}");
        }
    }

    #[test]
    fn kind_builds() {
        for kind in [
            TopologyKind::Ring,
            TopologyKind::Complete,
            TopologyKind::Random { p: 0.1, seed: 1 },
            TopologyKind::Torus,
            TopologyKind::Star,
            TopologyKind::Bipartite { seed: 1 },
        ] {
            assert!(kind.build(12).is_connected(), "{kind:?}");
        }
    }

    #[test]
    fn json_roundtrip() {
        for kind in [
            TopologyKind::Ring,
            TopologyKind::Random { p: 0.25, seed: 9 },
            TopologyKind::Bipartite { seed: 3 },
        ] {
            let back = TopologyKind::from_json(&kind.to_json()).unwrap();
            assert_eq!(back, kind);
        }
        // bare-string form
        assert_eq!(
            TopologyKind::from_json(&Json::from("ring")).unwrap(),
            TopologyKind::Ring
        );
        assert!(TopologyKind::from_json(&Json::from("hypercube")).is_err());
    }
}
