//! AD-PSGD [45]: fully asynchronous pairwise averaging.
//!
//! A worker that finishes its gradient immediately applies it and
//! atomically averages with one uniformly random neighbor — which may be
//! mid-computation (that in-flight gradient becomes stale) or itself busy
//! averaging (the atomic updates serialize, the conflict the Prague paper
//! highlights).  Stragglers are never waited for, but their parameters go
//! stale and keep getting mixed in, which is exactly the failure mode
//! DSGD-AAU targets (paper Fig. 1b).
//!
//! **Waiting discipline:** none — no worker ever waits for another; the
//! only serialization is the pairwise atomic-average busy horizon.
//! **Staleness semantics:** unbounded — an arbitrarily old neighbor is a
//! legal averaging partner, and in-flight gradients land on parameters
//! that moved underneath them.  Contrast [`super::HopBss`], which gates
//! every exchange on an explicit iteration-lag bound.

use super::UpdateRule;
use crate::engine::EngineCore;
use crate::WorkerId;
use crate::util::Rng64;

/// AD-PSGD state: per-worker atomic-averaging busy horizon.
#[derive(Debug)]
pub struct AdPsgd {
    rng: Rng64,
    busy_until: Vec<f64>,
}

impl AdPsgd {
    /// Fresh rule.
    pub fn new(seed: u64) -> Self {
        AdPsgd { rng: Rng64::seed_from_u64(seed), busy_until: Vec::new() }
    }
}

impl UpdateRule for AdPsgd {
    fn name(&self) -> &'static str {
        "AD-PSGD"
    }

    fn on_start(&mut self, core: &mut EngineCore) {
        self.busy_until = vec![0.0; core.num_workers()];
    }

    fn on_ready(&mut self, w: WorkerId, core: &mut EngineCore) {
        core.apply_gradient(w);
        // Live-graph neighbors are same-component by construction; under
        // partition-aware adaptivity the *observed* view additionally
        // filters peers the worker believes unreachable (a heal not yet
        // detected), so no averaging partner is sampled across a cut the
        // worker still assumes exists.
        let nbrs = core.observed_neighbors(w);
        if nbrs.is_empty() {
            // Solitary (or fully unreachable) worker: keep training alone.
            // The solo step still advances k — otherwise a fully shattered
            // fleet would freeze the iteration counter below
            // max_iterations and the run would never terminate.
            // (Unreachable in legacy mode: a connected graph with N >= 2
            // leaves no worker without neighbors.)
            core.advance_iteration();
            core.restart_after(w, 0.0);
            return;
        }
        let r = nbrs[self.rng.gen_range(nbrs.len())];

        // Values are exchanged at `end` (below); since nothing else
        // touches the pair between now and `end` in this serialization
        // model, the average itself is computed immediately.  The gossip
        // runs first so the exchange duration can be sized by what
        // actually moved (one shard under fragmentation, the full vector
        // otherwise).
        core.gossip_pair(w, r);

        // Atomic averaging: serialize on both endpoints' busy horizons.
        let now = core.now();
        let start = now.max(self.busy_until[w]).max(self.busy_until[r]);
        let dur = core.comm.gossip_time(2, core.round_wire_bytes());
        let end = start + dur;
        self.busy_until[w] = end;
        self.busy_until[r] = end;

        core.advance_iteration();

        core.restart_after(w, end - now);
        // r is untouched: if it is mid-compute, its gradient is now stale.
    }

    fn on_worker_leave(&mut self, w: WorkerId, _core: &mut EngineCore) {
        // The slot's averaging serialization dies with its occupant; a
        // future joiner inherits a free horizon.
        self.busy_until[w] = 0.0;
    }

    fn on_worker_join(&mut self, w: WorkerId, _core: &mut EngineCore) {
        self.busy_until[w] = 0.0;
    }
}
