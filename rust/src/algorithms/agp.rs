//! AGP — asynchronous gradient push [5].
//!
//! Push-sum averaging: each worker keeps a push weight `s_j`; on finishing
//! a gradient it absorbs its inbox, applies the gradient to its de-biased
//! estimate, then pushes half of its mass `(x_j, s_j/2)` to one random
//! neighbor's inbox.  The column-stochastic (not doubly stochastic) mixing
//! tolerates directed/asymmetric communication but converges slower under
//! heterogeneous update rates — matching AGP's position in the paper's
//! tables.
//!
//! We store the de-biased estimate `x = w/s` directly; a push updates the
//! receiver as `x_r ← (s_r x_r + δ x_w)/(s_r + δ)`, `s_r ← s_r + δ` with
//! `δ = s_w/2`, and the sender just halves `s_w` (its `x` is unchanged).
//!
//! **Waiting discipline:** none — pushes are fire-and-forget into the
//! receiver's inbox; nobody blocks on anybody.
//! **Staleness semantics:** unbounded — inbox messages are absorbed
//! whenever the receiver next finishes a gradient, however long that
//! takes, and carry no lag bound or expiry.

use super::UpdateRule;
use crate::engine::EngineCore;
use crate::WorkerId;
use crate::util::Rng64;

/// AGP push-sum state.
pub struct Agp {
    rng: Rng64,
    /// Push-sum weights s_j.
    weight: Vec<f64>,
    /// Inbox: pending `(lo, hi, x[lo..hi], δ)` messages per worker.  A
    /// full-vector push (the passthrough default) carries `lo = 0`,
    /// `hi = dim`; under fragmentation each push carries one scheduled
    /// shard and the mix applies to that range only.
    inbox: Vec<Vec<(usize, usize, Vec<f32>, f64)>>,
}

impl Agp {
    /// Fresh rule.
    pub fn new(seed: u64) -> Self {
        Agp { rng: Rng64::seed_from_u64(seed), weight: Vec::new(), inbox: Vec::new() }
    }

    fn absorb_inbox(&mut self, w: WorkerId, core: &mut EngineCore) {
        if self.inbox[w].is_empty() {
            return;
        }
        let msgs = std::mem::take(&mut self.inbox[w]);
        let mut s = self.weight[w];
        let mut x = core.params_of(w).to_vec();
        for (lo, hi, xi, delta) in msgs {
            let total = s + delta;
            let (a, b) = ((s / total) as f32, (delta / total) as f32);
            // a shard push mixes its range only; the rest of the receiver's
            // vector keeps its old value at the new mass (the fragment-
            // gossip approximation of push-sum)
            for (xo, xv) in x[lo..hi].iter_mut().zip(&xi) {
                *xo = a * *xo + b * *xv;
            }
            s = total;
        }
        self.weight[w] = s;
        core.set_params(w, x);
    }
}

impl UpdateRule for Agp {
    fn name(&self) -> &'static str {
        "AGP"
    }

    fn on_start(&mut self, core: &mut EngineCore) {
        let n = core.num_workers();
        self.weight = vec![1.0; n];
        self.inbox = vec![Vec::new(); n];
    }

    fn on_ready(&mut self, w: WorkerId, core: &mut EngineCore) {
        // 1. absorb pending pushes (stale by construction)
        self.absorb_inbox(w, core);
        // 2. local gradient on the de-biased estimate
        core.apply_gradient(w);
        // 3. push half of the mass to a random neighbor (under
        // partition-aware adaptivity, only to peers the worker's observed
        // component view says are reachable — pushing mass across an
        // undetected cut would strand it)
        let nbrs = core.observed_neighbors(w);
        if !nbrs.is_empty() {
            let r = nbrs[self.rng.gen_range(nbrs.len())];
            let delta = self.weight[w] / 2.0;
            self.weight[w] = (self.weight[w] - delta).max(1e-9);
            // one scheduled shard per push (the full vector in
            // passthrough), charged and delayed at its wire size
            let plan = core.fragment_plan(&[w, r]);
            self.inbox[r].push((plan.lo, plan.hi, core.wire_slice(w, &plan), delta));
            core.charge_shard_transfer(&plan);
            core.recorder.gossip_rounds += 1;
            core.recorder.group_size_sum += 2;
        }
        core.advance_iteration();
        let delay = core.comm.transfer_time(core.round_wire_bytes());
        core.restart_after(w, delay);
    }

    fn on_worker_leave(&mut self, w: WorkerId, _core: &mut EngineCore) {
        // Undelivered pushes and the departing user's residual mass
        // retire with its parameters (a small push-sum mass leak, the
        // price of an open world; the survivors' weights stay positive
        // so de-biasing remains well defined).
        self.inbox[w].clear();
        self.weight[w] = 1.0;
    }

    fn on_worker_join(&mut self, w: WorkerId, _core: &mut EngineCore) {
        // The joiner starts a fresh push-sum life with unit mass.
        self.inbox[w].clear();
        self.weight[w] = 1.0;
    }
}
