//! Decentralized update rules: DSGD-AAU, the paper's baselines, and the
//! Hop-style bounded-staleness adversary.
//!
//! Every algorithm reacts to one event — *worker w finished its local
//! gradient computation at virtual time t* — and decides who gossips with
//! whom, when iterations advance, and when workers restart computing.
//! The shared mechanics (parameter storage, Metropolis averaging, comm
//! accounting, the event queue) live in [`crate::engine::EngineCore`].

mod ad_psgd;
mod agp;
mod dsgd_aau;
mod dsgd_sync;
mod fixed_k;
mod hop_bss;
mod prague;

pub use ad_psgd::AdPsgd;
pub use agp::Agp;
pub use dsgd_aau::DsgdAau;
pub use dsgd_sync::DsgdSync;
pub use fixed_k::FixedFastest;
pub use hop_bss::HopBss;
pub use prague::Prague;

use crate::engine::EngineCore;
use crate::WorkerId;

/// Selectable update rule.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum AlgorithmKind {
    /// The paper's contribution: adaptive asynchronous updates driven by
    /// Pathsearch (Alg. 2 + 3).
    DsgdAau,
    /// Synchronous decentralized SGD (eq. 2) — full-neighbor gossip behind
    /// a global barrier; the straggler-bound baseline.
    DsgdSync,
    /// Asynchronous decentralized parallel SGD [45]: random-neighbor
    /// pairwise averaging with atomic-update serialization.
    AdPsgd,
    /// Prague [47]: partial all-reduce over randomly generated groups.
    Prague,
    /// Asynchronous gradient push [5]: push-sum averaging to one random
    /// neighbor (non-doubly-stochastic).
    Agp,
    /// Hop-style bounded-staleness scheduling (arxiv 1902.01064):
    /// per-directed-link token queues with a staleness bound, iteration
    /// skipping, and backup-worker activation, configured by the
    /// `"stale"` section.
    HopBss,
    /// Fixed-fastest-k partial participation (manually tuned group size —
    /// the stale-synchronous prior art DSGD-AAU's adaptivity replaces).
    FixedK {
        /// Workers waited for per round.
        k: usize,
    },
}

impl AlgorithmKind {
    /// Parse the snake_case config token.
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        Ok(match s {
            "dsgd_aau" => AlgorithmKind::DsgdAau,
            "dsgd_sync" => AlgorithmKind::DsgdSync,
            "ad_psgd" => AlgorithmKind::AdPsgd,
            "prague" => AlgorithmKind::Prague,
            "agp" => AlgorithmKind::Agp,
            "hop_bss" => AlgorithmKind::HopBss,
            s if s.starts_with("fixed_k") => {
                let k = s.strip_prefix("fixed_k").unwrap().parse().unwrap_or(4);
                AlgorithmKind::FixedK { k }
            }
            other => anyhow::bail!(
                "unknown algorithm {other} (dsgd_aau|dsgd_sync|ad_psgd|prague|agp|hop_bss)"
            ),
        })
    }

    /// Inverse of [`Self::parse`].
    pub fn token(&self) -> &'static str {
        match self {
            AlgorithmKind::DsgdAau => "dsgd_aau",
            AlgorithmKind::DsgdSync => "dsgd_sync",
            AlgorithmKind::AdPsgd => "ad_psgd",
            AlgorithmKind::Prague => "prague",
            AlgorithmKind::Agp => "agp",
            AlgorithmKind::HopBss => "hop_bss",
            AlgorithmKind::FixedK { .. } => "fixed_k",
        }
    }

    /// Display label used in tables (matches the paper's column names).
    pub fn label(&self) -> &'static str {
        match self {
            AlgorithmKind::DsgdAau => "DSGD-AAU",
            AlgorithmKind::DsgdSync => "DSGD",
            AlgorithmKind::AdPsgd => "AD-PSGD",
            AlgorithmKind::Prague => "Prague",
            AlgorithmKind::Agp => "AGP",
            AlgorithmKind::HopBss => "Hop-BSS",
            AlgorithmKind::FixedK { .. } => "Fixed-k",
        }
    }

    /// All algorithms, in the paper's table order.
    pub fn all() -> [AlgorithmKind; 6] {
        [
            AlgorithmKind::Agp,
            AlgorithmKind::AdPsgd,
            AlgorithmKind::Prague,
            AlgorithmKind::HopBss,
            AlgorithmKind::DsgdAau,
            AlgorithmKind::DsgdSync,
        ]
    }

    /// The four asynchronous-capable algorithms the paper's tables compare
    /// (DSGD with synchronous updates appears only in the speedup figure).
    pub fn paper_table() -> [AlgorithmKind; 4] {
        [
            AlgorithmKind::Agp,
            AlgorithmKind::AdPsgd,
            AlgorithmKind::Prague,
            AlgorithmKind::DsgdAau,
        ]
    }

    /// Instantiate the update rule.
    pub fn build(&self, prague_group: usize, seed: u64) -> Box<dyn UpdateRule> {
        match self {
            AlgorithmKind::DsgdAau => Box::new(DsgdAau::new()),
            AlgorithmKind::DsgdSync => Box::new(DsgdSync::new()),
            AlgorithmKind::AdPsgd => Box::new(AdPsgd::new(seed)),
            AlgorithmKind::Prague => Box::new(Prague::new(prague_group, seed)),
            AlgorithmKind::Agp => Box::new(Agp::new(seed)),
            AlgorithmKind::HopBss => Box::new(HopBss::new()),
            AlgorithmKind::FixedK { k } => Box::new(FixedFastest::new(*k)),
        }
    }
}

/// Walk `pending` in order and invoke `f` once per distinct *observed*
/// component, represented by its first pending member.  The shared shape
/// of every waiting rule's `on_view_changed` re-evaluation: after a
/// detected split or heal, each affected component must be re-tested for
/// firing exactly once, in a deterministic order.
pub(crate) fn for_each_distinct_component<F>(
    pending: &[WorkerId],
    core: &mut EngineCore,
    mut f: F,
) where
    F: FnMut(WorkerId, &mut EngineCore),
{
    let mut labels_seen: Vec<usize> = Vec::new();
    for &x in pending {
        let label = core.monitor.component_of(x);
        if labels_seen.contains(&label) {
            continue;
        }
        labels_seen.push(label);
        f(x, core);
    }
}

/// Event-driven decentralized update rule.
pub trait UpdateRule {
    /// Algorithm label.
    fn name(&self) -> &'static str;

    /// Worker `w` finished a local gradient computation; its gradient is
    /// stashed in the engine.  Decide gossip/restart actions.
    fn on_ready(&mut self, w: WorkerId, core: &mut EngineCore);

    /// Called once before the run starts (after all workers are scheduled).
    fn on_start(&mut self, _core: &mut EngineCore) {}

    /// The workers' observed component view changed — a split or heal was
    /// detected (partition-aware adaptivity).  Rules that *wait* must
    /// re-evaluate their pending sets here: after a split, a waiting set
    /// or barrier may already cover its entire (now smaller) component,
    /// and no further `ComputeDone` event will arrive to trigger it.
    fn on_view_changed(&mut self, _core: &mut EngineCore) {}

    /// Slot `w` was vacated (open-world membership).  Rules must forget
    /// any pending state for `w` — waiting-set entries, barrier marks,
    /// group memberships, in-flight mailbox contents — so a mid-epoch
    /// departure can never wedge the survivors.  The engine has already
    /// cancelled `w`'s in-flight compute and isolated it in the graph;
    /// component-scoped re-evaluation still arrives via
    /// [`Self::on_view_changed`] once the monitor promotes the change.
    fn on_worker_leave(&mut self, _w: WorkerId, _core: &mut EngineCore) {}

    /// Slot `w` was filled by a joining user (open-world membership).
    /// Called after the engine wired `w`'s edges and warm-started its
    /// parameters, but before `w`'s first `ComputeStart`.  Most rules
    /// need nothing; mailbox-style rules reset per-slot state here.
    fn on_worker_join(&mut self, _w: WorkerId, _core: &mut EngineCore) {}
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn labels_match_paper() {
        assert_eq!(AlgorithmKind::DsgdAau.label(), "DSGD-AAU");
        assert_eq!(AlgorithmKind::AdPsgd.label(), "AD-PSGD");
    }

    #[test]
    fn token_roundtrip() {
        for k in AlgorithmKind::all() {
            assert_eq!(AlgorithmKind::parse(k.token()).unwrap(), k);
        }
        assert_eq!(
            AlgorithmKind::parse("fixed_k6").unwrap(),
            AlgorithmKind::FixedK { k: 6 }
        );
        assert!(AlgorithmKind::parse("sgd").is_err());
    }

    #[test]
    fn build_all() {
        for k in AlgorithmKind::all() {
            let rule = k.build(4, 1);
            assert!(!rule.name().is_empty());
        }
    }
}
