//! Synchronous decentralized SGD (paper eq. 2, Fig. 1a).
//!
//! Every iteration, all N workers compute a gradient, then a global
//! barrier fires one full-graph Metropolis consensus update.  The barrier
//! makes each round as slow as the slowest worker — this is the
//! straggler-bound baseline that Figure 5's speedups are measured against.

use super::UpdateRule;
use crate::engine::EngineCore;
use crate::WorkerId;
use std::collections::HashSet;

/// Synchronous DSGD barrier state.
#[derive(Debug, Default)]
pub struct DsgdSync {
    done: HashSet<WorkerId>,
}

impl DsgdSync {
    /// Fresh rule.
    pub fn new() -> Self {
        Self::default()
    }
}

impl UpdateRule for DsgdSync {
    fn name(&self) -> &'static str {
        "DSGD"
    }

    fn on_ready(&mut self, w: WorkerId, core: &mut EngineCore) {
        self.done.insert(w);
        if self.done.len() < core.num_workers() {
            return; // barrier: wait for everyone, stragglers included
        }
        self.done.clear();

        let all: Vec<WorkerId> = (0..core.num_workers()).collect();
        for &m in &all {
            core.apply_gradient(m);
        }
        // Full-fleet Metropolis round; the engine caches the weight matrix
        // and recomputes it only after a topology change.
        core.gossip_all();
        core.advance_iteration();

        // Communication round: every worker exchanges with its neighbors;
        // the round completes when the max-degree worker has received all
        // its messages.
        let max_deg = all.iter().map(|&m| core.graph.degree(m)).max().unwrap_or(0);
        let delay = core.comm.gossip_time(max_deg + 1, core.param_bytes());
        for &m in &all {
            core.restart_after(m, delay);
        }
    }
}
