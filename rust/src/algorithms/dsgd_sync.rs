//! Synchronous decentralized SGD (paper eq. 2, Fig. 1a).
//!
//! Every iteration, all N workers compute a gradient, then a global
//! barrier fires one full-graph Metropolis consensus update.  The barrier
//! makes each round as slow as the slowest worker — this is the
//! straggler-bound baseline that Figure 5's speedups are measured against.
//!
//! **Waiting discipline:** a global barrier (per observed component in
//! partition-aware mode) — everyone waits for everyone.
//! **Staleness semantics:** zero — every consumed update is from the
//! current iteration; the price of that freshness is the straggler bound.

use super::UpdateRule;
use crate::engine::EngineCore;
use crate::WorkerId;
use std::collections::BTreeSet;

/// Synchronous DSGD barrier state.
#[derive(Debug, Default)]
pub struct DsgdSync {
    done: BTreeSet<WorkerId>,
}

impl DsgdSync {
    /// Fresh rule.
    pub fn new() -> Self {
        Self::default()
    }

    /// Component barrier (partition-aware mode): fire when every member
    /// of `rep`'s observed component is done.  Returns whether it fired.
    fn try_fire_component(&mut self, rep: WorkerId, core: &mut EngineCore) -> bool {
        let comp = core.monitor.component_members(rep);
        if !comp.iter().all(|m| self.done.contains(m)) {
            return false;
        }
        for &m in &comp {
            self.done.remove(&m);
            core.apply_gradient(m);
        }
        if comp.len() == core.num_workers() {
            // whole fleet in one component (the common state between
            // partition episodes): reuse the cached full-graph weights
            core.gossip_all();
        } else {
            let gw = crate::consensus::GroupWeights::metropolis(&core.graph, &comp);
            core.gossip(&gw);
        }
        core.advance_iteration();
        let max_deg = comp.iter().map(|&m| core.graph.degree(m)).max().unwrap_or(0);
        let delay = core.comm.gossip_time(max_deg + 1, core.round_wire_bytes());
        for &m in &comp {
            core.restart_after(m, delay);
        }
        true
    }
}

impl UpdateRule for DsgdSync {
    fn name(&self) -> &'static str {
        "DSGD"
    }

    fn on_ready(&mut self, w: WorkerId, core: &mut EngineCore) {
        self.done.insert(w);

        if core.partition_aware() {
            // Component barrier: an unreachable worker cannot join a
            // global barrier, so each observed component synchronizes on
            // its own — the straggler bound shrinks to the slowest worker
            // *of the component*.
            self.try_fire_component(w, core);
            return;
        }

        if self.done.len() < core.num_workers() {
            return; // barrier: wait for everyone, stragglers included
        }
        self.done.clear();

        let all: Vec<WorkerId> = (0..core.num_workers()).collect();
        for &m in &all {
            core.apply_gradient(m);
        }
        // Full-fleet Metropolis round; the engine caches the weight matrix
        // and recomputes it only after a topology change.
        core.gossip_all();
        core.advance_iteration();

        // Communication round: every worker exchanges with its neighbors;
        // the round completes when the max-degree worker has received all
        // its messages (each sized by what the round moved — one shard
        // under fragmentation).
        let max_deg = all.iter().map(|&m| core.graph.degree(m)).max().unwrap_or(0);
        let delay = core.comm.gossip_time(max_deg + 1, core.round_wire_bytes());
        for &m in &all {
            core.restart_after(m, delay);
        }
    }

    fn on_view_changed(&mut self, core: &mut EngineCore) {
        if !core.partition_aware() {
            return;
        }
        // After a split, a smaller component may consist entirely of
        // already-done workers; its barrier must fire now.  `done` is a
        // BTreeSet, so the iteration (and hence the event stream) is
        // already in sorted worker order.
        let done_sorted: Vec<WorkerId> = self.done.iter().copied().collect();
        super::for_each_distinct_component(&done_sorted, core, |x, core| {
            self.try_fire_component(x, core);
        });
    }

    fn on_worker_leave(&mut self, w: WorkerId, _core: &mut EngineCore) {
        // A departed worker can no longer hold a barrier: drop its done
        // mark; the component barriers re-evaluate when the monitor
        // promotes the vacancy (on_view_changed).
        self.done.remove(&w);
    }
}
