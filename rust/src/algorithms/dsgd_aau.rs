//! DSGD-AAU (paper Alg. 2): adaptive asynchronous updates via Pathsearch.
//!
//! Finished workers accumulate in a waiting set.  The moment a *novel*
//! edge (per Alg. 3) exists among the waiting workers, the iteration
//! fires: every waiting worker applies its local gradient and the whole
//! waiting set runs one Metropolis consensus update on its induced
//! subgraph; all newly visited edges/vertices are absorbed into the
//! Pathsearch sets (ID broadcast charged to the control plane).  When
//! `G' = (V, P)` spans the network and is connected, the epoch resets.
//!
//! The adaptivity is emergent: early in an epoch almost any pair of fast
//! workers triggers (small groups, no straggler waiting); as `P` fills,
//! only genuinely new edges fire, so fast workers wait just long enough
//! for information from the slow part of the graph to flow — never longer.
//!
//! **Partition-aware mode** (`adapt.partition_aware`): Pathsearch
//! retargets to the worker's *observed component*.  The epoch completes
//! when `G' = (V_c, P)` spans the component, component epochs retire
//! locally (other components keep accumulating), and when a heal merges
//! components the merged members' accumulation restarts (uninvolved
//! components keep theirs) instead of leaning on the stall
//! fallback.  With accurate views a spanning waiting set always holds a
//! novel or unvisited edge (or the component epoch already completed),
//! so `stall_fallbacks` stays at zero during partitioned phases — the
//! fallback remains only as a guard while detection latency makes a
//! worker's view lag the live graph.
//!
//! **Waiting discipline:** set-based and *adaptive* — finished workers
//! accumulate until the waiting set holds a novel Pathsearch edge, so
//! the effective group size is chosen by epoch coverage, not a knob.
//! **Staleness semantics:** zero within each firing group; cross-group
//! staleness is bounded in expectation by the epoch structure (every
//! worker must be absorbed before the epoch can complete).

use super::UpdateRule;
use crate::consensus::GroupWeights;
use crate::engine::EngineCore;
use crate::pathsearch::PathSearch;
use crate::WorkerId;

/// DSGD-AAU update rule state.
#[derive(Debug, Default)]
pub struct DsgdAau {
    waiting: Vec<WorkerId>,
    /// Observed merge events already acted on (heal-restart policy).
    seen_merges: u64,
}

impl DsgdAau {
    /// Fresh rule.
    pub fn new() -> Self {
        Self::default()
    }

    /// Fire one gossip iteration over `group` (Alg. 2 lines 4-9): absorb
    /// into Pathsearch, apply gradients, Metropolis-average, restart.
    fn fire(&mut self, group: Vec<WorkerId>, core: &mut EngineCore) {
        let new_edges = core.pathsearch.absorb_group(&core.graph, &group);
        core.recorder.control_bytes +=
            PathSearch::broadcast_bytes(core.num_workers(), new_edges);
        for &m in &group {
            core.apply_gradient(m); // w̃_j = w_j − η g_j
        }
        let gw = GroupWeights::metropolis(&core.graph, &group);
        core.gossip(&gw); // w_j = Σ_i w̃_i P_ij over N_j(k)
        core.advance_iteration();
        let delay = core.gossip_delay(group.len());
        for &m in &group {
            core.restart_after(m, delay);
        }
    }

    /// React to an observed component merge (heal): the merged
    /// components' accumulated subgraph proves nothing about the merged
    /// graph, so *their members'* `P, V` entries reset and re-accumulate —
    /// instead of the PR 2 stall fallback eventually papering over the
    /// mismatch.  Components uninvolved in the heal keep their progress.
    fn check_heal(&mut self, core: &mut EngineCore) {
        if core.monitor.observed_merges() > self.seen_merges {
            self.seen_merges = core.monitor.observed_merges();
            let members = core.monitor.take_merge_members();
            if core.heal_restart() && !members.is_empty() {
                core.pathsearch.reset_component(&members);
                core.recorder.epoch_restarts += 1;
            }
        }
    }

    /// Retire the epoch if the accumulated subgraph already spans `comp`.
    /// Called after every fire *and* on entry: a split can shrink the
    /// epoch target onto a component whose accumulation is already
    /// complete, and without the entry check that completion would
    /// masquerade as a stall (no novel pair, fallback gated off).
    fn retire_if_complete(&mut self, comp: &[WorkerId], core: &mut EngineCore) {
        if comp.len() == core.num_workers() {
            if core.pathsearch.is_complete(&core.graph) {
                core.pathsearch.reset_epoch();
            }
        } else if core.pathsearch.is_complete_within(&core.graph, comp) {
            core.pathsearch.reset_component(comp);
            // a solitary worker trivially "spans" itself every round —
            // only multi-worker completions count as component epochs
            if comp.len() > 1 {
                core.recorder.component_epochs += 1;
            }
        }
    }

    /// Component-retargeted firing test for `rep`'s observed component.
    /// Fires one iteration when the waiting members hold a novel edge, or
    /// when the entire component is waiting.  Returns whether it fired.
    fn try_fire_component(&mut self, rep: WorkerId, core: &mut EngineCore) -> bool {
        let comp = core.monitor.component_members(rep);
        self.retire_if_complete(&comp, core);
        let ready: Vec<WorkerId> =
            self.waiting.iter().copied().filter(|x| comp.contains(x)).collect();
        if ready.is_empty() {
            return false;
        }
        if core.pathsearch.find_novel_pair_within(&core.graph, &ready, &comp).is_none() {
            if ready.len() < comp.len() {
                return false; // keep waiting for the rest of the component
            }
            // The whole component is waiting with no usable edge.  With an
            // accurate view this is unreachable (see module docs); it can
            // happen only while detection latency leaves the observed
            // component stale.  Fire the liveness fallback, except for a
            // solitary worker, which simply keeps training alone.
            if comp.len() > 1 {
                core.recorder.stall_fallbacks += 1;
            }
        }
        self.waiting.retain(|x| !ready.contains(x));
        self.fire(ready, core);
        self.retire_if_complete(&comp, core);
        true
    }
}

impl UpdateRule for DsgdAau {
    fn name(&self) -> &'static str {
        "DSGD-AAU"
    }

    fn on_ready(&mut self, w: WorkerId, core: &mut EngineCore) {
        debug_assert!(!self.waiting.contains(&w), "worker {w} ready twice");
        self.waiting.push(w);

        if core.partition_aware() {
            self.check_heal(core);
            self.try_fire_component(w, core);
            return;
        }

        // Alg. 3: does the waiting set now contain a novel edge?
        if core.pathsearch.find_novel_pair(&core.graph, &self.waiting).is_none() {
            if self.waiting.len() < core.num_workers() {
                return; // keep waiting (worker idles; straggler may still matter)
            }
            // Liveness guard: every worker is now waiting, so no
            // ComputeDone/ComputeStart event is left in the queue and
            // returning here would quiesce the run silently before
            // max_iterations (reachable once churn's `prune_missing`
            // leaves the epoch without a usable novel edge).  Fire a
            // fallback Metropolis round over the whole waiting set
            // instead — one plain consensus step that restarts the fleet
            // and lets Pathsearch re-accumulate on the live graph.
            core.recorder.stall_fallbacks += 1;
        }

        // The iteration fires: all waiting workers participate (Alg. 2
        // lines 4-9 — j_k plus every i_k that finished during Pathsearch).
        let group = std::mem::take(&mut self.waiting);
        self.fire(group, core);

        if core.pathsearch.is_complete(&core.graph) {
            core.pathsearch.reset_epoch();
        }
    }

    fn on_view_changed(&mut self, core: &mut EngineCore) {
        if !core.partition_aware() {
            return;
        }
        self.check_heal(core);
        // A split may have left an entire (smaller) component waiting; a
        // merge may have created the novel edge a waiting set lacked.
        // Walk the distinct observed components of the waiting workers in
        // arrival order (deterministic) and fire whichever can.
        let snapshot = self.waiting.clone();
        super::for_each_distinct_component(&snapshot, core, |x, core| {
            self.try_fire_component(x, core);
        });
    }

    fn on_worker_leave(&mut self, w: WorkerId, _core: &mut EngineCore) {
        // A departed waiter can no longer contribute a novel edge; the
        // engine has already pruned its Pathsearch state (its edges left
        // the graph with it), and the shrunken component re-evaluates via
        // on_view_changed once the monitor promotes the vacancy.
        self.waiting.retain(|x| *x != w);
    }
}
