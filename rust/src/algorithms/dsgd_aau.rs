//! DSGD-AAU (paper Alg. 2): adaptive asynchronous updates via Pathsearch.
//!
//! Finished workers accumulate in a waiting set.  The moment a *novel*
//! edge (per Alg. 3) exists among the waiting workers, the iteration
//! fires: every waiting worker applies its local gradient and the whole
//! waiting set runs one Metropolis consensus update on its induced
//! subgraph; all newly visited edges/vertices are absorbed into the
//! Pathsearch sets (ID broadcast charged to the control plane).  When
//! `G' = (V, P)` spans the network and is connected, the epoch resets.
//!
//! The adaptivity is emergent: early in an epoch almost any pair of fast
//! workers triggers (small groups, no straggler waiting); as `P` fills,
//! only genuinely new edges fire, so fast workers wait just long enough
//! for information from the slow part of the graph to flow — never longer.

use super::UpdateRule;
use crate::consensus::GroupWeights;
use crate::engine::EngineCore;
use crate::pathsearch::PathSearch;
use crate::WorkerId;

/// DSGD-AAU update rule state.
#[derive(Debug, Default)]
pub struct DsgdAau {
    waiting: Vec<WorkerId>,
}

impl DsgdAau {
    /// Fresh rule.
    pub fn new() -> Self {
        Self::default()
    }
}

impl UpdateRule for DsgdAau {
    fn name(&self) -> &'static str {
        "DSGD-AAU"
    }

    fn on_ready(&mut self, w: WorkerId, core: &mut EngineCore) {
        debug_assert!(!self.waiting.contains(&w), "worker {w} ready twice");
        self.waiting.push(w);

        // Alg. 3: does the waiting set now contain a novel edge?
        if core.pathsearch.find_novel_pair(&core.graph, &self.waiting).is_none() {
            if self.waiting.len() < core.num_workers() {
                return; // keep waiting (worker idles; straggler may still matter)
            }
            // Liveness guard: every worker is now waiting, so no
            // ComputeDone/ComputeStart event is left in the queue and
            // returning here would quiesce the run silently before
            // max_iterations (reachable once churn's `prune_missing`
            // leaves the epoch without a usable novel edge).  Fire a
            // fallback Metropolis round over the whole waiting set
            // instead — one plain consensus step that restarts the fleet
            // and lets Pathsearch re-accumulate on the live graph.
            core.recorder.stall_fallbacks += 1;
        }

        // The iteration fires: all waiting workers participate (Alg. 2
        // lines 4-9 — j_k plus every i_k that finished during Pathsearch).
        let group = std::mem::take(&mut self.waiting);
        let new_edges = core.pathsearch.absorb_group(&core.graph, &group);
        core.recorder.control_bytes +=
            PathSearch::broadcast_bytes(core.num_workers(), new_edges);

        for &m in &group {
            core.apply_gradient(m); // w̃_j = w_j − η g_j
        }
        let gw = GroupWeights::metropolis(&core.graph, &group);
        core.gossip(&gw); // w_j = Σ_i w̃_i P_ij over N_j(k)
        core.advance_iteration();

        if core.pathsearch.is_complete(&core.graph) {
            core.pathsearch.reset_epoch();
        }

        let delay = core.gossip_delay(group.len());
        for &m in &group {
            core.restart_after(m, delay);
        }
    }
}
