//! Fixed-fastest-k baseline (the manually-configured partial-participation
//! scheme of the paper's related work, e.g. Xu et al. [74] / the
//! stale-synchronous configurations of [13, 23]).
//!
//! Every iteration waits for the first `k` workers to finish, then runs a
//! Metropolis consensus among them.  This is what DSGD-AAU's *adaptive*
//! group sizing is argued against: a fixed k must be tuned per workload
//! (too small → slow information diffusion, too large → stragglers are
//! back in the critical path), whereas Pathsearch sizes groups by what
//! the epoch still needs.  `bench fixedk` sweeps k.
//!
//! **Waiting discipline:** set-based with a fixed quota — each round
//! waits for the first `k` finishers (clamped to the observed component).
//! **Staleness semantics:** zero within a round's group, but the N−k
//! excluded workers' parameters age without bound between the rounds
//! that happen to include them.

use super::UpdateRule;
use crate::consensus::GroupWeights;
use crate::engine::EngineCore;
use crate::WorkerId;

/// Wait-for-first-k update rule.
#[derive(Debug)]
pub struct FixedFastest {
    k: usize,
    waiting: Vec<WorkerId>,
}

impl FixedFastest {
    /// Gossip among the first `k >= 2` finishers of each round.
    pub fn new(k: usize) -> Self {
        FixedFastest { k: k.max(2), waiting: Vec::new() }
    }
}

impl FixedFastest {
    /// One round over `group`: gradients, Metropolis consensus, restart.
    fn fire(group: &[WorkerId], core: &mut EngineCore) {
        for &m in group {
            core.apply_gradient(m);
        }
        let gw = GroupWeights::metropolis(&core.graph, group);
        core.gossip(&gw);
        core.advance_iteration();
        let delay = core.gossip_delay(group.len());
        for &m in group {
            core.restart_after(m, delay);
        }
    }

    /// Component-clamped round (partition-aware mode): fire the first
    /// `min(k, |component|)` finishers of `rep`'s observed component.
    /// Returns whether it fired.
    fn try_fire_component(&mut self, rep: WorkerId, core: &mut EngineCore) -> bool {
        let comp = core.monitor.component_members(rep);
        let mut ready: Vec<WorkerId> =
            self.waiting.iter().copied().filter(|x| comp.contains(x)).collect();
        let k_eff = self.k.min(comp.len());
        if ready.is_empty() || ready.len() < k_eff {
            return false;
        }
        // A merge can pool more than k waiting workers at once; the group
        // stays at the fixed size — that is the algorithm under test —
        // and the rest fire on subsequent rounds.
        ready.truncate(k_eff);
        self.waiting.retain(|x| !ready.contains(x));
        Self::fire(&ready, core);
        true
    }
}

impl UpdateRule for FixedFastest {
    fn name(&self) -> &'static str {
        "Fixed-k"
    }

    fn on_ready(&mut self, w: WorkerId, core: &mut EngineCore) {
        self.waiting.push(w);
        if core.partition_aware() {
            // Wait for the first k finishers *of w's component* — an
            // unreachable straggler must not hold the round hostage, and
            // k clamps to the component size so small components (down
            // to a solitary worker) keep making progress.
            self.try_fire_component(w, core);
            return;
        }
        if self.waiting.len() < self.k.min(core.num_workers()) {
            return;
        }
        let group = std::mem::take(&mut self.waiting);
        Self::fire(&group, core);
    }

    fn on_view_changed(&mut self, core: &mut EngineCore) {
        if !core.partition_aware() {
            return;
        }
        // After a split, min(k, |component|) may already be satisfied by
        // workers that were waiting on peers now unreachable.
        let snapshot = self.waiting.clone();
        super::for_each_distinct_component(&snapshot, core, |x, core| {
            self.try_fire_component(x, core);
        });
    }

    fn on_worker_leave(&mut self, w: WorkerId, _core: &mut EngineCore) {
        // A departed finisher must not be counted toward (or gossiped
        // into) a future first-k group.
        self.waiting.retain(|x| *x != w);
    }
}

#[cfg(test)]
mod tests {

    use crate::config::{BackendKind, ExperimentConfig};
    use crate::coordinator::run_experiment;

    fn cfg(k: usize) -> ExperimentConfig {
        let mut cfg = ExperimentConfig::default();
        cfg.num_workers = 8;
        cfg.algorithm = crate::algorithms::AlgorithmKind::FixedK { k };
        cfg.backend = BackendKind::Quadratic;
        cfg.max_iterations = 400;
        cfg.eval_every = 100;
        cfg.mean_compute = 0.01;
        cfg
    }

    #[test]
    fn fixed_k_learns() {
        let s = run_experiment(&cfg(4)).unwrap();
        let first = s.recorder.curve.first().unwrap().loss;
        assert!(s.final_loss() < first, "{first} -> {}", s.final_loss());
        // group size is pinned at k
        assert!((s.recorder.mean_group_size() - 4.0).abs() < 1e-9);
    }

    #[test]
    fn k_clamped_to_fleet() {
        let s = run_experiment(&cfg(64)).unwrap(); // k > N
        assert!(s.iterations > 0);
        assert!((s.recorder.mean_group_size() - 8.0).abs() < 1e-9);
    }

    #[test]
    fn small_k_faster_iterations_than_large_k() {
        // smaller groups fire earlier -> more iterations per virtual second
        let fast = run_experiment(&cfg(2)).unwrap();
        let slow = run_experiment(&cfg(8)).unwrap();
        let r_fast = fast.iterations as f64 / fast.virtual_time;
        let r_slow = slow.iterations as f64 / slow.virtual_time;
        assert!(r_fast > r_slow, "k=2 {r_fast:.1} it/s vs k=8 {r_slow:.1} it/s");
    }
}
