//! Prague [47]: partial all-reduce over randomly generated groups.
//!
//! A central "group generator" hands each finishing worker a random group;
//! the group's partial all-reduce runs only when *all* members have
//! finished their current local computation.  Random membership means a
//! straggler regularly lands in a group and stalls it — the paper's
//! explanation for Prague trailing DSGD-AAU (Appendix A).

use super::UpdateRule;
use crate::consensus::GroupWeights;
use crate::engine::EngineCore;
use crate::WorkerId;
use crate::util::Rng64;
use std::collections::HashSet;

struct Group {
    members: Vec<WorkerId>,
    ready: HashSet<WorkerId>,
}

/// Prague group-generator state.
pub struct Prague {
    group_size: usize,
    rng: Rng64,
    /// `assignment[w]` = open group index, if any.
    assignment: Vec<Option<usize>>,
    groups: Vec<Option<Group>>,
}

impl Prague {
    /// `group_size` members per partial all-reduce (paper's G).
    pub fn new(group_size: usize, seed: u64) -> Self {
        Prague {
            group_size: group_size.max(2),
            rng: Rng64::seed_from_u64(seed),
            assignment: Vec::new(),
            groups: Vec::new(),
        }
    }

    fn alloc_group(&mut self, seed_worker: WorkerId, n: usize) -> usize {
        // sample distinct unassigned peers (the generator doesn't know who
        // is slow — that is the point)
        let mut candidates: Vec<WorkerId> =
            (0..n).filter(|&x| x != seed_worker && self.assignment[x].is_none()).collect();
        self.rng.shuffle(&mut candidates);
        let mut members = vec![seed_worker];
        members.extend(candidates.into_iter().take(self.group_size - 1));
        let gid = self
            .groups
            .iter()
            .position(Option::is_none)
            .unwrap_or_else(|| {
                self.groups.push(None);
                self.groups.len() - 1
            });
        for &m in &members {
            self.assignment[m] = Some(gid);
        }
        self.groups[gid] = Some(Group { members, ready: HashSet::new() });
        gid
    }
}

impl UpdateRule for Prague {
    fn name(&self) -> &'static str {
        "Prague"
    }

    fn on_start(&mut self, core: &mut EngineCore) {
        self.assignment = vec![None; core.num_workers()];
    }

    fn on_ready(&mut self, w: WorkerId, core: &mut EngineCore) {
        let gid = match self.assignment[w] {
            Some(g) => g,
            None => self.alloc_group(w, core.num_workers()),
        };
        let complete = {
            let group = self.groups[gid].as_mut().expect("group exists");
            group.ready.insert(w);
            group.ready.len() == group.members.len()
        };
        if !complete {
            return; // wait for the rest of the randomly chosen group
        }
        let group = self.groups[gid].take().expect("group exists");
        for &m in &group.members {
            self.assignment[m] = None;
            core.apply_gradient(m);
        }
        // Partial all-reduce = uniform average over the group (Prague's
        // groups ignore the topology; its all-reduce is logical).
        let gw = GroupWeights::uniform(&group.members);
        // ring all-reduce: 2(m-1) parameter-sized message steps
        let m_len = group.members.len() as u64;
        let bytes = 2 * (m_len - 1) * core.param_bytes();
        core.gossip_costed(&gw, bytes);
        core.advance_iteration();

        // Ring all-reduce cost: 2(m−1) message steps.
        let m = group.members.len();
        let delay = 2.0 * (m as f64 - 1.0) * core.comm.transfer_time(core.param_bytes());
        for &mb in &group.members {
            core.restart_after(mb, delay);
        }
    }
}
