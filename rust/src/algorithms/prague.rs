//! Prague [47]: partial all-reduce over randomly generated groups.
//!
//! A central "group generator" hands each finishing worker a random group;
//! the group's partial all-reduce runs only when *all* members have
//! finished their current local computation.  Random membership means a
//! straggler regularly lands in a group and stalls it — the paper's
//! explanation for Prague trailing DSGD-AAU (Appendix A).
//!
//! **Waiting discipline:** set-based with random membership — a group's
//! partial all-reduce waits for its slowest member.
//! **Staleness semantics:** zero within a group; groups run concurrently
//! against each other without any cross-group freshness guarantee.

use super::UpdateRule;
use crate::consensus::GroupWeights;
use crate::engine::EngineCore;
use crate::WorkerId;
use crate::util::Rng64;
use std::collections::BTreeSet;

struct Group {
    members: Vec<WorkerId>,
    ready: BTreeSet<WorkerId>,
}

/// Prague group-generator state.
pub struct Prague {
    group_size: usize,
    rng: Rng64,
    /// `assignment[w]` = open group index, if any.
    assignment: Vec<Option<usize>>,
    groups: Vec<Option<Group>>,
}

impl Prague {
    /// `group_size` members per partial all-reduce (paper's G).
    pub fn new(group_size: usize, seed: u64) -> Self {
        Prague {
            group_size: group_size.max(2),
            rng: Rng64::seed_from_u64(seed),
            assignment: Vec::new(),
            groups: Vec::new(),
        }
    }

    fn alloc_group(&mut self, seed_worker: WorkerId, core: &EngineCore) -> usize {
        let n = core.num_workers();
        // sample distinct unassigned peers (the generator doesn't know who
        // is slow — that is the point).  Under partition-aware adaptivity
        // the generator stops sampling peers outside the seed worker's
        // observed component: a group spanning a cut could never complete
        // its all-reduce, so membership stays component-local (and the
        // group size degrades gracefully to what the component can offer).
        let mut candidates: Vec<WorkerId> = (0..n)
            .filter(|&x| x != seed_worker && self.assignment[x].is_none())
            .filter(|&x| {
                !core.partition_aware() || core.monitor.same_component_observed(seed_worker, x)
            })
            .collect();
        self.rng.shuffle(&mut candidates);
        let mut members = vec![seed_worker];
        members.extend(candidates.into_iter().take(self.group_size - 1));
        let gid = self.free_slot();
        for &m in &members {
            self.assignment[m] = Some(gid);
        }
        self.groups[gid] = Some(Group { members, ready: BTreeSet::new() });
        gid
    }

    /// First vacant group slot (allocating one if needed).
    fn free_slot(&mut self) -> usize {
        self.groups.iter().position(Option::is_none).unwrap_or_else(|| {
            self.groups.push(None);
            self.groups.len() - 1
        })
    }

    /// Run a completed group: gradients, per-reachable-sub-group ring
    /// all-reduce, iteration advance, member restarts.
    fn fire_group(&mut self, group: Group, core: &mut EngineCore) {
        for &m in &group.members {
            self.assignment[m] = None;
            core.apply_gradient(m);
        }
        // Partial all-reduce = uniform average over the group (Prague's
        // groups ignore the topology; its all-reduce is logical).  Under
        // partition-aware adaptivity a group allocated before a cut may
        // still straddle it at fire time (the proactive rebuild runs only
        // on *adopted* splits) — the all-reduce then runs per reachable
        // sub-group, never averaging across a detected partition.
        let subgroups: Vec<Vec<WorkerId>> = if core.partition_aware() {
            let mut by_label: std::collections::BTreeMap<usize, Vec<WorkerId>> =
                std::collections::BTreeMap::new();
            for &m in &group.members {
                by_label.entry(core.monitor.component_of(m)).or_default().push(m);
            }
            by_label.into_values().collect()
        } else {
            vec![group.members]
        };
        // Ring all-reduce: 2(m−1) message steps per sub-group, each step
        // sized by what the round actually moved (one shard under
        // fragmentation), so the delay is read back right after the
        // gossip that set it.  A stranded singleton skips the collective
        // entirely and restarts immediately.
        let mut delays = Vec::with_capacity(subgroups.len());
        for sub in &subgroups {
            if sub.len() >= 2 {
                let gw = GroupWeights::uniform(sub);
                core.gossip_costed(&gw, 2 * (sub.len() as u64 - 1));
                delays.push(
                    2.0 * (sub.len() as f64 - 1.0)
                        * core.comm.transfer_time(core.round_wire_bytes()),
                );
            } else {
                delays.push(0.0);
            }
        }
        core.advance_iteration();

        for (sub, delay) in subgroups.iter().zip(delays) {
            for &mb in sub {
                core.restart_after(mb, delay);
            }
        }
    }
}

impl UpdateRule for Prague {
    fn name(&self) -> &'static str {
        "Prague"
    }

    fn on_start(&mut self, core: &mut EngineCore) {
        self.assignment = vec![None; core.num_workers()];
    }

    fn on_ready(&mut self, w: WorkerId, core: &mut EngineCore) {
        let gid = match self.assignment[w] {
            Some(g) => g,
            None => self.alloc_group(w, core),
        };
        let complete = {
            let group = self.groups[gid].as_mut().expect("group exists");
            group.ready.insert(w);
            group.ready.len() == group.members.len()
        };
        if !complete {
            return; // wait for the rest of the randomly chosen group
        }
        let group = self.groups[gid].take().expect("group exists");
        self.fire_group(group, core);
    }

    fn on_view_changed(&mut self, core: &mut EngineCore) {
        if !core.partition_aware() {
            return;
        }
        // Proactive regrouping: the moment a split is *adopted*, rebuild
        // every group that straddles the cut instead of letting stranded
        // members wait for peers that can no longer reach them.  Each
        // straddler is partitioned by observed component with its ready
        // marks preserved; a fragment whose members have all finished
        // fires immediately, the rest keep waiting as smaller groups.
        for gid in 0..self.groups.len() {
            let straddles = match &self.groups[gid] {
                Some(g) => {
                    let l0 = core.monitor.component_of(g.members[0]);
                    g.members.iter().any(|&m| core.monitor.component_of(m) != l0)
                }
                None => false,
            };
            if !straddles {
                continue;
            }
            let old = self.groups[gid].take().expect("straddling group exists");
            core.recorder.prague_regroups += 1;
            let mut by_label: std::collections::BTreeMap<usize, Group> =
                std::collections::BTreeMap::new();
            for &m in &old.members {
                let frag = by_label
                    .entry(core.monitor.component_of(m))
                    .or_insert_with(|| Group { members: Vec::new(), ready: BTreeSet::new() });
                frag.members.push(m);
                if old.ready.contains(&m) {
                    frag.ready.insert(m);
                }
            }
            for (_, frag) in by_label {
                if frag.ready.len() == frag.members.len() {
                    self.fire_group(frag, core);
                } else {
                    let slot = self.free_slot();
                    for &m in &frag.members {
                        self.assignment[m] = Some(slot);
                    }
                    self.groups[slot] = Some(frag);
                }
            }
        }
    }

    fn on_worker_leave(&mut self, w: WorkerId, core: &mut EngineCore) {
        // Shrink the departed worker's group in place (a rebuild counted
        // as a regroup); if the survivors have all finished, the smaller
        // group fires now — a mid-epoch departure never wedges it.
        let Some(gid) = self.assignment[w] else { return };
        self.assignment[w] = None;
        core.recorder.prague_regroups += 1;
        let (empty, complete) = {
            let g = self.groups[gid].as_mut().expect("assigned group exists");
            g.members.retain(|x| *x != w);
            g.ready.remove(&w);
            (g.members.is_empty(), !g.members.is_empty() && g.ready.len() == g.members.len())
        };
        if empty {
            self.groups[gid] = None;
        } else if complete {
            let g = self.groups[gid].take().expect("group exists");
            self.fire_group(g, core);
        }
    }
}
