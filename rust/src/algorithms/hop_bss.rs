//! Hop-BSS: bounded-staleness scheduling (Hop, arxiv 1902.01064).
//!
//! **Waiting discipline:** queue-based, not set-based.  Nobody waits for
//! a *set* of peers (DSGD-AAU) or a barrier (DSGD); instead every
//! directed link carries a token queue ([`crate::stale::TokenQueue`]) and
//! each worker keeps a local iteration clock.  A finished worker
//! exchanges with an in-bound neighbor immediately; it waits only when
//! every outgoing queue is full — the producer-blocking case — and even
//! then the skip and backup policies usually fire first.
//!
//! **Staleness semantics:** an update is consumed only while the
//! producer/consumer iteration lag is at most the configured bound `s`
//! (every `gossip_pair` below is gated on it).  A worker whose entire
//! neighborhood fell more than `s` behind may *skip* (advance alone)
//! while queue room remains; once saturated, a designated backup clones
//! the straggler's role, and failing that the worker parks until the
//! laggard's clock advances (the stall lands in
//! `Recorder::queue_block_time`).  A worker that itself fell more than
//! `s` behind its whole neighborhood drops its overdue gradient and
//! pulls the freshest neighbor's parameters — Hop discards overdue work
//! rather than consuming it stale.
//!
//! All bounded-staleness bookkeeping (clocks, queues, parked workers,
//! observed-slow evidence) lives in [`crate::stale::StaleState`], owned
//! by the engine; this rule drives it and performs the parameter
//! movement.  Exchanges are charged like AD-PSGD pairs and sized by
//! [`EngineCore::round_wire_bytes`], so the rule composes with the
//! fragment wire unchanged.

use super::UpdateRule;
use crate::engine::EngineCore;
use crate::WorkerId;

/// Hop-BSS rule state: the atomic-exchange busy horizons.  Clocks,
/// queues, and policy knobs live in the engine's [`crate::stale`] state.
#[derive(Debug, Default)]
pub struct HopBss {
    busy_until: Vec<f64>,
}

impl HopBss {
    /// Fresh rule; scheduling randomness comes from the engine's
    /// `seed_for("stale")` stream.
    pub fn new() -> Self {
        HopBss { busy_until: Vec::new() }
    }

    /// Neighbor with the highest iteration clock (first wins ties).
    /// Callers guarantee `nbrs` is non-empty.
    fn freshest(core: &EngineCore, nbrs: &[WorkerId]) -> WorkerId {
        let mut best = nbrs[0];
        for &r in &nbrs[1..] {
            if core.stale.clock(r) > core.stale.clock(best) {
                best = r;
            }
        }
        best
    }

    /// Bounded-staleness exchange: drain both token queues, record the
    /// consumed staleness, average the pair, and restart the initiator
    /// `w` after the (fragment-sized) exchange delay.
    fn exchange(&mut self, core: &mut EngineCore, w: WorkerId, r: WorkerId) {
        let staleness = core.stale.consume_exchange(w, r);
        debug_assert!(staleness <= core.stale.config().bound, "consumed lag {staleness} > bound");
        core.recorder.note_staleness(staleness);
        core.gossip_pair(w, r);
        let now = core.now();
        let start = now.max(self.busy_until[w]).max(self.busy_until[r]);
        let dur = core.comm.gossip_time(2, core.round_wire_bytes());
        let end = start + dur;
        self.busy_until[w] = end;
        self.busy_until[r] = end;
        core.restart_after(w, end - now);
    }

    /// One-way parameter pull `donor -> sink` (resync and backup-clone
    /// paths): the sink consumes the donor's *current* state, so the
    /// consumed staleness is zero; its clock jumps to the donor's and its
    /// queues drain.
    fn pull(core: &mut EngineCore, sink: WorkerId, donor: WorkerId) {
        let v = core.params_of(donor).to_vec();
        core.set_params(sink, v);
        core.charge_param_bytes(core.param_bytes());
        let now = core.now();
        core.stale.resync(sink, donor, now);
        core.recorder.note_staleness(0);
    }

    /// Release every waiter parked on `target` after its clock moved:
    /// account the stall, then exchange (back in bound), re-park (still
    /// out of bound), or restart (target lost / leapfrogged).
    fn release_waiters(&mut self, core: &mut EngineCore, target: WorkerId) {
        let now = core.now();
        let released = core.stale.release(target, now);
        for (v, waited) in released {
            core.recorder.queue_block_time += waited;
            if !core.is_active(v) {
                continue;
            }
            let bound = core.stale.config().bound as i64;
            let lag = core.stale.lag(v, target);
            if core.is_active(target) && lag.abs() <= bound {
                self.exchange(core, v, target);
            } else if core.is_active(target) && lag > bound {
                core.stale.park(v, target, now);
            } else {
                // Target vacated, or a resync jumped it past the waiter:
                // let the waiter re-decide from its own event.
                core.restart_after(v, 0.0);
            }
        }
    }

    /// Backup activation: the first designated backup slot clones the
    /// straggler's role at `w`'s frontier.  Returns `false` when no
    /// usable backup slot exists (caller falls through to blocking).
    fn activate_backup(&mut self, core: &mut EngineCore, w: WorkerId, straggler: WorkerId) -> bool {
        let slots = core.stale.backup_slots();
        let b = match slots
            .into_iter()
            .find(|&b| b != w && b != straggler && core.is_active(b) && !core.stale.is_parked(b))
        {
            Some(b) => b,
            None => return false,
        };
        // The backup adopts the caller's current parameters (a fresh
        // pull: staleness zero, clock jumps to w's) ...
        Self::pull(core, b, w);
        // ... and reseeds the straggler from its own now-frontier state,
        // so the fleet stops accruing token debt against it.  The
        // straggler's in-flight gradient stays scheduled and lands on the
        // reseeded parameters — standard async semantics.
        Self::pull(core, straggler, b);
        core.recorder.backup_activations += 1;
        // Both clocks jumped to the frontier: waiters parked on either
        // can proceed.
        self.release_waiters(core, b);
        self.release_waiters(core, straggler);
        // w has an in-bound partner again — exchange with the clone.
        self.exchange(core, w, b);
        true
    }
}

impl UpdateRule for HopBss {
    fn name(&self) -> &'static str {
        "Hop-BSS"
    }

    fn on_start(&mut self, core: &mut EngineCore) {
        self.busy_until = vec![0.0; core.num_workers()];
    }

    fn on_ready(&mut self, w: WorkerId, core: &mut EngineCore) {
        let now = core.now();
        let nbrs = core.observed_neighbors(w);
        let (bound, allow_skip, allow_backup) = {
            let cfg = core.stale.config();
            (cfg.bound, cfg.skip, cfg.backup)
        };

        // Fell more than `s` behind the whole neighborhood?  The local
        // gradient is `s`+ iterations overdue — Hop drops it rather than
        // let neighbors consume it stale.  Pull the freshest neighbor's
        // parameters (one full-vector message) and rejoin at its clock.
        if !nbrs.is_empty() && core.stale.in_bound(w, &nbrs).is_empty() {
            let f = Self::freshest(core, &nbrs);
            if core.stale.lag(f, w) > bound as i64 {
                core.discard_stash(w);
                Self::pull(core, w, f);
                core.advance_iteration();
                // The clock jump can bring waiters parked on `w` back in
                // bound.
                self.release_waiters(core, w);
                let dur = core.comm.gossip_time(2, core.param_bytes());
                let start = now.max(self.busy_until[w]).max(self.busy_until[f]);
                let end = start + dur;
                self.busy_until[w] = end;
                self.busy_until[f] = end;
                core.restart_after(w, end - now);
                return;
            }
        }

        // Normal local step: apply the gradient, advance the clock, and
        // publish one token into every outgoing queue.
        core.apply_gradient(w);
        core.stale.advance(w, now, &nbrs);
        core.advance_iteration();
        self.release_waiters(core, w);

        if nbrs.is_empty() {
            // Solitary worker: keep training alone (same liveness
            // argument as AD-PSGD — a shattered fleet must still advance
            // k toward max_iterations).
            core.restart_after(w, 0.0);
            return;
        }

        let in_bound = core.stale.in_bound(w, &nbrs);
        if !in_bound.is_empty() {
            // Stalest-link-first: drain the fullest token queue (ties
            // broken by the seeded scheduling stream).
            let scores: Vec<u64> = in_bound
                .iter()
                .map(|&r| core.stale.occupancy(w, r) + core.stale.occupancy(r, w))
                .collect();
            let best = scores.iter().copied().max().unwrap_or(0);
            let tied: Vec<WorkerId> = in_bound
                .iter()
                .copied()
                .zip(scores)
                .filter(|&(_, s)| s == best)
                .map(|(r, _)| r)
                .collect();
            let r = tied[core.stale.pick(tied.len())];
            self.exchange(core, w, r);
            return;
        }

        // The whole neighborhood is more than `s` behind.  The nearest
        // laggard (highest clock) is the one worth waiting on.
        let r_star = Self::freshest(core, &nbrs);

        // Skip-iteration: advance alone while some outgoing queue still
        // has room.
        if allow_skip && !core.stale.producers_saturated(w, &nbrs) {
            core.recorder.stale_skips += 1;
            core.restart_after(w, 0.0);
            return;
        }

        // Backup activation: requires the laggard's observed slow state
        // to have persisted past the threshold (parked peers are stalled,
        // not slow, and are never cloned over).
        if allow_backup
            && core.stale.observed_slow(r_star, now)
            && self.activate_backup(core, w, r_star)
        {
            return;
        }

        // Producer blocks: every queue is full and no policy applies.
        // The gossip is deferred in virtual time — `w` parks until
        // `r_star`'s clock advances (released from `r_star`'s next
        // `on_ready`, a leave, or a view change).
        core.stale.park(w, r_star, now);
    }

    fn on_view_changed(&mut self, core: &mut EngineCore) {
        // Parked waiters may be blocked on peers the new view no longer
        // reaches: release everyone, account the stall, and let each
        // re-decide against the new observed neighborhood.
        let now = core.now();
        for (v, waited) in core.stale.release_all(now) {
            core.recorder.queue_block_time += waited;
            if core.is_active(v) {
                core.restart_after(v, 0.0);
            }
        }
    }

    fn on_worker_leave(&mut self, w: WorkerId, core: &mut EngineCore) {
        self.busy_until[w] = 0.0;
        // Waiters parked on the leaver would never be released by its
        // clock again.
        let now = core.now();
        for (v, waited) in core.stale.release(w, now) {
            core.recorder.queue_block_time += waited;
            if core.is_active(v) {
                core.restart_after(v, 0.0);
            }
        }
        core.stale.on_leave(w);
    }

    fn on_worker_join(&mut self, w: WorkerId, core: &mut EngineCore) {
        self.busy_until[w] = 0.0;
        // The engine warm-started the joiner's parameters from its
        // observed neighborhood; start its clock at the same frontier so
        // state and clock agree.
        let nbrs = core.observed_neighbors(w);
        let clocks: Vec<u64> = nbrs.iter().map(|&r| core.stale.clock(r)).collect();
        let now = core.now();
        core.stale.on_join(w, now, &clocks);
    }
}
