//! Deterministic PRNG + distributions.
//!
//! xoshiro256++ seeded through SplitMix64 — the standard small-state
//! generator with excellent statistical quality; every simulator component
//! derives its own stream from the experiment seed, so runs are exactly
//! reproducible across machines.

/// xoshiro256++ generator.
#[derive(Debug, Clone)]
pub struct Rng64 {
    s: [u64; 4],
    /// Cached second Box-Muller sample.
    spare_normal: Option<f64>,
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng64 {
    /// Seeded generator; distinct seeds yield decorrelated streams.
    pub fn seed_from_u64(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng64 { s, spare_normal: None }
    }

    /// Next raw 64-bit value.
    #[inline]
    pub fn gen_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)`.
    #[inline]
    pub fn gen_f64(&mut self) -> f64 {
        (self.gen_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[0, 1)`.
    #[inline]
    pub fn gen_f32(&mut self) -> f32 {
        self.gen_f64() as f32
    }

    /// Uniform integer in `[0, bound)` (Lemire-reduced, unbiased enough
    /// for simulation purposes; bound must be non-zero).
    #[inline]
    pub fn gen_range(&mut self, bound: usize) -> usize {
        debug_assert!(bound > 0);
        ((self.gen_u64() as u128 * bound as u128) >> 64) as usize
    }

    /// Bernoulli trial.
    #[inline]
    pub fn gen_bool(&mut self, p: f64) -> bool {
        self.gen_f64() < p
    }

    /// Standard normal via Box-Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        let u1 = loop {
            let u = self.gen_f64();
            if u > 1e-300 {
                break u;
            }
        };
        let u2 = self.gen_f64();
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        self.spare_normal = Some(r * theta.sin());
        r * theta.cos()
    }

    /// Standard normal as f32.
    pub fn normal_f32(&mut self) -> f32 {
        self.normal() as f32
    }

    /// Log-normal with `ln`-space mean 0 and std `sigma`.
    pub fn lognormal(&mut self, sigma: f64) -> f64 {
        (sigma * self.normal()).exp()
    }

    /// Exponential with the given `mean` (inverse-CDF transform).
    pub fn exponential(&mut self, mean: f64) -> f64 {
        debug_assert!(mean > 0.0);
        -mean * (1.0 - self.gen_f64()).ln()
    }

    /// Weibull with `shape` k and `scale` λ (inverse-CDF transform);
    /// `shape < 1` gives the heavy-tailed inter-failure times observed in
    /// real cluster traces.
    pub fn weibull(&mut self, shape: f64, scale: f64) -> f64 {
        debug_assert!(shape > 0.0 && scale > 0.0);
        scale * (-(1.0 - self.gen_f64()).ln()).powf(1.0 / shape)
    }

    /// Fisher-Yates in-place shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.gen_range(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct items from `pool` (partial Fisher-Yates).
    pub fn sample<T: Copy>(&mut self, pool: &[T], k: usize) -> Vec<T> {
        let mut pool = pool.to_vec();
        let k = k.min(pool.len());
        for i in 0..k {
            let j = i + self.gen_range(pool.len() - i);
            pool.swap(i, j);
        }
        pool.truncate(k);
        pool
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Rng64::seed_from_u64(7);
        let mut b = Rng64::seed_from_u64(7);
        for _ in 0..100 {
            assert_eq!(a.gen_u64(), b.gen_u64());
        }
        let mut c = Rng64::seed_from_u64(8);
        assert_ne!(Rng64::seed_from_u64(7).gen_u64(), c.gen_u64());
    }

    #[test]
    fn uniform_mean_and_range() {
        let mut r = Rng64::seed_from_u64(1);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let v = r.gen_f64();
            assert!((0.0..1.0).contains(&v));
            sum += v;
        }
        let mean = sum / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn gen_range_bounds_and_coverage() {
        let mut r = Rng64::seed_from_u64(2);
        let mut seen = [false; 7];
        for _ in 0..500 {
            let v = r.gen_range(7);
            assert!(v < 7);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&b| b));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng64::seed_from_u64(3);
        let n = 20_000;
        let samples: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = samples.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn lognormal_median_near_one() {
        let mut r = Rng64::seed_from_u64(4);
        let mut samples: Vec<f64> = (0..5001).map(|_| r.lognormal(0.5)).collect();
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = samples[2500];
        assert!((median - 1.0).abs() < 0.1, "median {median}");
        assert!(samples.iter().all(|&v| v > 0.0));
    }

    #[test]
    fn exponential_mean_and_positivity() {
        let mut r = Rng64::seed_from_u64(9);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let v = r.exponential(2.0);
            assert!(v >= 0.0);
            sum += v;
        }
        let mean = sum / n as f64;
        assert!((mean - 2.0).abs() < 0.1, "mean {mean}");
    }

    #[test]
    fn weibull_shape_one_is_exponential() {
        // k = 1 reduces to Exp(scale); check the mean.
        let mut r = Rng64::seed_from_u64(10);
        let n = 20_000;
        let mean = (0..n).map(|_| r.weibull(1.0, 3.0)).sum::<f64>() / n as f64;
        assert!((mean - 3.0).abs() < 0.15, "mean {mean}");
        // heavy-tailed shape < 1 still yields non-negative samples
        for _ in 0..1000 {
            assert!(r.weibull(0.5, 1.0) >= 0.0);
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng64::seed_from_u64(5);
        let mut xs: Vec<usize> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(xs, (0..50).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn sample_distinct() {
        let mut r = Rng64::seed_from_u64(6);
        let pool: Vec<usize> = (0..20).collect();
        let s = r.sample(&pool, 8);
        assert_eq!(s.len(), 8);
        let set: std::collections::HashSet<_> = s.iter().collect();
        assert_eq!(set.len(), 8);
        assert_eq!(r.sample(&pool, 50).len(), 20); // clamped
    }
}
