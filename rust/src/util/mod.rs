//! Self-contained utility substrates (this image vendors no `rand`,
//! `serde_json` or CLI crates, so we build exactly what the system needs).

pub mod json;
pub mod rng;

pub use rng::Rng64;
