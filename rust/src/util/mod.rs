//! Self-contained utility substrates (this image vendors no `rand`,
//! `serde_json` or CLI crates, so we build exactly what the system needs).

pub mod json;
pub mod rng;

pub use rng::Rng64;

/// FNV-1a over a byte string: the stable non-cryptographic hash shared
/// by the sweep layer's config hashing and the trace subsystem's
/// machine → worker `hash` mapping policy.
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}
