//! Minimal JSON parser/writer (no serde in the vendored dependency set).
//!
//! Supports the full JSON grammar minus exotic escapes (`\uXXXX` is
//! decoded for the BMP).  Used for the artifact manifest, experiment
//! configs and result files.

use anyhow::{bail, ensure, Context, Result};
use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    /// `null`
    Null,
    /// `true` / `false`
    Bool(bool),
    /// Any number (stored as f64).
    Num(f64),
    /// String.
    Str(String),
    /// Array.
    Arr(Vec<Json>),
    /// Object (sorted keys for deterministic output).
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse from text.
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        ensure!(p.pos == p.bytes.len(), "trailing garbage at byte {}", p.pos);
        Ok(v)
    }

    /// Object field access.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Object field or error (for required manifest fields).
    pub fn req(&self, key: &str) -> Result<&Json> {
        self.get(key).with_context(|| format!("missing field {key:?}"))
    }

    /// As f64.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// As usize (must be a non-negative integer).
    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 => Some(*n as usize),
            _ => None,
        }
    }

    /// As u64.
    pub fn as_u64(&self) -> Option<u64> {
        self.as_usize().map(|v| v as u64)
    }

    /// As bool.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// As string slice.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// As array slice.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// As object map.
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Serialize compactly.
    pub fn to_string_compact(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        ensure!(
            self.peek() == Some(b),
            "expected {:?} at byte {}, found {:?}",
            b as char,
            self.pos,
            self.peek().map(|c| c as char)
        );
        self.pos += 1;
        Ok(())
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json> {
        ensure!(
            self.bytes[self.pos..].starts_with(lit.as_bytes()),
            "bad literal at byte {}",
            self.pos
        );
        self.pos += lit.len();
        Ok(v)
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek().context("unexpected end of input")? {
            b'n' => self.literal("null", Json::Null),
            b't' => self.literal("true", Json::Bool(true)),
            b'f' => self.literal("false", Json::Bool(false)),
            b'"' => Ok(Json::Str(self.string()?)),
            b'[' => self.array(),
            b'{' => self.object(),
            b'-' | b'0'..=b'9' => self.number(),
            c => bail!("unexpected {:?} at byte {}", c as char, self.pos),
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            let c = *self.bytes.get(self.pos).context("unterminated string")?;
            self.pos += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = *self.bytes.get(self.pos).context("bad escape")?;
                    self.pos += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            ensure!(self.pos + 4 <= self.bytes.len(), "bad \\u escape");
                            let hex = std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])?;
                            let code = u32::from_str_radix(hex, 16)?;
                            self.pos += 4;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        }
                        _ => bail!("unknown escape \\{}", e as char),
                    }
                }
                c => {
                    // re-assemble UTF-8 multibyte sequences
                    if c < 0x80 {
                        out.push(c as char);
                    } else {
                        let start = self.pos - 1;
                        let len = match c {
                            0xC0..=0xDF => 2,
                            0xE0..=0xEF => 3,
                            _ => 4,
                        };
                        ensure!(start + len <= self.bytes.len(), "truncated UTF-8");
                        out.push_str(std::str::from_utf8(&self.bytes[start..start + len])?);
                        self.pos = start + len;
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        while let Some(c) = self.peek() {
            if matches!(c, b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9') {
                self.pos += 1;
            } else {
                break;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])?;
        Ok(Json::Num(text.parse::<f64>().with_context(|| format!("bad number {text:?}"))?))
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(out));
                }
                _ => bail!("expected , or ] at byte {}", self.pos),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            out.insert(key, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(out));
                }
                _ => bail!("expected , or }} at byte {}", self.pos),
            }
        }
    }
}

/// Convenience constructors for building result/config objects.
impl From<&str> for Json {
    fn from(s: &str) -> Self {
        Json::Str(s.to_string())
    }
}
impl From<f64> for Json {
    fn from(n: f64) -> Self {
        Json::Num(n)
    }
}
impl From<usize> for Json {
    fn from(n: usize) -> Self {
        Json::Num(n as f64)
    }
}
impl From<bool> for Json {
    fn from(b: bool) -> Self {
        Json::Bool(b)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_like_document() {
        let text = r#"{
            "format": "hlo-text/v1",
            "gossip_fanout": 8,
            "variants": {"mlp": {"dim": 1754, "layout": [["w0", [32, 32]]],
                                  "ok": true, "x": null}},
            "list": [1, -2.5, 3e2]
        }"#;
        let j = Json::parse(text).unwrap();
        assert_eq!(j.req("format").unwrap().as_str(), Some("hlo-text/v1"));
        assert_eq!(j.req("gossip_fanout").unwrap().as_usize(), Some(8));
        let layout = j.get("variants").unwrap().get("mlp").unwrap().get("layout").unwrap();
        assert_eq!(layout.as_arr().unwrap()[0].as_arr().unwrap()[0].as_str(), Some("w0"));
        let list = j.get("list").unwrap().as_arr().unwrap();
        assert_eq!(list[1].as_f64(), Some(-2.5));
        assert_eq!(list[2].as_f64(), Some(300.0));
    }

    #[test]
    fn string_escapes_roundtrip() {
        let j = Json::parse(r#""a\"b\\c\ndA""#).unwrap();
        assert_eq!(j.as_str(), Some("a\"b\\c\ndA"));
        let back = Json::parse(&j.to_string_compact()).unwrap();
        assert_eq!(back, j);
    }

    #[test]
    fn utf8_passthrough() {
        let j = Json::parse(r#""héllo — ü""#).unwrap();
        assert_eq!(j.as_str(), Some("héllo — ü"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("{} extra").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn write_roundtrip_nested() {
        let mut obj = BTreeMap::new();
        obj.insert("a".into(), Json::Arr(vec![Json::Num(1.0), Json::Bool(false), Json::Null]));
        obj.insert("b".into(), Json::from("text"));
        let j = Json::Obj(obj);
        let text = j.to_string_compact();
        assert_eq!(Json::parse(&text).unwrap(), j);
    }

    #[test]
    fn integers_written_without_fraction() {
        assert_eq!(Json::Num(8.0).to_string_compact(), "8");
        assert_eq!(Json::Num(2.5).to_string_compact(), "2.5");
    }
}
