//! Churn sweep: how DSGD-AAU and the four baselines cope with
//! time-varying communication graphs.
//!
//! Sweeps churn scenario × rate × algorithm on the quadratic workload and
//! reports iterations, final loss and the churn accounting (change
//! events, applied mutations, repair-deferred removals).  Scenarios:
//!
//! * `static`            — the paper's fixed graph (baseline)
//! * `flaky(r)`          — random link failures at r events/s
//! * `mobile`            — a cohort of workers re-wiring on an interval
//! * `partition/heal`    — periodic bisection cuts with later healing
//!
//! Run: `cargo run --release --bin bench_churn` (add `--full` for the
//! paper-scale fleet).

use anyhow::Result;
use dsgd_aau::algorithms::AlgorithmKind;
use dsgd_aau::churn::{ChurnConfig, ChurnKind};
use dsgd_aau::config::{BackendKind, ExperimentConfig};
use dsgd_aau::coordinator::run_sweep;
use dsgd_aau::harness::{BenchArgs, Table};
use dsgd_aau::topology::TopologyKind;

fn scenarios(full: bool) -> Vec<(String, ChurnConfig)> {
    let mut out = vec![("static".to_string(), ChurnConfig::default())];
    let rates: &[f64] = if full { &[0.5, 2.0, 8.0] } else { &[0.5, 2.0] };
    for &rate in rates {
        out.push((
            format!("flaky(r={rate})"),
            ChurnConfig {
                kind: ChurnKind::FlakyLinks { rate, mean_downtime: 1.0 },
                seed: None,
            },
        ));
    }
    out.push((
        "mobile".to_string(),
        ChurnConfig {
            kind: ChurnKind::Mobile { movers: 3, interval: 0.5, degree: 3 },
            seed: None,
        },
    ));
    out.push((
        "partition/heal".to_string(),
        ChurnConfig {
            kind: ChurnKind::PartitionHeal { period: 4.0, downtime: 1.5 },
            seed: None,
        },
    ));
    out
}

fn main() -> Result<()> {
    let args = BenchArgs::parse()?;
    let n = if args.full { 32 } else { 12 };
    let iters = if args.full { 3000 } else { 800 };

    let mut table = Table::new(&[
        "scenario", "algorithm", "iters", "vtime(s)", "loss", "gap", "changes", "applied",
        "deferred",
    ]);

    for (label, churn) in scenarios(args.full) {
        let cfgs: Vec<ExperimentConfig> = AlgorithmKind::all()
            .into_iter()
            .map(|alg| {
                let mut cfg = ExperimentConfig::default();
                cfg.name = format!("churn_{label}_{}", alg.token());
                cfg.num_workers = n;
                cfg.algorithm = alg;
                cfg.backend = BackendKind::Quadratic;
                cfg.topology = TopologyKind::Random { p: 0.3, seed: 11 };
                cfg.churn = churn.clone();
                cfg.max_iterations = iters;
                cfg.eval_every = iters / 10;
                cfg.mean_compute = 0.01;
                cfg.seed = 7000;
                args.apply(&mut cfg).unwrap();
                cfg
            })
            .collect();
        for (cfg, res) in run_sweep(cfgs) {
            let s = res?;
            table.row(vec![
                label.clone(),
                cfg.algorithm.label().to_string(),
                s.iterations.to_string(),
                format!("{:.2}", s.virtual_time),
                format!("{:.4}", s.final_loss()),
                format!("{:.2e}", s.consensus_gap),
                s.recorder.topology_changes.to_string(),
                s.recorder.mutations_applied.to_string(),
                s.recorder.mutations_deferred.to_string(),
            ]);
        }
        println!("[bench_churn] finished scenario {label}");
    }

    println!("\nChurn sweep — {n} workers, quadratic workload, {iters} iterations:\n");
    print!("{}", table.render());
    println!(
        "\nReading: the static rows reproduce the fixed-graph setting; under \
         churn every algorithm keeps converging because connectivity repair \
         preserves the paper's assumption, while `deferred` counts how often \
         a removal had to be held back to do so."
    );
    table.write_csv(&args.out_dir, "churn_sweep")?;
    Ok(())
}
