//! Deprecated shim for `bench churn` (dynamic-topology sweep) — kept for one release; same
//! flags, same outputs.

fn main() -> anyhow::Result<()> {
    dsgd_aau::sweep::cli::shim_main("churn")
}
