//! Straggler-process × churn × algorithm sweep (the ROADMAP's joint
//! churn-rate × straggler-rate grid).
//!
//! Sweeps the four straggler processes (i.i.d. Bernoulli, Gilbert–Elliott
//! persistent slow states, Weibull-renewal bursts, and a materialized
//! trace replay of the Gilbert–Elliott scenario) against churn scenarios
//! (static graph, flaky links at increasing rates) for all five
//! algorithms on the quadratic workload.  Every run is deterministic per
//! seed; the `trace` rows replay the exact slow/fast evolution of the
//! `gilbert_elliott` rows, so matching numbers double as a round-trip
//! check of the trace subsystem.
//!
//! Run: `cargo run --release --bin bench_straggler` (add `--full` for the
//! paper-scale fleet).

use anyhow::Result;
use dsgd_aau::algorithms::AlgorithmKind;
use dsgd_aau::churn::{ChurnConfig, ChurnKind};
use dsgd_aau::config::{BackendKind, ExperimentConfig};
use dsgd_aau::coordinator::run_sweep;
use dsgd_aau::harness::{BenchArgs, Table};
use dsgd_aau::sim::{materialize_trace, StragglerKind, StragglerModel};
use dsgd_aau::topology::TopologyKind;

const STRAGGLER_SEED: u64 = 5;

// Time constants sit at the workload scale (mean_compute = 0.01 s): slow
// windows of ~0.1 s span ~10 consecutive samples — persistent slowness,
// visible even to the fastest algorithms' short virtual-time runs.
fn ge_model() -> StragglerModel {
    StragglerModel {
        kind: StragglerKind::GilbertElliott { mean_fast: 0.4, mean_slow: 0.1 },
        seed: Some(STRAGGLER_SEED),
        ..StragglerModel::default()
    }
}

fn processes(n: usize, trace_path: &std::path::Path) -> Result<Vec<(String, StragglerModel)>> {
    // Materialize the Gilbert–Elliott evolution once so the trace rows
    // replay it bit for bit (horizon far past any run's virtual time —
    // even the paper-scale synchronous runs stay well under 600 s).
    let tl = materialize_trace(&ge_model(), n, 0, 600.0)?;
    tl.save(trace_path)?;
    Ok(vec![
        ("bernoulli".to_string(), StragglerModel::default()),
        ("gilbert_elliott".to_string(), ge_model()),
        (
            "weibull".to_string(),
            StragglerModel {
                kind: StragglerKind::WeibullBursts { shape: 0.7, scale: 0.4, mean_burst: 0.1 },
                seed: Some(STRAGGLER_SEED),
                ..StragglerModel::default()
            },
        ),
        (
            "trace(ge)".to_string(),
            StragglerModel {
                kind: StragglerKind::Trace { path: trace_path.display().to_string() },
                ..StragglerModel::default()
            },
        ),
    ])
}

fn churn_scenarios(full: bool) -> Vec<(String, ChurnConfig)> {
    let mut out = vec![("static".to_string(), ChurnConfig::default())];
    let rates: &[f64] = if full { &[0.5, 2.0, 8.0] } else { &[0.5, 2.0] };
    for &rate in rates {
        out.push((
            format!("flaky(r={rate})"),
            ChurnConfig {
                kind: ChurnKind::FlakyLinks { rate, mean_downtime: 1.0 },
                seed: None,
            },
        ));
    }
    out
}

fn main() -> Result<()> {
    let args = BenchArgs::parse()?;
    let n = if args.full { 32 } else { 12 };
    let iters = if args.full { 3000 } else { 600 };

    let trace_path = std::env::temp_dir()
        .join(format!("bench_straggler_trace_{}.json", std::process::id()));
    let procs = processes(n, &trace_path)?;

    let mut table = Table::new(&[
        "process", "churn", "algorithm", "iters", "vtime(s)", "loss", "strag%", "stalls",
    ]);

    for (proc_label, straggler) in &procs {
        for (churn_label, churn) in churn_scenarios(args.full) {
            let cfgs: Vec<ExperimentConfig> = AlgorithmKind::all()
                .into_iter()
                .map(|alg| {
                    let mut cfg = ExperimentConfig::default();
                    cfg.name = format!("straggler_{proc_label}_{churn_label}_{}", alg.token());
                    cfg.num_workers = n;
                    cfg.algorithm = alg;
                    cfg.backend = BackendKind::Quadratic;
                    cfg.topology = TopologyKind::Random { p: 0.3, seed: 11 };
                    cfg.churn = churn.clone();
                    cfg.straggler = straggler.clone();
                    cfg.max_iterations = iters;
                    cfg.eval_every = iters / 10;
                    cfg.mean_compute = 0.01;
                    cfg.seed = 9000;
                    args.apply(&mut cfg).unwrap();
                    cfg
                })
                .collect();
            for (cfg, res) in run_sweep(cfgs) {
                let s = res?;
                table.row(vec![
                    proc_label.clone(),
                    churn_label.clone(),
                    cfg.algorithm.label().to_string(),
                    s.iterations.to_string(),
                    format!("{:.2}", s.virtual_time),
                    format!("{:.4}", s.final_loss()),
                    format!("{:.1}", 100.0 * s.straggler_fraction),
                    s.recorder.stall_fallbacks.to_string(),
                ]);
            }
        }
        println!("[bench_straggler] finished process {proc_label}");
    }
    std::fs::remove_file(&trace_path).ok();

    println!(
        "\nStraggler-process sweep — {n} workers, quadratic workload, {iters} iterations:\n"
    );
    print!("{}", table.render());
    println!(
        "\nReading: under the correlated processes the same average straggler \
         budget hits the barrier algorithms much harder than the i.i.d. coin \
         (persistent slow workers sit in every round), which is exactly the \
         regime DSGD-AAU's adaptive waiting targets.  The trace(ge) rows \
         replay the gilbert_elliott rows' slow/fast evolution from JSON and \
         must match them; `stalls` counts DSGD-AAU's full-fleet liveness \
         fallbacks under churn."
    );
    table.write_csv(&args.out_dir, "straggler_sweep")?;
    Ok(())
}
