//! Deprecated shim for `bench straggler` (process x churn sweep)
//! — kept for one release; same flags; artifacts now use the
//! canonical <suite>.csv + BENCH_<suite>.json names.

fn main() -> anyhow::Result<()> {
    dsgd_aau::sweep::cli::shim_main("straggler")
}
