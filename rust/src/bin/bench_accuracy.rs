//! Deprecated shim for `bench accuracy` (Tables 1/8/10) — kept for one release; same
//! flags, same outputs.

fn main() -> anyhow::Result<()> {
    dsgd_aau::sweep::cli::shim_main("accuracy")
}
