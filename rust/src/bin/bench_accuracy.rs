//! Table 1 / Table 8 / Table 10 harness: final test accuracy of every
//! algorithm across model variants at a fixed worker count.
//!
//! Paper shape to reproduce: DSGD-AAU's final accuracy beats AGP, AD-PSGD
//! and Prague on every model, on both non-IID (Tables 1/8) and IID
//! (Table 10) data; AD-PSGD trails the field under stragglers.
//!
//! ```text
//! cargo run --release --bin bench_accuracy            # CI scale (N=32)
//! cargo run --release --bin bench_accuracy -- --full  # paper scale (N=128)
//! cargo run --release --bin bench_accuracy -- --iid=1
//! ```

use anyhow::Result;
use dsgd_aau::algorithms::AlgorithmKind;
use dsgd_aau::config::{BackendKind, ExperimentConfig};
use dsgd_aau::coordinator::{mean_std, run_sweep};
use dsgd_aau::harness::{pm, BenchArgs, Table};

fn main() -> Result<()> {
    let args = BenchArgs::parse()?;
    let iid = args.extra.get("iid").map(|v| v == "1").unwrap_or(false);
    let n = if args.full { 128 } else { 32 };
    // Equal virtual-time budget per cell (the paper trains every algorithm
    // on the same wall-clock testbed); iteration budgets would be unfair
    // across iteration semantics.
    let budget = if args.full { 300.0 } else { 120.0 };
    // Model ladder standing in for the paper's 2-NN/AlexNet/VGG/ResNet
    // (DESIGN.md §3): same 4-algorithm comparison per row.
    let models = ["mlp_tiny", "mlp_small", "mlp2nn"];
    let models: &[&str] = if args.full { &models } else { &models[..2] };

    let mut table = Table::new(&{
        let mut h = vec!["model"];
        h.extend(AlgorithmKind::paper_table().iter().map(|a| a.label()));
        h
    });

    for model in models {
        let mut cells = vec![model.to_string()];
        for alg in AlgorithmKind::paper_table() {
            let cfgs: Vec<ExperimentConfig> = (0..args.seeds)
                .map(|s| {
                    let mut cfg = ExperimentConfig::default();
                    cfg.name = format!("t1_{model}_{}_{s}", alg.token());
                    cfg.num_workers = n;
                    cfg.algorithm = alg;
                    cfg.backend = BackendKind::NativeMlp;
                    cfg.model = model.to_string();
                    cfg.iid = iid;
                    cfg.max_iterations = u64::MAX / 2;
                    cfg.time_budget = Some(budget);
                    cfg.eval_every = 50;
                    cfg.dataset_samples = if args.full { 16384 } else { 4096 };
                    cfg.seed = 1000 + s;
                    args.apply(&mut cfg).unwrap();
                    cfg
                })
                .collect();
            let accs: Vec<f64> = run_sweep(cfgs)
                .into_iter()
                .map(|(_, r)| 100.0 * r.expect("run failed").recorder.best_accuracy() as f64)
                .collect();
            let (m, s) = mean_std(&accs);
            cells.push(pm(m, s));
        }
        table.row(cells);
        println!("[bench_accuracy] finished {model}");
    }

    let tag = if iid { "table10_accuracy_iid" } else { "table1_accuracy_noniid" };
    println!("\nTable 1/8 analogue — final accuracy, N={n}, {} data:\n", if iid { "IID" } else { "non-IID" });
    print!("{}", table.render());
    let path = table.write_csv(&args.out_dir, tag)?;
    println!("\nwrote {}", path.display());
    Ok(())
}
