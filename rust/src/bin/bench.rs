//! The `bench` multiplexer: every paper table/figure suite (plus the
//! churn/straggler/partition grids) behind one binary, driven by
//! declarative `SweepSpec`s.
//!
//! ```text
//! bench list                 # suites and their paper mapping
//! bench accuracy --full      # one suite at paper scale
//! bench all --quick          # every suite's CI smoke grid
//! bench partition --resume   # re-run only the missing cells
//! ```

fn main() -> anyhow::Result<()> {
    dsgd_aau::sweep::cli::bench_main()
}
