//! Deprecated shim for `bench loss_curves` (Figures 3-4) — kept for one release; same
//! flags, same outputs.

fn main() -> anyhow::Result<()> {
    dsgd_aau::sweep::cli::shim_main("loss_curves")
}
