//! Figure 3 + Figure 4 harness: training-loss curves vs iteration and vs
//! virtual wall-clock for all five algorithms on one workload.
//!
//! Paper shape: per *iteration* (Fig. 3) DSGD-AAU tracks or beats Prague
//! and clearly beats AD-PSGD/AGP; per *wall-clock* (Fig. 4) the gap
//! widens because DSGD-AAU's iterations don't wait for stragglers.
//!
//! Writes `results/fig3_fig4_<algo>.csv` (iteration,time,loss,accuracy)
//! and prints loss checkpoints.

use anyhow::Result;
use dsgd_aau::algorithms::AlgorithmKind;
use dsgd_aau::config::{BackendKind, ExperimentConfig};
use dsgd_aau::coordinator::run_sweep;
use dsgd_aau::harness::{BenchArgs, Table};

fn main() -> Result<()> {
    let args = BenchArgs::parse()?;
    let n = if args.full { 128 } else { 32 };
    let iterations = if args.full { 6000 } else { 1500 };

    let cfgs: Vec<ExperimentConfig> = AlgorithmKind::all()
        .into_iter()
        .map(|alg| {
            let mut cfg = ExperimentConfig::default();
            cfg.name = format!("f34_{}", alg.token());
            cfg.num_workers = n;
            cfg.algorithm = alg;
            cfg.backend = BackendKind::NativeMlp;
            cfg.model = "mlp_small".into();
            cfg.max_iterations = iterations;
            cfg.eval_every = (iterations / 60).max(1);
            cfg.seed = 3000;
            args.apply(&mut cfg).unwrap();
            cfg
        })
        .collect();

    let mut table = Table::new(&[
        "algorithm",
        "loss@25%",
        "loss@50%",
        "loss@100%",
        "vtime(s)",
        "iters/s(virt)",
    ]);
    std::fs::create_dir_all(&args.out_dir)?;
    for (cfg, res) in run_sweep(cfgs) {
        let s = res.expect("run failed");
        let curve = &s.recorder.curve;
        let at = |frac: f64| -> f32 {
            let idx = ((curve.len() - 1) as f64 * frac) as usize;
            curve[idx].loss
        };
        table.row(vec![
            cfg.algorithm.label().to_string(),
            format!("{:.4}", at(0.25)),
            format!("{:.4}", at(0.5)),
            format!("{:.4}", at(1.0)),
            format!("{:.1}", s.virtual_time),
            format!("{:.1}", s.iterations as f64 / s.virtual_time.max(1e-9)),
        ]);
        let path = args.out_dir.join(format!("fig3_fig4_{}.csv", cfg.algorithm.token()));
        s.recorder.write_csv(&path)?;
        println!("[bench_loss_curves] {} -> {}", cfg.algorithm.label(), path.display());
    }

    println!("\nFigure 3/4 analogue — loss checkpoints (N={n}, non-IID):\n");
    print!("{}", table.render());
    table.write_csv(&args.out_dir, "fig3_fig4_summary")?;
    Ok(())
}
