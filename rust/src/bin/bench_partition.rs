//! Deprecated shim for `bench partition` (repair/blind/aware sweep)
//! — kept for one release; same flags; artifacts now use the
//! canonical <suite>.csv + BENCH_<suite>.json names.

fn main() -> anyhow::Result<()> {
    dsgd_aau::sweep::cli::shim_main("partition")
}
