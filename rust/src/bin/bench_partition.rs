//! Partition sweep: what real partitions cost each update rule, and what
//! partition-aware adaptivity buys back.
//!
//! Sweeps partition scenario × adapt mode × algorithm on the quadratic
//! workload.  Modes:
//!
//! * `repair`  — PR 1 connectivity repair (the cut keeps one bridge)
//! * `blind`   — real partitions, partition-blind rules (the PR 2
//!               baseline: DSGD-AAU survives on stall fallbacks)
//! * `aware`   — real partitions, component-retargeted rules
//!
//! Output: an aligned table + `partition_sweep.csv` (like the other
//! benches) **plus machine-readable `BENCH_partition.json`** — summary
//! rows CI uploads as an artifact so the perf trajectory is queryable.
//!
//! Run: `cargo run --release --bin bench_partition` (`--quick` for the
//! CI smoke grid, `--full` for the paper-scale fleet).

use anyhow::Result;
use dsgd_aau::adapt::AdaptConfig;
use dsgd_aau::algorithms::AlgorithmKind;
use dsgd_aau::churn::{ChurnConfig, ChurnKind};
use dsgd_aau::config::{BackendKind, ExperimentConfig};
use dsgd_aau::coordinator::run_sweep;
use dsgd_aau::engine::RunSummary;
use dsgd_aau::harness::{BenchArgs, Table};
use dsgd_aau::topology::TopologyKind;
use dsgd_aau::util::json::Json;
use std::collections::BTreeMap;

fn modes() -> Vec<(&'static str, AdaptConfig)> {
    vec![
        ("repair", AdaptConfig::default()),
        (
            "blind",
            AdaptConfig { allow_partitions: true, ..AdaptConfig::default() },
        ),
        (
            "aware",
            AdaptConfig {
                allow_partitions: true,
                partition_aware: true,
                detection_latency: 0.1,
                heal_restart: true,
            },
        ),
    ]
}

fn scenarios(quick: bool, full: bool) -> Vec<(String, ChurnConfig)> {
    let mut out = Vec::new();
    let grids: &[(f64, f64)] = if quick {
        &[(3.0, 1.5)]
    } else if full {
        &[(8.0, 3.0), (4.0, 2.0), (2.0, 1.0)]
    } else {
        &[(4.0, 2.0), (2.0, 1.0)]
    };
    for &(period, downtime) in grids {
        out.push((
            format!("partition(p={period},d={downtime})"),
            ChurnConfig {
                kind: ChurnKind::PartitionHeal { period, downtime },
                seed: Some(13),
            },
        ));
    }
    out
}

fn summary_row(
    scenario: &str,
    mode: &str,
    cfg: &ExperimentConfig,
    s: &RunSummary,
) -> Json {
    let mut m: BTreeMap<String, Json> = BTreeMap::new();
    m.insert("scenario".into(), Json::from(scenario));
    m.insert("mode".into(), Json::from(mode));
    m.insert("algorithm".into(), Json::from(cfg.algorithm.label()));
    m.insert("iterations".into(), Json::from(s.iterations as usize));
    m.insert("virtual_time".into(), Json::Num(s.virtual_time));
    m.insert("final_loss".into(), Json::Num(s.final_loss() as f64));
    m.insert("consensus_gap".into(), Json::Num(s.consensus_gap as f64));
    m.insert("total_bytes".into(), Json::from(s.recorder.total_bytes() as usize));
    m.insert("stall_fallbacks".into(), Json::from(s.recorder.stall_fallbacks as usize));
    m.insert("partition_splits".into(), Json::from(s.recorder.partition_splits as usize));
    m.insert("partition_merges".into(), Json::from(s.recorder.partition_merges as usize));
    m.insert("max_components".into(), Json::from(s.recorder.max_components));
    m.insert("component_epochs".into(), Json::from(s.recorder.component_epochs as usize));
    m.insert("epoch_restarts".into(), Json::from(s.recorder.epoch_restarts as usize));
    m.insert(
        "partitioned_gossips".into(),
        Json::from(s.recorder.partitioned_gossips as usize),
    );
    m.insert(
        "mutations_deferred".into(),
        Json::from(s.recorder.mutations_deferred as usize),
    );
    Json::Obj(m)
}

fn main() -> Result<()> {
    let args = BenchArgs::parse()?;
    let n = if args.full { 32 } else { 12 };
    let budget = if args.quick { 4.0 } else if args.full { 40.0 } else { 15.0 };

    let mut table = Table::new(&[
        "scenario", "mode", "algorithm", "iters", "loss", "stalls", "splits", "merges",
        "comp_epochs", "restarts",
    ]);
    let mut rows: Vec<Json> = Vec::new();

    for (label, churn) in scenarios(args.quick, args.full) {
        for (mode, adapt) in modes() {
            let cfgs: Vec<ExperimentConfig> = AlgorithmKind::all()
                .into_iter()
                .map(|alg| {
                    let mut cfg = ExperimentConfig::default();
                    cfg.name = format!("partition_{label}_{mode}_{}", alg.token());
                    cfg.num_workers = n;
                    cfg.algorithm = alg;
                    cfg.backend = BackendKind::Quadratic;
                    cfg.topology = TopologyKind::Random { p: 0.3, seed: 11 };
                    cfg.churn = churn.clone();
                    cfg.adapt = adapt.clone();
                    cfg.max_iterations = u64::MAX / 2;
                    cfg.time_budget = Some(budget);
                    cfg.eval_every = 200;
                    cfg.mean_compute = 0.01;
                    cfg.seed = 8000;
                    args.apply(&mut cfg).unwrap();
                    cfg
                })
                .collect();
            for (cfg, res) in run_sweep(cfgs) {
                let s = res?;
                table.row(vec![
                    label.clone(),
                    mode.to_string(),
                    cfg.algorithm.label().to_string(),
                    s.iterations.to_string(),
                    format!("{:.4}", s.final_loss()),
                    s.recorder.stall_fallbacks.to_string(),
                    s.recorder.partition_splits.to_string(),
                    s.recorder.partition_merges.to_string(),
                    s.recorder.component_epochs.to_string(),
                    s.recorder.epoch_restarts.to_string(),
                ]);
                rows.push(summary_row(&label, mode, &cfg, &s));
            }
            println!("[bench_partition] finished {label} / {mode}");
        }
    }

    println!("\nPartition sweep — {n} workers, quadratic workload, {budget}s budget:\n");
    print!("{}", table.render());
    println!(
        "\nReading: `repair` keeps the paper's connectivity assumption by \
         deferring the last bridge; `blind` lets the cut happen and the \
         partition-blind rules crawl (DSGD-AAU only via stall fallbacks); \
         `aware` retargets every rule to the live component — stalls drop \
         to zero and iterations recover."
    );
    table.write_csv(&args.out_dir, "partition_sweep")?;

    // machine-readable summary for the CI artifact
    let mut root: BTreeMap<String, Json> = BTreeMap::new();
    root.insert("bench".into(), Json::from("partition"));
    root.insert("workers".into(), Json::from(n));
    root.insert("time_budget".into(), Json::Num(budget));
    root.insert(
        "grid".into(),
        Json::from(if args.quick {
            "quick"
        } else if args.full {
            "full"
        } else {
            "default"
        }),
    );
    root.insert("rows".into(), Json::Arr(rows));
    std::fs::create_dir_all(&args.out_dir)?;
    let json_path = args.out_dir.join("BENCH_partition.json");
    std::fs::write(&json_path, Json::Obj(root).to_string_compact())?;
    println!("wrote {}", json_path.display());
    Ok(())
}
