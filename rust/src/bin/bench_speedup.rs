//! Figure 5(a)+(b) harness: speedup over synchronous DSGD and total
//! communication, vs the number of workers.
//!
//! Speedup is the paper's metric: time for synchronous full-participation
//! DSGD to reach a target accuracy divided by each algorithm's time to the
//! same accuracy (larger is better; DSGD itself = 1.0).
//!
//! Paper shape: DSGD-AAU has the best speedup at every N and its advantage
//! grows with N, at no extra communication versus the baselines (Fig 5b).

use anyhow::Result;
use dsgd_aau::algorithms::AlgorithmKind;
use dsgd_aau::config::{BackendKind, ExperimentConfig};
use dsgd_aau::coordinator::run_sweep;
use dsgd_aau::harness::{BenchArgs, Table};

fn main() -> Result<()> {
    let args = BenchArgs::parse()?;
    let worker_counts: Vec<usize> =
        if args.full { vec![32, 64, 128, 256] } else { vec![8, 16, 32] };
    let target_acc: f32 =
        args.extra.get("target").and_then(|v| v.parse().ok()).unwrap_or(0.45);

    let mut speedup_table = Table::new(&{
        let mut h = vec!["N"];
        h.extend(AlgorithmKind::all().iter().map(|a| a.label()));
        h
    });
    let mut comm_table = speedup_table_clone_headers(&speedup_table);

    for &n in &worker_counts {
        let cfgs: Vec<ExperimentConfig> = AlgorithmKind::all()
            .into_iter()
            .map(|alg| {
                let mut cfg = ExperimentConfig::default();
                cfg.name = format!("f5_n{n}_{}", alg.token());
                cfg.num_workers = n;
                cfg.algorithm = alg;
                cfg.backend = BackendKind::NativeMlp;
                cfg.model = "mlp_small".into();
                cfg.max_iterations = u64::MAX / 2;
                cfg.time_budget = Some(if args.full { 400.0 } else { 200.0 });
                cfg.eval_every = 20;
                cfg.seed = 4000;
                args.apply(&mut cfg).unwrap();
                cfg
            })
            .collect();
        let results = run_sweep(cfgs);
        let t_sync = results
            .iter()
            .find(|(cfg, _)| cfg.algorithm == AlgorithmKind::DsgdSync)
            .and_then(|(_, r)| r.as_ref().ok())
            .and_then(|s| s.recorder.time_to_accuracy(target_acc));
        let mut srow = vec![n.to_string()];
        let mut crow = vec![n.to_string()];
        for (cfg, res) in &results {
            let s = res.as_ref().expect("run failed");
            let t = s.recorder.time_to_accuracy(target_acc);
            let speedup = match (t_sync, t) {
                (Some(ts), Some(ta)) if ta > 0.0 => format!("{:.2}x", ts / ta),
                _ => "n/a".into(),
            };
            srow.push(speedup);
            // Fig 5b framing: communication *to reach the target accuracy*
            // (falls back to total traffic when the target was never hit).
            let bytes = s
                .recorder
                .bytes_to_accuracy(target_acc)
                .unwrap_or(s.recorder.total_bytes());
            crow.push(format!("{:.1}", bytes as f64 / 1e6));
            let _ = cfg;
        }
        speedup_table.row(srow);
        comm_table.row(crow);
        println!("[bench_speedup] finished N={n}");
    }

    println!("\nFigure 5(a) analogue — speedup to {target_acc:.0}% acc (rel. sync DSGD):\n",
             target_acc = 100.0 * target_acc);
    print!("{}", speedup_table.render());
    println!("\nFigure 5(b) analogue — communication (MB) within the budget:\n");
    print!("{}", comm_table.render());
    speedup_table.write_csv(&args.out_dir, "fig5a_speedup")?;
    comm_table.write_csv(&args.out_dir, "fig5b_communication")?;
    Ok(())
}

fn speedup_table_clone_headers(t: &Table) -> Table {
    let headers: Vec<&str> = t.headers.iter().map(|s| s.as_str()).collect();
    Table::new(&headers)
}
