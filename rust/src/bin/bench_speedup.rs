//! Deprecated shim for `bench speedup` (Figure 5) — kept for one
//! release; same flags, canonical artifact names.  The sweep executor renders a
//! failed cell as `err`/`n/a` and keeps going (the old binary aborted
//! the whole table on one failed run).

fn main() -> anyhow::Result<()> {
    dsgd_aau::sweep::cli::shim_main("speedup")
}
