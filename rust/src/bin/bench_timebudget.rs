//! Table 2 / Table 9 / Table 11 harness: accuracy after a fixed virtual
//! wall-clock budget across worker counts.
//!
//! Paper shape: at every N, DSGD-AAU reaches the highest accuracy within
//! the budget; AD-PSGD improves with N (staleness amortizes) but stays
//! behind; synchronous effects leave AGP between them; all algorithms
//! improve as N grows (more parallel SGD).
//!
//! ```text
//! cargo run --release --bin bench_timebudget             # N ∈ {8..64}
//! cargo run --release --bin bench_timebudget -- --full   # N ∈ {32..256}
//! ```

use anyhow::Result;
use dsgd_aau::algorithms::AlgorithmKind;
use dsgd_aau::config::{BackendKind, ExperimentConfig};
use dsgd_aau::coordinator::{mean_std, run_sweep};
use dsgd_aau::harness::{pm, BenchArgs, Table};

fn main() -> Result<()> {
    let args = BenchArgs::parse()?;
    let iid = args.extra.get("iid").map(|v| v == "1").unwrap_or(false);
    let worker_counts: Vec<usize> =
        if args.full { vec![32, 64, 128, 256] } else { vec![8, 16, 32, 64] };
    // Budget chosen so the fastest algorithm roughly plateaus (the paper
    // trains ResNet-18 for 50 s; our virtual compute step is 0.05 s).
    let budget = if args.full { 60.0 } else { 25.0 };

    let mut table = Table::new(&{
        let mut h = vec!["N"];
        h.extend(AlgorithmKind::paper_table().iter().map(|a| a.label()));
        h
    });

    for &n in &worker_counts {
        let mut cells = vec![n.to_string()];
        for alg in AlgorithmKind::paper_table() {
            let cfgs: Vec<ExperimentConfig> = (0..args.seeds)
                .map(|s| {
                    let mut cfg = ExperimentConfig::default();
                    cfg.name = format!("t2_n{n}_{}_{s}", alg.token());
                    cfg.num_workers = n;
                    cfg.algorithm = alg;
                    cfg.backend = BackendKind::NativeMlp;
                    cfg.model = "mlp_small".into();
                    cfg.iid = iid;
                    cfg.max_iterations = u64::MAX / 2;
                    cfg.time_budget = Some(budget);
                    cfg.eval_every = 25;
                    cfg.seed = 2000 + s;
                    args.apply(&mut cfg).unwrap();
                    cfg
                })
                .collect();
            let accs: Vec<f64> = run_sweep(cfgs)
                .into_iter()
                .map(|(_, r)| 100.0 * r.expect("run failed").final_accuracy() as f64)
                .collect();
            let (m, s) = mean_std(&accs);
            cells.push(pm(m, s));
        }
        table.row(cells);
        println!("[bench_timebudget] finished N={n}");
    }

    let tag = if iid { "table11_timebudget_iid" } else { "table2_timebudget_noniid" };
    println!(
        "\nTable 2/9 analogue — accuracy after {budget:.0}s virtual budget, {} data:\n",
        if iid { "IID" } else { "non-IID" }
    );
    print!("{}", table.render());
    let path = table.write_csv(&args.out_dir, tag)?;
    println!("\nwrote {}", path.display());
    Ok(())
}
