//! Deprecated shim for `bench timebudget` (Tables 2/9/11) — kept for one release; same
//! flags, same outputs.

fn main() -> anyhow::Result<()> {
    dsgd_aau::sweep::cli::shim_main("timebudget")
}
