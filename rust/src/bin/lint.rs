//! `pallas-lint` CLI: run the repo's static-analysis pass over the
//! crate source and report determinism/invariant findings.
//!
//! ```text
//! cargo run --bin lint                        # human report, exit 1 on errors
//! cargo run --bin lint -- --format=json       # machine-readable report on stdout
//! cargo run --bin lint -- --out=results/lint_report.json   # also write JSON
//! cargo run --bin lint -- --fix-list          # pragma stubs for each finding
//! cargo run --bin lint -- --rules             # rule catalogue
//! cargo run --bin lint -- --root=rust/src     # lint a different tree
//! ```

use dsgd_aau::analysis::{lint_tree, registry, rules::is_known_rule, Severity};
use std::path::PathBuf;
use std::process::ExitCode;

fn usage() -> &'static str {
    "usage: lint [--root=PATH] [--format=text|json] [--out=PATH] [--fix-list] [--rules]"
}

fn main() -> ExitCode {
    let mut root = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("rust/src");
    let mut format_json = false;
    let mut out_path: Option<PathBuf> = None;
    let mut fix_list = false;
    for arg in std::env::args().skip(1) {
        if let Some(v) = arg.strip_prefix("--root=") {
            root = PathBuf::from(v);
        } else if let Some(v) = arg.strip_prefix("--format=") {
            match v {
                "json" => format_json = true,
                "text" => format_json = false,
                other => {
                    eprintln!("unknown format {other:?} (want text or json)");
                    return ExitCode::from(2);
                }
            }
        } else if let Some(v) = arg.strip_prefix("--out=") {
            out_path = Some(PathBuf::from(v));
        } else if arg == "--fix-list" {
            fix_list = true;
        } else if arg == "--rules" {
            for r in registry() {
                println!("{:<24} {:<8} {}", r.name, r.severity.label(), r.description);
            }
            return ExitCode::SUCCESS;
        } else {
            eprintln!("unknown argument {arg:?}\n{}", usage());
            return ExitCode::from(2);
        }
    }

    let report = match lint_tree(&root) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("lint: {e:#}");
            return ExitCode::from(2);
        }
    };

    if let Some(path) = &out_path {
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                if let Err(e) = std::fs::create_dir_all(dir) {
                    eprintln!("lint: creating {}: {e}", dir.display());
                    return ExitCode::from(2);
                }
            }
        }
        if let Err(e) = std::fs::write(path, report.to_json().to_string_compact()) {
            eprintln!("lint: writing {}: {e}", path.display());
            return ExitCode::from(2);
        }
    }

    if fix_list {
        // Pragma stubs to baseline each suppressible finding; the TODO
        // keeps a pasted-but-unexplained stub failing the lint-pragma
        // reason check until a human writes the why.
        for f in &report.findings {
            if is_known_rule(&f.rule) {
                println!(
                    "{}:{}: // pallas-lint: allow({}) — TODO: why this site is safe",
                    f.file, f.line, f.rule
                );
            }
        }
        return ExitCode::SUCCESS;
    }

    if format_json {
        println!("{}", report.to_json().to_string_compact());
    } else {
        for f in &report.findings {
            println!("{}", f.render());
        }
        let errors = report.findings.iter().filter(|f| f.severity == Severity::Error).count();
        let warnings = report.findings.len() - errors;
        println!(
            "lint: {} files scanned, {} findings ({errors} errors, {warnings} warnings)",
            report.files_scanned,
            report.findings.len()
        );
    }
    if report.has_errors() {
        ExitCode::FAILURE
    } else {
        ExitCode::SUCCESS
    }
}
