//! Deprecated shim for `bench ablation` (Figures 9-12) and, with the
//! historical `--fixedk=1` flag, `bench fixedk` — kept for one release;
//! same flags; canonical artifact names.

use dsgd_aau::sweep::cli::{run_named, BenchArgs};

fn main() -> anyhow::Result<()> {
    let args = BenchArgs::parse()?;
    let suite = if args.extra.get("fixedk").map(|v| v == "1").unwrap_or(false) {
        "fixedk"
    } else {
        "ablation"
    };
    eprintln!("[bench_ablation] deprecated shim — use `bench {suite}` (same flags)");
    run_named(suite, &args).map(|_| ())
}
