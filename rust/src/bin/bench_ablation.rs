//! Figures 9–12 harness: ablations over batch size, straggler probability
//! and straggler slowdown — final accuracy (Figs. 9/11) and accuracy under
//! a fixed time budget (Figs. 10/12), IID via `--iid=1`.
//!
//! Paper shape: all algorithms degrade as straggler probability/slowdown
//! grow, DSGD-AAU stays on top throughout; batch size has a sweet spot.

use anyhow::Result;
use dsgd_aau::algorithms::AlgorithmKind;
use dsgd_aau::backend::{MlpShape, NativeMlpBackend};
use dsgd_aau::config::{BackendKind, ExperimentConfig};
use dsgd_aau::coordinator::run_sweep;
use dsgd_aau::engine::Engine;
use dsgd_aau::harness::{pct, BenchArgs, Table};

fn base_cfg(args: &BenchArgs, alg: AlgorithmKind, iid: bool, budget: Option<f64>) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default();
    cfg.num_workers = if args.full { 128 } else { 32 };
    cfg.algorithm = alg;
    cfg.backend = BackendKind::NativeMlp;
    cfg.model = "mlp_small".into();
    cfg.iid = iid;
    cfg.max_iterations = if budget.is_some() { u64::MAX / 2 } else if args.full { 3000 } else { 800 };
    cfg.time_budget = budget;
    cfg.eval_every = 25;
    cfg.seed = 5000;
    cfg
}

fn sweep_axis(
    args: &BenchArgs,
    iid: bool,
    budget: Option<f64>,
    axis: &str,
    values: &[f64],
    mutate: impl Fn(&mut ExperimentConfig, f64),
) -> Result<Table> {
    let mut table = Table::new(&{
        let mut h = vec![axis];
        h.extend(AlgorithmKind::paper_table().iter().map(|a| a.label()));
        h
    });
    for &v in values {
        let cfgs: Vec<ExperimentConfig> = AlgorithmKind::paper_table()
            .into_iter()
            .map(|alg| {
                let mut cfg = base_cfg(args, alg, iid, budget);
                cfg.name = format!("abl_{axis}_{v}_{}", alg.token());
                mutate(&mut cfg, v);
                args.apply(&mut cfg).unwrap();
                cfg
            })
            .collect();
        let mut row = vec![format!("{v}")];
        for (_, res) in run_sweep(cfgs) {
            let s = res.expect("run failed");
            let acc =
                if budget.is_some() { s.final_accuracy() } else { s.recorder.best_accuracy() };
            row.push(pct(acc as f64));
        }
        table.row(row);
        println!("[bench_ablation] {axis}={v} done");
    }
    Ok(table)
}

/// Batch-size ablation runs the native backend directly with a modified
/// `MlpShape` (batch is baked into the shape).
fn batch_ablation(args: &BenchArgs, iid: bool, budget: Option<f64>) -> Result<Table> {
    let batches = if args.full { vec![32, 64, 128, 256] } else { vec![16, 32, 64] };
    let mut table = Table::new(&{
        let mut h = vec!["batch"];
        h.extend(AlgorithmKind::paper_table().iter().map(|a| a.label()));
        h
    });
    for &b in &batches {
        let mut row = vec![b.to_string()];
        for alg in AlgorithmKind::paper_table() {
            let cfg = base_cfg(args, alg, iid, budget);
            let mut shape = MlpShape::small();
            shape.batch = b;
            let backend = NativeMlpBackend::new(
                shape,
                cfg.num_workers,
                cfg.dataset_samples,
                cfg.separation,
                cfg.iid,
                cfg.classes_per_worker,
                cfg.seed_for("data"),
            );
            let mut engine = Engine::from_config(&cfg, Box::new(backend));
            let s = engine.run();
            let acc =
                if budget.is_some() { s.final_accuracy() } else { s.recorder.best_accuracy() };
            row.push(pct(acc as f64));
        }
        table.row(row);
        println!("[bench_ablation] batch={b} done");
    }
    Ok(table)
}

/// Design-choice ablation (DESIGN.md §5): DSGD-AAU's *adaptive* group
/// sizing vs the manually-tuned fixed-fastest-k prior art, under a fixed
/// virtual-time budget with stragglers.
fn fixed_k_ablation(args: &BenchArgs) -> Result<Table> {
    let n = if args.full { 64 } else { 32 };
    let ks = if args.full { vec![2, 4, 8, 16, 32] } else { vec![2, 4, 8, 16] };
    let mut algos: Vec<(String, AlgorithmKind)> = ks
        .iter()
        .map(|&k| (format!("Fixed-k={k}"), AlgorithmKind::FixedK { k }))
        .collect();
    algos.push(("DSGD-AAU (adaptive)".into(), AlgorithmKind::DsgdAau));
    let mut table = Table::new(&["rule", "acc@budget", "iters", "mean_group"]);
    for (label, alg) in algos {
        let mut cfg = base_cfg(args, alg, false, Some(25.0));
        cfg.num_workers = n;
        cfg.name = format!("abl_fixedk_{}", label);
        args.apply(&mut cfg)?;
        let backend = dsgd_aau::coordinator::build_backend(&cfg)?;
        let mut engine = Engine::from_config(&cfg, backend);
        let s = engine.run();
        table.row(vec![
            label,
            pct(s.final_accuracy() as f64),
            s.iterations.to_string(),
            format!("{:.1}", s.recorder.mean_group_size()),
        ]);
    }
    Ok(table)
}

fn main() -> Result<()> {
    let args = BenchArgs::parse()?;
    if args.extra.get("fixedk").map(|v| v == "1").unwrap_or(false) {
        let t = fixed_k_ablation(&args)?;
        println!("\nAdaptivity ablation — fixed-k vs DSGD-AAU (25s budget, 10% stragglers):\n");
        print!("{}", t.render());
        t.write_csv(&args.out_dir, "ablation_fixed_k")?;
        return Ok(());
    }
    let iid = args.extra.get("iid").map(|v| v == "1").unwrap_or(false);
    let budget =
        if args.extra.get("budget").map(|v| v == "1").unwrap_or(false) { Some(25.0) } else { None };
    let figure = match (iid, budget.is_some()) {
        (false, false) => "Figure 9",
        (false, true) => "Figure 10",
        (true, false) => "Figure 11",
        (true, true) => "Figure 12",
    };
    let tag_base = figure.to_lowercase().replace(' ', "");

    // (b) straggler probability sweep — paper: 5% -> 40%
    let probs = if args.full { vec![0.05, 0.10, 0.20, 0.40] } else { vec![0.05, 0.20, 0.40] };
    let t_prob = sweep_axis(&args, iid, budget, "straggler_prob", &probs, |cfg, v| {
        cfg.straggler.probability = v;
    })?;
    println!("\n{figure}(b) analogue — accuracy vs straggler probability:\n");
    print!("{}", t_prob.render());
    t_prob.write_csv(&args.out_dir, &format!("{tag_base}_straggler_prob"))?;

    // (c) slowdown sweep — paper: 5x -> 40x
    let slows = if args.full { vec![5.0, 10.0, 20.0, 40.0] } else { vec![5.0, 20.0, 40.0] };
    let t_slow = sweep_axis(&args, iid, budget, "slowdown", &slows, |cfg, v| {
        cfg.straggler.slowdown = v;
    })?;
    println!("\n{figure}(c) analogue — accuracy vs straggler slowdown:\n");
    print!("{}", t_slow.render());
    t_slow.write_csv(&args.out_dir, &format!("{tag_base}_slowdown"))?;

    // (a) batch-size sweep
    let t_batch = batch_ablation(&args, iid, budget)?;
    println!("\n{figure}(a) analogue — accuracy vs batch size:\n");
    print!("{}", t_batch.render());
    t_batch.write_csv(&args.out_dir, &format!("{tag_base}_batch"))?;

    Ok(())
}
