//! Experiment configuration: one JSON-serializable struct drives the whole
//! stack (topology, algorithm, straggler model, workload, schedule).
//!
//! Configs are plain JSON files parsed with [`crate::util::json`]; every
//! field is optional and defaults to the paper's settings (§6: η0 = 0.1,
//! δ = 0.95, 10 % stragglers at 10×, batch 128-equivalent workloads).

use crate::adapt::AdaptConfig;
use crate::algorithms::AlgorithmKind;
use crate::churn::ChurnConfig;
use crate::fragment::FragmentConfig;
use crate::membership::MembershipConfig;
use crate::sim::{CommModel, StragglerModel};
use crate::stale::StaleConfig;
use crate::topology::TopologyKind;
use crate::trace::TraceConfig;
use crate::util::json::Json;
use anyhow::{bail, Result};
use std::collections::BTreeMap;
use std::path::Path;

/// Which gradient backend computes the local SGD step.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BackendKind {
    /// AOT-compiled JAX/Pallas artifacts executed via PJRT (the real path).
    Pjrt,
    /// Native least-squares problem (exact gradients, no artifacts needed);
    /// used by convergence-property tests and micro-benches.
    Quadratic,
    /// Native rust MLP fwd/bwd mirroring `mlp_*` variants (PJRT-free
    /// comparator for the perf benches).
    NativeMlp,
}

impl BackendKind {
    /// Parse from the snake_case config token.
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "pjrt" => BackendKind::Pjrt,
            "quadratic" => BackendKind::Quadratic,
            "native_mlp" => BackendKind::NativeMlp,
            other => bail!("unknown backend {other} (pjrt|quadratic|native_mlp)"),
        })
    }

    /// Inverse of [`Self::parse`].
    pub fn token(&self) -> &'static str {
        match self {
            BackendKind::Pjrt => "pjrt",
            BackendKind::Quadratic => "quadratic",
            BackendKind::NativeMlp => "native_mlp",
        }
    }
}

/// Learning-rate schedule. The paper uses `η(k) = η0 · δ^k` (§6).
#[derive(Debug, Clone, Copy)]
pub struct LrSchedule {
    /// Initial learning rate η0 (paper: 0.1).
    pub eta0: f64,
    /// Decay δ applied per `decay_every` iterations (paper: 0.95/round).
    pub decay: f64,
    /// Iterations per decay application.
    pub decay_every: u64,
    /// Floor.
    pub min_lr: f64,
}

impl Default for LrSchedule {
    fn default() -> Self {
        LrSchedule { eta0: 0.1, decay: 0.95, decay_every: 50, min_lr: 5e-3 }
    }
}

impl LrSchedule {
    /// Learning rate at gossip-iteration `k`.
    pub fn at(&self, k: u64) -> f32 {
        let steps = (k / self.decay_every.max(1)) as i32;
        (self.eta0 * self.decay.powi(steps)).max(self.min_lr) as f32
    }
}

/// Full experiment description.
#[derive(Debug, Clone)]
pub struct ExperimentConfig {
    /// Human-readable tag used in result file names.
    pub name: String,
    /// Number of workers N (paper sweeps 32–256).
    pub num_workers: usize,
    /// Communication topology (the graph at t = 0).
    pub topology: TopologyKind,
    /// Dynamic-topology churn scenario applied on top of `topology`
    /// (kind, rate parameters, seed override or schedule path).
    pub churn: ChurnConfig,
    /// Partition-aware adaptivity: allow real partitions (no connectivity
    /// repair), component-aware update rules, detection latency and the
    /// heal-restart policy.  Defaults preserve the legacy behavior.
    pub adapt: AdaptConfig,
    /// Real-cluster trace ingestion: when set, the named machine-event
    /// log is lowered into the straggler *and* topology timelines (the
    /// `churn` section must stay inactive and `straggler` on the default
    /// Bernoulli kind — its `slowdown` still applies).
    pub trace: Option<TraceConfig>,
    /// Open-world membership: a logical population of `population` users
    /// sampled into the `num_workers` slots (Poisson arrivals/departures,
    /// per-round participation sampling, optional two-tier hierarchy).
    /// `None` keeps the closed-world default.  Requires partition-aware
    /// adaptivity (`adapt.partition_aware` + `adapt.allow_partitions`):
    /// vacant slots are isolated vertices, which the legacy connectivity
    /// repair would reject.
    pub membership: Option<MembershipConfig>,
    /// Sharded gossip: split the parameter vector into `count` contiguous
    /// shards and transfer one scheduled shard per gossip round (optional
    /// `f16` wire encoding).  The default (`count = 1`, `f32`) is the
    /// legacy full-vector exchange, bit-identical to older configs.
    pub fragments: FragmentConfig,
    /// Bounded-staleness scheduling: the per-link staleness bound, token
    /// queue depth, and skip/backup policy knobs consumed by the
    /// `hop_bss` update rule (other rules ignore the section).
    pub stale: StaleConfig,
    /// Update rule under test.
    pub algorithm: AlgorithmKind,
    /// Gradient backend.
    pub backend: BackendKind,
    /// Model variant name (manifest key) for the PJRT / native backends.
    pub model: String,
    /// IID or label-shard non-IID partitioning.
    pub iid: bool,
    /// Classes dealt to each worker under non-IID (paper: 5).
    pub classes_per_worker: usize,
    /// Synthetic dataset size.
    pub dataset_samples: usize,
    /// Synthetic class separation (higher = easier).
    pub separation: f32,
    /// Stop after this many gossip iterations.
    pub max_iterations: u64,
    /// Stop after this much virtual time (seconds), if set.
    pub time_budget: Option<f64>,
    /// Evaluate the global average every this many gossip iterations.
    pub eval_every: u64,
    /// Additionally evaluate every this many *virtual seconds* (drives
    /// `EventKind::EvalTick`; useful when iteration rates differ wildly
    /// across algorithms).  `None` disables time-based evaluation.
    pub eval_every_seconds: Option<f64>,
    /// OS threads for intra-cell gradient batches: when several workers
    /// start computing at the same virtual instant, the backend may run
    /// their gradients on this many threads.  `1` is the serial legacy
    /// path, `0` sizes to the machine.  A pure wall-clock lever: results
    /// are byte-identical for every value (the determinism suite sweeps
    /// {1, 2, 8}).
    pub compute_threads: usize,
    /// Mean local compute time (virtual seconds per gradient step).
    pub mean_compute: f64,
    /// Log-normal σ of per-worker base speeds (0 = homogeneous fleet).
    pub hetero_sigma: f64,
    /// Straggler injection.
    pub straggler: StragglerModel,
    /// Link model.
    pub comm: CommModel,
    /// Learning-rate schedule.
    pub lr: LrSchedule,
    /// Index the schedule by normalized rounds (local_steps / N) instead of
    /// the algorithm's own iteration counter k (paper default: false).
    pub lr_per_round: bool,
    /// Prague's group size (its partial all-reduce).
    pub prague_group: usize,
    /// Base RNG seed (everything derives from it deterministically).
    pub seed: u64,
    /// Use the PJRT gossip-average artifact when the group fits its fanout
    /// (otherwise the engine averages natively).
    pub pjrt_gossip: bool,
    /// Directory containing `manifest.json` + HLO artifacts.
    pub artifacts_dir: String,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        ExperimentConfig {
            name: "default".into(),
            num_workers: 16,
            topology: TopologyKind::default(),
            churn: ChurnConfig::default(),
            adapt: AdaptConfig::default(),
            trace: None,
            membership: None,
            fragments: FragmentConfig::default(),
            stale: StaleConfig::default(),
            algorithm: AlgorithmKind::DsgdAau,
            backend: BackendKind::Quadratic,
            model: "mlp_small".into(),
            iid: false,
            classes_per_worker: 5,
            dataset_samples: 4096,
            separation: 2.0,
            max_iterations: 500,
            time_budget: None,
            eval_every: 10,
            eval_every_seconds: None,
            compute_threads: 1,
            mean_compute: 0.05,
            hetero_sigma: 0.25,
            straggler: StragglerModel::default(),
            comm: CommModel::default(),
            lr: LrSchedule::default(),
            lr_per_round: false,
            prague_group: 4,
            seed: 42,
            pjrt_gossip: false,
            artifacts_dir: "artifacts".into(),
        }
    }
}

impl ExperimentConfig {
    /// Parse from a JSON file (missing fields keep their defaults).
    pub fn from_json_file(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("read {}: {e}", path.display()))?;
        Self::from_json(&Json::parse(&text)?)
    }

    /// Parse from a JSON value.
    pub fn from_json(j: &Json) -> Result<Self> {
        let mut cfg = ExperimentConfig::default();
        let obj = j.as_obj().ok_or_else(|| anyhow::anyhow!("config must be an object"))?;
        for (key, v) in obj {
            cfg.apply_kv(key, v)?;
        }
        Ok(cfg)
    }

    /// Apply one config key (the unit [`Self::from_json`] loops over;
    /// also how the sweep layer routes generic `--key=value` overrides
    /// into a config).  Unknown keys are rejected.
    pub fn apply_kv(&mut self, key: &str, v: &Json) -> Result<()> {
        match key {
            "name" => self.name = v.as_str().unwrap_or(&self.name).to_string(),
            "num_workers" => self.num_workers = need_usize(key, v)?,
            "topology" => self.topology = TopologyKind::from_json(v)?,
            "churn" => self.churn = ChurnConfig::from_json(v)?,
            "adapt" => self.adapt = AdaptConfig::from_json(v)?,
            "trace" => {
                self.trace =
                    if matches!(v, Json::Null) { None } else { Some(TraceConfig::from_json(v)?) }
            }
            "membership" => {
                self.membership = if matches!(v, Json::Null) {
                    None
                } else {
                    Some(MembershipConfig::from_json(v)?)
                }
            }
            "fragments" => self.fragments = FragmentConfig::from_json(v)?,
            "stale" => self.stale = StaleConfig::from_json(v)?,
            "algorithm" => {
                self.algorithm = AlgorithmKind::parse(v.as_str().unwrap_or_default())?
            }
            "backend" => self.backend = BackendKind::parse(v.as_str().unwrap_or_default())?,
            "model" => self.model = v.as_str().unwrap_or(&self.model).to_string(),
            "iid" => self.iid = v.as_bool().unwrap_or(self.iid),
            "classes_per_worker" => self.classes_per_worker = need_usize(key, v)?,
            "dataset_samples" => self.dataset_samples = need_usize(key, v)?,
            "separation" => self.separation = need_f64(key, v)? as f32,
            "max_iterations" => self.max_iterations = need_usize(key, v)? as u64,
            "time_budget" => {
                self.time_budget =
                    if matches!(v, Json::Null) { None } else { Some(need_f64(key, v)?) }
            }
            "eval_every" => self.eval_every = need_usize(key, v)? as u64,
            "eval_every_seconds" => {
                self.eval_every_seconds =
                    if matches!(v, Json::Null) { None } else { Some(need_f64(key, v)?) }
            }
            "compute_threads" => self.compute_threads = need_usize(key, v)?,
            "mean_compute" => self.mean_compute = need_f64(key, v)?,
            "hetero_sigma" => self.hetero_sigma = need_f64(key, v)?,
            // the full straggler section (process kind + parameters)
            "straggler" => self.straggler = StragglerModel::from_json(v)?,
            // the structured link-model section
            "comm" => self.comm = CommModel::from_json(v)?,
            // legacy flat knobs, kept for config compatibility
            "straggler_probability" => self.straggler.probability = need_f64(key, v)?,
            "straggler_slowdown" => self.straggler.slowdown = need_f64(key, v)?,
            "comm_latency" => self.comm.latency = need_f64(key, v)?,
            "comm_bandwidth" => self.comm.bandwidth = need_f64(key, v)?,
            "lr_eta0" => self.lr.eta0 = need_f64(key, v)?,
            "lr_decay" => self.lr.decay = need_f64(key, v)?,
            "lr_decay_every" => self.lr.decay_every = need_usize(key, v)? as u64,
            "lr_min" => self.lr.min_lr = need_f64(key, v)?,
            "lr_per_round" => self.lr_per_round = v.as_bool().unwrap_or(false),
            "prague_group" => self.prague_group = need_usize(key, v)?,
            "seed" => self.seed = need_usize(key, v)? as u64,
            "pjrt_gossip" => self.pjrt_gossip = v.as_bool().unwrap_or(false),
            "artifacts_dir" => {
                self.artifacts_dir = v.as_str().unwrap_or(&self.artifacts_dir).to_string()
            }
            other => bail!("unknown config key {other:?}"),
        }
        Ok(())
    }

    /// Serialize to a JSON value (round-trips through [`Self::from_json`]).
    pub fn to_json(&self) -> Json {
        let mut m: BTreeMap<String, Json> = BTreeMap::new();
        m.insert("name".into(), Json::from(self.name.as_str()));
        m.insert("num_workers".into(), Json::from(self.num_workers));
        m.insert("topology".into(), self.topology.to_json());
        m.insert("churn".into(), self.churn.to_json());
        m.insert("adapt".into(), self.adapt.to_json());
        if let Some(tc) = &self.trace {
            m.insert("trace".into(), tc.to_json());
        }
        if let Some(mc) = &self.membership {
            m.insert("membership".into(), mc.to_json());
        }
        m.insert("fragments".into(), self.fragments.to_json());
        m.insert("stale".into(), self.stale.to_json());
        m.insert("algorithm".into(), Json::from(self.algorithm.token()));
        m.insert("backend".into(), Json::from(self.backend.token()));
        m.insert("model".into(), Json::from(self.model.as_str()));
        m.insert("iid".into(), Json::from(self.iid));
        m.insert("classes_per_worker".into(), Json::from(self.classes_per_worker));
        m.insert("dataset_samples".into(), Json::from(self.dataset_samples));
        m.insert("separation".into(), Json::Num(self.separation as f64));
        m.insert("max_iterations".into(), Json::from(self.max_iterations as usize));
        if let Some(t) = self.time_budget {
            m.insert("time_budget".into(), Json::Num(t));
        }
        m.insert("eval_every".into(), Json::from(self.eval_every as usize));
        if let Some(t) = self.eval_every_seconds {
            m.insert("eval_every_seconds".into(), Json::Num(t));
        }
        m.insert("compute_threads".into(), Json::from(self.compute_threads));
        m.insert("mean_compute".into(), Json::Num(self.mean_compute));
        m.insert("hetero_sigma".into(), Json::Num(self.hetero_sigma));
        m.insert("straggler".into(), self.straggler.to_json());
        m.insert("comm".into(), self.comm.to_json());
        m.insert("lr_eta0".into(), Json::Num(self.lr.eta0));
        m.insert("lr_decay".into(), Json::Num(self.lr.decay));
        m.insert("lr_decay_every".into(), Json::from(self.lr.decay_every as usize));
        m.insert("lr_min".into(), Json::Num(self.lr.min_lr));
        m.insert("lr_per_round".into(), Json::from(self.lr_per_round));
        m.insert("prague_group".into(), Json::from(self.prague_group));
        m.insert("seed".into(), Json::from(self.seed as usize));
        m.insert("pjrt_gossip".into(), Json::from(self.pjrt_gossip));
        m.insert("artifacts_dir".into(), Json::from(self.artifacts_dir.as_str()));
        Json::Obj(m)
    }

    /// Derived seed for a named subsystem (stable across runs).
    pub fn seed_for(&self, subsystem: &str) -> u64 {
        use std::hash::{Hash, Hasher};
        let mut h = std::collections::hash_map::DefaultHasher::new();
        self.seed.hash(&mut h);
        subsystem.hash(&mut h);
        h.finish()
    }

    /// Basic sanity validation.
    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(self.num_workers >= 2, "need at least 2 workers");
        anyhow::ensure!(self.max_iterations > 0, "max_iterations must be positive");
        anyhow::ensure!(self.mean_compute > 0.0, "mean_compute must be positive");
        if let Some(dt) = self.eval_every_seconds {
            anyhow::ensure!(dt > 0.0, "eval_every_seconds must be positive");
        }
        anyhow::ensure!(self.prague_group >= 2, "prague group must be >= 2");
        self.straggler.validate()?;
        self.comm.validate()?;
        self.churn.validate()?;
        self.adapt.validate()?;
        self.fragments.validate()?;
        self.stale.validate()?;
        if let Some(tc) = &self.trace {
            tc.validate()?;
            anyhow::ensure!(
                !self.churn.is_active(),
                "the trace section drives the topology timeline — remove the churn section"
            );
            anyhow::ensure!(
                !self.straggler.is_correlated(),
                "the trace section drives the straggler process — keep the straggler section \
                 on the default bernoulli kind (its slowdown still applies)"
            );
            anyhow::ensure!(
                self.straggler.probability == StragglerModel::default().probability,
                "the trace section drives the straggler process — the bernoulli probability \
                 is unused, leave it unset (only the straggler slowdown applies)"
            );
        }
        if let Some(mc) = &self.membership {
            mc.validate()?;
            anyhow::ensure!(
                mc.population >= self.num_workers,
                "membership.population ({}) must cover the {} slots",
                mc.population,
                self.num_workers
            );
            anyhow::ensure!(
                mc.aggregators < self.num_workers,
                "membership.aggregators ({}) must leave at least one edge slot (num_workers {})",
                mc.aggregators,
                self.num_workers
            );
            anyhow::ensure!(
                self.adapt.partition_aware && self.adapt.partitions_allowed(),
                "membership requires adapt.partition_aware and adapt.allow_partitions: vacant \
                 slots are isolated vertices, which connectivity repair would reject"
            );
            // Poisson/mobility churn generates edge mutations against slots
            // that may be vacant; only explicit schedules (and traces,
            // whose ADD/REMOVE route through the join/leave path) compose
            // with an open world.
            let synthetic = matches!(
                self.churn.kind,
                crate::churn::ChurnKind::FlakyLinks { .. }
                    | crate::churn::ChurnKind::Mobile { .. }
                    | crate::churn::ChurnKind::PartitionHeal { .. }
            );
            anyhow::ensure!(
                !synthetic,
                "membership composes with churn schedules and traces only — remove the \
                 synthetic churn section (flaky_links/mobile/partition_heal)"
            );
        }
        Ok(())
    }
}

fn need_usize(key: &str, v: &Json) -> Result<usize> {
    v.as_usize().ok_or_else(|| anyhow::anyhow!("{key} must be a non-negative integer"))
}

fn need_f64(key: &str, v: &Json) -> Result<f64> {
    v.as_f64().ok_or_else(|| anyhow::anyhow!("{key} must be a number"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_valid() {
        ExperimentConfig::default().validate().unwrap();
    }

    #[test]
    fn json_roundtrip() {
        let mut cfg = ExperimentConfig::default();
        cfg.algorithm = AlgorithmKind::Prague;
        cfg.backend = BackendKind::NativeMlp;
        cfg.time_budget = Some(50.0);
        cfg.topology = TopologyKind::Ring;
        cfg.churn = crate::churn::ChurnConfig {
            kind: crate::churn::ChurnKind::FlakyLinks { rate: 2.0, mean_downtime: 0.5 },
            seed: Some(9),
        };
        cfg.straggler = StragglerModel {
            kind: crate::sim::StragglerKind::GilbertElliott { mean_fast: 6.0, mean_slow: 1.5 },
            slowdown: 8.0,
            seed: Some(4),
            ..StragglerModel::default()
        };
        cfg.eval_every_seconds = Some(2.5);
        let text = cfg.to_json().to_string_compact();
        let back = ExperimentConfig::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.algorithm, cfg.algorithm);
        assert_eq!(back.backend, cfg.backend);
        assert_eq!(back.time_budget, cfg.time_budget);
        assert_eq!(back.num_workers, cfg.num_workers);
        assert_eq!(back.churn, cfg.churn);
        assert_eq!(back.straggler, cfg.straggler);
        assert_eq!(back.eval_every_seconds, cfg.eval_every_seconds);
    }

    #[test]
    fn straggler_section_parses_strictly() {
        let cfg = ExperimentConfig::from_json(
            &Json::parse(
                r#"{"straggler": {"kind": "weibull", "shape": 0.6, "scale": 9.0,
                     "mean_burst": 2.0, "slowdown": 12.0}}"#,
            )
            .unwrap(),
        )
        .unwrap();
        assert_eq!(
            cfg.straggler.kind,
            crate::sim::StragglerKind::WeibullBursts { shape: 0.6, scale: 9.0, mean_burst: 2.0 }
        );
        assert_eq!(cfg.straggler.slowdown, 12.0);
        // unknown straggler keys are rejected, not defaulted
        assert!(ExperimentConfig::from_json(
            &Json::parse(r#"{"straggler": {"kind": "bernoulli", "prob": 0.2}}"#).unwrap()
        )
        .is_err());
        // the legacy flat knobs still work and target the Bernoulli coin
        let cfg = ExperimentConfig::from_json(
            &Json::parse(r#"{"straggler_probability": 0.3, "straggler_slowdown": 6}"#).unwrap(),
        )
        .unwrap();
        assert_eq!(cfg.straggler.probability, 0.3);
        assert_eq!(cfg.straggler.slowdown, 6.0);
        assert_eq!(cfg.straggler.kind, crate::sim::StragglerKind::Bernoulli);
    }

    #[test]
    fn adapt_section_parses_strictly_and_roundtrips() {
        let cfg = ExperimentConfig::from_json(
            &Json::parse(
                r#"{"adapt": {"partition_aware": true, "detection_latency": 0.5,
                     "heal_restart": false}}"#,
            )
            .unwrap(),
        )
        .unwrap();
        assert!(cfg.adapt.partition_aware && cfg.adapt.partitions_allowed());
        assert_eq!(cfg.adapt.detection_latency, 0.5);
        assert!(!cfg.adapt.heal_restart);
        let back =
            ExperimentConfig::from_json(&Json::parse(&cfg.to_json().to_string_compact()).unwrap())
                .unwrap();
        assert_eq!(back.adapt, cfg.adapt);
        // unknown adapt keys are rejected, not defaulted
        assert!(ExperimentConfig::from_json(
            &Json::parse(r#"{"adapt": {"partition_awareness": true}}"#).unwrap()
        )
        .is_err());
        // omitting the section keeps legacy behavior
        let legacy = ExperimentConfig::from_json(&Json::parse("{}").unwrap()).unwrap();
        assert_eq!(legacy.adapt, crate::adapt::AdaptConfig::default());
    }

    #[test]
    fn comm_section_parses_strictly_and_roundtrips() {
        let cfg = ExperimentConfig::from_json(
            &Json::parse(r#"{"comm": {"latency": 0.001, "bandwidth": 1e9}}"#).unwrap(),
        )
        .unwrap();
        assert_eq!(cfg.comm.latency, 0.001);
        assert_eq!(cfg.comm.bandwidth, 1e9);
        let back =
            ExperimentConfig::from_json(&Json::parse(&cfg.to_json().to_string_compact()).unwrap())
                .unwrap();
        assert_eq!(back.comm, cfg.comm);
        // unknown comm keys are rejected, not defaulted
        assert!(ExperimentConfig::from_json(
            &Json::parse(r#"{"comm": {"latency": 0.001, "bandwith": 1e9}}"#).unwrap()
        )
        .is_err());
        // the legacy flat knobs still parse and target the same model
        let legacy = ExperimentConfig::from_json(
            &Json::parse(r#"{"comm_latency": 0.002, "comm_bandwidth": 5e8}"#).unwrap(),
        )
        .unwrap();
        assert_eq!(legacy.comm.latency, 0.002);
        assert_eq!(legacy.comm.bandwidth, 5e8);
        // omitting the section keeps the paper's measured fabric
        let default = ExperimentConfig::from_json(&Json::parse("{}").unwrap()).unwrap();
        assert_eq!(default.comm, crate::sim::CommModel::default());
    }

    #[test]
    fn trace_section_parses_strictly_and_roundtrips() {
        let cfg = ExperimentConfig::from_json(
            &Json::parse(
                r#"{"trace": {"kind": "alibaba", "path": "traces/usage.csv",
                     "map": "top_busiest", "window": [30, 900], "horizon": 20,
                     "threshold": 0.85, "hysteresis": 0.15}}"#,
            )
            .unwrap(),
        )
        .unwrap();
        let tc = cfg.trace.as_ref().expect("trace section parsed");
        assert_eq!(tc.kind, crate::trace::TraceKind::Alibaba);
        assert_eq!(tc.map, crate::trace::MapPolicy::TopBusiest);
        assert_eq!(tc.window, Some((30.0, 900.0)));
        let back =
            ExperimentConfig::from_json(&Json::parse(&cfg.to_json().to_string_compact()).unwrap())
                .unwrap();
        assert_eq!(back.trace, cfg.trace);
        // unknown trace keys are rejected, not defaulted
        assert!(ExperimentConfig::from_json(
            &Json::parse(r#"{"trace": {"kind": "borg", "path": "x.csv", "horzion": 5}}"#).unwrap()
        )
        .is_err());
        // omitting the section keeps the synthetic generators
        let legacy = ExperimentConfig::from_json(&Json::parse("{}").unwrap()).unwrap();
        assert!(legacy.trace.is_none());
        // trace + active churn (or a correlated straggler) is ambiguous
        let mut cfg = cfg;
        cfg.churn = crate::churn::ChurnConfig {
            kind: crate::churn::ChurnKind::FlakyLinks { rate: 1.0, mean_downtime: 1.0 },
            seed: None,
        };
        assert!(cfg.validate().is_err(), "trace replaces churn");
        cfg.churn = crate::churn::ChurnConfig::default();
        cfg.straggler.kind =
            crate::sim::StragglerKind::GilbertElliott { mean_fast: 1.0, mean_slow: 1.0 };
        assert!(cfg.validate().is_err(), "trace replaces the straggler process");
        cfg.straggler = StragglerModel::default();
        cfg.straggler.probability = 0.4;
        assert!(cfg.validate().is_err(), "an unused bernoulli probability is rejected");
        cfg.straggler = StragglerModel::default();
        cfg.straggler.slowdown = 15.0; // the slowdown DOES apply to trace slow states
        cfg.validate().unwrap();
    }

    #[test]
    fn membership_section_parses_strictly_and_roundtrips() {
        let cfg = ExperimentConfig::from_json(
            &Json::parse(
                r#"{"membership": {"population": 200000, "arrival_rate": 2.0,
                     "departure_rate": 0.5, "round_interval": 4.0,
                     "participation": 0.5, "sampling": "sticky",
                     "stickiness": 0.8, "aggregators": 4, "seed": 11},
                    "adapt": {"partition_aware": true, "allow_partitions": true,
                     "detection_latency": 0.1}}"#,
            )
            .unwrap(),
        )
        .unwrap();
        let mc = cfg.membership.as_ref().expect("membership section parsed");
        assert_eq!(mc.population, 200_000);
        assert_eq!(mc.aggregators, 4);
        assert_eq!(mc.seed, Some(11));
        cfg.validate().unwrap();
        let back =
            ExperimentConfig::from_json(&Json::parse(&cfg.to_json().to_string_compact()).unwrap())
                .unwrap();
        assert_eq!(back.membership, cfg.membership);
        // unknown membership keys are rejected, not defaulted
        assert!(ExperimentConfig::from_json(
            &Json::parse(r#"{"membership": {"population": 1000, "particpation": 0.5}}"#).unwrap()
        )
        .is_err());
        // omitting the section keeps the closed-world default
        let legacy = ExperimentConfig::from_json(&Json::parse("{}").unwrap()).unwrap();
        assert!(legacy.membership.is_none());
        // membership without partition-aware adaptivity is rejected
        let mut bad = cfg.clone();
        bad.adapt = crate::adapt::AdaptConfig::default();
        assert!(bad.validate().is_err(), "membership needs allow_partitions");
        // synthetic churn under membership is rejected
        let mut bad = cfg.clone();
        bad.churn.kind =
            crate::churn::ChurnKind::FlakyLinks { rate: 1.0, mean_downtime: 1.0 };
        assert!(bad.validate().is_err(), "synthetic churn incompatible");
        // a population smaller than the slot count is rejected
        let mut bad = cfg;
        bad.membership.as_mut().unwrap().population = 4;
        assert!(bad.validate().is_err(), "population must cover the slots");
    }

    #[test]
    fn fragments_section_parses_strictly_and_roundtrips() {
        let cfg = ExperimentConfig::from_json(
            &Json::parse(
                r#"{"fragments": {"count": 4, "schedule": "stalest_first",
                     "encoding": "f16", "seed": 3}}"#,
            )
            .unwrap(),
        )
        .unwrap();
        assert_eq!(cfg.fragments.count, 4);
        assert_eq!(cfg.fragments.schedule, crate::fragment::ShardSchedule::StalestFirst);
        assert_eq!(cfg.fragments.encoding, crate::fragment::WireEncoding::F16);
        assert_eq!(cfg.fragments.seed, Some(3));
        cfg.validate().unwrap();
        let back =
            ExperimentConfig::from_json(&Json::parse(&cfg.to_json().to_string_compact()).unwrap())
                .unwrap();
        assert_eq!(back.fragments, cfg.fragments);
        // unknown fragments keys are rejected, not defaulted
        assert!(ExperimentConfig::from_json(
            &Json::parse(r#"{"fragments": {"count": 4, "shedule": "round_robin"}}"#).unwrap()
        )
        .is_err());
        // omitting the section keeps the legacy full-vector exchange
        let legacy = ExperimentConfig::from_json(&Json::parse("{}").unwrap()).unwrap();
        assert_eq!(legacy.fragments, crate::fragment::FragmentConfig::default());
        assert!(legacy.fragments.is_passthrough());
    }

    #[test]
    fn stale_section_parses_strictly_and_roundtrips() {
        let cfg = ExperimentConfig::from_json(
            &Json::parse(
                r#"{"stale": {"bound": 6, "depth": 3, "skip": false,
                     "backup": true, "backups": 2, "backup_after": 0.5, "seed": 7}}"#,
            )
            .unwrap(),
        )
        .unwrap();
        assert_eq!(cfg.stale.bound, 6);
        assert_eq!(cfg.stale.depth, 3);
        assert!(!cfg.stale.skip);
        assert_eq!(cfg.stale.backups, 2);
        assert_eq!(cfg.stale.seed, Some(7));
        cfg.validate().unwrap();
        let back =
            ExperimentConfig::from_json(&Json::parse(&cfg.to_json().to_string_compact()).unwrap())
                .unwrap();
        assert_eq!(back.stale, cfg.stale);
        // unknown stale keys are rejected, not defaulted
        assert!(ExperimentConfig::from_json(
            &Json::parse(r#"{"stale": {"bond": 4}}"#).unwrap()
        )
        .is_err());
        // a zero bound is rejected at parse and at validate
        assert!(ExperimentConfig::from_json(
            &Json::parse(r#"{"stale": {"bound": 0}}"#).unwrap()
        )
        .is_err());
        // omitting the section keeps the defaults
        let legacy = ExperimentConfig::from_json(&Json::parse("{}").unwrap()).unwrap();
        assert_eq!(legacy.stale, crate::stale::StaleConfig::default());
    }

    #[test]
    fn apply_kv_routes_single_overrides() {
        let mut cfg = ExperimentConfig::default();
        cfg.apply_kv("num_workers", &Json::from(64usize)).unwrap();
        assert_eq!(cfg.num_workers, 64);
        cfg.apply_kv("model", &Json::from("mlp_tiny")).unwrap();
        assert_eq!(cfg.model, "mlp_tiny");
        assert_eq!(cfg.compute_threads, 1, "serial legacy default");
        cfg.apply_kv("compute_threads", &Json::from(8usize)).unwrap();
        assert_eq!(cfg.compute_threads, 8);
        cfg.apply_kv("compute_threads", &Json::from(0usize)).unwrap();
        assert_eq!(cfg.compute_threads, 0, "0 = auto is a valid setting");
        assert!(cfg.apply_kv("no_such_key", &Json::from(1usize)).is_err());
    }

    #[test]
    fn partial_json_uses_defaults() {
        let cfg =
            ExperimentConfig::from_json(&Json::parse(r#"{"num_workers": 64, "seed": 7}"#).unwrap())
                .unwrap();
        assert_eq!(cfg.num_workers, 64);
        assert_eq!(cfg.seed, 7);
        assert_eq!(cfg.eval_every, ExperimentConfig::default().eval_every);
    }

    #[test]
    fn unknown_key_rejected() {
        assert!(ExperimentConfig::from_json(&Json::parse(r#"{"typo": 1}"#).unwrap()).is_err());
    }

    #[test]
    fn lr_schedule_decays_with_floor() {
        let lr = LrSchedule { eta0: 0.1, decay: 0.5, decay_every: 10, min_lr: 0.01 };
        assert!((lr.at(0) - 0.1).abs() < 1e-9);
        assert!((lr.at(10) - 0.05).abs() < 1e-9);
        assert_eq!(lr.at(1000), 0.01);
    }

    #[test]
    fn seed_for_is_stable_and_distinct() {
        let cfg = ExperimentConfig::default();
        assert_eq!(cfg.seed_for("data"), cfg.seed_for("data"));
        assert_ne!(cfg.seed_for("data"), cfg.seed_for("compute"));
    }

    #[test]
    fn invalid_configs_rejected() {
        let mut cfg = ExperimentConfig::default();
        cfg.num_workers = 1;
        assert!(cfg.validate().is_err());
        let mut cfg = ExperimentConfig::default();
        cfg.straggler.slowdown = 0.5;
        assert!(cfg.validate().is_err());
        let mut cfg = ExperimentConfig::default();
        cfg.churn.kind =
            crate::churn::ChurnKind::Mobile { movers: 0, interval: 1.0, degree: 2 };
        assert!(cfg.validate().is_err(), "churn section is validated too");
    }

    #[test]
    fn backend_tokens_roundtrip() {
        for b in [BackendKind::Pjrt, BackendKind::Quadratic, BackendKind::NativeMlp] {
            assert_eq!(BackendKind::parse(b.token()).unwrap(), b);
        }
        assert!(BackendKind::parse("gpu").is_err());
    }
}
