//! Virtual-time event queue for the cluster simulator.

use crate::WorkerId;
use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// What happened at a point in virtual time.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EventKind {
    /// Worker begins a local gradient computation (the gradient is taken
    /// on the parameters as of this instant — staleness is then whatever
    /// gossip does to the worker's parameters before `ComputeDone`).
    ComputeStart(WorkerId),
    /// Worker finished its local gradient computation.
    ComputeDone(WorkerId),
    /// Periodic time-based evaluation tick (global metrics snapshot);
    /// scheduled by the engine when `eval_every_seconds` is configured
    /// and re-armed while other activity is pending.
    EvalTick,
    /// The communication graph mutates now (churn subsystem): the engine
    /// asks its `ChurnModel` for the due mutations and applies them with
    /// connectivity repair (or without it when the `adapt` config allows
    /// real partitions).
    TopologyChange,
    /// Workers' local component views catch up with ground truth: the
    /// engine promotes the `PartitionMonitor` observations staged
    /// `detection_latency` seconds ago (partition-aware adaptivity).
    PartitionDetect,
    /// A pool user fills this vacant active slot (open-world membership):
    /// the engine re-wires the slot's edges, warm-starts its parameters
    /// from the neighbor average, and starts its compute.
    WorkerJoin(WorkerId),
    /// The occupant of this active slot leaves (open-world membership):
    /// either a rotation leave (user returns to the pool) or a
    /// departure-clock leave (user retires forever); the engine isolates
    /// the slot and retires its parameters either way.
    WorkerLeave(WorkerId),
    /// Periodic participation rotation (open-world membership): the
    /// `MembershipModel` commits which users occupy the edge slots for
    /// the next round and the engine replays the deltas as
    /// `WorkerLeave`/`WorkerJoin` events at this timestamp.
    RoundSample,
}

/// A scheduled event.
#[derive(Debug, Clone, Copy)]
pub struct Event {
    /// Virtual timestamp in seconds.
    pub time: f64,
    /// Tie-break sequence number (FIFO among equal timestamps).
    pub seq: u64,
    /// Payload.
    pub kind: EventKind,
}

impl PartialEq for Event {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}
impl Eq for Event {}

impl Ord for Event {
    fn cmp(&self, other: &Self) -> Ordering {
        // Min-heap semantics: smaller time = greater priority.
        other
            .time
            .partial_cmp(&self.time)
            .unwrap_or(Ordering::Equal)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}
impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// Monotone virtual-time priority queue.
#[derive(Debug, Default)]
pub struct EventQueue {
    heap: BinaryHeap<Event>,
    seq: u64,
    now: f64,
}

impl EventQueue {
    /// Empty queue at t = 0.
    pub fn new() -> Self {
        Self::default()
    }

    /// Current virtual time (timestamp of the last popped event).
    pub fn now(&self) -> f64 {
        self.now
    }

    /// Schedule `kind` at absolute time `t` (clamped to now — time never
    /// goes backwards).
    pub fn schedule(&mut self, t: f64, kind: EventKind) {
        let t = if t < self.now { self.now } else { t };
        debug_assert!(t.is_finite(), "non-finite event time");
        self.heap.push(Event { time: t, seq: self.seq, kind });
        self.seq += 1;
    }

    /// Schedule `kind` after a relative delay.
    pub fn schedule_in(&mut self, delay: f64, kind: EventKind) {
        self.schedule(self.now + delay, kind);
    }

    /// The earliest pending event, without popping it or advancing the
    /// clock.  Returned by value (`Event` is `Copy`) so callers can keep
    /// mutating the queue while holding the peeked head — the engine's
    /// parallel-stepping drain uses this to batch consecutive
    /// same-timestamp `ComputeStart`s.
    pub fn peek(&self) -> Option<Event> {
        self.heap.peek().copied()
    }

    /// Pop the earliest event, advancing the clock.
    pub fn pop(&mut self) -> Option<Event> {
        let ev = self.heap.pop()?;
        debug_assert!(ev.time >= self.now, "virtual time went backwards");
        self.now = ev.time;
        Some(ev)
    }

    /// Number of pending events.
    pub fn len(&self) -> usize {
        self.heap.len()
    }

    /// Whether no events are pending.
    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.schedule(3.0, EventKind::ComputeDone(3));
        q.schedule(1.0, EventKind::ComputeDone(1));
        q.schedule(2.0, EventKind::ComputeDone(2));
        let order: Vec<f64> = std::iter::from_fn(|| q.pop()).map(|e| e.time).collect();
        assert_eq!(order, vec![1.0, 2.0, 3.0]);
    }

    #[test]
    fn fifo_among_ties() {
        let mut q = EventQueue::new();
        q.schedule(1.0, EventKind::ComputeDone(7));
        q.schedule(1.0, EventKind::ComputeDone(8));
        assert_eq!(q.pop().unwrap().kind, EventKind::ComputeDone(7));
        assert_eq!(q.pop().unwrap().kind, EventKind::ComputeDone(8));
    }

    #[test]
    fn clock_monotone_and_clamped() {
        let mut q = EventQueue::new();
        q.schedule(5.0, EventKind::EvalTick);
        q.pop();
        assert_eq!(q.now(), 5.0);
        // scheduling in the past clamps to now
        q.schedule(1.0, EventKind::ComputeDone(0));
        let e = q.pop().unwrap();
        assert_eq!(e.time, 5.0);
        assert_eq!(q.now(), 5.0);
    }

    #[test]
    fn schedule_in_is_relative() {
        let mut q = EventQueue::new();
        q.schedule(2.0, EventKind::EvalTick);
        q.pop();
        q.schedule_in(3.0, EventKind::EvalTick);
        assert_eq!(q.pop().unwrap().time, 5.0);
    }

    #[test]
    fn interleaved_schedule_pop_keeps_fifo_among_equal_timestamps() {
        // FIFO among ties must hold even when scheduling is interleaved
        // with pops at the tied timestamp (the sequence counter is global,
        // not per-push-batch).
        let mut q = EventQueue::new();
        q.schedule(1.0, EventKind::ComputeDone(0));
        q.schedule(1.0, EventKind::ComputeDone(1));
        assert_eq!(q.pop().unwrap().kind, EventKind::ComputeDone(0));
        // clock is now exactly 1.0; new same-time events go behind older ones
        q.schedule(1.0, EventKind::ComputeDone(2));
        q.schedule(1.0, EventKind::TopologyChange);
        assert_eq!(q.pop().unwrap().kind, EventKind::ComputeDone(1));
        assert_eq!(q.pop().unwrap().kind, EventKind::ComputeDone(2));
        assert_eq!(q.pop().unwrap().kind, EventKind::TopologyChange);
        assert_eq!(q.now(), 1.0);
        assert!(q.is_empty());
    }

    #[test]
    fn past_clamping_with_many_events_preserves_order_and_clock() {
        // Advance the clock, then schedule a burst of events whose
        // requested times all lie in the past: every one clamps to `now`,
        // pops in schedule (FIFO) order, and never rewinds the clock.
        let mut q = EventQueue::new();
        q.schedule(10.0, EventKind::EvalTick);
        q.pop();
        assert_eq!(q.now(), 10.0);
        for w in 0..50 {
            q.schedule(w as f64 * 0.1, EventKind::ComputeDone(w)); // all < 10.0
        }
        q.schedule(10.5, EventKind::EvalTick); // one genuine future event
        assert_eq!(q.len(), 51);
        for w in 0..50 {
            let e = q.pop().unwrap();
            assert_eq!(e.time, 10.0, "clamped to now");
            assert_eq!(e.kind, EventKind::ComputeDone(w), "FIFO among clamped");
            assert_eq!(q.now(), 10.0);
        }
        let e = q.pop().unwrap();
        assert_eq!((e.time, e.kind), (10.5, EventKind::EvalTick));
        assert_eq!(q.now(), 10.5);
    }

    #[test]
    fn peek_matches_next_pop_and_leaves_clock_alone() {
        let mut q = EventQueue::new();
        assert!(q.peek().is_none());
        q.schedule(2.0, EventKind::ComputeStart(1));
        q.schedule(1.0, EventKind::ComputeStart(0));
        let head = q.peek().unwrap();
        assert_eq!((head.time, head.kind), (1.0, EventKind::ComputeStart(0)));
        assert_eq!(q.now(), 0.0, "peek must not advance the clock");
        assert_eq!(q.len(), 2, "peek must not consume");
        let popped = q.pop().unwrap();
        assert_eq!((popped.time, popped.seq), (head.time, head.seq));
        let head = q.peek().unwrap();
        assert_eq!((head.time, head.kind), (2.0, EventKind::ComputeStart(1)));
    }

    #[test]
    fn mixed_past_and_future_after_partial_drain() {
        // Clamped events tie with an existing event at `now`-equal time:
        // the earlier-scheduled pending event wins the tie.
        let mut q = EventQueue::new();
        q.schedule(5.0, EventKind::EvalTick);
        q.schedule(5.0, EventKind::ComputeDone(1));
        q.pop(); // now = 5.0, ComputeDone(1) still pending at 5.0
        q.schedule(2.0, EventKind::ComputeDone(2)); // clamps to 5.0
        assert_eq!(q.pop().unwrap().kind, EventKind::ComputeDone(1));
        assert_eq!(q.pop().unwrap().kind, EventKind::ComputeDone(2));
    }
}
